//! Quickstart: optimize a single kernel with KernelBand and inspect the
//! full decision trace.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the public API end-to-end: build the benchmark suite, pick a
//! task, wire a simulated GPU engine + surrogate LLM, run Algorithm 1
//! for T = 20 iterations, and print every (cluster, strategy) decision
//! with its verification verdict and reward.

use kernelband::prelude::*;

fn main() {
    // 1. The workload: a TritonBench-G-like suite (183 kernels).
    let suite = Suite::full(kernelband::eval::EXPERIMENT_SEED);
    // pick an easy normalization kernel (L1-L2) for a readable trace
    let task = suite
        .tasks
        .iter()
        .find(|t| {
            t.category == Category::Normalization
                && t.difficulty <= Difficulty::L2
        })
        .expect("suite has easy normalization kernels");
    println!(
        "optimizing {} [{} / {:?}] — {} benchmark shapes",
        task.name,
        task.category.name(),
        task.difficulty,
        task.shapes.len()
    );

    // 2. The substrates: an H20 roofline simulator and a DeepSeek-V3.2
    //    surrogate. Swap `SimEngine` for `engine::pjrt::PjrtBench` to
    //    measure real Pallas artifacts (see the pjrt_end_to_end example),
    //    or implement `llm::LlmBackend` to call a real API.
    let engine = SimEngine::new(Device::H20);
    let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);

    // 3. The policy: paper defaults (K=3, tau=10, theta=75%, c=2.0).
    let band = KernelBand::new(PolicyConfig::default());
    let trace = band.optimize(task, &engine, &llm, &Rng::new(0));

    // 4. The trace.
    println!("\n t  cluster strategy          verdict reward  best-so-far");
    for r in &trace.records {
        println!(
            "{:>2}  {:^7} {:<17} {}{}      {:.3}   {:.3}x",
            r.t,
            r.cluster,
            r.strategy.map(|s| s.name()).unwrap_or("-"),
            if r.verdict.call_ok { "C" } else { "-" },
            if r.verdict.exec_ok { "E" } else { "-" },
            r.reward,
            r.best_speedup_so_far.max(1.0),
        );
    }

    let outcome = trace.outcome();
    println!(
        "\ncorrect={} best_speedup={:.3}x api_cost=${:.3} ncu_runs={} ({}s)",
        outcome.correct,
        trace.best_speedup(),
        outcome.cost_usd,
        trace.profile_runs,
        trace.profile_cost_s
    );
    println!(
        "best schedule: {:?} (naive was {:?})",
        trace.candidates[trace.best_id].config,
        task.naive_config()
    );
}
