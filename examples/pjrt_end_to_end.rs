//! End-to-end driver over the REAL three-layer stack.
//!
//! ```bash
//! make artifacts && cargo run --release --example pjrt_end_to_end
//! ```
//!
//! This is the reproduction's proof-of-composition: no simulator
//! anywhere. The L1 Pallas kernels (tiled matmul, fused epilogues,
//! row-blocked softmax, fused layernorm, flash attention) were
//! AOT-lowered by `python/compile/aot.py` into HLO-text artifacts; the
//! Rust coordinator loads them through PJRT (`runtime::Runtime`),
//! verifies every variant numerically against its pure-jnp reference
//! artifact (two-stage: call accuracy = executes, execution accuracy =
//! allclose at 1e-4), times them with do_bench-style medians, and runs
//! the same masked-UCB bandit over the variant families that the paper
//! runs over optimization strategies. It also exercises the AOT
//! coordinator kernels: K-means clustering and UCB scoring execute as
//! compiled XLA through PJRT and are parity-checked against the Rust
//! implementations.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use kernelband::bandit::MaskedUcb;
use kernelband::cluster::{ClusterBackend, RustKmeans};
use kernelband::engine::pjrt::PjrtBench;
use kernelband::features::Phi;
use kernelband::rng::Rng;
use kernelband::runtime::{pjrt_ucb_scores, PjrtKmeans, Runtime};
use kernelband::strategy::NUM_STRATEGIES;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let rt = Runtime::load(&dir)?;
    println!(
        "PJRT platform {} | {} AOT artifacts loaded from {dir}/",
        rt.platform(),
        rt.manifest().artifacts.len()
    );

    // --- 1. the kernel-variant search: bandit over real compiled kernels
    let mut bench = PjrtBench::new(&rt);
    let mut rng = Rng::new(0).split("e2e", 0);
    let mut total_best = 0.0f64;
    let mut ops_run = 0;
    for op in rt.manifest().variant_ops() {
        let out = bench.bandit_search(&op, 8, &mut rng)?;
        let verified = out.tried.iter().filter(|v| v.verdict.passed()).count();
        println!(
            "\n[{op}] reference {:.3} ms | {} variants tried, {} verified",
            out.reference_latency_s * 1e3,
            out.evaluations(),
            verified
        );
        for v in &out.tried {
            println!(
                "    {:<30} {}{}  {:>9.3} ms  {:>5.2}x  vmem {:>7} B  mxu {:.2}",
                v.name,
                if v.verdict.call_ok { "C" } else { "-" },
                if v.verdict.exec_ok { "E" } else { "-" },
                v.latency_s * 1e3,
                v.speedup,
                v.vmem_bytes as u64,
                v.mxu_util,
            );
        }
        if let Some(best) = &out.best {
            println!("    BEST {} at {:.2}x vs reference", best.name, best.speedup);
            total_best += best.speedup.ln();
            ops_run += 1;
        }
    }
    println!(
        "\ngeomean best-variant speedup across {ops_run} op families: {:.3}x",
        (total_best / ops_run.max(1) as f64).exp()
    );

    // --- 2. coordinator arithmetic through PJRT: K-means parity
    let mut blob_rng = Rng::new(11);
    let mut points: Vec<Phi> = Vec::new();
    for i in 0..30 {
        let c = if i % 3 == 0 { 0.2 } else if i % 3 == 1 { 0.5 } else { 0.85 };
        points.push([
            c + 0.02 * blob_rng.normal(),
            c + 0.02 * blob_rng.normal(),
            c,
            c,
            c,
        ]);
    }
    let rust = RustKmeans::default().cluster(&points, 3, &mut Rng::new(5));
    let pjrt = PjrtKmeans { runtime: &rt }.cluster(&points, 3, &mut Rng::new(5));
    let agree = rust
        .assign
        .iter()
        .zip(&pjrt.assign)
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "\nK-means parity (Rust vs AOT Pallas via PJRT): {agree}/{} assignments agree",
        points.len()
    );
    assert_eq!(agree, points.len(), "kmeans parity failed");

    // --- 3. masked-UCB scoring through PJRT
    let k = 3;
    let mu: Vec<f64> = (0..k * NUM_STRATEGIES).map(|i| (i as f64) * 0.04).collect();
    let n: Vec<f64> = (0..k * NUM_STRATEGIES).map(|i| 1.0 + (i % 7) as f64).collect();
    let mask: Vec<bool> = (0..k * NUM_STRATEGIES).map(|i| i % 4 != 0).collect();
    let scores = pjrt_ucb_scores(&rt, &mu, &n, 25, &mask, k)?;
    let ucb = MaskedUcb::default();
    let max_err = scores
        .iter()
        .enumerate()
        .filter(|(i, _)| mask[*i])
        .map(|(i, s)| (s - ucb.index(mu[i], n[i], 25.0)).abs())
        .fold(0.0f64, f64::max);
    println!("UCB parity (Rust vs AOT Pallas via PJRT): max |err| = {max_err:.2e}");
    assert!(max_err < 1e-4);

    println!(
        "\nruntime accounting: compile {:.2}s, execute {:.2}s across the run",
        rt.compile_time_s.borrow(),
        rt.execute_time_s.borrow()
    );
    println!("pjrt_end_to_end OK");
    Ok(())
}
