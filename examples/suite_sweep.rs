//! Domain example: full benchmark campaign across devices and methods —
//! the workload the paper's intro motivates (optimizing an LLM-serving
//! kernel zoo for heterogeneous fleet hardware).
//!
//! ```bash
//! cargo run --release --example suite_sweep
//! ```
//!
//! Runs KernelBand, GEAK and Best-of-N over the 50-kernel detailed-
//! analysis subset on all three device profiles, printing per-stratum
//! metrics, per-category winners, and the cross-device strategy-mix
//! shift (the hardware-adaptation evidence of Appendix I).

use std::collections::BTreeMap;

use kernelband::eval::{self, Method};
use kernelband::gpu_model::ALL_DEVICES;
use kernelband::llm::LlmProfile;
use kernelband::metrics::{aggregate, stratified};
use kernelband::policy::PolicyMode;
use kernelband::workload::Suite;

fn main() {
    let suite = Suite::full(eval::EXPERIMENT_SEED).subset50();
    println!(
        "suite: {} kernels, categories: {:?}",
        suite.len(),
        suite.category_counts()
    );
    let methods = [
        Method::BoN,
        Method::Geak,
        Method::KernelBand(PolicyMode::Full, 3),
    ];

    for device in ALL_DEVICES {
        println!("\n=== {} ===", device.name());
        for method in methods {
            let traces = method.run(
                &suite,
                device,
                LlmProfile::DeepSeekV32,
                20,
                eval::EXPERIMENT_SEED,
            );
            let outs = eval::outcomes(&traces);
            let all = aggregate(&outs);
            print!(
                "{:<12} C {:>5.1}%  F {:>5.1}%  G {:>4.2}x  (${:.2} total)  strata:",
                method.name(),
                all.correct_pct,
                all.fast1_pct,
                all.geomean_standard,
                all.total_cost_usd
            );
            for (s, a) in stratified(&outs) {
                if s != kernelband::metrics::Stratum::All {
                    print!(
                        " {}={:.2}x",
                        s.name(),
                        if a.geomean_standard.is_nan() { 1.0 } else { a.geomean_standard }
                    );
                }
            }
            println!();

            // per-category best speedups for KernelBand
            if matches!(method, Method::KernelBand(PolicyMode::Full, _)) {
                let mut by_cat: BTreeMap<&str, (f64, usize)> = BTreeMap::new();
                for (task, o) in suite.tasks.iter().zip(&outs) {
                    let e = by_cat.entry(task.category.name()).or_insert((0.0, 0));
                    e.0 += o.fallback_speedup().ln();
                    e.1 += 1;
                }
                print!("             per-category G: ");
                for (cat, (ls, n)) in &by_cat {
                    print!("{}={:.2} ", cat, (ls / *n as f64).exp());
                }
                println!();
            }
        }
    }

    // hardware adaptation: strategy-mix shift between devices
    println!("\n=== strategy mix by device (KernelBand) ===");
    println!("{:<17} {:>9} {:>9} {:>9}", "Strategy", "RTX 4090", "H20", "A100");
    let mixes: Vec<Vec<(String, f64, f64, f64)>> = ALL_DEVICES
        .iter()
        .map(|&d| {
            let traces = Method::KernelBand(PolicyMode::Full, 3).run(
                &suite,
                d,
                LlmProfile::DeepSeekV32,
                20,
                eval::EXPERIMENT_SEED,
            );
            eval::strategy_stats(&traces)
        })
        .collect();
    for i in 0..mixes[0].len() {
        println!(
            "{:<17} {:>8.1}% {:>8.1}% {:>8.1}%",
            mixes[0][i].0, mixes[0][i].1, mixes[1][i].1, mixes[2][i].1
        );
    }
}
