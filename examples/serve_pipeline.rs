//! Domain example: the batched optimization service (paper §4.4.1).
//!
//! ```bash
//! cargo run --release --example serve_pipeline
//! ```
//!
//! Demonstrates the Figure-3 wall-clock collapse with the real threaded
//! gateway (latencies compressed 1000×): a fleet of concurrent kernel-
//! optimization jobs submit their chained LLM calls to a bounded-queue
//! batching gateway, and the serial 13.4-minute iteration drops to the
//! ~129-second batched pipeline.

use kernelband::service::{
    BatchedLlmGateway, GatewayConfig, OptimizationService, TimeModel,
};

fn main() {
    let tm = TimeModel::default();
    println!("analytic Fig. 3 breakdown:");
    println!(
        "  serial  {:>6.1}s/iter ({:.1} min)",
        tm.serial_iteration_s(),
        tm.serial_iteration_s() / 60.0
    );
    for r in tm.serial_breakdown() {
        println!("    {:<14} {:>6.1}s  {:>5.1}%", r.component, r.seconds, r.percent);
    }
    println!("  batched {:>6.1}s/iter", tm.batched_iteration_s());
    for r in tm.batched_breakdown() {
        println!("    {:<14} {:>6.1}s  {:>5.1}%", r.component, r.seconds, r.percent);
    }

    // live run: sweep fleet sizes and measure the batching win
    println!("\nlive threaded pipeline (1 modeled second = 1 ms wall):");
    println!(
        "{:>5} {:>6} {:>14} {:>16} {:>9} {:>8}",
        "jobs", "iters", "wall (model s)", "serial-equiv (s)", "speedup", "batches"
    );
    for jobs in [1, 4, 16, 50] {
        let report = OptimizationService::default().run(jobs, 3);
        println!(
            "{:>5} {:>6} {:>14.0} {:>16.0} {:>8.1}x {:>8}",
            jobs,
            3,
            report.wall_model_s,
            report.serial_equivalent_s,
            report.batching_speedup(),
            report.gateway_batches
        );
    }

    // backpressure demo: a tiny queue still completes everything
    println!("\nbackpressure: queue_depth=4, 32 concurrent submitters");
    let gw: std::sync::Arc<BatchedLlmGateway<usize>> =
        std::sync::Arc::new(BatchedLlmGateway::spawn(GatewayConfig {
            max_batch: 8,
            window_s: 1.0,
            call_latency_s: 10.0,
            queue_depth: 4,
        }));
    let done: Vec<usize> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..32)
            .map(|i| {
                let g = gw.clone();
                scope.spawn(move || g.call(i).expect("gateway alive"))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    println!(
        "  completed {}/32 requests in {} batches (max batch {})",
        done.len(),
        gw.batches(),
        gw.max_batch_seen()
    );
}
