import os
import sys

# Make `compile.*` importable when pytest is run from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _importable(module_name):
    try:
        __import__(module_name)
        return True
    except Exception:
        return False


# Skip-if-missing guards: the Rust tier-1 pipeline must stay green on
# machines without the JAX/Pallas toolchain, so test modules are only
# collected when their dependencies import cleanly. When JAX is
# available the AOT layer is exercised for real.
collect_ignore = []

if not _importable("jax"):
    collect_ignore += ["test_kernel.py", "test_model_aot.py"]
elif not _importable("hypothesis"):
    # the kernel property sweeps are hypothesis-driven; the AOT tests
    # only need jax + numpy + pytest
    collect_ignore += ["test_kernel.py"]
