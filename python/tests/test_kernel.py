"""Core correctness signal: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/block configurations; data is seeded
random normals (drawn through numpy from a hypothesis-provided seed) so
failures shrink on structure, not on pathological float bit-patterns.
"""

import numpy as np
import pytest
from hypothesis import assume, given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import (attention, kmeans, layernorm, matmul, ref,
                             softmax, ucb)

F_DTYPES = [np.float32, np.float16]


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    mi=st.integers(1, 4), ni=st.integers(1, 4), ki=st.integers(1, 4),
    bm=st.sampled_from([16, 32, 64]), bn=st.sampled_from([16, 32, 64]),
    bk=st.sampled_from([16, 32, 64]),
    dtype=st.sampled_from(F_DTYPES), seed=st.integers(0, 2**32 - 1),
)
def test_matmul_matches_ref(mi, ni, ki, bm, bn, bk, dtype, seed):
    m, n, k = mi * bm, ni * bn, ki * bk
    r = _rng(seed)
    x = r.normal(size=(m, k)).astype(dtype)
    y = r.normal(size=(k, n)).astype(dtype)
    got = matmul.matmul(x, y, bm=bm, bn=bn, bk=bk)
    want = ref.matmul(jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    tile=st.sampled_from([(16, 16, 16), (32, 32, 32), (32, 64, 32),
                          (64, 64, 64)]),
    mult=st.integers(1, 3), seed=st.integers(0, 2**32 - 1),
)
def test_fused_and_unfused_bias_relu_match_ref(tile, mult, seed):
    bm, bn, bk = tile
    m, n, k = mult * bm, mult * bn, mult * bk
    r = _rng(seed)
    x = r.normal(size=(m, k)).astype(np.float32)
    y = r.normal(size=(k, n)).astype(np.float32)
    b = r.normal(size=(n,)).astype(np.float32)
    want = ref.matmul_bias_relu(jnp.asarray(x), jnp.asarray(y), jnp.asarray(b))
    for fn in (matmul.matmul_bias_relu_fused, matmul.matmul_bias_relu_unfused):
        got = fn(x, y, b, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_matmul_rejects_nondividing_tile():
    x = np.zeros((100, 64), np.float32)
    y = np.zeros((64, 64), np.float32)
    with pytest.raises(ValueError):
        matmul.matmul(x, y, bm=64, bn=64, bk=64)


def test_mxu_and_vmem_estimates():
    assert matmul.mxu_utilization(128, 128, 128) == 1.0
    assert matmul.mxu_utilization(32, 128, 8) == pytest.approx(0.25)
    assert matmul.vmem_bytes(64, 64, 64) == 4 * 3 * 64 * 64
    assert matmul.vmem_bytes(64, 64, 64, with_bias=True) \
        == 4 * (3 * 64 * 64 + 64)


# ---------------------------------------------------------------------------
# softmax
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    ri=st.integers(1, 8), c=st.sampled_from([8, 33, 128, 512]),
    br=st.sampled_from([1, 2, 8, 32]), dtype=st.sampled_from(F_DTYPES),
    seed=st.integers(0, 2**32 - 1), scale=st.floats(0.1, 50.0),
)
def test_softmax_matches_ref(ri, c, br, dtype, seed, scale):
    rows = ri * br
    x = (_rng(seed).normal(size=(rows, c)) * scale).astype(dtype)
    got = softmax.softmax_rows(x, br=br)
    want = ref.softmax_rows(jnp.asarray(x, jnp.float32))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    # rows sum to 1
    np.testing.assert_allclose(np.asarray(got).sum(-1), 1.0, rtol=1e-4)


def test_softmax_extreme_values_stable():
    x = np.array([[1e4, -1e4, 0.0, 1e4]], np.float32)
    got = np.asarray(softmax.softmax_rows(x, br=1))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got.sum(), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    ri=st.integers(1, 8), c=st.sampled_from([16, 64, 512]),
    br=st.sampled_from([1, 4, 16]), dtype=st.sampled_from(F_DTYPES),
    seed=st.integers(0, 2**32 - 1),
)
def test_layernorm_matches_ref(ri, c, br, dtype, seed):
    rows = ri * br
    r = _rng(seed)
    x = r.normal(size=(rows, c)).astype(dtype)
    g = r.normal(size=(c,)).astype(np.float32)
    b = r.normal(size=(c,)).astype(np.float32)
    got = layernorm.layernorm(x, g, b, br=br)
    want = ref.layernorm(jnp.asarray(x, jnp.float32), jnp.asarray(g),
                         jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_layernorm_constant_rows():
    # zero-variance rows must not produce NaN (eps guards rsqrt)
    x = np.full((4, 32), 3.5, np.float32)
    g = np.ones(32, np.float32)
    b = np.zeros(32, np.float32)
    got = np.asarray(layernorm.layernorm(x, g, b, br=2))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, 0.0, atol=1e-3)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    qi=st.integers(1, 4), ki=st.integers(1, 4),
    bq=st.sampled_from([16, 32, 64]), bkv=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([16, 64]), seed=st.integers(0, 2**32 - 1),
)
def test_attention_matches_ref(qi, ki, bq, bkv, d, seed):
    sq, sk = qi * bq, ki * bkv
    r = _rng(seed)
    q = r.normal(size=(sq, d)).astype(np.float32)
    k = r.normal(size=(sk, d)).astype(np.float32)
    v = r.normal(size=(sk, d)).astype(np.float32)
    got = attention.attention(q, k, v, bq=bq, bkv=bkv)
    want = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_attention_blocking_invariance():
    # online-softmax recurrence: result independent of KV block size
    r = _rng(7)
    q = r.normal(size=(64, 32)).astype(np.float32)
    k = r.normal(size=(128, 32)).astype(np.float32)
    v = r.normal(size=(128, 32)).astype(np.float32)
    outs = [np.asarray(attention.attention(q, k, v, bq=32, bkv=bkv))
            for bkv in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# kmeans
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 64), k=st.integers(1, 8), d=st.integers(1, 8),
    nvalid=st.integers(1, 64), seed=st.integers(0, 2**32 - 1),
)
def test_kmeans_step_matches_ref(n, k, d, nvalid, seed):
    r = _rng(seed)
    pts = r.normal(size=(n, d)).astype(np.float32)
    cts = pts[r.integers(0, n, size=k)] + 1e-3 * r.normal(size=(k, d)) \
        .astype(np.float32)
    cts = cts.astype(np.float32)
    mask = np.zeros(n, np.float32)
    mask[:min(nvalid, n)] = 1.0
    # The Pallas kernel computes argmin over |c|^2 - 2 p.c (dropping the
    # per-row |p|^2 constant); float rounding can flip the winner when two
    # centroids are near-equidistant from a point. Skip those knife-edge
    # draws — they are measure-zero for real phi(k) frontiers.
    d2 = ((pts[:, None, :].astype(np.float64)
           - cts[None, :, :].astype(np.float64)) ** 2).sum(-1)
    part = np.sort(d2, axis=1)
    if k > 1:
        margin = part[:, 1] - part[:, 0]
        assume((margin > 1e-3 * (1.0 + part[:, 0])).all())
    got_c, got_a = kmeans.kmeans_step(pts, cts, mask)
    want_c, want_a = ref.kmeans_step(jnp.asarray(pts), jnp.asarray(cts),
                                     jnp.asarray(mask))
    np.testing.assert_allclose(got_c, want_c, rtol=1e-4, atol=1e-4)
    assert (np.asarray(got_a) == np.asarray(want_a)).all()


def test_kmeans_empty_cluster_keeps_centroid():
    pts = np.zeros((4, 2), np.float32)
    cts = np.array([[0.0, 0.0], [100.0, 100.0]], np.float32)
    mask = np.ones(4, np.float32)
    new_c, assign = kmeans.kmeans_step(pts, cts, mask)
    assert (np.asarray(assign) == 0).all()
    np.testing.assert_allclose(np.asarray(new_c)[1], [100.0, 100.0])


def test_kmeans_masked_rows_do_not_contribute():
    pts = np.array([[0.0], [0.0], [1000.0]], np.float32)
    cts = np.array([[0.5]], np.float32)
    mask = np.array([1.0, 1.0, 0.0], np.float32)
    new_c, _ = kmeans.kmeans_step(pts, cts, mask)
    np.testing.assert_allclose(np.asarray(new_c), [[0.0]], atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), iters=st.integers(1, 8))
def test_kmeans_run_matches_ref_loop(seed, iters):
    r = _rng(seed)
    pts = r.normal(size=(32, 5)).astype(np.float32)
    cts = pts[:3].copy()
    mask = np.ones(32, np.float32)
    got_c, got_a = kmeans.kmeans_run(pts, cts, mask, iters=iters)
    want_c, want_a = ref.kmeans_run(jnp.asarray(pts), jnp.asarray(cts),
                                    jnp.asarray(mask), iters=iters)
    np.testing.assert_allclose(got_c, want_c, rtol=1e-3, atol=1e-3)


def test_kmeans_run_reduces_inertia():
    r = _rng(11)
    pts = np.concatenate([r.normal(0, 0.3, size=(16, 5)),
                          r.normal(5, 0.3, size=(16, 5))]).astype(np.float32)
    cts = pts[:2].copy()
    mask = np.ones(32, np.float32)

    def inertia(c):
        d2 = ((pts[:, None, :] - np.asarray(c)[None]) ** 2).sum(-1)
        return d2.min(-1).sum()

    final_c, _ = kmeans.kmeans_run(pts, cts, mask, iters=8)
    assert inertia(final_c) <= inertia(cts) + 1e-5


# ---------------------------------------------------------------------------
# masked UCB
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(k=st.integers(1, 8), s=st.integers(1, 8),
       t=st.integers(1, 10_000), seed=st.integers(0, 2**32 - 1))
def test_ucb_matches_ref(k, s, t, seed):
    r = _rng(seed)
    mu = r.uniform(size=(k, s)).astype(np.float32)
    n = r.integers(1, 50, size=(k, s)).astype(np.float32)
    mask = (r.uniform(size=(k, s)) > 0.4).astype(np.float32)
    tt = np.array([[float(t)]], np.float32)
    got = ucb.ucb_scores(mu, n, tt, mask)
    want = ref.ucb_scores(jnp.asarray(mu), jnp.asarray(n), jnp.asarray(tt),
                          jnp.asarray(mask))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ucb_masked_arms_are_neg_inf():
    mu = np.full((2, 3), 0.5, np.float32)
    n = np.ones((2, 3), np.float32)
    mask = np.zeros((2, 3), np.float32)
    mask[0, 1] = 1.0
    got = np.asarray(ucb.ucb_scores(mu, n, np.array([[5.0]], np.float32),
                                    mask))
    assert got[0, 1] > 0.0
    assert (got[mask == 0] <= ref.NEG_INF / 2).all()


def test_ucb_bonus_decreases_with_visits():
    mu = np.zeros((1, 2), np.float32)
    n = np.array([[1.0, 100.0]], np.float32)
    mask = np.ones((1, 2), np.float32)
    got = np.asarray(ucb.ucb_scores(mu, n, np.array([[50.0]], np.float32),
                                    mask))
    assert got[0, 0] > got[0, 1]
