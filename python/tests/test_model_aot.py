"""L2/AOT tests: registry sanity, lowering round-trips, manifest schema."""

import json
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model

REPO = pathlib.Path(__file__).resolve().parents[2]


def test_registry_names_unique():
    arts = model.all_artifacts()
    names = [a.name for a in arts]
    assert len(names) == len(set(names))
    assert len(arts) >= 40


def test_registry_has_all_roles_and_ops():
    arts = model.all_artifacts()
    roles = {a.role for a in arts}
    ops = {a.op for a in arts}
    assert roles == {"coordinator", "variant", "reference"}
    for op in ("kmeans", "ucb", "matmul", "fused", "softmax", "layernorm",
               "attention"):
        assert op in ops, op


def test_every_variant_op_has_reference():
    arts = model.all_artifacts()
    variant_ops = {a.op for a in arts if a.role == "variant"}
    ref_ops = {a.op for a in arts if a.role == "reference"}
    assert variant_ops <= ref_ops


def test_example_args_match_declared_shapes():
    for art in model.all_artifacts():
        args = model.example_args(art)
        assert len(args) == len(art.in_shapes)
        for a, s in zip(args, art.in_shapes):
            assert a.shape == tuple(s[:-1])


@pytest.mark.parametrize("name", ["kmeans_step_k3", "ucb_k3",
                                  "matmul_t64x64x64", "softmax_b32"])
def test_artifact_executes_and_matches_eager(name):
    art = next(a for a in model.all_artifacts() if a.name == name)
    rng = np.random.default_rng(0)
    args = []
    for s in art.in_shapes:
        dims = tuple(s[:-1])
        if s[-1] == "i32":
            args.append(rng.integers(0, 4, dims).astype(np.int32))
        else:
            # keep counts/t positive for ucb
            args.append(np.abs(rng.normal(size=dims)).astype(np.float32) + 0.5)
    eager = art.fn(*[jnp.asarray(a) for a in args])
    jitted = jax.jit(art.fn)(*[jnp.asarray(a) for a in args])
    for e, j in zip(jax.tree.leaves(eager), jax.tree.leaves(jitted)):
        np.testing.assert_allclose(np.asarray(e), np.asarray(j),
                                   rtol=1e-5, atol=1e-5)


def test_to_hlo_text_produces_parseable_module():
    art = next(a for a in model.all_artifacts() if a.name == "ucb_k3")
    text = aot.to_hlo_text(art.fn, model.example_args(art))
    assert "HloModule" in text
    assert "ENTRY" in text


def test_variant_vmem_fits_tpu_budget():
    # structural §Perf check: every variant's per-step VMEM footprint must
    # fit a TPU core's ~16 MiB VMEM with double-buffering headroom.
    for art in model.all_artifacts():
        if art.role == "variant" and art.vmem_bytes:
            assert 2 * art.vmem_bytes < 16 * 2**20, art.name


def test_manifest_on_disk_is_consistent():
    man_path = REPO / "artifacts" / "manifest.json"
    if not man_path.exists():
        pytest.skip("artifacts not built (run `make artifacts`)")
    man = json.loads(man_path.read_text())
    by_name = {a.name: a for a in model.all_artifacts()}
    assert {e["name"] for e in man["artifacts"]} == set(by_name)
    for e in man["artifacts"]:
        art = by_name[e["name"]]
        assert (REPO / "artifacts" / e["file"]).exists()
        assert [tuple(d["dims"]) for d in e["inputs"]] == \
            [tuple(s[:-1]) for s in art.in_shapes]
        assert e["role"] == art.role


def test_flash_attention_variants_agree():
    # all attention block choices compute the same function
    arts = [a for a in model.all_artifacts()
            if a.op == "attention" and a.role == "variant"]
    rng = np.random.default_rng(3)
    q, k, v = (rng.normal(size=(model.AT_S, model.AT_D)).astype(np.float32)
               for _ in range(3))
    outs = [np.asarray(a.fn(jnp.asarray(q), jnp.asarray(k),
                            jnp.asarray(v))[0]) for a in arts]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)
