"""L2: the JAX compute graphs that AOT-lower into ``artifacts/*.hlo.txt``.

Two families:

1. **Coordinator graphs** — the KernelBand decision arithmetic that the
   Rust L3 executes on its hot path via PJRT: the K-means clustering step
   (Pallas), a full fixed-iteration Lloyd loop (lax.scan over the Pallas
   step), and the masked-UCB score matrix (Pallas).

2. **Kernel-variant graphs** — the real-execution search space: for each
   op (matmul, fused epilogue, softmax, layernorm, attention) one graph
   per optimization-strategy configuration (tile sizes, fused/unfused,
   row-block width, flash block pair), plus a pure-jnp reference graph
   used by the Rust verifier as the numerical oracle.

Every entry is a pure function of arrays with static config baked in, so
each lowers to a self-contained HLO module with fixed shapes. The
``ARTIFACTS`` registry is consumed by ``aot.py``; its metadata
(shapes, flops, bytes, VMEM footprint, MXU estimate) lands in
``artifacts/manifest.json`` for the Rust side.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import kmeans as kmeans_k
from .kernels import layernorm as ln_k
from .kernels import matmul as mm_k
from .kernels import ref
from .kernels import softmax as sm_k
from .kernels import ucb as ucb_k

# Frontier capacity for clustering artifacts: the paper's budget is
# T<=40 iterations, so |P_t| <= 41 < 64; rows beyond the live frontier
# are masked out.
N_POINTS = 64
N_FEATURES = 5  # phi(k) is 5-dimensional (paper Eq. 4)
N_STRATEGIES = 6  # |S| = 6 (paper §3.6)
LLOYD_ITERS = 8

# Kernel-under-optimization problem sizes (kept small enough that
# interpret-mode execution is fast but large enough that tile choices
# change measured latency).
MM_M, MM_K, MM_N = 256, 256, 256
SM_R, SM_C = 256, 512
LN_R, LN_C = 256, 512
AT_S, AT_D = 128, 64


@dataclasses.dataclass(frozen=True)
class Artifact:
    """One AOT-lowered HLO module and its manifest metadata."""

    name: str
    fn: Callable  # positional array args; returns a tuple of arrays
    in_shapes: Sequence[tuple]  # [(dims..., dtype_str), ...]
    out_shapes: Sequence[tuple]
    op: str  # op family: kmeans | ucb | matmul | fused | softmax | ...
    role: str  # "coordinator" | "variant" | "reference"
    params: dict  # strategy configuration baked into the graph
    flops: int = 0
    hbm_bytes: int = 0  # minimal HBM traffic of the algorithm
    vmem_bytes: int = 0  # per-grid-step VMEM footprint (f32)
    mxu_util: float = 0.0  # structural MXU utilization estimate


def _shapes(*specs):
    return [tuple(list(s) + ["f32"]) for s in specs]


# ---------------------------------------------------------------------------
# Coordinator graphs
# ---------------------------------------------------------------------------

def _kmeans_step_fn(points, cents, mask):
    return kmeans_k.kmeans_step(points, cents, mask)


def _kmeans_run_fn(points, cents, mask):
    return kmeans_k.kmeans_run(points, cents, mask, iters=LLOYD_ITERS)


def _ucb_fn(mu, n, t, mask):
    return (ucb_k.ucb_scores(mu, n, t, mask, c=2.0),)


def coordinator_artifacts() -> list[Artifact]:
    arts = []
    for k in (1, 2, 3, 5, 8):
        arts.append(Artifact(
            name=f"kmeans_step_k{k}",
            fn=_kmeans_step_fn,
            in_shapes=_shapes((N_POINTS, N_FEATURES), (k, N_FEATURES),
                              (N_POINTS,)),
            out_shapes=[(k, N_FEATURES, "f32"), (N_POINTS, "i32")],
            op="kmeans", role="coordinator",
            params={"k": k, "n": N_POINTS, "d": N_FEATURES},
            flops=3 * N_POINTS * k * N_FEATURES,
            hbm_bytes=4 * (N_POINTS * N_FEATURES + 2 * k * N_FEATURES
                           + 2 * N_POINTS),
        ))
        arts.append(Artifact(
            name=f"kmeans_run_k{k}",
            fn=_kmeans_run_fn,
            in_shapes=_shapes((N_POINTS, N_FEATURES), (k, N_FEATURES),
                              (N_POINTS,)),
            out_shapes=[(k, N_FEATURES, "f32"), (N_POINTS, "i32")],
            op="kmeans_run", role="coordinator",
            params={"k": k, "iters": LLOYD_ITERS},
            flops=3 * N_POINTS * k * N_FEATURES * (LLOYD_ITERS + 1),
        ))
        arts.append(Artifact(
            name=f"ucb_k{k}",
            fn=_ucb_fn,
            in_shapes=_shapes((k, N_STRATEGIES), (k, N_STRATEGIES), (1, 1),
                              (k, N_STRATEGIES)),
            out_shapes=[(k, N_STRATEGIES, "f32")],
            op="ucb", role="coordinator",
            params={"k": k, "s": N_STRATEGIES, "c": 2.0},
        ))
    return arts


# ---------------------------------------------------------------------------
# Kernel-variant graphs
# ---------------------------------------------------------------------------

MATMUL_TILES = [
    (32, 32, 32), (32, 64, 32), (64, 64, 32), (64, 64, 64),
    (64, 128, 64), (128, 64, 64), (128, 128, 64), (128, 128, 128),
    (256, 256, 256),  # single-block / "no tiling" baseline
]
FUSED_TILES = [(32, 32, 32), (64, 64, 64), (128, 128, 64)]
SOFTMAX_BLOCKS = [8, 16, 32, 64, 128]
LAYERNORM_BLOCKS = [8, 16, 32, 64]
ATTENTION_BLOCKS = [(32, 32), (32, 64), (64, 64), (64, 128), (128, 128)]

_MM_FLOPS = 2 * MM_M * MM_K * MM_N
_MM_BYTES = 4 * (MM_M * MM_K + MM_K * MM_N + MM_M * MM_N)


def variant_artifacts() -> list[Artifact]:
    arts = []

    # --- matmul: TILING strategy ---
    for (bm, bn, bk) in MATMUL_TILES:
        fn = functools.partial(
            lambda x, y, bm, bn, bk: (mm_k.matmul(x, y, bm=bm, bn=bn, bk=bk),),
            bm=bm, bn=bn, bk=bk)
        arts.append(Artifact(
            name=f"matmul_t{bm}x{bn}x{bk}", fn=fn,
            in_shapes=_shapes((MM_M, MM_K), (MM_K, MM_N)),
            out_shapes=[(MM_M, MM_N, "f32")],
            op="matmul", role="variant",
            params={"bm": bm, "bn": bn, "bk": bk, "strategy": "tiling"},
            flops=_MM_FLOPS, hbm_bytes=_MM_BYTES,
            vmem_bytes=mm_k.vmem_bytes(bm, bn, bk),
            mxu_util=mm_k.mxu_utilization(bm, bn, bk),
        ))
    arts.append(Artifact(
        name="matmul_ref", fn=lambda x, y: (ref.matmul(x, y),),
        in_shapes=_shapes((MM_M, MM_K), (MM_K, MM_N)),
        out_shapes=[(MM_M, MM_N, "f32")],
        op="matmul", role="reference", params={},
        flops=_MM_FLOPS, hbm_bytes=_MM_BYTES,
    ))

    # --- fused epilogue: FUSION strategy ---
    fused_bytes = _MM_BYTES + 4 * MM_N
    unfused_bytes = fused_bytes + 2 * 4 * MM_M * MM_N  # extra HBM round-trip
    for (bm, bn, bk) in FUSED_TILES:
        fn_f = functools.partial(
            lambda x, y, b, bm, bn, bk:
            (mm_k.matmul_bias_relu_fused(x, y, b, bm=bm, bn=bn, bk=bk),),
            bm=bm, bn=bn, bk=bk)
        fn_u = functools.partial(
            lambda x, y, b, bm, bn, bk:
            (mm_k.matmul_bias_relu_unfused(x, y, b, bm=bm, bn=bn, bk=bk),),
            bm=bm, bn=bn, bk=bk)
        common = dict(
            in_shapes=_shapes((MM_M, MM_K), (MM_K, MM_N), (MM_N,)),
            out_shapes=[(MM_M, MM_N, "f32")], op="fused",
            flops=_MM_FLOPS + 2 * MM_M * MM_N,
            vmem_bytes=mm_k.vmem_bytes(bm, bn, bk, with_bias=True),
            mxu_util=mm_k.mxu_utilization(bm, bn, bk),
        )
        arts.append(Artifact(
            name=f"fused_bias_relu_t{bm}x{bn}x{bk}", fn=fn_f, role="variant",
            params={"bm": bm, "bn": bn, "bk": bk, "fused": True,
                    "strategy": "fusion"},
            hbm_bytes=fused_bytes, **common))
        arts.append(Artifact(
            name=f"unfused_bias_relu_t{bm}x{bn}x{bk}", fn=fn_u,
            role="variant",
            params={"bm": bm, "bn": bn, "bk": bk, "fused": False,
                    "strategy": "fusion"},
            hbm_bytes=unfused_bytes, **common))
    arts.append(Artifact(
        name="fused_bias_relu_ref",
        fn=lambda x, y, b: (ref.matmul_bias_relu(x, y, b),),
        in_shapes=_shapes((MM_M, MM_K), (MM_K, MM_N), (MM_N,)),
        out_shapes=[(MM_M, MM_N, "f32")],
        op="fused", role="reference", params={},
        flops=_MM_FLOPS + 2 * MM_M * MM_N, hbm_bytes=fused_bytes,
    ))

    # --- softmax: VECTORIZATION / row-panel width ---
    sm_bytes = 2 * 4 * SM_R * SM_C
    for br in SOFTMAX_BLOCKS:
        fn = functools.partial(lambda x, br: (sm_k.softmax_rows(x, br=br),),
                               br=br)
        arts.append(Artifact(
            name=f"softmax_b{br}", fn=fn,
            in_shapes=_shapes((SM_R, SM_C)),
            out_shapes=[(SM_R, SM_C, "f32")],
            op="softmax", role="variant",
            params={"br": br, "strategy": "vectorization"},
            flops=5 * SM_R * SM_C, hbm_bytes=sm_bytes,
            vmem_bytes=2 * 4 * br * SM_C,
        ))
    arts.append(Artifact(
        name="softmax_ref", fn=lambda x: (ref.softmax_rows(x),),
        in_shapes=_shapes((SM_R, SM_C)), out_shapes=[(SM_R, SM_C, "f32")],
        op="softmax", role="reference", params={},
        flops=5 * SM_R * SM_C, hbm_bytes=sm_bytes,
    ))

    # --- layernorm: FUSION (single-pass) ---
    ln_bytes = 2 * 4 * LN_R * LN_C + 2 * 4 * LN_C
    for br in LAYERNORM_BLOCKS:
        fn = functools.partial(
            lambda x, g, b, br: (ln_k.layernorm(x, g, b, br=br),), br=br)
        arts.append(Artifact(
            name=f"layernorm_b{br}", fn=fn,
            in_shapes=_shapes((LN_R, LN_C), (LN_C,), (LN_C,)),
            out_shapes=[(LN_R, LN_C, "f32")],
            op="layernorm", role="variant",
            params={"br": br, "fused": True, "strategy": "fusion"},
            flops=8 * LN_R * LN_C, hbm_bytes=ln_bytes,
            vmem_bytes=2 * 4 * br * LN_C + 2 * 4 * LN_C,
        ))
    arts.append(Artifact(
        name="layernorm_ref", fn=lambda x, g, b: (ref.layernorm(x, g, b),),
        in_shapes=_shapes((LN_R, LN_C), (LN_C,), (LN_C,)),
        out_shapes=[(LN_R, LN_C, "f32")],
        op="layernorm", role="reference", params={},
        flops=8 * LN_R * LN_C, hbm_bytes=ln_bytes,
    ))

    # --- attention: TILING + PIPELINE (flash blocking) ---
    at_bytes = 4 * 4 * AT_S * AT_D
    at_flops = 4 * AT_S * AT_S * AT_D
    for (bq, bkv) in ATTENTION_BLOCKS:
        fn = functools.partial(
            lambda q, k, v, bq, bkv:
            (attn_k.attention(q, k, v, bq=bq, bkv=bkv),), bq=bq, bkv=bkv)
        arts.append(Artifact(
            name=f"attention_q{bq}k{bkv}", fn=fn,
            in_shapes=_shapes((AT_S, AT_D), (AT_S, AT_D), (AT_S, AT_D)),
            out_shapes=[(AT_S, AT_D, "f32")],
            op="attention", role="variant",
            params={"bq": bq, "bkv": bkv, "strategy": "tiling"},
            flops=at_flops, hbm_bytes=at_bytes,
            vmem_bytes=4 * (bq * AT_D * 2 + bkv * AT_D * 2 + bq * bkv
                            + 2 * bq),
            mxu_util=(min(bq, 128) / 128.0) * (min(bkv, 128) / 128.0),
        ))
    arts.append(Artifact(
        name="attention_ref", fn=lambda q, k, v: (ref.attention(q, k, v),),
        in_shapes=_shapes((AT_S, AT_D), (AT_S, AT_D), (AT_S, AT_D)),
        out_shapes=[(AT_S, AT_D, "f32")],
        op="attention", role="reference", params={},
        flops=at_flops, hbm_bytes=at_bytes,
    ))
    return arts


def all_artifacts() -> list[Artifact]:
    return coordinator_artifacts() + variant_artifacts()


_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def example_args(art: Artifact):
    """ShapeDtypeStructs used by jax.jit(...).lower for an artifact."""
    return [jax.ShapeDtypeStruct(tuple(s[:-1]), _DTYPES[s[-1]])
            for s in art.in_shapes]
