"""Pallas kernel: fused row LayerNorm (mean/var/normalize/affine in one pass).

FUSION showcase for normalization kernels: the fused variant computes the
row statistics and the affine transform while the (br, C) panel is VMEM-
resident (1 read + 1 write per element); the unfused baseline is the
3-pass pure-jnp composition that bounces intermediates through HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-5


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    o_ref[...] = xc * jax.lax.rsqrt(var + EPS) * g_ref[...][None, :] \
        + b_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("br",))
def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, *,
              br: int = 32):
    """Fused layernorm over (R, C) with affine (C,) params."""
    r, c = x.shape
    if r % br:
        raise ValueError(f"row block {br} must divide rows {r}")
    return pl.pallas_call(
        _layernorm_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), gamma.astype(jnp.float32),
      beta.astype(jnp.float32))
