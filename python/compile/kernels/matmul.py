"""Pallas kernels: tiled matmul and the fused matmul+bias+relu epilogue.

These are the "kernels under optimization" for the real-execution engine:
each (bm, bn, bk) tile choice — the paper's TILING strategy — and the
fused-vs-unfused epilogue — the FUSION strategy — lowers to a distinct
HLO artifact that the Rust coordinator loads, times and verifies via PJRT.

TPU mapping (DESIGN.md §Hardware-Adaptation): the tile triple is the
``BlockSpec`` that schedules HBM->VMEM transfers; MXU-friendly variants
keep bm/bn multiples of 128 and bk multiples of 8. VMEM footprint per
grid step is (bm*bk + bk*bn + bm*bn) * 4 bytes and is reported in the
AOT manifest for the §Perf roofline estimate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                          preferred_element_type=jnp.float32)


def _matmul_bias_relu_kernel(x_ref, y_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        o_ref[...] = jnp.maximum(o_ref[...] + b_ref[...][None, :], 0.0)


def _check_tiles(m, n, k, bm, bn, bk):
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"tile ({bm},{bn},{bk}) must divide problem ({m},{n},{k})")


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 64, bn: int = 64,
           bk: int = 64):
    """Tiled (M,K)@(K,N) matmul. Grid (M/bm, N/bn, K/bk), K innermost."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (k, k2)
    _check_tiles(m, n, k, bm, bn, bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_bias_relu_fused(x: jax.Array, y: jax.Array, b: jax.Array, *,
                           bm: int = 64, bn: int = 64, bk: int = 64):
    """FUSION variant: relu(x@y + b) in one kernel — the bias/relu epilogue
    runs on the last K step while the (bm,bn) tile is still resident in
    VMEM, eliminating one full (M,N) HBM round-trip."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2 and b.shape == (n,)
    _check_tiles(m, n, k, bm, bn, bk)
    return pl.pallas_call(
        _matmul_bias_relu_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32), b.astype(jnp.float32))


def _bias_relu_kernel(x_ref, b_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...] + b_ref[...][None, :], 0.0)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_bias_relu_unfused(x, y, b, *, bm: int = 64, bn: int = 64,
                             bk: int = 64):
    """Unfused baseline for the FUSION strategy: two pallas_calls with the
    (M,N) intermediate bounced through HBM."""
    m, _ = x.shape
    _, n = y.shape
    z = matmul(x, y, bm=bm, bn=bn, bk=bk)
    return pl.pallas_call(
        _bias_relu_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(z, b.astype(jnp.float32))


def vmem_bytes(bm: int, bn: int, bk: int, with_bias: bool = False) -> int:
    """Per-grid-step VMEM footprint of the tiled matmul (f32)."""
    elems = bm * bk + bk * bn + bm * bn + (bn if with_bias else 0)
    return 4 * elems


def mxu_utilization(bm: int, bn: int, bk: int) -> float:
    """Fraction of the 128x128 MXU systolic array a (bm,bn,bk) tile keeps
    busy — the §Perf structural estimate (min(dim,128)/128 per axis)."""
    return (min(bm, 128) / 128.0) * (min(bn, 128) / 128.0) * min(bk / 8.0, 1.0)
