"""Pallas kernel: masked UCB index matrix (paper Eq. 6).

score[i,s] = mu_hat[i,s] + c * sqrt(ln t / N[i,s])   if  M[i,s] == 1
           = -inf                                     otherwise

The (K, S) arm matrix is tiny (K<=8, S=6) so the kernel is a single
block; it exists so the bandit's scoring — like the K-means step — is an
AOT artifact the Rust coordinator can execute through PJRT, keeping the
entire decision arithmetic in compiled XLA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ucb_kernel(c, mu_ref, n_ref, t_ref, mask_ref, out_ref):
    t = jnp.maximum(t_ref[0, 0], 1.0)
    n = jnp.maximum(n_ref[...], 1.0)
    bonus = c * jnp.sqrt(jnp.log(t) / n)
    out_ref[...] = jnp.where(mask_ref[...] > 0, mu_ref[...] + bonus, NEG_INF)


@functools.partial(jax.jit, static_argnames=("c",))
def ucb_scores(mu: jax.Array, n: jax.Array, t: jax.Array, mask: jax.Array,
               *, c: float = 2.0):
    """Masked UCB scores. mu/n/mask: (K,S) f32; t: (1,1) f32."""
    k, s = mu.shape
    kern = functools.partial(_ucb_kernel, c)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((k, s), jnp.float32),
        interpret=True,
    )(mu.astype(jnp.float32), n.astype(jnp.float32),
      t.astype(jnp.float32), mask.astype(jnp.float32))
