"""Pallas kernel: blocked (flash-style) single-head attention.

The (bq, bkv) block pair is the TILING + PIPELINE knob for attention: the
KV sequence is streamed in bkv-sized chunks with the online-softmax
running max/denominator kept in VMEM-resident accumulator outputs (the
classic Flash recurrence), so the (Sq, Sk) score matrix never
materializes in HBM.

Interpret-mode note: accumulators live in extra *outputs* rather than
scratch refs — outputs persist across grid steps under revisiting, which
is the portable pattern for interpret=True; the wrapper discards them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _attn_kernel(scale, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref):
    kidx = pl.program_id(1)

    @pl.when(kidx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]  # (bq, d)
    k = k_ref[...]  # (bkv, d)
    v = v_ref[...]  # (bkv, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m_prev = m_ref[...]  # (bq, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = o_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(kidx == pl.num_programs(1) - 1)
    def _final():
        o_ref[...] = o_ref[...] / l_ref[...]


@functools.partial(jax.jit, static_argnames=("bq", "bkv"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, bq: int = 64,
              bkv: int = 64):
    """Flash attention over (Sq,d), (Sk,d), (Sk,d); grid (Sq/bq, Sk/bkv)."""
    sq, d = q.shape
    sk, d2 = k.shape
    assert d == d2 and v.shape == (sk, d)
    if sq % bq or sk % bkv:
        raise ValueError(f"blocks ({bq},{bkv}) must divide ({sq},{sk})")
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_attn_kernel, scale)
    o, _m, _l = pl.pallas_call(
        kern,
        grid=(sq // bq, sk // bkv),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bkv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bkv, d), lambda i, j: (j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((sq, d), jnp.float32),
            jax.ShapeDtypeStruct((sq, 1), jnp.float32),
            jax.ShapeDtypeStruct((sq, 1), jnp.float32),
        ),
        interpret=True,
    )(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    return o
