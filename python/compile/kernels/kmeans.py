"""Pallas kernel: one masked K-means (Lloyd) step.

This is the trace-driven-clustering hot-spot of KernelBand (§3.3): the
frontier's behavioral feature vectors phi(k) are re-clustered every tau
iterations. The whole step — pairwise distances, argmin assignment,
masked centroid update with empty-cluster fallback — runs as a single
Pallas block (the frontier is small: N <= 64, D = 5, K <= 8), so the
HBM<->VMEM traffic is one load of points/centroids and one store of the
results.

Run with ``interpret=True`` everywhere: CPU PJRT cannot execute Mosaic
custom-calls (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmeans_kernel(points_ref, cents_ref, mask_ref, newc_ref, assign_ref):
    pts = points_ref[...]  # (N, D)
    cts = cents_ref[...]  # (K, D)
    msk = mask_ref[...]  # (N, 1)

    # Pairwise squared distances via |p|^2 - 2 p.c + |c|^2 (one MXU matmul
    # instead of an (N,K,D) broadcast — this is the vectorization-friendly
    # form; the |p|^2 term is constant per row and dropped from the argmin).
    cross = pts @ cts.T  # (N, K)
    c2 = jnp.sum(cts * cts, axis=-1)  # (K,)
    d2 = c2[None, :] - 2.0 * cross  # argmin-equivalent distances
    assign = jnp.argmin(d2, axis=-1).astype(jnp.int32)  # (N,)

    k = cts.shape[0]
    onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(pts.dtype)
    onehot = onehot * msk  # zero out padded rows
    counts = jnp.sum(onehot, axis=0)  # (K,)
    sums = onehot.T @ pts  # (K, D)
    newc = sums / jnp.maximum(counts, 1.0)[:, None]
    newc_ref[...] = jnp.where(counts[:, None] > 0, newc, cts)
    assign_ref[...] = jnp.where(msk[:, 0] > 0, assign, 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def kmeans_step(points: jax.Array, centroids: jax.Array, mask: jax.Array):
    """One Lloyd step. Shapes: points (N,D), centroids (K,D), mask (N,).

    Returns (new_centroids (K,D) f32, assignment (N,) i32). Matches
    ``ref.kmeans_step`` exactly up to float error.
    """
    n, _d = points.shape
    k, d = centroids.shape
    newc, assign = pl.pallas_call(
        _kmeans_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ),
        interpret=True,
    )(points.astype(jnp.float32), centroids.astype(jnp.float32),
      mask.astype(jnp.float32).reshape(n, 1))
    return newc, assign


def kmeans_run(points, centroids, mask, iters: int = 8):
    """Fixed-iteration Lloyd loop over the Pallas step (L2 composition)."""

    def body(c, _):
        new_c, _a = kmeans_step(points, c, mask)
        return new_c, None

    final_c, _ = jax.lax.scan(body, centroids, None, length=iters)
    _, assign = kmeans_step(points, final_c, mask)
    return final_c, assign
