# L1: Pallas kernels for KernelBand's compute hot-spots.
#
# Coordinator-side hot-spots (execute on the decision path via PJRT):
#   kmeans    — trace-driven clustering step (paper §3.3)
#   ucb       — masked UCB index matrix (paper Eq. 6)
# Kernels-under-optimization (the real-execution variant space):
#   matmul    — tiled matmul + fused/unfused bias-relu epilogue
#   softmax   — row-blocked stable softmax
#   layernorm — fused layernorm
#   attention — blocked flash-style attention
# ref       — pure-jnp oracles for all of the above.

from . import attention, kmeans, layernorm, matmul, ref, softmax, ucb  # noqa: F401
