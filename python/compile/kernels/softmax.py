"""Pallas kernel: row-blocked numerically-stable softmax.

The row-block size ``br`` is the VECTORIZATION / ACCESS & LAYOUT knob for
this memory-bound kernel: each grid step streams a (br, C) panel through
VMEM, computes the stable softmax entirely on-chip and writes it back —
one HBM read + one write per element (optimal traffic); ``br`` trades
VMEM footprint against grid overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("br",))
def softmax_rows(x: jax.Array, *, br: int = 32):
    """Row softmax over (R, C); grid over R/br row panels."""
    r, c = x.shape
    if r % br:
        raise ValueError(f"row block {br} must divide rows {r}")
    return pl.pallas_call(
        _softmax_kernel,
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
