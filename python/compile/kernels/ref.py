"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth for build-time correctness: pytest checks each
Pallas kernel (interpret=True) against the function here with
``assert_allclose``, and ``aot.py`` additionally emits each reference as
its own HLO artifact so the Rust coordinator can verify variant outputs
numerically at runtime (two-stage verification, paper §4.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# K-means (clustering substrate, paper §3.3)
# ---------------------------------------------------------------------------

def kmeans_step(points: jax.Array, centroids: jax.Array, mask: jax.Array):
    """One Lloyd iteration.

    Args:
      points:    (N, D) float32 feature vectors phi(k).
      centroids: (K, D) float32 current centroids.
      mask:      (N,)   float32, 1.0 for valid rows, 0.0 for padding.

    Returns:
      (new_centroids (K, D), assignment (N,) int32). Padded rows are
      assigned cluster 0 but contribute nothing to the update; empty
      clusters keep their previous centroid.
    """
    d2 = jnp.sum((points[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
    assign = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    onehot = jax.nn.one_hot(assign, centroids.shape[0], dtype=points.dtype)
    onehot = onehot * mask[:, None]
    counts = jnp.sum(onehot, axis=0)  # (K,)
    sums = onehot.T @ points  # (K, D)
    new_c = sums / jnp.maximum(counts, 1.0)[:, None]
    new_c = jnp.where(counts[:, None] > 0, new_c, centroids)
    assign = jnp.where(mask > 0, assign, 0).astype(jnp.int32)
    return new_c, assign


def kmeans_run(points, centroids, mask, iters: int = 8):
    """Full (fixed-iteration) Lloyd loop via lax.scan — L2 composition."""

    def body(c, _):
        new_c, _a = kmeans_step(points, c, mask)
        return new_c, None

    final_c, _ = jax.lax.scan(body, centroids, None, length=iters)
    _, assign = kmeans_step(points, final_c, mask)
    return final_c, assign


# ---------------------------------------------------------------------------
# Masked UCB scores (paper Eq. 6)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def ucb_scores(mu: jax.Array, n: jax.Array, t: jax.Array, mask: jax.Array,
               c: float = 2.0):
    """Masked UCB index matrix.

    score[i,s] = mu[i,s] + c*sqrt(ln(t)/n[i,s]) where mask==1, else -inf.
    ``t`` is a (1,1) float32 array (iteration counter, >= 1).
    """
    bonus = c * jnp.sqrt(jnp.log(jnp.maximum(t, 1.0)) / jnp.maximum(n, 1.0))
    return jnp.where(mask > 0, mu + bonus, NEG_INF)


# ---------------------------------------------------------------------------
# Kernels-under-optimization (the TritonBench-G stand-ins)
# ---------------------------------------------------------------------------

def matmul(x: jax.Array, y: jax.Array):
    """(M,K) @ (K,N) in f32."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def matmul_bias_relu(x: jax.Array, y: jax.Array, b: jax.Array):
    """Fused epilogue target: relu(x @ y + b)."""
    return jnp.maximum(matmul(x, y) + b[None, :], 0.0)


def softmax_rows(x: jax.Array):
    """Numerically-stable row softmax."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5):
    """Row layernorm with affine params."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma[None, :] + beta[None, :]


def attention(q: jax.Array, k: jax.Array, v: jax.Array):
    """Single-head scaled dot-product attention, (S,d) inputs."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    s = (q @ k.T) * scale
    return softmax_rows(s) @ v
