"""AOT pipeline: lower every registered L2 graph to HLO *text* artifacts.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every graph is lowered with ``return_tuple=True`` so the Rust runtime
always unwraps a tuple, regardless of arity.

Outputs:
  artifacts/<name>.hlo.txt      one module per registry entry
  artifacts/manifest.json       shapes + op/role/params + perf metadata

Usage: cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, args) -> str:
    """jitted fn + example args -> HLO text via stablehlo."""
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts",
                   help="output directory for *.hlo.txt + manifest.json")
    p.add_argument("--only", default=None,
                   help="comma-separated artifact-name filter (substring)")
    ns = p.parse_args(argv)

    out = pathlib.Path(ns.out)
    out.mkdir(parents=True, exist_ok=True)
    filters = ns.only.split(",") if ns.only else None

    manifest = {"version": 1, "artifacts": []}
    arts = model.all_artifacts()
    for i, art in enumerate(arts):
        if filters and not any(f in art.name for f in filters):
            continue
        text = to_hlo_text(art.fn, model.example_args(art))
        path = out / f"{art.name}.hlo.txt"
        path.write_text(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"].append({
            "name": art.name,
            "file": path.name,
            "op": art.op,
            "role": art.role,
            "params": art.params,
            "inputs": [{"dims": list(s[:-1]), "dtype": s[-1]}
                       for s in art.in_shapes],
            "outputs": [{"dims": list(s[:-1]), "dtype": s[-1]}
                        for s in art.out_shapes],
            "flops": art.flops,
            "hbm_bytes": art.hbm_bytes,
            "vmem_bytes": art.vmem_bytes,
            "mxu_util": art.mxu_util,
            "sha256_16": digest,
        })
        print(f"[{i + 1}/{len(arts)}] {art.name}: {len(text)} chars")

    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(manifest['artifacts'])} artifacts to {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
