#!/usr/bin/env python3
"""Diff fresh PERF_*.json bench artifacts against committed baselines.

Usage: perf_trend.py BASELINE_DIR CURRENT_DIR [--threshold PCT]

For every PERF_<suite>.json in CURRENT_DIR, looks up the committed
snapshot of the same name in BASELINE_DIR and prints a Markdown
regression table (entry, baseline items/sec, current items/sec, delta)
plus the suites' derived speedup fields. Entries regressing more than
--threshold percent (default 25) are flagged.

This is a *gate*: the CI step that runs it is blocking. The script
exits 1 when any throughput entry regresses past the threshold, when
any derived speedup ratio falls more than the threshold below its
committed floor, or when a committed baseline still carries
"pending": true (no numbers captured yet — diff impossible, so
recording forever would hide regressions).

Absolute entry throughput is machine-dependent, so the committed
baselines may legitimately ship with empty "entries" and gate only on
the derived ratios, which compare two paths measured by the same
binary on the same machine and are therefore portable floors.

Refreshing a baseline: download the `baselines-refresh` artifact from
a CI perf-smoke run on main (built by scripts/refresh_baselines.py
with "pending": false) and commit its PERF_<suite>.json files over
perf/baselines/. Subsequent runs diff instead of recording.
"""

import json
import sys
from pathlib import Path


def entry_rates(doc):
    """name -> items_per_sec for every entry that reports throughput."""
    rates = {}
    for e in doc.get("entries", []):
        if "items_per_sec" in e:
            rates[e["name"]] = float(e["items_per_sec"])
    return rates


def derived_fields(doc):
    """Top-level numeric fields beyond the schema boilerplate."""
    skip = {"schema_version", "entries", "suite", "pending", "note"}
    return {
        k: float(v)
        for k, v in doc.items()
        if k not in skip and isinstance(v, (int, float))
    }


def fmt_rate(v):
    return f"{v:,.1f}"


def report_suite(name, baseline, current, threshold):
    """Print one suite's report; returns (pending, flagged) where
    `pending` means the committed baseline cannot be diffed and
    `flagged` counts entries/ratios that regressed past the
    threshold."""
    print(f"### {name}")
    if baseline is None:
        print("_No committed baseline — recording current numbers._")
        print()
        record(current)
        return False, 0
    if baseline.get("pending"):
        print(
            "⚠️ **PENDING BASELINE — no diff performed.** The committed "
            f"`perf/baselines/{name}` still carries `\"pending\": true`, "
            "so every run of this suite records instead of diffing and "
            "regressions stay invisible. Commit this run's "
            "`baselines-refresh` artifact over `perf/baselines/` to arm "
            "the diff. Current numbers:"
        )
        print()
        record(current)
        return True, 0
    base_rates = entry_rates(baseline)
    cur_rates = entry_rates(current)
    rows = []
    flagged = 0
    for entry, cur in sorted(cur_rates.items()):
        base = base_rates.get(entry)
        if base is None or base <= 0:
            rows.append((entry, "—", fmt_rate(cur), "new", ""))
            continue
        delta = 100.0 * (cur - base) / base
        flag = "⚠️ regression" if delta < -threshold else ""
        if flag:
            flagged += 1
        rows.append(
            (entry, fmt_rate(base), fmt_rate(cur), f"{delta:+.1f}%", flag)
        )
    print("| entry | baseline it/s | current it/s | delta | |")
    print("|---|---:|---:|---:|---|")
    for r in rows:
        print("| " + " | ".join(r) + " |")
    print()
    base_d = derived_fields(baseline)
    cur_d = derived_fields(current)
    shared = sorted(set(base_d) & set(cur_d))
    if shared:
        print("| derived metric | baseline floor | current | |")
        print("|---|---:|---:|---|")
        for k in shared:
            flag = ""
            if cur_d[k] < base_d[k] * (1.0 - threshold / 100.0):
                flag = "⚠️ below floor"
                flagged += 1
            print(f"| {k} | {base_d[k]:.2f} | {cur_d[k]:.2f} | {flag} |")
        print()
    if flagged:
        print(
            f"**{flagged} metric{'' if flagged == 1 else 's'} regressed "
            f"more than {threshold:.0f}% vs the committed snapshot.**"
        )
        print()
    return False, flagged


def record(current):
    rates = entry_rates(current)
    print("| entry | current it/s |")
    print("|---|---:|")
    for entry, cur in sorted(rates.items()):
        print(f"| {entry} | {fmt_rate(cur)} |")
    print()
    derived = derived_fields(current)
    for k in sorted(derived):
        print(f"- {k}: {derived[k]:.2f}")
    print()


def main(argv):
    args = []
    threshold = 25.0
    i = 0
    while i < len(argv):
        a = argv[i]
        if a.startswith("--threshold"):
            if "=" in a:
                threshold = float(a.split("=", 1)[1])
            elif i + 1 < len(argv):
                i += 1
                threshold = float(argv[i])
            else:
                print("--threshold needs a value")
                return 0
        else:
            args.append(a)
        i += 1
    if len(args) != 2:
        print(__doc__)
        return 0
    base_dir, cur_dir = Path(args[0]), Path(args[1])
    found = sorted(cur_dir.glob("PERF_*.json"))
    if not found:
        print(f"_No PERF_*.json artifacts under {cur_dir}._")
        return 0
    pending = 0
    flagged = 0
    for cur_path in found:
        try:
            current = json.loads(cur_path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"### {cur_path.name}\n_unreadable: {e}_\n")
            continue
        base_path = base_dir / cur_path.name
        baseline = None
        if base_path.exists():
            try:
                baseline = json.loads(base_path.read_text())
            except (OSError, json.JSONDecodeError):
                baseline = None
        was_pending, suite_flagged = report_suite(
            cur_path.name, baseline, current, threshold
        )
        pending += int(was_pending)
        flagged += suite_flagged
    if pending:
        print(
            f"**{pending} suite{'' if pending == 1 else 's'} diffed "
            "against a pending baseline — failing the (blocking) CI "
            "step. Refresh `perf/baselines/` from the "
            "`baselines-refresh` artifact.**"
        )
        return 1
    if flagged:
        print(
            f"**{flagged} metric{'' if flagged == 1 else 's'} regressed "
            "past the threshold — failing the (blocking) CI step.**"
        )
        return 1
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main(sys.argv[1:]))
    except BrokenPipeError:
        sys.exit(0)
