#!/usr/bin/env python3
"""Schema-validate an advisory METRICS.json telemetry artifact.

Usage: check_metrics.py METRICS.json [--require NAME ...]

Checks the contract promised by `kernelband::obs::Recorder::metrics_json`
(schema_version 1):

- top level carries `schema_version` == 1, boolean `enabled`, and
  `counters` / `histograms` objects;
- every counter is a non-negative finite number;
- every histogram carries count/sum/min/max/mean/p50/p90/p95/p99, all
  non-negative finite numbers, with monotone percentiles
  p50 <= p90 <= p95 <= p99 <= max and min <= max whenever count > 0;
- the optional `regret` section (present when the run observed bandit
  pulls) carries non-negative counts, and its
  `cumulative_regret_per_pull` series is non-negative and non-increasing
  (it is a running mean of per-pull regret under a policy that only
  improves its incumbent, so any rise beyond float tolerance is a bug);
- the optional `covering` section is an array of per-recluster records,
  each with finite numeric t/clusters/covering_number/max_radius/
  mean_radius/lipschitz and mean_radius <= max_radius;
- every `--require NAME` names a counter with value > 0 or a histogram
  with count > 0 (the CI obs-smoke run must actually have observed the
  layers it instruments). `--require regret` / `--require covering`
  instead demand that section be present and non-empty.

Exits 1 on any violation. This is a *gate*: the METRICS.json document
is advisory and never byte-compared, but its shape is load-bearing for
`kernelband metrics` and the CI summary, so drift fails the build.
"""

import json
import math
import sys
from pathlib import Path

HIST_FIELDS = (
    "count", "sum", "min", "max", "mean", "p50", "p90", "p95", "p99",
)


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(v)


def check(doc, require):
    errors = []

    if doc.get("schema_version") != 1:
        errors.append(
            f"schema_version is {doc.get('schema_version')!r}, expected 1"
        )
    if not isinstance(doc.get("enabled"), bool):
        errors.append("enabled missing or not a boolean")

    counters = doc.get("counters")
    if not isinstance(counters, dict):
        errors.append("counters missing or not an object")
        counters = {}
    for name, v in sorted(counters.items()):
        if not is_num(v) or v < 0:
            errors.append(f"counter {name}: bad value {v!r}")

    hists = doc.get("histograms")
    if not isinstance(hists, dict):
        errors.append("histograms missing or not an object")
        hists = {}
    for name, h in sorted(hists.items()):
        if not isinstance(h, dict):
            errors.append(f"histogram {name}: not an object")
            continue
        bad = [f for f in HIST_FIELDS
               if not is_num(h.get(f)) or h.get(f) < 0]
        if bad:
            errors.append(f"histogram {name}: bad fields {bad}")
            continue
        if h["count"] > 0:
            chain = [h["p50"], h["p90"], h["p95"], h["p99"], h["max"]]
            if any(a > b for a, b in zip(chain, chain[1:])):
                errors.append(
                    f"histogram {name}: percentiles not monotone {chain}"
                )
            if h["min"] > h["max"]:
                errors.append(
                    f"histogram {name}: min {h['min']} > max {h['max']}"
                )

    errors += check_regret(doc.get("regret"))
    errors += check_covering(doc.get("covering"))

    for name in require:
        if name == "regret":
            r = doc.get("regret")
            if not isinstance(r, dict) or r.get("pulls", 0) <= 0:
                errors.append("required section regret: absent or empty")
            continue
        if name == "covering":
            if not doc.get("covering"):
                errors.append("required section covering: absent or empty")
            continue
        if counters.get(name, 0) > 0:
            continue
        if isinstance(hists.get(name), dict) \
                and hists[name].get("count", 0) > 0:
            continue
        errors.append(
            f"required metric {name}: absent, zero, or empty histogram"
        )

    return errors


def check_regret(r):
    """Validate the optional regret section (None when absent)."""
    if r is None:
        return []
    if not isinstance(r, dict):
        return ["regret: not an object"]
    errors = []
    for f in ("runs_exact", "runs_best_seen", "pulls", "final"):
        if not is_num(r.get(f)) or r.get(f) < 0:
            errors.append(f"regret.{f}: bad value {r.get(f)!r}")
    series = r.get("cumulative_regret_per_pull")
    if not isinstance(series, list):
        return errors + ["regret.cumulative_regret_per_pull: not an array"]
    for i, v in enumerate(series):
        if not is_num(v) or v < 0:
            errors.append(f"regret series[{i}]: bad value {v!r}")
            return errors
    # running mean of a shrinking per-pull regret: non-increasing up to
    # float accumulation noise
    for i, (a, b) in enumerate(zip(series, series[1:])):
        if b > a + 1e-9:
            errors.append(
                f"regret series not non-increasing at [{i + 1}]: "
                f"{a} -> {b}"
            )
            break
    return errors


def check_covering(c):
    """Validate the optional covering section (None when absent)."""
    if c is None:
        return []
    if not isinstance(c, list):
        return ["covering: not an array"]
    errors = []
    fields = ("t", "clusters", "covering_number", "max_radius",
              "mean_radius", "lipschitz")
    for i, rec in enumerate(c):
        if not isinstance(rec, dict):
            errors.append(f"covering[{i}]: not an object")
            continue
        bad = [f for f in fields
               if not is_num(rec.get(f)) or rec.get(f) < 0]
        if bad:
            errors.append(f"covering[{i}]: bad fields {bad}")
            continue
        if rec["mean_radius"] > rec["max_radius"] + 1e-9:
            errors.append(
                f"covering[{i}]: mean_radius {rec['mean_radius']} > "
                f"max_radius {rec['max_radius']}"
            )
    return errors


def main(argv):
    path = None
    require = []
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--require":
            if i + 1 >= len(argv):
                print("--require needs a metric name")
                return 1
            i += 1
            require.append(argv[i])
        elif path is None:
            path = Path(a)
        else:
            print(__doc__)
            return 1
        i += 1
    if path is None:
        print(__doc__)
        return 1
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable: {e}")
        return 1

    errors = check(doc, require)
    counters = doc.get("counters") or {}
    hists = doc.get("histograms") or {}
    print(
        f"{path}: {len(counters)} counters, {len(hists)} histograms, "
        f"{len(require)} required metrics"
    )
    if errors:
        for e in errors:
            print(f"  ✗ {e}")
        print(f"{len(errors)} violation{'' if len(errors) == 1 else 's'}.")
        return 1
    print("  ✓ schema valid")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main(sys.argv[1:]))
    except BrokenPipeError:
        sys.exit(0)
