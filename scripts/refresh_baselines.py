#!/usr/bin/env python3
"""Build commit-ready perf baselines from fresh PERF_*.json artifacts.

Usage: refresh_baselines.py CURRENT_DIR OUT_DIR

For every PERF_<suite>.json under CURRENT_DIR (the bench output the CI
perf-smoke job just produced), writes OUT_DIR/PERF_<suite>.json with
"pending": false and a provenance note. CI uploads OUT_DIR as the
`baselines-refresh` artifact; committing its files over
`perf/baselines/` arms scripts/perf_trend.py's regression diff (which
fails loudly while a committed baseline is still pending).

Exits 1 when CURRENT_DIR holds no artifacts — an empty refresh
artifact would silently keep the baselines pending forever.
"""

import json
import sys
from pathlib import Path


def refresh(doc):
    out = dict(doc)
    out["pending"] = False
    out["note"] = (
        "Refreshed from a CI perf-smoke `perf-json` artifact by "
        "scripts/refresh_baselines.py. Commit over perf/baselines/ to "
        "arm the trend diff; re-refresh from a newer run to re-baseline."
    )
    return out


def main(argv):
    if len(argv) != 2:
        print(__doc__)
        return 1
    cur_dir, out_dir = Path(argv[0]), Path(argv[1])
    found = sorted(cur_dir.glob("PERF_*.json"))
    if not found:
        print(f"error: no PERF_*.json artifacts under {cur_dir}")
        return 1
    out_dir.mkdir(parents=True, exist_ok=True)
    for path in found:
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: unreadable {path}: {e}")
            return 1
        target = out_dir / path.name
        target.write_text(
            json.dumps(refresh(doc), indent=2, sort_keys=True) + "\n"
        )
        print(f"refreshed {target} (pending: false)")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main(sys.argv[1:]))
    except BrokenPipeError:
        sys.exit(0)
