#!/usr/bin/env python3
"""Validate a Chrome-trace-event export from `--obs trace`.

Usage: check_trace_events.py trace_events.json

Checks the causal span tree promised by `kernelband::obs::trace`
(written as trace_events.json by serve/repro --obs trace, or rebuilt
from events.jsonl by `kernelband metrics perfetto`):

- the document is `{"displayTimeUnit": "ms", "traceEvents": [...]}`;
- every event carries name/cat/ts/pid/tid/ph and an `args` object with
  numeric trace_id/span_id/parent_id;
- `ph` is "X" (complete span, with a non-negative `dur`) or "i"
  (instant, with scope `s`);
- span_ids of "X" events are unique and non-zero;
- every parent_id is 0 (root) or resolves to an existing span_id;
- walking parent links from any event terminates at a root — no cycles;
- within each track (tid), `ts` is non-decreasing in array order (the
  sink emits globally start-sorted events, so per-track order is
  monotone too).

Exits 1 on any violation. The export is advisory and never
byte-compared; its *shape* is the contract Perfetto and `kernelband
explain` consumers rely on, so drift fails the build.
"""

import json
import math
import sys
from pathlib import Path


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(v)


def check(doc):
    errors = []
    if doc.get("displayTimeUnit") != "ms":
        errors.append("displayTimeUnit missing or not 'ms'")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return errors + ["traceEvents missing or not an array"]
    if not events:
        return errors + ["traceEvents is empty"]

    spans = {}          # span_id -> parent_id, "X" events only
    parents = []        # (index, name, span_id, parent_id) of every event
    last_ts = {}        # tid -> last seen ts

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event[{i}]: not an object")
            continue
        name = ev.get("name")
        args = ev.get("args")
        bad = [f for f in ("ts", "pid", "tid") if not is_num(ev.get(f))]
        if not isinstance(name, str):
            bad.append("name")
        if ev.get("cat") != "kernelband":
            bad.append("cat")
        if not isinstance(args, dict):
            bad.append("args")
            args = {}
        bad += [f"args.{f}" for f in ("trace_id", "span_id", "parent_id")
                if not is_num(args.get(f))]
        ph = ev.get("ph")
        if ph == "X":
            if not is_num(ev.get("dur")) or ev["dur"] < 0:
                bad.append("dur")
        elif ph == "i":
            if not isinstance(ev.get("s"), str):
                bad.append("s")
        else:
            bad.append(f"ph={ph!r}")
        if bad:
            errors.append(f"event[{i}] {name!r}: bad fields {bad}")
            continue

        sid, pid = args["span_id"], args["parent_id"]
        if ph == "X":
            if sid == 0:
                errors.append(f"event[{i}] {name!r}: span_id 0 (reserved)")
            elif sid in spans:
                errors.append(f"event[{i}] {name!r}: duplicate span_id {sid}")
            else:
                spans[sid] = pid
        parents.append((i, name, sid, pid))

        tid = ev["tid"]
        if ev["ts"] < last_ts.get(tid, ev["ts"]):
            errors.append(
                f"event[{i}] {name!r}: ts {ev['ts']} rewinds on tid {tid} "
                f"(last {last_ts[tid]})"
            )
        last_ts[tid] = max(last_ts.get(tid, ev["ts"]), ev["ts"])

    for i, name, sid, pid in parents:
        if pid != 0 and pid not in spans:
            errors.append(
                f"event[{i}] {name!r}: parent_id {pid} resolves to no span"
            )

    # cycle check: parent-walk each span with a visited set
    for sid in spans:
        seen = set()
        cur = sid
        while cur != 0:
            if cur in seen:
                errors.append(f"span {sid}: parent walk cycles at {cur}")
                break
            seen.add(cur)
            cur = spans.get(cur, 0)

    return errors, len(events), len(spans)


def main(argv):
    if len(argv) != 1:
        print(__doc__)
        return 1
    path = Path(argv[0])
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable: {e}")
        return 1

    result = check(doc)
    if isinstance(result, list):  # structural failure before counting
        errors, n_events, n_spans = result, 0, 0
    else:
        errors, n_events, n_spans = result
    print(f"{path}: {n_events} events, {n_spans} spans")
    if errors:
        for e in errors:
            print(f"  ✗ {e}")
        print(f"{len(errors)} violation{'' if len(errors) == 1 else 's'}.")
        return 1
    print("  ✓ span tree well-formed")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main(sys.argv[1:]))
    except BrokenPipeError:
        sys.exit(0)
