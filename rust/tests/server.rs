//! Integration coverage for the real serving subsystem
//! ([`kernelband::server`]) through the `JobSpec`/`ServeBackend` API:
//! the ledger contract (each distinct fingerprint paid once per round,
//! warm tenants do zero new work, measured wall-clock present while
//! deterministic sections stay byte-stable) and the mixed-tenant store
//! regression for `trace stats`.

use std::path::PathBuf;
use std::sync::Arc;

use kernelband::gpu_model::Device;
use kernelband::llm::LlmProfile;
use kernelband::sched::BatchMode;
use kernelband::server::{InProcess, ServeRequest};
use kernelband::store::{log as trace_log, TraceStore};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("kb_server_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn three_tenant_request() -> ServeRequest {
    let mut req = ServeRequest::grid(
        3,
        3,
        14,
        BatchMode::Fixed(1),
        2,
        Device::H20,
        LlmProfile::DeepSeekV32,
        7,
    );
    req.workers = 2;
    req
}

/// The satellite's ledger contract: overlapping task fingerprints are
/// paid once per round by the scheduler; tenants whose jobs all ride
/// the shared state report zero profiling and zero LLM round-trips;
/// measured wall-clock is present and positive.
#[test]
fn ledger_pays_fingerprints_once_per_round_and_warms_tenants() {
    let store = Arc::new(TraceStore::in_memory());
    let report = InProcess.run_report(&three_tenant_request(), &store);
    assert_eq!(report.jobs.len(), 9);

    // each round executes every distinct fingerprint exactly once
    for round in 0..report.rounds {
        let mut paid = std::collections::HashSet::new();
        for j in report.jobs.iter().filter(|j| j.round == round) {
            if j.shared {
                // a share's fingerprint was paid by its round-mate
                assert!(paid.contains(&j.job.fingerprint)
                        || report.jobs.iter().any(|r| {
                            r.round == round
                                && !r.shared
                                && r.job.fingerprint == j.job.fingerprint
                        }));
            } else {
                assert!(paid.insert(j.job.fingerprint),
                        "round {round} paid a fingerprint twice");
            }
        }
    }

    // tenants 1 and 2 submit the same fingerprints as tenant 0 and are
    // served entirely by shares: the real-path "warm tenant" —
    // profile_runs == 0 and zero gateway (LLM) round-trips
    for t in [1usize, 2] {
        let ledger = &report.tenants[t];
        assert_eq!(ledger.completed, 3);
        assert_eq!(ledger.profile_runs, 0, "tenant {t} re-profiled");
        assert_eq!(ledger.llm_round_trips, 0,
                   "tenant {t} paid LLM round-trips");
        assert_eq!(ledger.measure_sims, 0, "tenant {t} re-simulated");
        assert!(ledger.is_warm());
    }
    // tenant 0 actually did the work
    assert!(report.tenants[0].llm_round_trips > 0);
    assert!(report.tenants[0].measure_sims > 0);

    // measured wall-clock: present and positive, never TIME_SCALEd
    assert!(report.wall_s > 0.0);
    assert!(report.job_wall_s() > 0.0);
    for j in report.jobs.iter().filter(|j| !j.shared) {
        assert!(j.wall_s > 0.0, "executed job without measured wall");
    }

    // a fingerprint seen in an earlier round re-executes warm: the
    // last round's representative does zero new simulated work
    let last_round = report.rounds - 1;
    for j in report
        .jobs
        .iter()
        .filter(|j| j.round == last_round && !j.shared)
    {
        assert_eq!(j.measure_sims, 0, "cross-round execution not warm");
        assert_eq!(j.llm_round_trips, 0);
        assert_eq!(j.profile_runs, 0);
    }
}

/// Deterministic artifact sections are byte-stable across store
/// temperature (cold pass vs warm pass over one on-disk store) while
/// the measured ledger legitimately collapses to zero new work.
#[test]
fn deterministic_sections_survive_cold_and_warm_store_passes() {
    let dir = tmp_dir("coldwarm");
    let cold = {
        let store = Arc::new(TraceStore::open(&dir).unwrap());
        let report = InProcess.run_report(&three_tenant_request(), &store);
        store.persist().unwrap();
        report
    };
    assert!(cold.store_measure_sims > 0);
    assert!(cold.store_llm_sims > 0);
    let warm = {
        let store = Arc::new(TraceStore::open(&dir).unwrap());
        let report = InProcess.run_report(&three_tenant_request(), &store);
        store.persist().unwrap();
        report
    };
    // warm pass: pure lookups — the CI smoke greps these as
    // measure_sim=0 / llm_sim=0 on the second run
    assert_eq!(warm.store_measure_sims, 0);
    assert_eq!(warm.store_llm_sims, 0);
    // byte-stable deterministic sections; measured fields still present
    assert_eq!(
        cold.deterministic_json().dump(),
        warm.deterministic_json().dump()
    );
    assert!(warm.wall_s > 0.0);
    let ledger = warm.ledger_json();
    assert!(ledger.f64_field("wall_s") > 0.0);
    assert_eq!(ledger.f64_field("measure_sims"), 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Worker-count invariance of the deterministic sections (the real
/// path's analogue of the runner's `--threads` contract).
#[test]
fn deterministic_sections_are_worker_invariant() {
    let run = |workers: usize| {
        let mut req = three_tenant_request();
        req.workers = workers;
        for j in &mut req.jobs {
            j.batch = BatchMode::Adaptive { min: 1, max: 4 };
        }
        let store = Arc::new(TraceStore::in_memory());
        InProcess.run_report(&req, &store)
    };
    let w1 = run(1);
    let w4 = run(4);
    assert_eq!(
        w1.deterministic_json().dump(),
        w4.deterministic_json().dump()
    );
    // adaptive width traces ride in the deterministic section and stay
    // within bounds
    for j in &w1.jobs {
        assert_eq!(j.width_trace.len(), 14);
        assert!(j.width_trace.iter().all(|w| (1..=4).contains(w)));
    }
}

/// Satellite regression: `trace stats` on a store written by a
/// multi-tenant serve — per-tenant namespace counters and per-tenant
/// trace record counts survive reopen.
#[test]
fn mixed_tenant_store_reports_per_tenant_counts() {
    let dir = tmp_dir("mixed");
    for _pass in 0..2 {
        let store = Arc::new(TraceStore::open(&dir).unwrap());
        let _ = InProcess.run_report(&three_tenant_request(), &store);
        store.persist().unwrap();
    }
    let store = TraceStore::open(&dir).unwrap();
    // tenants.jsonl: all three namespaces, accumulated over both passes
    assert_eq!(store.loaded.tenants, 3);
    let totals = store.tenant_totals();
    assert_eq!(
        totals.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
        vec!["t0", "t1", "t2"]
    );
    for (_, c) in &totals {
        assert_eq!(c.jobs, 6); // 3 jobs per tenant × 2 passes
    }
    // only executing jobs contribute steps; shares (t1, t2) are free
    assert!(totals[0].1.steps > 0);
    assert_eq!(totals[1].1.steps, 0);
    assert_eq!(totals[2].1.steps, 0);
    assert_eq!(totals[1].1.profile_runs, 0);

    // trace.jsonl: records carry the executing tenant's namespace, and
    // the warm second pass appended no duplicates
    let trace_path = store.trace_path().unwrap();
    assert!(trace_path.exists());
    let summary = trace_log::replay_file(&trace_path).unwrap();
    let counts = summary.tenant_counts();
    assert_eq!(counts.len(), 1, "only the executing tenant appends");
    let (name, tasks, steps) = &counts[0];
    assert_eq!(name, "t0");
    // two distinct fingerprints executed fresh in pass 1 (variety 2)
    assert_eq!(*tasks, 2);
    assert_eq!(*steps, 2 * 14);
    let _ = std::fs::remove_dir_all(&dir);
}
