//! Experiment-level regression tests: the paper's qualitative claims,
//! checked on reduced budgets so `cargo test` stays fast. The full-size
//! regenerations live in `kernelband repro` and `cargo bench`.

use kernelband::eval::{self, Method};
use kernelband::gpu_model::Device;
use kernelband::llm::LlmProfile;
use kernelband::metrics::aggregate;
use kernelband::policy::PolicyMode;
use kernelband::workload::Suite;

fn subset() -> Suite {
    Suite::full(eval::EXPERIMENT_SEED).subset50()
}

fn geomean_std(m: Method, suite: &Suite, device: Device, llm: LlmProfile,
               t: usize) -> f64 {
    let traces = m.run(suite, device, llm, t, eval::EXPERIMENT_SEED);
    aggregate(&eval::outcomes(&traces)).geomean_standard
}

fn correct(m: Method, suite: &Suite, device: Device, llm: LlmProfile,
           t: usize) -> f64 {
    let traces = m.run(suite, device, llm, t, eval::EXPERIMENT_SEED);
    aggregate(&eval::outcomes(&traces)).correct_pct
}

const KB: Method = Method::KernelBand(PolicyMode::Full, 3);

/// Table 1's headline: KernelBand dominates both baselines on every
/// platform in geomean speedup and correctness.
#[test]
fn claim_kernelband_dominates_on_all_platforms() {
    let suite = subset();
    for device in kernelband::gpu_model::ALL_DEVICES {
        let g_kb = geomean_std(KB, &suite, device, LlmProfile::DeepSeekV32, 20);
        let g_geak =
            geomean_std(Method::Geak, &suite, device, LlmProfile::DeepSeekV32, 20);
        let g_bon =
            geomean_std(Method::BoN, &suite, device, LlmProfile::DeepSeekV32, 20);
        assert!(g_kb > g_geak && g_kb > g_bon,
                "{}: kb {g_kb} geak {g_geak} bon {g_bon}", device.name());
        let c_kb = correct(KB, &suite, device, LlmProfile::DeepSeekV32, 20);
        let c_bon =
            correct(Method::BoN, &suite, device, LlmProfile::DeepSeekV32, 20);
        assert!(c_kb > c_bon + 15.0, "{}: {c_kb} vs {c_bon}", device.name());
    }
}

/// §4.2: KernelBand improves over GEAK by a large margin (paper: >33%
/// average; we require >15% on the reduced subset).
#[test]
fn claim_improvement_margin_over_geak() {
    let suite = subset();
    let mut ratio_sum = 0.0;
    for device in kernelband::gpu_model::ALL_DEVICES {
        let g_kb = geomean_std(KB, &suite, device, LlmProfile::DeepSeekV32, 20);
        let g_geak =
            geomean_std(Method::Geak, &suite, device, LlmProfile::DeepSeekV32, 20);
        ratio_sum += g_kb / g_geak;
    }
    let avg = ratio_sum / 3.0;
    assert!(avg > 1.15, "average KB/GEAK ratio = {avg}");
}

/// Table 2: the advantage holds for every LLM backend, and stronger
/// models yield stronger absolute results for KernelBand.
#[test]
fn claim_llm_generalization() {
    let suite = subset();
    let mut g = std::collections::HashMap::new();
    for llm in kernelband::llm::ALL_LLMS {
        let kb = geomean_std(KB, &suite, Device::H20, llm, 15);
        let bon = geomean_std(Method::BoN, &suite, Device::H20, llm, 15);
        assert!(kb > bon, "{}: kb {kb} vs bon {bon}", llm.spec().name);
        g.insert(llm.spec().name, kb);
    }
    // Claude (strongest capability) beats Gemini Flash (weakest)
    assert!(g["Claude Opus 4.5"] > g["Gemini 3 Flash"]);
}

/// Table 4's central ablation: structured bandit selection beats both
/// free-form generation and raw-profiling prompt injection; removing the
/// strategy set collapses correctness.
#[test]
fn claim_ablation_ordering() {
    let suite = subset();
    let llm = LlmProfile::DeepSeekV32;
    let full = geomean_std(KB, &suite, Device::H20, llm, 20);
    let no_strat = geomean_std(
        Method::KernelBand(PolicyMode::NoStrategySet, 3),
        &suite, Device::H20, llm, 20);
    let raw = geomean_std(
        Method::KernelBand(PolicyMode::NoStrategyRawProfiling, 3),
        &suite, Device::H20, llm, 20);
    let bon = geomean_std(Method::BoN, &suite, Device::H20, llm, 20);
    assert!(full > no_strat, "full {full} vs w/o-strategy {no_strat}");
    assert!(full > raw, "full {full} vs raw-prof {raw}");
    assert!(no_strat > bon, "w/o-strategy {no_strat} vs bon {bon}");
    // raw profiling hurts correctness vs the full system (paper: 43.9 vs 87.8)
    let c_full = correct(KB, &suite, Device::H20, llm, 20);
    let c_raw = correct(
        Method::KernelBand(PolicyMode::NoStrategyRawProfiling, 3),
        &suite, Device::H20, llm, 20);
    assert!(c_raw < c_full - 10.0, "correctness: raw {c_raw} vs full {c_full}");
}

/// Figure 2: baselines saturate while KernelBand keeps improving —
/// KB's late-half curve gain exceeds GEAK's.
#[test]
fn claim_scaling_behaviour() {
    let suite = subset();
    let llm = LlmProfile::DeepSeekV32;
    let kb = KB.run(&suite, Device::H20, llm, 30, eval::EXPERIMENT_SEED);
    let geak =
        Method::Geak.run(&suite, Device::H20, llm, 30, eval::EXPERIMENT_SEED);
    let ck = eval::scaling_curve(&kb);
    let cg = eval::scaling_curve(&geak);
    // final value: KB above GEAK
    assert!(ck[29] > cg[29], "kb {} vs geak {}", ck[29], cg[29]);
    let late_gain_kb = ck[29] - ck[14];
    let late_gain_geak = cg[29] - cg[14];
    assert!(
        late_gain_kb > late_gain_geak,
        "late gains: kb {late_gain_kb} vs geak {late_gain_geak}"
    );
}

/// Figure 4: at equal API budget KernelBand delivers more speedup.
#[test]
fn claim_cost_efficiency() {
    let suite = subset();
    let llm = LlmProfile::DeepSeekV32;
    let kb = KB.run(&suite, Device::H20, llm, 30, eval::EXPERIMENT_SEED);
    let bon =
        Method::BoN.run(&suite, Device::H20, llm, 30, eval::EXPERIMENT_SEED);
    for budget in [0.15, 0.3] {
        let g = |traces: &[kernelband::policy::Trace]| {
            let ls: f64 = traces
                .iter()
                .map(|t| eval::speedup_within_budget(t, budget).ln())
                .sum();
            (ls / traces.len() as f64).exp()
        };
        assert!(
            g(&kb) > g(&bon),
            "budget ${budget}: kb {} vs bon {}",
            g(&kb),
            g(&bon)
        );
    }
}

/// Appendix I / Table 10: the strategy mix adapts to hardware — the
/// selection-frequency vector differs measurably between H20 and 4090.
#[test]
fn claim_hardware_adaptation() {
    let suite = subset();
    let llm = LlmProfile::DeepSeekV32;
    let h20 = KB.run(&suite, Device::H20, llm, 20, eval::EXPERIMENT_SEED);
    let rtx = KB.run(&suite, Device::Rtx4090, llm, 20, eval::EXPERIMENT_SEED);
    let f_h20: Vec<f64> =
        eval::strategy_stats(&h20).iter().map(|r| r.1).collect();
    let f_rtx: Vec<f64> =
        eval::strategy_stats(&rtx).iter().map(|r| r.1).collect();
    let l1: f64 = f_h20
        .iter()
        .zip(&f_rtx)
        .map(|(a, b)| (a - b).abs())
        .sum();
    // the shift is muted at T=20 (UCB is still mostly exploring, as in
    // the paper's ~3-6 point per-strategy deltas) but must be present
    assert!(l1 > 1.5, "strategy mixes identical across devices: {l1}");
}

/// Table 3: tiling is high-risk (lowest success rate among frequently
/// used strategies) while fusion/vectorization are reliable.
#[test]
fn claim_strategy_risk_profiles() {
    let suite = subset();
    let traces = KB.run(
        &suite,
        Device::H20,
        LlmProfile::DeepSeekV32,
        20,
        eval::EXPERIMENT_SEED,
    );
    let stats = eval::strategy_stats(&traces);
    let succ = |name: &str| {
        stats.iter().find(|r| r.0 == name).map(|r| r.2).unwrap()
    };
    assert!(succ("Tiling") < succ("Fusion"), "tiling should be riskier");
    assert!(succ("Tiling") < succ("Vectorization"));
}

/// Table 9: KernelBand-optimized kernels beat all three PyTorch modes.
#[test]
fn claim_beats_pytorch_modes() {
    let text = eval::table9(15);
    for line in text.lines().filter(|l| l.starts_with("vs.")) {
        let x: f64 = line
            .split_whitespace()
            .last()
            .unwrap()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(x > 1.0, "lost to a torch mode: {line}");
    }
}

/// All render entrypoints produce non-empty tables (smoke for `repro all`
/// at reduced budgets).
#[test]
fn all_experiments_render_at_reduced_budget() {
    for text in [
        eval::table2(6),
        eval::table3(6),
        eval::table4(6),
        eval::table9(6),
        eval::table10(6),
        eval::fig2(8),
        eval::fig4(8),
    ] {
        assert!(text.lines().count() > 4, "{text}");
    }
}
