//! Regression tests for `BatchedLlmGateway` shutdown semantics.
//!
//! The original gateway could strand submitters forever: a thread
//! blocked on a full ingress queue (backpressure wait) or waiting for a
//! queued request's completion would hang if the gateway shut down
//! underneath it. Shutdown is now drain-and-error — every pending or
//! newly-arriving request completes with `GatewayClosed` — and these
//! tests hold the liveness bar with watchdog deadlines instead of
//! scoped joins, so a regression fails fast rather than wedging CI.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use kernelband::service::{
    BatchedLlmGateway, GatewayClosed, GatewayConfig, RetryPolicy,
};

/// Poll until `done` reaches `target` or the deadline passes. Returns
/// whether the target was reached. Detached submitter threads mean a
/// regression fails the assertion instead of hanging the test binary.
fn wait_for(done: &AtomicUsize, target: usize, deadline: Duration) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if done.load(Ordering::Acquire) >= target {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    done.load(Ordering::Acquire) >= target
}

/// A gateway that will never complete a batch on its own (huge window):
/// shutdown must error the queued request out instead of hanging it.
#[test]
fn shutdown_unblocks_waiter_on_queued_request() {
    let gw: Arc<BatchedLlmGateway<usize>> =
        Arc::new(BatchedLlmGateway::spawn(GatewayConfig {
            max_batch: 64,
            window_s: 1e7,
            call_latency_s: 1e7,
            queue_depth: 64,
        }));
    let done = Arc::new(AtomicUsize::new(0));
    let errored = Arc::new(AtomicUsize::new(0));
    let (g, d, e) = (gw.clone(), done.clone(), errored.clone());
    std::thread::spawn(move || {
        let out = g.call(7);
        if out == Err(GatewayClosed(7)) {
            e.fetch_add(1, Ordering::Release);
        }
        d.fetch_add(1, Ordering::Release);
    });
    // let the request enqueue, then shut down
    std::thread::sleep(Duration::from_millis(30));
    gw.shutdown();
    assert!(
        wait_for(&done, 1, Duration::from_secs(10)),
        "submitter still blocked after shutdown — drain-and-error regressed"
    );
    assert_eq!(errored.load(Ordering::Acquire), 1);
}

/// Submitters blocked on a *full ingress queue* (the backpressure wait)
/// must also drain with an error on shutdown — this was the original
/// hang: the queue could never empty once the batcher stopped.
#[test]
fn shutdown_unblocks_submitters_stuck_on_full_queue() {
    let gw: Arc<BatchedLlmGateway<usize>> =
        Arc::new(BatchedLlmGateway::spawn(GatewayConfig {
            max_batch: 64,
            window_s: 1e7,
            call_latency_s: 1e7,
            queue_depth: 2, // tiny: most submitters block at ingress
        }));
    let done = Arc::new(AtomicUsize::new(0));
    const SUBMITTERS: usize = 12;
    for i in 0..SUBMITTERS {
        let (g, d) = (gw.clone(), done.clone());
        std::thread::spawn(move || {
            // whichever way it resolves, it must resolve
            let _ = g.call(i);
            d.fetch_add(1, Ordering::Release);
        });
    }
    std::thread::sleep(Duration::from_millis(50));
    gw.shutdown();
    assert!(
        wait_for(&done, SUBMITTERS, Duration::from_secs(10)),
        "only {}/{SUBMITTERS} submitters returned after shutdown",
        done.load(Ordering::Acquire)
    );
    // with a dead batcher and a huge window nothing was actually served
    assert_eq!(gw.requests(), 0);
}

/// Calls after shutdown fail fast with the payload handed back.
#[test]
fn post_shutdown_calls_fail_fast() {
    let gw: BatchedLlmGateway<&'static str> =
        BatchedLlmGateway::spawn(GatewayConfig::default());
    gw.shutdown();
    let t0 = Instant::now();
    assert_eq!(gw.call("x"), Err(GatewayClosed("x")));
    assert!(t0.elapsed() < Duration::from_secs(1));
    // shutdown is idempotent (and Drop will call it again)
    gw.shutdown();
}

/// The default retry policy is inert: `call_retry` must behave exactly
/// like `call` — one round-trip, zero retries — so existing timing and
/// artifact behavior is unchanged unless a failure probability is
/// explicitly injected.
#[test]
fn default_retry_policy_is_inert() {
    let gw: BatchedLlmGateway<usize> =
        BatchedLlmGateway::spawn(GatewayConfig {
            max_batch: 4,
            window_s: 0.5,
            call_latency_s: 1.0,
            queue_depth: 16,
        });
    assert_eq!(gw.call_retry(9, 0xfeed, &RetryPolicy::default()), Ok(9));
    assert_eq!(gw.requests(), 1);
    assert_eq!(gw.retries(), 0);
}

/// With `transient_fail_prob = 1.0` every completed attempt short of
/// the cap is judged transient, so the loop runs exactly
/// `max_attempts` round-trips, counts `max_attempts - 1` retries, and
/// still returns the payload — bounded, deterministic, replayable.
#[test]
fn transient_failures_retry_deterministically_up_to_the_cap() {
    let cfg = GatewayConfig {
        max_batch: 4,
        window_s: 0.5,
        call_latency_s: 1.0,
        queue_depth: 16,
    };
    let policy = RetryPolicy {
        max_attempts: 3,
        backoff_base_s: 0.5,
        transient_fail_prob: 1.0,
        seed: 7,
    };
    for _ in 0..2 {
        // identical gateways replay the identical schedule
        let gw: BatchedLlmGateway<usize> = BatchedLlmGateway::spawn(cfg);
        assert_eq!(gw.call_retry(1, 42, &policy), Ok(1));
        assert_eq!(gw.requests(), 3);
        assert_eq!(gw.retries(), 2);
    }
}

/// Retry draws are a pure function of `(seed, key, attempt)` — not of
/// wall-clock, thread interleaving, or call order — so a whole
/// multi-key run reproduces its retry count exactly.
#[test]
fn retry_draws_are_seeded_per_key_not_per_wall_clock() {
    let cfg = GatewayConfig {
        max_batch: 8,
        window_s: 0.5,
        call_latency_s: 1.0,
        queue_depth: 32,
    };
    let policy = RetryPolicy {
        max_attempts: 4,
        backoff_base_s: 0.1,
        transient_fail_prob: 0.5,
        seed: 11,
    };
    let run = || {
        let gw: BatchedLlmGateway<usize> = BatchedLlmGateway::spawn(cfg);
        for key in 0..16u64 {
            assert!(gw.call_retry(key as usize, key, &policy).is_ok());
        }
        (gw.requests(), gw.retries())
    };
    let a = run();
    assert_eq!(a, run());
    assert!(a.1 > 0, "p=0.5 over 16 keys never drew a retry");
    // every retry is one extra round-trip on top of the 16 requests
    assert_eq!(a.0, 16 + a.1);
}

/// `GatewayClosed` is not a transient failure: the retry loop must
/// short-circuit immediately, preserving drain-and-error semantics
/// (no spinning against a dying gateway, no counted retries).
#[test]
fn gateway_closed_short_circuits_retry_loop() {
    let gw: BatchedLlmGateway<&'static str> =
        BatchedLlmGateway::spawn(GatewayConfig::default());
    gw.shutdown();
    let policy =
        RetryPolicy { transient_fail_prob: 1.0, ..RetryPolicy::default() };
    let t0 = Instant::now();
    assert_eq!(gw.call_retry("x", 3, &policy), Err(GatewayClosed("x")));
    assert!(t0.elapsed() < Duration::from_secs(1));
    assert_eq!(gw.retries(), 0);
}

/// Normal completion still works end-to-end after the rework.
#[test]
fn requests_complete_normally_while_gateway_lives() {
    let gw: Arc<BatchedLlmGateway<usize>> =
        Arc::new(BatchedLlmGateway::spawn(GatewayConfig {
            max_batch: 8,
            window_s: 2.0,
            call_latency_s: 5.0,
            queue_depth: 16,
        }));
    let results: Vec<usize> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let g = gw.clone();
                scope.spawn(move || g.call(i).expect("gateway alive"))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(results, (0..8).collect::<Vec<_>>());
    assert_eq!(gw.requests(), 8);
}
