//! PJRT runtime integration: load real AOT artifacts, execute through
//! the XLA CPU client, verify numerics against the pure-jnp reference
//! artifacts, and check Rust↔Pallas parity for the coordinator kernels
//! (K-means, masked UCB).
//!
//! Requires `make artifacts`; each test skips gracefully when the
//! directory is missing so `cargo test` stays runnable pre-build.

use kernelband::bandit::MaskedUcb;
use kernelband::cluster::{ClusterBackend, RustKmeans};
use kernelband::engine::pjrt::PjrtBench;
use kernelband::features::Phi;
use kernelband::rng::Rng;
use kernelband::runtime::{pjrt_ucb_scores, PjrtKmeans, Runtime};
use kernelband::strategy::NUM_STRATEGIES;
use kernelband::verify::allclose;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(dir).expect("runtime loads"))
}

#[test]
fn manifest_covers_all_op_families() {
    let Some(rt) = runtime() else { return };
    let ops = rt.manifest().variant_ops();
    for op in ["matmul", "fused", "softmax", "layernorm", "attention"] {
        assert!(ops.iter().any(|o| o == op), "missing op {op}");
        assert!(!rt.manifest().variants(op).is_empty());
        assert!(rt.manifest().reference(op).is_some());
    }
    assert!(rt.manifest().artifacts.len() >= 40);
}

#[test]
fn matmul_variant_matches_reference_artifact() {
    let Some(rt) = runtime() else { return };
    let mut bench = PjrtBench::new(&rt);
    bench.reps = 2;
    for meta in rt.manifest().variants("matmul") {
        let r = bench.run_variant(meta).expect("variant runs");
        assert!(r.verdict.passed(), "{} failed allclose", meta.name);
        assert!(r.latency_s.is_finite() && r.latency_s > 0.0);
    }
}

#[test]
fn fused_and_unfused_epilogues_agree_with_reference() {
    let Some(rt) = runtime() else { return };
    let mut bench = PjrtBench::new(&rt);
    bench.reps = 2;
    let results = bench.sweep("fused").expect("sweep");
    assert!(results.len() >= 6);
    for r in &results {
        assert!(r.verdict.passed(), "{} failed", r.name);
    }
    // fused variant beats (or at worst matches) its unfused twin at
    // equal tiles — generous margin because cargo test runs test
    // binaries concurrently and CPU timing is noisy; the clean ordering
    // is recorded from a quiet machine in EXPERIMENTS.md §End-to-end
    let lat = |name: &str| {
        results.iter().find(|r| r.name == name).unwrap().latency_s
    };
    assert!(
        lat("fused_bias_relu_t128x128x64")
            < lat("unfused_bias_relu_t128x128x64") * 1.5
    );
}

#[test]
fn softmax_layernorm_attention_verify() {
    let Some(rt) = runtime() else { return };
    let mut bench = PjrtBench::new(&rt);
    bench.reps = 2;
    for op in ["softmax", "layernorm", "attention"] {
        for r in bench.sweep(op).expect("sweep") {
            assert!(r.verdict.passed(), "{} failed allclose", r.name);
        }
    }
}

#[test]
fn pjrt_kmeans_matches_rust_kmeans() {
    let Some(rt) = runtime() else { return };
    // two well-separated blobs in phi-space
    let mut rng = Rng::new(42);
    let mut points: Vec<Phi> = Vec::new();
    for i in 0..24 {
        let base = if i < 12 { 0.15 } else { 0.8 };
        points.push([
            base + 0.01 * rng.normal(),
            base + 0.01 * rng.normal(),
            base,
            base,
            base,
        ]);
    }
    let rust = RustKmeans::default().cluster(&points, 2, &mut Rng::new(7));
    let pjrt = PjrtKmeans { runtime: &rt }.cluster(&points, 2, &mut Rng::new(7));
    // identical seeding + identical Lloyd semantics → identical partition
    assert_eq!(rust.assign, pjrt.assign);
    for (rc, pc) in rust.centroids.iter().zip(&pjrt.centroids) {
        for j in 0..5 {
            assert!(
                (rc[j] - pc[j]).abs() < 1e-4,
                "centroid mismatch: {rc:?} vs {pc:?}"
            );
        }
    }
}

#[test]
fn pjrt_ucb_matches_rust_ucb() {
    let Some(rt) = runtime() else { return };
    let k = 3usize;
    let mut rng = Rng::new(9);
    let mu: Vec<f64> = (0..k * NUM_STRATEGIES).map(|_| rng.uniform()).collect();
    let n: Vec<f64> =
        (0..k * NUM_STRATEGIES).map(|_| 1.0 + rng.below(30) as f64).collect();
    let mask: Vec<bool> =
        (0..k * NUM_STRATEGIES).map(|_| rng.chance(0.6)).collect();
    let t = 17usize;
    let got = pjrt_ucb_scores(&rt, &mu, &n, t, &mask, k).expect("ucb artifact");
    let ucb = MaskedUcb::default();
    for i in 0..k * NUM_STRATEGIES {
        if mask[i] {
            let want = ucb.index(mu[i], n[i], t as f64);
            assert!(
                (got[i] - want).abs() < 1e-4 * want.abs().max(1.0),
                "arm {i}: {} vs {}",
                got[i],
                want
            );
        } else {
            assert!(got[i] < -1e20, "masked arm {i} not -inf: {}", got[i]);
        }
    }
}

#[test]
fn executable_cache_makes_second_call_cheap() {
    let Some(rt) = runtime() else { return };
    let inputs = rt.example_inputs("softmax_b32", 1).unwrap();
    let t0 = std::time::Instant::now();
    let _ = rt.execute("softmax_b32", &inputs).unwrap();
    let cold = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _ = rt.execute("softmax_b32", &inputs).unwrap();
    let warm = t1.elapsed();
    assert!(warm < cold, "cache ineffective: warm {warm:?} cold {cold:?}");
}

#[test]
fn execute_rejects_bad_inputs() {
    let Some(rt) = runtime() else { return };
    // wrong arity
    assert!(rt.execute("softmax_b32", &[]).is_err());
    // wrong element count
    assert!(rt.execute("softmax_b32", &[vec![0.0f32; 7]]).is_err());
    // unknown artifact
    assert!(rt.execute("nope", &[]).is_err());
}

#[test]
fn bandit_search_improves_or_matches_reference() {
    let Some(rt) = runtime() else { return };
    let mut bench = PjrtBench::new(&rt);
    bench.reps = 2;
    let mut rng = Rng::new(3);
    let out = bench.bandit_search("matmul", 5, &mut rng).expect("search");
    assert!(out.evaluations() <= 5);
    assert!(out.reference_latency_s > 0.0);
    if let Some(best) = &out.best {
        assert!(best.verdict.passed());
        assert!(best.latency_s.is_finite());
    }
}

#[test]
fn allclose_used_by_engine_is_strict() {
    // meta-test on the numeric gate the PJRT engine relies on
    let a: Vec<f32> = (0..100).map(|i| i as f32).collect();
    let mut b = a.clone();
    assert!(allclose(&a, &b, 1e-4, 1e-4));
    b[50] += 1.0;
    assert!(!allclose(&a, &b, 1e-4, 1e-4));
}
