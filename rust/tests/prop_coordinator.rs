//! Property-based tests on coordinator invariants.
//!
//! proptest is not available in this offline environment, so these are
//! hand-rolled property sweeps: each property is checked over hundreds
//! of randomized cases drawn from the crate's own splittable RNG, with
//! the failing seed printed on assertion failure (shrinking is replaced
//! by deterministic reproducibility — re-run with the printed seed).

use kernelband::bandit::{softmax_kernel_pick, ArmStats, MaskedUcb, RewardRecord};
use kernelband::cluster::{lloyd_step, ClusterBackend, RustKmeans};
use kernelband::engine::SimEngine;
use kernelband::features::{phi, phi_distance, Phi, PHI_DIM};
use kernelband::gpu_model::{Device, GpuSim, ALL_DEVICES};
use kernelband::kernel::{Counters, KernelConfig, Measurement};
use kernelband::llm::{LlmProfile, SurrogateLlm};
use kernelband::policy::{KernelBand, PolicyConfig, PolicyMode};
use kernelband::rng::Rng;
use kernelband::strategy::{Strategy, ALL_STRATEGIES, NUM_STRATEGIES};
use kernelband::workload::Suite;

const CASES: u64 = 200;

fn arbitrary_config(rng: &mut Rng) -> KernelConfig {
    KernelConfig {
        tile_m: rng.below(6) as u8,
        tile_n: rng.below(6) as u8,
        tile_k: rng.below(6) as u8,
        vector: rng.below(4) as u8,
        fusion: rng.below(4) as u8,
        pipeline: rng.below(4) as u8,
        loop_order: rng.below(6) as u8,
        layout: rng.below(4) as u8,
    }
}

fn arbitrary_phi(rng: &mut Rng) -> Phi {
    let mut p = [0.0; PHI_DIM];
    for v in p.iter_mut() {
        *v = rng.uniform();
    }
    p
}

// --- bandit invariants ------------------------------------------------

#[test]
fn prop_masked_ucb_never_selects_masked_arm() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("ucb", 0);
        let k = 1 + rng.below(6) as usize;
        let mut stats = ArmStats::new(k);
        // random update history
        for _ in 0..rng.below(50) {
            let c = rng.below(k as u64) as usize;
            let s = Strategy::from_index(rng.below(6) as usize);
            stats.update(c, s, rng.uniform());
        }
        let mask: Vec<bool> =
            (0..k * NUM_STRATEGIES).map(|_| rng.chance(0.5)).collect();
        let t = 1 + rng.below(1000) as usize;
        match MaskedUcb::default().select(&stats, t, &mask) {
            Some((c, s)) => {
                assert!(
                    mask[c * NUM_STRATEGIES + s.index()],
                    "case {case}: selected masked arm"
                );
            }
            None => {
                assert!(mask.iter().all(|&m| !m), "case {case}: spurious None");
            }
        }
    }
}

#[test]
fn prop_ucb_selects_max_index_among_valid() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("ucbmax", 0);
        let k = 1 + rng.below(4) as usize;
        let mut stats = ArmStats::new(k);
        for _ in 0..rng.below(80) {
            let c = rng.below(k as u64) as usize;
            let s = Strategy::from_index(rng.below(6) as usize);
            stats.update(c, s, rng.uniform());
        }
        let mask = vec![true; k * NUM_STRATEGIES];
        let t = 2 + rng.below(500) as usize;
        let ucb = MaskedUcb::default();
        let (c, s) = ucb.select(&stats, t, &mask).unwrap();
        let chosen = ucb.index(
            stats.mean(c, s),
            stats.visits(c, s),
            t as f64,
        );
        for ci in 0..k {
            for &si in &ALL_STRATEGIES {
                let idx =
                    ucb.index(stats.mean(ci, si), stats.visits(ci, si), t as f64);
                assert!(idx <= chosen + 1e-12, "case {case}");
            }
        }
    }
}

#[test]
fn prop_arm_update_keeps_mean_in_reward_hull() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("hull", 0);
        let mut stats = ArmStats::new(1);
        let s = Strategy::from_index(rng.below(6) as usize);
        let mut lo = 0.5f64; // prior mean
        let mut hi = 0.5f64;
        for _ in 0..rng.below(60) {
            let r = rng.uniform();
            lo = lo.min(r);
            hi = hi.max(r);
            stats.update(0, s, r);
            let m = stats.mean(0, s);
            assert!(m >= lo - 1e-12 && m <= hi + 1e-12, "case {case}");
        }
    }
}

#[test]
fn prop_reseed_visit_counts_conserve_history() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("reseed", 0);
        let k = 1 + rng.below(5) as usize;
        let n_kernels = 1 + rng.below(20) as usize;
        let assign: Vec<usize> =
            (0..n_kernels).map(|_| rng.below(k as u64) as usize).collect();
        let history: Vec<RewardRecord> = (0..rng.below(60))
            .map(|_| RewardRecord {
                kernel: rng.below(n_kernels as u64) as usize,
                strategy: Strategy::from_index(rng.below(6) as usize),
                reward: rng.uniform(),
            })
            .collect();
        let stats = ArmStats::reseed(k, &history, &assign);
        // total extra visits (beyond priors) equals history length
        let total: f64 = stats.n.iter().sum();
        let priors = (k * NUM_STRATEGIES) as f64;
        assert!(
            (total - priors - history.len() as f64).abs() < 1e-9,
            "case {case}"
        );
    }
}

#[test]
fn prop_softmax_pick_in_bounds() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("smx", 0);
        let n = 1 + rng.below(30) as usize;
        let headrooms: Vec<f64> =
            (0..n).map(|_| rng.uniform_in(-80.0, 80.0)).collect();
        let pick = softmax_kernel_pick(&headrooms, &mut rng);
        assert!(pick < n, "case {case}");
    }
}

// --- clustering invariants --------------------------------------------

#[test]
fn prop_kmeans_assignment_is_nearest_centroid() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("km", 0);
        let n = 2 + rng.below(40) as usize;
        let k = 1 + rng.below(5) as usize;
        let points: Vec<Phi> = (0..n).map(|_| arbitrary_phi(&mut rng)).collect();
        let c = RustKmeans::default().cluster(&points, k, &mut rng);
        for (pi, p) in points.iter().enumerate() {
            let assigned_d = phi_distance(p, &c.centroids[c.assign[pi]]);
            for cent in &c.centroids {
                assert!(
                    assigned_d <= phi_distance(p, cent) + 1e-9,
                    "case {case}: point {pi} not at nearest centroid"
                );
            }
        }
    }
}

#[test]
fn prop_lloyd_never_increases_inertia() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("lloyd", 0);
        let n = 3 + rng.below(30) as usize;
        let k = 1 + rng.below(4) as usize;
        let points: Vec<Phi> = (0..n).map(|_| arbitrary_phi(&mut rng)).collect();
        let mut centroids: Vec<Phi> =
            (0..k).map(|_| arbitrary_phi(&mut rng)).collect();
        let inertia = |cents: &[Phi]| -> f64 {
            points
                .iter()
                .map(|p| {
                    cents
                        .iter()
                        .map(|c| {
                            let d = phi_distance(p, c);
                            d * d
                        })
                        .fold(f64::INFINITY, f64::min)
                })
                .sum()
        };
        let mut prev = inertia(&centroids);
        for _ in 0..5 {
            lloyd_step(&points, &mut centroids);
            let cur = inertia(&centroids);
            assert!(cur <= prev + 1e-9, "case {case}: inertia rose");
            prev = cur;
        }
    }
}

// --- feature invariants -------------------------------------------------

#[test]
fn prop_phi_always_in_unit_box() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("phi", 0);
        let m = Measurement {
            total_latency_s: 10f64.powf(rng.uniform_in(-9.0, 3.0)),
            per_shape_s: vec![],
            counters: Counters {
                regs_per_thread: rng.uniform_in(0.0, 500.0),
                smem_per_block: rng.uniform_in(0.0, 1e6),
                block_dim: rng.uniform_in(0.0, 4096.0),
                occupancy: rng.uniform_in(-0.5, 1.5),
                sm_pct: rng.uniform_in(0.0, 100.0),
                dram_pct: rng.uniform_in(0.0, 100.0),
                l2_pct: rng.uniform_in(0.0, 100.0),
            },
        };
        let reference = 10f64.powf(rng.uniform_in(-9.0, 3.0));
        let p = phi(&m, reference);
        for (i, v) in p.iter().enumerate() {
            assert!((0.0..=1.0).contains(v), "case {case} dim {i}: {v}");
        }
    }
}

// --- simulator invariants -----------------------------------------------

#[test]
fn prop_simulator_latency_positive_and_counters_bounded() {
    let suite = Suite::full(3);
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("sim", 0);
        let task = &suite.tasks[rng.below(suite.len() as u64) as usize];
        let device = ALL_DEVICES[rng.below(3) as usize];
        let cfg = arbitrary_config(&mut rng).clamped();
        let sim = GpuSim::new(device);
        let m = sim.evaluate(task, &cfg, &mut rng);
        assert!(m.total_latency_s.is_finite() && m.total_latency_s > 0.0);
        assert!((0.0..=100.0).contains(&m.counters.sm_pct), "case {case}");
        assert!((0.0..=100.0).contains(&m.counters.dram_pct), "case {case}");
        assert!((0.0..=100.0).contains(&m.counters.l2_pct), "case {case}");
        assert!((0.0..=1.0).contains(&m.counters.occupancy), "case {case}");
        assert!(
            (m.per_shape_s.iter().sum::<f64>() - m.total_latency_s).abs()
                < 1e-9 * m.total_latency_s.max(1.0),
            "case {case}: per-shape sum mismatch"
        );
    }
}

#[test]
fn prop_oracle_config_is_near_optimal_along_each_dim() {
    // Perturbing any single dimension of the oracle config by one step
    // never improves noiseless latency by more than a few percent.
    // (The oracle is heuristic: occupancy couples dimensions, so tiny
    // cross-dimension wins are possible — but nothing material.)
    let suite = Suite::full(4);
    for case in 0..40 {
        let mut rng = Rng::new(case).split("oracle", 0);
        let task = &suite.tasks[(case as usize * 7) % suite.len()];
        let device = ALL_DEVICES[case as usize % 3];
        let sim = GpuSim::noiseless(device);
        let oracle = sim.oracle_config(task);
        let base = sim.evaluate(task, &oracle, &mut rng).total_latency_s;
        let neighbors = {
            let mut v = Vec::new();
            for delta in [-1i32, 1] {
                for dim in 0..8 {
                    let mut c = oracle;
                    let field = match dim {
                        0 => &mut c.tile_m,
                        1 => &mut c.tile_n,
                        2 => &mut c.tile_k,
                        3 => &mut c.vector,
                        4 => &mut c.fusion,
                        5 => &mut c.pipeline,
                        6 => &mut c.loop_order,
                        _ => &mut c.layout,
                    };
                    let nv = *field as i32 + delta;
                    if nv < 0 {
                        continue;
                    }
                    *field = nv as u8;
                    v.push(c.clamped());
                }
            }
            v
        };
        for n in neighbors {
            if n == oracle {
                continue;
            }
            let t = sim.evaluate(task, &n, &mut rng).total_latency_s;
            assert!(
                t >= base * 0.85,
                "case {case}: neighbor beat oracle by >15% on {} ({t} < {base})",
                task.name
            );
        }
    }
}

// --- policy invariants ----------------------------------------------------

#[test]
fn prop_policy_trace_wellformed_across_seeds_and_modes() {
    let suite = Suite::full(5);
    let engine = SimEngine::new(Device::H20);
    let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
    let modes = [
        PolicyMode::Full,
        PolicyMode::NoClustering,
        PolicyMode::NoProfiling,
        PolicyMode::LlmStrategySelection,
        PolicyMode::NoStrategySet,
    ];
    for case in 0..60 {
        let mut rng = Rng::new(case).split("pol", 0);
        let task = &suite.tasks[rng.below(suite.len() as u64) as usize];
        let mode = modes[case as usize % modes.len()];
        let mut cfg = PolicyConfig::with_mode(mode);
        cfg.iterations = 5 + rng.below(20) as usize;
        let tr = KernelBand::new(cfg.clone()).optimize(
            task,
            &engine,
            &llm,
            &Rng::new(case),
        );
        // trace shape
        assert_eq!(tr.records.len(), cfg.iterations, "case {case}");
        // candidate ids are dense and parents precede children
        for (i, c) in tr.candidates.iter().enumerate() {
            assert_eq!(c.id, i);
            if let kernelband::kernel::Origin::Llm { parent, .. } = c.origin {
                assert!(parent < i, "case {case}: parent after child");
            }
        }
        // best is the argmin over candidates
        let min_t = tr
            .candidates
            .iter()
            .map(|c| c.measurement.total_latency_s)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(
            tr.candidates[tr.best_id].measurement.total_latency_s, min_t,
            "case {case}"
        );
        // rewards clipped; failures have zero reward and no candidate
        for r in &tr.records {
            assert!((0.0..=1.0).contains(&r.reward), "case {case}");
            if !r.verdict.passed() {
                assert_eq!(r.reward, 0.0);
                assert!(r.accepted.is_none());
            }
            assert!(r.parent < tr.candidates.len());
            assert!(r.cost_usd >= 0.0 && r.llm_serial_s >= 0.0);
        }
        // cost is the sum of per-iteration costs
        let sum: f64 = tr.records.iter().map(|r| r.cost_usd).sum();
        assert!((sum - tr.total_cost_usd()).abs() < 1e-12);
    }
}

#[test]
fn prop_config_clamp_is_idempotent_and_legalizes() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("clamp", 0);
        let raw = KernelConfig {
            tile_m: rng.below(256) as u8,
            tile_n: rng.below(256) as u8,
            tile_k: rng.below(256) as u8,
            vector: rng.below(256) as u8,
            fusion: rng.below(256) as u8,
            pipeline: rng.below(256) as u8,
            loop_order: rng.below(256) as u8,
            layout: rng.below(256) as u8,
        };
        let c = raw.clamped();
        assert_eq!(c, c.clamped(), "case {case}");
        assert!((c.tile_m as usize) < 6 && (c.vector as usize) < 4);
        assert!(c.fusion <= 3 && c.pipeline <= 3);
        assert!(c.loop_order <= 5 && c.layout <= 3);
    }
}
