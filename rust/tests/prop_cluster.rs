//! Property tests for the §Perf clustering changes: the Lloyd
//! convergence early-exit must be bit-lossless against the full-
//! iteration reference, and warm-seeded re-clustering must honor its
//! equivalence contract — identity at a fixed point; where seeding
//! legitimately diverges from the k-means++ path, determinism and the
//! downstream `BENCH_*.json` byte-identity (asserted in
//! `runner_artifacts.rs` and the CI smoke) are the contract instead.

use kernelband::cluster::{kmeanspp_init, lloyd_step, representatives,
                          ClusterBackend, Clustering, RustKmeans};
use kernelband::features::{Phi, PHI_DIM};
use kernelband::rng::Rng;

/// Random points in the unit φ-box, with occasional duplicates to
/// exercise degenerate weight vectors in k-means++.
fn random_points(seed: u64, n: usize) -> Vec<Phi> {
    let mut rng = Rng::new(seed).split("pts", 0);
    let mut pts = Vec::with_capacity(n);
    for i in 0..n {
        if i > 0 && rng.chance(0.15) {
            let j = rng.below(i as u64) as usize;
            let dup = pts[j];
            pts.push(dup);
            continue;
        }
        let mut p = [0.0; PHI_DIM];
        for v in p.iter_mut() {
            *v = rng.uniform();
        }
        pts.push(p);
    }
    pts
}

/// Two well-separated blobs (fast Lloyd convergence, non-trivial K).
fn blobs(seed: u64, per_blob: usize) -> Vec<Phi> {
    let mut rng = Rng::new(seed).split("blobs", 0);
    let mut pts = Vec::new();
    for center in [0.15, 0.85] {
        for _ in 0..per_blob {
            let mut p = [0.0; PHI_DIM];
            for v in p.iter_mut() {
                *v = center + 0.03 * rng.normal();
            }
            pts.push(p);
        }
    }
    pts
}

/// The pre-§Perf `lloyd_finish`, verbatim: a fixed number of Lloyd
/// steps with no convergence early-exit, then a snapshot assignment
/// against the converged centroids.
fn reference_cluster(points: &[Phi], k: usize, rng: &mut Rng,
                     iters: usize) -> Clustering {
    let k = k.max(1).min(points.len().max(1));
    let mut centroids = kmeanspp_init(points, k, rng);
    for _ in 0..iters {
        lloyd_step(points, &mut centroids);
    }
    let assign = {
        let mut snapshot = centroids.clone();
        lloyd_step(points, &mut snapshot)
    };
    let reps = representatives(points, &assign, &centroids);
    Clustering { assign, centroids, representatives: reps }
}

fn assert_same(a: &Clustering, b: &Clustering) {
    assert_eq!(a.assign, b.assign);
    assert_eq!(a.representatives, b.representatives);
    assert_eq!(a.centroids.len(), b.centroids.len());
    for (ca, cb) in a.centroids.iter().zip(&b.centroids) {
        for j in 0..PHI_DIM {
            assert_eq!(ca[j].to_bits(), cb[j].to_bits(), "centroid bits");
        }
    }
}

/// Early-exit is lossless: `RustKmeans::cluster` must be bit-identical
/// to the no-early-exit reference on arbitrary inputs, and must leave
/// the RNG at exactly the same stream position (it consumes draws only
/// in k-means++ seeding, never in the exit check).
#[test]
fn early_exit_cluster_matches_reference_bitwise() {
    let km = RustKmeans::default();
    for seed in 0..30u64 {
        let n = 1 + (seed as usize * 7) % 80;
        let k = 1 + (seed as usize) % 6;
        let pts = random_points(seed, n);
        let mut rng_a = Rng::new(1000 + seed).split("cl", 0);
        let mut rng_b = Rng::new(1000 + seed).split("cl", 0);
        let got = km.cluster(&pts, k, &mut rng_a);
        let want = reference_cluster(&pts, k, &mut rng_b, km.iters);
        assert_same(&got, &want);
        assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "RNG positions differ");
    }
}

/// At a Lloyd fixed point, warm-seeded re-clustering is the identity:
/// re-seeding from converged centroids reproduces the same assignments,
/// centroids and representatives bit-for-bit. (This is the intra-run
/// seeding path the policy takes every re-clustering after the first.)
#[test]
fn seeded_recluster_is_identity_at_fixed_point() {
    // generous iteration budget so the cold pass converges (early-exits)
    let km = RustKmeans { iters: 200 };
    for seed in 0..20u64 {
        let pts = blobs(seed, 12 + (seed as usize % 10));
        for k in [1usize, 2, 3] {
            let cold = km.cluster(&pts, k, &mut Rng::new(seed).split("s", k as u64));
            // verify convergence (precondition of the identity contract):
            // one more Lloyd step must move neither the assignment nor
            // the centroids (bitwise) — i.e. `cold` is a true fixed point
            let mut snapshot = cold.centroids.clone();
            let again = lloyd_step(&pts, &mut snapshot);
            if again != cold.assign || snapshot != cold.centroids {
                continue; // not converged — contract does not apply
            }
            let warm = km.cluster_seeded(&pts, &cold.centroids);
            assert_same(&warm, &cold);
            // and idempotent once more
            let warm2 = km.cluster_seeded(&pts, &warm.centroids);
            assert_same(&warm2, &warm);
        }
    }
}

/// Away from a fixed point, seeding may legitimately diverge from the
/// k-means++ path — but it must stay deterministic (no RNG at all) and
/// structurally valid: every assignment in range, representatives
/// members of their clusters, empty clusters unselectable.
#[test]
fn seeded_recluster_diverges_only_deterministically() {
    let km = RustKmeans::default();
    for seed in 0..20u64 {
        let pts = random_points(seed, 40 + (seed as usize % 30));
        // arbitrary (non-converged) seeds
        let mut srng = Rng::new(seed).split("seed", 1);
        let init: Vec<Phi> = (0..3)
            .map(|_| {
                let mut p = [0.0; PHI_DIM];
                for v in p.iter_mut() {
                    *v = srng.uniform();
                }
                p
            })
            .collect();
        let a = km.cluster_seeded(&pts, &init);
        let b = km.cluster_seeded(&pts, &init);
        assert_same(&a, &b);
        let k = a.centroids.len();
        assert!(a.assign.iter().all(|&c| c < k));
        for (ci, &rep) in a.representatives.iter().enumerate() {
            if rep == usize::MAX {
                // empty cluster: stale centroid, no members, unselectable
                assert_eq!(a.members(ci).next(), None);
            } else {
                assert_eq!(a.assign[rep], ci);
                assert!(a.members(ci).any(|m| m == rep));
            }
        }
    }
}

/// The iterator form of `Clustering::members` partitions the point set:
/// every point appears in exactly one cluster's member stream, in
/// ascending order.
#[test]
fn members_iterator_partitions_points() {
    let km = RustKmeans::default();
    for seed in 0..10u64 {
        let pts = random_points(seed, 50);
        let c = km.cluster(&pts, 4, &mut Rng::new(seed).split("m", 2));
        let k = c.centroids.len();
        let mut seen = vec![false; pts.len()];
        for ci in 0..k {
            let mut prev: Option<usize> = None;
            for m in c.members(ci) {
                assert!(!seen[m], "point {m} in two clusters");
                seen[m] = true;
                assert_eq!(c.assign[m], ci);
                if let Some(p) = prev {
                    assert!(p < m, "not ascending");
                }
                prev = Some(m);
            }
        }
        assert!(seen.iter().all(|&s| s), "point missing from all clusters");
    }
}
