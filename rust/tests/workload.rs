//! Suite-structure pinning: `Suite::full` category/difficulty counts
//! and `subset50` stratified-sampling reproducibility (seed 42, Table 7
//! counts exact). Previously these invariants were only implicitly
//! covered via runner artifacts; this file pins them directly.

use std::collections::HashSet;

use kernelband::eval::EXPERIMENT_SEED;
use kernelband::workload::{
    Suite, ALL_CATEGORIES, FULL_COUNTS, FULL_DIFFICULTY_COUNTS,
    SUBSET_COUNTS,
};

/// Per-category counts the generator actually emits: Table 7 with one
/// Element-wise kernel (`sin_computation`) excluded, total 183.
fn expected_full_counts() -> [usize; 13] {
    let mut counts = FULL_COUNTS;
    let ew = ALL_CATEGORIES
        .iter()
        .position(|c| c.name() == "Element-wise Ops")
        .expect("ElementWise in registry");
    counts[ew] -= 1;
    counts
}

#[test]
fn full_suite_pins_table7_category_counts() {
    let suite = Suite::full(EXPERIMENT_SEED);
    assert_eq!(suite.len(), 183);
    assert_eq!(suite.category_counts(), expected_full_counts());
    assert_eq!(suite.difficulty_counts(), FULL_DIFFICULTY_COUNTS);
    assert_eq!(FULL_DIFFICULTY_COUNTS.iter().sum::<usize>(), 183);
    assert_eq!(expected_full_counts().iter().sum::<usize>(), 183);
}

#[test]
fn full_suite_structure_is_seed_invariant() {
    // category assignment order is fixed; only latents/difficulty
    // shuffles depend on the seed — the marginals never move
    for seed in [EXPERIMENT_SEED, 0, 1, 42, 12345] {
        let suite = Suite::full(seed);
        assert_eq!(suite.len(), 183, "seed {seed}");
        assert_eq!(suite.category_counts(), expected_full_counts(),
                   "seed {seed}");
        assert_eq!(suite.difficulty_counts(), FULL_DIFFICULTY_COUNTS,
                   "seed {seed}");
        for (i, t) in suite.tasks.iter().enumerate() {
            assert_eq!(t.id, i, "seed {seed}");
            assert_eq!(t.lineage, 0, "hand-built tasks carry no lineage");
        }
    }
}

#[test]
fn subset50_pins_table7_subset_counts_exactly() {
    let subset = Suite::full(EXPERIMENT_SEED).subset50();
    assert_eq!(subset.len(), 50);
    assert_eq!(SUBSET_COUNTS.iter().sum::<usize>(), 50);
    assert_eq!(subset.category_counts(), SUBSET_COUNTS);
}

#[test]
fn subset50_is_reproducible_and_sampling_seed_is_42_not_suite_seed() {
    // the stratified sampler draws from Rng::new(42) regardless of the
    // suite generator seed, and the category layout is fixed — so the
    // *selected ids* are identical across suite seeds and across calls
    let ids = |seed: u64| -> Vec<usize> {
        Suite::full(seed).subset50().tasks.iter().map(|t| t.id).collect()
    };
    let reference = ids(EXPERIMENT_SEED);
    assert_eq!(reference, ids(EXPERIMENT_SEED), "repeat call");
    for seed in [0, 1, 42, 12345] {
        assert_eq!(reference, ids(seed), "suite seed {seed}");
    }
    // sorted, unique, and in-range
    assert!(reference.windows(2).all(|w| w[0] < w[1]));
    assert!(reference.iter().all(|&id| id < 183));
}

#[test]
fn subset50_picks_fall_inside_their_category_id_blocks() {
    // Suite::full lays categories out contiguously in Table-7 order;
    // every stratified pick must land in its category's id block
    let counts = expected_full_counts();
    let mut starts = [0usize; 13];
    for i in 1..13 {
        starts[i] = starts[i - 1] + counts[i - 1];
    }
    let subset = Suite::full(EXPERIMENT_SEED).subset50();
    for t in &subset.tasks {
        let ci = t.category.index();
        let lo = starts[ci];
        let hi = lo + counts[ci];
        assert!(
            (lo..hi).contains(&t.id),
            "{} (id {}) outside {:?} block {lo}..{hi}",
            t.name, t.id, t.category
        );
    }
}

#[test]
fn subset_tasks_are_verbatim_full_suite_tasks() {
    let full = Suite::full(EXPERIMENT_SEED);
    let subset = full.subset50();
    let by_id: Vec<u64> = full.tasks.iter().map(|t| t.fingerprint()).collect();
    for t in &subset.tasks {
        assert_eq!(t.fingerprint(), by_id[t.id], "{}", t.name);
    }
}

#[test]
fn torch_subset_of_subset50_matches_appendix_g_bounds() {
    let torch = Suite::full(EXPERIMENT_SEED).subset50().torch_subset();
    assert!(
        (25..=30).contains(&torch.len()),
        "torch subset len {}",
        torch.len()
    );
    let seen: HashSet<usize> = torch.tasks.iter().map(|t| t.id).collect();
    assert_eq!(seen.len(), torch.len());
    for t in &torch.tasks {
        assert!(t.torch_comparable, "{}", t.name);
        assert!(t.category.torch_comparable(), "{}", t.name);
    }
}
