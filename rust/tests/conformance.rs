//! Differential conformance harness over generated task spaces: every
//! grammar expansion runs through the simulated engine (and the
//! feature-gated PJRT leg) asserting the invariants the bandit loop
//! relies on — Assumption-1 pruning-bound admissibility, monotone
//! FLOP/byte scaling along each sweep, batch=1 ≡ batch=N bit-identity —
//! plus artifact-level cold/warm store byte-identity per space.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use kernelband::eval::{self, RunOpts, WorkloadOverride};
use kernelband::sched::BatchMode;
use kernelband::store::TraceStore;
use kernelband::workload::gen::conformance::{check_suite, pjrt_leg, PjrtLeg};
use kernelband::workload::gen::{GrammarSpec, GRAMMARS};
use kernelband::workload::Suite;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("kb_conf_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn grammar_suite(name: &str) -> (GrammarSpec, Suite) {
    let spec = GrammarSpec::parse(&format!("grammar:{name}"))
        .expect("registry spec parses");
    let suite = Suite::from_grammar(&spec).expect("registry grammar");
    (spec, suite)
}

fn run_grammar_table3(
    spec: &GrammarSpec,
    threads: usize,
    session: Option<Arc<TraceStore>>,
    batch: BatchMode,
) -> String {
    let opts = RunOpts {
        threads,
        session,
        batch,
        workload: Some(WorkloadOverride::from_spec(spec).unwrap()),
        obs: None,
    };
    eval::report_opts("table3", Some(2), &opts)
        .expect("table3 exists")
        .json
        .pretty()
}

/// The tentpole gate: every task of every registered grammar, on every
/// modeled device, passes admissibility, monotone-scaling and
/// batch-bit-identity checks.
#[test]
fn every_registered_grammar_space_is_conformant() {
    for g in GRAMMARS {
        let (_, suite) = grammar_suite(g.name);
        assert_eq!(suite.len(), g.cardinality(), "{}", g.name);
        let report = check_suite(&suite);
        for v in &report.violations {
            eprintln!("[violation] {v}");
        }
        assert!(
            report.ok(),
            "{}: {} violations across {} checks",
            g.name,
            report.violations.len(),
            report.checks
        );
        assert_eq!(report.tasks, suite.len() * 3, "{}: tasks x devices", g.name);
        assert!(report.checks > report.tasks, "{}", g.name);
    }
}

/// Acceptance criterion: a >=200-task grammar space runs against one
/// store twice — the second run performs zero simulated measurements
/// and produces a byte-identical artifact.
#[test]
fn grammar_space_cold_warm_store_byte_identity() {
    let (spec, suite) = grammar_suite("pow2sweep");
    assert!(suite.len() >= 200, "acceptance floor: {} tasks", suite.len());
    let dir = tmp_dir("pow2");

    let cold_store = Arc::new(TraceStore::open(&dir).unwrap());
    let cold = run_grammar_table3(&spec, 4, Some(cold_store.clone()),
                                  BatchMode::default());
    cold_store.persist().unwrap();
    let cold_sims = cold_store.stats.measure_sims.load(Ordering::Relaxed);
    assert!(cold_sims > 0);

    let warm_store = Arc::new(TraceStore::open(&dir).unwrap());
    let warm = run_grammar_table3(&spec, 4, Some(warm_store.clone()),
                                  BatchMode::default());
    assert_eq!(cold, warm, "cold/warm artifact bytes diverged");
    assert_eq!(warm_store.stats.measure_sims.load(Ordering::Relaxed), 0);
    assert_eq!(warm_store.stats.llm_sims.load(Ordering::Relaxed), 0);
    assert!(warm_store.stats.measure_hits.load(Ordering::Relaxed) > 0);

    let _ = std::fs::remove_dir_all(&dir);
}

/// Distinct grammar seeds must never share store entries: a warm store
/// for seed A is cold for seed B (fingerprints carry the lineage).
#[test]
fn store_entries_do_not_leak_across_grammar_seeds() {
    let dir = tmp_dir("seeds");
    let spec_a = GrammarSpec::parse("grammar:raggedmix:seed=1").unwrap();
    let spec_b = GrammarSpec::parse("grammar:raggedmix:seed=2").unwrap();

    let store = Arc::new(TraceStore::open(&dir).unwrap());
    run_grammar_table3(&spec_a, 2, Some(store.clone()), BatchMode::default());
    store.persist().unwrap();

    let reopened = Arc::new(TraceStore::open(&dir).unwrap());
    run_grammar_table3(&spec_b, 2, Some(reopened.clone()),
                       BatchMode::default());
    // seed B found nothing reusable — every measurement was simulated
    assert!(reopened.stats.measure_sims.load(Ordering::Relaxed) > 0);
    assert_eq!(reopened.stats.measure_hits.load(Ordering::Relaxed), 0);

    let _ = std::fs::remove_dir_all(&dir);
}

/// Artifact-level batch identity on a generated space: `Fixed(0)`,
/// `Fixed(1)` and the default mode are byte-identical.
#[test]
fn grammar_artifacts_are_batch_width_invariant_at_unit_width() {
    let (spec, _) = grammar_suite("raggedmix");
    let base = run_grammar_table3(&spec, 2, None, BatchMode::default());
    let fixed0 = run_grammar_table3(&spec, 2, None, BatchMode::Fixed(0));
    let fixed1 = run_grammar_table3(&spec, 2, None, BatchMode::Fixed(1));
    assert_eq!(base, fixed0);
    assert_eq!(base, fixed1);
}

/// Without the real bindings the PJRT leg reports a typed skip — never
/// a hard failure — on every generated space.
#[test]
fn pjrt_leg_is_a_typed_skip_without_backend() {
    for g in GRAMMARS {
        let (_, suite) = grammar_suite(g.name);
        match pjrt_leg(&suite) {
            PjrtLeg::Skipped(reason) => {
                assert!(
                    reason.contains("PJRT backend unavailable"),
                    "{}: {reason}",
                    g.name
                );
            }
            PjrtLeg::Ran => {} // real backend present: also acceptable
            PjrtLeg::Failed(e) => panic!("{}: PJRT leg failed: {e}", g.name),
        }
    }
}
