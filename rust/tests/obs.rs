//! Telemetry invariants for the unified observability layer
//! ([`kernelband::obs`]): attaching a recorder — with or without the
//! event stream, at any worker count, on either real backend — never
//! changes a byte of the deterministic artifact or the persisted trace
//! log; open-loop percentiles land in the measured ledger only;
//! histogram merges are order-independent; and a disabled recorder is
//! completely inert.

use std::path::PathBuf;
use std::sync::Arc;

use kernelband::gpu_model::Device;
use kernelband::llm::LlmProfile;
use kernelband::obs::{Histogram, Recorder};
use kernelband::sched::BatchMode;
use kernelband::server::{
    InProcess, Modeled, OpenLoopPlan, Percentiles, ServeBackend,
    ServeRequest, Sharded,
};
use kernelband::store::TraceStore;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("kb_obs_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn small_request() -> ServeRequest {
    let mut req = ServeRequest::grid(
        2,
        2,
        8,
        BatchMode::Fixed(1),
        2,
        Device::H20,
        LlmProfile::DeepSeekV32,
        7,
    );
    req.workers = 2;
    req
}

/// The tentpole invariant: `BENCH`-side bytes and the on-disk trace
/// log are identical with telemetry off, on, and on-with-events,
/// across worker counts 1/4/8 and both real backends.
#[test]
fn telemetry_never_changes_deterministic_bytes() {
    let base_dir = tmp_dir("base");
    let (base_det, base_trace) = {
        let store = Arc::new(TraceStore::open(&base_dir).unwrap());
        let report = InProcess.run_report(&small_request(), &store);
        store.persist().unwrap();
        let trace = std::fs::read(store.trace_path().unwrap()).unwrap();
        (report.deterministic_json().dump(), trace)
    };
    assert!(!base_trace.is_empty());

    for workers in [1usize, 4, 8] {
        for (tag, rec) in [
            ("off", None),
            ("on", Some(Recorder::new())),
            ("events", Some(Recorder::with_events())),
            ("trace", Some(Recorder::with_trace())),
        ] {
            let dir = tmp_dir(&format!("ip_w{workers}_{tag}"));
            let store = Arc::new(TraceStore::open(&dir).unwrap());
            let rec = rec.map(Arc::new);
            if let Some(r) = &rec {
                store.set_recorder(r.clone());
            }
            let mut req = small_request();
            req.workers = workers;
            let report = InProcess.run_report(&req, &store);
            store.persist().unwrap();
            assert_eq!(
                report.deterministic_json().dump(),
                base_det,
                "inprocess w={workers} obs={tag}: bytes drifted"
            );
            let trace =
                std::fs::read(store.trace_path().unwrap()).unwrap();
            assert_eq!(trace, base_trace,
                       "inprocess w={workers} obs={tag}: trace drifted");
            if let Some(r) = &rec {
                // the recorder actually observed the run
                let counters = r.counter_values();
                assert!(
                    counters
                        .iter()
                        .any(|(k, v)| k == "policy.arm_pulls" && *v > 0),
                    "no arm pulls recorded: {counters:?}"
                );
                let hists = r.hist_snapshots();
                assert!(hists.iter().any(|(k, s)| {
                    k == "server.job_latency_us" && s.count > 0
                }));
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    // sharded backend, with supervisor lease telemetry flowing
    for workers in [1usize, 4] {
        for on in [false, true] {
            let dir = tmp_dir(&format!("sh_w{workers}_{on}"));
            let store = Arc::new(TraceStore::open(&dir).unwrap());
            let rec = on.then(|| Arc::new(Recorder::with_events()));
            if let Some(r) = &rec {
                store.set_recorder(r.clone());
            }
            let mut req = small_request();
            req.workers = workers;
            let (report, _sup) = Sharded.run_report(&req, &store);
            store.persist().unwrap();
            assert_eq!(
                report.deterministic_json().dump(),
                base_det,
                "sharded w={workers} obs={on}: bytes drifted"
            );
            let trace =
                std::fs::read(store.trace_path().unwrap()).unwrap();
            assert_eq!(trace, base_trace,
                       "sharded w={workers} obs={on}: trace drifted");
            // supervisor counters ride the report (ledger side)
            let sup = report.supervisor.expect("sharded sets SupCounts");
            assert!(sup.leases > 0);
            assert_eq!(sup.double_executed, 0);
            if let Some(r) = &rec {
                assert!(r
                    .counter_values()
                    .iter()
                    .any(|(k, v)| k == "server.lease.grant" && *v > 0));
                // lease lifecycle events landed in the stream, one
                // JSON object per line
                let events = r.events_jsonl();
                assert!(events.lines().count() > 0);
                for line in events.lines() {
                    let doc = kernelband::util::json::Json::parse(line)
                        .expect("event line parses");
                    assert!(doc.get("kind").is_some());
                }
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
    let _ = std::fs::remove_dir_all(&base_dir);
}

/// Open-loop pacing reports percentiles into the measured ledger and
/// leaves the deterministic artifact byte-identical to a closed-loop
/// run of the same request.
#[test]
fn open_loop_percentiles_live_in_the_ledger_only() {
    let closed = {
        let store = Arc::new(TraceStore::in_memory());
        InProcess.run_report(&small_request(), &store)
    };
    assert!(closed.open_loop.is_none());
    assert!(closed.ledger_json().get("open_loop").is_none());

    let store = Arc::new(TraceStore::in_memory());
    store.set_recorder(Arc::new(Recorder::new()));
    let mut req = small_request();
    // fast arrivals: 4 jobs at 2000/s all land within 2ms
    req.open_loop = Some(OpenLoopPlan { rate: 2000.0, duration_s: 0.002 });
    let open = InProcess.run_report(&req, &store);

    assert_eq!(
        open.deterministic_json().dump(),
        closed.deterministic_json().dump(),
        "open-loop pacing leaked into deterministic bytes"
    );

    let stats = open.open_loop.as_ref().expect("open-loop stats present");
    assert!(stats.arrivals() > 0);
    let qw = stats.queue_wait();
    let lat = stats.latency();
    assert!(qw.p50 <= qw.p95 && qw.p95 <= qw.p99 && qw.p99 <= qw.max);
    assert!(lat.p50 <= lat.p95 && lat.p95 <= lat.p99 && lat.p99 <= lat.max);
    assert!(lat.p50 >= 0.0);

    let ledger = open.ledger_json();
    let ol = ledger.get("open_loop").expect("ledger carries open_loop");
    assert_eq!(ol.get("rate_jobs_per_s").unwrap().as_f64(), Some(2000.0));
    for section in ["queue_wait", "latency"] {
        let p = ol.get(section).unwrap();
        for key in ["p50_s", "p95_s", "p99_s", "mean_s", "max_s"] {
            assert!(p.get(key).and_then(|v| v.as_f64()).is_some(),
                    "{section}.{key} missing");
        }
    }
    // but never in the deterministic artifact
    assert!(open.deterministic_json().get("open_loop").is_none());

    // the modeled backend has no queue to pace
    let mut modeled = ServeRequest::default();
    modeled.open_loop = Some(OpenLoopPlan { rate: 1.0, duration_s: 1.0 });
    assert!(Modeled.run(&modeled, None).is_err());
}

/// Bucket-wise histogram merging is order-independent: any merge
/// order over the same per-worker histograms yields identical
/// snapshots (and therefore identical `METRICS.json` percentiles).
#[test]
fn histogram_merge_is_order_independent() {
    let parts: Vec<Histogram> = (0..3)
        .map(|w| {
            let h = Histogram::new();
            for i in 0..200u64 {
                h.record(i * 17 + w * 1009);
            }
            h
        })
        .collect();
    let forward = Histogram::new();
    for p in parts.iter() {
        forward.merge(p);
    }
    let backward = Histogram::new();
    for p in parts.iter().rev() {
        backward.merge(p);
    }
    assert_eq!(forward.snapshot(), backward.snapshot());
    assert_eq!(forward.snapshot().count, 600);

    // same property at the recorder level, counters included
    let make = |names: &[&str]| {
        let r = Recorder::new();
        for (i, n) in names.iter().enumerate() {
            r.add("x.count", (i as u64 + 1) * 3);
            let h = r.hist(n);
            for v in 0..50u64 {
                h.record(v * 7);
            }
        }
        r
    };
    let a = make(&["h.one", "h.two"]);
    let b = make(&["h.two", "h.three"]);
    let ab = Recorder::new();
    ab.merge_from(&a);
    ab.merge_from(&b);
    let ba = Recorder::new();
    ba.merge_from(&b);
    ba.merge_from(&a);
    assert_eq!(ab.metrics_json().dump(), ba.metrics_json().dump());
}

/// A disabled recorder accepts every call and records nothing; noop
/// handles are safe everywhere a real handle is.
#[test]
fn disabled_recorder_is_inert() {
    let r = Recorder::disabled();
    assert!(!r.enabled());
    r.add("c", 5);
    r.counter("c").incr();
    let h = r.hist("h");
    h.record(42);
    h.stop(h.start());
    r.event("kind", kernelband::util::json::Json::Null);
    r.end_span(r.span("s"));
    assert!(r.counter_values().is_empty());
    assert!(r.hist_snapshots().is_empty());
    assert!(r.events_jsonl().is_empty());
    let doc = r.metrics_json();
    assert_eq!(doc.get("enabled"), Some(&kernelband::util::json::Json::Bool(false)));

    // merging a disabled recorder into an enabled one is a no-op
    let live = Recorder::new();
    live.add("kept", 1);
    live.merge_from(&r);
    assert_eq!(live.counter_values(), vec![("kept".to_string(), 1)]);
}

/// Nearest-rank percentile definition, pinned by example.
#[test]
fn percentiles_are_exact_nearest_rank() {
    let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
    let p = Percentiles::from_samples(&xs);
    assert_eq!(p.p50, 50.0);
    assert_eq!(p.p95, 95.0);
    assert_eq!(p.p99, 99.0);
    assert_eq!(p.max, 100.0);
    assert!((p.mean - 50.5).abs() < 1e-9);

    assert_eq!(Percentiles::from_samples(&[]), Percentiles::default());
    let single = Percentiles::from_samples(&[0.25]);
    assert_eq!(single.p50, 0.25);
    assert_eq!(single.p99, 0.25);
}

/// Nearest-rank percentiles pinned at tiny N: with 2 or 3 samples the
/// ranks land on exact sample values (never interpolated), matching
/// `ceil(q·N)` clamped to `[1, N]`.
#[test]
fn percentiles_pin_nearest_rank_at_tiny_n() {
    let two = Percentiles::from_samples(&[5.0, 1.0]);
    assert_eq!(two.p50, 1.0); // ceil(0.50·2) = rank 1
    assert_eq!(two.p95, 5.0); // ceil(0.95·2) = rank 2
    assert_eq!(two.p99, 5.0);
    assert_eq!(two.max, 5.0);
    assert!((two.mean - 3.0).abs() < 1e-12);

    let three = Percentiles::from_samples(&[3.0, 1.0, 2.0]);
    assert_eq!(three.p50, 2.0); // ceil(0.50·3) = rank 2
    assert_eq!(three.p95, 3.0); // ceil(0.95·3) = rank 3
    assert_eq!(three.p99, 3.0);
    assert_eq!(three.max, 3.0);
}

/// `--obs trace` on a grammar space: the run emits a well-formed causal
/// span tree, a decision ledger whose recorded UCB scores replay
/// bit-exact, an exact (latent-optimum) non-increasing regret series,
/// and per-recluster covering stats — all without touching a
/// deterministic byte (the matrix test above covers the byte side).
#[test]
fn trace_mode_records_tree_ledger_regret_and_covering() {
    use kernelband::obs::decision::recheck_pull;
    use kernelband::obs::trace::{
        chrome_trace_from_spans, span_fields, span_from_fields,
    };
    use kernelband::util::json::{self as json, Json};
    use kernelband::workload::gen::GrammarSpec;
    use std::collections::{BTreeMap, BTreeSet};

    let store = Arc::new(TraceStore::in_memory());
    let rec = Arc::new(Recorder::with_trace());
    store.set_recorder(rec.clone());
    // 12 iterations crosses the recluster period (10) so covering
    // records exist; the grammar lineage makes the regret oracle exact
    let mut req = ServeRequest::grid(
        1,
        2,
        12,
        BatchMode::Fixed(1),
        2,
        Device::H20,
        LlmProfile::DeepSeekV32,
        7,
    );
    req.workers = 2;
    req.workload =
        Some(GrammarSpec::parse("grammar:pow2sweep").unwrap());
    let _report = InProcess.run_report(&req, &store);

    // --- span tree: parents resolve, no cycles, per-track monotone ts
    let spans = rec.trace().expect("trace sink present").snapshot();
    assert!(!spans.is_empty());
    for name in
        ["serve.request", "serve.round", "serve.job", "policy.iter"]
    {
        assert!(
            spans.iter().any(|s| s.name == name),
            "no {name} span in {:?}",
            spans.iter().map(|s| &s.name).collect::<Vec<_>>()
        );
    }
    let ids: BTreeSet<u64> = spans.iter().map(|s| s.span_id).collect();
    assert_eq!(ids.len(), spans.len(), "span ids not unique");
    let parent_of: BTreeMap<u64, u64> =
        spans.iter().map(|s| (s.span_id, s.parent_id)).collect();
    let mut last_ts: BTreeMap<u64, u64> = BTreeMap::new();
    for s in &spans {
        assert!(
            s.parent_id == 0 || ids.contains(&s.parent_id),
            "{}: parent {} unresolved",
            s.name,
            s.parent_id
        );
        let mut seen = BTreeSet::new();
        let mut cur = s.span_id;
        while cur != 0 {
            assert!(seen.insert(cur), "cycle at span {cur}");
            cur = parent_of.get(&cur).copied().unwrap_or(0);
        }
        let prev = last_ts.entry(s.track).or_insert(s.start_us);
        assert!(s.start_us >= *prev, "ts rewinds on track {}", s.track);
        *prev = s.start_us;
        // jsonl twin round-trips losslessly
        assert_eq!(span_from_fields(&span_fields(s)).as_ref(), Some(s));
    }
    // Chrome export: one event per span, args carry the tree ids
    let doc = chrome_trace_from_spans(&spans);
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert_eq!(events.len(), spans.len());
    for (ev, s) in events.iter().zip(&spans) {
        let args = ev.get("args").expect("args");
        assert_eq!(
            args.get("span_id").and_then(Json::as_f64),
            Some(s.span_id as f64)
        );
        assert_eq!(
            args.get("parent_id").and_then(Json::as_f64),
            Some(s.parent_id as f64)
        );
    }

    // --- decision ledger: every recorded score replays bit-exact
    let jsonl = rec.decisions_jsonl();
    assert!(!jsonl.is_empty(), "ledger empty under --obs trace");
    let (rows, skipped) = json::parse_lines_lossy(&jsonl);
    assert_eq!(skipped, 0);
    let mut rechecked = 0usize;
    for row in &rows {
        if row.get("kind").and_then(Json::as_str) == Some("pull") {
            rechecked += recheck_pull(row)
                .unwrap_or_else(|e| panic!("ledger drift: {e}"));
        }
    }
    assert!(rechecked > 0, "no pull rows rechecked");

    // --- regret: exact oracle (grammar lineage), non-increasing curve
    let metrics = rec.metrics_json();
    let regret = metrics.get("regret").expect("regret section");
    assert!(regret.f64_field("runs_exact") >= 1.0, "oracle not exact");
    assert!(regret.f64_field("pulls") > 0.0);
    let series: Vec<f64> = regret
        .get("cumulative_regret_per_pull")
        .and_then(Json::as_arr)
        .expect("regret series")
        .iter()
        .filter_map(Json::as_f64)
        .collect();
    assert!(!series.is_empty());
    for (a, b) in series.iter().zip(series.iter().skip(1)) {
        assert!(*b <= *a + 1e-9, "regret curve rose: {a} -> {b}");
        assert!(*b >= 0.0);
    }

    // --- covering: at least one recluster record with sane geometry
    let covering = metrics
        .get("covering")
        .and_then(Json::as_arr)
        .expect("covering section");
    assert!(!covering.is_empty(), "no recluster crossed");
    for c in covering {
        assert!(c.f64_field("clusters") >= 1.0);
        assert!(c.f64_field("covering_number") >= 1.0);
        assert!(
            c.f64_field("covering_number") <= c.f64_field("clusters")
        );
        assert!(
            c.f64_field("mean_radius")
                <= c.f64_field("max_radius") + 1e-9
        );
        assert!(c.f64_field("lipschitz") >= 0.0);
    }
}

/// `METRICS.json` schema contract: version, enabled flag, numeric
/// counters, and monotone histogram percentiles — the same checks
/// `scripts/check_metrics.py` runs in CI.
#[test]
fn metrics_json_is_schema_valid_with_monotone_percentiles() {
    let store = Arc::new(TraceStore::in_memory());
    let rec = Arc::new(Recorder::with_events());
    store.set_recorder(rec.clone());
    let (_report, _sup) = Sharded.run_report(&small_request(), &store);
    store.obs_export();

    let doc = rec.metrics_json();
    assert_eq!(
        doc.get("schema_version").and_then(|v| v.as_usize()),
        Some(kernelband::obs::METRICS_SCHEMA_VERSION)
    );
    assert_eq!(
        doc.get("enabled"),
        Some(&kernelband::util::json::Json::Bool(true))
    );
    for (name, s) in rec.hist_snapshots() {
        assert!(s.p50 <= s.p90, "{name}");
        assert!(s.p90 <= s.p95, "{name}");
        assert!(s.p95 <= s.p99, "{name}");
        assert!(s.p99 <= s.max, "{name}");
        assert!(s.min <= s.max, "{name}");
    }
    // the store exported its gauge set
    assert!(rec
        .counter_values()
        .iter()
        .any(|(k, _)| k == "store.profile.entries"));
}
