//! ExperimentRunner + result-artifact integration tests: thread-count
//! invariance (the tier-1 acceptance bar for the parallel refactor),
//! runner ↔ `Method::run` parity, and JSON round-trips on the
//! `BENCH_*.json` schema.

use kernelband::eval::{self, CellSpec, ExperimentRunner, Method};
use kernelband::gpu_model::Device;
use kernelband::llm::LlmProfile;
use kernelband::policy::PolicyMode;
use kernelband::util::json;
use kernelband::workload::Suite;

fn tiny_suite() -> Suite {
    let full = Suite::full(eval::EXPERIMENT_SEED);
    Suite { tasks: full.tasks.into_iter().step_by(23).collect() }
}

#[test]
fn runner_results_invariant_to_thread_count() {
    let suite = tiny_suite();
    let cells = vec![
        CellSpec::new(
            Method::KernelBand(PolicyMode::Full, 3),
            Device::H20,
            LlmProfile::DeepSeekV32,
            8,
            7,
        ),
        CellSpec::new(Method::BoN, Device::A100, LlmProfile::Gpt5, 8, 7),
        CellSpec::new(
            Method::Geak,
            Device::Rtx4090,
            LlmProfile::Gemini3Flash,
            8,
            7,
        ),
    ];
    let one = ExperimentRunner::new(1).run(&suite, &cells);
    let two = ExperimentRunner::new(2).run(&suite, &cells);
    let eight = ExperimentRunner::new(8).run(&suite, &cells);
    for ((a, b), c) in one.iter().zip(&two).zip(&eight) {
        // bit-identical metrics, serialized bytes included
        assert_eq!(a.to_json().dump(), b.to_json().dump());
        assert_eq!(a.to_json().dump(), c.to_json().dump());
        for ((ta, tb), tc) in a.traces.iter().zip(&b.traces).zip(&c.traces) {
            assert_eq!(ta.best_id, tb.best_id);
            assert_eq!(ta.best_speedup(), tc.best_speedup());
            assert_eq!(ta.total_cost_usd(), tb.total_cost_usd());
        }
    }
}

#[test]
fn runner_matches_method_run() {
    // the runner's flattened fan-out derives exactly the RNG streams
    // Method::run derives, so both paths agree trace for trace
    let suite = tiny_suite();
    let m = Method::KernelBand(PolicyMode::Full, 3);
    let direct = m.run(&suite, Device::H20, LlmProfile::DeepSeekV32, 8, 7);
    let cells =
        vec![CellSpec::new(m, Device::H20, LlmProfile::DeepSeekV32, 8, 7)];
    let via = ExperimentRunner::new(2).run(&suite, &cells);
    assert_eq!(direct.len(), via[0].traces.len());
    for (d, v) in direct.iter().zip(&via[0].traces) {
        assert_eq!(d.task_id, v.task_id);
        assert_eq!(d.best_id, v.best_id);
        assert_eq!(d.candidates.len(), v.candidates.len());
        assert_eq!(d.best_speedup(), v.best_speedup());
        assert_eq!(d.total_cost_usd(), v.total_cost_usd());
    }
}

#[test]
fn method_run_threads_is_thread_invariant() {
    let suite = tiny_suite();
    let m = Method::KernelBand(PolicyMode::Full, 3);
    let t1 =
        m.run_threads(&suite, Device::H20, LlmProfile::DeepSeekV32, 6, 3, 1);
    let t8 =
        m.run_threads(&suite, Device::H20, LlmProfile::DeepSeekV32, 6, 3, 8);
    for (a, b) in t1.iter().zip(&t8) {
        assert_eq!(a.best_speedup(), b.best_speedup());
        assert_eq!(a.candidates.len(), b.candidates.len());
    }
}

#[test]
fn table_report_artifact_bit_identical_across_threads() {
    // the acceptance bar: the BENCH_*.json artifact is byte-identical
    // for --threads 1 and --threads 8 at the same seed
    let a = eval::table3_report(2, 1);
    let b = eval::table3_report(2, 8);
    assert_eq!(a.text, b.text);
    assert_eq!(a.json.dump(), b.json.dump());
    assert_eq!(a.json.pretty(), b.json.pretty());
}

#[test]
fn artifact_roundtrips_through_parser() {
    let rep = eval::table3_report(3, 4);
    let parsed = json::parse(&rep.json.dump()).expect("compact parses");
    assert_eq!(parsed, rep.json);
    let pretty = json::parse(&rep.json.pretty()).expect("pretty parses");
    assert_eq!(pretty, rep.json);
    // schema essentials downstream consumers rely on
    assert_eq!(parsed.str_field("experiment").unwrap(), "table3");
    assert_eq!(parsed.f64_field("schema_version"), 1.0);
    let cells = parsed.get("cells").unwrap().as_arr().unwrap();
    assert!(!cells.is_empty());
    let metrics = cells[0].get("metrics").unwrap();
    for key in [
        "tasks",
        "correct_pct",
        "fast1_pct",
        "geomean_fallback",
        "total_cost_usd",
    ] {
        assert!(metrics.get(key).is_some(), "missing metrics.{key}");
    }
    let curve = cells[0].get("curve").unwrap().as_arr().unwrap();
    assert_eq!(curve.len(), 3);
}

#[test]
fn write_artifact_creates_bench_json() {
    let rep = eval::fig3_report();
    let dir = std::env::temp_dir().join(format!(
        "kernelband_artifact_test_{}",
        std::process::id()
    ));
    let path = rep.write_artifact(&dir).expect("write artifact");
    assert!(path.ends_with("BENCH_fig3.json"), "{path:?}");
    let text = std::fs::read_to_string(&path).expect("artifact readable");
    let parsed = json::parse(&text).expect("artifact is valid JSON");
    assert_eq!(parsed, rep.json);
    assert_eq!(parsed.str_field("experiment").unwrap(), "fig3");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn report_dispatch_covers_all_experiments() {
    // every name in ALL_EXPERIMENTS must dispatch AND run: T=1 keeps
    // the grid experiments cheap, and a name added to the list without
    // a matching report() arm fails here instead of mid-`repro all`
    for name in eval::ALL_EXPERIMENTS {
        let iters = if name == "regret" { Some(100) } else { Some(1) };
        let rep = eval::report(name, iters, 2)
            .unwrap_or_else(|| panic!("{name} listed but not dispatchable"));
        assert_eq!(rep.name, name);
        assert!(!rep.text.is_empty(), "{name} rendered nothing");
        let parsed = json::parse(&rep.json.dump())
            .unwrap_or_else(|e| panic!("{name} artifact invalid: {e}"));
        assert_eq!(parsed.str_field("experiment").unwrap(), name);
    }
    assert!(eval::report("nope", None, 1).is_none());
    // regret honors --iterations as its horizon
    let rep = eval::regret_report(100);
    assert_eq!(rep.name, "regret");
    let parsed = json::parse(&rep.json.dump()).unwrap();
    let cps = parsed.get("checkpoints").unwrap().as_arr().unwrap();
    assert!(!cps.is_empty());
    assert_eq!(parsed.f64_field("max_t"), 100.0);
}

#[test]
fn fig2_artifact_curves_are_monotone_trajectories() {
    let rep = eval::fig2_report(6, 4);
    let cells = rep.json.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 6);
    for cell in cells {
        let curve = cell.get("curve").unwrap().as_arr().unwrap();
        assert_eq!(curve.len(), 6);
        let vals: Vec<f64> =
            curve.iter().map(|v| v.as_f64().unwrap()).collect();
        for w in vals.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "curve regressed: {vals:?}");
        }
        assert!(vals[0] >= 1.0);
    }
}
