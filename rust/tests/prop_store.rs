//! Property sweeps for the persistent store: random traces round-trip
//! through the JSONL log bit-exactly, replay reconstructs identical
//! bandit/cluster warm-start state, and the caches survive
//! serialization. Same discipline as `prop_coordinator.rs`: hand-rolled
//! randomized cases over the crate's splittable RNG, failing seeds
//! printed via the case index.

use kernelband::bandit::ArmStats;
use kernelband::kernel::{Counters, KernelConfig, Measurement};
use kernelband::llm::{GenOutcome, Proposal};
use kernelband::rng::Rng;
use kernelband::store::cache;
use kernelband::store::log::{
    replay_text, to_jsonl, StepRecord, TaskRecord, TraceRecord,
};
use kernelband::store::warm::WarmIndex;
use kernelband::strategy::Strategy;
use kernelband::util::json;

const CASES: u64 = 150;

fn arbitrary_counters(rng: &mut Rng) -> Counters {
    Counters {
        regs_per_thread: rng.uniform_in(0.0, 255.0),
        smem_per_block: rng.uniform_in(0.0, 2e5),
        block_dim: rng.uniform_in(32.0, 1024.0),
        occupancy: rng.uniform(),
        sm_pct: rng.uniform_in(0.0, 100.0),
        dram_pct: rng.uniform_in(0.0, 100.0),
        l2_pct: rng.uniform_in(0.0, 100.0),
    }
}

fn arbitrary_task(rng: &mut Rng, task: &str) -> TaskRecord {
    TaskRecord {
        cell: format!("cell-{}", rng.below(4)),
        device: "H20".into(),
        llm: "DeepSeek-V3.2".into(),
        seed: rng.next_u64(),
        task_id: rng.below(200) as usize,
        task: task.to_string(),
        difficulty: 1 + rng.below(5) as usize,
        naive_latency_s: 10f64.powf(rng.uniform_in(-6.0, -1.0)),
        tenant: arbitrary_tenant(rng),
    }
}

/// ~⅓ of records carry a tenant namespace (multi-tenant serve logs);
/// the rest exercise the pre-tenant byte layout.
fn arbitrary_tenant(rng: &mut Rng) -> Option<String> {
    let pick = rng.below(6);
    (pick < 2).then(|| format!("t{pick}"))
}

fn arbitrary_step(rng: &mut Rng, task: &str, t: usize) -> StepRecord {
    let accepted = rng.chance(0.6);
    StepRecord {
        cell: format!("cell-{}", rng.below(4)),
        device: ["H20", "RTX 4090", "A100"][rng.below(3) as usize].to_string(),
        llm: "DeepSeek-V3.2".into(),
        task: task.to_string(),
        t,
        cluster: rng.below(5) as usize,
        strategy: if rng.chance(0.85) {
            Some(Strategy::from_index(rng.below(6) as usize))
        } else {
            None
        },
        parent: rng.below(30) as usize,
        parent_hash: rng.next_u64(),
        child_hash: accepted.then(|| rng.next_u64()),
        call_ok: accepted || rng.chance(0.5),
        exec_ok: accepted,
        reward: rng.uniform(),
        cost_usd: rng.uniform_in(0.0, 0.5),
        runtime_s: accepted.then(|| 10f64.powf(rng.uniform_in(-6.0, -1.0))),
        best_speedup: rng.uniform_in(1.0, 8.0),
        counters: accepted.then(|| arbitrary_counters(rng)),
        tenant: arbitrary_tenant(rng),
    }
}

fn arbitrary_trace(rng: &mut Rng) -> Vec<TraceRecord> {
    let n_tasks = 1 + rng.below(4) as usize;
    let mut records = Vec::new();
    for ti in 0..n_tasks {
        let name = format!("task_{ti}");
        records.push(TraceRecord::Task(arbitrary_task(rng, &name)));
        let steps = 1 + rng.below(30) as usize;
        for t in 1..=steps {
            records.push(TraceRecord::Step(arbitrary_step(rng, &name, t)));
        }
    }
    records
}

#[test]
fn prop_trace_records_roundtrip_exactly() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("trace-rt", 0);
        let records = arbitrary_trace(&mut rng);
        let text = to_jsonl(&records);
        let summary = replay_text(&text);
        assert_eq!(summary.corrupt_lines, 0, "case {case}");
        assert_eq!(summary.skipped_versions, 0, "case {case}");
        assert_eq!(summary.records, records, "case {case}");
        // serialize(replay(serialize(x))) == serialize(x), byte for byte
        assert_eq!(to_jsonl(&summary.records), text, "case {case}");
    }
}

#[test]
fn prop_truncation_loses_only_the_torn_record() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("trunc", 0);
        let records = arbitrary_trace(&mut rng);
        let text = to_jsonl(&records);
        // cut strictly inside the final record's JSON (never after its
        // closing brace, which would leave a complete parseable line)
        let last_line_start = text[..text.len() - 1].rfind('\n').map(|i| i + 1)
            .unwrap_or(0);
        let cut_at = last_line_start
            + 1
            + rng.below((text.len() - last_line_start - 2) as u64) as usize;
        let summary = replay_text(&text[..cut_at]);
        assert_eq!(summary.corrupt_lines, 1, "case {case}");
        assert_eq!(summary.records.len(), records.len() - 1, "case {case}");
        assert_eq!(
            summary.records,
            records[..records.len() - 1],
            "case {case}"
        );
    }
}

#[test]
fn prop_tenant_counts_survive_roundtrip() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("tenant-rt", 0);
        let records = arbitrary_trace(&mut rng);
        let a = replay_text(&to_jsonl(&records));
        let b = replay_text(&to_jsonl(&a.records));
        assert_eq!(a.tenant_counts(), b.tenant_counts(), "case {case}");
        // counts agree with a direct scan of the generated records
        let direct: usize = records
            .iter()
            .filter(|r| match r {
                TraceRecord::Task(t) => t.tenant.is_some(),
                TraceRecord::Step(s) => s.tenant.is_some(),
            })
            .count();
        let counted: usize =
            a.tenant_counts().iter().map(|(_, t, s)| t + s).sum();
        assert_eq!(direct, counted, "case {case}");
    }
}

#[test]
fn prop_replay_reconstructs_identical_warm_state() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("warm-id", 0);
        let records = arbitrary_trace(&mut rng);
        let clusters = 1 + rng.below(4) as usize;
        // write → replay → index must equal the index of the original
        let replayed = replay_text(&to_jsonl(&records)).records;
        let a = WarmIndex::from_records(&records, clusters);
        let b = WarmIndex::from_records(&replayed, clusters);
        assert_eq!(a.len(), b.len(), "case {case}");
        for key in a.keys() {
            let (device, llm, task) = key;
            let wa = a.get(device, llm, task).unwrap();
            let wb = b.get(device, llm, task).unwrap();
            assert_eq!(wa, wb, "case {case} key {key:?}");
            // centroid bits are exactly reproduced (φ from roundtripped
            // counters and runtimes)
            for (ca, cb) in wa.centroids.iter().zip(&wb.centroids) {
                for (x, y) in ca.iter().zip(cb) {
                    assert_eq!(x.to_bits(), y.to_bits(), "case {case}");
                }
            }
        }
    }
}

#[test]
fn prop_replayed_rewards_rebuild_identical_arm_stats() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("arms", 0);
        let records = arbitrary_trace(&mut rng);
        let replayed = replay_text(&to_jsonl(&records)).records;
        let index_a = WarmIndex::from_records(&records, 3);
        let index_b = WarmIndex::from_records(&replayed, 3);
        for key in index_a.keys() {
            let (device, llm, task) = key;
            let apply = |w: &kernelband::store::warm::TaskWarmStart| {
                let mut stats = ArmStats::new(1);
                for &(s, r) in &w.rewards {
                    stats.update(0, s, r);
                }
                stats
            };
            let sa = apply(index_a.get(device, llm, task).unwrap());
            let sb = apply(index_b.get(device, llm, task).unwrap());
            assert_eq!(sa.n, sb.n, "case {case}");
            let bits =
                |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&sa.mu), bits(&sb.mu), "case {case}");
        }
    }
}

#[test]
fn prop_measurement_cache_records_roundtrip_bit_exactly() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("meas-rt", 0);
        let m = Measurement {
            total_latency_s: 10f64.powf(rng.uniform_in(-9.0, 2.0)),
            per_shape_s: (0..rng.below(12))
                .map(|_| 10f64.powf(rng.uniform_in(-9.0, 2.0)))
                .collect(),
            counters: arbitrary_counters(&mut rng),
        };
        let key = rng.next_u64();
        let line = cache::measurement_record(key, &m).dump();
        let (k2, m2) =
            cache::measurement_from_record(&json::parse(&line).unwrap())
                .unwrap();
        assert_eq!(k2, key, "case {case}");
        assert_eq!(
            m2.total_latency_s.to_bits(),
            m.total_latency_s.to_bits(),
            "case {case}"
        );
        assert_eq!(m2.per_shape_s.len(), m.per_shape_s.len());
        for (a, b) in m2.per_shape_s.iter().zip(&m.per_shape_s) {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case}");
        }
        assert_eq!(
            m2.counters.occupancy.to_bits(),
            m.counters.occupancy.to_bits(),
            "case {case}"
        );
    }
}

#[test]
fn prop_proposal_cache_records_roundtrip_exactly() {
    for case in 0..CASES {
        let mut rng = Rng::new(case).split("prop-rt", 0);
        let p = Proposal {
            outcome: match rng.below(3) {
                0 => GenOutcome::Ok,
                1 => GenOutcome::CompileError,
                _ => GenOutcome::WrongOutput,
            },
            config: KernelConfig {
                tile_m: rng.below(6) as u8,
                tile_n: rng.below(6) as u8,
                tile_k: rng.below(6) as u8,
                vector: rng.below(4) as u8,
                fusion: rng.below(4) as u8,
                pipeline: rng.below(4) as u8,
                loop_order: rng.below(6) as u8,
                layout: rng.below(4) as u8,
            },
            tokens_in: rng.below(1 << 20),
            tokens_out: rng.below(1 << 20),
            cost_usd: rng.uniform_in(0.0, 2.0),
            latency_s: rng.uniform_in(1.0, 2000.0),
        };
        let key = rng.next_u64();
        let line = cache::proposal_record(key, &p).dump();
        let (k2, p2) =
            cache::proposal_from_record(&json::parse(&line).unwrap()).unwrap();
        assert_eq!(k2, key, "case {case}");
        assert_eq!(p2.outcome, p.outcome, "case {case}");
        assert_eq!(p2.config, p.config, "case {case}");
        assert_eq!(p2.tokens_in, p.tokens_in, "case {case}");
        assert_eq!(p2.tokens_out, p.tokens_out, "case {case}");
        assert_eq!(p2.cost_usd.to_bits(), p.cost_usd.to_bits(), "case {case}");
        assert_eq!(
            p2.latency_s.to_bits(),
            p.latency_s.to_bits(),
            "case {case}"
        );
    }
}
