//! Cross-layer determinism & regression suite for the batched
//! measurement scheduler ([`kernelband::sched`]).
//!
//! The heart of this file is [`legacy_optimize_warm`]: a **frozen
//! transcription of the pre-batch `KernelBand::optimize_warm` body**
//! (the single-candidate loop as it shipped before `optimize_sched`
//! existed — branchy UCB scan, per-candidate `measure`, no admission
//! bounds, no shared caches). It is the executable reference for the
//! batch-1 equivalence contract: `optimize_sched` with the default
//! context must reproduce it **bit for bit** — every candidate
//! measurement, every reward, every RNG-dependent pick — for every
//! policy mode, with and without warm-start. Do not "modernize" this
//! function; its whole value is that it does not move.
//!
//! On top of that the suite locks:
//! * batch = N determinism across `--threads` and across store
//!   cold/warm runs (batch-aware cache lookups bypass everything);
//! * the shared re-clustering memo's interleaving-invariance (any job
//!   order, any parallelism → bit-identical per-job traces);
//! * zero representative-profiling recomputation on warm replay
//!   (profiler cache ↔ store integration);
//! * masked max-reduce UCB ≡ the branchy reference on 1000-candidate
//!   frontiers.

use std::sync::Arc;

use kernelband::bandit::{softmax_kernel_pick_in_place, ArmStats,
                         MaskedUcb, RewardRecord};
use kernelband::cluster::{ClusterBackend, Clustering, RustKmeans};
use kernelband::engine::{EvalEngine, SimEngine};
use kernelband::eval::runner::experiment_json;
use kernelband::eval::{CellSpec, ExperimentRunner, Method};
use kernelband::features::{phi, Phi};
use kernelband::gpu_model::Device;
use kernelband::kernel::{Candidate, Origin};
use kernelband::llm::{LlmBackend, LlmProfile, PromptMode,
                      ProposalRequest, SurrogateLlm};
use kernelband::policy::frontier::{nearest_centroid, ClusterState,
                                   Frontier};
use kernelband::policy::{IterationRecord, KernelBand, PolicyConfig,
                         PolicyMode, Trace};
use kernelband::profiler::{HardwareSignature, Profiler};
use kernelband::rng::Rng;
use kernelband::sched::centroids::CentroidCache;
use kernelband::sched::{BatchMode, SchedContext};
use kernelband::store::warm::TaskWarmStart;
use kernelband::store::TraceStore;
use kernelband::strategy::{Strategy, NUM_STRATEGIES};
use kernelband::util::par::spawn_map;
use kernelband::verify::verify_outcome;
use kernelband::workload::{Suite, TaskSpec};

// ---------------------------------------------------------------------------
// the frozen pre-batch reference loop
// ---------------------------------------------------------------------------

/// The pre-batch `KernelBand::optimize_warm` body, transcribed
/// verbatim at the moment the batched scheduler landed (only
/// `self.config/ucb/kmeans` became parameters, and the three
/// later-added `IterationRecord` batch fields take their batch-1
/// values). Frozen: this is what "bit-identical to the pre-batch
/// path" *means*.
#[allow(clippy::too_many_lines)]
fn legacy_optimize_warm<E: EvalEngine, L: LlmBackend>(
    cfg: &PolicyConfig,
    ucb: &MaskedUcb,
    kmeans: &RustKmeans,
    task: &TaskSpec,
    engine: &E,
    llm: &L,
    root: &Rng,
    warm: Option<&TaskWarmStart>,
) -> Trace {
    let rng = root.split("kernelband", task.id as u64);
    let freeform = matches!(
        cfg.mode,
        PolicyMode::NoStrategySet | PolicyMode::NoStrategyRawProfiling
    );

    // line 1: P ← {k0}
    let naive_cfg = task.naive_config();
    let naive_meas = engine.measure(task, &naive_cfg, &mut rng.split("m", 0));
    let naive_latency_s = naive_meas.total_latency_s;
    let mut front = Frontier::new();
    front.push(phi(&naive_meas, naive_latency_s), &naive_meas, 0);
    let mut candidates = vec![Candidate {
        id: 0,
        config: naive_cfg,
        origin: Origin::Naive,
        measurement: naive_meas,
        born_at: 0,
    }];

    // lines 1–3: single initial cluster, optimistic arms, open masks
    let mut clustering = Clustering {
        assign: vec![0],
        centroids: vec![front.phis[0]],
        representatives: vec![0],
    };
    let mut state = ClusterState::new(cfg.theta_sat);
    state.rebuild(&clustering, vec![None]);
    let mut stats = ArmStats::new(1);
    let mut history: Vec<RewardRecord> = Vec::new();
    let mut profiler = Profiler::new();
    let mut records: Vec<IterationRecord> = Vec::new();
    let mut best_id = 0usize;
    let mut pick_pool: Vec<usize> = Vec::new();
    let mut pick_w: Vec<f64> = Vec::new();
    let mut prev_centroids: Option<Vec<Phi>> = None;

    let mut warm_centroids: Option<Vec<Phi>> = None;
    if let Some(w) = warm {
        if !freeform {
            for &(s, r) in &w.rewards {
                stats.update(0, s, r);
                history.push(RewardRecord { kernel: 0, strategy: s, reward: r });
            }
            if w.centroids.len() == cfg.clusters {
                warm_centroids = Some(w.centroids.clone());
            }
        }
    }

    for t in 1..=cfg.iterations {
        let may_cluster = !freeform
            && t % cfg.recluster_every == 0
            && candidates.len() >= 2 * cfg.clusters;
        if may_cluster {
            let use_warm = warm_centroids
                .as_ref()
                .map_or(false, |init| init.len() <= front.len());
            clustering = if use_warm {
                let init = warm_centroids.take().expect("checked above");
                kmeans.cluster_seeded(&front.phis, &init)
            } else if let Some(init) = prev_centroids.take() {
                kmeans.cluster_seeded(&front.phis, &init)
            } else {
                let mut crng = rng.split("cluster", t as u64);
                kmeans.cluster(&front.phis, cfg.clusters, &mut crng)
            };
            prev_centroids = Some(clustering.centroids.clone());
            let k = clustering.centroids.len();
            stats = if cfg.reset_arms_on_recluster {
                ArmStats::new(k)
            } else {
                ArmStats::reseed(k, &history, &clustering.assign)
            };
            let mut cluster_sigs: Vec<Option<HardwareSignature>> =
                vec![None; k];
            if cfg.mode != PolicyMode::NoProfiling {
                for (ci, &rep) in
                    clustering.representatives.iter().enumerate()
                {
                    if rep != usize::MAX {
                        let cand = &candidates[rep];
                        cluster_sigs[ci] = Some(profiler.profile(
                            cand.config.code_hash(),
                            &cand.measurement.counters,
                        ));
                    }
                }
            }
            state.rebuild(&clustering, cluster_sigs);
        }

        let (cluster_id, strategy, prompt_mode) = match cfg.mode {
            PolicyMode::Full
            | PolicyMode::NoClustering
            | PolicyMode::NoProfiling => {
                let (ci, s) = ucb
                    .select(&stats, t, state.mask())
                    .or_else(|| ucb.select(&stats, t, state.nonempty()))
                    .expect("frontier is non-empty");
                (ci, Some(s), PromptMode::Strategy(s))
            }
            PolicyMode::LlmStrategySelection => {
                let s =
                    llm.select_strategy(task, &mut rng.split("sel", t as u64));
                pick_pool.clear();
                pick_pool.extend(
                    (0..state.clusters())
                        .filter(|&ci| !state.members(ci).is_empty()),
                );
                let pick = rng.split("cl", t as u64)
                    .below(pick_pool.len() as u64) as usize;
                (pick_pool[pick], Some(s), PromptMode::Strategy(s))
            }
            PolicyMode::NoStrategySet => (0, None, PromptMode::FreeForm),
            PolicyMode::NoStrategyRawProfiling => {
                (0, None, PromptMode::RawProfiling(front.sigs[best_id]))
            }
        };

        let parent_idx = if freeform {
            best_id
        } else {
            let members = state.members(cluster_id);
            debug_assert!(!members.is_empty());
            let best_t = front.latencies[best_id];
            pick_pool.clear();
            pick_pool.extend(members.iter().copied().filter(|&m| {
                front.latencies[m] <= cfg.prune_factor * best_t
            }));
            let pool: &[usize] =
                if pick_pool.is_empty() { members } else { &pick_pool };
            if cfg.mode == PolicyMode::NoProfiling {
                *pool.iter().max_by_key(|&&m| front.born_at[m]).unwrap()
            } else {
                let s = strategy.expect("strategy modes only");
                pick_w.clear();
                pick_w.extend(pool.iter().map(|&m| {
                    front.sigs[m].headroom(s, cfg.theta_sat)
                }));
                let pick = softmax_kernel_pick_in_place(
                    &mut pick_w,
                    &mut rng.split("pick", t as u64),
                );
                pool[pick]
            }
        };

        let parent_cfg = candidates[parent_idx].config;
        let req = ProposalRequest {
            task,
            parent: &parent_cfg,
            mode: prompt_mode,
            sim: engine.gpu(),
            iterative: true,
        };
        let proposal = llm.propose(&req, &mut rng.split("gen", t as u64));
        let verdict = verify_outcome(proposal.outcome);

        let mut reward = 0.0;
        let mut accepted = None;
        if verdict.passed() {
            let meas = engine.measure(
                task,
                &proposal.config,
                &mut rng.split("m", t as u64),
            );
            let parent_t = front.latencies[parent_idx];
            reward = ((parent_t - meas.total_latency_s) / parent_t)
                .clamp(0.0, 1.0);
            let id = candidates.len();
            let p = phi(&meas, naive_latency_s);
            let nearest = nearest_centroid(&p, &clustering.centroids);
            front.push(p, &meas, t);
            clustering.assign.push(nearest);
            state.insert(id, nearest);
            if meas.total_latency_s < front.latencies[best_id] {
                best_id = id;
            }
            accepted = Some(id);
            candidates.push(Candidate {
                id,
                config: proposal.config,
                origin: Origin::Llm {
                    parent: parent_idx,
                    strategy: strategy.unwrap_or(Strategy::Reordering),
                },
                measurement: meas,
                born_at: t,
            });
        }

        if let Some(s) = strategy {
            stats.update(cluster_id, s, reward);
            history.push(RewardRecord {
                kernel: parent_idx,
                strategy: s,
                reward,
            });
        }

        let best_speedup_so_far = if candidates.len() > 1 {
            naive_latency_s
                / candidates[best_id].measurement.total_latency_s
        } else {
            0.0
        };
        records.push(IterationRecord {
            t,
            cluster: cluster_id,
            strategy,
            parent: parent_idx,
            verdict,
            reward,
            accepted,
            cost_usd: proposal.cost_usd,
            llm_serial_s: proposal.latency_s,
            best_speedup_so_far,
            batch_accepted: Vec::new(),
            batch_pruned: 0,
            batch_width: 1,
        });
    }

    Trace {
        task_id: task.id,
        task_name: task.name.clone(),
        difficulty: task.difficulty,
        candidates,
        records,
        best_id,
        naive_latency_s,
        profile_cost_s: profiler.total_cost_s,
        profile_runs: profiler.misses,
    }
}

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

fn assert_traces_bit_equal(a: &Trace, b: &Trace, ctx: &str) {
    assert_eq!(a.task_id, b.task_id, "{ctx}: task_id");
    assert_eq!(a.best_id, b.best_id, "{ctx}: best_id");
    assert_eq!(a.naive_latency_s.to_bits(), b.naive_latency_s.to_bits(),
               "{ctx}: naive latency");
    assert_eq!(a.profile_runs, b.profile_runs, "{ctx}: profile_runs");
    assert_eq!(a.profile_cost_s.to_bits(), b.profile_cost_s.to_bits(),
               "{ctx}: profile cost");
    assert_eq!(a.candidates.len(), b.candidates.len(),
               "{ctx}: candidate count");
    for (i, (ca, cb)) in a.candidates.iter().zip(&b.candidates).enumerate()
    {
        assert_eq!(ca.config, cb.config, "{ctx}: candidate {i} config");
        assert_eq!(ca.origin, cb.origin, "{ctx}: candidate {i} origin");
        assert_eq!(ca.born_at, cb.born_at, "{ctx}: candidate {i} born_at");
        assert_eq!(
            ca.measurement.total_latency_s.to_bits(),
            cb.measurement.total_latency_s.to_bits(),
            "{ctx}: candidate {i} latency"
        );
        assert_eq!(ca.measurement.per_shape_s, cb.measurement.per_shape_s,
                   "{ctx}: candidate {i} shapes");
        assert_eq!(
            ca.measurement.counters.sm_pct.to_bits(),
            cb.measurement.counters.sm_pct.to_bits(),
            "{ctx}: candidate {i} sm"
        );
        assert_eq!(
            ca.measurement.counters.dram_pct.to_bits(),
            cb.measurement.counters.dram_pct.to_bits(),
            "{ctx}: candidate {i} dram"
        );
        assert_eq!(
            ca.measurement.counters.l2_pct.to_bits(),
            cb.measurement.counters.l2_pct.to_bits(),
            "{ctx}: candidate {i} l2"
        );
    }
    assert_eq!(a.records.len(), b.records.len(), "{ctx}: record count");
    for (i, (ra, rb)) in a.records.iter().zip(&b.records).enumerate() {
        assert_eq!(ra.t, rb.t, "{ctx}: record {i} t");
        assert_eq!(ra.cluster, rb.cluster, "{ctx}: record {i} cluster");
        assert_eq!(ra.strategy, rb.strategy, "{ctx}: record {i} strategy");
        assert_eq!(ra.parent, rb.parent, "{ctx}: record {i} parent");
        assert_eq!(ra.verdict, rb.verdict, "{ctx}: record {i} verdict");
        assert_eq!(ra.accepted, rb.accepted, "{ctx}: record {i} accepted");
        assert_eq!(ra.reward.to_bits(), rb.reward.to_bits(),
                   "{ctx}: record {i} reward");
        assert_eq!(ra.cost_usd.to_bits(), rb.cost_usd.to_bits(),
                   "{ctx}: record {i} cost");
        assert_eq!(ra.llm_serial_s.to_bits(), rb.llm_serial_s.to_bits(),
                   "{ctx}: record {i} llm latency");
        assert_eq!(
            ra.best_speedup_so_far.to_bits(),
            rb.best_speedup_so_far.to_bits(),
            "{ctx}: record {i} best speedup"
        );
        assert_eq!(ra.batch_accepted, rb.batch_accepted,
                   "{ctx}: record {i} batch_accepted");
        assert_eq!(ra.batch_pruned, rb.batch_pruned,
                   "{ctx}: record {i} batch_pruned");
        assert_eq!(ra.batch_width, rb.batch_width,
                   "{ctx}: record {i} batch_width");
    }
}

fn tiny_suite() -> Suite {
    let full = Suite::full(kernelband::eval::EXPERIMENT_SEED);
    Suite { tasks: full.tasks.into_iter().step_by(31).collect() }
}

// ---------------------------------------------------------------------------
// batch = 1 ≡ the frozen legacy loop
// ---------------------------------------------------------------------------

#[test]
fn batch1_is_bit_identical_to_the_frozen_legacy_loop() {
    let suite = Suite::full(1);
    let engine = SimEngine::new(Device::H20);
    let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
    let modes = [
        (PolicyMode::Full, 40usize),
        (PolicyMode::Full, 25),
        (PolicyMode::NoClustering, 25),
        (PolicyMode::NoProfiling, 40),
        (PolicyMode::LlmStrategySelection, 25),
        (PolicyMode::NoStrategySet, 20),
        (PolicyMode::NoStrategyRawProfiling, 20),
    ];
    for (mi, &(mode, iters)) in modes.iter().enumerate() {
        for (ti, task) in suite.tasks.iter().step_by(47).enumerate() {
            let mut cfg = PolicyConfig::with_mode(mode);
            cfg.iterations = iters;
            let root = Rng::new(1000 + mi as u64 * 31 + ti as u64);
            let band = KernelBand::new(cfg.clone());
            let legacy = legacy_optimize_warm(
                &cfg, &band.ucb, &band.kmeans, task, &engine, &llm,
                &root, None,
            );
            let batched = band.optimize_sched(
                task, &engine, &llm, &root, None,
                &SchedContext::default(),
            );
            assert_traces_bit_equal(
                &legacy, &batched,
                &format!("{mode:?} task {}", task.name),
            );
        }
    }
}

#[test]
fn batch1_matches_legacy_under_warm_start() {
    let suite = Suite::full(1);
    let engine = SimEngine::new(Device::H20);
    let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
    let task = &suite.tasks[4];
    // a warm state with both reward priors and fitted centroid seeds
    let mut rewards = Vec::new();
    let mut r = Rng::new(77);
    for i in 0..40 {
        rewards.push((
            Strategy::from_index(i % NUM_STRATEGIES),
            r.uniform(),
        ));
    }
    let centroid = |x: f64| -> Phi { [x; 5] };
    let warm = TaskWarmStart {
        rewards,
        centroids: vec![centroid(0.2), centroid(0.5), centroid(0.8)],
        best_runtime_s: 1.0e-3,
        steps: 40,
    };
    let mut cfg = PolicyConfig::default();
    cfg.iterations = 40;
    let band = KernelBand::new(cfg.clone());
    let root = Rng::new(9);
    let legacy = legacy_optimize_warm(
        &cfg, &band.ucb, &band.kmeans, task, &engine, &llm, &root,
        Some(&warm),
    );
    let batched = band.optimize_sched(
        task, &engine, &llm, &root, Some(&warm),
        &SchedContext::default(),
    );
    assert_traces_bit_equal(&legacy, &batched, "warm-start");
}

// ---------------------------------------------------------------------------
// batch = N: determinism across threads + store cold/warm bypass
// ---------------------------------------------------------------------------

#[test]
fn batch_n_artifacts_are_thread_invariant() {
    let suite = tiny_suite();
    let cells = vec![
        CellSpec::new(
            Method::KernelBand(PolicyMode::Full, 3),
            Device::H20,
            LlmProfile::DeepSeekV32,
            12,
            7,
        ),
        CellSpec::new(
            Method::KernelBand(PolicyMode::Full, 2),
            Device::A100,
            LlmProfile::Gpt5,
            12,
            7,
        ),
    ];
    let t1 = ExperimentRunner::new(1).with_batch(4).run(&suite, &cells);
    let t8 = ExperimentRunner::new(8).with_batch(4).run(&suite, &cells);
    assert_eq!(
        experiment_json("prop", 12, 7, &t1).dump(),
        experiment_json("prop", 12, 7, &t8).dump()
    );
}

#[test]
fn batch_n_warm_store_run_bypasses_everything_byte_identically() {
    let suite = tiny_suite();
    let store = Arc::new(TraceStore::in_memory());
    let cells = vec![CellSpec::new(
        Method::KernelBand(PolicyMode::Full, 3),
        Device::H20,
        LlmProfile::DeepSeekV32,
        12,
        5,
    )];
    let runner = ExperimentRunner::new(2)
        .with_session(Some(store.clone()))
        .with_batch(3);
    let cold = runner.run(&suite, &cells);
    let sims_after_cold = store
        .stats
        .measure_sims
        .load(std::sync::atomic::Ordering::Relaxed);
    let llm_after_cold = store
        .stats
        .llm_sims
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(sims_after_cold > 0);
    let warm = runner.run(&suite, &cells);
    // warm: zero new simulated measurements, zero LLM round-trips —
    // the batch-aware cache lookups bypass the fused path entirely
    assert_eq!(
        store.stats.measure_sims
            .load(std::sync::atomic::Ordering::Relaxed),
        sims_after_cold
    );
    assert_eq!(
        store.stats.llm_sims.load(std::sync::atomic::Ordering::Relaxed),
        llm_after_cold
    );
    assert_eq!(
        experiment_json("prop", 12, 5, &cold).dump(),
        experiment_json("prop", 12, 5, &warm).dump()
    );
    // and the store-attached batched run matches the storeless one
    let plain = ExperimentRunner::new(2).with_batch(3).run(&suite, &cells);
    assert_eq!(
        experiment_json("prop", 12, 5, &plain).dump(),
        experiment_json("prop", 12, 5, &cold).dump()
    );
}

// ---------------------------------------------------------------------------
// shared-scheduler memo: job interleaving never changes job results
// ---------------------------------------------------------------------------

#[test]
fn centroid_memo_is_interleaving_invariant() {
    let suite = Suite::full(1);
    let engine = SimEngine::new(Device::H20);
    let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
    // a job mix with *matching fingerprints* (duplicated tasks), the
    // case the shared memo exists for
    let job_tasks = [4usize, 7, 4, 7, 4, 11];
    let mut cfg = PolicyConfig::default();
    cfg.iterations = 40;

    let solo: Vec<Trace> = job_tasks
        .iter()
        .map(|&ti| {
            KernelBand::new(cfg.clone()).optimize_sched(
                &suite.tasks[ti],
                &engine,
                &llm,
                &Rng::new(3),
                None,
                &SchedContext::default(),
            )
        })
        .collect();

    let run_with_cache = |order: &[usize]| -> Vec<(usize, Trace)> {
        let cache = Arc::new(CentroidCache::new());
        let ctx = SchedContext {
            mode: BatchMode::Fixed(1),
            centroids: Some(cache.clone()),
            profiles: None,
            obs: None,
            job: None,
        };
        let out: Vec<(usize, Trace)> = order
            .iter()
            .map(|&j| {
                let tr = KernelBand::new(cfg.clone()).optimize_sched(
                    &suite.tasks[job_tasks[j]],
                    &engine,
                    &llm,
                    &Rng::new(3),
                    None,
                    &ctx,
                );
                (j, tr)
            })
            .collect();
        // duplicated jobs actually exercise the memo
        assert!(cache.hits() > 0, "memo never hit");
        out
    };

    for order in [
        vec![0usize, 1, 2, 3, 4, 5],
        vec![5, 4, 3, 2, 1, 0],
        vec![2, 0, 4, 1, 5, 3],
    ] {
        for (j, tr) in run_with_cache(&order) {
            assert_traces_bit_equal(
                &solo[j], &tr,
                &format!("order {order:?} job {j}"),
            );
        }
    }

    // and under real parallel interleaving
    let cache = Arc::new(CentroidCache::new());
    let ctx = SchedContext {
        mode: BatchMode::Fixed(1),
        centroids: Some(cache),
        profiles: None,
        obs: None,
        job: None,
    };
    let jobs: Vec<usize> = (0..job_tasks.len()).collect();
    let parallel: Vec<Trace> = spawn_map(&jobs, |_, &j| {
        KernelBand::new(cfg.clone()).optimize_sched(
            &suite.tasks[job_tasks[j]],
            &engine,
            &llm,
            &Rng::new(3),
            None,
            &ctx,
        )
    });
    for (j, tr) in parallel.iter().enumerate() {
        assert_traces_bit_equal(&solo[j], tr, &format!("parallel job {j}"));
    }
}

// ---------------------------------------------------------------------------
// profiler cache ↔ store: warm replay never re-profiles
// ---------------------------------------------------------------------------

#[test]
fn warm_session_skips_representative_profiling_entirely() {
    let suite = tiny_suite();
    let store = Arc::new(TraceStore::in_memory());
    let cells = vec![CellSpec::new(
        Method::KernelBand(PolicyMode::Full, 3),
        Device::H20,
        LlmProfile::DeepSeekV32,
        40,
        3,
    )];
    let runner =
        ExperimentRunner::new(2).with_session(Some(store.clone()));
    let cold = runner.run(&suite, &cells);
    let cold_profiled: u64 =
        cold[0].traces.iter().map(|t| t.profile_runs).sum();
    assert!(cold_profiled > 0, "cold run never profiled — test inert");
    assert!(store.profile_count() > 0);

    let warm = runner.run(&suite, &cells);
    let warm_profiled: u64 =
        warm[0].traces.iter().map(|t| t.profile_runs).sum();
    assert_eq!(warm_profiled, 0,
               "warm replay recomputed representative profiles");
    for t in &warm[0].traces {
        assert_eq!(t.profile_cost_s, 0.0);
    }
    // identical results regardless
    assert_eq!(
        experiment_json("prop", 40, 3, &cold).dump(),
        experiment_json("prop", 40, 3, &warm).dump()
    );
}

// ---------------------------------------------------------------------------
// adaptive batch width (`--batch auto`): determinism contract
// ---------------------------------------------------------------------------

const AUTO: BatchMode = BatchMode::Adaptive { min: 1, max: 8 };

fn auto_cells() -> Vec<CellSpec> {
    vec![
        CellSpec::new(
            Method::KernelBand(PolicyMode::Full, 3),
            Device::H20,
            LlmProfile::DeepSeekV32,
            14,
            5,
        ),
        CellSpec::new(
            Method::KernelBand(PolicyMode::Full, 2),
            Device::A100,
            LlmProfile::Gpt5,
            14,
            5,
        ),
    ]
}

/// Width traces of every (cell, task) trace, flattened in canonical
/// order — the replayable decision record of the AIMD controller.
fn width_traces(
    results: &[kernelband::eval::runner::CellResult],
) -> Vec<Vec<usize>> {
    results
        .iter()
        .flat_map(|cell| cell.traces.iter().map(Trace::width_trace))
        .collect()
}

#[test]
fn adaptive_width_trace_and_artifact_are_thread_invariant() {
    let suite = tiny_suite();
    let cells = auto_cells();
    let runs: Vec<_> = [1usize, 4, 8]
        .iter()
        .map(|&threads| {
            ExperimentRunner::new(threads)
                .with_batch_mode(AUTO)
                .run(&suite, &cells)
        })
        .collect();
    for other in &runs[1..] {
        assert_eq!(width_traces(&runs[0]), width_traces(other));
        assert_eq!(
            experiment_json("prop", 14, 5, &runs[0]).dump(),
            experiment_json("prop", 14, 5, other).dump()
        );
    }
    // the controller genuinely moves somewhere in the grid (a constant
    // width trace would make this suite vacuous)
    assert!(
        width_traces(&runs[0])
            .iter()
            .any(|ws| ws.iter().any(|&w| w > 1)),
        "adaptive mode never widened"
    );
}

#[test]
fn adaptive_width_trace_is_cold_warm_byte_identical() {
    let suite = tiny_suite();
    let cells = auto_cells();
    let store = Arc::new(TraceStore::in_memory());
    let runner = ExperimentRunner::new(2)
        .with_session(Some(store.clone()))
        .with_batch_mode(AUTO);
    let cold = runner.run(&suite, &cells);
    let sims_after_cold = store
        .stats
        .measure_sims
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(sims_after_cold > 0);
    let warm = runner.run(&suite, &cells);
    // warm replay: zero new simulated work even under adaptive widths
    // (the width sequence replays, so every slot key replays too)
    assert_eq!(
        store
            .stats
            .measure_sims
            .load(std::sync::atomic::Ordering::Relaxed),
        sims_after_cold
    );
    assert_eq!(width_traces(&cold), width_traces(&warm));
    assert_eq!(
        experiment_json("prop", 14, 5, &cold).dump(),
        experiment_json("prop", 14, 5, &warm).dump()
    );
    // and a storeless run matches the store-attached bytes
    let plain =
        ExperimentRunner::new(2).with_batch_mode(AUTO).run(&suite, &cells);
    assert_eq!(
        experiment_json("prop", 14, 5, &plain).dump(),
        experiment_json("prop", 14, 5, &cold).dump()
    );
}

#[test]
fn fixed_mode_is_bit_identical_to_the_static_batch_path() {
    let suite = tiny_suite();
    let cells = auto_cells();
    // Fixed(N) through the mode enum ≡ the pre-enum `--batch N` runner
    for n in [1usize, 3] {
        let legacy =
            ExperimentRunner::new(2).with_batch(n).run(&suite, &cells);
        let modal = ExperimentRunner::new(2)
            .with_batch_mode(BatchMode::Fixed(n))
            .run(&suite, &cells);
        assert_eq!(
            experiment_json("prop", 14, 5, &legacy).dump(),
            experiment_json("prop", 14, 5, &modal).dump()
        );
        for (a, b) in width_traces(&legacy)
            .into_iter()
            .zip(width_traces(&modal))
        {
            assert!(a.iter().all(|&w| w == n.max(1)));
            assert_eq!(a, b);
        }
    }
    // degenerate adaptive bounds collapse to Fixed bit-for-bit
    let fixed3 =
        ExperimentRunner::new(2).with_batch(3).run(&suite, &cells);
    let degen = ExperimentRunner::new(2)
        .with_batch_mode(BatchMode::Adaptive { min: 3, max: 3 })
        .run(&suite, &cells);
    assert_eq!(
        experiment_json("prop", 14, 5, &fixed3).dump(),
        experiment_json("prop", 14, 5, &degen).dump()
    );
}

#[test]
fn adaptive_widths_replay_the_aimd_rule_over_recorded_outcomes() {
    let suite = Suite::full(1);
    let engine = SimEngine::new(Device::H20);
    let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
    let mut cfg = PolicyConfig::default();
    cfg.iterations = 30;
    let trace = KernelBand::new(cfg).optimize_sched(
        &suite.tasks[4],
        &engine,
        &llm,
        &Rng::new(21),
        None,
        &SchedContext::with_mode(AUTO),
    );
    // the controller is re-exported for the serving API surface; both
    // paths name the same type
    let mut ctl = kernelband::sched::adaptive::AimdController::adaptive(1, 8);
    for r in &trace.records {
        assert_eq!(ctl.width(), r.batch_width, "t = {}", r.t);
        // wasted speculation = planned slots that never became a
        // measured candidate (bound-pruned or failed verification)
        let wasted = (r.batch_width - 1) - r.batch_accepted.len();
        assert!(r.batch_pruned <= wasted);
        ctl.observe(r.batch_width - 1, wasted);
    }
}

// ---------------------------------------------------------------------------
// UCB masked max-reduce ≡ branchy reference at frontier scale
// ---------------------------------------------------------------------------

#[test]
fn masked_reduce_matches_branchy_reference_on_1000_candidate_frontier() {
    // K grows with frontier size: ~1000 candidates / 6 strategies →
    // 170 clusters → 1020 arms, the regime the flattening targets
    let k = 170usize;
    let ucb = MaskedUcb::default();
    let mut rng = Rng::new(2026);
    for trial in 0..50 {
        let mut stats = ArmStats::new(k);
        for _ in 0..500 {
            let c = rng.below(k as u64) as usize;
            let s = Strategy::from_index(
                rng.below(NUM_STRATEGIES as u64) as usize,
            );
            stats.update(c, s, rng.uniform());
        }
        let mask: Vec<bool> = (0..k * NUM_STRATEGIES)
            .map(|_| rng.chance(0.8))
            .collect();
        let t = 1 + trial * 37;
        assert_eq!(
            ucb.select(&stats, t, &mask),
            ucb.select_masked_reduce(&stats, t, &mask),
            "trial {trial}"
        );
        // the all-open and all-closed extremes
        let open = vec![true; k * NUM_STRATEGIES];
        assert_eq!(
            ucb.select(&stats, t, &open),
            ucb.select_masked_reduce(&stats, t, &open)
        );
        let closed = vec![false; k * NUM_STRATEGIES];
        assert_eq!(ucb.select_masked_reduce(&stats, t, &closed), None);
    }
}
