//! Crash-recovery property tests for the sharded serving backend
//! ([`kernelband::server::Sharded`]): killed workers resume — never
//! restart — from the store's checkpoint journal, preemption parks and
//! resumes leases without RNG-stream drift, no fingerprint iteration is
//! ever executed twice, and the deterministic artifact plus the on-disk
//! trace log stay byte-identical to an uninterrupted run for every kill
//! point, preemption schedule and worker count.

use std::path::PathBuf;
use std::sync::Arc;

use kernelband::gpu_model::Device;
use kernelband::llm::LlmProfile;
use kernelband::sched::BatchMode;
use kernelband::server::{InProcess, ServeRequest, Sharded};
use kernelband::store::TraceStore;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("kb_recovery_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn small_request() -> ServeRequest {
    let mut req = ServeRequest::grid(
        2,
        2,
        8,
        BatchMode::Fixed(1),
        2,
        Device::H20,
        LlmProfile::DeepSeekV32,
        7,
    );
    req.workers = 2;
    req
}

/// Tentpole property (a): kill the worker after K iterations for every
/// interesting K; the recovered run's deterministic artifact AND the
/// persisted trace log must be byte-identical to an uninterrupted run.
#[test]
fn kill_at_every_boundary_recovers_to_identical_bytes() {
    let clean_dir = tmp_dir("kill_clean");
    let (clean_bytes, clean_trace) = {
        let store = Arc::new(TraceStore::open(&clean_dir).unwrap());
        let report = InProcess.run_report(&small_request(), &store);
        store.persist().unwrap();
        let trace = std::fs::read(store.trace_path().unwrap()).unwrap();
        (report.deterministic_json().dump(), trace)
    };
    assert!(!clean_trace.is_empty());

    for k in [0usize, 1, 3, 5, 7] {
        let dir = tmp_dir(&format!("kill_{k}"));
        let store = Arc::new(TraceStore::open(&dir).unwrap());
        let mut req = small_request();
        req.fault.kill_after = Some(k);
        let (report, sup) = Sharded.run_report(&req, &store);
        store.persist().unwrap();
        assert_eq!(
            report.deterministic_json().dump(),
            clean_bytes,
            "kill-after={k}: deterministic artifact drifted"
        );
        let trace = std::fs::read(store.trace_path().unwrap()).unwrap();
        assert_eq!(trace, clean_trace,
                   "kill-after={k}: trace log bytes drifted");
        // every execution was actually interrupted once and resumed
        assert!(sup.f64_field("revoked") > 0.0, "kill-after={k}");
        assert!(sup.f64_field("resumed") >= sup.f64_field("revoked"));
        assert_eq!(sup.f64_field("double_executed"), 0.0,
                   "kill-after={k}: an iteration ran twice");
        // completed runs retire their checkpoints
        assert!(store.ckpt_live().is_empty(), "kill-after={k}");
        let _ = std::fs::remove_dir_all(&dir);
    }
    let _ = std::fs::remove_dir_all(&clean_dir);
}

/// Tentpole property (b): lease expiry never double-executes a
/// fingerprint — the store ledger counts each simulated measurement and
/// LLM call exactly once, faulted or not.
#[test]
fn recovery_never_double_pays_simulations() {
    let s1 = Arc::new(TraceStore::in_memory());
    let clean = InProcess.run_report(&small_request(), &s1);
    assert!(clean.store_measure_sims > 0);
    assert!(clean.store_llm_sims > 0);

    let s2 = Arc::new(TraceStore::in_memory());
    let mut req = small_request();
    req.fault.kill_after = Some(3);
    let (faulted, sup) = Sharded.run_report(&req, &s2);
    // the kill → resume cycle replays banked iterations from the
    // checkpoint journal (zero engine/LLM calls) and executes only the
    // remainder live, so the totals match the uninterrupted run exactly
    assert_eq!(faulted.store_measure_sims, clean.store_measure_sims);
    assert_eq!(faulted.store_llm_sims, clean.store_llm_sims);
    assert!(sup.f64_field("resumed") > 0.0);
    assert_eq!(sup.f64_field("double_executed"), 0.0);
}

/// Tentpole property (c): preemption parks the lease at an iteration
/// boundary and resumes it with zero RNG-stream drift — the
/// deterministic artifact matches a preemption-free run byte-for-byte.
#[test]
fn preemption_parks_and_resumes_without_rng_drift() {
    let s1 = Arc::new(TraceStore::in_memory());
    let calm = InProcess.run_report(&small_request(), &s1);

    let s2 = Arc::new(TraceStore::in_memory());
    let mut req = small_request();
    req.fault.preempt_prob = 0.7;
    req.fault.seed = 5;
    let (stormy, sup) = Sharded.run_report(&req, &s2);
    assert_eq!(
        calm.deterministic_json().dump(),
        stormy.deterministic_json().dump()
    );
    assert!(sup.f64_field("parked") > 0.0, "ledger: {}", sup.dump());
    // every parked lease resumed (and only parked leases resume here)
    assert_eq!(sup.f64_field("parked"), sup.f64_field("resumed"));
    assert_eq!(sup.f64_field("double_executed"), 0.0);
    assert!(s2.ckpt_live().is_empty());
}

/// Tentpole property (d): mixed-tenant sharded runs are worker-count
/// invariant under faults, and an unfaulted sharded run matches the
/// in-process backend byte-for-byte.
#[test]
fn sharded_is_worker_invariant_and_matches_inprocess() {
    let run = |workers: usize| {
        let mut req = small_request();
        req.workers = workers;
        req.fault.kill_after = Some(2);
        req.fault.preempt_prob = 0.4;
        req.fault.seed = 9;
        let store = Arc::new(TraceStore::in_memory());
        let (report, sup) = Sharded.run_report(&req, &store);
        assert_eq!(sup.f64_field("double_executed"), 0.0);
        report.deterministic_json().dump()
    };
    let w1 = run(1);
    let w4 = run(4);
    assert_eq!(w1, w4, "worker count leaked into deterministic bytes");

    // and the faulted sharded bytes equal the plain in-process bytes
    let store = Arc::new(TraceStore::in_memory());
    let inproc = InProcess.run_report(&small_request(), &store);
    assert_eq!(inproc.deterministic_json().dump(), w1);
}
