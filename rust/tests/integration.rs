//! Cross-module integration tests: suite → engine → LLM → policy →
//! metrics, plus baselines and the service, wired the way the eval
//! harnesses wire them.

use kernelband::baselines::{BestOfN, Geak, TorchMode};
use kernelband::engine::{EvalEngine, SimEngine};
use kernelband::eval::{self, Method};
use kernelband::gpu_model::{Device, ALL_DEVICES};
use kernelband::llm::{LlmProfile, SurrogateLlm, ALL_LLMS};
use kernelband::metrics::aggregate;
use kernelband::policy::{KernelBand, PolicyConfig, PolicyMode};
use kernelband::rng::Rng;
use kernelband::service::OptimizationService;
use kernelband::workload::Suite;

fn small_suite() -> Suite {
    let full = Suite::full(eval::EXPERIMENT_SEED);
    Suite { tasks: full.tasks.into_iter().step_by(13).collect() }
}

#[test]
fn kernelband_beats_baselines_on_fallback_geomean() {
    let suite = small_suite();
    let seed = eval::EXPERIMENT_SEED;
    let kb = Method::KernelBand(PolicyMode::Full, 3)
        .run(&suite, Device::H20, LlmProfile::DeepSeekV32, 20, seed);
    let geak =
        Method::Geak.run(&suite, Device::H20, LlmProfile::DeepSeekV32, 20, seed);
    let bon =
        Method::BoN.run(&suite, Device::H20, LlmProfile::DeepSeekV32, 20, seed);
    let g = |traces: &[kernelband::policy::Trace]| {
        aggregate(&eval::outcomes(traces)).geomean_fallback
    };
    let (g_kb, g_geak, g_bon) = (g(&kb), g(&geak), g(&bon));
    assert!(g_kb > g_geak, "KB {g_kb} vs GEAK {g_geak}");
    assert!(g_geak >= g_bon * 0.95, "GEAK {g_geak} vs BoN {g_bon}");
}

#[test]
fn kernelband_correctness_dominates_bon() {
    let suite = small_suite();
    let seed = eval::EXPERIMENT_SEED;
    let kb = Method::KernelBand(PolicyMode::Full, 3)
        .run(&suite, Device::A100, LlmProfile::DeepSeekV32, 20, seed);
    let bon =
        Method::BoN.run(&suite, Device::A100, LlmProfile::DeepSeekV32, 20, seed);
    let c_kb = aggregate(&eval::outcomes(&kb)).correct_pct;
    let c_bon = aggregate(&eval::outcomes(&bon)).correct_pct;
    assert!(c_kb > c_bon, "KB {c_kb}% vs BoN {c_bon}%");
}

#[test]
fn results_are_reproducible_across_runs_and_parallelism() {
    let suite = small_suite();
    let m = Method::KernelBand(PolicyMode::Full, 3);
    let a = m.run(&suite, Device::H20, LlmProfile::Gpt5, 15, 99);
    let b = m.run(&suite, Device::H20, LlmProfile::Gpt5, 15, 99);
    for (ta, tb) in a.iter().zip(&b) {
        assert_eq!(ta.best_id, tb.best_id);
        assert_eq!(ta.candidates.len(), tb.candidates.len());
        assert_eq!(ta.best_speedup(), tb.best_speedup());
        assert_eq!(ta.total_cost_usd(), tb.total_cost_usd());
    }
}

#[test]
fn every_llm_backend_runs_end_to_end() {
    let suite = Suite {
        tasks: small_suite().tasks.into_iter().take(4).collect(),
    };
    for llm in ALL_LLMS {
        let traces = Method::KernelBand(PolicyMode::Full, 3)
            .run(&suite, Device::H20, llm, 10, 7);
        assert_eq!(traces.len(), 4);
        for tr in &traces {
            assert_eq!(tr.records.len(), 10);
        }
    }
}

#[test]
fn every_device_runs_end_to_end() {
    let suite = Suite {
        tasks: small_suite().tasks.into_iter().take(4).collect(),
    };
    for device in ALL_DEVICES {
        let traces = Method::KernelBand(PolicyMode::Full, 3)
            .run(&suite, device, LlmProfile::DeepSeekV32, 10, 7);
        assert!(traces.iter().all(|t| t.naive_latency_s > 0.0));
    }
}

#[test]
fn all_ablation_modes_complete() {
    let suite = Suite {
        tasks: small_suite().tasks.into_iter().take(3).collect(),
    };
    let engine = SimEngine::new(Device::H20);
    let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
    for mode in [
        PolicyMode::Full,
        PolicyMode::NoClustering,
        PolicyMode::NoProfiling,
        PolicyMode::LlmStrategySelection,
        PolicyMode::NoStrategyRawProfiling,
        PolicyMode::NoStrategySet,
    ] {
        for task in &suite.tasks {
            let mut cfg = PolicyConfig::with_mode(mode);
            cfg.iterations = 12;
            let tr = KernelBand::new(cfg).optimize(
                task,
                &engine,
                &llm,
                &Rng::new(5),
            );
            assert_eq!(tr.records.len(), 12, "{mode:?}");
            let _ = tr.outcome();
        }
    }
}

#[test]
fn scaling_curves_are_monotone() {
    let suite = Suite {
        tasks: small_suite().tasks.into_iter().take(6).collect(),
    };
    for m in [
        Method::KernelBand(PolicyMode::Full, 3),
        Method::Geak,
        Method::BoN,
    ] {
        let traces =
            m.run(&suite, Device::H20, LlmProfile::DeepSeekV32, 25, 11);
        let curve = eval::scaling_curve(&traces);
        assert_eq!(curve.len(), 25);
        for w in curve.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "{m:?} curve regressed");
        }
        assert!(curve[0] >= 1.0);
    }
}

#[test]
fn budgeted_speedup_is_monotone_in_budget() {
    let suite = Suite {
        tasks: small_suite().tasks.into_iter().take(5).collect(),
    };
    let traces = Method::KernelBand(PolicyMode::Full, 3).run(
        &suite,
        Device::H20,
        LlmProfile::DeepSeekV32,
        30,
        13,
    );
    for tr in &traces {
        let mut prev = 0.0;
        for b in [0.02, 0.05, 0.1, 0.2, 0.5, 1.0] {
            let s = eval::speedup_within_budget(tr, b);
            assert!(s >= prev);
            prev = s;
        }
    }
}

#[test]
fn torch_modes_and_kernelband_compose_for_table9() {
    let suite = Suite::full(eval::EXPERIMENT_SEED).subset50().torch_subset();
    let engine = SimEngine::new(Device::H20);
    let root = Rng::new(1);
    // the torch-comparable subset is non-trivial and all latencies finite
    assert!(suite.len() >= 20);
    for task in suite.tasks.iter().take(8) {
        for mode in [TorchMode::Eager, TorchMode::Inductor, TorchMode::MaxAutotune] {
            let t = mode.latency(task, &engine, &root);
            assert!(t.is_finite() && t > 0.0);
        }
    }
}

#[test]
fn geak_reflexion_retry_costs_more_than_bon_per_failure() {
    // GEAK's self-repair retries show up as extra spend on hard tasks
    let suite = Suite::full(eval::EXPERIMENT_SEED);
    let hard: Vec<_> = suite
        .tasks
        .iter()
        .filter(|t| t.difficulty.level() >= 4)
        .take(6)
        .cloned()
        .collect();
    let hard_suite = Suite { tasks: hard };
    let engine = SimEngine::new(Device::H20);
    let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
    let mut geak_cost = 0.0;
    let mut bon_cost = 0.0;
    for task in &hard_suite.tasks {
        let root = Rng::new(17);
        geak_cost += Geak::new(15)
            .optimize(task, &engine, &llm, &root)
            .total_cost_usd();
        bon_cost += BestOfN::new(15)
            .optimize(task, &engine, &llm, &root)
            .total_cost_usd();
    }
    assert!(geak_cost > bon_cost, "geak {geak_cost} vs bon {bon_cost}");
}

#[test]
fn service_report_is_consistent() {
    let report = OptimizationService::default().run(4, 2);
    assert_eq!(report.jobs.len(), 4);
    assert_eq!(report.gateway_requests, 8);
    assert!(report.wall_model_s > 0.0);
    assert!(report.batching_speedup() > 1.0);
    // per-job wall time can't exceed the whole run's wall time
    for j in &report.jobs {
        assert!(j.wall_model_s <= report.wall_model_s + 1.0);
    }
}

#[test]
fn fig3_and_regret_render() {
    let fig3 = eval::fig3();
    assert!(fig3.contains("LLM inference"));
    assert!(fig3.contains("batched"));
    let regret = eval::regret(400);
    assert!(regret.contains("avg regret"));
    // regret decreases between first and last checkpoint
    let rows: Vec<&str> = regret.lines().skip(3).collect();
    let first: f64 = rows.first().unwrap().split_whitespace().nth(1).unwrap()
        .parse().unwrap();
    let last: f64 = rows.last().unwrap().split_whitespace().nth(1).unwrap()
        .parse().unwrap();
    assert!(last < first, "regret did not decay: {first} -> {last}");
}

#[test]
fn engine_trait_object_usable() {
    // EvalEngine is the substitution point for real backends
    let engine: &dyn EvalEngine = &SimEngine::noiseless(Device::A100);
    let suite = small_suite();
    let m = engine.measure(
        &suite.tasks[0],
        &suite.tasks[0].naive_config(),
        &mut Rng::new(0),
    );
    assert!(m.total_latency_s > 0.0);
}
