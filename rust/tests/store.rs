//! Integration tests for the persistent trace store: cold-run byte
//! identity, warm-run work elision (the PR's acceptance criteria), and
//! cross-session trace accumulation.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use kernelband::eval::{self, RunOpts};
use kernelband::store::{log, TraceStore};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("kb_store_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn run_table3(iters: usize, threads: usize,
              session: Option<Arc<TraceStore>>) -> String {
    let opts = RunOpts { threads, session, ..RunOpts::default() };
    eval::report_opts("table3", Some(iters), &opts)
        .expect("table3 exists")
        .json
        .pretty()
}

/// Cold-run artifacts are byte-identical with and without a store, for
/// multiple thread counts — attaching the cache changes no observable
/// output, only the work performed.
#[test]
fn cold_run_with_store_is_byte_identical_to_storeless() {
    let baseline = run_table3(2, 2, None);
    let store = Arc::new(TraceStore::in_memory());
    let with_store = run_table3(2, 2, Some(store.clone()));
    assert_eq!(baseline, with_store);
    // and across thread counts while cached (mixed hit/miss patterns)
    let threads1 = run_table3(2, 1, Some(store.clone()));
    let threads8 = run_table3(2, 8, Some(store));
    assert_eq!(baseline, threads1);
    assert_eq!(baseline, threads8);
}

/// Acceptance criterion: a warm-started run over the same grid performs
/// strictly fewer simulated LLM calls and compile/exec steps than the
/// cold run — here, *zero* — with byte-identical artifacts.
#[test]
fn warm_run_elides_all_simulated_work() {
    let dir = tmp_dir("warm");

    // session 1: cold — populates the cache, persists to disk
    let cold_store = Arc::new(TraceStore::open(&dir).unwrap());
    let cold_json = run_table3(2, 2, Some(cold_store.clone()));
    cold_store.persist().unwrap();
    let cold_measure_sims =
        cold_store.stats.measure_sims.load(Ordering::Relaxed);
    let cold_llm_sims = cold_store.stats.llm_sims.load(Ordering::Relaxed);
    assert!(cold_measure_sims > 0);
    assert!(cold_llm_sims > 0);
    assert_eq!(cold_store.stats.measure_hits.load(Ordering::Relaxed), 0);
    assert_eq!(cold_store.stats.llm_hits.load(Ordering::Relaxed), 0);

    // session 2: a fresh process-equivalent reopens the store
    let warm_store = Arc::new(TraceStore::open(&dir).unwrap());
    assert_eq!(warm_store.loaded.kernels as u64, cold_measure_sims);
    assert_eq!(warm_store.loaded.proposals as u64, cold_llm_sims);
    let warm_json = run_table3(2, 2, Some(warm_store.clone()));

    // byte-identical artifact…
    assert_eq!(cold_json, warm_json);
    // …with strictly fewer (zero) simulated steps and full hit coverage
    let warm_measure_sims =
        warm_store.stats.measure_sims.load(Ordering::Relaxed);
    let warm_llm_sims = warm_store.stats.llm_sims.load(Ordering::Relaxed);
    assert!(warm_measure_sims < cold_measure_sims);
    assert!(warm_llm_sims < cold_llm_sims);
    assert_eq!(warm_measure_sims, 0);
    assert_eq!(warm_llm_sims, 0);
    assert_eq!(
        warm_store.stats.measure_hits.load(Ordering::Relaxed),
        cold_measure_sims
    );
    assert_eq!(
        warm_store.stats.llm_hits.load(Ordering::Relaxed),
        cold_llm_sims
    );
    // the bypassed LLM spend is accounted
    assert!(warm_store.stats.saved_cost_usd() > 0.0);
    assert!(warm_store.stats.saved_serial_llm_s() > 0.0);

    let _ = std::fs::remove_dir_all(&dir);
}

/// The runner's trace emission is thread-count-invariant and replayable
/// into warm-start state for every task of the grid.
#[test]
fn emitted_trace_log_is_deterministic_and_replayable() {
    let dir1 = tmp_dir("log1");
    let dir8 = tmp_dir("log8");
    for (dir, threads) in [(&dir1, 1usize), (&dir8, 8usize)] {
        let store = Arc::new(TraceStore::open(dir).unwrap());
        let _ = run_table3(2, threads, Some(store.clone()));
        store.persist().unwrap();
    }
    let text1 =
        std::fs::read_to_string(dir1.join("trace.jsonl")).unwrap();
    let text8 =
        std::fs::read_to_string(dir8.join("trace.jsonl")).unwrap();
    assert!(!text1.is_empty());
    assert_eq!(text1, text8, "trace log must not depend on --threads");

    let summary = log::replay_text(&text1);
    assert_eq!(summary.corrupt_lines, 0);
    assert_eq!(summary.tasks(), 50); // table3: the 50-kernel subset
    assert_eq!(summary.steps(), 50 * 2);
    let index =
        kernelband::store::warm::WarmIndex::from_records(&summary.records, 3);
    assert_eq!(index.len(), 50);

    let _ = std::fs::remove_dir_all(&dir1);
    let _ = std::fs::remove_dir_all(&dir8);
}

/// Warm-start priors flow end-to-end: a run with warm state attached
/// still completes with well-formed, deterministic results.
#[test]
fn warm_start_session_end_to_end() {
    let dir = tmp_dir("ws_e2e");
    {
        let store = Arc::new(TraceStore::open(&dir).unwrap());
        let _ = run_table3(3, 4, Some(store.clone()));
        store.persist().unwrap();
    }
    // new session: warm-start from the accumulated trace
    let mut store = TraceStore::open(&dir).unwrap();
    let trace_path = store.trace_path().unwrap();
    let summary = store.load_warm(&trace_path, 3).unwrap();
    assert!(summary.steps() > 0);
    assert_eq!(store.warm_index().unwrap().len(), 50);
    let store = Arc::new(store);
    // warm-started runs are deterministic (same priors, same caches)
    let a = run_table3(3, 2, Some(store.clone()));
    let b = run_table3(3, 2, Some(store.clone()));
    assert_eq!(a, b);
    let _ = std::fs::remove_dir_all(&dir);
}
