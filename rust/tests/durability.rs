//! Crash-consistency property suite for the persistent store.
//!
//! The central invariant: **killing a persist at any byte boundary
//! loses nothing and duplicates nothing.** After `trace fsck --repair`
//! and a warm recovery rerun, every convergent store file is
//! byte-identical to the file a never-crashed run produces. The sweep
//! below proves it exhaustively — one simulated crash per byte of the
//! session's write stream — via the deterministic disk-fault injector
//! (`--store-fault kill-at-byte=K`).
//!
//! `tenants.jsonl` is exempt from byte comparison (delta semantics: a
//! recovery rerun legitimately re-credits deltas), and
//! `checkpoints.jsonl` interleaves writers nondeterministically by
//! design, so the comparison set is `trace.jsonl` plus the four
//! content-addressed files.

use std::path::{Path, PathBuf};

use kernelband::kernel::{Counters, KernelConfig, Measurement};
use kernelband::llm::{GenOutcome, Proposal};
use kernelband::policy::resume::{Checkpoint, SlotCheckpoint};
use kernelband::profiler::HardwareSignature;
use kernelband::service::OptimizationService;
use kernelband::store::log::{StepRecord, TaskRecord, TraceRecord};
use kernelband::store::{
    fsck, Durability, StoreFaultPlan, TraceStore, STORE_FILES,
};

/// Store files whose bytes must converge after crash recovery.
const CONVERGENT: [&str; 5] = [
    "trace.jsonl",
    "kernels.jsonl",
    "proposals.jsonl",
    "profiles.jsonl",
    "service.jsonl",
];

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("kb_durability_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn meas(t: f64) -> Measurement {
    Measurement {
        total_latency_s: t,
        per_shape_s: vec![t, t * 2.0],
        counters: Counters { sm_pct: 42.5, ..Default::default() },
    }
}

fn prop(cost: f64) -> Proposal {
    Proposal {
        outcome: GenOutcome::Ok,
        config: KernelConfig::naive(),
        tokens_in: 120,
        tokens_out: 60,
        cost_usd: cost,
        latency_s: 2.5,
    }
}

fn sig(x: f64) -> HardwareSignature {
    HardwareSignature { sm_pct: x, dram_pct: 2.0 * x, l2_pct: 0.5 * x }
}

fn ckpt(t: usize) -> Checkpoint {
    Checkpoint {
        t,
        strategy: None,
        slots: vec![SlotCheckpoint { proposal: prop(0.05), measured: None }],
    }
}

fn trace_records(run: usize) -> Vec<TraceRecord> {
    let task = format!("matmul_{run}");
    vec![
        TraceRecord::Task(TaskRecord {
            cell: "KernelBand".into(),
            device: "H20".into(),
            llm: "DeepSeek-V3.2".into(),
            seed: 7 + run as u64,
            task_id: run,
            task: task.clone(),
            difficulty: 1,
            naive_latency_s: 0.5,
            tenant: None,
        }),
        TraceRecord::Step(StepRecord {
            cell: "KernelBand".into(),
            device: "H20".into(),
            llm: "DeepSeek-V3.2".into(),
            task,
            t: 1,
            cluster: 0,
            strategy: None,
            parent: 0,
            parent_hash: 0x10 + run as u64,
            child_hash: Some(0x20 + run as u64),
            call_ok: true,
            exec_ok: true,
            reward: 0.25,
            cost_usd: 0.01,
            runtime_s: Some(0.125),
            best_speedup: 1.5,
            counters: None,
            tenant: None,
        }),
    ]
}

/// Session 1 of the canonical two-session workload: touches all seven
/// store files. Idempotent by construction — re-running it against a
/// partially persisted store only re-marks what never reached disk.
fn session1(store: &TraceStore) {
    store.insert_measurement(1, &meas(0.125));
    store.insert_proposal(11, &prop(0.01));
    store.profiles().insert(21, sig(10.0));
    store.service_insert(31);
    store.tenant_add("t0", 1, 8, 1, 0);
    store.ckpt_append(0x51, &ckpt(1));
    store.append_trace(trace_records(0));
}

/// Session 2: more of everything, plus the checkpointed job completes.
fn session2(store: &TraceStore) {
    store.insert_measurement(2, &meas(0.25));
    store.insert_proposal(12, &prop(0.02));
    store.profiles().insert(22, sig(20.0));
    store.service_insert(32);
    store.tenant_add("t1", 2, 16, 0, 1);
    store.ckpt_retire(0x51);
    store.append_trace(trace_records(1));
}

fn snapshot(dir: &Path) -> Vec<(&'static str, Vec<u8>)> {
    CONVERGENT
        .iter()
        .map(|&f| (f, std::fs::read(dir.join(f)).unwrap_or_default()))
        .collect()
}

/// Build the never-crashed two-session reference store in `dir`.
fn build_reference(dir: &Path) {
    {
        let store = TraceStore::open(dir).unwrap();
        session1(&store);
        store.persist().unwrap();
    }
    {
        let store = TraceStore::open(dir).unwrap();
        session2(&store);
        store.persist().unwrap();
    }
}

fn store_bytes_written(dir: &Path) -> u64 {
    STORE_FILES
        .iter()
        .map(|f| {
            std::fs::metadata(dir.join(f)).map(|m| m.len()).unwrap_or(0)
        })
        .sum()
}

/// The tentpole property: kill session 1's persist at **every** byte of
/// its write stream; after `fsck --repair` and a warm recovery rerun,
/// the two-session store is byte-identical to the never-crashed
/// reference on every convergent file — nothing acknowledged is lost,
/// nothing is duplicated.
#[test]
fn kill_at_every_byte_sweep_converges_to_reference_bytes() {
    let ref_dir = tmp_dir("sweep_ref");
    build_reference(&ref_dir);
    let reference = snapshot(&ref_dir);

    // total bytes a clean session-1 persist writes (the sweep domain)
    let probe = tmp_dir("sweep_probe");
    {
        let store = TraceStore::open(&probe).unwrap();
        session1(&store);
        store.persist().unwrap();
    }
    let total = store_bytes_written(&probe);
    assert!(total > 0);
    let _ = std::fs::remove_dir_all(&probe);

    let dir = tmp_dir("sweep");
    for k in 0..=total {
        let _ = std::fs::remove_dir_all(&dir);
        // session 1 crashes at byte k of its persist
        {
            let store = TraceStore::open(&dir).unwrap();
            session1(&store);
            store.set_store_fault(StoreFaultPlan {
                kill_at_byte: Some(k),
                ..StoreFaultPlan::default()
            });
            let result = store.persist();
            assert_eq!(
                result.is_err(),
                k < total,
                "kill at byte {k} of {total}"
            );
        }
        // repair, then a fresh session re-runs the same work (warm:
        // whatever landed is deduplicated, whatever tore is redone)
        fsck::fsck(&dir, true).unwrap();
        {
            let store = TraceStore::open(&dir).unwrap();
            session1(&store);
            store.persist().unwrap();
        }
        {
            let store = TraceStore::open(&dir).unwrap();
            session2(&store);
            store.persist().unwrap();
        }
        let got = snapshot(&dir);
        for ((file, want), (_, have)) in reference.iter().zip(&got) {
            assert_eq!(
                want, have,
                "{file} diverged after kill at byte {k} of {total}"
            );
        }
        // and the recovered store carries no residual corruption
        let store = TraceStore::open(&dir).unwrap();
        assert_eq!(store.loaded.skipped, 0, "kill at byte {k}");
    }
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A torn tail in each of the seven files is (a) tolerated by `open`,
/// (b) quarantined **verbatim** by `fsck --repair`, and (c) gone for
/// good: the second fsck run is clean and a reopen skips nothing.
#[test]
fn torn_tail_in_every_file_is_tolerated_then_repaired() {
    let dir = tmp_dir("torn");
    build_reference(&dir);
    let garbage = "{\"v\":2,\"key\":\"dead";
    for file in STORE_FILES {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join(file))
            .unwrap();
        f.write_all(garbage.as_bytes()).unwrap();
    }

    // open() loads six files (trace replays separately) and skips
    // exactly the torn line in each
    let store = TraceStore::open(&dir).unwrap();
    assert_eq!(store.loaded.kernels, 2);
    assert_eq!(store.loaded.proposals, 2);
    assert_eq!(store.loaded.service, 2);
    assert_eq!(store.loaded.skipped, 6);
    assert_eq!(store.loaded.corrupt_files().len(), 6);
    drop(store);

    let report = fsck::fsck(&dir, true).unwrap();
    assert!(report.repair);
    for f in &report.files {
        assert_eq!(f.torn, 1, "{}", f.file);
        assert_eq!(f.quarantined, 1, "{}", f.file);
        assert!(f.rewritten, "{}", f.file);
    }
    // quarantined lines are byte-verbatim
    for file in STORE_FILES {
        let q = std::fs::read_to_string(
            dir.join(fsck::QUARANTINE_DIR).join(file),
        )
        .unwrap();
        assert_eq!(q, format!("{garbage}\n"), "{file}");
    }
    // idempotent: a second repair pass finds nothing and writes nothing
    let again = fsck::fsck(&dir, true).unwrap();
    assert!(again.clean(), "{:?}", again.summary_lines());
    let store = TraceStore::open(&dir).unwrap();
    assert_eq!(store.loaded.skipped, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Files written under `--durability off` (raw JSONL) stay readable
/// after the store upgrades to framed appends: mixed files load fully,
/// fsck keeps every parseable line, and nothing is ever re-encoded
/// behind the operator's back.
#[test]
fn mixed_framed_and_unframed_files_roundtrip() {
    let dir = tmp_dir("mixed");
    {
        let store = TraceStore::open(&dir).unwrap();
        store.set_durability(Durability::Off);
        session1(&store);
        store.persist().unwrap();
    }
    let raw = std::fs::read_to_string(dir.join("kernels.jsonl")).unwrap();
    assert!(raw.starts_with('{'), "off = legacy raw lines");
    {
        // default durability (relaxed) frames its appends
        let store = TraceStore::open(&dir).unwrap();
        assert_eq!(store.loaded.kernels, 1);
        session2(&store);
        store.persist().unwrap();
    }
    let mixed =
        std::fs::read_to_string(dir.join("kernels.jsonl")).unwrap();
    let mut lines = mixed.lines();
    assert!(lines.next().unwrap().starts_with('{'));
    assert!(lines.next().unwrap().starts_with("#f1:"));

    let store = TraceStore::open(&dir).unwrap();
    assert_eq!(store.loaded.kernels, 2);
    assert_eq!(store.loaded.proposals, 2);
    assert_eq!(store.loaded.service, 2);
    assert_eq!(store.loaded.skipped, 0);
    drop(store);

    // repair keeps both encodings verbatim in the content files
    fsck::fsck(&dir, true).unwrap();
    let after = std::fs::read_to_string(dir.join("kernels.jsonl")).unwrap();
    assert_eq!(after, mixed);
    assert!(fsck::fsck(&dir, true).unwrap().clean());
    let _ = std::fs::remove_dir_all(&dir);
}

/// ENOSPC mid-persist degrades the store instead of dropping deltas:
/// serving continues warm from memory, and once space returns the
/// requeued records land — nothing acknowledged is lost.
#[test]
fn enospc_degrades_then_recovers_without_losing_records() {
    let dir = tmp_dir("enospc");
    let store = TraceStore::open(&dir).unwrap();
    session1(&store);
    store.set_store_fault(StoreFaultPlan {
        enospc_after: Some(100),
        ..StoreFaultPlan::default()
    });
    assert!(store.persist().is_err());
    assert!(store.store_degraded());
    assert!(store.flush_errors() >= 1);
    assert!(store.requeued_records() >= 1);
    assert!(store.last_flush_error().unwrap().contains("enospc"));
    // warm continuation: every cache still serves from memory
    assert!(store.lookup_measurement(1).is_some());
    assert!(store.lookup_proposal(11).is_some());
    assert!(store.service_done(31));

    // space returns: repair the torn tail, flush the requeued deltas
    fsck::fsck(&dir, true).unwrap();
    store.set_store_fault(StoreFaultPlan::default());
    store.persist().unwrap();
    drop(store);

    fsck::fsck(&dir, true).unwrap();
    let reloaded = TraceStore::open(&dir).unwrap();
    assert_eq!(reloaded.loaded.kernels, 1);
    assert_eq!(reloaded.loaded.proposals, 1);
    assert_eq!(reloaded.loaded.profiles, 1);
    assert_eq!(reloaded.loaded.service, 1);
    assert_eq!(reloaded.loaded.tenants, 1);
    assert_eq!(reloaded.loaded.skipped, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Short-write faults are seeded: two identical runs under the same
/// plan fail (or not) identically and leave byte-identical files — the
/// injector never adds nondeterminism of its own.
#[test]
fn short_write_faults_are_deterministic() {
    let run = |tag: &str| -> (bool, Vec<(&'static str, Vec<u8>)>) {
        let dir = tmp_dir(tag);
        let store = TraceStore::open(&dir).unwrap();
        session1(&store);
        store.set_store_fault(StoreFaultPlan {
            short_write_prob: 0.5,
            seed: 9,
            ..StoreFaultPlan::default()
        });
        let failed = store.persist().is_err();
        drop(store);
        let snap = snapshot(&dir);
        let _ = std::fs::remove_dir_all(&dir);
        (failed, snap)
    };
    assert_eq!(run("short_a"), run("short_b"));
}

/// Serve-level strided kill sweep through the modeled service: the
/// gateway-bypass ledger proves zero duplicated LLM work after
/// recovery, and `service.jsonl` converges to the unfaulted bytes.
#[test]
fn serve_level_kill_sweep_recovers_with_zero_duplicate_work() {
    let svc = || OptimizationService {
        time_model: kernelband::service::TimeModel {
            llm_call_s: 4.0,
            calls_per_iter: 2.0,
            compile_s: 1.0,
            exec_s: 1.0,
            profile_amortized_s: 0.5,
            llm_batched_s: 2.0,
        },
        ..OptimizationService::default()
    };
    const JOBS: usize = 2;
    const ITERS: usize = 1;
    let work = (JOBS * ITERS) as u64;

    let ref_dir = tmp_dir("serve_ref");
    {
        let store = TraceStore::open(&ref_dir).unwrap();
        svc().run_with_store(JOBS, ITERS, Some(&store));
        store.persist().unwrap();
    }
    let reference = std::fs::read(ref_dir.join("service.jsonl")).unwrap();
    let total = reference.len() as u64;
    assert!(total > 0);

    let dir = tmp_dir("serve_sweep");
    let mut k = 0u64;
    loop {
        let _ = std::fs::remove_dir_all(&dir);
        {
            let store = TraceStore::open(&dir).unwrap();
            store.set_store_fault(StoreFaultPlan {
                kill_at_byte: Some(k),
                ..StoreFaultPlan::default()
            });
            svc().run_with_store(JOBS, ITERS, Some(&store));
            let _ = store.persist(); // killed mid-flush (or clean at k = total)
        }
        fsck::fsck(&dir, true).unwrap();
        {
            // recovery rerun: surviving keys bypass the gateway, torn
            // ones are redone — together they cover the workload once
            let store = TraceStore::open(&dir).unwrap();
            let rep = svc().run_with_store(JOBS, ITERS, Some(&store));
            store.persist().unwrap();
            assert_eq!(
                rep.gateway_requests + rep.gateway_bypassed,
                work,
                "kill at byte {k}"
            );
        }
        {
            // fully warm: zero fresh round-trips — no duplicated work
            let store = TraceStore::open(&dir).unwrap();
            let rep = svc().run_with_store(JOBS, ITERS, Some(&store));
            assert_eq!(rep.gateway_requests, 0, "kill at byte {k}");
            assert_eq!(rep.gateway_bypassed, work, "kill at byte {k}");
        }
        assert_eq!(
            std::fs::read(dir.join("service.jsonl")).unwrap(),
            reference,
            "service.jsonl diverged after kill at byte {k} of {total}"
        );
        if k >= total {
            break;
        }
        k = (k + 7).min(total);
    }
    let _ = std::fs::remove_dir_all(&ref_dir);
    let _ = std::fs::remove_dir_all(&dir);
}
