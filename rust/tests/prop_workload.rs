//! Property tests for grammar expansion itself.
//!
//! * same grammar + seed ⇒ byte-identical task list, and byte-identical
//!   `BENCH_*.json` artifacts across `--threads 1/4/8`;
//! * disjoint seeds ⇒ disjoint task fingerprints;
//! * expansion size matches the grammar's computed cardinality (no
//!   silent truncation).

use std::collections::HashSet;

use kernelband::eval::{self, RunOpts, WorkloadOverride};
use kernelband::workload::gen::{self, GrammarSpec, GRAMMARS};
use kernelband::workload::{Suite, TaskSpec};

/// A byte-exact serialization of a task list: every field that feeds a
/// measurement, with floats rendered as raw bits.
fn task_list_bytes(tasks: &[TaskSpec]) -> String {
    let mut out = String::new();
    for t in tasks {
        out.push_str(&format!(
            "{}|{}|{}|{}|{:016x}|{:016x}|{}\n",
            t.id,
            t.name,
            t.category.index(),
            t.difficulty.level(),
            t.fingerprint(),
            t.lineage,
            t.torch_comparable,
        ));
        for s in &t.shapes {
            out.push_str(&format!(
                "  {:016x} {:016x} {:016x}\n",
                s.flops.to_bits(),
                s.bytes.to_bits(),
                s.working_set.to_bits(),
            ));
        }
        let l = &t.latent;
        out.push_str(&format!(
            "  {} {} {} {:016x} {} {}",
            l.best_loop_order, l.best_layout, l.max_fusion,
            l.fusion_saving.to_bits(), l.best_vector, l.tile_bias,
        ));
        for s in l.sensitivity {
            out.push_str(&format!(" {:016x}", s.to_bits()));
        }
        out.push('\n');
    }
    out
}

#[test]
fn expansion_size_matches_computed_cardinality() {
    for g in GRAMMARS {
        for seed in [0, 7, 42] {
            let tasks = g.expand(seed);
            assert_eq!(
                tasks.len(),
                g.cardinality(),
                "{} seed {seed}: expansion truncated or inflated",
                g.name
            );
        }
    }
    // the registry's cardinalities are themselves pinned
    assert_eq!(gen::grammar("pow2sweep").unwrap().cardinality(), 324);
    assert_eq!(gen::grammar("raggedmix").unwrap().cardinality(), 84);
}

#[test]
fn same_grammar_and_seed_expand_byte_identically() {
    for g in GRAMMARS {
        let a = task_list_bytes(&g.expand(7));
        let b = task_list_bytes(&g.expand(7));
        assert_eq!(a, b, "{}", g.name);
        // and through the Suite::from_grammar wiring
        let spec = GrammarSpec::parse(&format!("grammar:{}", g.name))
            .expect("registry spec parses");
        let c = task_list_bytes(&Suite::from_grammar(&spec).unwrap().tasks);
        assert_eq!(a, c, "{} via Suite::from_grammar", g.name);
    }
}

#[test]
fn disjoint_seeds_expand_to_disjoint_fingerprints() {
    for g in GRAMMARS {
        let mut seen: HashSet<u64> = HashSet::new();
        for seed in [1, 2, 3] {
            for t in g.expand(seed) {
                assert!(
                    seen.insert(t.fingerprint()),
                    "{} seed {seed}: fingerprint collision on {}",
                    g.name, t.name
                );
            }
        }
    }
    // lineage drives the disjointness: same grammar, different seed
    let g = gen::grammar("raggedmix").unwrap();
    assert_ne!(g.lineage(1), g.lineage(2));
    // and distinct grammars never share a lineage at equal seed
    assert_ne!(
        gen::grammar("pow2sweep").unwrap().lineage(7),
        gen::grammar("raggedmix").unwrap().lineage(7)
    );
}

#[test]
fn generated_and_handbuilt_fingerprints_never_alias() {
    let legacy: HashSet<u64> = Suite::full(eval::EXPERIMENT_SEED)
        .tasks
        .iter()
        .map(|t| t.fingerprint())
        .collect();
    for g in GRAMMARS {
        for t in g.expand(7) {
            assert!(t.lineage != 0);
            assert!(
                !legacy.contains(&t.fingerprint()),
                "{} aliases a hand-built task",
                t.name
            );
        }
    }
}

#[test]
fn grammar_artifacts_are_thread_invariant() {
    let spec = GrammarSpec::parse("grammar:raggedmix").unwrap();
    let artifact = |threads: usize| -> String {
        let opts = RunOpts {
            threads,
            workload: Some(WorkloadOverride::from_spec(&spec).unwrap()),
            ..RunOpts::default()
        };
        let report = eval::report_opts("table3", Some(2), &opts)
            .expect("table3 exists");
        report.json.pretty()
    };
    let one = artifact(1);
    assert_eq!(one, artifact(4), "threads 1 vs 4");
    assert_eq!(one, artifact(8), "threads 1 vs 8");
    assert!(
        one.contains("\"workload\""),
        "grammar artifacts carry the workload tag"
    );
    assert!(one.contains("grammar:raggedmix:seed=7"));
}

#[test]
fn legacy_artifacts_have_no_workload_tag() {
    let report =
        eval::report_opts("table3", Some(2), &RunOpts::threads(2))
            .expect("table3 exists");
    assert!(
        !report.json.pretty().contains("\"workload\""),
        "no --workload must keep legacy artifact bytes"
    );
}
