//! Store hot-path benchmark: content-address hashing + cache lookup vs
//! the simulated compile+exec it replaces.
//!
//! The store's value proposition is that a warm run turns every
//! measurement into `hash + HashMap hit` and every LLM proposal into
//! `hash + clone`. This bench quantifies that hot path against the
//! simulated work it elides (roofline evaluation over the task's shape
//! list; surrogate-LLM proposal) and prints the resulting speedup.
//! Numbers are recorded in CHANGES.md.

use std::sync::Arc;

use kernelband::engine::{EvalEngine, SimEngine};
use kernelband::eval;
use kernelband::gpu_model::{Device, GpuSim};
use kernelband::llm::{LlmBackend, LlmProfile, PromptMode, ProposalRequest,
                      SurrogateLlm};
use kernelband::rng::Rng;
use kernelband::store::cache::measurement_key;
use kernelband::store::wrap::{CachedEngine, CachedLlm};
use kernelband::store::TraceStore;
use kernelband::strategy::Strategy;
use kernelband::util::bench::{perf_json, write_perf_artifact, BenchSuite,
                              PerfEntry};
use kernelband::util::json::Json;
use kernelband::workload::Suite;

fn main() {
    let bs = BenchSuite::new("store");
    let suite = Suite::full(eval::EXPERIMENT_SEED);
    let task = &suite.tasks[0];
    let cfg = task.naive_config();
    let sim = GpuSim::new(Device::H20);
    let device_fp = sim.fingerprint();
    let mut rng = Rng::new(0);

    // the work a cache hit elides: simulated compile+exec over shapes
    let engine = SimEngine::new(Device::H20);
    let sim_stats =
        bs.bench_throughput("simulated_compile_exec", 1.0, || {
            let m = engine.measure(task, &cfg, &mut rng);
            std::hint::black_box(m.total_latency_s);
        });

    // the replacement: key hash alone…
    let probe = Rng::new(1).split("m", 3);
    let hash_stats = bs.bench_throughput("measurement_key_hash", 1.0, || {
        std::hint::black_box(measurement_key(task, &cfg, device_fp, &probe));
    });

    // …and hash + lookup through the full CachedEngine path (hot)
    let store = Arc::new(TraceStore::in_memory());
    let cached = CachedEngine::new(SimEngine::new(Device::H20), store.clone());
    let _ = cached.measure(task, &cfg, &mut Rng::new(1).split("m", 3));
    let hit_stats =
        bs.bench_throughput("cached_engine_hit", 1.0, || {
            let m = cached.measure(task, &cfg, &mut Rng::new(1).split("m", 3));
            std::hint::black_box(m.total_latency_s);
        });

    // same comparison for the LLM side
    let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
    let parent = cfg;
    let req = ProposalRequest {
        task,
        parent: &parent,
        mode: PromptMode::Strategy(Strategy::Fusion),
        sim: &sim,
        iterative: true,
    };
    let llm_sim_stats = bs.bench_throughput("simulated_llm_propose", 1.0, || {
        std::hint::black_box(llm.propose(&req, &mut rng).cost_usd);
    });
    let cached_llm = CachedLlm::new(
        SurrogateLlm::new(LlmProfile::DeepSeekV32),
        store.clone(),
    );
    let _ = cached_llm.propose(&req, &mut Rng::new(2).split("gen", 5));
    let llm_hit_stats =
        bs.bench_throughput("cached_llm_hit", 1.0, || {
            let p = cached_llm.propose(&req, &mut Rng::new(2).split("gen", 5));
            std::hint::black_box(p.cost_usd);
        });

    let ratio = |slow: f64, fast: f64| slow / fast.max(1e-12);
    let hit_speedup = ratio(
        sim_stats.median.as_secs_f64(),
        hit_stats.median.as_secs_f64(),
    );
    let llm_speedup = ratio(
        llm_sim_stats.median.as_secs_f64(),
        llm_hit_stats.median.as_secs_f64(),
    );
    println!();
    println!(
        "speedup: compile+exec -> key hash          {:>10.1}x",
        ratio(
            sim_stats.median.as_secs_f64(),
            hash_stats.median.as_secs_f64()
        )
    );
    println!(
        "speedup: compile+exec -> cached-engine hit {hit_speedup:>10.1}x"
    );
    println!(
        "speedup: llm propose  -> cached-llm hit    {llm_speedup:>10.1}x"
    );

    let entries = vec![
        PerfEntry::with_items("simulated_compile_exec", sim_stats, 1.0),
        PerfEntry::with_items("measurement_key_hash", hash_stats, 1.0),
        PerfEntry::with_items("cached_engine_hit", hit_stats, 1.0),
        PerfEntry::with_items("simulated_llm_propose", llm_sim_stats, 1.0),
        PerfEntry::with_items("cached_llm_hit", llm_hit_stats, 1.0),
    ];
    let json = perf_json(
        "store",
        &entries,
        vec![
            ("cached_engine_hit_speedup", Json::num(hit_speedup)),
            ("cached_llm_hit_speedup", Json::num(llm_speedup)),
        ],
    );
    match write_perf_artifact("store", &json) {
        Ok(path) => println!("perf artifact: {}", path.display()),
        Err(e) => eprintln!("perf artifact not written: {e}"),
    }
}
