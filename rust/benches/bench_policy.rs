//! Policy hot-loop benchmark (§Perf): steady-state inner-loop
//! iterations/sec on a large synthetic frontier — the legacy
//! rebuild-everything-per-iteration path vs the incremental SoA path
//! shipped in `policy::frontier` — plus re-clustering cold vs
//! warm-seeded and the end-to-end `KernelBand::optimize` amortized cost.
//!
//! The legacy closure below is a faithful transcription of the per-
//! iteration work the pre-§Perf policy did: recount `cluster_size`,
//! re-allocate the `nonempty`/`mask` arm vectors, materialize the
//! selected cluster's member list, recompute every member's
//! `HardwareSignature::from_counters`, and softmax through two more
//! fresh allocations. The incremental closure runs the exact state the
//! policy now keeps. Both are checked to produce identical picks before
//! timing. Prints the speedup (target: ≥ 3×) and writes
//! `PERF_policy.json` for the CI perf-smoke artifact.

use kernelband::bandit::{softmax_kernel_pick, softmax_kernel_pick_in_place,
                         ArmStats, MaskedUcb};
use kernelband::cluster::{ClusterBackend, Clustering, RustKmeans};
use kernelband::engine::SimEngine;
use kernelband::eval;
use kernelband::features::{Phi, PHI_DIM};
use kernelband::gpu_model::Device;
use kernelband::kernel::{Counters, KernelConfig, Measurement};
use kernelband::llm::{LlmProfile, SurrogateLlm};
use kernelband::policy::frontier::{ClusterState, Frontier};
use kernelband::policy::{KernelBand, PolicyConfig};
use kernelband::profiler::{HardwareSignature, THETA_SAT};
use kernelband::rng::Rng;
use kernelband::sched::SchedContext;
use kernelband::strategy::{Strategy, ALL_STRATEGIES, NUM_STRATEGIES};
use kernelband::util::bench::{perf_json, write_perf_artifact, BenchSuite,
                              PerfEntry};
use kernelband::util::json::Json;
use kernelband::workload::Suite;

/// Candidates on the synthetic frontier (acceptance floor is ≥ 200; a
/// late-stage serve-path frontier is this large, and the legacy path's
/// O(frontier) rebuilds are what the incremental state removes).
const FRONTIER: usize = 1000;
/// Clusters (the paper's K = 3 default).
const K: usize = 3;
/// Iterations per timed sample.
const ITERS: usize = 200;
const PRUNE_FACTOR: f64 = 1.5;

struct Synth {
    phis: Vec<Phi>,
    counters: Vec<Counters>,
    latencies: Vec<f64>,
    clustering: Clustering,
    cluster_sigs: Vec<Option<HardwareSignature>>,
    frontier: Frontier,
    state: ClusterState,
    best_id: usize,
}

/// A synthetic steady-state frontier: latencies spread enough that
/// pruning bites, signatures spread across the saturation threshold so
/// masks and headrooms are non-trivial.
fn synth_frontier(n: usize) -> Synth {
    let mut rng = Rng::new(2026).split("synth", 0);
    let mut phis = Vec::with_capacity(n);
    let mut counters = Vec::with_capacity(n);
    let mut latencies = Vec::with_capacity(n);
    let mut frontier = Frontier::new();
    for i in 0..n {
        let mut p = [0.0; PHI_DIM];
        for v in p.iter_mut() {
            *v = rng.uniform();
        }
        let c = Counters {
            regs_per_thread: rng.uniform_in(30.0, 200.0),
            smem_per_block: rng.uniform_in(1024.0, 96.0 * 1024.0),
            block_dim: rng.uniform_in(64.0, 1024.0),
            occupancy: rng.uniform(),
            sm_pct: rng.uniform_in(5.0, 95.0),
            dram_pct: rng.uniform_in(5.0, 95.0),
            l2_pct: rng.uniform_in(5.0, 95.0),
        };
        // wide spread: most of a mature frontier is pruned-out slow
        // kernels (the paper's "filtering low-value candidates early")
        let t = rng.uniform_in(1.0e-3, 8.0e-3);
        let m = Measurement {
            total_latency_s: t,
            per_shape_s: vec![t],
            counters: c,
        };
        frontier.push(p, &m, i);
        phis.push(p);
        counters.push(c);
        latencies.push(t);
    }
    let clustering =
        RustKmeans::default().cluster(&phis, K, &mut Rng::new(7));
    let mut cluster_sigs: Vec<Option<HardwareSignature>> =
        vec![None; clustering.centroids.len()];
    for (ci, &rep) in clustering.representatives.iter().enumerate() {
        if rep != usize::MAX {
            cluster_sigs[ci] =
                Some(HardwareSignature::from_counters(&counters[rep]));
        }
    }
    let mut state = ClusterState::new(THETA_SAT);
    state.rebuild(&clustering, cluster_sigs.clone());
    let best_id = latencies
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.total_cmp(b))
        .map(|(i, _)| i)
        .unwrap();
    Synth {
        phis,
        counters,
        latencies,
        clustering,
        cluster_sigs,
        frontier,
        state,
        best_id,
    }
}

/// One pre-§Perf policy iteration: every piece of selection state
/// rebuilt from scratch (the old per-iteration body, verbatim shape).
fn legacy_iteration(s: &Synth, stats: &ArmStats, ucb: &MaskedUcb, t: usize,
                    rng: &mut Rng) -> usize {
    let k = s.clustering.centroids.len();
    let mut cluster_size = vec![0usize; k];
    for &a in &s.clustering.assign {
        cluster_size[a] += 1;
    }
    let nonempty: Vec<bool> = (0..k * NUM_STRATEGIES)
        .map(|i| cluster_size[i / NUM_STRATEGIES] > 0)
        .collect();
    let mut mask = nonempty.clone();
    for ci in 0..k {
        if let Some(sig) = s.cluster_sigs[ci] {
            for &st in &ALL_STRATEGIES {
                mask[ci * NUM_STRATEGIES + st.index()] &=
                    sig.strategy_valid(st, THETA_SAT);
            }
        }
    }
    let (cluster_id, strat) = ucb
        .select(stats, t, &mask)
        .or_else(|| ucb.select(stats, t, &nonempty))
        .expect("non-empty frontier");
    let mut members: Vec<usize> = s
        .clustering
        .assign
        .iter()
        .enumerate()
        .filter(|(_, &c)| c == cluster_id)
        .map(|(j, _)| j)
        .collect();
    let best_t = s.latencies[s.best_id];
    let promising: Vec<usize> = members
        .iter()
        .copied()
        .filter(|&m| s.latencies[m] <= PRUNE_FACTOR * best_t)
        .collect();
    if !promising.is_empty() {
        members = promising;
    }
    let headrooms: Vec<f64> = members
        .iter()
        .map(|&m| {
            HardwareSignature::from_counters(&s.counters[m])
                .headroom(strat, THETA_SAT)
        })
        .collect();
    members[softmax_kernel_pick(&headrooms, rng)]
}

/// One §Perf policy iteration: cached masks, incremental member lists,
/// memoized signatures, reusable scratch buffers.
fn incremental_iteration(s: &Synth, stats: &ArmStats, ucb: &MaskedUcb,
                         t: usize, pick_pool: &mut Vec<usize>,
                         pick_w: &mut Vec<f64>, rng: &mut Rng) -> usize {
    let (cluster_id, strat) = ucb
        .select_masked_reduce(stats, t, s.state.mask())
        .or_else(|| ucb.select_masked_reduce(stats, t, s.state.nonempty()))
        .expect("non-empty frontier");
    let members = s.state.members(cluster_id);
    let best_t = s.frontier.latencies[s.best_id];
    pick_pool.clear();
    pick_pool.extend(
        members
            .iter()
            .copied()
            .filter(|&m| s.frontier.latencies[m] <= PRUNE_FACTOR * best_t),
    );
    let pool: &[usize] = if pick_pool.is_empty() { members } else { pick_pool };
    pick_w.clear();
    pick_w.extend(
        pool.iter()
            .map(|&m| s.frontier.sigs[m].headroom(strat, THETA_SAT)),
    );
    pool[softmax_kernel_pick_in_place(pick_w, rng)]
}

fn main() {
    let bs = BenchSuite::new("policy");
    let mut entries: Vec<PerfEntry> = Vec::new();
    let synth = synth_frontier(FRONTIER);
    let ucb = MaskedUcb::default();
    let mut stats = ArmStats::new(synth.clustering.centroids.len());
    // non-uniform arms so selection is realistic
    let mut arm_rng = Rng::new(11);
    for _ in 0..64 {
        let c = arm_rng.below(K as u64) as usize;
        let st = Strategy::from_index(
            arm_rng.below(NUM_STRATEGIES as u64) as usize,
        );
        stats.update(c, st, arm_rng.uniform());
    }

    // equivalence gate: both paths must pick identical parents
    {
        let mut pool = Vec::new();
        let mut w = Vec::new();
        for t in 1..=ITERS {
            let mut ra = Rng::new(99).split("pick", t as u64);
            let mut rb = Rng::new(99).split("pick", t as u64);
            let a = legacy_iteration(&synth, &stats, &ucb, t, &mut ra);
            let b = incremental_iteration(
                &synth, &stats, &ucb, t, &mut pool, &mut w, &mut rb,
            );
            assert_eq!(a, b, "paths diverged at t={t}");
        }
        println!(
            "equivalence: legacy and incremental picks identical over {ITERS} \
             iterations on a {FRONTIER}-candidate frontier"
        );
    }

    // --- steady-state inner loop: legacy (per-iteration rebuild) ---
    let legacy = bs.bench_throughput(
        &format!("steady_state_legacy_n{FRONTIER}"),
        ITERS as f64,
        || {
            let mut rng = Rng::new(3);
            for t in 1..=ITERS {
                let p = legacy_iteration(&synth, &stats, &ucb, t, &mut rng);
                std::hint::black_box(p);
            }
        },
    );
    entries.push(PerfEntry::with_items(
        "steady_state_legacy",
        legacy,
        ITERS as f64,
    ));

    // --- steady-state inner loop: incremental SoA ---
    let mut pool = Vec::new();
    let mut w = Vec::new();
    let incremental = bs.bench_throughput(
        &format!("steady_state_incremental_n{FRONTIER}"),
        ITERS as f64,
        || {
            let mut rng = Rng::new(3);
            for t in 1..=ITERS {
                let p = incremental_iteration(
                    &synth, &stats, &ucb, t, &mut pool, &mut w, &mut rng,
                );
                std::hint::black_box(p);
            }
        },
    );
    entries.push(PerfEntry::with_items(
        "steady_state_incremental",
        incremental,
        ITERS as f64,
    ));

    // --- re-clustering: cold k-means++ vs warm-seeded + early exit ---
    let km = RustKmeans::default();
    let cold = bs.bench_throughput("recluster_cold_kmeanspp", 1.0, || {
        let c = km.cluster(&synth.phis, K, &mut Rng::new(7));
        std::hint::black_box(c.assign.len());
    });
    entries.push(PerfEntry::with_items("recluster_cold", cold, 1.0));
    let seeds = synth.clustering.centroids.clone();
    let warm = bs.bench_throughput("recluster_warm_seeded", 1.0, || {
        let c = km.cluster_seeded(&synth.phis, &seeds);
        std::hint::black_box(c.assign.len());
    });
    entries.push(PerfEntry::with_items("recluster_warm_seeded", warm, 1.0));

    // --- end-to-end policy run, amortized per iteration ---
    let suite = Suite::full(eval::EXPERIMENT_SEED);
    let task = &suite.tasks[0];
    let engine = SimEngine::new(Device::H20);
    let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
    let e2e = bs.bench_throughput("optimize_t40_amortized", 40.0, || {
        let mut cfg = PolicyConfig::default();
        cfg.iterations = 40;
        let tr = KernelBand::new(cfg).optimize(task, &engine, &llm,
                                               &Rng::new(3));
        std::hint::black_box(tr.best_id);
    });
    entries.push(PerfEntry::with_items("optimize_t40_amortized", e2e, 40.0));

    // --- batched measurement: serial per-candidate loop vs one fused
    // engine call over the same candidate set. Both timed bodies
    // process the identical BATCH candidates, so "iterations/sec" of
    // the fused path >= the serial path is exactly the batch>1 vs
    // batch=1 steady-state measurement claim.
    const BATCH: usize = 8;
    let mut bcfgs: Vec<KernelConfig> = Vec::new();
    {
        let mut c = task.naive_config();
        for i in 0..BATCH {
            c.tile_m = (1 + (i % 5)) as u8;
            c.vector = (i % 4) as u8;
            c.fusion = (i % 3) as u8;
            bcfgs.push(c.clamped());
        }
    }
    // equivalence gate: the fused path must be bit-identical before
    // its timings mean anything
    {
        let mut rngs: Vec<Rng> = (0..BATCH as u64)
            .map(|i| Rng::new(7).split("m", i))
            .collect();
        let fused = engine.sim.evaluate_batch(task, &bcfgs, &mut rngs);
        for (i, cfg) in bcfgs.iter().enumerate() {
            let solo = engine.sim.evaluate(
                task, cfg, &mut Rng::new(7).split("m", i as u64),
            );
            assert_eq!(
                fused[i].total_latency_s.to_bits(),
                solo.total_latency_s.to_bits(),
                "fused/serial divergence at candidate {i}"
            );
        }
        println!(
            "equivalence: fused evaluate_batch bit-identical to {BATCH} \
             serial evaluates"
        );
    }
    let serial_measure = bs.bench_throughput(
        &format!("steady_state_measure_serial_{BATCH}x1"),
        BATCH as f64,
        || {
            for (i, cfg) in bcfgs.iter().enumerate() {
                let m = engine.sim.evaluate(
                    task, cfg, &mut Rng::new(7).split("m", i as u64),
                );
                std::hint::black_box(m.total_latency_s);
            }
        },
    );
    entries.push(PerfEntry::with_items(
        "steady_state_measure_serial",
        serial_measure,
        BATCH as f64,
    ));
    let fused_measure = bs.bench_throughput(
        &format!("steady_state_measure_fused_1x{BATCH}"),
        BATCH as f64,
        || {
            let mut rngs: Vec<Rng> = (0..BATCH as u64)
                .map(|i| Rng::new(7).split("m", i))
                .collect();
            let out = engine.sim.evaluate_batch(task, &bcfgs, &mut rngs);
            std::hint::black_box(out.len());
        },
    );
    entries.push(PerfEntry::with_items(
        "steady_state_measure_fused",
        fused_measure,
        BATCH as f64,
    ));

    // --- end-to-end batched optimize (4 proposals/iteration) ---
    let e2e_b4 = bs.bench_throughput("optimize_t40_batch4_amortized", 40.0, || {
        let mut cfg = PolicyConfig::default();
        cfg.iterations = 40;
        let tr = KernelBand::new(cfg).optimize_sched(
            task,
            &engine,
            &llm,
            &Rng::new(3),
            None,
            &SchedContext::with_batch(4),
        );
        std::hint::black_box(tr.candidates.len());
    });
    entries.push(PerfEntry::with_items(
        "optimize_t40_batch4_amortized",
        e2e_b4,
        40.0,
    ));

    // --- telemetry overhead: the identical end-to-end run with an
    // enabled Recorder attached vs bare. PolicyHooks resolve handles
    // once per run and each hook is one relaxed atomic op, so the
    // instrumented loop must stay within 2% of bare (gated by CI
    // perf-smoke against perf/baselines/obs/ at --threshold 2).
    let obs_bare = bs.bench_throughput("optimize_t40_obs_bare", 40.0, || {
        let mut cfg = PolicyConfig::default();
        cfg.iterations = 40;
        let tr = KernelBand::new(cfg).optimize_sched(
            task,
            &engine,
            &llm,
            &Rng::new(3),
            None,
            &SchedContext::with_batch(4),
        );
        std::hint::black_box(tr.candidates.len());
    });
    entries.push(PerfEntry::with_items(
        "optimize_t40_obs_bare",
        obs_bare,
        40.0,
    ));
    let recorder = std::sync::Arc::new(kernelband::obs::Recorder::new());
    let mut obs_ctx = SchedContext::with_batch(4);
    obs_ctx.obs = Some(recorder.clone());
    let obs_instr = bs.bench_throughput(
        "optimize_t40_obs_instrumented",
        40.0,
        || {
            let mut cfg = PolicyConfig::default();
            cfg.iterations = 40;
            let tr = KernelBand::new(cfg).optimize_sched(
                task,
                &engine,
                &llm,
                &Rng::new(3),
                None,
                &obs_ctx,
            );
            std::hint::black_box(tr.candidates.len());
        },
    );
    entries.push(PerfEntry::with_items(
        "optimize_t40_obs_instrumented",
        obs_instr,
        40.0,
    ));
    assert!(
        recorder
            .counter_values()
            .iter()
            .any(|(k, v)| k == "policy.arm_pulls" && *v > 0),
        "instrumented run recorded nothing"
    );

    let ratio = |slow: f64, fast: f64| slow / fast.max(1e-12);
    let steady = ratio(
        legacy.median.as_secs_f64(),
        incremental.median.as_secs_f64(),
    );
    let recluster = ratio(cold.median.as_secs_f64(), warm.median.as_secs_f64());
    let batch_measure = ratio(
        serial_measure.median.as_secs_f64(),
        fused_measure.median.as_secs_f64(),
    );
    // bare/instrumented: 1.0 = free, 0.98 = the 2% overhead ceiling
    let obs_overhead = ratio(
        obs_bare.median.as_secs_f64(),
        obs_instr.median.as_secs_f64(),
    );
    println!();
    println!(
        "speedup: steady-state inner loop (n={FRONTIER})  {steady:>8.1}x  \
         (target >= 3x)"
    );
    println!("speedup: recluster cold -> warm-seeded        {recluster:>8.1}x");
    println!(
        "speedup: fused batched measurement (b={BATCH})    \
         {batch_measure:>8.2}x  (target >= 1x)"
    );
    println!(
        "overhead: telemetry on vs off (e2e)           \
         {obs_overhead:>8.3}x  (gate >= 0.98x)"
    );

    let json = perf_json(
        "policy",
        &entries,
        vec![
            ("frontier_candidates", Json::num(FRONTIER as f64)),
            ("steady_state_speedup", Json::num(steady)),
            ("recluster_speedup", Json::num(recluster)),
            ("batch_width", Json::num(BATCH as f64)),
            ("batch_measure_speedup", Json::num(batch_measure)),
            ("obs_overhead_ratio", Json::num(obs_overhead)),
        ],
    );
    match write_perf_artifact("policy", &json) {
        Ok(path) => println!("perf artifact: {}", path.display()),
        Err(e) => eprintln!("perf artifact not written: {e}"),
    }
}
