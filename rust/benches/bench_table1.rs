//! Regenerates Table 1 (main results) and times the end-to-end campaign.
//! Full scale: `kernelband repro table1`. Bench scale: reduced budget so
//! `cargo bench` completes quickly while printing the same rows.

use kernelband::eval;
use kernelband::util::bench::BenchSuite;

fn main() {
    let suite = BenchSuite::heavy("table1");
    let mut out = String::new();
    suite.bench("table1_t8_full_suite_3dev_3methods", || {
        out = eval::table1(8);
    });
    println!("{out}");
    suite.bench("table1_single_cell_kb_h20_t20", || {
        use eval::Method;
        use kernelband::policy::PolicyMode;
        let s = kernelband::workload::Suite::full(eval::EXPERIMENT_SEED).subset50();
        let traces = Method::KernelBand(PolicyMode::Full, 3).run(
            &s,
            kernelband::gpu_model::Device::H20,
            kernelband::llm::LlmProfile::DeepSeekV32,
            20,
            eval::EXPERIMENT_SEED,
        );
        assert_eq!(traces.len(), 50);
    });
}
