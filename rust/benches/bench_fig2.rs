//! Regenerates Figure 2 (scaling & clustering sensitivity, K sweep).

use kernelband::eval;
use kernelband::util::bench::BenchSuite;

fn main() {
    let suite = BenchSuite::heavy("fig2");
    let mut out = String::new();
    suite.bench("fig2_t16_k_sweep_plus_baselines", || {
        out = eval::fig2(16);
    });
    // print only every 4th iteration row at bench scale
    for (i, line) in out.lines().enumerate() {
        if i < 3 || (i - 3) % 4 == 0 {
            println!("{line}");
        }
    }
}
