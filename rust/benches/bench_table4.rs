//! Regenerates Table 4 (component + framework ablations) and includes
//! the DESIGN.md-called-out extra ablation: arm-statistics carry-over vs
//! full reset at re-clustering.

use kernelband::engine::SimEngine;
use kernelband::eval;
use kernelband::gpu_model::Device;
use kernelband::llm::{LlmProfile, SurrogateLlm};
use kernelband::policy::{KernelBand, PolicyConfig};
use kernelband::rng::Rng;
use kernelband::util::bench::BenchSuite;
use kernelband::workload::Suite;

fn main() {
    let bs = BenchSuite::heavy("table4");
    let mut out = String::new();
    bs.bench("table4_t12_all_ablations", || {
        out = eval::table4(12);
    });
    println!("{out}");

    // extra ablation promised in DESIGN.md: arm-statistics carry-over
    // (reseed from reward history, the default) vs full reset at each
    // re-clustering — run over the subset and report both geomeans.
    let suite = Suite::full(eval::EXPERIMENT_SEED).subset50();
    let engine = SimEngine::new(Device::H20);
    let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
    for (label, reset) in [("reseed_from_history", false), ("reset_arms", true)] {
        let mut log_sum = 0.0;
        bs.bench(&format!("ablation_recluster_{label}_t30"), || {
            log_sum = 0.0;
            for task in &suite.tasks {
                let mut cfg = PolicyConfig::default();
                cfg.iterations = 30;
                cfg.reset_arms_on_recluster = reset;
                let tr = KernelBand::new(cfg).optimize(
                    task, &engine, &llm, &Rng::new(task.id as u64),
                );
                log_sum += tr.outcome().fallback_speedup().ln();
            }
        });
        println!(
            "  recluster ablation [{label}]: fallback geomean {:.3}x",
            (log_sum / suite.len() as f64).exp()
        );
    }

    bs.bench("kernelband_t20_one_task_full_policy", || {
        let tr = KernelBand::new(PolicyConfig::default()).optimize(
            &suite.tasks[0],
            &engine,
            &llm,
            &Rng::new(1),
        );
        assert_eq!(tr.records.len(), 20);
    });
}
