//! Regenerates Table 3 (strategy risk/reward statistics, H20).

use kernelband::eval;
use kernelband::util::bench::BenchSuite;

fn main() {
    let suite = BenchSuite::heavy("table3");
    let mut out = String::new();
    suite.bench("table3_t20_h20_subset", || {
        out = eval::table3(20);
    });
    println!("{out}");
}
