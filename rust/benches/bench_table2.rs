//! Regenerates Table 2 (LLM generalization) at bench scale.

use kernelband::eval;
use kernelband::util::bench::BenchSuite;

fn main() {
    let suite = BenchSuite::heavy("table2");
    let mut out = String::new();
    suite.bench("table2_t10_4llms_3methods", || {
        out = eval::table2(10);
    });
    println!("{out}");
}
