//! Micro-benchmarks of the L3 hot paths (the §Perf working set):
//! masked-UCB selection, K-means re-clustering, φ featurization, the
//! roofline simulator, surrogate-LLM proposal, and one full policy
//! iteration amortized. Before/after numbers live in EXPERIMENTS.md §Perf.

use kernelband::bandit::{ArmStats, MaskedUcb};
use kernelband::cluster::{ClusterBackend, RustKmeans};
use kernelband::engine::SimEngine;
use kernelband::eval;
use kernelband::features::{phi, Phi};
use kernelband::gpu_model::{Device, GpuSim};
use kernelband::llm::{LlmBackend, LlmProfile, PromptMode, ProposalRequest,
                      SurrogateLlm};
use kernelband::policy::{KernelBand, PolicyConfig};
use kernelband::rng::Rng;
use kernelband::strategy::{Strategy, NUM_STRATEGIES};
use kernelband::util::bench::BenchSuite;
use kernelband::workload::Suite;

fn main() {
    let bs = BenchSuite::new("hotpath");
    let suite = Suite::full(eval::EXPERIMENT_SEED);
    let task = &suite.tasks[0];
    let sim = GpuSim::new(Device::H20);
    let mut rng = Rng::new(0);

    // roofline evaluation (dominates the inner loop of every experiment)
    bs.bench_throughput("gpu_sim_evaluate_12shapes", 1.0, || {
        let m = sim.evaluate(task, &task.naive_config(), &mut rng);
        std::hint::black_box(m.total_latency_s);
    });

    // masked UCB over K=3 x 6 arms
    let stats = ArmStats::new(3);
    let mask = vec![true; 3 * NUM_STRATEGIES];
    let ucb = MaskedUcb::default();
    bs.bench_throughput("masked_ucb_select_18_arms", 1.0, || {
        std::hint::black_box(ucb.select(&stats, 17, &mask));
    });

    // K-means over a 40-kernel frontier
    let points: Vec<Phi> = (0..40)
        .map(|i| {
            let mut p = [0.0; 5];
            let mut r = Rng::new(i);
            for v in p.iter_mut() {
                *v = r.uniform();
            }
            p
        })
        .collect();
    bs.bench_throughput("kmeans_40pts_k3_8iters", 1.0, || {
        let c = RustKmeans::default().cluster(&points, 3, &mut rng);
        std::hint::black_box(c.assign.len());
    });

    // featurization
    let meas = sim.evaluate(task, &task.naive_config(), &mut Rng::new(0));
    bs.bench_throughput("phi_featurize", 1.0, || {
        std::hint::black_box(phi(&meas, 1.0));
    });

    // surrogate-LLM proposal
    let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
    let parent = task.naive_config();
    let req = ProposalRequest {
        task,
        parent: &parent,
        mode: PromptMode::Strategy(Strategy::Fusion),
        sim: &sim,
        iterative: true,
    };
    bs.bench_throughput("llm_propose", 1.0, || {
        std::hint::black_box(llm.propose(&req, &mut rng).cost_usd);
    });

    // full policy run, amortized per iteration
    let engine = SimEngine::new(Device::H20);
    bs.bench_throughput("policy_iteration_amortized_t20", 20.0, || {
        let tr = KernelBand::new(PolicyConfig::default()).optimize(
            task, &engine, &llm, &Rng::new(3),
        );
        std::hint::black_box(tr.best_id);
    });

    // suite-scale throughput: tasks/second for the table-1 inner loop
    let sub = Suite::full(eval::EXPERIMENT_SEED).subset50();
    bs.bench_throughput("subset50_kernelband_t20", 50.0, || {
        let traces = eval::Method::KernelBand(
            kernelband::policy::PolicyMode::Full, 3)
            .run(&sub, Device::H20, LlmProfile::DeepSeekV32, 20,
                 eval::EXPERIMENT_SEED);
        std::hint::black_box(traces.len());
    });
}
