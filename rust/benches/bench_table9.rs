//! Regenerates Table 9 (PyTorch eager/inductor/max-autotune comparison).

use kernelband::eval;
use kernelband::util::bench::BenchSuite;

fn main() {
    let suite = BenchSuite::heavy("table9");
    let mut out = String::new();
    suite.bench("table9_t12_torch_subset", || {
        out = eval::table9(12);
    });
    println!("{out}");
}
