//! Regenerates Figure 4 (speedup vs API cost per kernel).

use kernelband::eval;
use kernelband::util::bench::BenchSuite;

fn main() {
    let suite = BenchSuite::heavy("fig4");
    let mut out = String::new();
    suite.bench("fig4_t20_budget_sweep", || {
        out = eval::fig4(20);
    });
    println!("{out}");
}
