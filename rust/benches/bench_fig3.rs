//! Regenerates Figure 3 (time breakdown) and benchmarks the live
//! batching gateway at several fleet sizes.

use kernelband::eval;
use kernelband::service::OptimizationService;
use kernelband::util::bench::BenchSuite;

fn main() {
    println!("{}", eval::fig3());
    let suite = BenchSuite::heavy("fig3");
    for jobs in [1usize, 8, 32] {
        suite.bench(&format!("service_{jobs}_jobs_x2_iters"), || {
            let report = OptimizationService::default().run(jobs, 2);
            assert_eq!(report.gateway_requests, jobs as u64 * 2);
        });
    }
}
