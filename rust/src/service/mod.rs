//! *Modeled* optimization service: concurrent per-kernel optimization
//! with a batched LLM gateway (paper §4.4.1, Figure 3).
//!
//! **This module is the `serve --modeled` path.** Latencies here are
//! synthesized through [`TIME_SCALE`] to measure the pipeline's
//! *shape* (batching efficiency, overlap, backpressure) in
//! milliseconds — useful as a fast smoke, but the ledger is a model.
//! The default `serve` path is [`crate::server`]: a multi-tenant job
//! queue driving **actual** `KernelBand::optimize_sched` runs whose
//! ledger reports measured wall-clock with no `TIME_SCALE` anywhere.
//!
//! The paper's wall-clock win comes from batching: serially, one
//! iteration costs ≈13.4 min, 87% of it LLM inference (the ~8 chained
//! plan/generate/repair calls); with batched LLM calls the iteration
//! collapses to ≈129 s and the bottleneck shifts to kernel compilation
//! (34%) and execution (30%). This module provides:
//!
//! * [`TimeModel`] — the calibrated per-component costs, from which the
//!   Fig.-3 serial and batched breakdowns are computed analytically;
//! * [`BatchedLlmGateway`] — a real OS-thread batching gateway: bounded
//!   ingress queue (backpressure: submitters block when it is full), a
//!   window/size-triggered batcher thread, and scaled-latency simulation
//!   (1 modeled second = [`TIME_SCALE`] of wall-clock), used by the
//!   service tests and the `serve` subcommand to demonstrate the same
//!   collapse end-to-end. Shutdown is drain-and-error: pending and
//!   newly-arriving requests complete with [`GatewayClosed`] instead of
//!   blocking forever, so no submitter ever hangs on a dying gateway;
//! * [`OptimizationService`] — drives N concurrent kernel-optimization
//!   jobs through the gateway, a shared re-clustering scheduler, and
//!   the batched measurement model.
//!
//! ## Shared scheduler & batched measurement
//!
//! Jobs no longer run fully independent loops: every τ iterations each
//! job submits its re-clustering — the one remaining super-O(members)
//! step — to one service-wide
//! [`crate::sched::scheduler::ReclusterScheduler`], which coalesces
//! concurrent requests into rounds, pays each distinct task
//! fingerprint once per round, and resumes warm (cached centroids)
//! for fingerprints seen before. [`ServiceReport`] carries the
//! scheduler's round/dedup/warm statistics. The measurement slice uses
//! [`TimeModel::fused_measure_s`]: a candidate batch measured through
//! one fused engine call costs the first candidate plus a marginal
//! slice per extra candidate, mirroring the policy-side
//! [`crate::engine::EvalEngine::measure_batch`] path
//! (`serve --batch N`).
//!
//! ## Cache-hit fast path
//!
//! With a persistent store attached
//! ([`OptimizationService::run_with_store`]), a job iteration whose
//! content key is already recorded as completed **skips the LLM gateway
//! round-trip entirely** — no enqueue, no batching window, no modeled
//! API latency; only the compile/execute/profile slice remains. A
//! repeated `serve --store DIR` run therefore reports
//! [`ServiceReport::gateway_bypassed`] > 0 and proportionally fewer
//! gateway requests, mirroring the repro path where proposal-cache hits
//! bypass the simulated LLM (see [`crate::store`]).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::rng::Rng;
use crate::sched::scheduler::{ReclusterScheduler, SchedulerConfig};
use crate::store::TraceStore;
use crate::util::hash::KeyHasher;

/// Wall-clock seconds per *modeled* second (the service simulates the
/// paper's minute-scale latencies in milliseconds: 1000× compression).
pub const TIME_SCALE: f64 = 1.0 / 1000.0;

/// Calibrated component costs (seconds), per kernel/iteration.
#[derive(Debug, Clone, Copy)]
pub struct TimeModel {
    /// One LLM API call (serial).
    pub llm_call_s: f64,
    /// Chained calls per iteration (plan → generate → repair loop).
    pub calls_per_iter: f64,
    /// Kernel compilation per iteration (all candidate builds).
    pub compile_s: f64,
    /// Benchmark execution per iteration (10+ shapes, do_bench style).
    pub exec_s: f64,
    /// NCU profiling, amortized per iteration (representatives only,
    /// every τ iterations).
    pub profile_amortized_s: f64,
    /// Wall-clock of one *batched* LLM round (the chained calls of one
    /// iteration submitted together; latency ≈ the longest call chain
    /// after parallelization, not the sum).
    pub llm_batched_s: f64,
}

impl Default for TimeModel {
    fn default() -> Self {
        // Calibrated against both panels of Fig. 3: serial total
        // 803.7 s = 13.4 min with LLM at 87.0%; batched total 129.0 s
        // with compilation at 34.0% and execution at 30.0%. The
        // profiling slice covers NCU runs on cluster representatives
        // plus the do_bench warmup discipline.
        TimeModel {
            llm_call_s: 87.4,
            calls_per_iter: 8.0,
            compile_s: 43.9,
            exec_s: 38.7,
            profile_amortized_s: 21.9,
            llm_batched_s: 24.5,
        }
    }
}

/// One slice of the Fig.-3 pie.
#[derive(Debug, Clone)]
pub struct BreakdownRow {
    pub component: &'static str,
    pub seconds: f64,
    pub percent: f64,
}

impl TimeModel {
    /// Serial cumulative time per iteration (Fig. 3a).
    pub fn serial_iteration_s(&self) -> f64 {
        self.llm_call_s * self.calls_per_iter
            + self.compile_s
            + self.exec_s
            + self.profile_amortized_s
    }

    /// Batched wall-clock per iteration (Fig. 3b).
    pub fn batched_iteration_s(&self) -> f64 {
        self.llm_batched_s + self.compile_s + self.exec_s
            + self.profile_amortized_s
    }

    fn rows(&self, llm: f64, total: f64) -> Vec<BreakdownRow> {
        let mk = |component, seconds: f64| BreakdownRow {
            component,
            seconds,
            percent: 100.0 * seconds / total,
        };
        vec![
            mk("LLM inference", llm),
            mk("Compilation", self.compile_s),
            mk("Execution", self.exec_s),
            mk("Profiling", self.profile_amortized_s),
        ]
    }

    pub fn serial_breakdown(&self) -> Vec<BreakdownRow> {
        self.rows(
            self.llm_call_s * self.calls_per_iter,
            self.serial_iteration_s(),
        )
    }

    pub fn batched_breakdown(&self) -> Vec<BreakdownRow> {
        self.rows(self.llm_batched_s, self.batched_iteration_s())
    }

    /// Marginal cost fraction of each extra candidate in a fused
    /// measurement batch: the batch shares one shape sweep and launch
    /// discipline, so candidates 2..N pay only the per-candidate slice
    /// of compile + execute.
    pub const BATCH_MARGINAL: f64 = 0.35;

    /// Compile + execute wall-clock for `batch` candidates measured
    /// through one fused engine call. `batch <= 1` is exactly the
    /// serial `compile_s + exec_s` slice, so the pre-batch service
    /// timing is unchanged at the default width.
    pub fn fused_measure_s(&self, batch: usize) -> f64 {
        let extra = batch.saturating_sub(1) as f64;
        (self.compile_s + self.exec_s)
            * (1.0 + Self::BATCH_MARGINAL * extra)
    }
}

/// Sleep for `model_seconds` of modeled time (shared with the
/// recluster scheduler so the scaling rule lives in one place).
pub(crate) fn scaled_sleep(model_seconds: f64) {
    std::thread::sleep(Duration::from_secs_f64(
        (model_seconds * TIME_SCALE).max(0.0),
    ));
}

/// Error returned to submitters when the gateway shuts down while their
/// request is queued (or arrives after shutdown began). The payload is
/// handed back so the caller can retry elsewhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GatewayClosed<T>(pub T);

impl<T> std::fmt::Display for GatewayClosed<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "LLM gateway shut down before the request completed")
    }
}

/// One queued request: a payload plus its completion slot.
struct Pending<T> {
    payload: T,
    done: Arc<(Mutex<Option<Result<T, GatewayClosed<T>>>>, Condvar)>,
}

/// Gateway configuration (modeled seconds).
#[derive(Debug, Clone, Copy)]
pub struct GatewayConfig {
    /// Max requests per batch.
    pub max_batch: usize,
    /// Batching window (modeled seconds): a partial batch is flushed
    /// after this long.
    pub window_s: f64,
    /// Modeled latency of one batched API round.
    pub call_latency_s: f64,
    /// Ingress queue bound — submitters block when it is full
    /// (backpressure).
    pub queue_depth: usize,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            max_batch: 64,
            window_s: 2.0,
            call_latency_s: 24.5,
            queue_depth: 256,
        }
    }
}

/// Bounded deterministic retry policy for transient gateway failures
/// ([`BatchedLlmGateway::call_retry`]).
///
/// The transient-failure draw is seeded per `(seed, key, attempt)`, so
/// a given request retries (or doesn't) identically across runs and is
/// invariant to thread interleaving. The default is **inert**
/// (`transient_fail_prob = 0.0`): `call_retry` then behaves exactly
/// like [`BatchedLlmGateway::call`] — one round-trip, no backoff, no
/// change to any deterministic artifact.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts per request, including the first (min 1).
    pub max_attempts: u32,
    /// Backoff before retry `n` costs `backoff_base_s * 2^(n-1)`
    /// modeled seconds ([`crate::llm::accounting::retry_backoff_s`]),
    /// charged through the same [`TIME_SCALE`] clock as API latency.
    pub backoff_base_s: f64,
    /// Probability a completed round-trip is treated as a transient
    /// failure (fault-injection knob; 0.0 disables retries entirely).
    pub transient_fail_prob: f64,
    /// Seed for the per-(key, attempt) failure draws.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            backoff_base_s: 1.0,
            transient_fail_prob: 0.0,
            seed: 0,
        }
    }
}

/// Gateway runtime statistics.
#[derive(Debug, Default)]
pub struct GatewayStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub max_batch_seen: AtomicU64,
    /// Transient-failure resubmissions made by `call_retry`.
    pub retries: AtomicU64,
}

struct GatewayShared<T> {
    queue: Mutex<VecDeque<Pending<T>>>,
    ingress: Condvar,
    shutdown: AtomicBool,
    config: GatewayConfig,
    stats: GatewayStats,
}

/// The batched LLM gateway (one batcher OS thread).
pub struct BatchedLlmGateway<T: Send + 'static> {
    shared: Arc<GatewayShared<T>>,
    batcher: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl<T: Send + 'static> BatchedLlmGateway<T> {
    pub fn spawn(config: GatewayConfig) -> Self {
        let shared = Arc::new(GatewayShared {
            queue: Mutex::new(VecDeque::new()),
            ingress: Condvar::new(),
            shutdown: AtomicBool::new(false),
            config,
            stats: GatewayStats::default(),
        });
        let s = shared.clone();
        let batcher = std::thread::spawn(move || Self::batcher_loop(&s));
        BatchedLlmGateway { shared, batcher: Mutex::new(Some(batcher)) }
    }

    /// Complete every queued request with [`GatewayClosed`] and wake
    /// blocked submitters. Runs under the queue lock so it serializes
    /// with `call`'s shutdown check: a request either lands in the
    /// queue before the drain (and is errored here) or observes
    /// `shutdown` and never enqueues.
    fn drain_and_error(s: &GatewayShared<T>) {
        let drained: Vec<Pending<T>> =
            s.queue.lock().unwrap().drain(..).collect();
        for p in drained {
            let (slot, cv) = &*p.done;
            *slot.lock().unwrap() = Some(Err(GatewayClosed(p.payload)));
            cv.notify_one();
        }
        s.ingress.notify_all();
    }

    fn batcher_loop(s: &GatewayShared<T>) {
        loop {
            // wait for the head of the next batch
            let mut q = s.queue.lock().unwrap();
            while q.is_empty() {
                if s.shutdown.load(Ordering::Acquire) {
                    drop(q);
                    // drain-and-error: anything racing in between the
                    // emptiness check and here is completed with an error
                    Self::drain_and_error(s);
                    return;
                }
                let (guard, _timeout) = s
                    .ingress
                    .wait_timeout(q, Duration::from_millis(5))
                    .unwrap();
                q = guard;
            }
            // window: wait (in scaled time) for the batch to fill;
            // shutdown mid-window drains instead of firing the batch
            drop(q);
            let window = Duration::from_secs_f64(s.config.window_s * TIME_SCALE);
            let deadline = Instant::now() + window;
            loop {
                if s.shutdown.load(Ordering::Acquire) {
                    Self::drain_and_error(s);
                    return;
                }
                let filled = s.queue.lock().unwrap().len() >= s.config.max_batch;
                if filled || Instant::now() >= deadline {
                    break;
                }
                std::thread::sleep(Duration::from_micros(200));
            }
            // take the batch
            let mut batch = Vec::new();
            {
                let mut q = s.queue.lock().unwrap();
                while batch.len() < s.config.max_batch {
                    match q.pop_front() {
                        Some(p) => batch.push(p),
                        None => break,
                    }
                }
            }
            s.ingress.notify_all(); // wake blocked submitters
            if batch.is_empty() {
                continue;
            }
            // one API round for the whole batch. An already-taken batch
            // completes normally even during shutdown (it is "in
            // flight"); the next loop iteration drains the rest.
            scaled_sleep(s.config.call_latency_s);
            s.stats.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
            s.stats.batches.fetch_add(1, Ordering::Relaxed);
            s.stats
                .max_batch_seen
                .fetch_max(batch.len() as u64, Ordering::Relaxed);
            for p in batch {
                let (slot, cv) = &*p.done;
                *slot.lock().unwrap() = Some(Ok(p.payload));
                cv.notify_one();
            }
        }
    }

    /// Submit a request and block until its (batched) completion.
    /// Blocks on a full ingress queue — the backpressure mechanism —
    /// but never blocks across shutdown: a request queued (or still
    /// waiting for queue space) when the gateway shuts down completes
    /// with [`GatewayClosed`] instead of hanging.
    pub fn call(&self, payload: T) -> Result<T, GatewayClosed<T>> {
        let done = Arc::new((Mutex::new(None), Condvar::new()));
        {
            let mut q = self.shared.queue.lock().unwrap();
            loop {
                // checked under the queue lock: serialized against the
                // batcher's final drain (see `drain_and_error`)
                if self.shared.shutdown.load(Ordering::Acquire) {
                    return Err(GatewayClosed(payload));
                }
                if q.len() < self.shared.config.queue_depth {
                    break;
                }
                q = self
                    .shared
                    .ingress
                    .wait_timeout(q, Duration::from_millis(5))
                    .unwrap()
                    .0;
            }
            q.push_back(Pending { payload, done: done.clone() });
        }
        self.shared.ingress.notify_all();
        let (slot, cv) = &*done;
        let mut guard = slot.lock().unwrap();
        while guard.is_none() {
            guard = cv.wait(guard).unwrap();
        }
        guard.take().unwrap()
    }

    /// [`BatchedLlmGateway::call`] with bounded deterministic retries.
    ///
    /// Each completed round-trip is re-judged against the policy's
    /// transient-failure draw, seeded by `(policy.seed, key, attempt)`;
    /// a failed draw charges the modeled exponential backoff
    /// ([`crate::llm::accounting::retry_backoff_s`]) and resubmits,
    /// up to `policy.max_attempts` total attempts (the last attempt's
    /// result is always accepted, so the loop is bounded).
    ///
    /// Shutdown semantics are untouched: a [`GatewayClosed`] error
    /// short-circuits immediately — a dying gateway is not a transient
    /// failure, and retrying against it would spin on the drain path.
    pub fn call_retry(&self, payload: T, key: u64, policy: &RetryPolicy)
                      -> Result<T, GatewayClosed<T>> {
        let attempts = policy.max_attempts.max(1);
        let mut p = payload;
        for attempt in 1..=attempts {
            p = self.call(p)?;
            let transient = attempt < attempts
                && policy.transient_fail_prob > 0.0
                && Rng::new(policy.seed)
                    .split("gw-retry", key)
                    .split("attempt", attempt as u64)
                    .chance(policy.transient_fail_prob);
            if !transient {
                return Ok(p);
            }
            self.shared.stats.retries.fetch_add(1, Ordering::Relaxed);
            scaled_sleep(crate::llm::accounting::retry_backoff_s(
                attempt,
                policy.backoff_base_s,
            ));
        }
        Ok(p)
    }

    /// Initiate shutdown and join the batcher. Idempotent; called by
    /// `Drop`. Queued and newly-arriving requests drain with
    /// [`GatewayClosed`] rather than blocking their submitters.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.ingress.notify_all();
        let handle = self.batcher.lock().unwrap().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
        // belt-and-braces for requests that slipped in after the
        // batcher's final drain but before its thread exited
        Self::drain_and_error(&self.shared);
    }

    pub fn requests(&self) -> u64 {
        self.shared.stats.requests.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.shared.stats.batches.load(Ordering::Relaxed)
    }

    pub fn max_batch_seen(&self) -> u64 {
        self.shared.stats.max_batch_seen.load(Ordering::Relaxed)
    }

    pub fn retries(&self) -> u64 {
        self.shared.stats.retries.load(Ordering::Relaxed)
    }
}

impl<T: Send + 'static> Drop for BatchedLlmGateway<T> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-job result of a service run.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub job_id: usize,
    pub iterations: usize,
    /// Modeled wall-clock the job spent end-to-end (seconds).
    pub wall_model_s: f64,
}

/// Outcome of a whole service run (times in modeled seconds).
#[derive(Debug, Clone)]
pub struct ServiceReport {
    pub jobs: Vec<JobReport>,
    pub wall_model_s: f64,
    pub gateway_requests: u64,
    pub gateway_batches: u64,
    pub gateway_max_batch: u64,
    /// Iterations whose LLM round-trip was skipped entirely because the
    /// store had already recorded their completion (cache-hit fast
    /// path; 0 without a store).
    pub gateway_bypassed: u64,
    /// Transient-failure resubmissions ([`RetryPolicy`]; 0 with the
    /// inert default policy).
    pub gateway_retries: u64,
    /// Re-clustering requests jobs submitted to the shared scheduler.
    pub sched_requests: u64,
    /// Scheduling rounds the requests coalesced into.
    pub sched_rounds: u64,
    /// Requests that resumed from warm (previously cached) centroids.
    pub sched_warm_hits: u64,
    /// Requests that shared a round-mate's identical re-clustering.
    pub sched_dedup_shares: u64,
    /// Modeled seconds the scheduler saved vs every request paying a
    /// solo cold re-clustering.
    pub sched_saved_model_s: f64,
    /// Serial-equivalent modeled time (sum over jobs × iterations of the
    /// serial iteration model, plus the serial compile+exec slice of
    /// every extra batched candidate).
    pub serial_equivalent_s: f64,
}

impl ServiceReport {
    pub fn batching_speedup(&self) -> f64 {
        self.serial_equivalent_s / self.wall_model_s.max(1e-9)
    }
}

/// Drives N concurrent optimization jobs through a batched gateway and
/// a shared re-clustering scheduler.
pub struct OptimizationService {
    pub time_model: TimeModel,
    pub gateway_config: GatewayConfig,
    pub sched_config: SchedulerConfig,
    /// Re-clustering period τ: each job submits to the shared
    /// scheduler when `it > 0 && it % recluster_every == 0`.
    pub recluster_every: usize,
    /// Distinct task fingerprints across the job population (models
    /// many users resubmitting the same hot kernels; jobs map onto
    /// fingerprints round-robin).
    pub task_variety: usize,
    /// Candidates measured per iteration through one fused engine call
    /// ([`TimeModel::fused_measure_s`]); 1 = the pre-batch service.
    pub batch: usize,
    /// Transient-failure retry policy for gateway round-trips (inert by
    /// default: `transient_fail_prob = 0.0`).
    pub retry: RetryPolicy,
}

impl Default for OptimizationService {
    fn default() -> Self {
        OptimizationService {
            time_model: TimeModel::default(),
            gateway_config: GatewayConfig::default(),
            sched_config: SchedulerConfig::default(),
            recluster_every: 2,
            task_variety: 4,
            batch: 1,
            retry: RetryPolicy::default(),
        }
    }
}

impl OptimizationService {
    /// Run `jobs` concurrent kernel optimizations of `iterations` each.
    /// Latencies are scaled by [`TIME_SCALE`], so the run measures the
    /// pipeline's *shape* — batching efficiency, overlap, backpressure —
    /// in milliseconds of real time.
    ///
    /// Job fan-out rides the same scoped-thread machinery as the
    /// experiment runner ([`crate::util::par`]): `spawn_map` gives every
    /// job a dedicated thread so all jobs block on the gateway at once,
    /// which is what keeps its batching window full.
    pub fn run(&self, jobs: usize, iterations: usize) -> ServiceReport {
        self.run_with_store(jobs, iterations, None)
    }

    /// [`OptimizationService::run`] with an optional persistent store.
    ///
    /// Each (job, iteration) has a deterministic content key; when the
    /// store already records it as completed, the iteration takes the
    /// cache-hit fast path — the LLM gateway round-trip is skipped
    /// entirely and only compile/execute/profile time is paid. Freshly
    /// completed keys are recorded so the *next* run over the same
    /// store bypasses them.
    pub fn run_with_store(&self, jobs: usize, iterations: usize,
                          store: Option<&TraceStore>) -> ServiceReport {
        let gateway: BatchedLlmGateway<usize> =
            BatchedLlmGateway::spawn(self.gateway_config);
        let scheduler = ReclusterScheduler::spawn(self.sched_config);
        let bypassed = AtomicU64::new(0);
        let tm = self.time_model;
        let retry = self.retry;
        let batch = self.batch.max(1);
        let variety = self.task_variety.max(1);
        let recluster_every = self.recluster_every.max(1);
        let t0 = Instant::now();
        let job_ids: Vec<usize> = (0..jobs).collect();
        let reports: Vec<JobReport> =
            crate::util::par::spawn_map(&job_ids, |_, &job_id| {
                let j0 = Instant::now();
                // the job's task fingerprint: jobs map onto the
                // service's hot-kernel population round-robin, so
                // matching fingerprints share scheduler work
                let task_fp = KeyHasher::new("serve-task")
                    .u64((job_id % variety) as u64)
                    .finish();
                for it in 0..iterations {
                    // every τ iterations: the super-O(members) step
                    // goes through the shared scheduler instead of
                    // running (and paying) per job. A shutdown error
                    // only means the service is tearing down.
                    if it > 0 && it % recluster_every == 0 {
                        let _ = scheduler.recluster(task_fp);
                    }
                    // keyed by the iteration's content identity alone —
                    // not the grid shape — so a re-run with different
                    // --jobs/--iterations still reuses overlapping work
                    let key = KeyHasher::new("serve")
                        .u64(job_id as u64)
                        .u64(it as u64)
                        .finish();
                    let hit =
                        store.map_or(false, |s| s.service_done(key));
                    if hit {
                        // cache-hit fast path: no enqueue, no window,
                        // no modeled API latency
                        bypassed.fetch_add(1, Ordering::Relaxed);
                    } else {
                        // the iteration's chained LLM calls, batched
                        // (with deterministic transient-failure
                        // retries); only a completed round-trip is
                        // recorded as done (a shutdown error must not
                        // poison the store with a bypass key for
                        // skipped work)
                        if gateway.call_retry(job_id, key, &retry).is_ok() {
                            if let Some(s) = store {
                                s.service_insert(key);
                            }
                        }
                    }
                    // fused batched measurement + amortized profiling
                    scaled_sleep(
                        tm.fused_measure_s(batch)
                            + tm.profile_amortized_s,
                    );
                }
                JobReport {
                    job_id,
                    iterations,
                    wall_model_s: j0.elapsed().as_secs_f64() / TIME_SCALE,
                }
            });
        let wall_model_s = t0.elapsed().as_secs_f64() / TIME_SCALE;
        ServiceReport {
            jobs: reports,
            wall_model_s,
            gateway_requests: gateway.requests(),
            gateway_batches: gateway.batches(),
            gateway_max_batch: gateway.max_batch_seen(),
            gateway_bypassed: bypassed.load(Ordering::Relaxed),
            gateway_retries: gateway.retries(),
            sched_requests: scheduler.requests(),
            sched_rounds: scheduler.rounds(),
            sched_warm_hits: scheduler.warm_hits(),
            sched_dedup_shares: scheduler.dedup_shares(),
            sched_saved_model_s: scheduler.saved_model_s(),
            serial_equivalent_s: jobs as f64
                * iterations as f64
                * (tm.serial_iteration_s()
                    + (batch as f64 - 1.0)
                        * (tm.compile_s + tm.exec_s)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_model_matches_paper_figure3() {
        let tm = TimeModel::default();
        // Fig. 3a: 13.4 min serial
        let serial_min = tm.serial_iteration_s() / 60.0;
        assert!((13.0..14.0).contains(&serial_min), "serial = {serial_min} min");
        // Fig. 3b: 129 s batched
        let batched = tm.batched_iteration_s();
        assert!((125.0..133.0).contains(&batched), "batched = {batched} s");
        // serial breakdown: LLM dominates at ~87%
        let llm_pct = tm.serial_breakdown()[0].percent;
        assert!((85.0..89.0).contains(&llm_pct), "llm = {llm_pct}%");
        // batched breakdown: compilation becomes the largest component
        let b = tm.batched_breakdown();
        let compile_pct = b[1].percent;
        let exec_pct = b[2].percent;
        assert!((32.0..36.0).contains(&compile_pct), "compile = {compile_pct}%");
        assert!((28.0..32.0).contains(&exec_pct), "exec = {exec_pct}%");
        assert!(b[1].seconds >= b[0].seconds);
    }

    #[test]
    fn breakdown_percentages_sum_to_100() {
        let tm = TimeModel::default();
        for rows in [tm.serial_breakdown(), tm.batched_breakdown()] {
            let sum: f64 = rows.iter().map(|r| r.percent).sum();
            assert!((sum - 100.0).abs() < 1e-9);
        }
    }

    #[test]
    fn breakdown_rows_carry_components_in_canonical_order() {
        // the Fig.-3 renderers index rows positionally, so the
        // component order is a contract, not a display detail
        let tm = TimeModel::default();
        let expected =
            ["LLM inference", "Compilation", "Execution", "Profiling"];
        for rows in [tm.serial_breakdown(), tm.batched_breakdown()] {
            assert_eq!(rows.len(), expected.len());
            for (row, name) in rows.iter().zip(expected) {
                assert_eq!(row.component, name);
                assert!(row.seconds > 0.0);
                assert!(row.percent > 0.0);
            }
            // non-LLM slices are shared between the two pipelines
            assert_eq!(rows[1].seconds, tm.compile_s);
            assert_eq!(rows[2].seconds, tm.exec_s);
            assert_eq!(rows[3].seconds, tm.profile_amortized_s);
        }
        // the LLM slice is the only one that differs
        assert_eq!(tm.serial_breakdown()[0].seconds,
                   tm.llm_call_s * tm.calls_per_iter);
        assert_eq!(tm.batched_breakdown()[0].seconds, tm.llm_batched_s);
        // each row's percent is consistent with its own total
        for (rows, total) in [
            (tm.serial_breakdown(), tm.serial_iteration_s()),
            (tm.batched_breakdown(), tm.batched_iteration_s()),
        ] {
            for row in rows {
                assert!((row.percent - 100.0 * row.seconds / total).abs()
                    < 1e-12);
            }
        }
    }

    #[test]
    fn fused_measure_is_serial_at_one_and_sublinear_after() {
        let tm = TimeModel::default();
        let serial = tm.compile_s + tm.exec_s;
        assert_eq!(tm.fused_measure_s(0), serial);
        assert_eq!(tm.fused_measure_s(1), serial);
        for b in 2..=8usize {
            let fused = tm.fused_measure_s(b);
            let prev = tm.fused_measure_s(b - 1);
            assert!(fused > prev, "monotone at {b}");
            assert!(fused < serial * b as f64, "sublinear at {b}");
        }
    }

    #[test]
    fn shared_scheduler_interleaves_and_dedups_reclusters() {
        let mut svc = OptimizationService::default();
        svc.recluster_every = 1; // recluster on every it > 0
        svc.task_variety = 2;
        let report = svc.run(6, 3);
        // it = 1, 2 for each of 6 jobs
        assert_eq!(report.sched_requests, 12);
        assert!(report.sched_rounds >= 1);
        // only the first-ever request per fingerprint pays cold: every
        // other request is a round-share or a warm resume
        assert!(
            report.sched_warm_hits + report.sched_dedup_shares >= 10,
            "warm = {} dedup = {}",
            report.sched_warm_hits,
            report.sched_dedup_shares
        );
        assert!(report.sched_saved_model_s > 0.0);
    }

    #[test]
    fn batched_service_amortizes_measurement() {
        let mut fast = OptimizationService::default();
        fast.batch = 4;
        let report = fast.run(2, 2);
        // 4 candidates per iteration: serial equivalent grows by the
        // extra candidates' compile+exec, wall only by the marginal
        // fused slice — so batching speedup improves over batch=1
        let solo = OptimizationService::default().run(2, 2);
        assert!(report.serial_equivalent_s > solo.serial_equivalent_s);
        assert_eq!(report.gateway_requests, solo.gateway_requests);
    }

    #[test]
    fn gateway_batches_concurrent_requests() {
        let gw: Arc<BatchedLlmGateway<usize>> =
            Arc::new(BatchedLlmGateway::spawn(GatewayConfig {
                max_batch: 32,
                window_s: 5.0,
                call_latency_s: 40.0,
                queue_depth: 64,
            }));
        let results: Vec<usize> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..16)
                .map(|i| {
                    let g = gw.clone();
                    scope.spawn(move || g.call(i).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(results, (0..16).collect::<Vec<_>>());
        assert_eq!(gw.requests(), 16);
        // 16 concurrent requests should coalesce into very few batches
        assert!(gw.batches() <= 4, "batches = {}", gw.batches());
        assert!(gw.max_batch_seen() >= 4);
    }

    #[test]
    fn service_batching_beats_serial() {
        let svc = OptimizationService::default();
        let report = svc.run(8, 3);
        assert_eq!(report.jobs.len(), 8);
        // with 8 concurrent jobs the run must land far below the
        // serial-equivalent time
        assert!(
            report.batching_speedup() > 4.0,
            "speedup = {}",
            report.batching_speedup()
        );
        assert_eq!(report.gateway_requests, 8 * 3);
    }

    #[test]
    fn single_job_wall_time_tracks_batched_model() {
        let svc = OptimizationService::default();
        let report = svc.run(1, 2);
        let per_iter = report.wall_model_s / 2.0;
        let expected = svc.time_model.batched_iteration_s();
        // one lone job still pays window + call latency per iteration;
        // generous bounds because scaled sleeps are milliseconds
        assert!(
            per_iter > 0.6 * expected && per_iter < 2.0 * expected,
            "per-iter {per_iter} vs model {expected}"
        );
    }

    #[test]
    fn repeated_store_run_bypasses_the_gateway() {
        let store = TraceStore::in_memory();
        let svc = OptimizationService::default();
        let cold = svc.run_with_store(4, 2, Some(&store));
        assert_eq!(cold.gateway_bypassed, 0);
        assert_eq!(cold.gateway_requests, 8);
        let warm = svc.run_with_store(4, 2, Some(&store));
        assert_eq!(warm.gateway_bypassed, 8);
        assert_eq!(warm.gateway_requests, 0);
        // a larger grid reuses the overlapping (job, iteration) work
        // and only pays the gateway for the new cells
        let grown = svc.run_with_store(4, 3, Some(&store));
        assert_eq!(grown.gateway_bypassed, 8);
        assert_eq!(grown.gateway_requests, 4);
        // a storeless run never bypasses
        let none = svc.run_with_store(2, 2, None);
        assert_eq!(none.gateway_bypassed, 0);
        assert_eq!(none.gateway_requests, 4);
    }

    #[test]
    fn shutdown_errors_queued_requests_instead_of_hanging() {
        let gw: Arc<BatchedLlmGateway<usize>> =
            Arc::new(BatchedLlmGateway::spawn(GatewayConfig {
                max_batch: 64,
                // enormous window + latency: nothing completes on its own
                window_s: 1e6,
                call_latency_s: 1e6,
                queue_depth: 64,
            }));
        let g2 = gw.clone();
        let submitter = std::thread::spawn(move || g2.call(1));
        // give the request time to enqueue, then pull the plug
        std::thread::sleep(Duration::from_millis(20));
        gw.shutdown();
        let out = submitter.join().unwrap();
        assert_eq!(out, Err(GatewayClosed(1)));
        // post-shutdown submissions fail fast
        assert_eq!(gw.call(2), Err(GatewayClosed(2)));
    }

    #[test]
    fn backpressure_bounds_queue() {
        // queue_depth 2 with 8 submitters: all complete, none lost
        let gw: Arc<BatchedLlmGateway<usize>> =
            Arc::new(BatchedLlmGateway::spawn(GatewayConfig {
                max_batch: 2,
                window_s: 1.0,
                call_latency_s: 5.0,
                queue_depth: 2,
            }));
        let results: Vec<usize> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let g = gw.clone();
                    scope.spawn(move || g.call(i).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(results.len(), 8);
        assert_eq!(gw.requests(), 8);
        assert!(gw.batches() >= 4); // max_batch=2 forces ≥4 rounds
    }
}
