//! Masked-UCB bandit over (cluster, strategy) arms (paper §3.4, Eq. 6).
//!
//! Arms are the cross product of the current K clusters with the 6
//! optimization strategies. Selection maximizes the UCB index
//! `μ̂ + c·sqrt(ln t / N)` over arms whose hardware mask is 1; the mask
//! prunes strategies whose target resource the cluster representative
//! has already saturated (Eq. 5). Ties break on the lowest arm index so
//! selection is deterministic.

use crate::rng::Rng;
use crate::strategy::{Strategy, NUM_STRATEGIES};

/// Per-arm visit counts and empirical means, row-major `[cluster][strategy]`.
#[derive(Debug, Clone)]
pub struct ArmStats {
    k: usize,
    /// Visit counts (Algorithm 1 initializes N = 1).
    pub n: Vec<f64>,
    /// Empirical mean rewards (initialized to the optimistic prior 0.5).
    pub mu: Vec<f64>,
}

/// Algorithm 1's optimistic initialization.
pub const PRIOR_N: f64 = 1.0;
pub const PRIOR_MU: f64 = 0.5;

impl ArmStats {
    pub fn new(k: usize) -> ArmStats {
        ArmStats {
            k,
            n: vec![PRIOR_N; k * NUM_STRATEGIES],
            mu: vec![PRIOR_MU; k * NUM_STRATEGIES],
        }
    }

    pub fn clusters(&self) -> usize {
        self.k
    }

    #[inline]
    fn idx(&self, cluster: usize, strategy: Strategy) -> usize {
        cluster * NUM_STRATEGIES + strategy.index()
    }

    pub fn mean(&self, cluster: usize, strategy: Strategy) -> f64 {
        self.mu[self.idx(cluster, strategy)]
    }

    pub fn visits(&self, cluster: usize, strategy: Strategy) -> f64 {
        self.n[self.idx(cluster, strategy)]
    }

    /// Incremental-mean update (Algorithm 1 lines 22–23):
    /// `N += 1; μ̂ += (r − μ̂)/N`.
    pub fn update(&mut self, cluster: usize, strategy: Strategy, reward: f64) {
        let i = self.idx(cluster, strategy);
        self.n[i] += 1.0;
        self.mu[i] += (reward - self.mu[i]) / self.n[i];
    }

    /// Rebuild arm statistics after re-clustering.
    ///
    /// The paper is silent on what happens to (cluster, strategy)
    /// statistics when clusters move; we re-seed each new arm from the
    /// reward history of the kernels now assigned to it (records carry
    /// the per-kernel rewards each strategy produced), on top of the
    /// optimistic prior. DESIGN.md documents this choice and
    /// `benches/bench_hotpath.rs` has an ablation comparing it with a
    /// full reset.
    pub fn reseed(k: usize, history: &[RewardRecord], assign: &[usize])
                  -> ArmStats {
        let mut stats = ArmStats::new(k);
        for rec in history {
            // the record's kernel may have left the frontier window
            if let Some(&cluster) = assign.get(rec.kernel) {
                if cluster < k {
                    stats.update(cluster, rec.strategy, rec.reward);
                }
            }
        }
        stats
    }
}

/// One historical pull: strategy applied to frontier kernel `kernel`
/// yielding `reward`. Kept by the policy to survive re-clustering.
#[derive(Debug, Clone, Copy)]
pub struct RewardRecord {
    pub kernel: usize,
    pub strategy: Strategy,
    pub reward: f64,
}

/// The masked-UCB selector.
#[derive(Debug, Clone)]
pub struct MaskedUcb {
    /// Exploration constant (paper §3.6: c = 2.0).
    pub c: f64,
}

impl Default for MaskedUcb {
    fn default() -> Self {
        MaskedUcb { c: 2.0 }
    }
}

impl MaskedUcb {
    /// UCB index of a single arm at time `t`.
    #[inline]
    pub fn index(&self, mu: f64, n: f64, t: f64) -> f64 {
        mu + self.c * (t.max(1.0).ln() / n.max(1.0)).sqrt()
    }

    /// Select the argmax over valid arms (Eq. 6). `mask[cluster][strategy]`
    /// flattened row-major; returns `None` when every arm is masked
    /// (callers then unmask, per the all-saturated fallback).
    pub fn select(&self, stats: &ArmStats, t: usize, mask: &[bool])
                  -> Option<(usize, Strategy)> {
        debug_assert_eq!(mask.len(), stats.n.len());
        let tf = t as f64;
        let mut best: Option<(usize, f64)> = None;
        for (i, &valid) in mask.iter().enumerate() {
            if !valid {
                continue;
            }
            let score = self.index(stats.mu[i], stats.n[i], tf);
            match best {
                Some((_, b)) if score <= b => {}
                _ => best = Some((i, score)),
            }
        }
        best.map(|(i, _)| (i / NUM_STRATEGIES, Strategy::from_index(i % NUM_STRATEGIES)))
    }

    /// Select with an all-true mask.
    pub fn select_unmasked(&self, stats: &ArmStats, t: usize)
                           -> (usize, Strategy) {
        let mask = vec![true; stats.n.len()];
        self.select(stats, t, &mask).expect("non-empty arms")
    }

    /// Flattened masked max-reduce form of [`MaskedUcb::select`] — the
    /// hot-path selector.
    ///
    /// The branchy reference skips masked arms and recomputes `ln t`
    /// per arm; this form hoists `ln t` once, computes every arm's
    /// index unconditionally (a tight scan over the `mu`/`n` parallel
    /// arrays the optimizer can keep in registers/SIMD lanes), and
    /// folds the mask in as a `-∞` sentinel before a single
    /// first-max reduce. Selection is **bit-identical** to `select`:
    /// the per-arm arithmetic is the same expression (hoisting `ln t`
    /// reuses the identical value), a real arm's index is always
    /// finite (μ̂ ∈ [0, 1], bonus ≥ 0) so the sentinel can never tie a
    /// valid arm, and `>` keeps the first maximum exactly like the
    /// reference's `score <= best` skip. Equivalence is pinned by a
    /// property test on 1000-arm frontiers in
    /// `rust/tests/prop_sched.rs`.
    pub fn select_masked_reduce(&self, stats: &ArmStats, t: usize,
                                mask: &[bool])
                                -> Option<(usize, Strategy)> {
        debug_assert_eq!(mask.len(), stats.n.len());
        let lnt = (t as f64).max(1.0).ln();
        let mut best_i = usize::MAX;
        let mut best = f64::NEG_INFINITY;
        for i in 0..mask.len() {
            let score = stats.mu[i]
                + self.c * (lnt / stats.n[i].max(1.0)).sqrt();
            let score = if mask[i] { score } else { f64::NEG_INFINITY };
            if score > best {
                best = score;
                best_i = i;
            }
        }
        if best_i == usize::MAX {
            return None;
        }
        Some((
            best_i / NUM_STRATEGIES,
            Strategy::from_index(best_i % NUM_STRATEGIES),
        ))
    }
}

/// Headroom-to-score temperature divisor: 20 points of headroom
/// difference is decisive but not degenerate. Shared by
/// [`softmax_kernel_pick`] and [`softmax_kernel_pick_in_place`] so the
/// allocating and scratch-buffer paths stay draw-for-draw identical.
pub const SOFTMAX_HEADROOM_SCALE: f64 = 15.0;

/// Within-cluster kernel pick (paper §3.4): softmax over the remaining
/// hardware headroom `V_hw(k, s) = θ_sat − h(k)[Target(s)]`.
///
/// `headrooms` are the V_hw scores of the cluster members; returns the
/// position of the sampled member.
pub fn softmax_kernel_pick(headrooms: &[f64], rng: &mut Rng) -> usize {
    debug_assert!(!headrooms.is_empty());
    // scores are in percent; scale to temperature
    let scaled: Vec<f64> =
        headrooms.iter().map(|h| h / SOFTMAX_HEADROOM_SCALE).collect();
    rng.softmax(&scaled)
}

/// Allocation-free [`softmax_kernel_pick`] for the policy's reusable
/// scratch buffer: scales `headrooms` into softmax weights in place and
/// draws. Identical weights, identical RNG consumption.
pub fn softmax_kernel_pick_in_place(headrooms: &mut [f64], rng: &mut Rng)
                                    -> usize {
    debug_assert!(!headrooms.is_empty());
    for h in headrooms.iter_mut() {
        *h /= SOFTMAX_HEADROOM_SCALE;
    }
    rng.softmax_in_place(headrooms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy::ALL_STRATEGIES;

    #[test]
    fn prior_initialization() {
        let s = ArmStats::new(3);
        for c in 0..3 {
            for &st in &ALL_STRATEGIES {
                assert_eq!(s.visits(c, st), PRIOR_N);
                assert_eq!(s.mean(c, st), PRIOR_MU);
            }
        }
    }

    #[test]
    fn update_is_incremental_mean() {
        let mut s = ArmStats::new(1);
        let st = Strategy::Fusion;
        s.update(0, st, 1.0);
        // prior (n=1, mu=0.5) + one observation of 1.0 → mean 0.75, n=2
        assert_eq!(s.visits(0, st), 2.0);
        assert!((s.mean(0, st) - 0.75).abs() < 1e-12);
        s.update(0, st, 0.0);
        assert!((s.mean(0, st) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ucb_explores_unvisited_then_exploits() {
        let mut s = ArmStats::new(1);
        let ucb = MaskedUcb::default();
        // hammer Tiling with rewards; others stay at the prior
        for _ in 0..50 {
            s.update(0, Strategy::Tiling, 1.0);
        }
        // at large t the exploration bonus of unvisited arms dominates…
        let (_, pick) = ucb.select_unmasked(&s, 1000);
        assert_ne!(pick, Strategy::Tiling, "bonus should force exploration");
        // …but if all arms are equally visited, the best mean wins
        let mut s2 = ArmStats::new(1);
        for &st in &ALL_STRATEGIES {
            for _ in 0..20 {
                s2.update(0, st, if st == Strategy::Fusion { 0.9 } else { 0.1 });
            }
        }
        let (_, pick2) = ucb.select_unmasked(&s2, 200);
        assert_eq!(pick2, Strategy::Fusion);
    }

    #[test]
    fn masked_arms_are_never_selected() {
        let s = ArmStats::new(2);
        let ucb = MaskedUcb::default();
        let mut mask = vec![false; 2 * NUM_STRATEGIES];
        mask[NUM_STRATEGIES + Strategy::Pipeline.index()] = true;
        let got = ucb.select(&s, 5, &mask);
        assert_eq!(got, Some((1, Strategy::Pipeline)));
    }

    #[test]
    fn all_masked_returns_none() {
        let s = ArmStats::new(2);
        let ucb = MaskedUcb::default();
        let mask = vec![false; 2 * NUM_STRATEGIES];
        assert_eq!(ucb.select(&s, 5, &mask), None);
    }

    #[test]
    fn masked_reduce_matches_branchy_select() {
        let mut rng = Rng::new(31);
        let ucb = MaskedUcb::default();
        for trial in 0..200 {
            let k = 1 + (trial % 7);
            let mut stats = ArmStats::new(k);
            for _ in 0..(trial % 40) {
                let c = rng.below(k as u64) as usize;
                let s = Strategy::from_index(
                    rng.below(NUM_STRATEGIES as u64) as usize,
                );
                stats.update(c, s, rng.uniform());
            }
            let mask: Vec<bool> =
                (0..k * NUM_STRATEGIES).map(|_| rng.chance(0.7)).collect();
            let t = 1 + (trial * 13) % 500;
            assert_eq!(
                ucb.select(&stats, t, &mask),
                ucb.select_masked_reduce(&stats, t, &mask),
                "trial {trial}"
            );
        }
        // all-masked → None on both paths
        let stats = ArmStats::new(2);
        let mask = vec![false; 2 * NUM_STRATEGIES];
        assert_eq!(ucb.select_masked_reduce(&stats, 5, &mask), None);
    }

    #[test]
    fn tie_breaks_on_lowest_index() {
        let s = ArmStats::new(2); // all arms identical
        let ucb = MaskedUcb::default();
        let (c, st) = ucb.select_unmasked(&s, 3);
        assert_eq!((c, st), (0, Strategy::Tiling));
    }

    #[test]
    fn reseed_aggregates_history_by_new_assignment() {
        let history = vec![
            RewardRecord { kernel: 0, strategy: Strategy::Fusion, reward: 1.0 },
            RewardRecord { kernel: 1, strategy: Strategy::Fusion, reward: 0.0 },
            RewardRecord { kernel: 2, strategy: Strategy::Tiling, reward: 1.0 },
        ];
        // kernels 0,1 now in cluster 0; kernel 2 in cluster 1
        let assign = vec![0, 0, 1];
        let s = ArmStats::reseed(2, &history, &assign);
        // cluster 0 fusion: prior 0.5 + {1.0, 0.0} → n=3, mean=0.5
        assert_eq!(s.visits(0, Strategy::Fusion), 3.0);
        assert!((s.mean(0, Strategy::Fusion) - 0.5).abs() < 1e-12);
        // cluster 1 tiling: prior + {1.0} → n=2, mean=0.75
        assert!((s.mean(1, Strategy::Tiling) - 0.75).abs() < 1e-12);
        // untouched arm keeps prior
        assert_eq!(s.visits(1, Strategy::Fusion), PRIOR_N);
    }

    #[test]
    fn reseed_ignores_stale_kernels() {
        let history =
            vec![RewardRecord { kernel: 9, strategy: Strategy::Fusion, reward: 1.0 }];
        let s = ArmStats::reseed(2, &history, &[0, 1]);
        assert_eq!(s.visits(0, Strategy::Fusion), PRIOR_N);
    }

    #[test]
    fn softmax_pick_prefers_headroom() {
        let mut rng = Rng::new(12);
        let mut hits = 0;
        for _ in 0..1000 {
            if softmax_kernel_pick(&[5.0, 65.0], &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 900, "hits={hits}");
    }

    #[test]
    fn in_place_pick_matches_allocating_pick() {
        let headrooms = [5.0, 65.0, 30.0, -10.0];
        for seed in 0..50 {
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            let mut buf = headrooms;
            assert_eq!(
                softmax_kernel_pick(&headrooms, &mut a),
                softmax_kernel_pick_in_place(&mut buf, &mut b)
            );
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ucb_index_monotonicity() {
        let ucb = MaskedUcb::default();
        assert!(ucb.index(0.5, 1.0, 10.0) > ucb.index(0.5, 10.0, 10.0));
        assert!(ucb.index(0.9, 5.0, 10.0) > ucb.index(0.1, 5.0, 10.0));
    }
}
