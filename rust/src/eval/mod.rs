//! Experiment harnesses: regenerate every table and figure of the
//! paper's evaluation (§4) from this reproduction's substrates.
//!
//! Each `table*`/`fig*` experiment has two entry points: the legacy
//! `table1(iterations) -> String` renderers (kept for tests and
//! benches) and the `table1_report(iterations, threads)` functions that
//! run the full (device × llm × method × seed) grid through the shared
//! [`ExperimentRunner`] and return a [`ReproReport`] carrying both the
//! rendered text and a machine-readable JSON artifact
//! (`BENCH_<exp>.json`). `kernelband repro <exp> [--threads N]
//! [--out DIR]` exposes them on the CLI and `rust/benches/` wraps
//! scaled-down versions.
//!
//! Absolute numbers differ from the paper (the substrate is a
//! simulator, not the authors' testbed) — the *shape* (who wins, by
//! roughly what factor, orderings) is the reproduction target.
//!
//! Determinism contract: every experiment derives all randomness from
//! `EXPERIMENT_SEED` through split RNG lineages, and the runner's
//! fan-out preserves input order, so rendered tables and JSON artifacts
//! are byte-identical for any `--threads` value.

pub mod runner;

pub use runner::{CellResult, CellSpec, ExperimentRunner, ReproReport};

use std::sync::Arc;

use crate::baselines::{BestOfN, Geak, TorchMode};
use crate::engine::{EvalEngine, SimEngine};
use crate::gpu_model::{Device, ALL_DEVICES};
use crate::llm::{LlmBackend, LlmProfile, SurrogateLlm, ALL_LLMS};
use crate::metrics::{stratified, Aggregate, TaskOutcome};
use crate::policy::{KernelBand, PolicyConfig, PolicyMode, Trace};
use crate::rng::Rng;
use crate::sched::{BatchMode, SchedContext};
use crate::service::{BreakdownRow, TimeModel};
use crate::store::warm::TaskWarmStart;
use crate::store::TraceStore;
use crate::strategy::{ALL_STRATEGIES, NUM_STRATEGIES};
use crate::util::json::Json;
use crate::util::par::parallel_map;
use crate::workload::{Suite, TaskSpec};

/// Root seed for all experiments (subset sampling uses the paper's 42
/// independently; this keys simulator noise and LLM sampling).
pub const EXPERIMENT_SEED: u64 = 20_260_212;

/// Every experiment `kernelband repro` knows, in `repro all` order.
pub const ALL_EXPERIMENTS: [&str; 10] = [
    "table1", "table2", "table3", "table4", "table9", "table10", "fig2",
    "fig3", "fig4", "regret",
];

/// An optimization method under evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// KernelBand with a policy mode and cluster count K.
    KernelBand(PolicyMode, usize),
    BoN,
    Geak,
}

impl Method {
    pub fn name(self) -> String {
        match self {
            Method::KernelBand(PolicyMode::Full, 3) => "KernelBand".into(),
            Method::KernelBand(PolicyMode::Full, k) => {
                format!("KernelBand (K={k})")
            }
            Method::KernelBand(mode, _) => format!("KernelBand [{mode:?}]"),
            Method::BoN => "BoN".into(),
            Method::Geak => "GEAK".into(),
        }
    }

    /// Optimize a single task. `root` must be the method-lineage stream
    /// (`Rng::new(seed).split("method", tag)`); per-task streams derive
    /// from it by task id, so calls are independent of execution order.
    pub fn run_task<E: EvalEngine, L: LlmBackend>(
        self,
        task: &TaskSpec,
        engine: &E,
        llm: &L,
        iterations: usize,
        root: &Rng,
    ) -> Trace {
        self.run_task_warm(task, engine, llm, iterations, root, None)
    }

    /// [`Method::run_task`] with optional warm-start state replayed
    /// from a prior trace. Only KernelBand consumes it (the baselines
    /// have no arms or clusters to seed); `None` is bit-identical to
    /// `run_task`.
    pub fn run_task_warm<E: EvalEngine, L: LlmBackend>(
        self,
        task: &TaskSpec,
        engine: &E,
        llm: &L,
        iterations: usize,
        root: &Rng,
        warm: Option<&TaskWarmStart>,
    ) -> Trace {
        self.run_task_sched(task, engine, llm, iterations, root, warm,
                            &SchedContext::default())
    }

    /// [`Method::run_task_warm`] with a scheduling context
    /// ([`crate::sched`]): KernelBand runs the batched loop with the
    /// shared re-clustering / profile caches; the baselines ignore the
    /// context (they have no clusters to batch over or profile). The
    /// default context is bit-identical to `run_task_warm`.
    pub fn run_task_sched<E: EvalEngine, L: LlmBackend>(
        self,
        task: &TaskSpec,
        engine: &E,
        llm: &L,
        iterations: usize,
        root: &Rng,
        warm: Option<&TaskWarmStart>,
        ctx: &SchedContext,
    ) -> Trace {
        match self {
            Method::KernelBand(mode, k) => {
                let mut cfg = PolicyConfig::with_mode(mode);
                cfg.iterations = iterations;
                if mode != PolicyMode::NoClustering {
                    cfg.clusters = k;
                }
                KernelBand::new(cfg)
                    .optimize_sched(task, engine, llm, root, warm, ctx)
            }
            Method::BoN => {
                BestOfN::new(iterations).optimize(task, engine, llm, root)
            }
            Method::Geak => {
                Geak::new(iterations).optimize(task, engine, llm, root)
            }
        }
    }

    /// Run the method on every task of a suite with an explicit worker
    /// bound (0 = available parallelism). The split RNG keys make
    /// results invariant to thread count and execution order.
    pub fn run_threads(self, suite: &Suite, device: Device,
                       llm_profile: LlmProfile, iterations: usize, seed: u64,
                       threads: usize) -> Vec<Trace> {
        let engine = SimEngine::new(device);
        let llm = SurrogateLlm::new(llm_profile);
        let root = Rng::new(seed).split("method", self.tag());
        parallel_map(&suite.tasks, threads, |_, task| {
            self.run_task(task, &engine, &llm, iterations, &root)
        })
    }

    /// [`Method::run_threads`] with all available cores.
    pub fn run(self, suite: &Suite, device: Device, llm_profile: LlmProfile,
               iterations: usize, seed: u64) -> Vec<Trace> {
        self.run_threads(suite, device, llm_profile, iterations, seed, 0)
    }

    fn tag(self) -> u64 {
        match self {
            Method::KernelBand(mode, k) => 100 + k as u64 * 10 + mode as u64,
            Method::BoN => 1,
            Method::Geak => 2,
        }
    }
}

pub fn outcomes(traces: &[Trace]) -> Vec<TaskOutcome> {
    traces.iter().map(|t| t.outcome()).collect()
}

/// How a grid experiment runs: fan-out width plus the optional
/// persistent store session ([`crate::store`]). `RunOpts::default()` is
/// the pre-store behavior (all cores, no session).
#[derive(Debug, Clone, Default)]
pub struct RunOpts {
    /// Worker threads (0 = available parallelism). Results are
    /// invariant to this value.
    pub threads: usize,
    /// Store session shared by every cell of the experiment: caches,
    /// warm-start, trace emission.
    pub session: Option<Arc<TraceStore>>,
    /// Per-iteration candidate batch sizing. `Fixed(0)`/`Fixed(1)`
    /// both mean the legacy single-candidate loop (byte-identical
    /// artifacts to the pre-batch path); `Adaptive` is `--batch auto`.
    pub batch: BatchMode,
    /// Generated-workload override (`repro --workload grammar:...`):
    /// every suite-driven experiment runs on this expanded space
    /// instead of the Table-7 suite, and the artifact JSON is tagged
    /// with the workload label. `None` keeps legacy artifacts
    /// byte-identical.
    pub workload: Option<WorkloadOverride>,
    /// Advisory telemetry recorder (`repro --obs ...`): grid runs get
    /// the same METRICS.json / decision-ledger / regret accounting the
    /// serve path has. Strictly observational — `BENCH_*.json` bytes
    /// are invariant to it.
    pub obs: Option<Arc<crate::obs::Recorder>>,
}

/// An expanded grammar space substituted for the hand-built suite.
#[derive(Debug, Clone)]
pub struct WorkloadOverride {
    /// Canonical spec string (`grammar:<name>:seed=S`) — the artifact
    /// `workload` tag.
    pub label: String,
    /// The expanded task space, shared across cells.
    pub suite: Arc<Suite>,
}

impl WorkloadOverride {
    /// Expand a parsed grammar spec into an override.
    pub fn from_spec(spec: &crate::workload::gen::GrammarSpec)
                     -> Result<WorkloadOverride, String> {
        Ok(WorkloadOverride {
            label: spec.canonical(),
            suite: Arc::new(Suite::from_grammar(spec)?),
        })
    }
}

impl RunOpts {
    pub fn threads(threads: usize) -> RunOpts {
        RunOpts {
            threads,
            session: None,
            batch: BatchMode::default(),
            workload: None,
            obs: None,
        }
    }

    /// Set a fixed per-iteration candidate batch width.
    pub fn with_batch(self, batch: usize) -> RunOpts {
        self.with_batch_mode(BatchMode::Fixed(batch))
    }

    /// Set the full batch sizing mode (`Fixed` or `Adaptive`).
    pub fn with_batch_mode(mut self, batch: BatchMode) -> RunOpts {
        self.batch = batch;
        self
    }

    fn runner(&self) -> ExperimentRunner {
        ExperimentRunner::new(self.threads)
            .with_session(self.session.clone())
            .with_batch_mode(self.batch)
            .with_obs(self.obs.clone())
    }
}

/// The full-suite view of a run: the Table-7 suite, or the whole
/// generated space under a `--workload` override.
fn suite_full(opts: &RunOpts) -> Suite {
    match &opts.workload {
        Some(w) => (*w.suite).clone(),
        None => Suite::full(EXPERIMENT_SEED),
    }
}

/// The detailed-analysis view: the stratified 50-kernel subset for the
/// Table-7 suite. Generated spaces run whole — their category
/// marginals don't match Table 7, so the stratified sample doesn't
/// apply.
fn suite_analysis(opts: &RunOpts) -> Suite {
    match &opts.workload {
        Some(w) => (*w.suite).clone(),
        None => Suite::full(EXPERIMENT_SEED).subset50(),
    }
}

/// The torch-comparable view (Appendix G) of [`suite_analysis`].
fn suite_torch(opts: &RunOpts) -> Suite {
    match &opts.workload {
        Some(w) => w.suite.torch_subset(),
        None => Suite::full(EXPERIMENT_SEED).subset50().torch_subset(),
    }
}

/// Dispatch an experiment by name at the standard budgets (tables
/// default to T=20, figures to T=40, regret's horizon to T=3200);
/// `None` for an unknown name. `threads` bounds the runner fan-out and
/// is ignored by the analytic/synthetic experiments (fig3, regret).
pub fn report(exp: &str, iterations: Option<usize>, threads: usize)
              -> Option<ReproReport> {
    report_opts(exp, iterations, &RunOpts::threads(threads))
}

/// [`report`] with full run options (store session, warm-start).
pub fn report_opts(exp: &str, iterations: Option<usize>, opts: &RunOpts)
                   -> Option<ReproReport> {
    let t20 = iterations.unwrap_or(20);
    let t40 = iterations.unwrap_or(40);
    let mut report = match exp {
        "table1" => table1_report_opts(t20, opts),
        "table2" => table2_report_opts(t20, opts),
        "table3" => table3_report_opts(t20, opts),
        "table4" => table4_report_opts(t20, opts),
        "table9" => table9_report_opts(t20, opts),
        "table10" => table10_report_opts(t20, opts),
        "fig2" => fig2_report_opts(t40, opts),
        "fig3" => fig3_report(),
        "fig4" => fig4_report_opts(t40, opts),
        "regret" => regret_report(iterations.unwrap_or(3200)),
        _ => return None,
    };
    // tag suite-driven artifacts with the workload label; fig3/regret
    // are suite-free and keep legacy bytes even under --workload
    if let Some(w) = &opts.workload {
        if !matches!(exp, "fig3" | "regret") {
            report.json.insert("workload", Json::str(w.label.clone()));
        }
    }
    Some(report)
}

// ---------------------------------------------------------------------------
// text-table rendering
// ---------------------------------------------------------------------------

/// Render an aligned text table.
pub fn render_table(title: &str, headers: &[&str], rows: &[Vec<String>])
                    -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    out.push_str(&format!("## {title}\n"));
    let hdr: Vec<String> = headers
        .iter()
        .enumerate()
        .map(|(i, h)| format!("{:<w$}", h, w = widths[i]))
        .collect();
    out.push_str(&hdr.join("  "));
    out.push('\n');
    out.push_str(&"-".repeat(hdr.join("  ").len()));
    out.push('\n');
    for row in rows {
        let cells: Vec<String> = row
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
            .collect();
        out.push_str(&cells.join("  "));
        out.push('\n');
    }
    out
}

fn fmt_cfg(a: &Aggregate) -> [String; 3] {
    [
        format!("{:.1}", a.correct_pct),
        format!("{:.1}", a.fast1_pct),
        if a.geomean_standard.is_nan() {
            "-".into()
        } else {
            format!("{:.2}", a.geomean_standard)
        },
    ]
}

// ---------------------------------------------------------------------------
// Table 1 — main results
// ---------------------------------------------------------------------------

/// Table 1: {RTX 4090, H20, A100} × {BoN, GEAK, KernelBand}, stratified
/// by difficulty, on the full 183-kernel suite, T = 20.
pub fn table1_report(iterations: usize, threads: usize) -> ReproReport {
    table1_report_opts(iterations, &RunOpts::threads(threads))
}

/// [`table1_report`] with full run options.
pub fn table1_report_opts(iterations: usize, opts: &RunOpts) -> ReproReport {
    let suite = suite_full(opts);
    let methods = [
        Method::BoN,
        Method::Geak,
        Method::KernelBand(PolicyMode::Full, 3),
    ];
    let mut cells = Vec::new();
    for device in ALL_DEVICES {
        for method in methods {
            cells.push(CellSpec::new(
                method,
                device,
                LlmProfile::DeepSeekV32,
                iterations,
                EXPERIMENT_SEED,
            ));
        }
    }
    let results = opts.runner().run(&suite, &cells);
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            let strata = stratified(&outcomes(&r.traces));
            let mut row =
                vec![r.spec.device.name().to_string(), r.spec.label.clone()];
            for (_, agg) in &strata {
                row.extend(fmt_cfg(agg));
            }
            row
        })
        .collect();
    let text = render_table(
        "Table 1 — TritonBench-G main results (C %, F %, G geomean; standard mode)",
        &[
            "Platform", "Method", "L1-2 C", "F", "G", "L3 C", "F", "G",
            "L4-5 C", "F", "G", "All C", "F", "G",
        ],
        &rows,
    );
    let json =
        runner::experiment_json("table1", iterations, EXPERIMENT_SEED, &results);
    ReproReport { name: "table1".into(), text, json }
}

pub fn table1(iterations: usize) -> String {
    table1_report(iterations, 0).text
}

// ---------------------------------------------------------------------------
// Table 2 — LLM generalization
// ---------------------------------------------------------------------------

/// Table 2: 4 LLM backends × 3 methods on the 50-kernel subset, H20.
pub fn table2_report(iterations: usize, threads: usize) -> ReproReport {
    table2_report_opts(iterations, &RunOpts::threads(threads))
}

/// [`table2_report`] with full run options.
pub fn table2_report_opts(iterations: usize, opts: &RunOpts) -> ReproReport {
    let suite = suite_analysis(opts);
    let methods = [
        Method::BoN,
        Method::Geak,
        Method::KernelBand(PolicyMode::Full, 3),
    ];
    let mut cells = Vec::new();
    for llm in ALL_LLMS {
        for method in methods {
            cells.push(CellSpec::new(
                method,
                Device::H20,
                llm,
                iterations,
                EXPERIMENT_SEED,
            ));
        }
    }
    let results = opts.runner().run(&suite, &cells);
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            let [c, f, g] = fmt_cfg(&r.aggregate);
            vec![
                r.spec.llm.spec().name.to_string(),
                r.spec.label.clone(),
                c,
                f,
                g,
            ]
        })
        .collect();
    let text = render_table(
        "Table 2 — LLM generalization (50-kernel subset, H20, T=20)",
        &["Model", "Method", "C (%)", "F (%)", "G"],
        &rows,
    );
    let json =
        runner::experiment_json("table2", iterations, EXPERIMENT_SEED, &results);
    ReproReport { name: "table2".into(), text, json }
}

pub fn table2(iterations: usize) -> String {
    table2_report(iterations, 0).text
}

// ---------------------------------------------------------------------------
// Tables 3 / 10 — strategy selection statistics
// ---------------------------------------------------------------------------

/// Aggregated per-strategy Freq/Succ/Best over a set of traces.
pub fn strategy_stats(traces: &[Trace]) -> Vec<(String, f64, f64, f64)> {
    let mut selected = [0usize; NUM_STRATEGIES];
    let mut success = [0usize; NUM_STRATEGIES];
    let mut on_best = [0usize; NUM_STRATEGIES];
    for tr in traces {
        for (i, c) in tr.strategy_counts().iter().enumerate() {
            selected[i] += c.selected;
            success[i] += c.success;
            on_best[i] += c.on_best_chain;
        }
    }
    let total: usize = selected.iter().sum();
    ALL_STRATEGIES
        .iter()
        .enumerate()
        .map(|(i, s)| {
            (
                s.name().to_string(),
                100.0 * selected[i] as f64 / total.max(1) as f64,
                100.0 * success[i] as f64 / selected[i].max(1) as f64,
                100.0 * on_best[i] as f64 / success[i].max(1) as f64,
            )
        })
        .collect()
}

fn strategy_stats_json(traces: &[Trace]) -> Json {
    Json::Arr(
        strategy_stats(traces)
            .into_iter()
            .map(|(name, f, s, b)| {
                Json::obj(vec![
                    ("strategy", Json::str(name)),
                    ("freq_pct", Json::num(f)),
                    ("succ_pct", Json::num(s)),
                    ("best_pct", Json::num(b)),
                ])
            })
            .collect(),
    )
}

fn strategy_rows(traces: &[Trace]) -> Vec<Vec<String>> {
    strategy_stats(traces)
        .into_iter()
        .map(|(name, f, s, b)| {
            vec![
                name,
                format!("{f:.1}"),
                format!("{s:.1}"),
                format!("{b:.1}"),
            ]
        })
        .collect()
}

fn kernelband_cell(device: Device, iterations: usize) -> CellSpec {
    CellSpec::new(
        Method::KernelBand(PolicyMode::Full, 3),
        device,
        LlmProfile::DeepSeekV32,
        iterations,
        EXPERIMENT_SEED,
    )
}

/// Table 3: strategy risk/reward profiles on H20.
pub fn table3_report(iterations: usize, threads: usize) -> ReproReport {
    table3_report_opts(iterations, &RunOpts::threads(threads))
}

/// [`table3_report`] with full run options.
pub fn table3_report_opts(iterations: usize, opts: &RunOpts) -> ReproReport {
    let suite = suite_analysis(opts);
    let cells = vec![kernelband_cell(Device::H20, iterations)];
    let results = opts.runner().run(&suite, &cells);
    let text = render_table(
        "Table 3 — strategy selection statistics (H20, 50-kernel subset)",
        &["Strategy", "Freq (%)", "Succ (%)", "Best (%)"],
        &strategy_rows(&results[0].traces),
    );
    let mut json =
        runner::experiment_json("table3", iterations, EXPERIMENT_SEED, &results);
    json.insert("strategies", strategy_stats_json(&results[0].traces));
    ReproReport { name: "table3".into(), text, json }
}

pub fn table3(iterations: usize) -> String {
    table3_report(iterations, 0).text
}

/// Table 10: strategy statistics on H20 vs RTX 4090 (hardware
/// adaptation, Appendix I).
pub fn table10_report(iterations: usize, threads: usize) -> ReproReport {
    table10_report_opts(iterations, &RunOpts::threads(threads))
}

/// [`table10_report`] with full run options.
pub fn table10_report_opts(iterations: usize, opts: &RunOpts) -> ReproReport {
    let suite = suite_analysis(opts);
    let cells = vec![
        kernelband_cell(Device::H20, iterations),
        kernelband_cell(Device::Rtx4090, iterations),
    ];
    let results = opts.runner().run(&suite, &cells);
    let h20 = strategy_rows(&results[0].traces);
    let rtx = strategy_rows(&results[1].traces);
    let rows: Vec<Vec<String>> = h20
        .into_iter()
        .zip(rtx)
        .map(|(a, b)| {
            vec![
                a[0].clone(),
                a[1].clone(),
                a[2].clone(),
                a[3].clone(),
                b[1].clone(),
                b[2].clone(),
                b[3].clone(),
            ]
        })
        .collect();
    let text = render_table(
        "Table 10 — strategy utilization, H20 vs RTX 4090",
        &[
            "Strategy", "H20 Freq", "Succ", "Best", "4090 Freq", "Succ", "Best",
        ],
        &rows,
    );
    let mut json = runner::experiment_json(
        "table10",
        iterations,
        EXPERIMENT_SEED,
        &results,
    );
    json.insert("strategies_h20", strategy_stats_json(&results[0].traces));
    json.insert(
        "strategies_rtx4090",
        strategy_stats_json(&results[1].traces),
    );
    ReproReport { name: "table10".into(), text, json }
}

pub fn table10(iterations: usize) -> String {
    table10_report(iterations, 0).text
}

// ---------------------------------------------------------------------------
// Table 4 — ablations
// ---------------------------------------------------------------------------

/// Table 4: single-component and framework-level ablations (H20,
/// 50-kernel subset).
pub fn table4_report(iterations: usize, threads: usize) -> ReproReport {
    table4_report_opts(iterations, &RunOpts::threads(threads))
}

/// [`table4_report`] with full run options.
pub fn table4_report_opts(iterations: usize, opts: &RunOpts) -> ReproReport {
    let suite = suite_analysis(opts);
    let configs: Vec<(&str, Method)> = vec![
        ("KernelBand (Full)", Method::KernelBand(PolicyMode::Full, 3)),
        (
            "w/o Clustering (K=1)",
            Method::KernelBand(PolicyMode::NoClustering, 1),
        ),
        (
            "w/o Profiling",
            Method::KernelBand(PolicyMode::NoProfiling, 3),
        ),
        (
            "LLM Strategy Selection",
            Method::KernelBand(PolicyMode::LlmStrategySelection, 3),
        ),
        (
            "w/o Strategy + Raw Prof.",
            Method::KernelBand(PolicyMode::NoStrategyRawProfiling, 3),
        ),
        (
            "w/o Strategy Set",
            Method::KernelBand(PolicyMode::NoStrategySet, 3),
        ),
        ("BoN (baseline)", Method::BoN),
    ];
    let cells: Vec<CellSpec> = configs
        .iter()
        .map(|(label, method)| {
            CellSpec::new(
                *method,
                Device::H20,
                LlmProfile::DeepSeekV32,
                iterations,
                EXPERIMENT_SEED,
            )
            .with_label(label)
        })
        .collect();
    let results = opts.runner().run(&suite, &cells);
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            let [c, f, g] = fmt_cfg(&r.aggregate);
            vec![r.spec.label.clone(), c, f, g]
        })
        .collect();
    let text = render_table(
        "Table 4 — ablations (H20, 50-kernel subset, T=20)",
        &["Configuration", "C (%)", "F (%)", "G"],
        &rows,
    );
    let json =
        runner::experiment_json("table4", iterations, EXPERIMENT_SEED, &results);
    ReproReport { name: "table4".into(), text, json }
}

pub fn table4(iterations: usize) -> String {
    table4_report(iterations, 0).text
}

// ---------------------------------------------------------------------------
// Table 9 — PyTorch baselines (Appendix G)
// ---------------------------------------------------------------------------

/// Table 9: KernelBand-optimized kernels vs PyTorch eager / inductor /
/// max-autotune on the 30-kernel torch-comparable subset (H20).
pub fn table9_report(iterations: usize, threads: usize) -> ReproReport {
    table9_report_opts(iterations, &RunOpts::threads(threads))
}

/// Geomean speedups of the KernelBand traces over each PyTorch mode,
/// measured through `engine` — generic so a store session's
/// [`CachedEngine`](crate::store::wrap::CachedEngine) covers the torch
/// baselines too (a warm run must re-simulate nothing, and the `[store]`
/// ledger must count this work).
fn torch_baseline_rows<E: EvalEngine>(suite: &Suite, traces: &[Trace],
                                      engine: &E)
                                      -> (Vec<Vec<String>>, Vec<Json>) {
    let root = Rng::new(EXPERIMENT_SEED).split("torch", 0);
    let mut rows = Vec::new();
    let mut modes_json = Vec::new();
    for mode in [TorchMode::Eager, TorchMode::Inductor, TorchMode::MaxAutotune] {
        let mut log_sum = 0.0;
        for (task, trace) in suite.tasks.iter().zip(traces) {
            let torch_latency = mode.latency(task, engine, &root);
            // fallback semantics: if optimization failed, the deployed
            // kernel is the Triton reference
            let best = if trace.correct() {
                trace.candidates[trace.best_id].measurement.total_latency_s
                    .min(trace.naive_latency_s)
            } else {
                trace.naive_latency_s
            };
            log_sum += (torch_latency / best).ln();
        }
        let geomean = (log_sum / suite.len().max(1) as f64).exp();
        rows.push(vec![
            format!("vs. {}", mode.name()),
            format!("{geomean:.2}x"),
        ]);
        modes_json.push(Json::obj(vec![
            ("baseline", Json::str(mode.name())),
            ("geomean_speedup", Json::num(geomean)),
        ]));
    }
    (rows, modes_json)
}

/// [`table9_report`] with full run options.
pub fn table9_report_opts(iterations: usize, opts: &RunOpts) -> ReproReport {
    let suite = suite_torch(opts);
    let cells = vec![kernelband_cell(Device::H20, iterations)];
    let results = opts.runner().run(&suite, &cells);
    let traces = &results[0].traces;
    let (rows, modes_json) = match &opts.session {
        Some(store) => torch_baseline_rows(
            &suite,
            traces,
            &crate::store::wrap::CachedEngine::new(
                SimEngine::new(Device::H20),
                store.clone(),
            ),
        ),
        None => torch_baseline_rows(
            &suite,
            traces,
            &SimEngine::new(Device::H20),
        ),
    };
    let text = render_table(
        "Table 9 — speedup over PyTorch baselines (30 kernels, H20, T=20)",
        &["PyTorch Baseline", "Speedup"],
        &rows,
    );
    let mut json =
        runner::experiment_json("table9", iterations, EXPERIMENT_SEED, &results);
    json.insert("torch_baselines", Json::Arr(modes_json));
    ReproReport { name: "table9".into(), text, json }
}

pub fn table9(iterations: usize) -> String {
    table9_report(iterations, 0).text
}

// ---------------------------------------------------------------------------
// Figure 2 — scaling & clustering sensitivity
// ---------------------------------------------------------------------------

/// Fallback-mode geomean best-speedup curve across iterations for a set
/// of traces (all with the same T).
///
/// Each trace's curve is materialized once up front — the old
/// `tr.speedup_curve()[i]` inner call re-allocated every trace's full
/// curve per iteration, turning a T-point reduction into O(|traces|·T²)
/// allocations on the runner's artifact path. Identical output bytes:
/// same values summed in the same order.
pub fn scaling_curve(traces: &[Trace]) -> Vec<f64> {
    let t = traces.iter().map(|tr| tr.records.len()).min().unwrap_or(0);
    let curves: Vec<Vec<f64>> =
        traces.iter().map(|tr| tr.speedup_curve()).collect();
    (0..t)
        .map(|i| {
            let log_sum: f64 = curves.iter().map(|c| c[i].ln()).sum();
            (log_sum / traces.len() as f64).exp()
        })
        .collect()
}

/// Figure 2: T = 40 scaling for KernelBand K ∈ {1, 2, 3, 5} vs BoN and
/// GEAK (fallback-mode geomean, 50-kernel subset, H20).
pub fn fig2_report(iterations: usize, threads: usize) -> ReproReport {
    fig2_report_opts(iterations, &RunOpts::threads(threads))
}

/// [`fig2_report`] with full run options.
pub fn fig2_report_opts(iterations: usize, opts: &RunOpts) -> ReproReport {
    let suite = suite_analysis(opts);
    let methods = [
        Method::KernelBand(PolicyMode::Full, 1),
        Method::KernelBand(PolicyMode::Full, 2),
        Method::KernelBand(PolicyMode::Full, 3),
        Method::KernelBand(PolicyMode::Full, 5),
        Method::Geak,
        Method::BoN,
    ];
    let cells: Vec<CellSpec> = methods
        .iter()
        .map(|&m| {
            CellSpec::new(
                m,
                Device::H20,
                LlmProfile::DeepSeekV32,
                iterations,
                EXPERIMENT_SEED,
            )
        })
        .collect();
    let results = opts.runner().run(&suite, &cells);
    let series: Vec<(String, Vec<f64>)> = results
        .iter()
        .map(|r| (r.spec.label.clone(), scaling_curve(&r.traces)))
        .collect();

    let mut headers = vec!["iter".to_string()];
    headers.extend(series.iter().map(|(n, _)| n.clone()));
    let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut rows = Vec::new();
    for t in 0..iterations {
        let mut row = vec![format!("{}", t + 1)];
        for (_, curve) in &series {
            row.push(format!("{:.3}", curve[t]));
        }
        rows.push(row);
    }
    let text = render_table(
        "Figure 2 — scaling & clustering sensitivity (fallback geomean, H20)",
        &headers_ref,
        &rows,
    );
    let json =
        runner::experiment_json("fig2", iterations, EXPERIMENT_SEED, &results);
    ReproReport { name: "fig2".into(), text, json }
}

pub fn fig2(iterations: usize) -> String {
    fig2_report(iterations, 0).text
}

// ---------------------------------------------------------------------------
// Figure 3 — time breakdown
// ---------------------------------------------------------------------------

/// Figure 3: per-kernel/iteration time breakdown, serial vs batched.
pub fn fig3_report() -> ReproReport {
    let tm = TimeModel::default();
    let mut rows = Vec::new();
    for r in tm.serial_breakdown() {
        rows.push(vec![
            "serial".into(),
            r.component.into(),
            format!("{:.1}", r.seconds),
            format!("{:.1}", r.percent),
        ]);
    }
    rows.push(vec![
        "serial".into(),
        "TOTAL".into(),
        format!("{:.1} ({:.1} min)", tm.serial_iteration_s(),
                tm.serial_iteration_s() / 60.0),
        "100.0".into(),
    ]);
    for r in tm.batched_breakdown() {
        rows.push(vec![
            "batched".into(),
            r.component.into(),
            format!("{:.1}", r.seconds),
            format!("{:.1}", r.percent),
        ]);
    }
    rows.push(vec![
        "batched".into(),
        "TOTAL".into(),
        format!("{:.1} s", tm.batched_iteration_s()),
        "100.0".into(),
    ]);
    let text = render_table(
        "Figure 3 — time breakdown per kernel/iteration",
        &["Pipeline", "Component", "Seconds", "% of total"],
        &rows,
    );
    let breakdown_json = |rows: &[BreakdownRow], total_s: f64| {
        Json::obj(vec![
            ("total_s", Json::num(total_s)),
            (
                "components",
                Json::Arr(
                    rows.iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("component", Json::str(r.component)),
                                ("seconds", Json::num(r.seconds)),
                                ("percent", Json::num(r.percent)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    };
    let json = Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("experiment", Json::str("fig3")),
        (
            "serial",
            breakdown_json(&tm.serial_breakdown(), tm.serial_iteration_s()),
        ),
        (
            "batched",
            breakdown_json(&tm.batched_breakdown(), tm.batched_iteration_s()),
        ),
    ]);
    ReproReport { name: "fig3".into(), text, json }
}

pub fn fig3() -> String {
    fig3_report().text
}

// ---------------------------------------------------------------------------
// Figure 4 — speedup vs API cost
// ---------------------------------------------------------------------------

/// Best fallback speedup achievable within a per-kernel budget, read off
/// a trace's cumulative cost curve.
pub fn speedup_within_budget(trace: &Trace, budget_usd: f64) -> f64 {
    let mut spent = 0.0;
    let mut best = 1.0f64;
    for r in &trace.records {
        spent += r.cost_usd;
        if spent > budget_usd {
            break;
        }
        best = best.max(r.best_speedup_so_far);
    }
    best
}

/// Figure 4: geomean speedup as a function of API budget per kernel.
pub fn fig4_report(iterations: usize, threads: usize) -> ReproReport {
    fig4_report_opts(iterations, &RunOpts::threads(threads))
}

/// [`fig4_report`] with full run options.
pub fn fig4_report_opts(iterations: usize, opts: &RunOpts) -> ReproReport {
    let suite = suite_analysis(opts);
    let budgets = [0.05, 0.10, 0.15, 0.20, 0.30, 0.40, 0.50];
    let methods = [
        Method::KernelBand(PolicyMode::Full, 3),
        Method::Geak,
        Method::BoN,
    ];
    let cells: Vec<CellSpec> = methods
        .iter()
        .map(|&m| {
            CellSpec::new(
                m,
                Device::H20,
                LlmProfile::DeepSeekV32,
                iterations,
                EXPERIMENT_SEED,
            )
        })
        .collect();
    let results = opts.runner().run(&suite, &cells);
    let budget_geomean = |traces: &[Trace], b: f64| {
        let log_sum: f64 = traces
            .iter()
            .map(|tr| speedup_within_budget(tr, b).ln())
            .sum();
        (log_sum / traces.len() as f64).exp()
    };
    let mut rows = Vec::new();
    for &b in &budgets {
        let mut row = vec![format!("${b:.2}")];
        for r in &results {
            row.push(format!("{:.3}", budget_geomean(&r.traces, b)));
        }
        rows.push(row);
    }
    let text = render_table(
        "Figure 4 — geomean speedup vs API cost per kernel (H20, T=40)",
        &["Budget", "KernelBand", "GEAK", "BoN"],
        &rows,
    );
    let curves = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("method", Json::str(r.spec.label.clone())),
                (
                    "points",
                    Json::Arr(
                        budgets
                            .iter()
                            .map(|&b| {
                                Json::obj(vec![
                                    ("budget_usd", Json::num(b)),
                                    (
                                        "geomean_fallback_speedup",
                                        Json::num(budget_geomean(
                                            &r.traces, b,
                                        )),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    let mut json =
        runner::experiment_json("fig4", iterations, EXPERIMENT_SEED, &results);
    json.insert("budget_curves", Json::Arr(curves));
    ReproReport { name: "fig4".into(), text, json }
}

pub fn fig4(iterations: usize) -> String {
    fig4_report(iterations, 0).text
}

// ---------------------------------------------------------------------------
// Theorem 1 — empirical regret check
// ---------------------------------------------------------------------------

/// Empirical average regret of masked UCB on a synthetic (K × S)-arm
/// instance vs the Theorem-1 rate `C·sqrt(K|S| ln T / T)`.
pub fn regret_report(max_t: usize) -> ReproReport {
    use crate::bandit::{ArmStats, MaskedUcb};
    let k = 3usize;
    let s = NUM_STRATEGIES;
    let mut rng = Rng::new(7).split("regret", 0);
    // true means in [0, 0.9]
    let means: Vec<f64> = (0..k * s).map(|_| rng.uniform_in(0.0, 0.9)).collect();
    let mu_star = means.iter().cloned().fold(0.0, f64::max);

    let ucb = MaskedUcb::default();
    let mut stats = ArmStats::new(k);
    let mask = vec![true; k * s];
    let mut cum_regret = 0.0;
    let mut checkpoint_data = Vec::new();
    let checkpoints: Vec<usize> =
        [10, 25, 50, 100, 200, 400, 800, 1600, 3200]
            .into_iter()
            .filter(|&t| t <= max_t)
            .collect();
    for t in 1..=max_t {
        let (ci, st) = ucb.select(&stats, t, &mask).unwrap();
        let idx = ci * s + st.index();
        // Bernoulli reward with the arm's true mean
        let r = if rng.chance(means[idx]) { 1.0 } else { 0.0 };
        stats.update(ci, st, r);
        cum_regret += mu_star - means[idx];
        if checkpoints.contains(&t) {
            let avg = cum_regret / t as f64;
            let bound =
                ((k * s) as f64 * (t as f64).ln() / t as f64).sqrt();
            checkpoint_data.push((t, avg, bound, avg <= bound * 1.5));
        }
    }
    let rows: Vec<Vec<String>> = checkpoint_data
        .iter()
        .map(|&(t, avg, bound, within)| {
            vec![
                format!("{t}"),
                format!("{avg:.4}"),
                format!("{bound:.4}"),
                format!("{within}"),
            ]
        })
        .collect();
    let text = render_table(
        "Theorem 1 — empirical avg regret vs O(sqrt(K|S| ln T / T)) rate",
        &["T", "avg regret", "rate (C=1)", "within 1.5x rate"],
        &rows,
    );
    let json = Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("experiment", Json::str("regret")),
        ("max_t", Json::num(max_t as f64)),
        ("clusters", Json::num(k as f64)),
        ("strategies", Json::num(s as f64)),
        (
            "checkpoints",
            Json::Arr(
                checkpoint_data
                    .iter()
                    .map(|&(t, avg, bound, within)| {
                        Json::obj(vec![
                            ("t", Json::num(t as f64)),
                            ("avg_regret", Json::num(avg)),
                            ("rate_bound", Json::num(bound)),
                            ("within_1_5x", Json::Bool(within)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    ReproReport { name: "regret".into(), text, json }
}

pub fn regret(max_t: usize) -> String {
    regret_report(max_t).text
}
