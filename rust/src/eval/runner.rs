//! Shared experiment runner: one deterministic parallel fan-out for the
//! whole (device × llm × method × seed) grid, plus the machine-readable
//! JSON result artifacts every table/figure emits.
//!
//! The runner flattens its grid into (cell, task) work items and pushes
//! them through [`crate::util::par::parallel_map`], so a 9-cell Table-1
//! campaign keeps every core busy even though individual cells have
//! tails. Determinism is structural, not accidental:
//!
//! * every work item derives its RNG from the cell's `(seed, method)`
//!   lineage and the task id — never from shared mutable state — so
//!   results are invariant to scheduling;
//! * `parallel_map` returns results in input order regardless of which
//!   thread ran what;
//! * JSON artifacts serialize with sorted keys and shortest-roundtrip
//!   float formatting, and contain no wall-clock or thread-count fields.
//!
//! Consequently the `BENCH_<exp>.json` artifact produced with
//! `--threads 1` is byte-identical to the one produced with
//! `--threads 8` (covered by `rust/tests/runner_artifacts.rs`).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::engine::SimEngine;
use crate::eval::{outcomes, scaling_curve, Method};
use crate::gpu_model::Device;
use crate::llm::{LlmProfile, SurrogateLlm};
use crate::metrics::{aggregate, stratified, Aggregate};
use crate::policy::Trace;
use crate::rng::Rng;
use crate::obs::regret as obs_regret;
use crate::sched::{BatchMode, JobObs, SchedContext};
use crate::store::log::records_for_trace;
use crate::store::wrap::{CachedEngine, CachedLlm};
use crate::store::TraceStore;
use crate::util::json::Json;
use crate::util::par::parallel_map;
use crate::workload::Suite;

/// One cell of the experiment grid: a method evaluated on a device with
/// an LLM backend for `iterations` steps under `seed`.
#[derive(Debug, Clone)]
pub struct CellSpec {
    /// Row label in rendered tables ("KernelBand", "w/o Profiling", …).
    pub label: String,
    pub method: Method,
    pub device: Device,
    pub llm: LlmProfile,
    pub iterations: usize,
    pub seed: u64,
}

impl CellSpec {
    pub fn new(method: Method, device: Device, llm: LlmProfile,
               iterations: usize, seed: u64) -> CellSpec {
        CellSpec {
            label: method.name(),
            method,
            device,
            llm,
            iterations,
            seed,
        }
    }

    /// Override the display label (Table 4's ablation row names).
    pub fn with_label(mut self, label: &str) -> CellSpec {
        self.label = label.to_string();
        self
    }
}

/// Per-cell result: traces in suite task order plus aggregate metrics.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub spec: CellSpec,
    pub traces: Vec<Trace>,
    pub aggregate: Aggregate,
}

impl CellResult {
    /// The cell as a result-artifact JSON object: spec, aggregate
    /// metrics, per-stratum metrics, and the fallback-geomean trajectory
    /// over iterations (the `BENCH_*.json` curve consumers read).
    pub fn to_json(&self) -> Json {
        let outs = outcomes(&self.traces);
        let strata = stratified(&outs)
            .iter()
            .map(|(s, a)| {
                Json::obj(vec![
                    ("stratum", Json::str(s.name())),
                    ("metrics", aggregate_json(a)),
                ])
            })
            .collect();
        let curve = scaling_curve(&self.traces)
            .into_iter()
            .map(Json::num)
            .collect();
        Json::obj(vec![
            ("label", Json::str(self.spec.label.clone())),
            ("method", Json::str(self.spec.method.name())),
            ("device", Json::str(self.spec.device.name())),
            ("llm", Json::str(self.spec.llm.spec().name)),
            ("iterations", Json::num(self.spec.iterations as f64)),
            ("seed", Json::num(self.spec.seed as f64)),
            ("metrics", aggregate_json(&self.aggregate)),
            ("strata", Json::Arr(strata)),
            ("curve", Json::Arr(curve)),
        ])
    }
}

/// Aggregate metrics as a JSON object (NaN geomeans become `null`).
pub fn aggregate_json(a: &Aggregate) -> Json {
    Json::obj(vec![
        ("tasks", Json::num(a.tasks as f64)),
        ("correct_pct", Json::num(a.correct_pct)),
        ("fast1_pct", Json::num(a.fast1_pct)),
        ("geomean_standard", Json::num(a.geomean_standard)),
        ("geomean_fallback", Json::num(a.geomean_fallback)),
        ("total_cost_usd", Json::num(a.total_cost_usd)),
    ])
}

/// The result-artifact root for a grid experiment.
pub fn experiment_json(name: &str, iterations: usize, seed: u64,
                       cells: &[CellResult]) -> Json {
    Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("experiment", Json::str(name)),
        ("iterations", Json::num(iterations as f64)),
        ("seed", Json::num(seed as f64)),
        (
            "cells",
            Json::Arr(cells.iter().map(CellResult::to_json).collect()),
        ),
    ])
}

/// Fans (cell × task) work items through the deterministic parallel map.
#[derive(Debug, Clone, Default)]
pub struct ExperimentRunner {
    /// Worker threads (0 = available parallelism). Results are invariant
    /// to this value.
    pub threads: usize,
    /// Optional persistent store session: when set, every measurement
    /// and LLM proposal routes through the content-addressed caches
    /// ([`crate::store::wrap`]), warm-start state is applied per task,
    /// and the run's traces are queued on the store's append-only log.
    pub session: Option<Arc<TraceStore>>,
    /// Per-iteration candidate batch sizing. `Fixed(0)`/`Fixed(1)` are
    /// the legacy single-candidate loop. Results are invariant to
    /// `threads` for every mode — the `Adaptive` controller consumes
    /// only per-job deterministic state — and `Fixed(n ≤ 1)` is
    /// byte-identical to the pre-batch runner.
    pub batch: BatchMode,
    /// Advisory telemetry recorder (`repro --obs ...`). Takes
    /// precedence over the session store's recorder; strictly
    /// observational either way.
    pub obs: Option<Arc<crate::obs::Recorder>>,
}

impl ExperimentRunner {
    pub fn new(threads: usize) -> ExperimentRunner {
        ExperimentRunner {
            threads,
            session: None,
            batch: BatchMode::default(),
            obs: None,
        }
    }

    /// Attach (or detach) a store session.
    pub fn with_session(mut self, session: Option<Arc<TraceStore>>)
                        -> ExperimentRunner {
        self.session = session;
        self
    }

    /// Attach (or detach) an explicit telemetry recorder.
    pub fn with_obs(mut self, obs: Option<Arc<crate::obs::Recorder>>)
                    -> ExperimentRunner {
        self.obs = obs;
        self
    }

    /// Set a fixed per-iteration candidate batch width.
    pub fn with_batch(self, batch: usize) -> ExperimentRunner {
        self.with_batch_mode(BatchMode::Fixed(batch))
    }

    /// Set the full batch sizing mode (`Fixed` or `Adaptive`).
    pub fn with_batch_mode(mut self, batch: BatchMode)
                           -> ExperimentRunner {
        self.batch = batch;
        self
    }

    /// The scheduling context every work item shares: the batch width
    /// plus — with a store session — the session's re-clustering memo
    /// and persisted profile cache. Both caches are pure memos, so the
    /// context never perturbs results (see [`crate::sched`]).
    fn sched_context(&self) -> SchedContext {
        let mut ctx = match &self.session {
            Some(store) => SchedContext {
                mode: self.batch,
                centroids: Some(store.session_centroids()),
                profiles: Some(store.profiles()),
                obs: store.recorder(),
                job: None,
            },
            None => SchedContext::with_mode(self.batch),
        };
        if self.obs.is_some() {
            ctx.obs = self.obs.clone();
        }
        ctx
    }

    /// Run every cell of the grid over every task of `suite`.
    ///
    /// The flattened (cell, task) item list is processed by
    /// `parallel_map`; each item rebuilds its engine/LLM substrate
    /// (both are cheap value types) and derives its RNG from the cell
    /// seed + method lineage, so the traces returned for a cell are
    /// bit-identical to `Method::run` on the same inputs — with or
    /// without a store session, cold or warm (cache keys embed the same
    /// seed lineages the substrates consume).
    ///
    /// Trace-log emission is sharded per cell and merged in canonical
    /// cell order (then task order) after the parallel fan-in, so the
    /// appended log bytes are invariant to `threads`.
    pub fn run(&self, suite: &Suite, cells: &[CellSpec]) -> Vec<CellResult> {
        let items: Vec<(usize, usize)> = (0..cells.len())
            .flat_map(|c| (0..suite.len()).map(move |t| (c, t)))
            .collect();
        let ctx = self.sched_context();
        // each item reports whether it performed any *new* simulated
        // work (false = fully replayed from cache)
        let traces = parallel_map(&items, self.threads, |_, &(c, t)| {
            let spec = &cells[c];
            let task = &suite.tasks[t];
            let root = Rng::new(spec.seed).split("method", spec.method.tag());
            // per-item causal anchor (`--obs events|trace`): each
            // (cell, task) item runs on its own trace track and stamps
            // ledger rows with its cell label; plain `--obs on` runs
            // skip all of this
            let mut ictx = ctx.clone();
            let ispan = match ictx.obs.clone().filter(|r| {
                r.trace().is_some() || r.decisions().is_some()
            }) {
                Some(r) => {
                    let track = crate::obs::trace::TRACK_JOBS
                        + (c * suite.len() + t) as u64;
                    let span = r.trace().map(|s| {
                        s.begin(
                            "repro.item",
                            0,
                            track,
                            Json::obj(vec![
                                ("cell", Json::str(spec.label.clone())),
                                ("task", Json::str(task.name.clone())),
                            ]),
                        )
                    });
                    ictx.job = Some(JobObs {
                        span: span.unwrap_or(0),
                        track,
                        label: Arc::from(
                            format!("{} {}", spec.label, task.name)
                                .as_str(),
                        ),
                    });
                    span
                }
                None => None,
            };
            let (trace, fresh) = match &self.session {
                None => {
                    let engine = SimEngine::new(spec.device);
                    let llm = SurrogateLlm::new(spec.llm);
                    let trace = spec.method.run_task_sched(
                        task, &engine, &llm, spec.iterations, &root,
                        None, &ictx,
                    );
                    (trace, true)
                }
                Some(store) => {
                    let engine = CachedEngine::new(
                        SimEngine::new(spec.device),
                        store.clone(),
                    );
                    let llm = CachedLlm::new(
                        SurrogateLlm::new(spec.llm),
                        store.clone(),
                    );
                    let trace = spec.method.run_task_sched(
                        task,
                        &engine,
                        &llm,
                        spec.iterations,
                        &root,
                        store.warm_for(
                            spec.device.name(),
                            spec.llm.spec().name,
                            &task.name,
                        ),
                        &ictx,
                    );
                    let new_work =
                        engine.local_sims() + llm.local_sims() > 0;
                    (trace, new_work)
                }
            };
            if let Some(r) = ictx.obs.as_ref().filter(|r| r.enabled()) {
                if let (Some(s), Some(id)) = (r.trace(), ispan) {
                    s.end(id);
                }
                let oracle = obs_regret::latent_oracle_latency_s(
                    task,
                    spec.device,
                );
                let (curve, exact) =
                    obs_regret::regret_curve(&trace, oracle);
                r.observe_regret(&curve, exact);
            }
            (trace, fresh)
        });
        let mut it = traces.into_iter();
        let results: Vec<(CellResult, Vec<bool>)> = cells
            .iter()
            .map(|spec| {
                let (cell_traces, new_work): (Vec<Trace>, Vec<bool>) =
                    it.by_ref().take(suite.len()).unzip();
                let agg = aggregate(&outcomes(&cell_traces));
                (
                    CellResult {
                        spec: spec.clone(),
                        traces: cell_traces,
                        aggregate: agg,
                    },
                    new_work,
                )
            })
            .collect();
        if let Some(store) = &self.session {
            // a fully-replayed (task, cell) trace contributes no new
            // history — appending it would only grow the log with
            // byte-identical duplicates on every overlapping rerun
            for (res, new_work) in &results {
                for (trace, &fresh) in res.traces.iter().zip(new_work) {
                    if fresh {
                        store.append_trace(records_for_trace(
                            &res.spec.label,
                            res.spec.device.name(),
                            res.spec.llm.spec().name,
                            res.spec.seed,
                            trace,
                        ));
                    }
                }
            }
        }
        results.into_iter().map(|(res, _)| res).collect()
    }
}

/// A fully-rendered experiment: the text table the CLI prints and the
/// JSON artifact it writes.
#[derive(Debug, Clone)]
pub struct ReproReport {
    /// Experiment name ("table1", "fig2", …).
    pub name: String,
    /// Rendered text table(s).
    pub text: String,
    /// Machine-readable result artifact.
    pub json: Json,
}

impl ReproReport {
    /// `BENCH_<name>.json` — the artifact filename convention consumed
    /// by downstream tooling and the CI smoke job.
    pub fn artifact_filename(&self) -> String {
        format!("BENCH_{}.json", self.name)
    }

    /// Write the pretty-printed artifact under `dir` (created if
    /// missing); returns the path written.
    pub fn write_artifact(&self, dir: &Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(self.artifact_filename());
        std::fs::write(&path, self.json.pretty() + "\n")?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PolicyMode;

    fn tiny_suite() -> Suite {
        let full = Suite::full(crate::eval::EXPERIMENT_SEED);
        Suite { tasks: full.tasks.into_iter().step_by(31).collect() }
    }

    #[test]
    fn runner_regroups_cells_in_order() {
        let suite = tiny_suite();
        let cells = vec![
            CellSpec::new(
                Method::BoN,
                Device::H20,
                LlmProfile::DeepSeekV32,
                4,
                3,
            ),
            CellSpec::new(
                Method::KernelBand(PolicyMode::Full, 3),
                Device::A100,
                LlmProfile::Gpt5,
                4,
                3,
            ),
        ];
        let results = ExperimentRunner::new(2).run(&suite, &cells);
        assert_eq!(results.len(), 2);
        for (res, spec) in results.iter().zip(&cells) {
            assert_eq!(res.spec.label, spec.label);
            assert_eq!(res.traces.len(), suite.len());
            assert_eq!(res.aggregate.tasks, suite.len());
        }
        assert_eq!(results[0].spec.device, Device::H20);
        assert_eq!(results[1].spec.device, Device::A100);
    }

    #[test]
    fn with_label_overrides_display_name() {
        let cell = CellSpec::new(
            Method::KernelBand(PolicyMode::NoProfiling, 3),
            Device::H20,
            LlmProfile::DeepSeekV32,
            2,
            1,
        )
        .with_label("w/o Profiling");
        assert_eq!(cell.label, "w/o Profiling");
        assert_eq!(cell.method, Method::KernelBand(PolicyMode::NoProfiling, 3));
    }

    #[test]
    fn batch_one_artifacts_match_default_runner() {
        let suite = tiny_suite();
        let cells = vec![CellSpec::new(
            Method::KernelBand(PolicyMode::Full, 3),
            Device::H20,
            LlmProfile::DeepSeekV32,
            6,
            3,
        )];
        let base = ExperimentRunner::new(2).run(&suite, &cells);
        let b1 =
            ExperimentRunner::new(2).with_batch(1).run(&suite, &cells);
        assert_eq!(
            experiment_json("unit", 6, 3, &base).dump(),
            experiment_json("unit", 6, 3, &b1).dump()
        );
    }

    #[test]
    fn batched_runner_is_thread_invariant() {
        let suite = tiny_suite();
        let cells = vec![
            CellSpec::new(
                Method::KernelBand(PolicyMode::Full, 3),
                Device::H20,
                LlmProfile::DeepSeekV32,
                8,
                3,
            ),
            CellSpec::new(
                Method::BoN,
                Device::A100,
                LlmProfile::DeepSeekV32,
                8,
                3,
            ),
        ];
        let t1 = ExperimentRunner::new(1).with_batch(3).run(&suite, &cells);
        let t4 = ExperimentRunner::new(4).with_batch(3).run(&suite, &cells);
        assert_eq!(
            experiment_json("unit", 8, 3, &t1).dump(),
            experiment_json("unit", 8, 3, &t4).dump()
        );
    }

    #[test]
    fn cell_json_has_schema_fields() {
        let suite = tiny_suite();
        let cells = vec![CellSpec::new(
            Method::Geak,
            Device::Rtx4090,
            LlmProfile::Gemini3Flash,
            3,
            9,
        )];
        let results = ExperimentRunner::new(1).run(&suite, &cells);
        let json = results[0].to_json();
        assert_eq!(json.str_field("device").unwrap(), "RTX 4090");
        assert_eq!(json.f64_field("iterations"), 3.0);
        let metrics = json.get("metrics").unwrap();
        assert_eq!(metrics.f64_field("tasks"), suite.len() as f64);
        let curve = json.get("curve").unwrap().as_arr().unwrap();
        assert_eq!(curve.len(), 3);
        let strata = json.get("strata").unwrap().as_arr().unwrap();
        assert_eq!(strata.len(), 4);
    }

    #[test]
    fn experiment_json_wraps_cells() {
        let suite = tiny_suite();
        let cells = vec![CellSpec::new(
            Method::BoN,
            Device::H20,
            LlmProfile::DeepSeekV32,
            2,
            5,
        )];
        let results = ExperimentRunner::new(0).run(&suite, &cells);
        let root = experiment_json("unit", 2, 5, &results);
        assert_eq!(root.str_field("experiment").unwrap(), "unit");
        assert_eq!(root.f64_field("schema_version"), 1.0);
        assert_eq!(root.get("cells").unwrap().as_arr().unwrap().len(), 1);
    }
}
