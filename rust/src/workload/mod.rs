//! TritonBench-G-like workload suite.
//!
//! The paper evaluates on a corrected TritonBench-G: 183 Triton kernels
//! across 13 functional categories and 5 difficulty levels, each
//! benchmarked over 10+ input shapes (paper §4.1, Appendix E/F). The real
//! benchmark only runs on NVIDIA GPUs, so this module synthesizes a suite
//! with the same *observable structure*: the exact category distribution
//! of Table 7, the difficulty profile of Table 1/Appendix E, per-shape
//! FLOP/byte workloads with category-appropriate arithmetic intensity,
//! and per-task latent optima that the optimization strategies move
//! candidates toward.
//!
//! Everything is generated deterministically from a seed; the 50-kernel
//! detailed-analysis subset uses stratified sampling with the paper's
//! seed (42) and reproduces the Table 7 subset counts exactly.


use crate::kernel::{KernelConfig, NUM_LAYOUTS, NUM_LOOP_ORDERS};
use crate::rng::Rng;

pub mod gen;

/// The 13 functional categories of TritonBench-G (Table 7 order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    Attention,
    MatMul,
    Normalization,
    LinearAttention,
    ElementWise,
    MemoryIndex,
    Other,
    EmbeddingRope,
    Softmax,
    FusedActivation,
    Quantization,
    LossFunctions,
    Reduction,
}

/// All categories in Table 7 order.
pub const ALL_CATEGORIES: [Category; 13] = [
    Category::Attention,
    Category::MatMul,
    Category::Normalization,
    Category::LinearAttention,
    Category::ElementWise,
    Category::MemoryIndex,
    Category::Other,
    Category::EmbeddingRope,
    Category::Softmax,
    Category::FusedActivation,
    Category::Quantization,
    Category::LossFunctions,
    Category::Reduction,
];

/// Full-benchmark category counts (Table 7, 184 kernels; one
/// Element-wise kernel — `sin_computation` — is excluded, giving 183).
pub const FULL_COUNTS: [usize; 13] = [29, 26, 18, 17, 16, 13, 12, 11, 11, 10, 8, 7, 6];

/// 50-kernel subset category counts (Table 7 right column).
pub const SUBSET_COUNTS: [usize; 13] = [7, 7, 4, 4, 3, 3, 3, 3, 4, 4, 2, 3, 3];

impl Category {
    pub fn name(self) -> &'static str {
        match self {
            Category::Attention => "Attention",
            Category::MatMul => "MatMul/GEMM",
            Category::Normalization => "Normalization",
            Category::LinearAttention => "Linear Attention/SSM",
            Category::ElementWise => "Element-wise Ops",
            Category::MemoryIndex => "Memory/Index Ops",
            Category::Other => "Other",
            Category::EmbeddingRope => "Embedding/RoPE",
            Category::Softmax => "Softmax",
            Category::FusedActivation => "Fused Ops/Activation",
            Category::Quantization => "Quantization",
            Category::LossFunctions => "Loss Functions",
            Category::Reduction => "Reduction",
        }
    }

    pub fn index(self) -> usize {
        ALL_CATEGORIES.iter().position(|&c| c == self).unwrap()
    }

    /// Typical arithmetic intensity (FLOPs per byte of minimal HBM
    /// traffic) — the category's position on the roofline.
    pub fn base_intensity(self) -> f64 {
        match self {
            Category::MatMul => 96.0,
            Category::Attention => 24.0,
            Category::LinearAttention => 8.0,
            Category::FusedActivation => 2.0,
            Category::Normalization => 1.6,
            Category::Softmax => 1.2,
            Category::LossFunctions => 1.0,
            Category::Quantization => 0.6,
            Category::Reduction => 0.5,
            Category::EmbeddingRope => 0.35,
            Category::ElementWise => 0.25,
            Category::Other => 0.8,
            Category::MemoryIndex => 0.08,
        }
    }

    /// How many epilogue/prologue ops can usefully be fused (latent cap
    /// for the FUSION strategy).
    pub fn max_fusion(self) -> u8 {
        match self {
            Category::ElementWise | Category::FusedActivation => 3,
            Category::Normalization | Category::Softmax
            | Category::LossFunctions | Category::EmbeddingRope => 2,
            Category::MatMul | Category::Attention
            | Category::LinearAttention | Category::Quantization => 1,
            Category::MemoryIndex | Category::Reduction | Category::Other => 1,
        }
    }

    /// Whether a native PyTorch operator exists (Appendix G's
    /// torch-comparable criterion).
    pub fn torch_comparable(self) -> bool {
        !matches!(
            self,
            Category::Quantization
                | Category::MemoryIndex
                | Category::LinearAttention
                | Category::Other
        )
    }
}

/// Difficulty levels L1 (easiest) – L5 (hardest).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Difficulty {
    L1,
    L2,
    L3,
    L4,
    L5,
}

pub const ALL_DIFFICULTIES: [Difficulty; 5] = [
    Difficulty::L1,
    Difficulty::L2,
    Difficulty::L3,
    Difficulty::L4,
    Difficulty::L5,
];

/// Full-suite difficulty counts. L1 = 3 and L5 = 5 are stated in the
/// Table 1 caption; L2–L4 are chosen to match the 27.2% subset ratio
/// against the subset's (1, 7, 18, 23, 1) split. Total = 183.
pub const FULL_DIFFICULTY_COUNTS: [usize; 5] = [3, 26, 66, 83, 5];

impl Difficulty {
    pub fn level(self) -> usize {
        match self {
            Difficulty::L1 => 1,
            Difficulty::L2 => 2,
            Difficulty::L3 => 3,
            Difficulty::L4 => 4,
            Difficulty::L5 => 5,
        }
    }

    pub fn from_level(l: usize) -> Difficulty {
        ALL_DIFFICULTIES[l - 1]
    }

    /// Multiplier on the surrogate LLM's failure probability — harder
    /// kernels are harder to transform correctly.
    pub fn hardness(self) -> f64 {
        match self {
            Difficulty::L1 => 0.55,
            Difficulty::L2 => 0.75,
            Difficulty::L3 => 1.0,
            Difficulty::L4 => 1.35,
            Difficulty::L5 => 1.7,
        }
    }
}

/// One benchmark input shape: the minimal work the kernel must do.
#[derive(Debug, Clone, Copy)]
pub struct ShapeSpec {
    /// Floating-point operations.
    pub flops: f64,
    /// Minimal HBM bytes moved by an un-fused implementation.
    pub bytes: f64,
    /// Resident working set (bytes) — drives L2 behaviour.
    pub working_set: f64,
}

/// Latent per-task structure: where the optima live and how much each
/// schedule dimension matters. The optimizer never sees these directly —
/// only latencies and counters.
#[derive(Debug, Clone, Copy)]
pub struct Latent {
    /// Best loop-order permutation id.
    pub best_loop_order: u8,
    /// Best layout id.
    pub best_layout: u8,
    /// Useful fusion depth cap (≤ category cap).
    pub max_fusion: u8,
    /// Fraction of HBM traffic removed at full fusion.
    pub fusion_saving: f64,
    /// Best vector-width index.
    pub best_vector: u8,
    /// Task-specific jitter (in index steps) applied to the
    /// device-optimal tile.
    pub tile_bias: i8,
    /// Sensitivity weights in [0,1] for (tiling, vector, fusion,
    /// pipeline, reorder, layout) — how much a wrong setting hurts.
    pub sensitivity: [f64; 6],
}

/// One kernel-optimization task.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub id: usize,
    pub name: String,
    pub category: Category,
    pub difficulty: Difficulty,
    pub shapes: Vec<ShapeSpec>,
    pub latent: Latent,
    /// Appendix G: does a native PyTorch op exist for this task?
    pub torch_comparable: bool,
    /// Grammar lineage hash for generated tasks ([`gen::Grammar`]);
    /// `0` for the hand-built suite. Nonzero lineage folds into
    /// [`TaskSpec::fingerprint`], so stores, warm-start and centroid
    /// memos never alias tasks across grammars or expansion seeds —
    /// while hand-built fingerprints stay byte-identical to every
    /// pre-grammar store on disk.
    pub lineage: u64,
}

impl TaskSpec {
    /// The reference implementation every optimization starts from.
    pub fn naive_config(&self) -> KernelConfig {
        KernelConfig::naive()
    }

    /// Total FLOPs across benchmark shapes.
    pub fn total_flops(&self) -> f64 {
        self.shapes.iter().map(|s| s.flops).sum()
    }

    /// Stable content fingerprint of the task — everything that affects
    /// a measurement except the schedule itself. Two suite generations
    /// that produce the same task (same generator seed) fingerprint
    /// identically, which is what lets the persistent kernel store
    /// ([`crate::store`]) recognize work across sessions.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::hash::KeyHasher::new("task")
            .u64(self.id as u64)
            .str(&self.name)
            .u64(self.category.index() as u64)
            .u64(self.difficulty.level() as u64);
        for s in &self.shapes {
            h = h.f64(s.flops).f64(s.bytes).f64(s.working_set);
        }
        // the latent optimum drives every simulated measurement: a
        // regenerated suite with retuned latents must never be served
        // stale cached results
        let l = &self.latent;
        h = h
            .u64(l.best_loop_order as u64)
            .u64(l.best_layout as u64)
            .u64(l.max_fusion as u64)
            .f64(l.fusion_saving)
            .u64(l.best_vector as u64)
            .u64(l.tile_bias as u64)
            .f64(l.sensitivity[0])
            .f64(l.sensitivity[1])
            .f64(l.sensitivity[2])
            .f64(l.sensitivity[3])
            .f64(l.sensitivity[4])
            .f64(l.sensitivity[5]);
        // conditional fold: legacy (lineage 0) fingerprints must not
        // move, or every existing store goes cold
        if self.lineage != 0 {
            h = h.u64(self.lineage);
        }
        h.finish()
    }
}

/// A generated benchmark suite.
#[derive(Debug, Clone)]
pub struct Suite {
    pub tasks: Vec<TaskSpec>,
}

fn gen_latent(cat: Category, diff: Difficulty, rng: &mut Rng) -> Latent {
    let mem_bound = cat.base_intensity() < 4.0;
    // Memory-bound kernels want wide vectors; compute-bound moderate.
    let best_vector = if mem_bound {
        2 + rng.below(2) as u8 // 4 or 8 lanes
    } else {
        1 + rng.below(2) as u8 // 2 or 4 lanes
    };
    let max_fusion = cat.max_fusion().min(1 + rng.below(3) as u8);
    let fusion_saving = if mem_bound {
        rng.uniform_in(0.2, 0.45)
    } else {
        rng.uniform_in(0.05, 0.2)
    };
    // Harder kernels are sensitive in more dimensions.
    let base = 0.2 + 0.12 * (diff.level() as f64 - 1.0);
    let mut sensitivity = [0.0f64; 6];
    for s in sensitivity.iter_mut() {
        *s = (base + rng.uniform_in(-0.12, 0.28)).clamp(0.05, 0.85);
    }
    // Category emphasis: GEMM/attention are tiling-heavy, element-wise is
    // vector/layout-heavy, fused-ops fusion-heavy.
    match cat {
        Category::MatMul | Category::Attention | Category::LinearAttention => {
            sensitivity[0] = (sensitivity[0] + 0.45).min(1.0);
            sensitivity[3] = (sensitivity[3] + 0.2).min(1.0);
        }
        Category::ElementWise | Category::MemoryIndex | Category::EmbeddingRope => {
            sensitivity[1] = (sensitivity[1] + 0.4).min(1.0);
            sensitivity[5] = (sensitivity[5] + 0.3).min(1.0);
        }
        Category::FusedActivation | Category::Normalization | Category::Softmax => {
            sensitivity[2] = (sensitivity[2] + 0.4).min(1.0);
        }
        _ => {}
    }
    Latent {
        best_loop_order: rng.below(NUM_LOOP_ORDERS as u64) as u8,
        best_layout: rng.below(NUM_LAYOUTS as u64) as u8,
        max_fusion,
        fusion_saving,
        best_vector,
        tile_bias: rng.below(3) as i8 - 1,
        sensitivity,
    }
}

fn gen_shapes(cat: Category, diff: Difficulty, rng: &mut Rng) -> Vec<ShapeSpec> {
    let n_shapes = 10 + rng.below(5) as usize; // "10+ input shapes"
    // Base problem scale: harder levels tend to be larger/fused problems.
    let scale = 2.0f64.powf(diff.level() as f64 - 1.0);
    let intensity = cat.base_intensity();
    (0..n_shapes)
        .map(|_| {
            // Shape sizes span ~2 orders of magnitude so the
            // runtime-weighted aggregation (Appendix H) is non-trivial.
            let size = rng.uniform_in(0.5, 64.0) * scale * 1.0e6; // bytes
            let bytes = size;
            let flops = bytes * intensity * rng.uniform_in(0.7, 1.4);
            let working_set = bytes * rng.uniform_in(0.1, 0.9);
            ShapeSpec { flops, bytes, working_set }
        })
        .collect()
}

impl Suite {
    /// The full 183-kernel suite (deterministic in `seed`).
    pub fn full(seed: u64) -> Suite {
        let root = Rng::new(seed);
        // Interleave categories and difficulties deterministically so the
        // joint distribution matches both marginals.
        let mut cats: Vec<Category> = Vec::new();
        for (ci, &n) in FULL_COUNTS.iter().enumerate() {
            let n = if ALL_CATEGORIES[ci] == Category::ElementWise {
                n - 1 // sin_computation excluded (paper §4.1)
            } else {
                n
            };
            cats.extend(std::iter::repeat(ALL_CATEGORIES[ci]).take(n));
        }
        let mut diffs: Vec<Difficulty> = Vec::new();
        for (di, &n) in FULL_DIFFICULTY_COUNTS.iter().enumerate() {
            diffs.extend(std::iter::repeat(ALL_DIFFICULTIES[di]).take(n));
        }
        assert_eq!(cats.len(), 183);
        assert_eq!(diffs.len(), 183);
        let mut shuffle_rng = root.split("assign", 0);
        shuffle_rng.shuffle(&mut diffs);

        let tasks = cats
            .into_iter()
            .zip(diffs)
            .enumerate()
            .map(|(id, (category, difficulty))| {
                let mut trng = root.split("task", id as u64);
                let per_cat_idx = id; // unique suffix
                TaskSpec {
                    id,
                    name: format!(
                        "{}_{:03}",
                        category.name().to_ascii_lowercase().replace(['/', ' ', '-'], "_"),
                        per_cat_idx
                    ),
                    category,
                    difficulty,
                    shapes: gen_shapes(category, difficulty, &mut trng),
                    latent: gen_latent(category, difficulty, &mut trng),
                    torch_comparable: category.torch_comparable()
                        && difficulty < Difficulty::L5,
                    lineage: 0,
                }
            })
            .collect();
        Suite { tasks }
    }

    /// Expand a grammar workload spec ([`gen::GrammarSpec`]) into a
    /// suite. Deterministic in `(grammar, seed)`; fails only for a
    /// name missing from the registry (CLI parsing already validates).
    pub fn from_grammar(spec: &gen::GrammarSpec) -> Result<Suite, String> {
        let g = spec.grammar()?;
        Ok(Suite { tasks: g.expand(spec.seed) })
    }

    /// The 50-kernel detailed-analysis subset: stratified by category with
    /// the exact Table 7 subset counts, sampled with the paper's seed.
    pub fn subset50(&self) -> Suite {
        let mut rng = Rng::new(42).split("subset", 0);
        let mut tasks = Vec::with_capacity(50);
        for (ci, &want) in SUBSET_COUNTS.iter().enumerate() {
            let cat = ALL_CATEGORIES[ci];
            let pool: Vec<&TaskSpec> = self
                .tasks
                .iter()
                .filter(|t| t.category == cat)
                .collect();
            let picks = rng.sample_indices(pool.len(), want);
            for p in picks {
                tasks.push(pool[p].clone());
            }
        }
        tasks.sort_by_key(|t| t.id);
        Suite { tasks }
    }

    /// The 30-kernel PyTorch-comparable subset of the 50 (Appendix G).
    pub fn torch_subset(&self) -> Suite {
        let mut tasks: Vec<TaskSpec> = self
            .tasks
            .iter()
            .filter(|t| t.torch_comparable)
            .cloned()
            .collect();
        tasks.truncate(30);
        Suite { tasks }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Count per category (diagnostics / tests).
    pub fn category_counts(&self) -> [usize; 13] {
        let mut counts = [0usize; 13];
        for t in &self.tasks {
            counts[t.category.index()] += 1;
        }
        counts
    }

    /// Count per difficulty level.
    pub fn difficulty_counts(&self) -> [usize; 5] {
        let mut counts = [0usize; 5];
        for t in &self.tasks {
            counts[t.difficulty.level() - 1] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_suite_matches_table7() {
        let suite = Suite::full(1);
        assert_eq!(suite.len(), 183);
        let counts = suite.category_counts();
        // Element-wise is one short of Table 7's 16 (sin_computation).
        let mut expected = FULL_COUNTS;
        expected[Category::ElementWise.index()] -= 1;
        assert_eq!(counts, expected);
    }

    #[test]
    fn full_suite_difficulty_totals() {
        let suite = Suite::full(1);
        assert_eq!(suite.difficulty_counts(), FULL_DIFFICULTY_COUNTS);
    }

    #[test]
    fn suite_is_deterministic() {
        let a = Suite::full(1);
        let b = Suite::full(1);
        for (ta, tb) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(ta.name, tb.name);
            assert_eq!(ta.shapes.len(), tb.shapes.len());
            assert!((ta.shapes[0].flops - tb.shapes[0].flops).abs() < 1e-9);
        }
        let c = Suite::full(2);
        assert!(a
            .tasks
            .iter()
            .zip(&c.tasks)
            .any(|(x, y)| (x.shapes[0].flops - y.shapes[0].flops).abs() > 1.0));
    }

    #[test]
    fn subset50_matches_table7_subset() {
        let suite = Suite::full(1);
        let sub = suite.subset50();
        assert_eq!(sub.len(), 50);
        assert_eq!(sub.category_counts(), SUBSET_COUNTS);
        // stratified sampling is deterministic
        let sub2 = suite.subset50();
        let ids: Vec<_> = sub.tasks.iter().map(|t| t.id).collect();
        let ids2: Vec<_> = sub2.tasks.iter().map(|t| t.id).collect();
        assert_eq!(ids, ids2);
    }

    #[test]
    fn torch_subset_is_30_and_comparable() {
        let sub = Suite::full(1).subset50().torch_subset();
        assert!(sub.len() <= 30);
        assert!(sub.len() >= 25, "len={}", sub.len());
        assert!(sub.tasks.iter().all(|t| t.torch_comparable));
    }

    #[test]
    fn shapes_have_ten_plus_entries_and_positive_work() {
        let suite = Suite::full(1);
        for t in &suite.tasks {
            assert!(t.shapes.len() >= 10, "{}", t.name);
            for s in &t.shapes {
                assert!(s.flops > 0.0 && s.bytes > 0.0 && s.working_set > 0.0);
            }
        }
    }

    #[test]
    fn latents_are_legal() {
        let suite = Suite::full(3);
        for t in &suite.tasks {
            let l = &t.latent;
            assert!((l.best_loop_order as u32) < NUM_LOOP_ORDERS);
            assert!((l.best_layout as u32) < NUM_LAYOUTS);
            assert!(l.max_fusion <= t.category.max_fusion());
            assert!((0.0..=0.6).contains(&l.fusion_saving));
            assert!(l.sensitivity.iter().all(|&s| (0.0..=1.0).contains(&s)));
        }
    }

    #[test]
    fn gemm_is_compute_intense_elementwise_is_not() {
        assert!(Category::MatMul.base_intensity() > 50.0);
        assert!(Category::ElementWise.base_intensity() < 1.0);
    }

    #[test]
    fn category_name_roundtrip_unique() {
        let names: std::collections::HashSet<_> =
            ALL_CATEGORIES.iter().map(|c| c.name()).collect();
        assert_eq!(names.len(), 13);
    }
}
