//! Differential conformance harness for generated workload spaces.
//!
//! Every task a grammar expands to is run through the simulated engine
//! and checked against three model-level invariants; a fourth leg
//! attempts the PJRT runtime and skips cleanly (typed
//! [`XlaError::Unavailable`]) when the real backend is absent.
//!
//! ## 1. Pruning-bound admissibility (Assumption 1)
//!
//! For the naive parent and any strategy `s`,
//! `latency_bound(naive, h(naive), s)` must not exceed
//! `prune_factor × oracle` — otherwise speculative batch admission
//! could prune the latent optimum itself. For generated spaces this is
//! *provable* from the roofline model (noiseless):
//!
//! - the bound equals the parent's at-peak work time for the targeted
//!   resource: `Σ term_at_peak = total · pct/100` by construction of
//!   the counters;
//! - SM target: `Σ flops/peak ≤ EFF_CAP · Σ t_comp(oracle) ≤ 0.95 ·
//!   oracle`;
//! - DRAM target: the naive config fuses nothing, so its at-peak DRAM
//!   time is `Σ bytes/bw`; the oracle moves at least
//!   `(1 − MAX_FUSION_SAVING)` of those bytes at efficiency ≤ 0.95,
//!   so `bound/oracle ≤ 0.95/0.72 ≈ 1.32 < 1.5`;
//! - L2 target: naive L2 amplification is ≤ 1.1 + 0.5·(1−eff) +
//!   0.25·2 ≤ 2.1 and `l2_bw ≥ 3 × dram_bw`, so the L2 bound is under
//!   `0.7 · Σ bytes/bw` — below the oracle's own DRAM floor;
//! - the 5% `BOUND_FLOOR` case needs `naive ≤ 30 × oracle`, and the
//!   capped sensitivities ([`MAX_SENSITIVITY`]) keep the worst
//!   naive/oracle ratio under ~10×.
//!
//! The caps ([`MAX_FUSION_SAVING`], [`MAX_SENSITIVITY`]) are what make
//! this hold; `Suite::full`'s hand-built latents (fusion saving to
//! 0.45) do *not* satisfy it, which is why the harness runs on
//! generated spaces only.
//!
//! ## 2. Monotone FLOP/byte scaling
//!
//! Generated sweeps hold intensity and working-set fraction constant
//! per task, so bytes, FLOPs and working set are strictly increasing
//! across the sweep and every roofline term is monotone: per-shape
//! noiseless latency must be non-decreasing for any config.
//!
//! ## 3. batch=1 ≡ batch=N bit-identity
//!
//! `GpuSim::evaluate_batch` must be bit-identical to standalone
//! `evaluate` calls, per candidate, including the noise stream.
//!
//! (4. Cold/warm byte-identity per generated space is an end-to-end
//! store property and lives in `rust/tests/conformance.rs`.)

use crate::gpu_model::{Device, GpuSim, ALL_DEVICES};
use crate::policy::PolicyConfig;
use crate::profiler::HardwareSignature;
use crate::rng::Rng;
use crate::sched::batch::latency_bound;
use crate::strategy::ALL_STRATEGIES;
use crate::workload::{Suite, TaskSpec};

use super::{MAX_FUSION_SAVING, MAX_SENSITIVITY};

/// One failed conformance check.
#[derive(Debug, Clone)]
pub struct Violation {
    pub task: String,
    pub device: &'static str,
    pub check: &'static str,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {} on {}: {}", self.check, self.task,
               self.device, self.detail)
    }
}

/// Conformance outcome for one suite × device-set run.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// Tasks examined (once per device).
    pub tasks: usize,
    /// Individual assertions evaluated.
    pub checks: usize,
    pub violations: Vec<Violation>,
}

impl Report {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Run every conformance check on every task of `suite` across all
/// simulated devices.
pub fn check_suite(suite: &Suite) -> Report {
    let mut report = Report::default();
    for device in ALL_DEVICES {
        for task in &suite.tasks {
            report.tasks += 1;
            check_task(task, device, &mut report);
        }
    }
    report
}

fn violation(report: &mut Report, task: &TaskSpec, device: Device,
             check: &'static str, detail: String) {
    report.violations.push(Violation {
        task: task.name.clone(),
        device: device.name(),
        check,
        detail,
    });
}

/// All checks for one `(task, device)` pair.
pub fn check_task(task: &TaskSpec, device: Device, report: &mut Report) {
    let sim = GpuSim::noiseless(device);
    let mut rng = Rng::new(0);
    let naive = sim.evaluate(task, &task.naive_config(), &mut rng);
    let oracle_cfg = sim.oracle_config(task);
    let oracle = sim.evaluate(task, &oracle_cfg, &mut rng);
    let prune_factor = PolicyConfig::default().prune_factor;

    // 1. admissibility: no strategy's bound on the naive parent may
    // exclude the latent optimum from the frontier
    let sig = HardwareSignature::from_counters(&naive.counters);
    let strategies =
        ALL_STRATEGIES.iter().map(|&s| Some(s)).chain([None]);
    for strategy in strategies {
        report.checks += 1;
        let bound = latency_bound(naive.total_latency_s, &sig, strategy);
        if bound > prune_factor * oracle.total_latency_s {
            violation(report, task, device, "admissibility", format!(
                "bound {:.3e}s for {:?} exceeds {} x oracle {:.3e}s \
                 (latents: fusion_saving {:.3} <= {MAX_FUSION_SAVING}, \
                 sensitivity cap {MAX_SENSITIVITY})",
                bound, strategy, prune_factor, oracle.total_latency_s,
                task.latent.fusion_saving,
            ));
        }
    }

    // 2. monotone FLOP/byte scaling across the sweep, and latency
    // monotone with it for both endpoints of the config space
    report.checks += 1;
    for (i, w) in task.shapes.windows(2).enumerate() {
        if w[1].flops <= w[0].flops || w[1].bytes <= w[0].bytes {
            violation(report, task, device, "monotone-sweep", format!(
                "shape {} -> {}: flops/bytes not strictly increasing",
                i, i + 1,
            ));
        }
    }
    for (label, m) in [("naive", &naive), ("oracle", &oracle)] {
        for (i, w) in m.per_shape_s.windows(2).enumerate() {
            if w[1] < w[0] {
                violation(report, task, device, "monotone-sweep", format!(
                    "{label} latency decreases {:.3e} -> {:.3e} at shape {}",
                    w[0], w[1], i + 1,
                ));
            }
        }
    }

    // 3. batched measurement is bit-identical to serial measurement,
    // noise stream included
    report.checks += 1;
    let noisy = GpuSim::new(device);
    let mid = crate::kernel::KernelConfig {
        tile_m: 3,
        tile_n: 3,
        tile_k: 1,
        vector: 2,
        fusion: 1,
        pipeline: 1,
        loop_order: 2,
        layout: 1,
    }
    .clamped();
    let wide = crate::kernel::KernelConfig {
        tile_m: 5,
        tile_n: 2,
        tile_k: 2,
        vector: 3,
        fusion: task.latent.max_fusion,
        pipeline: 3,
        loop_order: 5,
        layout: 3,
    }
    .clamped();
    let cfgs = [task.naive_config(), oracle_cfg, mid, wide];
    let base = Rng::new(33);
    let mut batch_rngs: Vec<Rng> = (0..cfgs.len() as u64)
        .map(|i| base.split("cand", i))
        .collect();
    let batched = noisy.evaluate_batch(task, &cfgs, &mut batch_rngs);
    for (i, cfg) in cfgs.iter().enumerate() {
        let mut serial_rng = base.split("cand", i as u64);
        let serial = noisy.evaluate(task, cfg, &mut serial_rng);
        let same = serial.total_latency_s.to_bits()
            == batched[i].total_latency_s.to_bits()
            && serial.per_shape_s.len() == batched[i].per_shape_s.len()
            && serial
                .per_shape_s
                .iter()
                .zip(batched[i].per_shape_s.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits())
            && serial.counters == batched[i].counters;
        if !same {
            violation(report, task, device, "batch-identity", format!(
                "candidate {i}: evaluate_batch diverges from evaluate \
                 ({:.17e} vs {:.17e})",
                batched[i].total_latency_s, serial.total_latency_s,
            ));
        }
    }
}

/// Outcome of the feature-flagged PJRT leg.
#[derive(Debug, Clone)]
pub enum PjrtLeg {
    /// The runtime reported a typed `Unavailable` — the leg is skipped
    /// cleanly (default build, or `pjrt` feature without vendored
    /// bindings).
    Skipped(String),
    /// A real PJRT client came up; generated tasks were driven through
    /// it.
    Ran,
    /// The backend claimed availability but failed — a conformance
    /// failure, not a skip.
    Failed(String),
}

/// Attempt the PJRT leg for a generated space: bring up a CPU client
/// and, when one exists, drive each generated task's reference
/// computation through it. With the stub runtime this returns
/// [`PjrtLeg::Skipped`] via the typed error — never a panic.
pub fn pjrt_leg(_suite: &Suite) -> PjrtLeg {
    use crate::runtime::xla;
    match xla::PjRtClient::cpu() {
        Ok(_client) => PjrtLeg::Ran,
        Err(e) if e.is_unavailable() => PjrtLeg::Skipped(e.to_string()),
        Err(e) => PjrtLeg::Failed(e.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen;

    #[test]
    fn raggedmix_space_is_conformant() {
        let suite = Suite {
            tasks: gen::grammar("raggedmix").unwrap().expand(7),
        };
        let report = check_suite(&suite);
        assert_eq!(report.tasks, 84 * ALL_DEVICES.len());
        for v in &report.violations {
            eprintln!("{v}");
        }
        assert!(report.ok(), "{} violations", report.violations.len());
    }

    #[test]
    fn pjrt_leg_skips_cleanly_without_backend() {
        let suite = Suite {
            tasks: gen::grammar("raggedmix").unwrap().expand(7),
        };
        match pjrt_leg(&suite) {
            PjrtLeg::Skipped(msg) => {
                assert!(msg.contains("PJRT backend unavailable"), "{msg}");
            }
            PjrtLeg::Ran => {}
            PjrtLeg::Failed(msg) => panic!("pjrt leg failed: {msg}"),
        }
    }
}
