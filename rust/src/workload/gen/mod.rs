//! Enumerative workload grammar DSL.
//!
//! A *grammar* is a small product-space description — op families ×
//! fused-op depth × dtype × scale level — that deterministically expands
//! into a [`TaskSpec`] space of hundreds of tasks (in the spirit of
//! ruler's `enumo`: tiny grammars enumerated into big benchmark spaces
//! that double as property-test universes). Every generated task carries
//! derived latent optima and arithmetic intensity consistent with the
//! hand-built suite's category model, so the bandit loop, clustering and
//! pruning bounds all behave as they do on the Table-7 suite — just over
//! a much larger, structured space.
//!
//! Determinism contract: `expand(seed)` is a pure function of
//! `(grammar, seed)`. Task ordering is the fixed enumeration order
//! (op, fusion depth, dtype, scale); per-task randomness comes from
//! `Rng::new(seed).split("gtask", index)`, so the task list is
//! byte-identical across processes and thread counts, and disjoint
//! seeds produce disjoint task fingerprints (the grammar lineage hash
//! folds the seed into every fingerprint).
//!
//! Conformance caps: unlike `Suite::full`'s latents (fusion saving up
//! to 0.45), generated latents are capped so the Assumption-1 pruning
//! bound provably never prunes the latent optimum on any device — see
//! [`conformance`] for the derivation. The caps are part of the
//! grammar contract, asserted by `rust/tests/prop_workload.rs`.

use crate::rng::Rng;
use crate::util::hash::KeyHasher;
use crate::util::json::Json;
use crate::workload::{Category, Difficulty, Latent, ShapeSpec, Suite, TaskSpec};

pub mod conformance;

/// Upper cap on generated `Latent::fusion_saving`. The Assumption-1
/// DRAM bound for the naive parent is `Σ bytes / dram_bw`; the oracle
/// runs no faster than `Σ bytes · (1 − fusion_saving) / (dram_bw ·
/// EFF_CAP)`, so the bound/oracle ratio is at most
/// `EFF_CAP / (1 − MAX_FUSION_SAVING)` = 0.95 / 0.72 ≈ 1.32 < 1.5
/// (the default prune factor). See `conformance` module docs.
pub const MAX_FUSION_SAVING: f64 = 0.28;

/// Upper cap on generated per-dimension `Latent::sensitivity`. Bounds
/// how far the naive config can fall behind the oracle, which keeps
/// the 5% `BOUND_FLOOR` case of the pruning bound admissible
/// (naive/oracle stays well under 30×).
pub const MAX_SENSITIVITY: f64 = 0.90;

/// Default grammar expansion seed (matches the serve default job seed).
pub const DEFAULT_SEED: u64 = 7;

/// Benchmark-sweep length per generated task (matches the hand-built
/// suite's "10+ input shapes per kernel").
pub const SWEEP_LEN: usize = 12;

/// Numeric format axis of a grammar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F16,
    I8,
}

impl Dtype {
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F16 => "f16",
            Dtype::I8 => "i8",
        }
    }

    /// Bytes per element.
    pub fn bytes(self) -> f64 {
        match self {
            Dtype::F32 => 4.0,
            Dtype::F16 => 2.0,
            Dtype::I8 => 1.0,
        }
    }

    /// Arithmetic-intensity multiplier relative to f32: narrower
    /// elements mean more ops per byte of HBM traffic at equal work.
    pub fn intensity_factor(self) -> f64 {
        match self {
            Dtype::F32 => 1.0,
            Dtype::F16 => 1.75,
            Dtype::I8 => 2.5,
        }
    }

    /// Quantized formats have no native torch reference op in the
    /// Appendix-G sense.
    pub fn torch_comparable(self) -> bool {
        !matches!(self, Dtype::I8)
    }
}

/// One op-family production rule of a grammar. The fused-op axis it
/// induces is `0..=category.max_fusion()` epilogue depths.
#[derive(Debug, Clone, Copy)]
pub struct OpRule {
    /// Short label used in generated task names.
    pub label: &'static str,
    pub category: Category,
}

const fn op(label: &'static str, category: Category) -> OpRule {
    OpRule { label, category }
}

/// An enumerative task-space grammar.
#[derive(Debug, Clone, Copy)]
pub struct Grammar {
    pub name: &'static str,
    /// One-line description for `kernelband workload list`.
    pub about: &'static str,
    pub ops: &'static [OpRule],
    pub dtypes: &'static [Dtype],
    /// Power-of-two scale levels (doubling the base problem size).
    pub scales: usize,
    /// Whether the shape sweep interleaves ragged (non-power-of-two)
    /// dims between the power-of-two steps.
    pub ragged: bool,
}

/// `pow2sweep`: every dense-compute family over pure power-of-two
/// shape sweeps, 3 dtypes × 4 scale levels.
/// Cardinality: Σ(max_fusion+1) = 27 ops·depths × 3 × 4 = **324**.
const POW2SWEEP_OPS: [OpRule; 10] = [
    op("matmul", Category::MatMul),
    op("attention", Category::Attention),
    op("elementwise", Category::ElementWise),
    op("softmax", Category::Softmax),
    op("layernorm", Category::Normalization),
    op("fusedact", Category::FusedActivation),
    op("reduce", Category::Reduction),
    op("gather", Category::MemoryIndex),
    op("quant", Category::Quantization),
    op("rope", Category::EmbeddingRope),
];

/// `raggedmix`: memory-bound families over ragged shape sweeps
/// (non-power-of-two dims interleaved), 2 dtypes × 3 scale levels.
/// Cardinality: Σ(max_fusion+1) = 14 ops·depths × 2 × 3 = **84**.
const RAGGEDMIX_OPS: [OpRule; 5] = [
    op("elementwise", Category::ElementWise),
    op("gather", Category::MemoryIndex),
    op("rope", Category::EmbeddingRope),
    op("reduce", Category::Reduction),
    op("softmax", Category::Softmax),
];

const POW2SWEEP: Grammar = Grammar {
    name: "pow2sweep",
    about: "dense families, power-of-two sweeps, f32/f16/i8 x 4 scales (324 tasks)",
    ops: &POW2SWEEP_OPS,
    dtypes: &[Dtype::F32, Dtype::F16, Dtype::I8],
    scales: 4,
    ragged: false,
};

const RAGGEDMIX: Grammar = Grammar {
    name: "raggedmix",
    about: "memory-bound families, ragged sweeps, f32/f16 x 3 scales (84 tasks)",
    ops: &RAGGEDMIX_OPS,
    dtypes: &[Dtype::F32, Dtype::F16],
    scales: 3,
    ragged: true,
};

/// The grammar registry, in `workload list` order.
pub const GRAMMARS: [&Grammar; 2] = [&POW2SWEEP, &RAGGEDMIX];

/// Look up a grammar by name.
pub fn grammar(name: &str) -> Option<&'static Grammar> {
    GRAMMARS.iter().copied().find(|g| g.name == name)
}

/// Comma-separated registry names (error messages, usage).
pub fn grammar_names() -> String {
    let names: Vec<&str> = GRAMMARS.iter().map(|g| g.name).collect();
    names.join(", ")
}

impl Grammar {
    /// Number of tasks `expand` produces, computed from the grammar's
    /// axes alone (never from the expansion itself) — property tests
    /// assert the expansion matches, so truncation can't hide.
    pub fn cardinality(&self) -> usize {
        let depth_sum: usize = self
            .ops
            .iter()
            .map(|o| o.category.max_fusion() as usize + 1)
            .sum();
        depth_sum * self.dtypes.len() * self.scales
    }

    /// Stable lineage hash of `(grammar, seed)` — folded into every
    /// generated task's fingerprint so stores, warm-start and centroid
    /// memos never confuse spaces across grammars or seeds.
    pub fn lineage(&self, seed: u64) -> u64 {
        KeyHasher::new("grammar").str(self.name).u64(seed).finish()
    }

    /// Deterministically expand the grammar into its task space.
    pub fn expand(&self, seed: u64) -> Vec<TaskSpec> {
        let root = Rng::new(seed);
        let lineage = self.lineage(seed);
        let mut tasks = Vec::with_capacity(self.cardinality());
        for op in self.ops {
            for depth in 0..=op.category.max_fusion() {
                for &dtype in self.dtypes {
                    for scale in 0..self.scales {
                        let idx = tasks.len();
                        let mut rng = root.split("gtask", idx as u64);
                        tasks.push(self.gen_task(
                            idx, seed, lineage, *op, depth, dtype, scale,
                            &mut rng,
                        ));
                    }
                }
            }
        }
        debug_assert_eq!(tasks.len(), self.cardinality());
        tasks
    }

    #[allow(clippy::too_many_arguments)]
    fn gen_task(&self, idx: usize, seed: u64, lineage: u64, op: OpRule,
                depth: u8, dtype: Dtype, scale: usize, rng: &mut Rng)
                -> TaskSpec {
        let cat = op.category;
        // deeper scale levels are harder kernels; L2..L5 mirrors the
        // hand-built suite's mass sitting in the middle difficulties
        let difficulty = Difficulty::from_level((2 + scale).min(5));
        let shapes = self.gen_shapes(cat, dtype, scale, rng);
        let latent = gen_latent(cat, difficulty, depth, rng);
        let name = format!(
            "g_{}_s{}_{}_{}_f{}_x{}_{:04}",
            self.name, seed, op.label, dtype.name(), depth, scale, idx
        );
        TaskSpec {
            id: idx,
            name,
            category: cat,
            difficulty,
            shapes,
            latent,
            torch_comparable: cat.torch_comparable()
                && dtype.torch_comparable()
                && difficulty < Difficulty::L5,
            lineage,
        }
    }

    /// A strictly size-increasing benchmark sweep. Per-task arithmetic
    /// intensity and working-set fraction are *constant across the
    /// sweep* — so FLOPs, bytes and working set all scale strictly
    /// monotonically with shape index, and every roofline term of the
    /// simulated engine is monotone in them. That is the invariant the
    /// conformance harness' monotonicity check rests on.
    fn gen_shapes(&self, cat: Category, dtype: Dtype, scale: usize,
                  rng: &mut Rng) -> Vec<ShapeSpec> {
        let intensity =
            cat.base_intensity() * dtype.intensity_factor()
                * rng.uniform_in(0.8, 1.25);
        let ws_frac = rng.uniform_in(0.15, 0.85);
        // base problem size: 2^14 elements at scale 0, doubling per
        // scale level — sweeps span ~64 KB to ~1 GB of HBM traffic
        let base_elems = (1u64 << (14 + scale)) as f64;
        let mut shapes = Vec::with_capacity(SWEEP_LEN);
        for j in 0..SWEEP_LEN {
            let elems = if self.ragged {
                // pairs (2^k, 2^k * r) with r in (1.1, 1.9): ragged
                // dims interleave the doublings, still strictly
                // increasing because r < 2
                let pow2 = base_elems * (1u64 << (j / 2)) as f64;
                if j % 2 == 1 {
                    pow2 * rng.uniform_in(1.1, 1.9)
                } else {
                    pow2
                }
            } else {
                base_elems * (1u64 << j) as f64
            };
            let bytes = elems * dtype.bytes();
            shapes.push(ShapeSpec {
                flops: bytes * intensity,
                bytes,
                working_set: bytes * ws_frac,
            });
        }
        shapes
    }
}

/// Latent optimum for a generated task. Mirrors the hand-built suite's
/// `gen_latent` shape but with the conformance caps applied:
/// `fusion_saving <= MAX_FUSION_SAVING`, every sensitivity
/// `<= MAX_SENSITIVITY`, and `max_fusion` equal to the grammar's
/// fused-op depth axis (not a random redraw), so the fusion axis is
/// observable in the task's optimal schedule.
fn gen_latent(cat: Category, difficulty: Difficulty, depth: u8,
              rng: &mut Rng) -> Latent {
    let mem_bound = cat.base_intensity() < 4.0;
    let best_vector = if mem_bound {
        2 + rng.below(2) as u8
    } else {
        1 + rng.below(2) as u8
    };
    let fusion_saving = if depth == 0 {
        0.0
    } else {
        rng.uniform_in(0.08, MAX_FUSION_SAVING)
    };
    let level = difficulty.level();
    let base = 0.15 + 0.12 * (level as f64 - 1.0);
    let mut sensitivity = [0.0; 6];
    for s in sensitivity.iter_mut() {
        *s = (base + rng.uniform_in(-0.10, 0.22)).clamp(0.05, MAX_SENSITIVITY);
    }
    Latent {
        best_loop_order: rng.below(6) as u8,
        best_layout: rng.below(4) as u8,
        max_fusion: depth,
        fusion_saving,
        best_vector,
        tile_bias: rng.below(3) as i8 - 1,
        sensitivity,
    }
}

// ---------------------------------------------------------------------------
// CLI-facing grammar spec
// ---------------------------------------------------------------------------

/// A parsed `grammar:<name>[:seed=S]` workload spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GrammarSpec {
    pub name: String,
    pub seed: u64,
}

impl GrammarSpec {
    /// Parse a CLI workload spec. Accepts `grammar:<name>` and
    /// `grammar:<name>:seed=S`; the name must be in the registry.
    pub fn parse(s: &str) -> Result<GrammarSpec, String> {
        let rest = s.strip_prefix("grammar:").ok_or_else(|| {
            format!("expected grammar:<name>[:seed=S], got {s:?}")
        })?;
        let mut parts = rest.split(':');
        let name = parts.next().unwrap_or("");
        if grammar(name).is_none() {
            return Err(format!(
                "unknown grammar {name:?} (expected one of: {})",
                grammar_names()
            ));
        }
        let mut seed = DEFAULT_SEED;
        for part in parts {
            match part.split_once('=') {
                Some(("seed", v)) => {
                    seed = v.parse().map_err(|_| {
                        format!("grammar seed: bad number {v:?}")
                    })?;
                }
                _ => {
                    return Err(format!(
                        "grammar param: expected seed=S, got {part:?}"
                    ));
                }
            }
        }
        Ok(GrammarSpec { name: name.to_string(), seed })
    }

    /// Canonical spelling (always carries the seed) — used as the
    /// artifact workload tag so differently-spelled specs that expand
    /// to the same space produce byte-identical artifacts.
    pub fn canonical(&self) -> String {
        format!("grammar:{}:seed={}", self.name, self.seed)
    }

    /// The registry grammar this spec names. `parse` validates the
    /// name, so this only fails for hand-built specs.
    pub fn grammar(&self) -> Result<&'static Grammar, String> {
        grammar(&self.name).ok_or_else(|| {
            format!(
                "unknown grammar {:?} (expected one of: {})",
                self.name,
                grammar_names()
            )
        })
    }
}

// ---------------------------------------------------------------------------
// Space statistics (CI artifact)
// ---------------------------------------------------------------------------

/// Structured stats for a generated space: task counts per category
/// and difficulty, cardinality, lineage — the CI `workload-smoke` job
/// uploads this as `WORKLOAD_<name>.json`.
pub fn space_stats(spec: &GrammarSpec, suite: &Suite) -> Json {
    let g = match grammar(&spec.name) {
        Some(g) => g,
        None => return Json::Null,
    };
    let mut by_category: Vec<(&str, Json)> = Vec::new();
    for cat in crate::workload::ALL_CATEGORIES {
        let n = suite
            .tasks
            .iter()
            .filter(|t| t.category == cat)
            .count();
        if n > 0 {
            by_category.push((cat.name(), Json::num(n as f64)));
        }
    }
    let mut by_difficulty: Vec<(&str, Json)> = Vec::new();
    let labels = ["L1", "L2", "L3", "L4", "L5"];
    for (i, label) in labels.iter().enumerate() {
        let n = suite
            .tasks
            .iter()
            .filter(|t| t.difficulty.level() == i + 1)
            .count();
        by_difficulty.push((label, Json::num(n as f64)));
    }
    let torch = suite.tasks.iter().filter(|t| t.torch_comparable).count();
    Json::obj(vec![
        ("grammar", Json::str(spec.name.clone())),
        ("seed", Json::num(spec.seed as f64)),
        ("workload", Json::str(spec.canonical())),
        ("lineage", Json::str(format!("{:016x}", g.lineage(spec.seed)))),
        ("cardinality", Json::num(g.cardinality() as f64)),
        ("tasks", Json::num(suite.tasks.len() as f64)),
        ("torch_comparable", Json::num(torch as f64)),
        ("by_category", Json::obj(by_category)),
        ("by_difficulty", Json::obj(by_difficulty)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_cardinalities_are_pinned() {
        assert_eq!(grammar("pow2sweep").unwrap().cardinality(), 324);
        assert_eq!(grammar("raggedmix").unwrap().cardinality(), 84);
        assert!(grammar("nope").is_none());
    }

    #[test]
    fn expansion_matches_cardinality_and_is_deterministic() {
        for g in GRAMMARS {
            let a = g.expand(7);
            let b = g.expand(7);
            assert_eq!(a.len(), g.cardinality(), "{}", g.name);
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.name, y.name);
                assert_eq!(x.fingerprint(), y.fingerprint());
            }
        }
    }

    #[test]
    fn generated_latents_respect_conformance_caps() {
        for g in GRAMMARS {
            for t in g.expand(7) {
                assert!(t.latent.fusion_saving <= MAX_FUSION_SAVING,
                        "{}", t.name);
                assert!(t.latent.max_fusion <= t.category.max_fusion(),
                        "{}", t.name);
                for s in t.latent.sensitivity {
                    assert!(s <= MAX_SENSITIVITY, "{}", t.name);
                }
                assert!(t.shapes.len() >= 10, "{}", t.name);
                for w in t.shapes.windows(2) {
                    assert!(w[1].bytes > w[0].bytes, "{}", t.name);
                    assert!(w[1].flops > w[0].flops, "{}", t.name);
                }
            }
        }
    }

    #[test]
    fn spec_parsing_round_trips() {
        let s = GrammarSpec::parse("grammar:pow2sweep").unwrap();
        assert_eq!(s.name, "pow2sweep");
        assert_eq!(s.seed, DEFAULT_SEED);
        let s = GrammarSpec::parse("grammar:raggedmix:seed=99").unwrap();
        assert_eq!(s.seed, 99);
        assert_eq!(s.canonical(), "grammar:raggedmix:seed=99");
        assert!(GrammarSpec::parse("pow2sweep").is_err());
        assert!(GrammarSpec::parse("grammar:nope").is_err());
        assert!(GrammarSpec::parse("grammar:pow2sweep:fuel=2").is_err());
        assert!(GrammarSpec::parse("grammar:pow2sweep:seed=x").is_err());
    }
}
