//! Real-execution engine: the kernel-variant search over AOT Pallas
//! artifacts, measured through PJRT.
//!
//! This is the honest end of the reproduction: instead of the roofline
//! simulator, candidates here are *actual compiled kernels* — each
//! (tile / fusion / row-block / flash-block) choice from
//! `python/compile/model.py` is its own HLO module — and "measure" means
//! executing through the PJRT CPU client and timing, while "verify"
//! means an allclose comparison against the op's pure-jnp reference
//! artifact (two-stage: execution errors = call-accuracy failure,
//! mismatches = execution-accuracy failure).
//!
//! The same masked-UCB machinery drives the search: arms are the
//! strategy families present in the manifest (`tiling`, `fusion`,
//! `vectorization`, …); pulling an arm tries the next untried variant of
//! that family, and the reward is the clipped relative improvement over
//! the best latency so far — exactly the paper's reward signal with a
//! real measurement substrate.

use std::collections::HashMap;

use anyhow::{anyhow as eyre, Result};

use crate::bandit::{ArmStats, MaskedUcb};
use crate::rng::Rng;
use crate::runtime::{ArtifactMeta, Runtime};
use crate::strategy::{Strategy, NUM_STRATEGIES};
use crate::verify::{verify_buffers, Verdict};

/// One measured + verified variant.
#[derive(Debug, Clone)]
pub struct VariantResult {
    pub name: String,
    pub op: String,
    pub strategy: Option<Strategy>,
    pub verdict: Verdict,
    /// Median seconds per execution (PJRT CPU, interpret-lowered HLO).
    pub latency_s: f64,
    /// Speedup over the op's reference artifact.
    pub speedup: f64,
    /// Structural §Perf metadata from the manifest.
    pub vmem_bytes: f64,
    pub mxu_util: f64,
}

/// The real-kernel benchmark harness.
pub struct PjrtBench<'rt> {
    pub runtime: &'rt Runtime,
    /// Timed repetitions per measurement (median reported).
    pub reps: usize,
    /// Baseline (reference-artifact) latency per op, populated lazily.
    ref_latency: HashMap<String, f64>,
    ref_outputs: HashMap<String, Vec<f32>>,
}

impl<'rt> PjrtBench<'rt> {
    pub fn new(runtime: &'rt Runtime) -> Self {
        PjrtBench {
            runtime,
            reps: 5,
            ref_latency: HashMap::new(),
            ref_outputs: HashMap::new(),
        }
    }

    /// Deterministic shared inputs for every artifact of an op family
    /// (variants and reference see identical data, keyed by op).
    pub fn op_inputs(&self, op: &str) -> Result<Vec<Vec<f32>>> {
        let reference = self
            .runtime
            .manifest()
            .reference(op)
            .ok_or_else(|| eyre!("no reference artifact for op {op}"))?;
        // generate from the *reference* meta so all variants of the op
        // (identical signatures) share buffers
        self.runtime.example_inputs(&reference.name, 0xC0FFEE)
    }

    /// Measure + memoize the reference implementation of an op.
    pub fn reference(&mut self, op: &str) -> Result<(f64, Vec<f32>)> {
        if let (Some(&lat), Some(out)) =
            (self.ref_latency.get(op), self.ref_outputs.get(op))
        {
            return Ok((lat, out.clone()));
        }
        let name = self
            .runtime
            .manifest()
            .reference(op)
            .ok_or_else(|| eyre!("no reference for {op}"))?
            .name
            .clone();
        let inputs = self.op_inputs(op)?;
        let (outs, lat) = self.runtime.time_execution(&name, &inputs, self.reps)?;
        self.ref_latency.insert(op.to_string(), lat);
        self.ref_outputs.insert(op.to_string(), outs[0].clone());
        Ok((lat, self.ref_outputs[op].clone()))
    }

    /// Measure and verify a single variant.
    pub fn run_variant(&mut self, meta: &ArtifactMeta) -> Result<VariantResult> {
        let (ref_lat, ref_out) = self.reference(&meta.op)?;
        let inputs = self.op_inputs(&meta.op)?;
        let (verdict, latency_s) =
            match self.runtime.time_execution(&meta.name, &inputs, self.reps) {
                Ok((outs, lat)) => {
                    (verify_buffers(Some(&outs[0]), &ref_out), lat)
                }
                // execution failure = call-accuracy failure
                Err(_) => (verify_buffers(None, &ref_out), f64::INFINITY),
            };
        Ok(VariantResult {
            name: meta.name.clone(),
            op: meta.op.clone(),
            strategy: meta.strategy().and_then(Strategy::parse),
            verdict,
            latency_s,
            speedup: ref_lat / latency_s,
            vmem_bytes: meta.vmem_bytes,
            mxu_util: meta.mxu_util,
        })
    }

    /// Exhaustively measure every variant of an op (the per-op "table").
    pub fn sweep(&mut self, op: &str) -> Result<Vec<VariantResult>> {
        let metas: Vec<ArtifactMeta> = self
            .runtime
            .manifest()
            .variants(op)
            .into_iter()
            .cloned()
            .collect();
        metas.iter().map(|m| self.run_variant(m)).collect()
    }

    /// Masked-UCB search over an op's variant space (the end-to-end
    /// driver's inner loop): arms = strategy families; pulling an arm
    /// measures that family's next untried variant; reward = clipped
    /// improvement over the incumbent best latency.
    pub fn bandit_search(&mut self, op: &str, budget: usize, rng: &mut Rng)
                         -> Result<SearchOutcome> {
        let metas: Vec<ArtifactMeta> = self
            .runtime
            .manifest()
            .variants(op)
            .into_iter()
            .cloned()
            .collect();
        let (ref_lat, _) = self.reference(op)?;

        // group variant indices by strategy family
        let mut by_family: Vec<Vec<usize>> = vec![Vec::new(); NUM_STRATEGIES];
        for (i, m) in metas.iter().enumerate() {
            if let Some(s) = m.strategy().and_then(Strategy::parse) {
                by_family[s.index()].push(i);
            }
        }
        // shuffle within family so the pull order is seed-dependent
        for fam in by_family.iter_mut() {
            rng.shuffle(fam);
        }

        let ucb = MaskedUcb::default();
        let mut stats = ArmStats::new(1);
        let mut next_in_family = vec![0usize; NUM_STRATEGIES];
        let mut best_latency = ref_lat;
        let mut tried = Vec::new();
        for t in 1..=budget {
            // mask exhausted families
            let mask: Vec<bool> = (0..NUM_STRATEGIES)
                .map(|s| next_in_family[s] < by_family[s].len())
                .collect();
            let Some((_, s)) = ucb.select(&stats, t, &mask) else {
                break; // every variant tried
            };
            let vi = by_family[s.index()][next_in_family[s.index()]];
            next_in_family[s.index()] += 1;
            let result = self.run_variant(&metas[vi])?;
            let reward = if result.verdict.passed() {
                ((best_latency - result.latency_s) / best_latency).clamp(0.0, 1.0)
            } else {
                0.0
            };
            if result.verdict.passed() && result.latency_s < best_latency {
                best_latency = result.latency_s;
            }
            stats.update(0, s, reward);
            tried.push(result);
        }
        let best = tried
            .iter()
            .filter(|r| r.verdict.passed())
            .min_by(|a, b| a.latency_s.total_cmp(&b.latency_s))
            .cloned();
        Ok(SearchOutcome {
            op: op.to_string(),
            reference_latency_s: ref_lat,
            tried,
            best,
        })
    }
}

/// Result of a bandit search over one op's variant space.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub op: String,
    pub reference_latency_s: f64,
    pub tried: Vec<VariantResult>,
    pub best: Option<VariantResult>,
}

impl SearchOutcome {
    pub fn best_speedup(&self) -> f64 {
        self.best.as_ref().map(|b| b.speedup).unwrap_or(1.0)
    }

    /// Measurements issued (the search's cost).
    pub fn evaluations(&self) -> usize {
        self.tried.len()
    }
}
