//! Evaluation engines: how a candidate schedule is measured.
//!
//! The search policies are generic over [`EvalEngine`] so the same
//! Algorithm-1 driver runs against the roofline simulator (the full
//! TritonBench-G-scale experiments) or against real AOT-compiled Pallas
//! artifacts through PJRT ([`pjrt::PjrtBench`], used by the end-to-end
//! example and integration tests).

pub mod pjrt;

use crate::gpu_model::{Device, GpuSim};
use crate::kernel::{KernelConfig, Measurement};
use crate::rng::Rng;
use crate::workload::TaskSpec;

/// Measurement backend for the schedule space.
pub trait EvalEngine {
    /// The simulated device profile (the surrogate LLM reads hardware
    /// specs from here, like a prompt embedding the GPU datasheet).
    fn gpu(&self) -> &GpuSim;

    /// Benchmark a schedule on a task (all shapes, noise keyed by `rng`).
    fn measure(&self, task: &TaskSpec, cfg: &KernelConfig, rng: &mut Rng)
               -> Measurement;
}

/// The simulator-backed engine.
#[derive(Debug, Clone)]
pub struct SimEngine {
    pub sim: GpuSim,
}

impl SimEngine {
    pub fn new(device: Device) -> SimEngine {
        SimEngine { sim: GpuSim::new(device) }
    }

    pub fn noiseless(device: Device) -> SimEngine {
        SimEngine { sim: GpuSim::noiseless(device) }
    }
}

impl EvalEngine for SimEngine {
    fn gpu(&self) -> &GpuSim {
        &self.sim
    }

    fn measure(&self, task: &TaskSpec, cfg: &KernelConfig, rng: &mut Rng)
               -> Measurement {
        self.sim.evaluate(task, cfg, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Suite;

    #[test]
    fn sim_engine_measures() {
        let suite = Suite::full(1);
        let engine = SimEngine::new(Device::A100);
        let m = engine.measure(
            &suite.tasks[0],
            &KernelConfig::naive(),
            &mut Rng::new(0),
        );
        assert!(m.total_latency_s > 0.0);
        assert_eq!(engine.gpu().profile.device, Device::A100);
    }
}
