//! Evaluation engines: how a candidate schedule is measured.
//!
//! The search policies are generic over [`EvalEngine`] so the same
//! Algorithm-1 driver runs against the roofline simulator (the full
//! TritonBench-G-scale experiments) or against real AOT-compiled Pallas
//! artifacts through PJRT ([`pjrt::PjrtBench`], used by the end-to-end
//! example and integration tests).

pub mod pjrt;

use crate::gpu_model::{Device, GpuSim};
use crate::kernel::{KernelConfig, Measurement};
use crate::rng::Rng;
use crate::workload::TaskSpec;

/// Measurement backend for the schedule space.
pub trait EvalEngine {
    /// The simulated device profile (the surrogate LLM reads hardware
    /// specs from here, like a prompt embedding the GPU datasheet).
    fn gpu(&self) -> &GpuSim;

    /// Benchmark a schedule on a task (all shapes, noise keyed by `rng`).
    fn measure(&self, task: &TaskSpec, cfg: &KernelConfig, rng: &mut Rng)
               -> Measurement;

    /// Benchmark a *batch* of schedules through one engine call
    /// (`rngs[i]` keys candidate `i`'s noise, exactly as a standalone
    /// [`EvalEngine::measure`] would). The default loops `measure`;
    /// engines with a fused path (the simulator's shape loop, a cache
    /// that can batch its lookups) override it. Contract: element `i`
    /// of the result is bit-identical to `measure(task, &cfgs[i],
    /// &mut rngs[i])`.
    fn measure_batch(&self, task: &TaskSpec, cfgs: &[KernelConfig],
                     rngs: &mut [Rng]) -> Vec<Measurement> {
        debug_assert_eq!(cfgs.len(), rngs.len());
        cfgs.iter()
            .zip(rngs.iter_mut())
            .map(|(cfg, rng)| self.measure(task, cfg, rng))
            .collect()
    }
}

/// The simulator-backed engine.
#[derive(Debug, Clone)]
pub struct SimEngine {
    pub sim: GpuSim,
}

impl SimEngine {
    pub fn new(device: Device) -> SimEngine {
        SimEngine { sim: GpuSim::new(device) }
    }

    pub fn noiseless(device: Device) -> SimEngine {
        SimEngine { sim: GpuSim::noiseless(device) }
    }
}

impl EvalEngine for SimEngine {
    fn gpu(&self) -> &GpuSim {
        &self.sim
    }

    fn measure(&self, task: &TaskSpec, cfg: &KernelConfig, rng: &mut Rng)
               -> Measurement {
        self.sim.evaluate(task, cfg, rng)
    }

    fn measure_batch(&self, task: &TaskSpec, cfgs: &[KernelConfig],
                     rngs: &mut [Rng]) -> Vec<Measurement> {
        // fused: one shape sweep for the whole batch
        self.sim.evaluate_batch(task, cfgs, rngs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Suite;

    #[test]
    fn sim_engine_measures() {
        let suite = Suite::full(1);
        let engine = SimEngine::new(Device::A100);
        let m = engine.measure(
            &suite.tasks[0],
            &KernelConfig::naive(),
            &mut Rng::new(0),
        );
        assert!(m.total_latency_s > 0.0);
        assert_eq!(engine.gpu().profile.device, Device::A100);
    }

    #[test]
    fn measure_batch_matches_serial_measures() {
        let suite = Suite::full(1);
        let engine = SimEngine::new(Device::H20);
        let task = &suite.tasks[2];
        let cfgs = [KernelConfig::naive(), {
            let mut c = KernelConfig::naive();
            c.tile_m = 3;
            c
        }];
        let mut rngs: Vec<Rng> =
            (0..2).map(|i| Rng::new(9).split("m", i)).collect();
        let fused = engine.measure_batch(task, &cfgs, &mut rngs);
        for (i, cfg) in cfgs.iter().enumerate() {
            let solo = engine.measure(
                task, cfg, &mut Rng::new(9).split("m", i as u64),
            );
            assert_eq!(fused[i].total_latency_s.to_bits(),
                       solo.total_latency_s.to_bits());
        }
    }
}
