//! The optimization-strategy set `S` (paper §3.6, Appendix D).
//!
//! Six strategies, each targeting a hardware resource; the mapping from
//! strategy to *target resource* drives the hardware-aware mask
//! `M[i,s] = 1[h(k_c)[Target(s)] < θ_sat]` (paper Eq. 5).


/// The hardware resource a strategy primarily relieves (paper §3.2:
/// the NCU signature measures DRAM, L2 and SM peak-throughput %).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Resource {
    /// Streaming-multiprocessor / compute-pipe utilization.
    Sm,
    /// DRAM (HBM) bandwidth.
    Dram,
    /// L2-cache bandwidth / hit behaviour.
    L2,
}

/// The paper's refined 6-strategy set (Appendix D, Table 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Partition computation into configurable tile sizes for cache
    /// locality and parallelism.
    Tiling,
    /// Vector loads/stores (float4 on CUDA; lane-aligned blocks on TPU).
    Vectorization,
    /// Combine ops to cut intermediate memory traffic.
    Fusion,
    /// Software-pipelining depth for latency hiding.
    Pipeline,
    /// Loop order / instruction scheduling for ILP.
    Reordering,
    /// Memory access patterns, coalescing, data layout.
    AccessLayout,
}

/// `|S|` — used to size arm matrices.
pub const NUM_STRATEGIES: usize = 6;

/// All strategies in canonical order (matches the L1 `ucb` artifact's
/// column order and the paper's Table 3 row order).
pub const ALL_STRATEGIES: [Strategy; NUM_STRATEGIES] = [
    Strategy::Tiling,
    Strategy::Vectorization,
    Strategy::Fusion,
    Strategy::Pipeline,
    Strategy::Reordering,
    Strategy::AccessLayout,
];

impl Strategy {
    /// Canonical index in `[0, NUM_STRATEGIES)`.
    pub fn index(self) -> usize {
        ALL_STRATEGIES.iter().position(|&s| s == self).unwrap()
    }

    /// Inverse of [`Strategy::index`].
    pub fn from_index(i: usize) -> Strategy {
        ALL_STRATEGIES[i]
    }

    /// `Target(s)` — the resource whose saturation gates this strategy
    /// (paper Eq. 5). A strategy is only worth applying while its target
    /// resource still has headroom:
    ///
    /// * Tiling / Reordering raise *compute* efficiency → gated on SM.
    /// * Vectorization / Fusion relieve *DRAM* traffic → gated on DRAM.
    /// * Pipeline hides latency → gated on SM (issue slots).
    /// * Access & layout improves locality → gated on L2.
    pub fn target(self) -> Resource {
        match self {
            Strategy::Tiling => Resource::Sm,
            Strategy::Vectorization => Resource::Dram,
            Strategy::Fusion => Resource::Dram,
            Strategy::Pipeline => Resource::Sm,
            Strategy::Reordering => Resource::Sm,
            Strategy::AccessLayout => Resource::L2,
        }
    }

    /// Human-readable name (paper table row labels).
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Tiling => "Tiling",
            Strategy::Vectorization => "Vectorization",
            Strategy::Fusion => "Fusion",
            Strategy::Pipeline => "Pipeline",
            Strategy::Reordering => "Reordering",
            Strategy::AccessLayout => "Access & Layout",
        }
    }

    /// Parse from the names used in configs/CLI (case-insensitive).
    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "tiling" => Some(Strategy::Tiling),
            "vectorization" | "vectorize" => Some(Strategy::Vectorization),
            "fusion" | "fuse" => Some(Strategy::Fusion),
            "pipeline" => Some(Strategy::Pipeline),
            "reordering" | "reorder" => Some(Strategy::Reordering),
            "access_layout" | "access & layout" | "layout" => {
                Some(Strategy::AccessLayout)
            }
            _ => None,
        }
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        for (i, &s) in ALL_STRATEGIES.iter().enumerate() {
            assert_eq!(s.index(), i);
            assert_eq!(Strategy::from_index(i), s);
        }
    }

    #[test]
    fn parse_roundtrip() {
        for &s in &ALL_STRATEGIES {
            assert_eq!(Strategy::parse(s.name()), Some(s));
        }
        assert_eq!(Strategy::parse("nope"), None);
    }

    #[test]
    fn targets_cover_all_resources() {
        let targets: std::collections::HashSet<_> =
            ALL_STRATEGIES.iter().map(|s| s.target()).collect();
        assert!(targets.contains(&Resource::Sm));
        assert!(targets.contains(&Resource::Dram));
        assert!(targets.contains(&Resource::L2));
    }
}
