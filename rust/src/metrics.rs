//! Evaluation metrics (paper §4.1).
//!
//! * **Correct (%)** — tasks yielding ≥1 verified kernel.
//! * **Fast@1 (%)** — tasks whose best kernel achieves speedup > 1.0×
//!   (failed tasks count as 0).
//! * **Geometric-mean speedup** in two modes: *standard* averages only
//!   correct tasks (including regressions) to isolate optimization
//!   quality; *fallback* assigns failures/regressions a baseline 1.0× —
//!   the deployed-system view used in the scaling figures.
//!
//! Per-task speedup is the ratio of *total* runtimes across all
//! benchmark shapes (Appendix H), so long-running shapes dominate.


use crate::workload::{Difficulty, TaskSpec};

/// Result of optimizing one task with one method.
#[derive(Debug, Clone)]
pub struct TaskOutcome {
    pub task_id: usize,
    pub task_name: String,
    pub difficulty: Difficulty,
    /// ≥1 candidate passed two-stage verification.
    pub correct: bool,
    /// Best verified speedup over the reference (ratio of total
    /// runtimes); meaningful only when `correct`.
    pub best_speedup: f64,
    /// Cumulative API cost spent on the task (USD).
    pub cost_usd: f64,
    /// Iterations actually executed.
    pub iterations: usize,
}

impl TaskOutcome {
    pub fn failed(task: &TaskSpec, iterations: usize, cost_usd: f64) -> Self {
        TaskOutcome {
            task_id: task.id,
            task_name: task.name.clone(),
            difficulty: task.difficulty,
            correct: false,
            best_speedup: 0.0,
            cost_usd,
            iterations,
        }
    }

    /// Fallback-mode speedup: failures and regressions fall back to the
    /// reference kernel (1.0×).
    pub fn fallback_speedup(&self) -> f64 {
        if self.correct {
            self.best_speedup.max(1.0)
        } else {
            1.0
        }
    }
}

/// Aggregated metrics over a set of task outcomes.
#[derive(Debug, Clone, Copy)]
pub struct Aggregate {
    pub tasks: usize,
    pub correct_pct: f64,
    pub fast1_pct: f64,
    /// Standard-mode geomean (correct tasks only, regressions included).
    pub geomean_standard: f64,
    /// Fallback-mode geomean (all tasks; failures → 1.0×).
    pub geomean_fallback: f64,
    pub total_cost_usd: f64,
}

fn geomean(xs: impl Iterator<Item = f64>) -> f64 {
    let mut log_sum = 0.0;
    let mut n = 0usize;
    for x in xs {
        log_sum += x.max(1e-12).ln();
        n += 1;
    }
    if n == 0 {
        f64::NAN
    } else {
        (log_sum / n as f64).exp()
    }
}

/// Aggregate a slice of outcomes.
pub fn aggregate(outcomes: &[TaskOutcome]) -> Aggregate {
    let tasks = outcomes.len();
    let correct = outcomes.iter().filter(|o| o.correct).count();
    let fast1 = outcomes
        .iter()
        .filter(|o| o.correct && o.best_speedup > 1.0)
        .count();
    Aggregate {
        tasks,
        correct_pct: 100.0 * correct as f64 / tasks.max(1) as f64,
        fast1_pct: 100.0 * fast1 as f64 / tasks.max(1) as f64,
        geomean_standard: geomean(
            outcomes.iter().filter(|o| o.correct).map(|o| o.best_speedup),
        ),
        geomean_fallback: geomean(outcomes.iter().map(|o| o.fallback_speedup())),
        total_cost_usd: outcomes.iter().map(|o| o.cost_usd).sum(),
    }
}

/// Table-1 difficulty strata: L1-2, L3, L4-5, All.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stratum {
    L12,
    L3,
    L45,
    All,
}

pub const ALL_STRATA: [Stratum; 4] = [Stratum::L12, Stratum::L3, Stratum::L45, Stratum::All];

impl Stratum {
    pub fn name(self) -> &'static str {
        match self {
            Stratum::L12 => "L1-2",
            Stratum::L3 => "L3",
            Stratum::L45 => "L4-5",
            Stratum::All => "All",
        }
    }

    pub fn contains(self, d: Difficulty) -> bool {
        match self {
            Stratum::L12 => d.level() <= 2,
            Stratum::L3 => d.level() == 3,
            Stratum::L45 => d.level() >= 4,
            Stratum::All => true,
        }
    }
}

/// Aggregate per Table-1 stratum.
pub fn stratified(outcomes: &[TaskOutcome]) -> Vec<(Stratum, Aggregate)> {
    ALL_STRATA
        .iter()
        .map(|&s| {
            let subset: Vec<TaskOutcome> = outcomes
                .iter()
                .filter(|o| s.contains(o.difficulty))
                .cloned()
                .collect();
            (s, aggregate(&subset))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(correct: bool, speedup: f64, d: Difficulty) -> TaskOutcome {
        TaskOutcome {
            task_id: 0,
            task_name: "t".into(),
            difficulty: d,
            correct,
            best_speedup: speedup,
            cost_usd: 0.1,
            iterations: 20,
        }
    }

    #[test]
    fn correct_and_fast1_percentages() {
        let outs = vec![
            outcome(true, 2.0, Difficulty::L1),
            outcome(true, 0.8, Difficulty::L2), // correct but regressed
            outcome(false, 0.0, Difficulty::L3),
            outcome(true, 1.5, Difficulty::L4),
        ];
        let a = aggregate(&outs);
        assert!((a.correct_pct - 75.0).abs() < 1e-9);
        assert!((a.fast1_pct - 50.0).abs() < 1e-9);
    }

    #[test]
    fn standard_geomean_includes_regressions_excludes_failures() {
        let outs = vec![
            outcome(true, 2.0, Difficulty::L1),
            outcome(true, 0.5, Difficulty::L2),
            outcome(false, 0.0, Difficulty::L3),
        ];
        let a = aggregate(&outs);
        // geomean(2.0, 0.5) = 1.0
        assert!((a.geomean_standard - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fallback_geomean_floors_at_one() {
        let outs = vec![
            outcome(true, 2.0, Difficulty::L1),
            outcome(true, 0.5, Difficulty::L2), // regression → 1.0
            outcome(false, 0.0, Difficulty::L3), // failure → 1.0
        ];
        let a = aggregate(&outs);
        // geomean(2.0, 1.0, 1.0) = 2^(1/3)
        assert!((a.geomean_fallback - 2.0f64.powf(1.0 / 3.0)).abs() < 1e-9);
        assert!(a.geomean_fallback >= 1.0);
    }

    #[test]
    fn strata_partition_difficulties() {
        for d in crate::workload::ALL_DIFFICULTIES {
            let n = [Stratum::L12, Stratum::L3, Stratum::L45]
                .iter()
                .filter(|s| s.contains(d))
                .count();
            assert_eq!(n, 1, "{d:?} must be in exactly one stratum");
            assert!(Stratum::All.contains(d));
        }
    }

    #[test]
    fn stratified_totals_match() {
        let outs = vec![
            outcome(true, 2.0, Difficulty::L1),
            outcome(true, 1.2, Difficulty::L3),
            outcome(false, 0.0, Difficulty::L5),
        ];
        let rows = stratified(&outs);
        let all = rows.iter().find(|(s, _)| *s == Stratum::All).unwrap().1;
        assert_eq!(all.tasks, 3);
        let l12 = rows.iter().find(|(s, _)| *s == Stratum::L12).unwrap().1;
        assert_eq!(l12.tasks, 1);
    }

    #[test]
    fn empty_aggregate_is_sane() {
        let a = aggregate(&[]);
        assert_eq!(a.tasks, 0);
        assert_eq!(a.correct_pct, 0.0);
        assert!(a.geomean_standard.is_nan());
    }

    #[test]
    fn cost_accumulates() {
        let outs = vec![
            outcome(true, 2.0, Difficulty::L1),
            outcome(false, 0.0, Difficulty::L2),
        ];
        assert!((aggregate(&outs).total_cost_usd - 0.2).abs() < 1e-12);
    }
}
