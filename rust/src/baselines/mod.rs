//! Baseline optimizers (paper §4.1) and the PyTorch execution modes of
//! Appendix G.
//!
//! * [`BestOfN`] — samples N = T independent free-form variants of the
//!   naive kernel and keeps the fastest (isolates iterative effects).
//! * [`Geak`] — a GEAK-style Reflexion agent: free-form iterative
//!   refinement from the current best kernel, with a one-step verbal-
//!   reflection memory that boosts the retry after a failure. No
//!   strategy structure, no profiling guidance.
//! * [`TorchMode`] — eager / inductor / max-autotune reference latencies
//!   for the Table-9 comparison.

use crate::engine::EvalEngine;
use crate::kernel::{Candidate, Origin};
use crate::llm::{GenOutcome, LlmBackend, PromptMode, ProposalRequest};
use crate::policy::{IterationRecord, Trace};
use crate::rng::Rng;
use crate::verify::verify_outcome;
use crate::workload::TaskSpec;

/// Best-of-N independent sampling.
pub struct BestOfN {
    pub n: usize,
}

impl BestOfN {
    pub fn new(n: usize) -> Self {
        BestOfN { n }
    }

    pub fn optimize<E: EvalEngine, L: LlmBackend>(
        &self,
        task: &TaskSpec,
        engine: &E,
        llm: &L,
        root: &Rng,
    ) -> Trace {
        let rng = root.split("bon", task.id as u64);
        let naive_cfg = task.naive_config();
        let naive_meas = engine.measure(task, &naive_cfg, &mut rng.split("m", 0));
        let naive_latency_s = naive_meas.total_latency_s;
        let mut candidates = vec![Candidate {
            id: 0,
            config: naive_cfg,
            origin: Origin::Naive,
            measurement: naive_meas,
            born_at: 0,
        }];
        let mut records = Vec::new();
        let mut best_id = 0usize;
        for t in 1..=self.n {
            // every sample starts from the naive kernel — no iteration
            let req = ProposalRequest {
                task,
                parent: &naive_cfg,
                mode: PromptMode::FreeForm,
                sim: engine.gpu(),
                iterative: false, // every BoN sample is a one-shot rewrite
            };
            let proposal = llm.propose(&req, &mut rng.split("gen", t as u64));
            let verdict = verify_outcome(proposal.outcome);
            let mut accepted = None;
            let mut reward = 0.0;
            if verdict.passed() {
                let meas = engine.measure(
                    task,
                    &proposal.config,
                    &mut rng.split("m", t as u64),
                );
                reward = ((naive_latency_s - meas.total_latency_s)
                    / naive_latency_s)
                    .clamp(0.0, 1.0);
                let id = candidates.len();
                if meas.total_latency_s
                    < candidates[best_id].measurement.total_latency_s
                {
                    best_id = id;
                }
                candidates.push(Candidate {
                    id,
                    config: proposal.config,
                    origin: Origin::Llm {
                        parent: 0,
                        strategy: crate::strategy::Strategy::Reordering,
                    },
                    measurement: meas,
                    born_at: t,
                });
                accepted = Some(id);
            }
            let best_speedup_so_far = if candidates.len() > 1 {
                naive_latency_s
                    / candidates[best_id].measurement.total_latency_s
            } else {
                0.0
            };
            records.push(IterationRecord {
                t,
                cluster: 0,
                strategy: None,
                parent: 0,
                verdict,
                reward,
                accepted,
                cost_usd: proposal.cost_usd,
                llm_serial_s: proposal.latency_s,
                best_speedup_so_far,
                batch_accepted: Vec::new(),
                batch_pruned: 0,
                batch_width: 1,
            });
        }
        Trace {
            task_id: task.id,
            task_name: task.name.clone(),
            difficulty: task.difficulty,
            candidates,
            records,
            best_id,
            naive_latency_s,
            profile_cost_s: 0.0,
            profile_runs: 0,
        }
    }
}

/// GEAK-style Reflexion agent.
pub struct Geak {
    pub iterations: usize,
}

impl Geak {
    pub fn new(iterations: usize) -> Self {
        Geak { iterations }
    }

    pub fn optimize<E: EvalEngine, L: LlmBackend>(
        &self,
        task: &TaskSpec,
        engine: &E,
        llm: &L,
        root: &Rng,
    ) -> Trace {
        let rng = root.split("geak", task.id as u64);
        let naive_cfg = task.naive_config();
        let naive_meas = engine.measure(task, &naive_cfg, &mut rng.split("m", 0));
        let naive_latency_s = naive_meas.total_latency_s;
        let mut candidates = vec![Candidate {
            id: 0,
            config: naive_cfg,
            origin: Origin::Naive,
            measurement: naive_meas,
            born_at: 0,
        }];
        let mut records = Vec::new();
        let mut best_id = 0usize;
        // Reflexion memory: after a failed generation, the retry gets one
        // extra attempt (the agent "reflects" on the error message).
        let mut reflect = false;
        for t in 1..=self.iterations {
            let parent_idx = best_id; // refine the current best
            let parent_cfg = candidates[parent_idx].config;
            let req = ProposalRequest {
                task,
                parent: &parent_cfg,
                mode: PromptMode::FreeForm,
                sim: engine.gpu(),
                iterative: true, // GEAK refines verified code in-context
            };
            let mut proposal =
                llm.propose(&req, &mut rng.split("gen", t as u64));
            if reflect && proposal.outcome != GenOutcome::Ok {
                // one self-repair retry informed by the previous failure
                let retry = llm.propose(&req, &mut rng.split("retry", t as u64));
                proposal.cost_usd += retry.cost_usd;
                proposal.latency_s += retry.latency_s;
                proposal.outcome = retry.outcome;
                proposal.config = retry.config;
            }
            let verdict = verify_outcome(proposal.outcome);
            reflect = !verdict.passed();
            let mut accepted = None;
            let mut reward = 0.0;
            if verdict.passed() {
                let meas = engine.measure(
                    task,
                    &proposal.config,
                    &mut rng.split("m", t as u64),
                );
                let parent_t =
                    candidates[parent_idx].measurement.total_latency_s;
                reward = ((parent_t - meas.total_latency_s) / parent_t)
                    .clamp(0.0, 1.0);
                let id = candidates.len();
                if meas.total_latency_s
                    < candidates[best_id].measurement.total_latency_s
                {
                    best_id = id;
                }
                candidates.push(Candidate {
                    id,
                    config: proposal.config,
                    origin: Origin::Llm {
                        parent: parent_idx,
                        strategy: crate::strategy::Strategy::Reordering,
                    },
                    measurement: meas,
                    born_at: t,
                });
                accepted = Some(id);
            }
            let best_speedup_so_far = if candidates.len() > 1 {
                naive_latency_s
                    / candidates[best_id].measurement.total_latency_s
            } else {
                0.0
            };
            records.push(IterationRecord {
                t,
                cluster: 0,
                strategy: None,
                parent: parent_idx,
                verdict,
                reward,
                accepted,
                cost_usd: proposal.cost_usd,
                llm_serial_s: proposal.latency_s,
                best_speedup_so_far,
                batch_accepted: Vec::new(),
                batch_pruned: 0,
                batch_width: 1,
            });
        }
        Trace {
            task_id: task.id,
            task_name: task.name.clone(),
            difficulty: task.difficulty,
            candidates,
            records,
            best_id,
            naive_latency_s,
            profile_cost_s: 0.0,
            profile_runs: 0,
        }
    }
}

/// PyTorch execution modes (Appendix G / Table 9), modeled as fixed
/// latency multipliers over the Triton reference implementation with
/// small per-task jitter:
///
/// * **eager** — unfused op-by-op dispatch: extra HBM round-trips and
///   launch overhead.
/// * **inductor** — `torch.compile` default: fuses the easy traffic away
///   but doesn't tile aggressively.
/// * **max-autotune** — heavy per-shape autotuning that over-specializes:
///   excellent on the tuned shape, brittle across the 10+ benchmark
///   shapes (the paper measures it *slower* than inductor overall).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TorchMode {
    Eager,
    Inductor,
    MaxAutotune,
}

impl TorchMode {
    pub fn name(self) -> &'static str {
        match self {
            TorchMode::Eager => "eager",
            TorchMode::Inductor => "inductor",
            TorchMode::MaxAutotune => "max-autotune",
        }
    }

    /// Latency multiplier vs the task's naive Triton reference.
    fn factor(self, task: &TaskSpec, rng: &mut Rng) -> f64 {
        let jitter = rng.lognormal_noise(0.08);
        let fusable = task.latent.fusion_saving; // eager pays this twice
        let base = match self {
            TorchMode::Eager => 1.25 + 0.5 * fusable,
            TorchMode::Inductor => 1.12 + 0.15 * fusable,
            // over-specialization: great on one shape, poor on the rest
            TorchMode::MaxAutotune => 1.27 + 0.45 * fusable,
        };
        base * jitter
    }

    /// Total latency of this mode on the task.
    pub fn latency<E: EvalEngine>(self, task: &TaskSpec, engine: &E,
                                  root: &Rng) -> f64 {
        let mut rng = root.split("torch", task.id as u64 ^ self as u64);
        let naive = engine
            .measure(task, &task.naive_config(), &mut rng.split("m", 0))
            .total_latency_s;
        naive * self.factor(task, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SimEngine;
    use crate::gpu_model::Device;
    use crate::llm::{LlmProfile, SurrogateLlm};
    use crate::workload::Suite;

    fn setup() -> (Suite, SimEngine, SurrogateLlm) {
        (
            Suite::full(1),
            SimEngine::new(Device::H20),
            SurrogateLlm::new(LlmProfile::DeepSeekV32),
        )
    }

    #[test]
    fn bon_samples_always_from_naive() {
        let (suite, engine, llm) = setup();
        let tr = BestOfN::new(15).optimize(&suite.tasks[2], &engine, &llm,
                                           &Rng::new(1));
        assert_eq!(tr.records.len(), 15);
        assert!(tr.records.iter().all(|r| r.parent == 0));
    }

    #[test]
    fn geak_refines_current_best() {
        let (suite, engine, llm) = setup();
        let tr = Geak::new(20).optimize(&suite.tasks[2], &engine, &llm,
                                        &Rng::new(1));
        assert_eq!(tr.records.len(), 20);
        // once something better than naive exists, parents move off 0
        let improved = tr
            .records
            .iter()
            .any(|r| r.accepted.is_some() && r.best_speedup_so_far > 1.0);
        if improved {
            assert!(tr.records.iter().any(|r| r.parent != 0));
        }
    }

    #[test]
    fn baselines_are_deterministic() {
        let (suite, engine, llm) = setup();
        let a = BestOfN::new(10).optimize(&suite.tasks[5], &engine, &llm,
                                          &Rng::new(2));
        let b = BestOfN::new(10).optimize(&suite.tasks[5], &engine, &llm,
                                          &Rng::new(2));
        assert_eq!(a.best_speedup(), b.best_speedup());
        let g1 = Geak::new(10).optimize(&suite.tasks[5], &engine, &llm,
                                        &Rng::new(2));
        let g2 = Geak::new(10).optimize(&suite.tasks[5], &engine, &llm,
                                        &Rng::new(2));
        assert_eq!(g1.best_speedup(), g2.best_speedup());
    }

    #[test]
    fn torch_modes_are_slower_than_reference() {
        let (suite, engine, _) = setup();
        let root = Rng::new(3);
        for task in suite.tasks.iter().take(10) {
            let naive = engine
                .measure(task, &task.naive_config(), &mut Rng::new(0))
                .total_latency_s;
            for mode in [TorchMode::Eager, TorchMode::Inductor,
                         TorchMode::MaxAutotune] {
                let t = mode.latency(task, &engine, &root);
                assert!(t > naive * 0.95, "{} on {}", mode.name(), task.name);
            }
        }
    }

    #[test]
    fn inductor_beats_eager_and_max_autotune_on_average() {
        let (suite, engine, _) = setup();
        let root = Rng::new(4);
        let avg = |mode: TorchMode| {
            suite
                .tasks
                .iter()
                .take(40)
                .map(|t| mode.latency(t, &engine, &root))
                .sum::<f64>()
        };
        let eager = avg(TorchMode::Eager);
        let inductor = avg(TorchMode::Inductor);
        let maxat = avg(TorchMode::MaxAutotune);
        assert!(inductor < eager);
        assert!(inductor < maxat);
    }
}
