//! Candidate-kernel state.
//!
//! The optimization graph's nodes (paper §2.1): each candidate is a point
//! in the kernel *configuration space* — the latent schedule the surrogate
//! LLM mutates and the GPU simulator (or the PJRT engine, for real Pallas
//! variants) evaluates. The config dimensions mirror the 6-strategy set:
//! tiles ↔ Tiling, `vector_width` ↔ Vectorization, `fusion_depth` ↔
//! Fusion, `pipeline_depth` ↔ Pipeline, `loop_order` ↔ Reordering,
//! `layout` ↔ Access & Layout.


use crate::strategy::Strategy;

/// Allowed tile edge sizes (powers of two, CUDA-threadblock / Pallas
/// BlockSpec flavoured).
pub const TILE_LEVELS: [u32; 6] = [8, 16, 32, 64, 128, 256];
/// Allowed vector widths (float1/2/4/8 loads).
pub const VECTOR_LEVELS: [u32; 4] = [1, 2, 4, 8];
/// Max ops fused into the kernel epilogue/prologue.
pub const MAX_FUSION: u32 = 3;
/// Software-pipeline stages.
pub const MAX_PIPELINE: u32 = 4;
/// Distinct loop orders (3 nested loops → 6 permutations).
pub const NUM_LOOP_ORDERS: u32 = 6;
/// Distinct data layouts (row/col-major × swizzled/padded).
pub const NUM_LAYOUTS: u32 = 4;

/// A point in the schedule space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KernelConfig {
    /// Tile sizes as indices into [`TILE_LEVELS`].
    pub tile_m: u8,
    pub tile_n: u8,
    pub tile_k: u8,
    /// Index into [`VECTOR_LEVELS`].
    pub vector: u8,
    /// Ops fused (0 = none).
    pub fusion: u8,
    /// Pipeline stages − 1 (0 = no pipelining).
    pub pipeline: u8,
    /// Loop-order permutation id.
    pub loop_order: u8,
    /// Layout id.
    pub layout: u8,
}

impl KernelConfig {
    /// The "naive kernel" the paper starts every task from: smallest
    /// tiles, scalar loads, nothing fused, no pipelining.
    pub fn naive() -> Self {
        KernelConfig {
            tile_m: 1,
            tile_n: 1,
            tile_k: 0,
            vector: 0,
            fusion: 0,
            pipeline: 0,
            loop_order: 0,
            layout: 0,
        }
    }

    /// Actual tile edge sizes.
    pub fn tiles(&self) -> (u32, u32, u32) {
        (
            TILE_LEVELS[self.tile_m as usize],
            TILE_LEVELS[self.tile_n as usize],
            TILE_LEVELS[self.tile_k as usize],
        )
    }

    /// Actual vector width.
    pub fn vector_width(&self) -> u32 {
        VECTOR_LEVELS[self.vector as usize]
    }

    /// Clamp every field into its legal range (defensive for mutations).
    pub fn clamped(mut self) -> Self {
        self.tile_m = self.tile_m.min(TILE_LEVELS.len() as u8 - 1);
        self.tile_n = self.tile_n.min(TILE_LEVELS.len() as u8 - 1);
        self.tile_k = self.tile_k.min(TILE_LEVELS.len() as u8 - 1);
        self.vector = self.vector.min(VECTOR_LEVELS.len() as u8 - 1);
        self.fusion = self.fusion.min(MAX_FUSION as u8);
        self.pipeline = self.pipeline.min(MAX_PIPELINE as u8 - 1);
        self.loop_order = self.loop_order.min(NUM_LOOP_ORDERS as u8 - 1);
        self.layout = self.layout.min(NUM_LAYOUTS as u8 - 1);
        self
    }

    /// A stable 64-bit hash of the schedule — used as the NCU-result
    /// cache key (the paper caches profiling by code hash, §3.6).
    pub fn code_hash(&self) -> u64 {
        let fields = [
            self.tile_m, self.tile_n, self.tile_k, self.vector, self.fusion,
            self.pipeline, self.loop_order, self.layout,
        ];
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for f in fields {
            h ^= f as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h
    }

    /// L1-style distance in schedule space (used by tests and the
    /// Lipschitz diagnostics, not by the algorithm itself).
    pub fn distance(&self, other: &KernelConfig) -> u32 {
        let d = |a: u8, b: u8| (a as i32 - b as i32).unsigned_abs();
        d(self.tile_m, other.tile_m)
            + d(self.tile_n, other.tile_n)
            + d(self.tile_k, other.tile_k)
            + d(self.vector, other.vector)
            + d(self.fusion, other.fusion)
            + d(self.pipeline, other.pipeline)
            + u32::from(self.loop_order != other.loop_order)
            + u32::from(self.layout != other.layout)
    }
}

/// Outcome of measuring one candidate on the evaluation engine.
#[derive(Debug, Clone, PartialEq)]
pub struct Measurement {
    /// Total latency across the task's benchmark shapes (seconds).
    pub total_latency_s: f64,
    /// Per-shape latencies (seconds), aligned with the task's shape list.
    pub per_shape_s: Vec<f64>,
    /// Execution counters feeding φ(k) (paper Eq. 4).
    pub counters: Counters,
}

/// The raw execution counters behind φ(k) and h(k).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Counters {
    /// Registers per thread (`cuFuncGetAttribute`).
    pub regs_per_thread: f64,
    /// Shared memory per block, bytes.
    pub smem_per_block: f64,
    /// Threads per block (flattened block dimension).
    pub block_dim: f64,
    /// Theoretical occupancy in `[0,1]`.
    pub occupancy: f64,
    /// Achieved SM throughput, % of peak (NCU `sm__throughput...`).
    pub sm_pct: f64,
    /// Achieved DRAM throughput, % of peak.
    pub dram_pct: f64,
    /// Achieved L2 throughput, % of peak.
    pub l2_pct: f64,
}

/// How a candidate came to exist (provenance edge in the search graph).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Origin {
    /// The task's reference/naive implementation.
    Naive,
    /// Produced by applying `strategy` to frontier kernel `parent`.
    Llm { parent: usize, strategy: Strategy },
}

/// A frontier member: schedule + verification status + measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    /// Index in the frontier (stable; frontier is append-only).
    pub id: usize,
    pub config: KernelConfig,
    pub origin: Origin,
    /// Passed two-stage verification and was benchmarked.
    pub measurement: Measurement,
    /// Iteration at which the candidate was added (0 = initial).
    pub born_at: usize,
}

impl Candidate {
    /// Speedup over a baseline latency (ratio of total runtimes,
    /// paper Appendix H).
    pub fn speedup_vs(&self, baseline_total_s: f64) -> f64 {
        baseline_total_s / self.measurement.total_latency_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_is_legal() {
        let c = KernelConfig::naive();
        assert_eq!(c, c.clamped());
        assert_eq!(c.tiles(), (16, 16, 8));
        assert_eq!(c.vector_width(), 1);
    }

    #[test]
    fn clamp_saturates() {
        let c = KernelConfig {
            tile_m: 200,
            tile_n: 200,
            tile_k: 200,
            vector: 9,
            fusion: 9,
            pipeline: 9,
            loop_order: 9,
            layout: 9,
        }
        .clamped();
        assert_eq!(c.tiles(), (256, 256, 256));
        assert_eq!(c.vector_width(), 8);
        assert_eq!(c.fusion, MAX_FUSION as u8);
        assert_eq!(c.pipeline, MAX_PIPELINE as u8 - 1);
        assert!((c.loop_order as u32) < NUM_LOOP_ORDERS);
        assert!((c.layout as u32) < NUM_LAYOUTS);
    }

    #[test]
    fn code_hash_distinguishes_configs() {
        let a = KernelConfig::naive();
        let mut b = a;
        b.fusion = 1;
        assert_ne!(a.code_hash(), b.code_hash());
        assert_eq!(a.code_hash(), KernelConfig::naive().code_hash());
    }

    #[test]
    fn distance_is_metric_like() {
        let a = KernelConfig::naive();
        let mut b = a;
        b.tile_m = 3;
        b.layout = 1;
        assert_eq!(a.distance(&a), 0);
        assert_eq!(a.distance(&b), b.distance(&a));
        assert_eq!(a.distance(&b), 2 + 1);
    }

    #[test]
    fn speedup_ratio() {
        let c = Candidate {
            id: 0,
            config: KernelConfig::naive(),
            origin: Origin::Naive,
            measurement: Measurement {
                total_latency_s: 0.5,
                per_shape_s: vec![0.5],
                counters: Counters::default(),
            },
            born_at: 0,
        };
        assert!((c.speedup_vs(1.0) - 2.0).abs() < 1e-12);
    }
}
