//! Crash-consistent append plumbing for the store files: length+CRC
//! line framing, a configurable sync discipline, atomic rewrites for
//! compaction, and a deterministic disk-fault injector.
//!
//! ## Frame format (v1)
//!
//! A framed line wraps one JSONL payload:
//!
//! ```text
//! #f1:<len:8 hex>:<crc32:8 hex>:<payload>\n
//! ```
//!
//! `len` is the payload byte length and `crc32` the IEEE CRC of the
//! payload bytes, so a torn or bit-flipped line is *detected* instead
//! of silently parsing as garbage-or-worse. Framing is recognized per
//! line — legacy raw JSON lines (which can never start with `#`) stay
//! readable forever, and files may freely mix framed and raw lines.
//! [`Durability`] picks the write-side encoding: `strict` and `relaxed`
//! frame every appended line (strict additionally fsyncs the ordering-
//! critical files), while `off` writes the legacy raw bytes.
//!
//! ## Fault injection
//!
//! [`StoreFaultPlan`] (`--store-fault
//! kill-at-byte=K,short-write=P,enospc-after=N,seed=S`) sits *under*
//! every store write, in the same seeded-stream style as the serve
//! layer's `FaultPlan`: byte offsets are counted across the store's
//! lifetime, so a test can sweep a kill across every byte boundary of a
//! persist and assert byte-identical recovery.

use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::rng::Rng;

/// Framed-line marker. Raw JSON lines always start with `{`, so the
/// prefix is unambiguous per line.
pub const FRAME_PREFIX: &str = "#f1:";

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE 802.3) over a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xffff_ffffu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

/// Write-side sync discipline (`--durability strict|relaxed|off`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Durability {
    /// Framed appends + fsync after the ordering-critical files (trace
    /// log and checkpoint journal), preserving the flush-order
    /// crash-tolerance contract through a power loss.
    Strict,
    /// Framed appends, no fsync: torn/corrupt lines are detected and
    /// quarantined, but an OS crash may lose the page-cache tail.
    #[default]
    Relaxed,
    /// Legacy raw appends, byte-identical to the pre-framing format.
    Off,
}

impl Durability {
    pub fn parse(s: &str) -> Option<Durability> {
        match s {
            "strict" => Some(Durability::Strict),
            "relaxed" => Some(Durability::Relaxed),
            "off" => Some(Durability::Off),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Durability::Strict => "strict",
            Durability::Relaxed => "relaxed",
            Durability::Off => "off",
        }
    }

    fn framed(&self) -> bool {
        !matches!(self, Durability::Off)
    }
}

/// Frame one payload line (no trailing newline in, none out).
pub fn frame_line(payload: &str) -> String {
    format!(
        "{FRAME_PREFIX}{:08x}:{:08x}:{payload}",
        payload.len(),
        crc32(payload.as_bytes())
    )
}

/// What one stored line decodes to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LineDecode<'a> {
    /// A legacy unframed line, passed through verbatim.
    Raw(&'a str),
    /// A framed line whose length and CRC both verified.
    Framed(&'a str),
    /// A framed line that failed verification (torn tail, bit flip).
    CorruptFrame,
}

/// Decode one line, detecting framing per line.
pub fn decode_line(line: &str) -> LineDecode<'_> {
    let Some(rest) = line.strip_prefix(FRAME_PREFIX) else {
        return LineDecode::Raw(line);
    };
    let ok = || -> Option<&str> {
        let len = u32::from_str_radix(rest.get(0..8)?, 16).ok()?;
        if rest.as_bytes().get(8) != Some(&b':') {
            return None;
        }
        let crc = u32::from_str_radix(rest.get(9..17)?, 16).ok()?;
        if rest.as_bytes().get(17) != Some(&b':') {
            return None;
        }
        let payload = rest.get(18..)?;
        if payload.len() as u32 != len || crc32(payload.as_bytes()) != crc
        {
            return None;
        }
        Some(payload)
    };
    match ok() {
        Some(payload) => LineDecode::Framed(payload),
        None => LineDecode::CorruptFrame,
    }
}

/// Decode a whole file's text: framed lines are verified and unwrapped,
/// raw lines pass through verbatim, corrupt frames are dropped and
/// counted. The result feeds the same lossy JSONL parsers as before.
pub fn decode_text(text: &str) -> (String, usize) {
    if !text.contains(FRAME_PREFIX) {
        return (text.to_string(), 0);
    }
    let mut out = String::with_capacity(text.len());
    let mut corrupt = 0usize;
    for line in text.lines() {
        match decode_line(line) {
            LineDecode::Raw(l) => {
                out.push_str(l);
                out.push('\n');
            }
            LineDecode::Framed(p) => {
                out.push_str(p);
                out.push('\n');
            }
            LineDecode::CorruptFrame => corrupt += 1,
        }
    }
    (out, corrupt)
}

/// Read a store file, decoding frames. Missing files read as empty.
pub fn read_decoded(path: &Path) -> std::io::Result<(String, usize)> {
    match std::fs::read_to_string(path) {
        Ok(text) => Ok(decode_text(&text)),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            Ok((String::new(), 0))
        }
        Err(e) => Err(e),
    }
}

/// Encode payload JSONL text for appending under `durability`.
pub fn encode_text(text: &str, durability: Durability) -> String {
    if !durability.framed() {
        return text.to_string();
    }
    let mut out = String::with_capacity(text.len() + 64);
    for line in text.lines() {
        out.push_str(&frame_line(line));
        out.push('\n');
    }
    out
}

/// Deterministic disk-fault plan
/// (`--store-fault kill-at-byte=K,short-write=P,enospc-after=N,seed=S`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StoreFaultPlan {
    /// Simulated crash: the write reaching cumulative byte offset `K`
    /// lands only its prefix up to `K`, errors, and every later write
    /// fails (the process is "dead" to the disk).
    pub kill_at_byte: Option<u64>,
    /// Per-write probability of a short write (half the buffer lands,
    /// the call errors). Seeded per write index.
    pub short_write_prob: f64,
    /// Simulated disk-full: writes past cumulative byte `N` land their
    /// prefix and fail, but the store stays alive (degraded mode).
    pub enospc_after: Option<u64>,
    /// Seed of the short-write draws.
    pub seed: u64,
}

impl Default for StoreFaultPlan {
    fn default() -> StoreFaultPlan {
        StoreFaultPlan {
            kill_at_byte: None,
            short_write_prob: 0.0,
            enospc_after: None,
            seed: 0,
        }
    }
}

impl StoreFaultPlan {
    /// True when the plan injects nothing.
    pub fn is_none(&self) -> bool {
        self.kill_at_byte.is_none()
            && self.short_write_prob <= 0.0
            && self.enospc_after.is_none()
    }
}

/// Mutable injector state: cumulative bytes written through the store,
/// the per-write draw index, and whether a kill already fired.
#[derive(Debug, Default)]
pub(crate) struct FaultRuntime {
    plan: StoreFaultPlan,
    written: u64,
    ops: u64,
    dead: bool,
}

fn fault_err(msg: &str) -> std::io::Error {
    std::io::Error::other(format!("injected store fault: {msg}"))
}

impl FaultRuntime {
    pub fn new(plan: StoreFaultPlan) -> FaultRuntime {
        FaultRuntime { plan, ..FaultRuntime::default() }
    }

    /// Replace the plan (byte/op counters keep running).
    pub fn set_plan(&mut self, plan: StoreFaultPlan) {
        self.plan = plan;
        self.dead = false;
    }

    /// Write `buf` through the fault plan. On an injected fault the
    /// surviving prefix still lands (that is the point: the next load
    /// sees exactly what a real crash would leave behind).
    fn write(&mut self, f: &mut std::fs::File, buf: &[u8])
             -> std::io::Result<()> {
        if self.plan.is_none() {
            return f.write_all(buf);
        }
        if self.dead {
            return Err(fault_err("kill-at-byte (process dead)"));
        }
        let op = self.ops;
        self.ops += 1;
        let mut limit = buf.len() as u64;
        let mut fault: Option<&'static str> = None;
        if let Some(k) = self.plan.kill_at_byte {
            if self.written + limit > k {
                limit = k.saturating_sub(self.written);
                fault = Some("kill-at-byte");
                self.dead = true;
            }
        }
        if let Some(n) = self.plan.enospc_after {
            if self.written + limit > n {
                limit = n.saturating_sub(self.written);
                fault.get_or_insert("enospc-after (disk full)");
            }
        }
        if fault.is_none() && self.plan.short_write_prob > 0.0 {
            let mut draw =
                Rng::new(self.plan.seed).split("short-write", op);
            if draw.uniform() < self.plan.short_write_prob {
                limit = limit / 2;
                fault = Some("short-write");
            }
        }
        f.write_all(&buf[..limit as usize])?;
        self.written += limit;
        match fault {
            Some(msg) => Err(fault_err(msg)),
            None => Ok(()),
        }
    }
}

/// Append payload JSONL `text` to `path` under `durability`, routed
/// through the fault injector. `sync` requests an fsync after the
/// append (honored only under `strict`).
///
/// If the file's current tail is torn (no trailing newline — a prior
/// crash mid-append), a newline is healed in first so the new records
/// never concatenate onto the torn fragment: acknowledged appends stay
/// parseable no matter what the previous session left behind.
pub(crate) fn append_file(path: &Path, text: &str,
                          durability: Durability,
                          fault: &mut FaultRuntime, sync: bool)
                          -> std::io::Result<()> {
    if text.is_empty() {
        return Ok(());
    }
    let encoded = encode_text(text, durability);
    let mut f = std::fs::OpenOptions::new()
        .read(true)
        .create(true)
        .append(true)
        .open(path)?;
    let len = f.metadata()?.len();
    if len > 0 {
        f.seek(SeekFrom::End(-1))?;
        let mut last = [0u8; 1];
        f.read_exact(&mut last)?;
        if last[0] != b'\n' {
            fault.write(&mut f, b"\n")?;
        }
    }
    fault.write(&mut f, encoded.as_bytes())?;
    if sync && durability == Durability::Strict {
        f.sync_all()?;
    }
    Ok(())
}

/// Atomically replace `path` with `bytes`: write a sibling tmp file,
/// fsync it, rename over the original. Readers never observe a partial
/// rewrite — this is the compaction path (`trace fsck --repair`).
pub fn atomic_rewrite(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // standard check value for "123456789"
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_roundtrip_and_per_line_detection() {
        let payload = r#"{"v":2,"key":"00ff"}"#;
        let framed = frame_line(payload);
        assert!(framed.starts_with(FRAME_PREFIX));
        assert_eq!(decode_line(&framed), LineDecode::Framed(payload));
        assert_eq!(decode_line(payload), LineDecode::Raw(payload));
    }

    #[test]
    fn corrupt_frames_are_detected_not_parsed() {
        let framed = frame_line("{\"a\":1}");
        // torn tail: every strict prefix of a framed line is corrupt
        for cut in FRAME_PREFIX.len()..framed.len() {
            assert_eq!(
                decode_line(&framed[..cut]),
                LineDecode::CorruptFrame,
                "cut at {cut}"
            );
        }
        // bit flip in the payload breaks the CRC
        let flipped = framed.replace("\"a\"", "\"b\"");
        assert_eq!(decode_line(&flipped), LineDecode::CorruptFrame);
    }

    #[test]
    fn decode_text_mixes_raw_and_framed() {
        let mut text = String::new();
        text.push_str("{\"raw\":1}\n");
        text.push_str(&frame_line("{\"framed\":2}"));
        text.push('\n');
        text.push_str(FRAME_PREFIX);
        text.push_str("garbage\n");
        let (decoded, corrupt) = decode_text(&text);
        assert_eq!(decoded, "{\"raw\":1}\n{\"framed\":2}\n");
        assert_eq!(corrupt, 1);
        // pure-raw text passes through byte-identically
        let raw = "{\"a\":1}\n{\"b\":2}\n";
        assert_eq!(decode_text(raw), (raw.to_string(), 0));
    }

    #[test]
    fn encode_off_is_identity() {
        let text = "{\"a\":1}\n{\"b\":2}\n";
        assert_eq!(encode_text(text, Durability::Off), text);
        let framed = encode_text(text, Durability::Relaxed);
        assert_ne!(framed, text);
        assert_eq!(decode_text(&framed), (text.to_string(), 0));
    }

    fn tmp_file(tag: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "kb_durable_{tag}_{}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn kill_at_byte_lands_exact_prefix_then_stays_dead() {
        let p = tmp_file("kill");
        let mut fault = FaultRuntime::new(StoreFaultPlan {
            kill_at_byte: Some(5),
            ..StoreFaultPlan::default()
        });
        let err = append_file(&p, "{\"a\":1}\n", Durability::Off,
                              &mut fault, false)
            .unwrap_err();
        assert!(err.to_string().contains("kill-at-byte"), "{err}");
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"a\":");
        // the "process" is dead: nothing further lands
        assert!(append_file(&p, "x\n", Durability::Off, &mut fault,
                            false)
            .is_err());
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"a\":");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn enospc_fails_but_store_stays_alive() {
        let p = tmp_file("enospc");
        let mut fault = FaultRuntime::new(StoreFaultPlan {
            enospc_after: Some(4),
            ..StoreFaultPlan::default()
        });
        assert!(append_file(&p, "{\"a\":1}\n", Durability::Off,
                            &mut fault, false)
            .is_err());
        // clearing the plan (disk freed) lets appends succeed again
        fault.set_plan(StoreFaultPlan::default());
        append_file(&p, "{\"b\":2}\n", Durability::Off, &mut fault,
                    false)
            .unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        // the torn prefix was healed with a newline before the append
        assert!(text.ends_with("{\"b\":2}\n"), "{text:?}");
        assert!(text.starts_with("{\"a\"\n"), "{text:?}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn short_write_is_seeded_and_deterministic() {
        let plan = StoreFaultPlan {
            short_write_prob: 1.0,
            seed: 9,
            ..StoreFaultPlan::default()
        };
        let p1 = tmp_file("short1");
        let p2 = tmp_file("short2");
        for p in [&p1, &p2] {
            let mut fault = FaultRuntime::new(plan);
            assert!(append_file(p, "{\"a\":1}\n", Durability::Off,
                                &mut fault, false)
                .is_err());
        }
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p2).unwrap()
        );
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
    }

    #[test]
    fn atomic_rewrite_replaces_content() {
        let p = tmp_file("rewrite");
        std::fs::write(&p, "old\n").unwrap();
        atomic_rewrite(&p, b"new\n").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "new\n");
        assert!(!p.with_extension("tmp").exists());
        let _ = std::fs::remove_file(&p);
    }
}
