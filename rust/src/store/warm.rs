//! Warm-start: replay a prior trace into bandit priors and cluster
//! seeds.
//!
//! A trace log records, per task, every `(strategy, reward)` pull and —
//! for accepted candidates — the runtime and execution counters behind
//! the behavioral features φ(k). [`WarmIndex::from_records`] folds a
//! replayed log into [`TaskWarmStart`]s keyed by **(device, llm,
//! task)** — never task alone: strategy profiles differ across
//! hardware (the repo's own Table 10), so a prior learned on H20 must
//! not pre-bias an RTX 4090 run:
//!
//! * **bandit priors** — the chronological reward history, capped at
//!   the most recent [`MAX_WARM_REWARDS`] pulls so a long history
//!   sharpens the arms without extinguishing UCB exploration; the
//!   policy applies them as pre-run arm updates
//!   ([`crate::policy::KernelBand::optimize_warm`]);
//! * **cluster seeds** — K-means centroids fitted (deterministically)
//!   over the historical φ(k) cloud, used as the initialization of the
//!   first re-clustering in place of k-means++
//!   ([`crate::cluster::RustKmeans::cluster_seeded`]).
//!
//! Replay is a pure function of the record list: the same trace always
//! reconstructs bit-identical priors and centroids (property-tested in
//! `rust/tests/prop_store.rs`). Exact-duplicate step records — an
//! append-only log accumulates them when overlapping reruns re-log
//! partially-replayed traces — fold into the priors exactly once.

use std::collections::{HashMap, HashSet};

use crate::cluster::{ClusterBackend, RustKmeans};
use crate::features::{phi, Phi};
use crate::kernel::Measurement;
use crate::rng::Rng;
use crate::store::log::TraceRecord;
use crate::strategy::Strategy;
use crate::util::hash::fnv1a;

/// Reward-history cap per task (most recent pulls win).
pub const MAX_WARM_REWARDS: usize = 64;

/// Per-task warm-start state distilled from a prior trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskWarmStart {
    /// Chronological `(strategy, reward)` pulls (capped, oldest first).
    pub rewards: Vec<(Strategy, f64)>,
    /// Seed centroids for the first re-clustering (empty when the
    /// history is too thin to fit `clusters` centroids).
    pub centroids: Vec<Phi>,
    /// Fastest verified runtime seen historically (diagnostics).
    pub best_runtime_s: f64,
    /// Total steps replayed for this task.
    pub steps: usize,
}

/// The context a prior is valid for: same hardware, same model, same
/// task. `(device, llm, task)` as recorded in the step records.
pub type WarmKey = (String, String, String);

/// All warm-start state, keyed by `(device, llm, task)`.
#[derive(Debug, Clone, Default)]
pub struct WarmIndex {
    tasks: HashMap<WarmKey, TaskWarmStart>,
    /// Cluster count the centroids were fitted for.
    pub clusters: usize,
}

impl WarmIndex {
    /// Distill replayed records into per-(device, llm, task) warm-start
    /// state; `clusters` is the K the centroid seeds are fitted for.
    pub fn from_records(records: &[TraceRecord], clusters: usize) -> WarmIndex {
        // naive reference latency per context (first task header wins;
        // the reference differs per device, so it is keyed like steps)
        let mut naive: HashMap<(&str, &str), f64> = HashMap::new();
        for r in records {
            if let TraceRecord::Task(t) = r {
                naive
                    .entry((&t.device, &t.task))
                    .or_insert(t.naive_latency_s);
            }
        }

        struct Acc {
            rewards: Vec<(Strategy, f64)>,
            phis: Vec<Phi>,
            best_runtime_s: f64,
            steps: usize,
        }
        let mut acc: HashMap<WarmKey, Acc> = HashMap::new();
        // the log is append-only and overlapping reruns may re-log steps
        // they partially replayed; an exact duplicate record is the same
        // deterministic pull and must fold into the priors exactly once
        let mut seen: HashSet<u64> = HashSet::new();
        for r in records {
            let TraceRecord::Step(s) = r else { continue };
            if !seen.insert(fnv1a(r.to_json().dump().as_bytes())) {
                continue;
            }
            let key =
                (s.device.clone(), s.llm.clone(), s.task.clone());
            let a = acc.entry(key).or_insert(Acc {
                rewards: Vec::new(),
                phis: Vec::new(),
                best_runtime_s: f64::INFINITY,
                steps: 0,
            });
            a.steps += 1;
            if let Some(strategy) = s.strategy {
                a.rewards.push((strategy, s.reward));
            }
            if let (Some(runtime), Some(counters)) = (s.runtime_s, &s.counters)
            {
                a.best_runtime_s = a.best_runtime_s.min(runtime);
                let reference = naive
                    .get(&(s.device.as_str(), s.task.as_str()))
                    .copied()
                    .unwrap_or(runtime);
                let m = Measurement {
                    total_latency_s: runtime,
                    per_shape_s: Vec::new(),
                    counters: *counters,
                };
                a.phis.push(phi(&m, reference));
            }
        }

        let kmeans = RustKmeans::default();
        let tasks = acc
            .into_iter()
            .map(|(key, mut a)| {
                if a.rewards.len() > MAX_WARM_REWARDS {
                    let cut = a.rewards.len() - MAX_WARM_REWARDS;
                    a.rewards.drain(..cut);
                }
                let centroids = if clusters > 0 && a.phis.len() >= 2 * clusters
                {
                    // deterministic: the seeding RNG is keyed by the
                    // warm key, never by wall clock or replay order
                    let seed = fnv1a(
                        format!("{}/{}/{}", key.0, key.1, key.2).as_bytes(),
                    );
                    let mut rng = Rng::new(seed).split("warm", 0);
                    kmeans.cluster(&a.phis, clusters, &mut rng).centroids
                } else {
                    Vec::new()
                };
                (
                    key,
                    TaskWarmStart {
                        rewards: a.rewards,
                        centroids,
                        best_runtime_s: a.best_runtime_s,
                        steps: a.steps,
                    },
                )
            })
            .collect();
        WarmIndex { tasks, clusters }
    }

    /// Warm state for exactly this (device, llm, task) context.
    pub fn get(&self, device: &str, llm: &str, task: &str)
               -> Option<&TaskWarmStart> {
        self.tasks.get(&(
            device.to_string(),
            llm.to_string(),
            task.to_string(),
        ))
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Keys in sorted order (deterministic iteration for display).
    pub fn keys(&self) -> Vec<&WarmKey> {
        let mut keys: Vec<&WarmKey> = self.tasks.keys().collect();
        keys.sort_unstable();
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SimEngine;
    use crate::gpu_model::Device;
    use crate::llm::{LlmProfile, SurrogateLlm};
    use crate::policy::{KernelBand, PolicyConfig};
    use crate::store::log::records_for_trace;
    use crate::workload::Suite;

    fn sample_records() -> Vec<TraceRecord> {
        let suite = Suite::full(1);
        let engine = SimEngine::new(Device::H20);
        let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
        let mut cfg = PolicyConfig::default();
        cfg.iterations = 25;
        let trace = KernelBand::new(cfg).optimize(
            &suite.tasks[4],
            &engine,
            &llm,
            &Rng::new(11),
        );
        records_for_trace("KernelBand", "H20", "DeepSeek-V3.2", 11, &trace)
    }

    fn only_entry(idx: &WarmIndex) -> &TaskWarmStart {
        assert_eq!(idx.len(), 1);
        let (device, llm, task) = idx.keys()[0].clone();
        idx.get(&device, &llm, &task).unwrap()
    }

    #[test]
    fn index_collects_rewards_and_steps() {
        let records = sample_records();
        let idx = WarmIndex::from_records(&records, 3);
        let w = only_entry(&idx);
        assert_eq!(w.steps, 25);
        assert_eq!(w.rewards.len(), 25); // Full mode: every step has a strategy
        assert!(w.rewards.iter().all(|&(_, r)| (0.0..=1.0).contains(&r)));
        assert!(w.best_runtime_s.is_finite());
    }

    #[test]
    fn index_keys_by_device_and_llm_not_task_alone() {
        let mut records = sample_records();
        // the same task traced on another device must form its own entry
        for r in sample_records() {
            match r {
                TraceRecord::Task(mut t) => {
                    t.device = "A100".into();
                    records.push(TraceRecord::Task(t));
                }
                TraceRecord::Step(mut s) => {
                    s.device = "A100".into();
                    records.push(TraceRecord::Step(s));
                }
            }
        }
        let idx = WarmIndex::from_records(&records, 3);
        assert_eq!(idx.len(), 2);
        let keys = idx.keys();
        assert_eq!(keys[0].0, "A100");
        assert_eq!(keys[1].0, "H20");
        // priors never mix across devices
        let task = keys[0].2.clone();
        assert_eq!(
            idx.get("H20", "DeepSeek-V3.2", &task).unwrap().rewards.len(),
            25
        );
        assert!(idx.get("H20", "GPT-5", &task).is_none());
    }

    #[test]
    fn index_is_deterministic() {
        let records = sample_records();
        let a = WarmIndex::from_records(&records, 3);
        let b = WarmIndex::from_records(&records, 3);
        assert_eq!(only_entry(&a), only_entry(&b));
    }

    #[test]
    fn reward_history_is_capped_to_most_recent() {
        // one genuinely long run: more distinct pulls than the cap
        let suite = Suite::full(1);
        let engine = SimEngine::new(Device::H20);
        let llm = SurrogateLlm::new(LlmProfile::DeepSeekV32);
        let mut cfg = PolicyConfig::default();
        cfg.iterations = MAX_WARM_REWARDS + 16;
        let trace = KernelBand::new(cfg).optimize(
            &suite.tasks[4],
            &engine,
            &llm,
            &Rng::new(11),
        );
        let records =
            records_for_trace("KernelBand", "H20", "DeepSeek-V3.2", 11, &trace);
        let idx = WarmIndex::from_records(&records, 3);
        let w = only_entry(&idx);
        assert_eq!(w.steps, MAX_WARM_REWARDS + 16);
        assert_eq!(w.rewards.len(), MAX_WARM_REWARDS);
    }

    #[test]
    fn duplicate_step_records_fold_into_priors_once() {
        let mut records = sample_records();
        // an overlapping rerun re-appending the identical trace must not
        // double-count pulls
        let dup: Vec<TraceRecord> = records.clone();
        for _ in 0..10 {
            records.extend(dup.iter().cloned());
        }
        let idx = WarmIndex::from_records(&records, 3);
        let w = only_entry(&idx);
        assert_eq!(w.steps, 25);
        assert_eq!(w.rewards.len(), 25);
    }

    #[test]
    fn thin_history_yields_no_centroids() {
        let records = sample_records();
        // demand more clusters than the φ cloud can support
        let idx = WarmIndex::from_records(&records, 1000);
        assert!(only_entry(&idx).centroids.is_empty());
    }

    #[test]
    fn centroids_form_when_history_is_rich() {
        // run long enough that ≥ 6 candidates are accepted
        let suite = Suite::full(1);
        let engine = SimEngine::new(Device::H20);
        let llm = SurrogateLlm::new(LlmProfile::ClaudeOpus45);
        let mut cfg = PolicyConfig::default();
        cfg.iterations = 40;
        let trace = KernelBand::new(cfg).optimize(
            &suite.tasks[2],
            &engine,
            &llm,
            &Rng::new(5),
        );
        let records =
            records_for_trace("KernelBand", "H20", "Claude Opus 4.5", 5, &trace);
        let accepted =
            trace.records.iter().filter(|r| r.accepted.is_some()).count();
        let idx = WarmIndex::from_records(&records, 3);
        let w = idx.get("H20", "Claude Opus 4.5", &trace.task_name).unwrap();
        if accepted >= 6 {
            assert_eq!(w.centroids.len(), 3);
        }
    }
}
