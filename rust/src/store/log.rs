//! Append-only, versioned JSONL trace log.
//!
//! Every bandit step of every optimization run can be captured as one
//! self-describing JSON line: `{"v": 1, "kind": "task" | "step", ...}`.
//! Records are written through the deterministic [`crate::util::json`]
//! writer (sorted keys, shortest-roundtrip floats), so a log produced by
//! a replayed run is byte-identical to the original.
//!
//! Replay is corruption-tolerant by construction:
//!
//! * a truncated final line (crash mid-append) parses as garbage and is
//!   counted in [`ReplaySummary::corrupt_lines`], never fatal;
//! * records with an unknown `v` are skipped and counted in
//!   [`ReplaySummary::skipped_versions`] — a newer writer's records do
//!   not break an older reader;
//! * unknown `kind`s under a known version are likewise skipped.
//!
//! Determinism under `--threads N`: the experiment runner generates
//! per-(cell, task) traces in parallel but serializes their records in
//! canonical cell order then task order ([`records_for_traces`] is
//! called per cell after the fan-in), so the log bytes are invariant to
//! the thread count.

use crate::kernel::Counters;
use crate::policy::Trace;
use crate::strategy::{Strategy, ALL_STRATEGIES};
use crate::util::json::{parse_lines_lossy, Json};

/// Current trace-record schema version.
pub const TRACE_VERSION: f64 = 1.0;

/// Header emitted once per (cell, task): identifies the run context and
/// the reference point warm-start normalization needs.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskRecord {
    /// Cell label ("KernelBand", "BoN", "optimize", …).
    pub cell: String,
    pub device: String,
    pub llm: String,
    /// Cell seed, hex-encoded on disk (u64 range exceeds JSON f64).
    pub seed: u64,
    pub task_id: usize,
    pub task: String,
    pub difficulty: usize,
    pub naive_latency_s: f64,
    /// Tenant namespace ("t0", "t1", …) for multi-tenant serve runs;
    /// `None` for single-tenant history. Serialized only when present,
    /// so pre-tenant logs keep their exact byte layout.
    pub tenant: Option<String>,
}

/// One bandit step `(parent, strategy) -> child` with its measurement.
///
/// Carries its own device/llm context (not just the cell label): warm
/// start aggregates rewards per `(device, llm, task)` — Table 10 shows
/// strategy profiles differ across devices, so priors must never mix
/// hardware — and a step must stay attributable even when its task
/// header line is the one a crash tore.
#[derive(Debug, Clone, PartialEq)]
pub struct StepRecord {
    pub cell: String,
    pub device: String,
    pub llm: String,
    pub task: String,
    pub t: usize,
    pub cluster: usize,
    /// `None` for free-form (strategy-less) modes.
    pub strategy: Option<Strategy>,
    /// Frontier index of the expanded kernel.
    pub parent: usize,
    /// Content hash of the parent schedule.
    pub parent_hash: u64,
    /// Content hash of the accepted child schedule, if verification
    /// passed.
    pub child_hash: Option<u64>,
    pub call_ok: bool,
    pub exec_ok: bool,
    pub reward: f64,
    pub cost_usd: f64,
    /// Child total latency (seconds) when accepted.
    pub runtime_s: Option<f64>,
    pub best_speedup: f64,
    /// Child execution counters when accepted (feeds φ(k) on replay).
    pub counters: Option<Counters>,
    /// Tenant namespace (see [`TaskRecord::tenant`]).
    pub tenant: Option<String>,
}

/// A parsed trace-log record.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceRecord {
    Task(TaskRecord),
    Step(StepRecord),
}

use super::{
    counters_from_json, counters_to_json as counters_json,
    hex_u64 as hex, parse_hex_u64 as parse_hex,
};

fn strategy_json(s: Option<Strategy>) -> Json {
    match s {
        Some(s) => Json::str(s.name()),
        None => Json::Null,
    }
}

fn strategy_from_json(j: Option<&Json>) -> Option<Strategy> {
    let name = j?.as_str()?;
    ALL_STRATEGIES.iter().copied().find(|s| s.name() == name)
}

fn tenant_from_json(j: &Json) -> Option<String> {
    j.get("tenant").and_then(Json::as_str).map(str::to_string)
}

impl TraceRecord {
    /// Serialize as one JSONL value (sorted keys, deterministic bytes).
    pub fn to_json(&self) -> Json {
        match self {
            TraceRecord::Task(t) => {
                let mut obj = Json::obj(vec![
                    ("v", Json::num(TRACE_VERSION)),
                    ("kind", Json::str("task")),
                    ("cell", Json::str(t.cell.clone())),
                    ("device", Json::str(t.device.clone())),
                    ("llm", Json::str(t.llm.clone())),
                    ("seed", hex(t.seed)),
                    ("task_id", Json::num(t.task_id as f64)),
                    ("task", Json::str(t.task.clone())),
                    ("difficulty", Json::num(t.difficulty as f64)),
                    ("naive_latency_s", Json::num(t.naive_latency_s)),
                ]);
                if let Some(tn) = &t.tenant {
                    obj.insert("tenant", Json::str(tn.clone()));
                }
                obj
            }
            TraceRecord::Step(s) => {
                let mut obj = Json::obj(vec![
                    ("v", Json::num(TRACE_VERSION)),
                    ("kind", Json::str("step")),
                    ("cell", Json::str(s.cell.clone())),
                    ("device", Json::str(s.device.clone())),
                    ("llm", Json::str(s.llm.clone())),
                    ("task", Json::str(s.task.clone())),
                    ("t", Json::num(s.t as f64)),
                    ("cluster", Json::num(s.cluster as f64)),
                    ("strategy", strategy_json(s.strategy)),
                    ("parent", Json::num(s.parent as f64)),
                    ("parent_hash", hex(s.parent_hash)),
                    ("call_ok", Json::Bool(s.call_ok)),
                    ("exec_ok", Json::Bool(s.exec_ok)),
                    ("reward", Json::num(s.reward)),
                    ("cost_usd", Json::num(s.cost_usd)),
                    ("best_speedup", Json::num(s.best_speedup)),
                ]);
                if let Some(h) = s.child_hash {
                    obj.insert("child_hash", hex(h));
                }
                if let Some(r) = s.runtime_s {
                    obj.insert("runtime_s", Json::num(r));
                }
                if let Some(c) = &s.counters {
                    obj.insert("counters", counters_json(c));
                }
                if let Some(tn) = &s.tenant {
                    obj.insert("tenant", Json::str(tn.clone()));
                }
                obj
            }
        }
    }

    /// Decode one parsed JSONL value; `None` for unknown kinds (the
    /// version gate lives in [`replay_values`]).
    pub fn from_json(j: &Json) -> Option<TraceRecord> {
        match j.get("kind")?.as_str()? {
            "task" => Some(TraceRecord::Task(TaskRecord {
                cell: j.str_field("cell").ok()?.to_string(),
                device: j.str_field("device").ok()?.to_string(),
                llm: j.str_field("llm").ok()?.to_string(),
                seed: parse_hex(j.get("seed"))?,
                task_id: j.f64_field("task_id") as usize,
                task: j.str_field("task").ok()?.to_string(),
                difficulty: j.f64_field("difficulty") as usize,
                naive_latency_s: j.f64_field("naive_latency_s"),
                tenant: tenant_from_json(j),
            })),
            "step" => Some(TraceRecord::Step(StepRecord {
                cell: j.str_field("cell").ok()?.to_string(),
                device: j.str_field("device").ok()?.to_string(),
                llm: j.str_field("llm").ok()?.to_string(),
                task: j.str_field("task").ok()?.to_string(),
                t: j.f64_field("t") as usize,
                cluster: j.f64_field("cluster") as usize,
                strategy: strategy_from_json(j.get("strategy")),
                parent: j.f64_field("parent") as usize,
                parent_hash: parse_hex(j.get("parent_hash"))?,
                child_hash: parse_hex(j.get("child_hash")),
                call_ok: j.get("call_ok") == Some(&Json::Bool(true)),
                exec_ok: j.get("exec_ok") == Some(&Json::Bool(true)),
                reward: j.f64_field("reward"),
                cost_usd: j.f64_field("cost_usd"),
                runtime_s: j.get("runtime_s").and_then(Json::as_f64),
                best_speedup: j.f64_field("best_speedup"),
                counters: j.get("counters").map(counters_from_json),
                tenant: tenant_from_json(j),
            })),
            _ => None,
        }
    }

    /// Task name the record belongs to.
    pub fn task_name(&self) -> &str {
        match self {
            TraceRecord::Task(t) => &t.task,
            TraceRecord::Step(s) => &s.task,
        }
    }
}

/// Outcome of replaying a trace log.
#[derive(Debug, Default, Clone)]
pub struct ReplaySummary {
    pub records: Vec<TraceRecord>,
    /// Lines that failed to parse (truncation, corruption).
    pub corrupt_lines: usize,
    /// Well-formed records with an unrecognized `v`.
    pub skipped_versions: usize,
    /// Known-version records with an unrecognized `kind`.
    pub skipped_kinds: usize,
}

impl ReplaySummary {
    pub fn tasks(&self) -> usize {
        self.records
            .iter()
            .filter(|r| matches!(r, TraceRecord::Task(_)))
            .count()
    }

    pub fn steps(&self) -> usize {
        self.records
            .iter()
            .filter(|r| matches!(r, TraceRecord::Step(_)))
            .count()
    }

    /// Per-tenant `(label, task records, step records)` counts, sorted
    /// by tenant label. Records without a tenant namespace (the
    /// single-tenant history) are not listed.
    pub fn tenant_counts(&self) -> Vec<(String, usize, usize)> {
        let mut map: std::collections::BTreeMap<String, (usize, usize)> =
            std::collections::BTreeMap::new();
        for r in &self.records {
            let tenant = match r {
                TraceRecord::Task(t) => t.tenant.as_ref(),
                TraceRecord::Step(s) => s.tenant.as_ref(),
            };
            if let Some(name) = tenant {
                let e = map.entry(name.clone()).or_insert((0, 0));
                match r {
                    TraceRecord::Task(_) => e.0 += 1,
                    TraceRecord::Step(_) => e.1 += 1,
                }
            }
        }
        map.into_iter().map(|(k, (t, s))| (k, t, s)).collect()
    }
}

/// Replay already-parsed JSONL values (see [`replay_text`]).
pub fn replay_values(values: &[Json]) -> ReplaySummary {
    let mut out = ReplaySummary::default();
    for v in values {
        if v.get("v").and_then(Json::as_f64) != Some(TRACE_VERSION) {
            out.skipped_versions += 1;
            continue;
        }
        match TraceRecord::from_json(v) {
            Some(r) => out.records.push(r),
            None => out.skipped_kinds += 1,
        }
    }
    out
}

/// Replay a trace log from its raw text, tolerating truncated or
/// corrupt lines and unknown record versions.
pub fn replay_text(text: &str) -> ReplaySummary {
    let (values, corrupt) = parse_lines_lossy(text);
    let mut summary = replay_values(&values);
    summary.corrupt_lines = corrupt;
    summary
}

/// Replay a trace log file, decoding CRC framing (`store::durable`)
/// first so framed, unframed, and mixed logs all replay. Corrupt
/// frames count toward `corrupt_lines` alongside unparseable JSON.
pub fn replay_file(path: &std::path::Path) -> std::io::Result<ReplaySummary> {
    let (text, frame_corrupt) =
        crate::store::durable::decode_text(&std::fs::read_to_string(path)?);
    let mut summary = replay_text(&text);
    summary.corrupt_lines += frame_corrupt;
    Ok(summary)
}

/// Serialize an optimization [`Trace`] as log records: one task header
/// followed by its steps in iteration order.
pub fn records_for_trace(cell: &str, device: &str, llm: &str, seed: u64,
                         trace: &Trace) -> Vec<TraceRecord> {
    records_for_trace_tenant(cell, None, device, llm, seed, trace)
}

/// [`records_for_trace`] under a tenant namespace: every record carries
/// the tenant label, so `trace stats` can attribute a multi-tenant
/// serve store's history per tenant. `tenant = None` is byte-identical
/// to the pre-tenant encoding.
pub fn records_for_trace_tenant(cell: &str, tenant: Option<&str>,
                                device: &str, llm: &str, seed: u64,
                                trace: &Trace) -> Vec<TraceRecord> {
    let tenant = tenant.map(str::to_string);
    let mut out = Vec::with_capacity(1 + trace.records.len());
    out.push(TraceRecord::Task(TaskRecord {
        cell: cell.to_string(),
        device: device.to_string(),
        llm: llm.to_string(),
        seed,
        task_id: trace.task_id,
        task: trace.task_name.clone(),
        difficulty: trace.difficulty.level(),
        naive_latency_s: trace.naive_latency_s,
        tenant: tenant.clone(),
    }));
    for r in &trace.records {
        let child = r.accepted.map(|id| &trace.candidates[id]);
        out.push(TraceRecord::Step(StepRecord {
            cell: cell.to_string(),
            device: device.to_string(),
            llm: llm.to_string(),
            task: trace.task_name.clone(),
            t: r.t,
            cluster: r.cluster,
            strategy: r.strategy,
            parent: r.parent,
            parent_hash: trace.candidates[r.parent].config.code_hash(),
            child_hash: child.map(|c| c.config.code_hash()),
            call_ok: r.verdict.call_ok,
            exec_ok: r.verdict.exec_ok,
            reward: r.reward,
            cost_usd: r.cost_usd,
            runtime_s: child.map(|c| c.measurement.total_latency_s),
            best_speedup: r.best_speedup_so_far,
            counters: child.map(|c| c.measurement.counters),
            tenant: tenant.clone(),
        }));
    }
    out
}

/// Render records as JSONL text (one compact line per record, trailing
/// newline). Byte-deterministic.
pub fn to_jsonl(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    for r in records {
        out.push_str(&r.to_json().dump());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_step() -> StepRecord {
        StepRecord {
            cell: "KernelBand".into(),
            device: "H20".into(),
            llm: "DeepSeek-V3.2".into(),
            task: "matmul_0".into(),
            t: 3,
            cluster: 1,
            strategy: Some(Strategy::Fusion),
            parent: 0,
            parent_hash: 0xdead_beef_0123_4567,
            child_hash: Some(0xffff_0000_aaaa_5555),
            call_ok: true,
            exec_ok: true,
            reward: 0.25,
            cost_usd: 0.013,
            runtime_s: Some(0.0042),
            best_speedup: 1.7,
            counters: Some(Counters {
                regs_per_thread: 64.0,
                smem_per_block: 16384.0,
                block_dim: 256.0,
                occupancy: 0.5,
                sm_pct: 41.0,
                dram_pct: 72.5,
                l2_pct: 30.25,
            }),
            tenant: None,
        }
    }

    fn sample_task() -> TaskRecord {
        TaskRecord {
            cell: "KernelBand".into(),
            device: "H20".into(),
            llm: "DeepSeek-V3.2".into(),
            seed: u64::MAX - 3, // above 2^53: exercises hex encoding
            task_id: 17,
            task: "matmul_0".into(),
            difficulty: 4,
            naive_latency_s: 0.031,
            tenant: None,
        }
    }

    #[test]
    fn record_roundtrip_is_exact() {
        for rec in [
            TraceRecord::Task(sample_task()),
            TraceRecord::Step(sample_step()),
            TraceRecord::Step(StepRecord {
                strategy: None,
                child_hash: None,
                runtime_s: None,
                counters: None,
                call_ok: false,
                exec_ok: false,
                ..sample_step()
            }),
        ] {
            let line = rec.to_json().dump();
            let parsed = crate::util::json::parse(&line).unwrap();
            assert_eq!(TraceRecord::from_json(&parsed).unwrap(), rec);
        }
    }

    #[test]
    fn tenant_namespace_roundtrips_and_counts() {
        let mut task = sample_task();
        task.tenant = Some("t1".into());
        let mut step = sample_step();
        step.tenant = Some("t1".into());
        let mut step0 = sample_step();
        step0.tenant = Some("t0".into());
        let recs = vec![
            TraceRecord::Task(task),
            TraceRecord::Step(step),
            TraceRecord::Step(step0),
            TraceRecord::Step(sample_step()), // un-namespaced history
        ];
        for rec in &recs {
            let line = rec.to_json().dump();
            let parsed = crate::util::json::parse(&line).unwrap();
            assert_eq!(&TraceRecord::from_json(&parsed).unwrap(), rec);
        }
        let summary = replay_text(&to_jsonl(&recs));
        assert_eq!(
            summary.tenant_counts(),
            vec![("t0".to_string(), 0, 1), ("t1".to_string(), 1, 1)]
        );
        // a tenant-free record serializes the pre-tenant bytes exactly
        let plain = TraceRecord::Step(sample_step()).to_json().dump();
        assert!(!plain.contains("tenant"));
    }

    #[test]
    fn replay_skips_unknown_versions_and_kinds() {
        let mut text = to_jsonl(&[TraceRecord::Step(sample_step())]);
        text.push_str("{\"v\":99,\"kind\":\"step\",\"future\":true}\n");
        text.push_str("{\"v\":1,\"kind\":\"hologram\"}\n");
        let summary = replay_text(&text);
        assert_eq!(summary.records.len(), 1);
        assert_eq!(summary.skipped_versions, 1);
        assert_eq!(summary.skipped_kinds, 1);
        assert_eq!(summary.corrupt_lines, 0);
    }

    #[test]
    fn replay_recovers_before_truncated_tail() {
        let full = to_jsonl(&[
            TraceRecord::Task(sample_task()),
            TraceRecord::Step(sample_step()),
        ]);
        // crash mid-append: cut the final line in half
        let cut = &full[..full.len() - 40];
        let summary = replay_text(cut);
        assert_eq!(summary.records.len(), 1);
        assert_eq!(summary.corrupt_lines, 1);
        assert_eq!(summary.tasks(), 1);
        assert_eq!(summary.steps(), 0);
    }

    #[test]
    fn jsonl_bytes_are_deterministic() {
        let recs = vec![
            TraceRecord::Task(sample_task()),
            TraceRecord::Step(sample_step()),
        ];
        assert_eq!(to_jsonl(&recs), to_jsonl(&recs));
        // and replay . serialize is the identity on bytes
        let summary = replay_text(&to_jsonl(&recs));
        assert_eq!(to_jsonl(&summary.records), to_jsonl(&recs));
    }

    #[test]
    fn seed_survives_full_u64_range() {
        let rec = TraceRecord::Task(sample_task());
        let line = rec.to_json().dump();
        let parsed = crate::util::json::parse(&line).unwrap();
        match TraceRecord::from_json(&parsed).unwrap() {
            TraceRecord::Task(t) => assert_eq!(t.seed, u64::MAX - 3),
            _ => unreachable!(),
        }
    }
}
