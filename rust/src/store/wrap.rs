//! Cache-decorated evaluation engine and LLM backend.
//!
//! [`CachedEngine`] and [`CachedLlm`] are transparent decorators: every
//! policy and baseline is generic over [`EvalEngine`] / [`LlmBackend`],
//! so wrapping the substrates is all it takes to route the entire
//! system — Algorithm 1, BoN, GEAK, the experiment grids — through the
//! persistent store.
//!
//! Transparency is literal: a cache hit returns the bit-identical
//! [`Measurement`]/[`Proposal`] the wrapped substrate would have
//! produced (keys include the call's RNG seed lineage), and a miss
//! delegates and records. The only observable differences are the
//! store's hit/miss counters and the work skipped, which is what the
//! warm-vs-cold acceptance test asserts on.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::engine::EvalEngine;
use crate::gpu_model::GpuSim;
use crate::kernel::{KernelConfig, Measurement};
use crate::llm::{accounting, LlmBackend, ModelSpec, Proposal, ProposalRequest};
use crate::rng::Rng;
use crate::store::cache::{measurement_key, proposal_key};
use crate::store::TraceStore;
use crate::strategy::Strategy;
use crate::workload::TaskSpec;

/// [`EvalEngine`] decorator: content-addressed measurement cache.
pub struct CachedEngine<E: EvalEngine> {
    inner: E,
    store: Arc<TraceStore>,
    device_fp: u64,
    /// Misses served by *this instance* (the store's counters are
    /// session-global; callers that wrap one engine per work item use
    /// this to tell which items did new work).
    local_sims: AtomicU64,
}

impl<E: EvalEngine> CachedEngine<E> {
    pub fn new(inner: E, store: Arc<TraceStore>) -> CachedEngine<E> {
        let device_fp = inner.gpu().fingerprint();
        CachedEngine { inner, store, device_fp, local_sims: AtomicU64::new(0) }
    }

    /// Simulated (non-cached) measurements this instance performed.
    pub fn local_sims(&self) -> u64 {
        self.local_sims.load(Ordering::Relaxed)
    }
}

impl<E: EvalEngine> EvalEngine for CachedEngine<E> {
    fn gpu(&self) -> &GpuSim {
        self.inner.gpu()
    }

    fn measure(&self, task: &TaskSpec, cfg: &KernelConfig, rng: &mut Rng)
               -> Measurement {
        let key = measurement_key(task, cfg, self.device_fp, rng);
        if let Some(m) = self.store.lookup_measurement(key) {
            self.store.stats.measure_hits.fetch_add(1, Ordering::Relaxed);
            self.store.obs_measure(true, 1);
            return m;
        }
        let m = self.inner.measure(task, cfg, rng);
        self.store.stats.measure_sims.fetch_add(1, Ordering::Relaxed);
        self.store.obs_measure(false, 1);
        self.local_sims.fetch_add(1, Ordering::Relaxed);
        self.store.insert_measurement(key, &m);
        m
    }

    /// Batch-aware cache path: all keys are probed first, and only the
    /// misses go through the wrapped engine — in one fused
    /// `measure_batch` call — so a warm run stays pure lookups even at
    /// `--batch N` and a cold run still amortizes the shape loop
    /// across its misses.
    fn measure_batch(&self, task: &TaskSpec, cfgs: &[KernelConfig],
                     rngs: &mut [Rng]) -> Vec<Measurement> {
        debug_assert_eq!(cfgs.len(), rngs.len());
        let keys: Vec<u64> = cfgs
            .iter()
            .zip(rngs.iter())
            .map(|(cfg, rng)| {
                measurement_key(task, cfg, self.device_fp, rng)
            })
            .collect();
        let mut out: Vec<Option<Measurement>> =
            keys.iter().map(|&k| self.store.lookup_measurement(k)).collect();
        let hits = out.iter().filter(|m| m.is_some()).count() as u64;
        if hits > 0 {
            self.store.stats.measure_hits.fetch_add(hits, Ordering::Relaxed);
            self.store.obs_measure(true, hits);
        }
        let miss_idx: Vec<usize> = out
            .iter()
            .enumerate()
            .filter(|(_, m)| m.is_none())
            .map(|(i, _)| i)
            .collect();
        if !miss_idx.is_empty() {
            let miss_cfgs: Vec<KernelConfig> =
                miss_idx.iter().map(|&i| cfgs[i]).collect();
            // `measure` only ever *splits* from the caller's stream, so
            // cloning the miss streams preserves semantics exactly
            let mut miss_rngs: Vec<Rng> =
                miss_idx.iter().map(|&i| rngs[i].clone()).collect();
            let measured =
                self.inner.measure_batch(task, &miss_cfgs, &mut miss_rngs);
            let n = miss_idx.len() as u64;
            self.store.stats.measure_sims.fetch_add(n, Ordering::Relaxed);
            self.store.obs_measure(false, n);
            self.local_sims.fetch_add(n, Ordering::Relaxed);
            for (&i, m) in miss_idx.iter().zip(measured) {
                self.store.insert_measurement(keys[i], &m);
                out[i] = Some(m);
            }
        }
        out.into_iter().map(|m| m.expect("filled above")).collect()
    }
}

/// [`LlmBackend`] decorator: content-addressed proposal cache.
///
/// A hit skips the (simulated) LLM round-trip entirely; the bypassed
/// spend and serial latency ([`crate::llm::accounting::bypass_savings`])
/// are credited to the store's [`crate::store::StoreStats`] counters so
/// the Fig.-3/4 cost model can report what the cache saved.
pub struct CachedLlm<L: LlmBackend> {
    inner: L,
    store: Arc<TraceStore>,
    /// Misses served by *this instance* (see [`CachedEngine::local_sims`]).
    local_sims: AtomicU64,
}

impl<L: LlmBackend> CachedLlm<L> {
    pub fn new(inner: L, store: Arc<TraceStore>) -> CachedLlm<L> {
        CachedLlm { inner, store, local_sims: AtomicU64::new(0) }
    }

    /// Simulated (non-cached) proposals this instance performed.
    pub fn local_sims(&self) -> u64 {
        self.local_sims.load(Ordering::Relaxed)
    }
}

impl<L: LlmBackend> LlmBackend for CachedLlm<L> {
    fn spec(&self) -> &ModelSpec {
        self.inner.spec()
    }

    fn propose(&self, req: &ProposalRequest<'_>, rng: &mut Rng) -> Proposal {
        let key = proposal_key(self.inner.spec().name, req, rng);
        if let Some(p) = self.store.lookup_proposal(key) {
            let stats = &self.store.stats;
            stats.llm_hits.fetch_add(1, Ordering::Relaxed);
            let saved = accounting::bypass_savings(&p);
            stats
                .saved_cost_micro_usd
                .fetch_add(saved.cost_micro_usd, Ordering::Relaxed);
            stats
                .saved_serial_llm_ms
                .fetch_add(saved.serial_ms, Ordering::Relaxed);
            self.store.obs_llm(true);
            return p;
        }
        let p = self.inner.propose(req, rng);
        self.store.stats.llm_sims.fetch_add(1, Ordering::Relaxed);
        self.store.obs_llm(false);
        self.local_sims.fetch_add(1, Ordering::Relaxed);
        self.store.insert_proposal(key, &p);
        p
    }

    fn select_strategy(&self, task: &TaskSpec, rng: &mut Rng) -> Strategy {
        // strategy selection is a cheap single call with no compile/exec
        // behind it; delegating keeps the ablation's behavior identical
        self.inner.select_strategy(task, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SimEngine;
    use crate::gpu_model::Device;
    use crate::llm::{LlmProfile, PromptMode, SurrogateLlm};
    use crate::workload::Suite;

    #[test]
    fn engine_hit_returns_bit_identical_measurement() {
        let suite = Suite::full(1);
        let store = Arc::new(TraceStore::in_memory());
        let engine =
            CachedEngine::new(SimEngine::new(Device::H20), store.clone());
        let cfg = KernelConfig::naive();
        let cold =
            engine.measure(&suite.tasks[0], &cfg, &mut Rng::new(1).split("m", 0));
        let warm =
            engine.measure(&suite.tasks[0], &cfg, &mut Rng::new(1).split("m", 0));
        assert_eq!(cold.total_latency_s.to_bits(), warm.total_latency_s.to_bits());
        assert_eq!(store.stats.measure_sims.load(Ordering::Relaxed), 1);
        assert_eq!(store.stats.measure_hits.load(Ordering::Relaxed), 1);
        // a different noise lineage is a different address
        let other =
            engine.measure(&suite.tasks[0], &cfg, &mut Rng::new(1).split("m", 1));
        assert_eq!(store.stats.measure_sims.load(Ordering::Relaxed), 2);
        assert!(other.total_latency_s > 0.0);
    }

    #[test]
    fn measure_batch_probes_cache_and_fuses_misses() {
        let suite = Suite::full(1);
        let store = Arc::new(TraceStore::in_memory());
        let engine =
            CachedEngine::new(SimEngine::new(Device::H20), store.clone());
        let task = &suite.tasks[3];
        let cfgs = [KernelConfig::naive(), {
            let mut c = KernelConfig::naive();
            c.fusion = 1;
            c
        }];
        let mk_rngs = || -> Vec<Rng> {
            (0..2u64).map(|i| Rng::new(4).split("m", i)).collect()
        };
        // cold: both slots simulated through one fused inner call
        let cold = engine.measure_batch(task, &cfgs, &mut mk_rngs());
        assert_eq!(store.stats.measure_sims.load(Ordering::Relaxed), 2);
        assert_eq!(store.stats.measure_hits.load(Ordering::Relaxed), 0);
        // warm: pure lookups, bit-identical results
        let warm = engine.measure_batch(task, &cfgs, &mut mk_rngs());
        assert_eq!(store.stats.measure_sims.load(Ordering::Relaxed), 2);
        assert_eq!(store.stats.measure_hits.load(Ordering::Relaxed), 2);
        for (c, w) in cold.iter().zip(&warm) {
            assert_eq!(c.total_latency_s.to_bits(),
                       w.total_latency_s.to_bits());
        }
        // partial: one cached slot + one new slot → exactly one sim
        let cfgs3 = [cfgs[0], cfgs[1], {
            let mut c = KernelConfig::naive();
            c.vector = 2;
            c
        }];
        let mut rngs3: Vec<Rng> =
            (0..3u64).map(|i| Rng::new(4).split("m", i)).collect();
        let mixed = engine.measure_batch(task, &cfgs3, &mut rngs3);
        assert_eq!(store.stats.measure_sims.load(Ordering::Relaxed), 3);
        assert_eq!(store.stats.measure_hits.load(Ordering::Relaxed), 4);
        assert_eq!(mixed[0].total_latency_s.to_bits(),
                   cold[0].total_latency_s.to_bits());
        // batch results match what standalone measure would produce
        let solo = engine.measure(
            task, &cfgs3[2], &mut Rng::new(4).split("m", 2),
        );
        assert_eq!(mixed[2].total_latency_s.to_bits(),
                   solo.total_latency_s.to_bits());
    }

    #[test]
    fn llm_hit_skips_round_trip_and_credits_savings() {
        let suite = Suite::full(1);
        let store = Arc::new(TraceStore::in_memory());
        let sim = GpuSim::new(Device::H20);
        let llm = CachedLlm::new(
            SurrogateLlm::new(LlmProfile::DeepSeekV32),
            store.clone(),
        );
        let parent = KernelConfig::naive();
        let req = ProposalRequest {
            task: &suite.tasks[0],
            parent: &parent,
            mode: PromptMode::Strategy(Strategy::Fusion),
            sim: &sim,
            iterative: true,
        };
        let cold = llm.propose(&req, &mut Rng::new(5).split("gen", 1));
        let warm = llm.propose(&req, &mut Rng::new(5).split("gen", 1));
        assert_eq!(cold.outcome, warm.outcome);
        assert_eq!(cold.config, warm.config);
        assert_eq!(cold.cost_usd.to_bits(), warm.cost_usd.to_bits());
        assert_eq!(store.stats.llm_sims.load(Ordering::Relaxed), 1);
        assert_eq!(store.stats.llm_hits.load(Ordering::Relaxed), 1);
        assert!(store.stats.saved_cost_usd() > 0.0);
        assert!(store.stats.saved_serial_llm_s() > 0.0);
    }
}
