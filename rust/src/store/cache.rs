//! Content-addressed caches for measurements and LLM proposals.
//!
//! Keys are 64-bit digests ([`crate::util::hash::KeyHasher`]) over
//! everything that determines the result bit for bit:
//!
//! * **measurements** — task fingerprint, schedule `code_hash`, device
//!   fingerprint, and the measurement RNG's seed lineage (simulator
//!   noise is part of the result, so the noise stream is part of the
//!   address);
//! * **proposals** — model name, task fingerprint, parent schedule,
//!   prompt mode, device fingerprint, and the generation RNG lineage.
//!
//! Because every experiment derives its RNG from split seed lineages,
//! a re-run of the same grid reconstructs the exact same keys, so a
//! populated cache turns the whole run into lookups while keeping the
//! artifacts byte-identical to the cold run. Entries serialize to JSONL
//! through [`crate::util::json`], whose shortest-roundtrip float
//! formatting guarantees `parse(dump(x)) == x` — a reloaded measurement
//! is bit-identical to the one simulated.

use std::collections::HashMap;

use crate::kernel::{Counters, KernelConfig, Measurement};
use crate::llm::{GenOutcome, Proposal, PromptMode, ProposalRequest};
use crate::rng::Rng;
use crate::util::hash::KeyHasher;
use crate::util::json::{parse_lines_lossy, Json};
use crate::workload::TaskSpec;

/// Cache-record schema version (bumped on layout changes *and* on
/// simulator-semantics changes; unknown versions are skipped at load,
/// mirroring the trace log).
///
/// v2: `GpuSim::evaluate` dropped the algebraically-cancelled `/ t * t`
/// counter time-weighting, which shifts sm/dram/l2 percentages by ulps.
/// Measurements recorded under v1 would replay old-bit counters next to
/// fresh new-bit ones and silently break the cold/warm byte-identity
/// invariant, so v1 entries are invalidated wholesale.
pub const CACHE_VERSION: f64 = 2.0;

/// Content address of one measurement.
pub fn measurement_key(task: &TaskSpec, cfg: &KernelConfig, device_fp: u64,
                       rng: &Rng) -> u64 {
    KeyHasher::new("measure")
        .u64(task.fingerprint())
        .u64(cfg.code_hash())
        .u64(device_fp)
        .u64(rng.fingerprint())
        .finish()
}

/// Content address of one LLM proposal.
pub fn proposal_key(model: &str, req: &ProposalRequest<'_>, rng: &Rng) -> u64 {
    let mut h = KeyHasher::new("proposal")
        .str(model)
        .u64(req.task.fingerprint())
        .u64(req.parent.code_hash())
        .u64(req.sim.fingerprint())
        .u64(req.iterative as u64)
        .u64(rng.fingerprint());
    h = match req.mode {
        PromptMode::Strategy(s) => h.u64(1).u64(s.index() as u64),
        PromptMode::FreeForm => h.u64(2),
        PromptMode::RawProfiling(sig) => {
            h.u64(3).f64(sig.sm_pct).f64(sig.dram_pct).f64(sig.l2_pct)
        }
    };
    h.finish()
}

use super::{
    counters_from_json, counters_to_json, hex_u64 as hex,
    parse_hex_u64 as parse_hex,
};

pub(crate) fn config_to_arr(c: &KernelConfig) -> Json {
    Json::Arr(
        [c.tile_m, c.tile_n, c.tile_k, c.vector, c.fusion, c.pipeline,
         c.loop_order, c.layout]
            .iter()
            .map(|&v| Json::num(v as f64))
            .collect(),
    )
}

pub(crate) fn config_from_arr(j: &Json) -> Option<KernelConfig> {
    let a = j.as_arr()?;
    if a.len() != 8 {
        return None;
    }
    let f = |i: usize| a[i].as_f64().unwrap_or(0.0) as u8;
    Some(KernelConfig {
        tile_m: f(0),
        tile_n: f(1),
        tile_k: f(2),
        vector: f(3),
        fusion: f(4),
        pipeline: f(5),
        loop_order: f(6),
        layout: f(7),
    })
}

/// One generic content-addressed cache with persistence bookkeeping:
/// entries inserted since the last flush are tracked so persistence can
/// append exactly the new records (the on-disk file is append-only).
#[derive(Debug)]
pub struct ContentCache<V> {
    entries: HashMap<u64, V>,
    dirty: Vec<u64>,
}

// manual impl: the derive would demand `V: Default`, which cached
// payloads (Measurement, Proposal) do not and should not implement
impl<V> Default for ContentCache<V> {
    fn default() -> Self {
        ContentCache { entries: HashMap::new(), dirty: Vec::new() }
    }
}

impl<V: Clone> ContentCache<V> {
    pub fn get(&self, key: u64) -> Option<V> {
        self.entries.get(&key).cloned()
    }

    pub fn insert(&mut self, key: u64, value: V) {
        if self.entries.insert(key, value).is_none() {
            self.dirty.push(key);
        }
    }

    /// Insert at load time (not marked dirty).
    pub fn insert_loaded(&mut self, key: u64, value: V) {
        self.entries.insert(key, value);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Re-mark keys dirty after a failed append. `take_dirty` retains
    /// the entries themselves, so restoring just the keys is enough to
    /// make the next persist retry the same records.
    pub fn restore_dirty(&mut self, keys: impl IntoIterator<Item = u64>) {
        self.dirty.extend(keys);
    }

    /// Drain the new entries, sorted by key so the appended bytes are
    /// deterministic regardless of insertion (thread) order.
    pub fn take_dirty(&mut self) -> Vec<(u64, V)> {
        let mut keys = std::mem::take(&mut self.dirty);
        keys.sort_unstable();
        keys.dedup();
        keys.into_iter()
            .filter_map(|k| self.entries.get(&k).map(|v| (k, v.clone())))
            .collect()
    }
}

// --- measurement serialization ---------------------------------------------

/// Serialize one measurement cache entry as a JSONL value.
pub fn measurement_record(key: u64, m: &Measurement) -> Json {
    Json::obj(vec![
        ("v", Json::num(CACHE_VERSION)),
        ("key", hex(key)),
        ("total_s", Json::num(m.total_latency_s)),
        (
            "shapes",
            Json::Arr(m.per_shape_s.iter().map(|&s| Json::num(s)).collect()),
        ),
        ("counters", counters_to_json(&m.counters)),
    ])
}

/// Decode one measurement cache entry.
pub fn measurement_from_record(j: &Json) -> Option<(u64, Measurement)> {
    if j.get("v").and_then(Json::as_f64) != Some(CACHE_VERSION) {
        return None;
    }
    let key = parse_hex(j.get("key"))?;
    let per_shape_s = j
        .get("shapes")?
        .as_arr()?
        .iter()
        .map(|s| s.as_f64().unwrap_or(0.0))
        .collect();
    Some((
        key,
        Measurement {
            total_latency_s: j.get("total_s")?.as_f64()?,
            per_shape_s,
            counters: counters_from_json(j.get("counters")?),
        },
    ))
}

// --- proposal serialization ------------------------------------------------

pub(crate) fn outcome_str(o: GenOutcome) -> &'static str {
    match o {
        GenOutcome::Ok => "ok",
        GenOutcome::CompileError => "compile_error",
        GenOutcome::WrongOutput => "wrong_output",
    }
}

pub(crate) fn outcome_from_str(s: &str) -> Option<GenOutcome> {
    match s {
        "ok" => Some(GenOutcome::Ok),
        "compile_error" => Some(GenOutcome::CompileError),
        "wrong_output" => Some(GenOutcome::WrongOutput),
        _ => None,
    }
}

/// Serialize one proposal cache entry as a JSONL value.
pub fn proposal_record(key: u64, p: &Proposal) -> Json {
    Json::obj(vec![
        ("v", Json::num(CACHE_VERSION)),
        ("key", hex(key)),
        ("outcome", Json::str(outcome_str(p.outcome))),
        ("config", config_to_arr(&p.config)),
        ("tokens_in", Json::num(p.tokens_in as f64)),
        ("tokens_out", Json::num(p.tokens_out as f64)),
        ("cost_usd", Json::num(p.cost_usd)),
        ("latency_s", Json::num(p.latency_s)),
    ])
}

/// Decode one proposal cache entry.
pub fn proposal_from_record(j: &Json) -> Option<(u64, Proposal)> {
    if j.get("v").and_then(Json::as_f64) != Some(CACHE_VERSION) {
        return None;
    }
    let key = parse_hex(j.get("key"))?;
    Some((
        key,
        Proposal {
            outcome: outcome_from_str(j.str_field("outcome").ok()?)?,
            config: config_from_arr(j.get("config")?)?,
            tokens_in: j.f64_field("tokens_in") as u64,
            tokens_out: j.f64_field("tokens_out") as u64,
            cost_usd: j.get("cost_usd")?.as_f64()?,
            latency_s: j.get("latency_s")?.as_f64()?,
        },
    ))
}

/// Load a cache file's JSONL text into entries via `decode`, skipping
/// corrupt lines and unknown versions. Returns entries + skipped count.
pub fn load_entries<V>(
    text: &str,
    decode: impl Fn(&Json) -> Option<(u64, V)>,
) -> (Vec<(u64, V)>, usize) {
    let (values, corrupt) = parse_lines_lossy(text);
    let mut skipped = corrupt;
    let mut out = Vec::with_capacity(values.len());
    for v in &values {
        match decode(v) {
            Some(kv) => out.push(kv),
            None => skipped += 1,
        }
    }
    (out, skipped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_model::{Device, GpuSim};
    use crate::workload::Suite;

    fn sample_measurement() -> Measurement {
        Measurement {
            total_latency_s: 0.001234567890123,
            per_shape_s: vec![0.0004, 0.0008345678901234],
            counters: Counters {
                regs_per_thread: 96.0,
                smem_per_block: 49152.0,
                block_dim: 512.0,
                occupancy: 0.625,
                sm_pct: 33.33333333333333,
                dram_pct: 81.0,
                l2_pct: 12.5,
            },
        }
    }

    #[test]
    fn measurement_roundtrip_is_bit_exact() {
        let m = sample_measurement();
        let rec = measurement_record(0xabcd_ef01_2345_6789, &m);
        let line = rec.dump();
        let parsed = crate::util::json::parse(&line).unwrap();
        let (key, back) = measurement_from_record(&parsed).unwrap();
        assert_eq!(key, 0xabcd_ef01_2345_6789);
        assert_eq!(back.total_latency_s.to_bits(), m.total_latency_s.to_bits());
        assert_eq!(back.per_shape_s, m.per_shape_s);
        assert_eq!(back.counters.sm_pct.to_bits(), m.counters.sm_pct.to_bits());
        assert_eq!(back.counters.occupancy.to_bits(),
                   m.counters.occupancy.to_bits());
    }

    #[test]
    fn proposal_roundtrip_is_exact() {
        let p = Proposal {
            outcome: GenOutcome::WrongOutput,
            config: KernelConfig {
                tile_m: 3,
                tile_n: 4,
                tile_k: 2,
                vector: 1,
                fusion: 2,
                pipeline: 3,
                loop_order: 5,
                layout: 1,
            },
            tokens_in: 20_800,
            tokens_out: 11_200,
            cost_usd: 0.01234567,
            latency_s: 700.125,
        };
        let rec = proposal_record(7, &p);
        let parsed = crate::util::json::parse(&rec.dump()).unwrap();
        let (key, back) = proposal_from_record(&parsed).unwrap();
        assert_eq!(key, 7);
        assert_eq!(back.outcome, p.outcome);
        assert_eq!(back.config, p.config);
        assert_eq!(back.tokens_in, p.tokens_in);
        assert_eq!(back.tokens_out, p.tokens_out);
        assert_eq!(back.cost_usd.to_bits(), p.cost_usd.to_bits());
    }

    #[test]
    fn unknown_cache_version_is_skipped() {
        let text = "{\"v\":9,\"key\":\"00000000000000ff\",\"total_s\":1}\n";
        let (entries, skipped) = load_entries(text, measurement_from_record);
        assert!(entries.is_empty());
        assert_eq!(skipped, 1);
    }

    #[test]
    fn keys_separate_devices_tasks_and_lineages() {
        let suite = Suite::full(1);
        let cfg = KernelConfig::naive();
        let h20 = GpuSim::new(Device::H20).fingerprint();
        let a100 = GpuSim::new(Device::A100).fingerprint();
        let rng = Rng::new(3).split("m", 1);
        let k0 = measurement_key(&suite.tasks[0], &cfg, h20, &rng);
        assert_ne!(k0, measurement_key(&suite.tasks[1], &cfg, h20, &rng));
        assert_ne!(k0, measurement_key(&suite.tasks[0], &cfg, a100, &rng));
        assert_ne!(
            k0,
            measurement_key(&suite.tasks[0], &cfg, h20, &Rng::new(3).split("m", 2))
        );
        // and the address is stable across calls
        assert_eq!(k0, measurement_key(&suite.tasks[0], &cfg, h20, &rng));
    }

    #[test]
    fn content_cache_tracks_dirty_entries_sorted() {
        let mut c: ContentCache<u32> = ContentCache::default();
        c.insert(9, 90);
        c.insert(3, 30);
        c.insert(9, 91); // overwrite: not re-marked dirty
        c.insert_loaded(1, 10); // loaded: never dirty
        let dirty = c.take_dirty();
        assert_eq!(dirty.iter().map(|&(k, _)| k).collect::<Vec<_>>(), vec![3, 9]);
        assert!(c.take_dirty().is_empty());
        assert_eq!(c.len(), 3);
        assert_eq!(c.get(9), Some(91));
    }
}
