//! Persistent trace store: content-addressed kernel cache +
//! append-only trace log + cross-session warm-start.
//!
//! Every `repro`/`serve` run used to start cold and throw its
//! optimization history away at exit. This subsystem makes that history
//! durable and reusable:
//!
//! * [`cache`] — **content-addressed caches**: measurements keyed by
//!   `(task, schedule, device, noise lineage)` and LLM proposals keyed
//!   by `(model, task, parent schedule, prompt mode, generation
//!   lineage)`. Any kernel already compiled + benchmarked anywhere in a
//!   previous grid is a lookup instead of a simulated compile/exec, and
//!   a cached proposal skips the (simulated) LLM round-trip entirely.
//! * [`log`] — an **append-only JSONL trace log** with versioned
//!   records and corruption-tolerant replay: every bandit step `(parent
//!   kernel, strategy, child kernel, runtime, profile counters, seed
//!   lineage)` survives the process.
//! * [`warm`] — a **warm-start loader** that replays a prior trace into
//!   bandit priors and seeds K-means centroids from historical
//!   runtimes.
//! * [`wrap`] — [`wrap::CachedEngine`] / [`wrap::CachedLlm`]: drop-in
//!   [`crate::engine::EvalEngine`] / [`crate::llm::LlmBackend`]
//!   decorators that route every measurement and proposal through the
//!   store.
//!
//! ## Determinism contract
//!
//! Cache keys include the split-RNG seed lineage of the call site, so a
//! hit returns *exactly* the bytes the simulation would have produced —
//! a run against a populated store emits `BENCH_*.json` artifacts
//! byte-identical to a cold run, for any `--threads N`. Trace records
//! are serialized per cell in canonical cell order after the parallel
//! fan-in, so the log is thread-count-invariant too.
//!
//! ## On-disk layout (`--store DIR`)
//!
//! ```text
//! DIR/kernels.jsonl      measurement cache (append-only, content-addressed)
//! DIR/proposals.jsonl    LLM-proposal cache (append-only, content-addressed)
//! DIR/profiles.jsonl     representative NCU signatures (profiler memo)
//! DIR/service.jsonl      service-job completions (gateway bypass keys)
//! DIR/trace.jsonl        the trace log (append-only, versioned records)
//! DIR/tenants.jsonl      per-tenant counters (multi-tenant serve deltas)
//! DIR/checkpoints.jsonl  mid-job checkpoint journal (crash recovery)
//! ```
//!
//! All seven files tolerate truncated tails and unknown record versions
//! on load ([`crate::util::json::parse_lines_lossy`]), and every file
//! may freely mix legacy raw JSON lines with CRC-framed lines
//! ([`durable`], detected per line).
//!
//! ## Durability discipline
//!
//! [`TraceStore::persist`] writes through [`durable::append_file`]
//! under a configurable [`durable::Durability`] level (`--durability`):
//! `strict` frames every line and fsyncs the ordering-critical files
//! (trace log, checkpoint journal), `relaxed` (default) frames without
//! fsync, `off` reproduces the legacy raw bytes exactly. Each persist
//! section *stages* its deltas, appends, and only commits the take on
//! success — an I/O error re-queues the staged records (counted in
//! `store.requeued_records`), flips the store into a degraded state
//! ([`TraceStore::store_degraded`]) and aborts the flush at the failed
//! section, so the flush-order contract below is never reordered
//! around a failure. Serving continues warm-from-memory; the degraded
//! status is surfaced in `SERVE_LEDGER.json` and the obs counters
//! rather than aborting mid-round. A deterministic disk-fault injector
//! ([`durable::StoreFaultPlan`], `--store-fault`) sits under every
//! append so tests can sweep a kill across each byte boundary, and
//! `kernelband trace fsck --repair` ([`fsck`]) heals what a real
//! crash leaves behind.
//!
//! ## Multi-writer append discipline
//!
//! Many worker threads (and, under sharded serving, many leased worker
//! shards) write through one `TraceStore` concurrently. The discipline
//! that keeps the files deterministic where it matters:
//!
//! * **Nothing is written at event time.** Every mutation lands in an
//!   in-memory structure behind a mutex (caches mark dirty keys, trace
//!   records queue in `pending_log`, checkpoints queue in the journal
//!   registry); the *only* writer of file bytes is
//!   [`TraceStore::persist`], called from the planning thread after
//!   fan-in. Workers never race on a file descriptor.
//! * **Deterministic sections sort before flushing.** Cache entries
//!   append sorted by content key, tenant deltas in label order, and
//!   trace records are queued in canonical round/job order by the
//!   fan-in — so `kernels.jsonl`, `proposals.jsonl`, `profiles.jsonl`,
//!   `service.jsonl`, `tenants.jsonl` and `trace.jsonl` bytes are
//!   invariant to worker count and scheduling.
//! * **The checkpoint journal is exempt.** Shards checkpoint mid-job,
//!   so `checkpoints.jsonl` interleaves fingerprints in wall-clock
//!   order; replay groups lines per fingerprint, which is sound, but
//!   the file is never byte-compared (see [`ckpt`]).
//!
//! `profiles.jsonl` persists the policy's memoized representative
//! NCU signatures ([`crate::sched::profiles::SharedProfiles`], keyed
//! by run fingerprint + code hash), so a warm session replays
//! representative profiling as pure lookups — zero recomputation,
//! zero simulated NCU cost. The store also owns a session-scoped
//! in-memory re-clustering memo
//! ([`crate::sched::centroids::CentroidCache`]); centroids are *not*
//! persisted (cross-session centroid reuse rides the trace log's
//! warm-start seeds instead).

pub mod cache;
pub(crate) mod ckpt;
pub mod durable;
pub mod fsck;
pub mod log;
pub mod warm;
pub mod wrap;

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::obs::{Counter, Recorder};

use crate::kernel::Measurement;
use crate::llm::Proposal;
use crate::profiler::HardwareSignature;
use crate::sched::centroids::CentroidCache;
use crate::sched::profiles::SharedProfiles;
use crate::util::json::{parse_lines_lossy, Json};

use self::cache::ContentCache;
pub use self::ckpt::JournalHealth;
pub use self::durable::{Durability, StoreFaultPlan};
use self::log::TraceRecord;
use self::warm::{TaskWarmStart, WarmIndex};

pub(crate) const KERNELS_FILE: &str = "kernels.jsonl";
pub(crate) const PROPOSALS_FILE: &str = "proposals.jsonl";
pub(crate) const PROFILES_FILE: &str = "profiles.jsonl";
pub(crate) const SERVICE_FILE: &str = "service.jsonl";
pub(crate) const TRACE_FILE: &str = "trace.jsonl";
pub(crate) const TENANTS_FILE: &str = "tenants.jsonl";
pub(crate) const CHECKPOINTS_FILE: &str = "checkpoints.jsonl";

/// Every store file, in the canonical reporting order used by
/// [`LoadSummary::skipped_by_file`], `trace stats`, `trace fsck` and
/// the obs export.
pub const STORE_FILES: [&str; 7] = [
    KERNELS_FILE,
    PROPOSALS_FILE,
    PROFILES_FILE,
    SERVICE_FILE,
    TRACE_FILE,
    TENANTS_FILE,
    CHECKPOINTS_FILE,
];

/// Serialize one persisted NCU signature as a JSONL value.
pub(crate) fn profile_record(key: u64, sig: &HardwareSignature) -> Json {
    Json::obj(vec![
        ("v", Json::num(cache::CACHE_VERSION)),
        ("key", hex_u64(key)),
        ("sm_pct", Json::num(sig.sm_pct)),
        ("dram_pct", Json::num(sig.dram_pct)),
        ("l2_pct", Json::num(sig.l2_pct)),
    ])
}

/// Decode one persisted NCU signature.
pub(crate) fn profile_from_record(j: &Json)
                                  -> Option<(u64, HardwareSignature)> {
    if j.get("v").and_then(Json::as_f64) != Some(cache::CACHE_VERSION) {
        return None;
    }
    let key = parse_hex_u64(j.get("key"))?;
    Some((
        key,
        HardwareSignature {
            sm_pct: j.get("sm_pct")?.as_f64()?,
            dram_pct: j.get("dram_pct")?.as_f64()?,
            l2_pct: j.get("l2_pct")?.as_f64()?,
        },
    ))
}

/// u64 → zero-padded hex JSON string. Hashes and seeds span the full
/// u64 range, which exceeds what a JSON number (f64) represents
/// exactly, so every store file encodes them as 16-digit hex strings.
pub(crate) fn hex_u64(v: u64) -> Json {
    Json::str(format!("{v:016x}"))
}

/// Inverse of [`hex_u64`]; `None` on a missing/non-string/bad field.
pub(crate) fn parse_hex_u64(j: Option<&Json>) -> Option<u64> {
    u64::from_str_radix(j?.as_str()?, 16).ok()
}

/// The single on-disk encoding of [`Counters`] shared by the
/// measurement cache and the trace log: a named object, so field
/// addition/reordering can never silently scramble values the way a
/// positional array would.
pub(crate) fn counters_to_json(c: &crate::kernel::Counters) -> Json {
    Json::obj(vec![
        ("regs_per_thread", Json::num(c.regs_per_thread)),
        ("smem_per_block", Json::num(c.smem_per_block)),
        ("block_dim", Json::num(c.block_dim)),
        ("occupancy", Json::num(c.occupancy)),
        ("sm_pct", Json::num(c.sm_pct)),
        ("dram_pct", Json::num(c.dram_pct)),
        ("l2_pct", Json::num(c.l2_pct)),
    ])
}

/// Inverse of [`counters_to_json`] (missing fields decode as 0.0).
pub(crate) fn counters_from_json(j: &Json) -> crate::kernel::Counters {
    crate::kernel::Counters {
        regs_per_thread: j.f64_field("regs_per_thread"),
        smem_per_block: j.f64_field("smem_per_block"),
        block_dim: j.f64_field("block_dim"),
        occupancy: j.f64_field("occupancy"),
        sm_pct: j.f64_field("sm_pct"),
        dram_pct: j.f64_field("dram_pct"),
        l2_pct: j.f64_field("l2_pct"),
    }
}

/// Lock-free hit/miss accounting, shared across worker threads.
///
/// `*_sims` count work actually simulated this session; `*_hits` count
/// simulated compile/exec steps and LLM round-trips bypassed by the
/// cache. Saved cost/latency are accumulated in integer micro-units so
/// plain atomics suffice.
#[derive(Debug, Default)]
pub struct StoreStats {
    pub measure_hits: AtomicU64,
    pub measure_sims: AtomicU64,
    pub llm_hits: AtomicU64,
    pub llm_sims: AtomicU64,
    /// Micro-USD of LLM spend bypassed by proposal-cache hits.
    pub saved_cost_micro_usd: AtomicU64,
    /// Milliseconds of *serial* LLM latency bypassed by hits.
    pub saved_serial_llm_ms: AtomicU64,
}

impl StoreStats {
    pub fn saved_cost_usd(&self) -> f64 {
        self.saved_cost_micro_usd.load(Ordering::Relaxed) as f64 * 1e-6
    }

    pub fn saved_serial_llm_s(&self) -> f64 {
        self.saved_serial_llm_ms.load(Ordering::Relaxed) as f64 * 1e-3
    }
}

/// What a load found on disk.
#[derive(Debug, Default, Clone, Copy)]
pub struct LoadSummary {
    pub kernels: usize,
    pub proposals: usize,
    /// Persisted representative NCU signatures.
    pub profiles: usize,
    pub service: usize,
    /// Distinct tenant namespaces with persisted counters.
    pub tenants: usize,
    /// Fingerprints with a live (untombstoned) mid-job checkpoint
    /// prefix — jobs a previous session left in flight.
    pub checkpoints: usize,
    /// Cache/service lines skipped (corrupt or unknown version),
    /// summed over every file. Per-file counts below.
    pub skipped: usize,
    /// Per-file skipped-line counts in [`STORE_FILES`] order (torn
    /// frames, corrupt JSON, unknown versions) — a rotting file shows
    /// up here, in `trace stats` and in `store.corrupt_lines.<file>`
    /// rather than hiding inside the aggregate.
    pub skipped_by_file: [usize; 7],
}

impl LoadSummary {
    /// `(file name, skipped lines)` for every store file with at least
    /// one skipped line.
    pub fn corrupt_files(&self) -> Vec<(&'static str, usize)> {
        STORE_FILES
            .iter()
            .zip(self.skipped_by_file)
            .filter(|&(_, n)| n > 0)
            .map(|(&f, n)| (f, n))
            .collect()
    }
}

/// Accumulated per-tenant counters (`tenants.jsonl`): what a tenant's
/// serve jobs contributed to this store across sessions. Appended as
/// deltas per run and summed on load.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TenantCounts {
    /// Jobs completed under the tenant's namespace.
    pub jobs: u64,
    /// Bandit steps the tenant's executed jobs recorded.
    pub steps: u64,
    /// Representative NCU profilings the tenant's jobs recomputed
    /// (0 for tenants served entirely from the shared caches).
    pub profile_runs: u64,
    /// Jobs completed without any fresh simulated work (pure cache
    /// lookups / dedup shares). `warm_jobs / jobs` is the tenant's
    /// warm ratio, reported by `trace stats`.
    pub warm_jobs: u64,
}

#[derive(Debug, Default)]
struct TenantRegistry {
    /// Totals including everything loaded from disk (sorted by label).
    totals: std::collections::BTreeMap<String, TenantCounts>,
    /// This session's deltas, flushed by [`TraceStore::persist`].
    dirty: std::collections::BTreeMap<String, TenantCounts>,
}

fn tenant_record(name: &str, c: &TenantCounts) -> Json {
    Json::obj(vec![
        ("v", Json::num(cache::CACHE_VERSION)),
        ("tenant", Json::str(name)),
        ("jobs", Json::num(c.jobs as f64)),
        ("steps", Json::num(c.steps as f64)),
        ("profile_runs", Json::num(c.profile_runs as f64)),
        ("warm_jobs", Json::num(c.warm_jobs as f64)),
    ])
}

fn tenant_from_record(j: &Json) -> Option<(String, TenantCounts)> {
    if j.get("v").and_then(Json::as_f64) != Some(cache::CACHE_VERSION) {
        return None;
    }
    Some((
        j.str_field("tenant").ok()?.to_string(),
        TenantCounts {
            jobs: j.f64_field("jobs") as u64,
            steps: j.f64_field("steps") as u64,
            profile_runs: j.f64_field("profile_runs") as u64,
            // absent on pre-obs records: decodes as 0
            warm_jobs: j.f64_field("warm_jobs") as u64,
        },
    ))
}

/// The persistent store. Thread-safe: the experiment runner's workers
/// share one instance behind an `Arc`.
#[derive(Debug)]
pub struct TraceStore {
    dir: Option<PathBuf>,
    kernels: Mutex<ContentCache<Measurement>>,
    proposals: Mutex<ContentCache<Proposal>>,
    service: Mutex<ServiceCache>,
    /// Per-tenant counters (`tenants.jsonl`; multi-tenant serve).
    tenants: Mutex<TenantRegistry>,
    /// Representative NCU signatures (persisted; shared with the
    /// policy through [`crate::sched::SchedContext`]).
    profiles: Arc<SharedProfiles>,
    /// Session-scoped re-clustering memo (in-memory only).
    centroids: Arc<CentroidCache>,
    /// Records appended this session, flushed by [`TraceStore::persist`].
    pending_log: Mutex<Vec<TraceRecord>>,
    /// Mid-job checkpoint journal (`checkpoints.jsonl`; crash recovery).
    ckpts: Mutex<ckpt::CkptRegistry>,
    /// Sync/framing level for [`TraceStore::persist`] appends.
    durability: Mutex<Durability>,
    /// Deterministic disk-fault injector under every store append.
    fault: Mutex<durable::FaultRuntime>,
    /// FNV hashes of the trace lines already on disk, loaded lazily at
    /// the first trace append of a session and kept in step with
    /// successful appends. Persist filters pending records against it,
    /// so a crash-recovery rerun that re-simulates (torn caches defeat
    /// the pure-replay guard) appends only the records the crash lost —
    /// the log converges to the clean-run bytes instead of doubling.
    /// Invalidated (`None`) when a trace append errors: the on-disk
    /// tail is unknown until the next successful read.
    trace_seen: Mutex<Option<HashSet<u64>>>,
    /// Flush-failure accounting ([`TraceStore::store_degraded`]).
    health: FlushHealth,
    warm: Option<WarmIndex>,
    /// Advisory telemetry handles, attached at most once per store via
    /// [`TraceStore::set_recorder`]. Purely observational: reads are a
    /// lock-free `OnceLock::get`, and nothing downstream of the
    /// recorder feeds back into cache contents or file bytes.
    obs: OnceLock<StoreObs>,
    pub stats: StoreStats,
    pub loaded: LoadSummary,
}

/// Pre-resolved telemetry handles for the store's hot paths (one
/// relaxed atomic add per cache probe once attached).
#[derive(Debug)]
struct StoreObs {
    rec: Arc<Recorder>,
    measure_hit: Counter,
    measure_miss: Counter,
    llm_hit: Counter,
    llm_miss: Counter,
    service_hit: Counter,
    service_miss: Counter,
    flush_errors: Counter,
    requeued: Counter,
}

#[derive(Debug, Default)]
struct ServiceCache {
    keys: HashSet<u64>,
    dirty: Vec<u64>,
}

/// Degraded-mode accounting: what [`TraceStore::persist`] failed to
/// flush (and re-queued) so far. A degraded store keeps serving
/// warm-from-memory; the state is surfaced in `SERVE_LEDGER.json` and
/// via the `store.flush_errors` / `store.requeued_records` counters.
#[derive(Debug, Default)]
struct FlushHealth {
    degraded: std::sync::atomic::AtomicBool,
    flush_errors: AtomicU64,
    requeued_records: AtomicU64,
    last_error: Mutex<Option<String>>,
}

impl TraceStore {
    /// A store with no backing directory: caches and warm-start work,
    /// [`TraceStore::persist`] is a no-op.
    pub fn in_memory() -> TraceStore {
        TraceStore {
            dir: None,
            kernels: Mutex::new(ContentCache::default()),
            proposals: Mutex::new(ContentCache::default()),
            service: Mutex::new(ServiceCache::default()),
            tenants: Mutex::new(TenantRegistry::default()),
            profiles: Arc::new(SharedProfiles::new()),
            centroids: Arc::new(CentroidCache::new()),
            pending_log: Mutex::new(Vec::new()),
            ckpts: Mutex::new(ckpt::CkptRegistry::default()),
            durability: Mutex::new(Durability::default()),
            fault: Mutex::new(durable::FaultRuntime::default()),
            trace_seen: Mutex::new(None),
            health: FlushHealth::default(),
            warm: None,
            obs: OnceLock::new(),
            stats: StoreStats::default(),
            loaded: LoadSummary::default(),
        }
    }

    /// Open (creating if missing) a store directory and load its
    /// caches. Corrupt lines and unknown record versions are skipped,
    /// never fatal.
    pub fn open(dir: &Path) -> std::io::Result<TraceStore> {
        std::fs::create_dir_all(dir)?;
        let mut store = TraceStore::in_memory();
        store.dir = Some(dir.to_path_buf());

        // decoded text + frame-corrupt count; per-file skips land in
        // `skipped_by_file` at the file's STORE_FILES index
        let read = |name: &str| -> std::io::Result<(String, usize)> {
            durable::read_decoded(&dir.join(name))
        };
        let file_idx = |name: &str| -> usize {
            STORE_FILES.iter().position(|&f| f == name).unwrap()
        };

        let mut summary = LoadSummary::default();
        {
            let (text, frames) = read(KERNELS_FILE)?;
            let (entries, skipped) =
                cache::load_entries(&text, cache::measurement_from_record);
            summary.skipped_by_file[file_idx(KERNELS_FILE)] =
                frames + skipped;
            let mut kernels = store.kernels.lock().unwrap();
            for (k, v) in entries {
                kernels.insert_loaded(k, v);
            }
            summary.kernels = kernels.len();
        }
        {
            let (text, frames) = read(PROPOSALS_FILE)?;
            let (entries, skipped) =
                cache::load_entries(&text, cache::proposal_from_record);
            summary.skipped_by_file[file_idx(PROPOSALS_FILE)] =
                frames + skipped;
            let mut proposals = store.proposals.lock().unwrap();
            for (k, v) in entries {
                proposals.insert_loaded(k, v);
            }
            summary.proposals = proposals.len();
        }
        {
            let (text, frames) = read(PROFILES_FILE)?;
            let (entries, skipped) =
                cache::load_entries(&text, profile_from_record);
            summary.skipped_by_file[file_idx(PROFILES_FILE)] =
                frames + skipped;
            for (k, sig) in entries {
                store.profiles.insert_loaded(k, sig);
            }
            summary.profiles = store.profiles.len();
        }
        {
            let (text, frames) = read(SERVICE_FILE)?;
            let (values, corrupt) = parse_lines_lossy(&text);
            let mut skipped = frames + corrupt;
            let mut service = store.service.lock().unwrap();
            for v in &values {
                if v.get("v").and_then(Json::as_f64)
                    != Some(cache::CACHE_VERSION)
                {
                    skipped += 1;
                    continue;
                }
                match parse_hex_u64(v.get("key")) {
                    Some(k) => {
                        service.keys.insert(k);
                    }
                    None => skipped += 1,
                }
            }
            summary.skipped_by_file[file_idx(SERVICE_FILE)] = skipped;
            summary.service = service.keys.len();
        }
        {
            let (text, frames) = read(TENANTS_FILE)?;
            let (values, corrupt) = parse_lines_lossy(&text);
            let mut skipped = frames + corrupt;
            let mut tenants = store.tenants.lock().unwrap();
            for v in &values {
                match tenant_from_record(v) {
                    Some((name, c)) => {
                        let e = tenants
                            .totals
                            .entry(name)
                            .or_insert_with(TenantCounts::default);
                        e.jobs += c.jobs;
                        e.steps += c.steps;
                        e.profile_runs += c.profile_runs;
                        e.warm_jobs += c.warm_jobs;
                    }
                    None => skipped += 1,
                }
            }
            summary.skipped_by_file[file_idx(TENANTS_FILE)] = skipped;
            summary.tenants = tenants.totals.len();
        }
        {
            let (text, frames) = read(CHECKPOINTS_FILE)?;
            let (values, corrupt) = parse_lines_lossy(&text);
            let mut skipped = frames + corrupt;
            let mut lines = Vec::new();
            for v in &values {
                match ckpt::journal_from_record(v) {
                    Some(l) => lines.push(l),
                    None => skipped += 1,
                }
            }
            summary.skipped_by_file[file_idx(CHECKPOINTS_FILE)] = skipped;
            summary.checkpoints =
                store.ckpts.lock().unwrap().load(lines);
        }
        summary.skipped = summary.skipped_by_file.iter().sum();
        store.loaded = summary;
        Ok(store)
    }

    /// Attach a warm-start index replayed from `trace_path` (fitting
    /// centroid seeds for `clusters` clusters). Returns the replay
    /// summary for display.
    pub fn load_warm(&mut self, trace_path: &Path, clusters: usize)
                     -> std::io::Result<log::ReplaySummary> {
        let summary = log::replay_file(trace_path)?;
        let idx = STORE_FILES
            .iter()
            .position(|&f| f == TRACE_FILE)
            .unwrap();
        self.loaded.skipped -=
            std::mem::replace(
                &mut self.loaded.skipped_by_file[idx],
                summary.corrupt_lines,
            );
        self.loaded.skipped += summary.corrupt_lines;
        self.warm = Some(WarmIndex::from_records(&summary.records, clusters));
        Ok(summary)
    }

    /// Attach a warm-start index built from in-memory records.
    pub fn set_warm(&mut self, index: WarmIndex) {
        self.warm = Some(index);
    }

    /// Warm-start state for exactly this (device, llm, task) context,
    /// if a warm index is attached and has matching history. Priors are
    /// never served across hardware or model boundaries.
    pub fn warm_for(&self, device: &str, llm: &str, task_name: &str)
                    -> Option<&TaskWarmStart> {
        self.warm.as_ref()?.get(device, llm, task_name)
    }

    pub fn warm_index(&self) -> Option<&WarmIndex> {
        self.warm.as_ref()
    }

    /// Path of this store's trace log (None for in-memory stores).
    pub fn trace_path(&self) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(TRACE_FILE))
    }

    /// The store's backing directory (None for in-memory stores).
    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    // --- durability configuration ---------------------------------------

    /// Set the sync/framing level for subsequent persists (default
    /// [`Durability::Relaxed`]). Interior-mutable so it can be applied
    /// after the store is shared behind an `Arc`.
    pub fn set_durability(&self, level: Durability) {
        *self.durability.lock().unwrap() = level;
    }

    pub fn durability(&self) -> Durability {
        *self.durability.lock().unwrap()
    }

    /// Arm (or, with a default plan, disarm) the deterministic
    /// disk-fault injector under every store append. Clearing the plan
    /// also revives a store killed by `kill-at-byte`.
    pub fn set_store_fault(&self, plan: StoreFaultPlan) {
        self.fault.lock().unwrap().set_plan(plan);
    }

    /// True once any persist section failed to reach disk; the failed
    /// deltas are re-queued in memory and serving continues
    /// warm-from-memory.
    pub fn store_degraded(&self) -> bool {
        self.health.degraded.load(Ordering::Relaxed)
    }

    /// Count of persist sections that returned an I/O error.
    pub fn flush_errors(&self) -> u64 {
        self.health.flush_errors.load(Ordering::Relaxed)
    }

    /// Total records re-queued by failed persist sections (cumulative;
    /// a record re-queued twice counts twice).
    pub fn requeued_records(&self) -> u64 {
        self.health.requeued_records.load(Ordering::Relaxed)
    }

    /// Message of the most recent flush failure.
    pub fn last_flush_error(&self) -> Option<String> {
        self.health.last_error.lock().unwrap().clone()
    }

    // --- cache access (used by `wrap`) ---------------------------------

    pub fn lookup_measurement(&self, key: u64) -> Option<Measurement> {
        self.kernels.lock().unwrap().get(key)
    }

    pub fn insert_measurement(&self, key: u64, m: &Measurement) {
        self.kernels.lock().unwrap().insert(key, m.clone());
    }

    pub fn lookup_proposal(&self, key: u64) -> Option<Proposal> {
        self.proposals.lock().unwrap().get(key)
    }

    pub fn insert_proposal(&self, key: u64, p: &Proposal) {
        self.proposals.lock().unwrap().insert(key, p.clone());
    }

    /// Service-job completion check (the gateway-bypass fast path).
    pub fn service_done(&self, key: u64) -> bool {
        let hit = self.service.lock().unwrap().keys.contains(&key);
        if let Some(o) = self.obs.get() {
            if hit {
                o.service_hit.incr();
            } else {
                o.service_miss.incr();
            }
        }
        hit
    }

    /// Record a completed service job.
    pub fn service_insert(&self, key: u64) {
        let mut s = self.service.lock().unwrap();
        if s.keys.insert(key) {
            s.dirty.push(key);
        }
    }

    /// Queue trace records for the next [`TraceStore::persist`].
    pub fn append_trace(&self, records: Vec<TraceRecord>) {
        self.pending_log.lock().unwrap().extend(records);
    }

    // --- mid-job checkpoint journal (crash recovery) --------------------

    /// Journal one iteration checkpoint of the job addressed by `fp`
    /// (the serve fingerprint). Extends the job's resumable prefix.
    pub fn ckpt_append(&self, fp: u64,
                       c: &crate::policy::resume::Checkpoint) {
        self.ckpts.lock().unwrap().append(fp, c);
    }

    /// The job's current resumable checkpoint prefix (iterations
    /// `1..=len`, contiguous; empty when the job has no live prefix).
    pub fn ckpt_prefix(&self, fp: u64)
                       -> Vec<crate::policy::resume::Checkpoint> {
        self.ckpts.lock().unwrap().prefix(fp)
    }

    /// Mark the job complete: its prefix is dropped and, if any of it
    /// already reached disk, tombstoned so a reload ignores it.
    pub fn ckpt_retire(&self, fp: u64) {
        self.ckpts.lock().unwrap().retire(fp);
    }

    /// Fingerprints with a live checkpoint prefix — in-flight jobs
    /// this session, or crashed jobs a previous session left behind
    /// (surface for [`crate::server::recover`]).
    pub fn ckpt_live(&self) -> Vec<u64> {
        self.ckpts.lock().unwrap().live_fingerprints()
    }

    /// Checkpoint-journal health as observed when this store was
    /// opened (all zeros for in-memory stores): live vs. retired
    /// entries in `checkpoints.jsonl`, for `trace stats`.
    pub fn ckpt_journal_health(&self) -> JournalHealth {
        self.ckpts.lock().unwrap().journal_health()
    }

    /// Credit per-tenant work to the tenant namespace (accumulated
    /// across sessions through `tenants.jsonl`). `warm_jobs` counts
    /// the subset of `jobs` completed without fresh simulated work.
    pub fn tenant_add(&self, tenant: &str, jobs: u64, steps: u64,
                      profile_runs: u64, warm_jobs: u64) {
        let mut guard = self.tenants.lock().unwrap();
        let reg = &mut *guard; // split-borrow totals and dirty
        for map in [&mut reg.totals, &mut reg.dirty] {
            let e = map
                .entry(tenant.to_string())
                .or_insert_with(TenantCounts::default);
            e.jobs += jobs;
            e.steps += steps;
            e.profile_runs += profile_runs;
            e.warm_jobs += warm_jobs;
        }
    }

    /// Accumulated per-tenant counters, sorted by tenant label.
    pub fn tenant_totals(&self) -> Vec<(String, TenantCounts)> {
        self.tenants
            .lock()
            .unwrap()
            .totals
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    pub fn kernel_count(&self) -> usize {
        self.kernels.lock().unwrap().len()
    }

    pub fn proposal_count(&self) -> usize {
        self.proposals.lock().unwrap().len()
    }

    pub fn profile_count(&self) -> usize {
        self.profiles.len()
    }

    /// The persisted NCU-signature cache, shareable with the policy
    /// through [`crate::sched::SchedContext`].
    pub fn profiles(&self) -> Arc<SharedProfiles> {
        self.profiles.clone()
    }

    /// The session-scoped re-clustering memo (in-memory only).
    pub fn session_centroids(&self) -> Arc<CentroidCache> {
        self.centroids.clone()
    }

    // --- advisory telemetry ---------------------------------------------

    /// Attach the telemetry recorder. First call wins; later calls are
    /// ignored (the store outlives any one serve request).
    pub fn set_recorder(&self, rec: Arc<Recorder>) {
        if !rec.enabled() {
            return;
        }
        let _ = self.obs.set(StoreObs {
            measure_hit: rec.counter("store.measure.hit"),
            measure_miss: rec.counter("store.measure.miss"),
            llm_hit: rec.counter("store.llm.hit"),
            llm_miss: rec.counter("store.llm.miss"),
            service_hit: rec.counter("store.service.hit"),
            service_miss: rec.counter("store.service.miss"),
            flush_errors: rec.counter("store.flush_errors"),
            requeued: rec.counter("store.requeued_records"),
            rec,
        });
    }

    /// The attached telemetry recorder, if any.
    pub fn recorder(&self) -> Option<Arc<Recorder>> {
        self.obs.get().map(|o| o.rec.clone())
    }

    /// Per-cache-class hit/miss hooks for [`wrap`] (no-ops until a
    /// recorder is attached).
    pub(crate) fn obs_measure(&self, hit: bool, n: u64) {
        if let Some(o) = self.obs.get() {
            if hit { &o.measure_hit } else { &o.measure_miss }.add(n);
        }
    }

    pub(crate) fn obs_llm(&self, hit: bool) {
        if let Some(o) = self.obs.get() {
            if hit { &o.llm_hit } else { &o.llm_miss }.add(1);
        }
    }

    /// Snapshot the store's cumulative bypass accounting into the
    /// recorder as gauge-style counters. Call once, right before
    /// emitting `METRICS.json`.
    pub fn obs_export(&self) {
        let Some(rec) = self.recorder() else { return };
        let s = &self.stats;
        rec.add(
            "store.bypass.saved_cost_micro_usd",
            s.saved_cost_micro_usd.load(Ordering::Relaxed),
        );
        rec.add(
            "store.bypass.saved_serial_llm_ms",
            s.saved_serial_llm_ms.load(Ordering::Relaxed),
        );
        rec.add("store.profile.hit", self.profiles.hits.load(Ordering::Relaxed));
        rec.add("store.profile.entries", self.profile_count() as u64);
        rec.add("store.kernels.entries", self.kernel_count() as u64);
        rec.add("store.proposals.entries", self.proposal_count() as u64);
        rec.add("store.ckpt.live_jobs", self.ckpt_live().len() as u64);
        for (file, n) in self.loaded.corrupt_files() {
            let stem = file.strip_suffix(".jsonl").unwrap_or(file);
            rec.add(&format!("store.corrupt_lines.{stem}"), n as u64);
        }
    }

    // --- persistence ----------------------------------------------------

    /// Flush pending trace records and new cache entries, appending to
    /// the store files. New cache entries are written sorted by key, so
    /// the bytes are independent of worker scheduling. No-op without a
    /// backing directory.
    ///
    /// Ordering matters for crash tolerance: the trace log flushes
    /// *before* the caches. The pure-replay guards skip re-appending a
    /// trace when every step cache-hits, so if the caches landed but the
    /// trace didn't, that history would be unrecoverable; the reverse
    /// failure (trace landed, caches torn) only makes the next run
    /// re-simulate and re-queue byte-identical records, which the
    /// on-disk dedup (`trace_seen`) drops at the next persist.
    ///
    /// Fail-safe: each section stages its deltas and commits the take
    /// only after its append succeeds. On an I/O error the staged
    /// records are re-queued, the store flips to
    /// [`TraceStore::store_degraded`], and the flush aborts at the
    /// failed section — later sections keep their deltas pending, so a
    /// partial flush can never write the caches after losing the trace.
    pub fn persist(&self) -> std::io::Result<()> {
        let Some(dir) = self.dir.clone() else { return Ok(()) };
        let durability = self.durability();
        let result = self.persist_inner(&dir, durability);
        if let Err(e) = &result {
            self.health.degraded.store(true, Ordering::Relaxed);
            self.health.flush_errors.fetch_add(1, Ordering::Relaxed);
            *self.health.last_error.lock().unwrap() = Some(e.to_string());
            if let Some(o) = self.obs.get() {
                o.flush_errors.add(1);
            }
        }
        result
    }

    /// One store append through the durability layer + fault injector.
    fn append_section(&self, dir: &Path, name: &str, text: &str,
                      durability: Durability, sync: bool)
                      -> std::io::Result<()> {
        let mut fault = self.fault.lock().unwrap();
        durable::append_file(&dir.join(name), text, durability,
                             &mut fault, sync)
    }

    /// Record `n` re-queued records after a failed section append.
    fn requeued(&self, n: usize) {
        self.health
            .requeued_records
            .fetch_add(n as u64, Ordering::Relaxed);
        if let Some(o) = self.obs.get() {
            o.requeued.add(n as u64);
        }
    }

    fn persist_inner(&self, dir: &Path, durability: Durability)
                     -> std::io::Result<()> {
        // --- trace log first (flush-order contract above) ---
        let pending =
            std::mem::take(&mut *self.pending_log.lock().unwrap());
        if !pending.is_empty() {
            if let Err(e) =
                self.append_trace_deduped(dir, durability, &pending)
            {
                self.requeued(pending.len());
                let mut guard = self.pending_log.lock().unwrap();
                let mut restored = pending;
                restored.append(&mut *guard);
                *guard = restored;
                // the on-disk tail is unknown after a torn append
                *self.trace_seen.lock().unwrap() = None;
                return Err(e);
            }
        }

        // checkpoint journal right after the trace: losing it only
        // costs re-execution (absorbed by the caches below), while a
        // persisted prefix lets the next session resume a crashed job
        // on its exact iteration boundary. Flushed fingerprints are
        // marked only on success, so a failed append never earns a
        // tombstone debt for lines that never reached disk.
        let staged = self.ckpts.lock().unwrap().stage_pending();
        if !staged.is_empty() {
            let mut text = String::new();
            for (_, line) in &staged {
                text.push_str(&line.dump());
                text.push('\n');
            }
            match self.append_section(dir, CHECKPOINTS_FILE, &text,
                                      durability, true) {
                Ok(()) => {
                    self.ckpts.lock().unwrap().mark_flushed(&staged);
                }
                Err(e) => {
                    self.requeued(staged.len());
                    self.ckpts.lock().unwrap().restore_pending(staged);
                    return Err(e);
                }
            }
        }

        let kernels = self.kernels.lock().unwrap().take_dirty();
        if !kernels.is_empty() {
            let mut text = String::new();
            for (k, m) in &kernels {
                text.push_str(&cache::measurement_record(*k, m).dump());
                text.push('\n');
            }
            if let Err(e) = self.append_section(dir, KERNELS_FILE, &text,
                                                durability, false) {
                self.requeued(kernels.len());
                self.kernels
                    .lock()
                    .unwrap()
                    .restore_dirty(kernels.iter().map(|&(k, _)| k));
                return Err(e);
            }
        }

        let proposals = self.proposals.lock().unwrap().take_dirty();
        if !proposals.is_empty() {
            let mut text = String::new();
            for (k, p) in &proposals {
                text.push_str(&cache::proposal_record(*k, p).dump());
                text.push('\n');
            }
            if let Err(e) = self.append_section(dir, PROPOSALS_FILE,
                                                &text, durability, false) {
                self.requeued(proposals.len());
                self.proposals
                    .lock()
                    .unwrap()
                    .restore_dirty(proposals.iter().map(|&(k, _)| k));
                return Err(e);
            }
        }

        let profiles = self.profiles.take_dirty();
        if !profiles.is_empty() {
            let mut text = String::new();
            for (k, sig) in &profiles {
                text.push_str(&profile_record(*k, sig).dump());
                text.push('\n');
            }
            if let Err(e) = self.append_section(dir, PROFILES_FILE, &text,
                                                durability, false) {
                self.requeued(profiles.len());
                self.profiles
                    .restore_dirty(profiles.iter().map(|&(k, _)| k));
                return Err(e);
            }
        }

        let service_dirty = {
            let mut s = self.service.lock().unwrap();
            let mut dirty = std::mem::take(&mut s.dirty);
            dirty.sort_unstable();
            dirty.dedup();
            dirty
        };
        if !service_dirty.is_empty() {
            let mut text = String::new();
            for k in &service_dirty {
                let rec = Json::obj(vec![
                    ("v", Json::num(cache::CACHE_VERSION)),
                    ("key", hex_u64(*k)),
                ]);
                text.push_str(&rec.dump());
                text.push('\n');
            }
            if let Err(e) = self.append_section(dir, SERVICE_FILE, &text,
                                                durability, false) {
                self.requeued(service_dirty.len());
                self.service.lock().unwrap().dirty.extend(service_dirty);
                return Err(e);
            }
        }

        // BTreeMap iteration: label-sorted, byte-deterministic
        let tenant_dirty =
            std::mem::take(&mut self.tenants.lock().unwrap().dirty);
        if !tenant_dirty.is_empty() {
            let mut text = String::new();
            for (name, c) in &tenant_dirty {
                text.push_str(&tenant_record(name, c).dump());
                text.push('\n');
            }
            if let Err(e) = self.append_section(dir, TENANTS_FILE, &text,
                                                durability, false) {
                self.requeued(tenant_dirty.len());
                let mut reg = self.tenants.lock().unwrap();
                for (name, c) in tenant_dirty {
                    let slot = reg
                        .dirty
                        .entry(name)
                        .or_insert_with(TenantCounts::default);
                    slot.jobs += c.jobs;
                    slot.steps += c.steps;
                    slot.profile_runs += c.profile_runs;
                    slot.warm_jobs += c.warm_jobs;
                }
                return Err(e);
            }
        }
        Ok(())
    }

    /// Append pending trace records, skipping any whose serialized line
    /// already exists on disk, and fsyncing under strict durability.
    /// The dedup is what makes crash recovery byte-convergent: a rerun
    /// after a torn flush re-queues the *entire* record set, and only
    /// the suffix the crash cut off is actually appended.
    fn append_trace_deduped(&self, dir: &Path, durability: Durability,
                            pending: &[TraceRecord])
                            -> std::io::Result<()> {
        let path = dir.join(TRACE_FILE);
        let mut seen_guard = self.trace_seen.lock().unwrap();
        if seen_guard.is_none() {
            let (text, _) = durable::read_decoded(&path)?;
            let set: HashSet<u64> = text
                .lines()
                .map(str::trim)
                .filter(|l| !l.is_empty())
                .map(|l| crate::util::hash::fnv1a(l.as_bytes()))
                .collect();
            *seen_guard = Some(set);
        }
        let seen = seen_guard.as_mut().unwrap();
        let mut text = String::new();
        for r in pending {
            let line = r.to_json().dump();
            if seen.insert(crate::util::hash::fnv1a(line.as_bytes())) {
                text.push_str(&line);
                text.push('\n');
            }
        }
        drop(seen_guard);
        self.append_section(dir, TRACE_FILE, &text, durability, true)
    }

    /// One-line, grep-friendly summary for the CLI (`[store] …`).
    pub fn stats_line(&self) -> String {
        let s = &self.stats;
        format!(
            "measure_sim={} measure_hit={} llm_sim={} llm_hit={} \
             cost_saved_usd={:.4} serial_llm_s_saved={:.1} \
             kernels={} proposals={} profiles={} profile_hit={}",
            s.measure_sims.load(Ordering::Relaxed),
            s.measure_hits.load(Ordering::Relaxed),
            s.llm_sims.load(Ordering::Relaxed),
            s.llm_hits.load(Ordering::Relaxed),
            s.saved_cost_usd(),
            s.saved_serial_llm_s(),
            self.kernel_count(),
            self.proposal_count(),
            self.profile_count(),
            self.profiles
                .hits
                .load(std::sync::atomic::Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::Counters;

    fn meas(t: f64) -> Measurement {
        Measurement {
            total_latency_s: t,
            per_shape_s: vec![t],
            counters: Counters { sm_pct: 12.0, ..Default::default() },
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "kb_store_unit_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn in_memory_store_caches_without_disk() {
        let store = TraceStore::in_memory();
        assert!(store.lookup_measurement(1).is_none());
        store.insert_measurement(1, &meas(0.5));
        assert_eq!(store.lookup_measurement(1).unwrap().total_latency_s, 0.5);
        store.persist().unwrap(); // no-op, no panic
        assert!(store.trace_path().is_none());
    }

    #[test]
    fn open_persist_reopen_roundtrips() {
        let dir = tmp_dir("roundtrip");
        {
            let store = TraceStore::open(&dir).unwrap();
            assert_eq!(store.loaded.kernels, 0);
            store.insert_measurement(42, &meas(0.25));
            store.service_insert(7);
            store.persist().unwrap();
        }
        {
            let store = TraceStore::open(&dir).unwrap();
            assert_eq!(store.loaded.kernels, 1);
            assert_eq!(store.loaded.service, 1);
            assert_eq!(
                store.lookup_measurement(42).unwrap().total_latency_s,
                0.25
            );
            assert!(store.service_done(7));
            assert!(!store.service_done(8));
            // reloaded entries are not re-appended
            store.persist().unwrap();
        }
        let text =
            std::fs::read_to_string(dir.join(KERNELS_FILE)).unwrap();
        assert_eq!(text.lines().count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn profiles_roundtrip_bit_exact_and_reload() {
        let sig = HardwareSignature {
            sm_pct: 33.33333333333333,
            dram_pct: 81.0,
            l2_pct: 12.5,
        };
        let rec = profile_record(0xfeed_face_0000_0001, &sig);
        let parsed = crate::util::json::parse(&rec.dump()).unwrap();
        let (key, back) = profile_from_record(&parsed).unwrap();
        assert_eq!(key, 0xfeed_face_0000_0001);
        assert_eq!(back.sm_pct.to_bits(), sig.sm_pct.to_bits());
        assert_eq!(back.dram_pct.to_bits(), sig.dram_pct.to_bits());
        assert_eq!(back.l2_pct.to_bits(), sig.l2_pct.to_bits());

        let dir = tmp_dir("profiles");
        {
            let store = TraceStore::open(&dir).unwrap();
            store.profiles().insert(7, sig);
            store.persist().unwrap();
        }
        {
            let store = TraceStore::open(&dir).unwrap();
            assert_eq!(store.loaded.profiles, 1);
            assert_eq!(store.profiles().get(7), Some(sig));
            // reloaded entries are not re-appended
            store.persist().unwrap();
        }
        let text =
            std::fs::read_to_string(dir.join(PROFILES_FILE)).unwrap();
        assert_eq!(text.lines().count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tenant_counters_accumulate_across_sessions() {
        let dir = tmp_dir("tenants");
        {
            let store = TraceStore::open(&dir).unwrap();
            store.tenant_add("t0", 2, 24, 3, 0);
            store.tenant_add("t1", 1, 12, 0, 1);
            store.tenant_add("t0", 1, 12, 0, 1); // same session, same tenant
            store.persist().unwrap();
        }
        {
            let store = TraceStore::open(&dir).unwrap();
            assert_eq!(store.loaded.tenants, 2);
            let totals = store.tenant_totals();
            assert_eq!(totals.len(), 2);
            assert_eq!(totals[0].0, "t0");
            assert_eq!(
                totals[0].1,
                TenantCounts {
                    jobs: 3,
                    steps: 36,
                    profile_runs: 3,
                    warm_jobs: 1,
                }
            );
            assert_eq!(totals[1].0, "t1");
            assert_eq!(
                totals[1].1,
                TenantCounts {
                    jobs: 1,
                    steps: 12,
                    profile_runs: 0,
                    warm_jobs: 1,
                }
            );
            // a second serve session appends deltas that sum on reload
            store.tenant_add("t1", 1, 12, 0, 1);
            store.persist().unwrap();
        }
        {
            let store = TraceStore::open(&dir).unwrap();
            let totals = store.tenant_totals();
            assert_eq!(totals[1].1.jobs, 2);
            // reloaded totals are not re-appended
            store.persist().unwrap();
        }
        let text =
            std::fs::read_to_string(dir.join(TENANTS_FILE)).unwrap();
        assert_eq!(text.lines().count(), 3); // t0+t1, then t1 delta
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_journal_survives_reopen_until_retired() {
        let dir = tmp_dir("ckpts");
        let ck = crate::policy::resume::Checkpoint {
            t: 1,
            strategy: None,
            slots: vec![crate::policy::resume::SlotCheckpoint {
                proposal: crate::llm::Proposal {
                    outcome: crate::llm::GenOutcome::Ok,
                    config: crate::kernel::KernelConfig::naive(),
                    tokens_in: 10,
                    tokens_out: 20,
                    cost_usd: 0.25,
                    latency_s: 2.0,
                },
                measured: Some(meas(0.125)),
            }],
        };
        {
            // in-flight at persist time: the prefix reaches disk
            let store = TraceStore::open(&dir).unwrap();
            store.ckpt_append(5, &ck);
            store.persist().unwrap();
        }
        {
            let store = TraceStore::open(&dir).unwrap();
            assert_eq!(store.loaded.checkpoints, 1);
            assert_eq!(store.ckpt_live(), vec![5]);
            assert_eq!(store.ckpt_prefix(5), vec![ck.clone()]);
            // the resumed job completes: tombstone on the next flush
            store.ckpt_retire(5);
            store.persist().unwrap();
        }
        {
            let store = TraceStore::open(&dir).unwrap();
            assert_eq!(store.loaded.checkpoints, 0);
            assert!(store.ckpt_live().is_empty());
        }
        // a job that completes within one session never hits the file
        {
            let store = TraceStore::open(&dir).unwrap();
            store.ckpt_append(6, &ck);
            store.ckpt_retire(6);
            store.persist().unwrap();
        }
        let text = std::fs::read_to_string(dir.join(CHECKPOINTS_FILE))
            .unwrap();
        assert!(!text.contains(&format!("{:016x}", 6u64)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_tolerates_corrupt_cache_tail() {
        let dir = tmp_dir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let store = TraceStore::open(&dir).unwrap();
        store.insert_measurement(1, &meas(0.1));
        store.insert_measurement(2, &meas(0.2));
        store.persist().unwrap();
        // simulate a crash mid-append
        let path = dir.join(KERNELS_FILE);
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"v\":1,\"key\":\"trunca");
        std::fs::write(&path, text).unwrap();
        let store = TraceStore::open(&dir).unwrap();
        assert_eq!(store.loaded.kernels, 2);
        assert_eq!(store.loaded.skipped, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_persist_requeues_deltas_and_degrades() {
        let dir = tmp_dir("failsafe");
        let store = TraceStore::open(&dir).unwrap();
        store.insert_measurement(1, &meas(0.1));
        store.service_insert(9);
        store.tenant_add("t", 1, 2, 0, 0);
        // kill the disk before any byte lands
        store.set_store_fault(StoreFaultPlan {
            kill_at_byte: Some(0),
            ..StoreFaultPlan::default()
        });
        assert!(store.persist().is_err());
        assert!(store.store_degraded());
        assert!(store.flush_errors() >= 1);
        assert!(store.requeued_records() >= 1);
        assert!(store.last_flush_error().is_some());
        // clearing the fault revives the store; nothing was dropped
        store.set_store_fault(StoreFaultPlan::default());
        store.persist().unwrap();
        let reloaded = TraceStore::open(&dir).unwrap();
        assert_eq!(reloaded.loaded.kernels, 1);
        assert_eq!(reloaded.loaded.service, 1);
        assert_eq!(reloaded.loaded.tenants, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_only_store_dir_requeues_instead_of_dropping() {
        let dir = tmp_dir("readonly");
        let store = TraceStore::open(&dir).unwrap();
        store.insert_measurement(3, &meas(0.3));
        let mut perms = std::fs::metadata(&dir).unwrap().permissions();
        perms.set_readonly(true);
        std::fs::set_permissions(&dir, perms.clone()).unwrap();
        let result = store.persist();
        perms.set_readonly(false);
        std::fs::set_permissions(&dir, perms).unwrap();
        if result.is_ok() {
            // running as root: directory permissions are advisory and
            // the write landed; the fault-injector test above covers
            // the failure path
            let _ = std::fs::remove_dir_all(&dir);
            return;
        }
        assert!(store.store_degraded());
        // the delta survived: persisting once writable again lands it
        store.persist().unwrap();
        let reloaded = TraceStore::open(&dir).unwrap();
        assert_eq!(reloaded.loaded.kernels, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn framed_durability_roundtrips_and_counts_corruption_per_file() {
        let dir = tmp_dir("framed");
        {
            let store = TraceStore::open(&dir).unwrap();
            store.set_durability(Durability::Strict);
            store.insert_measurement(1, &meas(0.1));
            store.persist().unwrap();
        }
        let path = dir.join(KERNELS_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with(durable::FRAME_PREFIX));
        // corrupt the framed line's payload (flip its closing byte):
        // the CRC catches it and the skip is attributed to
        // kernels.jsonl specifically
        let corrupted = text.replacen("}\n", "X\n", 1);
        assert_ne!(corrupted, text);
        std::fs::write(&path, corrupted).unwrap();
        let store = TraceStore::open(&dir).unwrap();
        assert_eq!(store.loaded.kernels, 0);
        assert_eq!(store.loaded.corrupt_files(),
                   vec![(KERNELS_FILE, 1)]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_line_is_grep_friendly() {
        let store = TraceStore::in_memory();
        store.stats.measure_sims.fetch_add(3, Ordering::Relaxed);
        store.stats.llm_hits.fetch_add(2, Ordering::Relaxed);
        let line = store.stats_line();
        assert!(line.contains("measure_sim=3"));
        assert!(line.contains("llm_hit=2"));
        assert!(line.contains("measure_hit=0"));
    }
}
