//! `kernelband trace fsck`: offline scan and self-repair for the seven
//! store files.
//!
//! The store's readers already *tolerate* damage (torn tails, corrupt
//! frames and unknown versions are skipped on load), but tolerance
//! leaves the rot on disk: a torn fragment sits in front of every
//! future append, duplicate content lines accumulate, and the
//! checkpoint journal grows without bound as retired jobs pile up
//! tombstones. `fsck` turns the skip counters into a repair:
//!
//! * every file is scanned line by line (CRC framing decoded per line,
//!   exactly like the loaders);
//! * torn/corrupt lines are **quarantined verbatim** — framing and all
//!   — by appending them to `DIR/quarantine/<file>`, never deleted;
//! * parseable lines survive verbatim, including unknown-version lines
//!   (forward compatibility: a newer writer's records are not ours to
//!   judge). The only parseable lines a repair removes are
//!   byte-identical duplicate payloads in the content-addressed files
//!   (the first copy survives) and checkpoint-journal lines belonging
//!   to retired jobs (dropped by canonical compaction, see
//!   [`super::ckpt`]);
//! * repairs rewrite atomically (tmp + rename,
//!   [`super::durable::atomic_rewrite`]) and only when the bytes
//!   actually change, so a second `fsck --repair` is a byte-level
//!   no-op.
//!
//! Exit-code mapping (done by the CLI): 0 clean, 1 issues
//! found/repaired, 2 unrepairable (I/O error mid-scan or mid-repair).

use std::collections::HashSet;
use std::path::Path;

use crate::util::hash::fnv1a;
use crate::util::json::{self, Json};

use super::durable::{self, LineDecode};
use super::{
    ckpt, CHECKPOINTS_FILE, KERNELS_FILE, PROFILES_FILE, PROPOSALS_FILE,
    SERVICE_FILE, STORE_FILES, TRACE_FILE,
};

/// Subdirectory (under the store dir) bad lines are appended to.
pub const QUARANTINE_DIR: &str = "quarantine";

/// What the scan found (and, under `--repair`, did) in one store file.
#[derive(Debug, Default, Clone)]
pub struct FileReport {
    pub file: &'static str,
    /// Non-empty lines scanned.
    pub lines: usize,
    /// Truncated final line (crash mid-append: no trailing newline).
    pub torn: usize,
    /// Corrupt frames / unparseable JSON elsewhere in the file.
    pub corrupt: usize,
    /// Byte-identical duplicate payloads dropped (content files only;
    /// the first copy survives).
    pub duplicates: usize,
    /// Parseable lines with an unrecognized version or shape —
    /// preserved verbatim, reported so a rotting store is visible.
    pub unknown_version: usize,
    /// Checkpoint-journal lines dropped by canonical compaction
    /// (retired jobs' entries, their tombstones, gap-truncated tails).
    pub compacted: usize,
    /// Lines appended to `quarantine/<file>` this run.
    pub quarantined: usize,
    /// Whether `--repair` rewrote the file.
    pub rewritten: bool,
}

impl FileReport {
    /// Lines a repair would (or did) remove from the file.
    pub fn issues(&self) -> usize {
        self.torn + self.corrupt + self.duplicates + self.compacted
    }
}

/// Whole-store scan result, one entry per [`STORE_FILES`] member.
#[derive(Debug, Default)]
pub struct FsckReport {
    pub files: Vec<FileReport>,
    /// Whether this run was allowed to write (`--repair`).
    pub repair: bool,
}

impl FsckReport {
    /// True when no file has removable lines and no rewrite happened.
    pub fn clean(&self) -> bool {
        self.files.iter().all(|f| f.issues() == 0 && !f.rewritten)
    }

    /// Grep-friendly per-file report plus a status line.
    pub fn summary_lines(&self) -> Vec<String> {
        let mut out = Vec::new();
        for f in &self.files {
            out.push(format!(
                "[fsck] {}: lines={} torn={} corrupt={} duplicates={} \
                 unknown_version={} compacted={} quarantined={} \
                 rewritten={}",
                f.file,
                f.lines,
                f.torn,
                f.corrupt,
                f.duplicates,
                f.unknown_version,
                f.compacted,
                f.quarantined,
                f.rewritten,
            ));
        }
        let status = if self.clean() {
            "clean"
        } else if self.repair {
            "repaired"
        } else {
            "issues"
        };
        out.push(format!("[fsck] status={status}"));
        out
    }
}

/// Scan (and with `repair`, heal) every store file under `dir`.
/// Missing files report as empty; any I/O error is "unrepairable" and
/// surfaces as `Err`.
pub fn fsck(dir: &Path, repair: bool) -> std::io::Result<FsckReport> {
    let mut report = FsckReport { files: Vec::new(), repair };
    for name in STORE_FILES {
        report.files.push(scan_file(dir, name, repair)?);
    }
    Ok(report)
}

/// Schema version the file's parseable lines are expected to carry
/// (`None`: the file's own decoder decides, as with checkpoints).
fn expected_version(name: &str) -> Option<f64> {
    match name {
        TRACE_FILE => Some(super::log::TRACE_VERSION),
        CHECKPOINTS_FILE => None,
        _ => Some(super::cache::CACHE_VERSION),
    }
}

fn scan_file(dir: &Path, name: &'static str, repair: bool)
             -> std::io::Result<FileReport> {
    let mut rep = FileReport { file: name, ..FileReport::default() };
    let path = dir.join(name);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(rep);
        }
        Err(e) => return Err(e),
    };
    let complete_tail = text.is_empty() || text.ends_with('\n');
    let dedup_payloads = matches!(
        name,
        KERNELS_FILE | PROPOSALS_FILE | PROFILES_FILE | SERVICE_FILE
    );

    let all: Vec<&str> = text.lines().collect();
    let mut kept: Vec<&str> = Vec::new(); // verbatim survivors
    let mut bad: Vec<&str> = Vec::new(); // verbatim quarantine lines
    let mut journal: Vec<ckpt::JournalLine> = Vec::new();
    let mut unknown_tail: Vec<&str> = Vec::new(); // ckpt: kept unknowns
    let mut seen: HashSet<u64> = HashSet::new();

    for (i, raw) in all.iter().copied().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        rep.lines += 1;
        let is_torn_candidate = i + 1 == all.len() && !complete_tail;
        let mut reject = |rep: &mut FileReport| {
            if is_torn_candidate {
                rep.torn += 1;
            } else {
                rep.corrupt += 1;
            }
            bad.push(raw);
        };
        let payload = match durable::decode_line(line) {
            LineDecode::CorruptFrame => {
                reject(&mut rep);
                continue;
            }
            LineDecode::Raw(p) | LineDecode::Framed(p) => p,
        };
        let value = match json::parse(payload) {
            Ok(v) => v,
            Err(_) => {
                reject(&mut rep);
                continue;
            }
        };
        if name == CHECKPOINTS_FILE {
            match ckpt::journal_from_record(&value) {
                Some(l) => journal.push(l),
                None => {
                    rep.unknown_version += 1;
                    unknown_tail.push(raw);
                }
            }
            continue;
        }
        if expected_version(name).is_some_and(|v| {
            value.get("v").and_then(Json::as_f64) != Some(v)
        }) {
            rep.unknown_version += 1; // preserved, only reported
        }
        if dedup_payloads && !seen.insert(fnv1a(payload.as_bytes())) {
            rep.duplicates += 1; // dropped; the first copy survives
            continue;
        }
        kept.push(raw);
    }

    // the repaired byte image
    let mut new_text = String::new();
    if name == CHECKPOINTS_FILE {
        let (canonical, dropped) = ckpt::compact_lines(journal);
        rep.compacted = dropped;
        new_text.push_str(&canonical);
        for raw in &unknown_tail {
            new_text.push_str(raw);
            new_text.push('\n');
        }
    } else {
        for raw in &kept {
            new_text.push_str(raw);
            new_text.push('\n');
        }
    }

    if repair {
        if !bad.is_empty() {
            let qdir = dir.join(QUARANTINE_DIR);
            std::fs::create_dir_all(&qdir)?;
            let mut q = String::new();
            for raw in &bad {
                q.push_str(raw);
                q.push('\n');
            }
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(qdir.join(name))?;
            f.write_all(q.as_bytes())?;
            rep.quarantined = bad.len();
        }
        if new_text != text {
            durable::atomic_rewrite(&path, new_text.as_bytes())?;
            rep.rewritten = true;
        }
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "kb_fsck_unit_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn read(p: &Path) -> String {
        std::fs::read_to_string(p).unwrap()
    }

    fn report_for<'r>(rep: &'r FsckReport, file: &str)
                      -> &'r FileReport {
        rep.files.iter().find(|f| f.file == file).unwrap()
    }

    #[test]
    fn torn_tail_is_quarantined_verbatim_and_repair_is_idempotent() {
        let dir = tmp_dir("torn");
        let good = "{\"v\":1,\"kind\":\"task\",\"cell\":\"c\"}";
        std::fs::write(
            dir.join(TRACE_FILE),
            format!("{good}\n{{\"v\":1,\"kin"),
        )
        .unwrap();

        // report-only: issues found, nothing written
        let rep = fsck(&dir, false).unwrap();
        assert!(!rep.clean());
        assert_eq!(report_for(&rep, TRACE_FILE).torn, 1);
        assert!(!dir.join(QUARANTINE_DIR).exists());

        let rep = fsck(&dir, true).unwrap();
        let f = report_for(&rep, TRACE_FILE);
        assert_eq!((f.torn, f.quarantined), (1, 1));
        assert!(f.rewritten);
        assert_eq!(read(&dir.join(TRACE_FILE)), format!("{good}\n"));
        assert_eq!(
            read(&dir.join(QUARANTINE_DIR).join(TRACE_FILE)),
            "{\"v\":1,\"kin\n"
        );

        // second repair: byte-level no-op, clean status
        let before = read(&dir.join(TRACE_FILE));
        let rep = fsck(&dir, true).unwrap();
        assert!(rep.clean());
        assert_eq!(read(&dir.join(TRACE_FILE)), before);
        assert_eq!(
            read(&dir.join(QUARANTINE_DIR).join(TRACE_FILE)),
            "{\"v\":1,\"kin\n"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_payloads_drop_but_unknown_versions_survive() {
        let dir = tmp_dir("dups");
        let a = "{\"v\":2,\"key\":\"0000000000000007\"}";
        let b = "{\"v\":2,\"key\":\"0000000000000008\"}";
        let future = "{\"v\":99,\"key\":\"0000000000000009\"}";
        // a appears raw and framed: same payload, still a duplicate
        let framed_a = durable::frame_line(a);
        std::fs::write(
            dir.join(SERVICE_FILE),
            format!("{a}\n{b}\n{framed_a}\n{future}\n"),
        )
        .unwrap();
        let rep = fsck(&dir, true).unwrap();
        let f = report_for(&rep, SERVICE_FILE);
        assert_eq!(f.duplicates, 1);
        assert_eq!(f.unknown_version, 1);
        assert!(f.rewritten);
        // first copy of `a` survives in its original (raw) form; the
        // future-versioned line is preserved verbatim
        assert_eq!(
            read(&dir.join(SERVICE_FILE)),
            format!("{a}\n{b}\n{future}\n")
        );
        assert!(fsck(&dir, true).unwrap().clean());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_compaction_drops_retired_jobs_and_tombstones() {
        let dir = tmp_dir("ckpt");
        // a tombstone with no surviving entries is pure dead weight
        let done = "{\"v\":2,\"kind\":\"done\",\"fp\":\"0000000000000005\"}";
        std::fs::write(
            dir.join(CHECKPOINTS_FILE),
            format!("{done}\n"),
        )
        .unwrap();
        let rep = fsck(&dir, true).unwrap();
        let f = report_for(&rep, CHECKPOINTS_FILE);
        assert_eq!(f.compacted, 1);
        assert!(f.rewritten);
        assert_eq!(read(&dir.join(CHECKPOINTS_FILE)), "");
        assert!(fsck(&dir, true).unwrap().clean());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_files_report_clean() {
        let dir = tmp_dir("empty");
        let rep = fsck(&dir, true).unwrap();
        assert!(rep.clean());
        assert_eq!(rep.files.len(), STORE_FILES.len());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
