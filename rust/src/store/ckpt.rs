//! Mid-job checkpoint journal (`checkpoints.jsonl`).
//!
//! [`crate::store::warm`] replays *completed* traces into priors at
//! session granularity; this journal extends durability to *mid-job*
//! granularity. Each line is one of:
//!
//! * `{"kind":"ckpt", "fp":…, "t":…, …}` — one
//!   [`Checkpoint`](crate::policy::resume::Checkpoint) of the job
//!   addressed by its serve fingerprint: the iteration's strategy pick,
//!   per-slot proposals and per-slot measurements, encoded with the
//!   exact same codecs as the content caches (bit-exact roundtrip);
//! * `{"kind":"done", "fp":…}` — a tombstone: the job completed and its
//!   checkpoint prefix is dead.
//!
//! Replaying the file in order reconstructs, per fingerprint, the
//! checkpoint prefix of every job that was in flight when the session
//! ended — which is exactly what
//! [`crate::server::recover`] hands the supervisor to resume a crashed
//! job on the iteration boundary it died at, instead of restarting it.
//!
//! The journal is a cache, not the source of truth: losing it (torn
//! tail, version bump) only costs re-execution, which the content
//! caches absorb. Decoding is therefore lossy-tolerant like every
//! other store file, and a fingerprint's prefix is truncated at the
//! first gap in its iteration sequence.

use std::collections::{BTreeMap, HashSet};

use crate::policy::resume::{Checkpoint, SlotCheckpoint};
use crate::strategy::Strategy;
use crate::util::json::Json;

use super::cache::{
    self, config_from_arr, config_to_arr, outcome_from_str, outcome_str,
};
use super::{
    counters_from_json, counters_to_json, hex_u64, parse_hex_u64,
};

fn slot_to_json(s: &SlotCheckpoint) -> Json {
    let p = &s.proposal;
    let mut obj = Json::obj(vec![
        ("outcome", Json::str(outcome_str(p.outcome))),
        ("config", config_to_arr(&p.config)),
        ("tokens_in", Json::num(p.tokens_in as f64)),
        ("tokens_out", Json::num(p.tokens_out as f64)),
        ("cost_usd", Json::num(p.cost_usd)),
        ("latency_s", Json::num(p.latency_s)),
    ]);
    if let Some(m) = &s.measured {
        obj.insert(
            "measured",
            Json::obj(vec![
                ("total_s", Json::num(m.total_latency_s)),
                (
                    "shapes",
                    Json::Arr(
                        m.per_shape_s
                            .iter()
                            .map(|&v| Json::num(v))
                            .collect(),
                    ),
                ),
                ("counters", counters_to_json(&m.counters)),
            ]),
        );
    }
    obj
}

fn slot_from_json(j: &Json) -> Option<SlotCheckpoint> {
    let proposal = crate::llm::Proposal {
        outcome: outcome_from_str(j.str_field("outcome").ok()?)?,
        config: config_from_arr(j.get("config")?)?,
        tokens_in: j.f64_field("tokens_in") as u64,
        tokens_out: j.f64_field("tokens_out") as u64,
        cost_usd: j.get("cost_usd")?.as_f64()?,
        latency_s: j.get("latency_s")?.as_f64()?,
    };
    let measured = match j.get("measured") {
        None => None,
        Some(m) => Some(crate::kernel::Measurement {
            total_latency_s: m.get("total_s")?.as_f64()?,
            per_shape_s: m
                .get("shapes")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64().unwrap_or(0.0))
                .collect(),
            counters: counters_from_json(m.get("counters")?),
        }),
    };
    Some(SlotCheckpoint { proposal, measured })
}

/// Serialize one checkpoint of job `fp` as a JSONL value.
pub(crate) fn ckpt_record(fp: u64, c: &Checkpoint) -> Json {
    let mut obj = Json::obj(vec![
        ("v", Json::num(cache::CACHE_VERSION)),
        ("kind", Json::str("ckpt")),
        ("fp", hex_u64(fp)),
        ("t", Json::num(c.t as f64)),
        (
            "slots",
            Json::Arr(c.slots.iter().map(slot_to_json).collect()),
        ),
    ]);
    if let Some(s) = c.strategy {
        obj.insert("strategy", Json::num(s.index() as f64));
    }
    obj
}

/// Serialize a completion tombstone for job `fp`.
pub(crate) fn done_record(fp: u64) -> Json {
    Json::obj(vec![
        ("v", Json::num(cache::CACHE_VERSION)),
        ("kind", Json::str("done")),
        ("fp", hex_u64(fp)),
    ])
}

/// One decoded journal line.
pub(crate) enum JournalLine {
    Ckpt(u64, Checkpoint),
    Done(u64),
}

/// Decode one journal line; `None` on unknown version/kind/shape.
pub(crate) fn journal_from_record(j: &Json) -> Option<JournalLine> {
    if j.get("v").and_then(Json::as_f64) != Some(cache::CACHE_VERSION) {
        return None;
    }
    let fp = parse_hex_u64(j.get("fp"))?;
    match j.get("kind")?.as_str()? {
        "done" => Some(JournalLine::Done(fp)),
        "ckpt" => {
            let strategy = match j.get("strategy") {
                None => None,
                Some(v) => {
                    let i = v.as_f64()? as usize;
                    if i >= crate::strategy::NUM_STRATEGIES {
                        return None;
                    }
                    Some(Strategy::from_index(i))
                }
            };
            let slots = j
                .get("slots")?
                .as_arr()?
                .iter()
                .map(slot_from_json)
                .collect::<Option<Vec<_>>>()?;
            Some(JournalLine::Ckpt(
                fp,
                Checkpoint {
                    t: j.f64_field("t") as usize,
                    strategy,
                    slots,
                },
            ))
        }
        _ => None,
    }
}

/// In-memory journal state: live checkpoint prefixes per fingerprint
/// plus the lines pending the next flush.
///
/// ## Multi-writer append discipline
///
/// Worker shards checkpoint concurrently, so the pending line order
/// interleaves fingerprints nondeterministically. That is sound
/// because replay groups lines *per fingerprint* (each fingerprint's
/// own lines stay in emission order under the registry mutex) — but it
/// means `checkpoints.jsonl` is the one store file whose bytes are
/// **not** compared across runs; the determinism contract covers the
/// artifacts and `trace.jsonl`, never the journal.
#[derive(Debug, Default)]
pub(crate) struct CkptRegistry {
    live: BTreeMap<u64, Vec<Checkpoint>>,
    pending: Vec<(u64, Json)>,
    /// Fingerprints with at least one line already flushed to disk —
    /// their retirement must append a tombstone; a fingerprint retired
    /// before any flush simply drops its pending lines.
    flushed: HashSet<u64>,
    /// Journal health observed at load time (`trace stats`).
    health: JournalHealth,
}

/// What a journal load found on disk — the operator-facing audit view
/// printed by `trace stats` (live prefixes = recoverable in-flight
/// jobs; retired = completed jobs whose lines are tombstoned dead
/// weight awaiting a future compaction).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct JournalHealth {
    /// Decoded `ckpt` lines in the file (live + dead).
    pub ckpt_lines: usize,
    /// `done` tombstone lines.
    pub tombstones: usize,
    /// Fingerprints with a usable (contiguous, untombstoned) prefix.
    pub live_jobs: usize,
    /// Checkpoints across all live prefixes after normalization.
    pub live_entries: usize,
    /// Fingerprints whose lines are all dead: tombstoned, or truncated
    /// away entirely by gap/dedup normalization.
    pub retired_jobs: usize,
}

impl CkptRegistry {
    pub fn append(&mut self, fp: u64, c: &Checkpoint) {
        self.pending.push((fp, ckpt_record(fp, c)));
        self.live.entry(fp).or_default().push(c.clone());
    }

    /// Current checkpoint prefix for `fp` (empty when none).
    pub fn prefix(&self, fp: u64) -> Vec<Checkpoint> {
        self.live.get(&fp).cloned().unwrap_or_default()
    }

    /// The job completed: drop its prefix and tombstone it on disk if
    /// any of its lines already landed there.
    pub fn retire(&mut self, fp: u64) {
        self.live.remove(&fp);
        self.pending.retain(|(f, _)| *f != fp);
        if self.flushed.contains(&fp) {
            self.pending.push((fp, done_record(fp)));
        }
    }

    /// Fingerprints with a live (non-empty) checkpoint prefix.
    pub fn live_fingerprints(&self) -> Vec<u64> {
        self.live
            .iter()
            .filter(|(_, v)| !v.is_empty())
            .map(|(&k, _)| k)
            .collect()
    }

    /// Stage pending lines for a flush attempt. Nothing is marked
    /// flushed yet: the fail-safe persist calls
    /// [`CkptRegistry::mark_flushed`] once the append lands or
    /// [`CkptRegistry::restore_pending`] when it errors, so a failed
    /// write never convinces retirement that a tombstone is owed.
    pub fn stage_pending(&mut self) -> Vec<(u64, Json)> {
        std::mem::take(&mut self.pending)
    }

    /// A staged flush landed: remember which fingerprints now have
    /// on-disk lines (their retirement must append a tombstone).
    pub fn mark_flushed(&mut self, lines: &[(u64, Json)]) {
        for (fp, _) in lines {
            self.flushed.insert(*fp);
        }
    }

    /// A staged flush failed: re-queue the lines ahead of anything
    /// appended meanwhile, preserving journal order.
    pub fn restore_pending(&mut self, mut lines: Vec<(u64, Json)>) {
        lines.append(&mut self.pending);
        self.pending = lines;
    }

    /// Drain pending lines as JSONL text for appending, marking them
    /// flushed (the pre-fail-safe convenience path; tests use it).
    pub fn take_pending(&mut self) -> String {
        let lines = self.stage_pending();
        self.mark_flushed(&lines);
        let mut out = String::new();
        for (_, line) in lines {
            out.push_str(&line.dump());
            out.push('\n');
        }
        out
    }

    /// Rebuild from decoded journal lines (load path). Applies lines in
    /// file order, then normalizes each fingerprint's prefix: sorted by
    /// iteration, truncated at the first gap, so a torn tail can never
    /// fabricate a resumable-looking but discontiguous prefix.
    pub fn load(&mut self, lines: Vec<JournalLine>) -> usize {
        let mut seen: HashSet<u64> = HashSet::new();
        for line in lines {
            match line {
                JournalLine::Ckpt(fp, c) => {
                    self.health.ckpt_lines += 1;
                    seen.insert(fp);
                    self.flushed.insert(fp);
                    self.live.entry(fp).or_default().push(c);
                }
                JournalLine::Done(fp) => {
                    self.health.tombstones += 1;
                    seen.insert(fp);
                    self.flushed.insert(fp);
                    self.live.remove(&fp);
                }
            }
        }
        self.live.retain(|_, cks| {
            cks.sort_by_key(|c| c.t);
            cks.dedup_by_key(|c| c.t);
            let mut keep = 0;
            while keep < cks.len() && cks[keep].t == keep + 1 {
                keep += 1;
            }
            cks.truncate(keep);
            !cks.is_empty()
        });
        self.health.live_jobs = self.live.len();
        self.health.live_entries =
            self.live.values().map(Vec::len).sum();
        self.health.retired_jobs = seen.len() - self.live.len();
        self.live.len()
    }

    /// Journal health as observed by the last [`CkptRegistry::load`].
    pub fn journal_health(&self) -> JournalHealth {
        self.health
    }
}

/// Compact a decoded journal: re-emit only what [`CkptRegistry::load`]
/// would keep — the live, normalized checkpoint prefixes — dropping
/// tombstoned/retired jobs' lines and the tombstones themselves (the
/// unbounded-growth dead weight `trace fsck --repair` reclaims).
/// Emission is canonical (fingerprints ascending, iterations
/// ascending), so compacting a compacted journal is the byte-level
/// identity. Returns `(compacted JSONL text, dropped line count)`.
pub(crate) fn compact_lines(lines: Vec<JournalLine>)
                            -> (String, usize) {
    let total = lines.len();
    let mut reg = CkptRegistry::default();
    reg.load(lines);
    let mut out = String::new();
    let mut kept = 0usize;
    for (fp, cks) in &reg.live {
        for c in cks {
            out.push_str(&ckpt_record(*fp, c).dump());
            out.push('\n');
            kept += 1;
        }
    }
    (out, total - kept)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{Counters, KernelConfig, Measurement};
    use crate::llm::{GenOutcome, Proposal};

    fn sample_ckpt(t: usize) -> Checkpoint {
        Checkpoint {
            t,
            strategy: Some(Strategy::Fusion),
            slots: vec![
                SlotCheckpoint {
                    proposal: Proposal {
                        outcome: GenOutcome::Ok,
                        config: KernelConfig {
                            tile_m: 3,
                            tile_n: 4,
                            tile_k: 2,
                            vector: 1,
                            fusion: 2,
                            pipeline: 3,
                            loop_order: 5,
                            layout: 1,
                        },
                        tokens_in: 20_800,
                        tokens_out: 11_200,
                        cost_usd: 0.01234567,
                        latency_s: 700.125,
                    },
                    measured: Some(Measurement {
                        total_latency_s: 0.001234567890123,
                        per_shape_s: vec![0.0004, 0.0008345678901234],
                        counters: Counters {
                            sm_pct: 33.33333333333333,
                            ..Default::default()
                        },
                    }),
                },
                SlotCheckpoint {
                    proposal: Proposal {
                        outcome: GenOutcome::CompileError,
                        config: KernelConfig::naive(),
                        tokens_in: 1,
                        tokens_out: 2,
                        cost_usd: 0.5,
                        latency_s: 1.5,
                    },
                    measured: None,
                },
            ],
        }
    }

    #[test]
    fn checkpoint_roundtrip_is_bit_exact() {
        let ck = sample_ckpt(7);
        let line = ckpt_record(0xfeed_0000_0000_beef, &ck).dump();
        let parsed = crate::util::json::parse(&line).unwrap();
        match journal_from_record(&parsed).unwrap() {
            JournalLine::Ckpt(fp, back) => {
                assert_eq!(fp, 0xfeed_0000_0000_beef);
                assert_eq!(back, ck);
            }
            JournalLine::Done(_) => panic!("wrong kind"),
        }
        // a strategy-less (freeform) checkpoint omits the field
        let mut no_strat = sample_ckpt(1);
        no_strat.strategy = None;
        let line = ckpt_record(1, &no_strat).dump();
        assert!(!line.contains("strategy"));
        let parsed = crate::util::json::parse(&line).unwrap();
        match journal_from_record(&parsed).unwrap() {
            JournalLine::Ckpt(_, back) => assert_eq!(back, no_strat),
            JournalLine::Done(_) => panic!("wrong kind"),
        }
    }

    #[test]
    fn registry_retire_before_flush_leaves_no_bytes() {
        let mut reg = CkptRegistry::default();
        reg.append(9, &sample_ckpt(1));
        reg.append(9, &sample_ckpt(2));
        assert_eq!(reg.prefix(9).len(), 2);
        assert_eq!(reg.live_fingerprints(), vec![9]);
        // completed before any flush: the journal never sees the job
        reg.retire(9);
        assert!(reg.prefix(9).is_empty());
        assert!(reg.take_pending().is_empty());
    }

    #[test]
    fn registry_tombstones_after_flush() {
        let mut reg = CkptRegistry::default();
        reg.append(9, &sample_ckpt(1));
        let flushed = reg.take_pending();
        assert_eq!(flushed.lines().count(), 1);
        reg.retire(9);
        let tomb = reg.take_pending();
        assert!(tomb.contains("\"kind\":\"done\""));
    }

    #[test]
    fn staged_flush_restores_on_error_and_never_false_tombstones() {
        let mut reg = CkptRegistry::default();
        reg.append(9, &sample_ckpt(1));
        reg.append(9, &sample_ckpt(2));
        let staged = reg.stage_pending();
        assert_eq!(staged.len(), 2);
        // simulate a failed append: restore, then retire — no line was
        // ever flushed, so no tombstone is owed
        reg.restore_pending(staged);
        reg.retire(9);
        assert!(reg.take_pending().is_empty());
        // and the success path still tombstones
        let mut reg = CkptRegistry::default();
        reg.append(9, &sample_ckpt(1));
        let staged = reg.stage_pending();
        reg.mark_flushed(&staged);
        reg.retire(9);
        assert!(reg.take_pending().contains("\"kind\":\"done\""));
    }

    #[test]
    fn compaction_keeps_live_prefixes_and_is_idempotent() {
        let lines = vec![
            JournalLine::Ckpt(2, sample_ckpt(1)),
            JournalLine::Ckpt(1, sample_ckpt(1)),
            JournalLine::Ckpt(2, sample_ckpt(2)),
            JournalLine::Done(2), // retired: all its lines are dead
            JournalLine::Ckpt(1, sample_ckpt(2)),
            JournalLine::Ckpt(1, sample_ckpt(4)), // gap: truncated away
        ];
        let (text, dropped) = compact_lines(lines);
        assert_eq!(dropped, 4); // fp2's two lines + tombstone + the gap
        assert_eq!(text.lines().count(), 2);
        assert!(!text.contains("\"kind\":\"done\""));
        // idempotent: compacting the compacted text is the identity
        let values: Vec<Json> = text
            .lines()
            .map(|l| crate::util::json::parse(l).unwrap())
            .collect();
        let decoded: Vec<JournalLine> = values
            .iter()
            .map(|v| journal_from_record(v).unwrap())
            .collect();
        let (again, dropped2) = compact_lines(decoded);
        assert_eq!(again, text);
        assert_eq!(dropped2, 0);
    }

    #[test]
    fn load_reconstructs_prefixes_and_applies_tombstones() {
        let lines = vec![
            JournalLine::Ckpt(1, sample_ckpt(1)),
            JournalLine::Ckpt(2, sample_ckpt(1)),
            JournalLine::Ckpt(1, sample_ckpt(2)),
            JournalLine::Done(2),
            // gap: t=4 without t=3 must truncate to the contiguous
            // prefix [1, 2]
            JournalLine::Ckpt(1, sample_ckpt(4)),
        ];
        let mut reg = CkptRegistry::default();
        let live = reg.load(lines);
        assert_eq!(live, 1);
        assert_eq!(reg.live_fingerprints(), vec![1]);
        let prefix = reg.prefix(1);
        assert_eq!(
            prefix.iter().map(|c| c.t).collect::<Vec<_>>(),
            vec![1, 2]
        );
        assert!(reg.prefix(2).is_empty());
        assert_eq!(
            reg.journal_health(),
            JournalHealth {
                ckpt_lines: 4,
                tombstones: 1,
                live_jobs: 1,
                live_entries: 2,
                retired_jobs: 1,
            }
        );
    }
}
