//! Roofline GPU simulator — the hardware substrate.
//!
//! The paper measures kernels on RTX 4090 / H20 / A100 and feeds two
//! things back into the search: *latency* and *NCU throughput counters*.
//! This module produces both from an analytical model in the spirit of
//! Williams et al.'s roofline (the same model the paper's Assumption 1
//! bounding function B(k,s) is built on):
//!
//! ```text
//! latency = max(t_compute, t_dram, t_l2) + launch_overhead
//! t_compute = flops / (peak_flops   · eff_compute(config))
//! t_dram    = bytes / (dram_bw      · eff_memory(config))
//! t_l2      = l2_bytes / (l2_bw     · eff_l2(config))
//! ```
//!
//! where the efficiency terms depend on how close the candidate's
//! schedule is to the task's latent optimum along each strategy
//! dimension, scaled by the task's sensitivity, and multiplied by an
//! occupancy factor derived from register/shared-memory pressure — so
//! the simulator exposes exactly the structure KernelBand's assumptions
//! require: per-device compute/memory crossovers (H20 is bandwidth-rich
//! and compute-poor, RTX 4090 the inverse, A100 balanced) and Lipschitz-
//! continuous rewards in behaviour space.
//!
//! Deterministic multiplicative lognormal noise (±2% geometric σ) models
//! run-to-run variance; it is keyed by the caller's RNG so experiments
//! are bit-reproducible.


use crate::kernel::{Counters, KernelConfig, Measurement};
use crate::rng::Rng;
use crate::workload::TaskSpec;

/// The three evaluation platforms (paper §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Device {
    Rtx4090,
    H20,
    A100,
}

pub const ALL_DEVICES: [Device; 3] = [Device::Rtx4090, Device::H20, Device::A100];

impl Device {
    pub fn name(self) -> &'static str {
        match self {
            Device::Rtx4090 => "RTX 4090",
            Device::H20 => "H20",
            Device::A100 => "A100",
        }
    }

    pub fn profile(self) -> DeviceProfile {
        match self {
            // Consumer Ada: massive FP pipes, modest GDDR6X bandwidth,
            // huge L2 — most kernels are memory-bound here.
            Device::Rtx4090 => DeviceProfile {
                device: self,
                peak_tflops: 82.6,
                dram_gbps: 1008.0,
                l2_mb: 72.0,
                l2_bw_factor: 4.0,
                sm_count: 128,
                regfile_per_sm: 65_536,
                smem_per_sm_kb: 100.0,
                max_threads_per_sm: 1536,
                launch_us: 5.0,
                optimal_tile_idx: 3, // 64-wide tiles fit the big L2 well
            },
            // Hopper bandwidth-binned part: HBM3-rich, compute-poor —
            // the heavy kernels go compute-bound.
            Device::H20 => DeviceProfile {
                device: self,
                peak_tflops: 44.0,
                dram_gbps: 4000.0,
                l2_mb: 60.0,
                l2_bw_factor: 3.0,
                sm_count: 78,
                regfile_per_sm: 65_536,
                smem_per_sm_kb: 228.0,
                max_threads_per_sm: 2048,
                launch_us: 5.0,
                optimal_tile_idx: 4, // large tiles amortize weak SMs
            },
            // Ampere datacenter: balanced tensor-core machine.
            Device::A100 => DeviceProfile {
                device: self,
                peak_tflops: 156.0,
                dram_gbps: 2039.0,
                l2_mb: 40.0,
                l2_bw_factor: 3.2,
                sm_count: 108,
                regfile_per_sm: 65_536,
                smem_per_sm_kb: 164.0,
                max_threads_per_sm: 2048,
                launch_us: 4.0,
                optimal_tile_idx: 3,
            },
        }
    }
}

/// Static hardware description.
#[derive(Debug, Clone, Copy)]
pub struct DeviceProfile {
    pub device: Device,
    pub peak_tflops: f64,
    pub dram_gbps: f64,
    pub l2_mb: f64,
    /// L2 bandwidth as a multiple of DRAM bandwidth.
    pub l2_bw_factor: f64,
    pub sm_count: u32,
    pub regfile_per_sm: u32,
    pub smem_per_sm_kb: f64,
    pub max_threads_per_sm: u32,
    pub launch_us: f64,
    /// Index into `kernel::TILE_LEVELS` of the tile edge this device
    /// prefers (before per-task jitter).
    pub optimal_tile_idx: i8,
}

impl DeviceProfile {
    /// FLOPs-per-byte machine balance — the roofline ridge point.
    pub fn balance(&self) -> f64 {
        self.peak_tflops * 1.0e12 / (self.dram_gbps * 1.0e9)
    }
}

/// Resource pressure / occupancy for a schedule (CUDA-flavoured).
#[derive(Debug, Clone, Copy)]
pub struct Occupancy {
    pub regs_per_thread: f64,
    pub smem_per_block: f64,
    pub threads_per_block: f64,
    pub occupancy: f64,
}

/// Per-config efficiency decomposition (useful for tests/diagnostics).
#[derive(Debug, Clone, Copy)]
pub struct Efficiency {
    pub compute: f64,
    pub memory: f64,
    pub l2: f64,
    /// Effective HBM bytes after fusion, as a fraction of minimal bytes.
    pub traffic_factor: f64,
    pub occ: Occupancy,
}

/// The simulator.
#[derive(Debug, Clone)]
pub struct GpuSim {
    pub profile: DeviceProfile,
    /// Geometric σ of run-to-run noise (0 disables).
    pub noise_sigma: f64,
}

// Achievable (fully-optimized) efficiencies: a well-tuned kernel
// reaches ~88% of a unit's peak, so saturation (θ_sat = 75%) is
// reachable — which is what arms the hardware masks late in a search.
const BASE_COMPUTE_EFF: f64 = 0.88;
const BASE_MEMORY_EFF: f64 = 0.88;
const BASE_L2_EFF: f64 = 0.80;
const EFF_CAP: f64 = 0.95;

/// `1 - sensitivity · (1 - goodness)` — a wrong setting along a dimension
/// costs at most `sensitivity` of the efficiency.
#[inline]
fn dim_mult(sensitivity: f64, goodness: f64) -> f64 {
    1.0 - sensitivity * (1.0 - goodness.clamp(0.0, 1.0))
}

impl GpuSim {
    pub fn new(device: Device) -> GpuSim {
        GpuSim { profile: device.profile(), noise_sigma: 0.02 }
    }

    /// Noise-free simulator (property tests, bound computations).
    pub fn noiseless(device: Device) -> GpuSim {
        GpuSim { profile: device.profile(), noise_sigma: 0.0 }
    }

    /// Stable fingerprint of the simulated hardware: every
    /// [`DeviceProfile`] field plus the noise model. Part of the
    /// persistent store's content-address ([`crate::store`]) — a
    /// measurement cached on one device (or at one noise setting, or
    /// before a profile retune) is never served for another.
    pub fn fingerprint(&self) -> u64 {
        let p = &self.profile;
        crate::util::hash::KeyHasher::new("gpu")
            .str(p.device.name())
            .f64(p.peak_tflops)
            .f64(p.dram_gbps)
            .f64(p.l2_mb)
            .f64(p.l2_bw_factor)
            .u64(p.sm_count as u64)
            .u64(p.regfile_per_sm as u64)
            .f64(p.smem_per_sm_kb)
            .u64(p.max_threads_per_sm as u64)
            .f64(p.launch_us)
            .u64(p.optimal_tile_idx as u64)
            .f64(self.noise_sigma)
            .finish()
    }

    /// The device+task optimal tile index for each of (m, n, k).
    pub fn optimal_tile(&self, task: &TaskSpec) -> (i8, i8, i8) {
        let base = (self.profile.optimal_tile_idx + task.latent.tile_bias)
            .clamp(1, 5);
        (base, base, (base - 1).max(0))
    }

    /// Occupancy model: registers, shared memory and thread-count
    /// pressure as a function of the schedule.
    pub fn occupancy(&self, cfg: &KernelConfig) -> Occupancy {
        let (tm, tn, tk) = cfg.tiles();
        let vec = cfg.vector_width() as f64;
        let regs = 28.0
            + 6.0 * vec
            + 5.0 * cfg.tile_k as f64
            + 9.0 * cfg.fusion as f64
            + 11.0 * cfg.pipeline as f64;
        let threads = ((tm * tn) as f64 / vec).clamp(32.0, 1024.0);
        let smem = ((tm * tk + tk * tn) as f64) * 4.0
            * (1.0 + cfg.pipeline as f64);
        let p = &self.profile;
        let by_regs = p.regfile_per_sm as f64 / (regs * threads);
        let by_smem = (p.smem_per_sm_kb * 1024.0) / smem.max(1.0);
        let by_threads = p.max_threads_per_sm as f64 / threads;
        let blocks_per_sm = by_regs.min(by_smem).min(by_threads).min(16.0);
        let occupancy = (blocks_per_sm * threads
            / p.max_threads_per_sm as f64)
            .clamp(0.0, 1.0);
        Occupancy {
            regs_per_thread: regs,
            smem_per_block: smem,
            threads_per_block: threads,
            occupancy,
        }
    }

    /// Efficiency decomposition for a schedule on a task.
    pub fn efficiency(&self, task: &TaskSpec, cfg: &KernelConfig) -> Efficiency {
        let lat = &task.latent;
        let s = &lat.sensitivity;
        let occ = self.occupancy(cfg);

        // --- Tiling: log-index distance from the device+task optimum ---
        let (om, on, ok) = self.optimal_tile(task);
        let dist = (cfg.tile_m as i32 - om as i32).abs() as f64
            + (cfg.tile_n as i32 - on as i32).abs() as f64
            + 0.5 * (cfg.tile_k as i32 - ok as i32).abs() as f64;
        let g_tile = 0.80f64.powf(dist);

        // --- Vectorization: fraction of the best lane width ---
        let best_vw = crate::kernel::VECTOR_LEVELS[lat.best_vector as usize] as f64;
        let vw = cfg.vector_width() as f64;
        let g_vec = (vw.min(best_vw) / best_vw).powf(0.7)
            * if vw > best_vw { 0.92 } else { 1.0 }; // over-vectorize: spills

        // --- Fusion: traffic reduction up to the useful cap ---
        let useful = cfg.fusion.min(lat.max_fusion) as f64;
        let cap = lat.max_fusion.max(1) as f64;
        let traffic_factor = 1.0 - lat.fusion_saving * (useful / cap);
        let over_fusion = (cfg.fusion.saturating_sub(lat.max_fusion)) as f64;
        let g_fuse_penalty = 0.96f64.powf(over_fusion);

        // --- Pipeline: best depth ~2 stages; deviation hurts ---
        let g_pipe = 1.0 - 0.22 * ((cfg.pipeline as f64 - 2.0).abs() / 2.0);

        // --- Reordering / layout: right-or-wrong with partial credit ---
        let g_reorder = if cfg.loop_order == lat.best_loop_order {
            1.0
        } else {
            0.65
        };
        let g_layout = if cfg.layout == lat.best_layout { 1.0 } else { 0.60 };

        // Occupancy contributes with diminishing returns: even 50%
        // occupancy keeps most units busy on latency-tolerant kernels.
        let occ_factor = 0.45 + 0.55 * occ.occupancy.powf(0.6);

        let compute = (BASE_COMPUTE_EFF
            * dim_mult(s[0], g_tile)
            * dim_mult(s[3], g_pipe)
            * dim_mult(s[4], g_reorder)
            * g_fuse_penalty
            * occ_factor
            / BASE_OCC_NORM)
            .min(EFF_CAP);
        let memory = (BASE_MEMORY_EFF
            * dim_mult(s[1], g_vec)
            * dim_mult(s[5], g_layout)
            * occ_factor.sqrt()
            / BASE_OCC_NORM.sqrt())
        .min(EFF_CAP);
        let l2 = (BASE_L2_EFF
            * dim_mult(s[5], g_layout)
            * dim_mult(s[4], g_reorder))
        .min(EFF_CAP);

        Efficiency { compute, memory, l2, traffic_factor, occ }
    }

    /// Simulate one benchmark run of `cfg` on `task`; `rng` keys the
    /// measurement noise.
    pub fn evaluate(&self, task: &TaskSpec, cfg: &KernelConfig,
                    rng: &mut Rng) -> Measurement {
        let p = &self.profile;
        let eff = self.efficiency(task, cfg);
        let peak_flops = p.peak_tflops * 1.0e12;
        let dram_bw = p.dram_gbps * 1.0e9;
        let l2_bw = dram_bw * p.l2_bw_factor;
        let launch_s = p.launch_us * 1.0e-6;

        let mut per_shape = Vec::with_capacity(task.shapes.len());
        let mut total = 0.0;
        let mut sm_acc = 0.0;
        let mut dram_acc = 0.0;
        let mut l2_acc = 0.0;
        // one derived noise stream per (measurement, schedule): shapes
        // draw sequentially from it — same determinism as per-shape
        // splitting, one label hash instead of |shapes| (§Perf: −29%)
        let mut noise_rng = if self.noise_sigma > 0.0 {
            Some(rng.split("noise", cfg.code_hash()))
        } else {
            None
        };
        for shape in task.shapes.iter() {
            let bytes_eff = shape.bytes * eff.traffic_factor;
            // L2 traffic is amplified when layout/order thrash the cache
            // and when the working set spills past L2.
            let spill = (shape.working_set / (p.l2_mb * 1.0e6)).min(2.0);
            let l2_bytes = bytes_eff * (1.1 + 0.5 * (1.0 - eff.l2) + 0.25 * spill);
            let t_comp = shape.flops / (peak_flops * eff.compute);
            let t_dram = bytes_eff / (dram_bw * eff.memory);
            let t_l2 = l2_bytes / (l2_bw * eff.l2);
            let ideal = t_comp.max(t_dram).max(t_l2) + launch_s;
            let noise = match noise_rng.as_mut() {
                Some(nr) => nr.lognormal_noise(self.noise_sigma),
                None => 1.0,
            };
            let t = ideal * noise;
            per_shape.push(t);
            total += t;
            // Achieved throughput as % of peak (the NCU metrics): the
            // time-weighted mean Σ(work_i/peak)/t_i · t_i / Σt_i — the
            // t_i cancel, leaving total ideal work over peak (divided by
            // the total time below). The cancelled form also skips two
            // rounding steps per shape.
            sm_acc += 100.0 * (shape.flops / peak_flops);
            dram_acc += 100.0 * (bytes_eff / dram_bw);
            l2_acc += 100.0 * (l2_bytes / l2_bw);
        }
        let counters = Counters {
            regs_per_thread: eff.occ.regs_per_thread,
            smem_per_block: eff.occ.smem_per_block,
            block_dim: eff.occ.threads_per_block,
            occupancy: eff.occ.occupancy,
            sm_pct: (sm_acc / total).min(100.0),
            dram_pct: (dram_acc / total).min(100.0),
            l2_pct: (l2_acc / total).min(100.0),
        };
        Measurement { total_latency_s: total, per_shape_s: per_shape, counters }
    }

    /// Fused multi-candidate evaluation: loop the task's shapes **once
    /// per batch** instead of once per candidate, amortizing the
    /// per-shape spill/traffic terms and shape-data traversal across
    /// the whole batch (the batched-measurement hot path,
    /// [`crate::sched`]).
    ///
    /// Per candidate the arithmetic is *identical* to
    /// [`GpuSim::evaluate`] — independent accumulators, shapes visited
    /// in the same order, the noise stream split from that candidate's
    /// RNG by the same `("noise", code_hash)` lineage — so every
    /// returned [`Measurement`] is bit-identical to a standalone
    /// `evaluate` call (property-tested in `rust/tests/prop_sched.rs`).
    pub fn evaluate_batch(&self, task: &TaskSpec, cfgs: &[KernelConfig],
                          rngs: &mut [Rng]) -> Vec<Measurement> {
        debug_assert_eq!(cfgs.len(), rngs.len());
        let n = cfgs.len();
        if n == 0 {
            return Vec::new();
        }
        let p = &self.profile;
        let peak_flops = p.peak_tflops * 1.0e12;
        let dram_bw = p.dram_gbps * 1.0e9;
        let l2_bw = dram_bw * p.l2_bw_factor;
        let launch_s = p.launch_us * 1.0e-6;
        let effs: Vec<Efficiency> =
            cfgs.iter().map(|c| self.efficiency(task, c)).collect();
        let mut noise: Vec<Option<Rng>> = cfgs
            .iter()
            .zip(rngs.iter_mut())
            .map(|(c, r)| {
                if self.noise_sigma > 0.0 {
                    Some(r.split("noise", c.code_hash()))
                } else {
                    None
                }
            })
            .collect();
        let shapes = task.shapes.len();
        let mut per_shape: Vec<Vec<f64>> =
            (0..n).map(|_| Vec::with_capacity(shapes)).collect();
        let mut total = vec![0.0f64; n];
        let mut sm_acc = vec![0.0f64; n];
        let mut dram_acc = vec![0.0f64; n];
        let mut l2_acc = vec![0.0f64; n];
        for shape in task.shapes.iter() {
            // candidate-independent per-shape terms, loaded once
            let spill = (shape.working_set / (p.l2_mb * 1.0e6)).min(2.0);
            let sm_pts = 100.0 * (shape.flops / peak_flops);
            for i in 0..n {
                let eff = &effs[i];
                let bytes_eff = shape.bytes * eff.traffic_factor;
                let l2_bytes = bytes_eff
                    * (1.1 + 0.5 * (1.0 - eff.l2) + 0.25 * spill);
                let t_comp = shape.flops / (peak_flops * eff.compute);
                let t_dram = bytes_eff / (dram_bw * eff.memory);
                let t_l2 = l2_bytes / (l2_bw * eff.l2);
                let ideal = t_comp.max(t_dram).max(t_l2) + launch_s;
                let noise_f = match noise[i].as_mut() {
                    Some(nr) => nr.lognormal_noise(self.noise_sigma),
                    None => 1.0,
                };
                let t = ideal * noise_f;
                per_shape[i].push(t);
                total[i] += t;
                sm_acc[i] += sm_pts;
                dram_acc[i] += 100.0 * (bytes_eff / dram_bw);
                l2_acc[i] += 100.0 * (l2_bytes / l2_bw);
            }
        }
        (0..n)
            .map(|i| Measurement {
                total_latency_s: total[i],
                per_shape_s: std::mem::take(&mut per_shape[i]),
                counters: Counters {
                    regs_per_thread: effs[i].occ.regs_per_thread,
                    smem_per_block: effs[i].occ.smem_per_block,
                    block_dim: effs[i].occ.threads_per_block,
                    occupancy: effs[i].occ.occupancy,
                    sm_pct: (sm_acc[i] / total[i]).min(100.0),
                    dram_pct: (dram_acc[i] / total[i]).min(100.0),
                    l2_pct: (l2_acc[i] / total[i]).min(100.0),
                },
            })
            .collect()
    }

    /// Latency of the best reachable schedule (latent optimum) — used by
    /// tests and the Theorem-1 regret diagnostics, not by the search.
    pub fn oracle_config(&self, task: &TaskSpec) -> KernelConfig {
        let (om, on, ok) = self.optimal_tile(task);
        KernelConfig {
            tile_m: om as u8,
            tile_n: on as u8,
            tile_k: ok as u8,
            vector: task.latent.best_vector,
            fusion: task.latent.max_fusion,
            pipeline: 2,
            loop_order: task.latent.best_loop_order,
            layout: task.latent.best_layout,
        }
        .clamped()
    }
}

/// Normalization so the *naive* occupancy factor doesn't double-count —
/// computed for a mid-range occupancy of ~0.75.
const BASE_OCC_NORM: f64 = 0.45 + 0.55 * 0.8254; // occ=0.75^0.6

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Category, Suite};

    fn task_of(suite: &Suite, cat: Category) -> &TaskSpec {
        suite.tasks.iter().find(|t| t.category == cat).unwrap()
    }

    #[test]
    fn device_balances_are_ordered() {
        // H20 is bandwidth-rich (low balance); 4090 compute-rich.
        let b4090 = Device::Rtx4090.profile().balance();
        let bh20 = Device::H20.profile().balance();
        let ba100 = Device::A100.profile().balance();
        assert!(bh20 < ba100 && bh20 < b4090);
        assert!(bh20 < 15.0 && b4090 > 60.0);
    }

    #[test]
    fn oracle_beats_naive_everywhere() {
        let suite = Suite::full(1);
        for dev in ALL_DEVICES {
            let sim = GpuSim::noiseless(dev);
            for task in suite.tasks.iter().step_by(7) {
                let mut rng = Rng::new(0);
                let naive = sim.evaluate(task, &task.naive_config(), &mut rng);
                let oracle =
                    sim.evaluate(task, &sim.oracle_config(task), &mut rng);
                assert!(
                    oracle.total_latency_s < naive.total_latency_s,
                    "{} on {}",
                    task.name,
                    dev.name()
                );
            }
        }
    }

    #[test]
    fn oracle_speedup_in_paper_range() {
        // Average headroom should be paper-scale (geomean best-case
        // roughly 1.5–4x, not 1.01x and not 100x).
        let suite = Suite::full(1);
        let sim = GpuSim::noiseless(Device::A100);
        let mut log_sum = 0.0;
        let mut n = 0;
        for task in &suite.tasks {
            let mut rng = Rng::new(0);
            let naive = sim.evaluate(task, &task.naive_config(), &mut rng);
            let oracle = sim.evaluate(task, &sim.oracle_config(task), &mut rng);
            log_sum += (naive.total_latency_s / oracle.total_latency_s).ln();
            n += 1;
        }
        let geomean = (log_sum / n as f64).exp();
        assert!(
            (1.8..6.0).contains(&geomean),
            "oracle geomean speedup = {geomean}"
        );
    }

    #[test]
    fn memory_bound_kernel_saturates_dram_when_optimized() {
        let suite = Suite::full(1);
        let task = task_of(&suite, Category::ElementWise);
        let sim = GpuSim::noiseless(Device::Rtx4090);
        let mut rng = Rng::new(0);
        let m = sim.evaluate(task, &sim.oracle_config(task), &mut rng);
        assert!(
            m.counters.dram_pct > m.counters.sm_pct,
            "elementwise should be DRAM-dominated: {:?}",
            m.counters
        );
        assert!(m.counters.dram_pct > 60.0, "{:?}", m.counters);
    }

    #[test]
    fn gemm_goes_compute_bound_on_h20() {
        let suite = Suite::full(1);
        let task = task_of(&suite, Category::MatMul);
        let sim = GpuSim::noiseless(Device::H20);
        let mut rng = Rng::new(0);
        let m = sim.evaluate(task, &sim.oracle_config(task), &mut rng);
        assert!(
            m.counters.sm_pct > m.counters.dram_pct,
            "GEMM on H20 should be compute-bound: {:?}",
            m.counters
        );
    }

    #[test]
    fn gemm_is_memory_or_l2_bound_on_4090_naive_vs_h20() {
        // The same GEMM should be *more* memory-pressed on 4090 than H20.
        let suite = Suite::full(1);
        let task = task_of(&suite, Category::MatMul);
        let mut rng = Rng::new(0);
        let m4090 = GpuSim::noiseless(Device::Rtx4090)
            .evaluate(task, &task.naive_config(), &mut rng);
        let mh20 = GpuSim::noiseless(Device::H20)
            .evaluate(task, &task.naive_config(), &mut rng);
        assert!(m4090.counters.dram_pct > mh20.counters.dram_pct);
    }

    #[test]
    fn fusion_reduces_latency_for_memory_bound() {
        let suite = Suite::full(1);
        let task = task_of(&suite, Category::FusedActivation);
        let sim = GpuSim::noiseless(Device::Rtx4090);
        let mut rng = Rng::new(0);
        let base = task.naive_config();
        let mut fused = base;
        fused.fusion = task.latent.max_fusion;
        let m0 = sim.evaluate(task, &base, &mut rng);
        let m1 = sim.evaluate(task, &fused, &mut rng);
        assert!(m1.total_latency_s < m0.total_latency_s);
    }

    #[test]
    fn noise_is_small_and_seeded() {
        let suite = Suite::full(1);
        let task = &suite.tasks[0];
        let sim = GpuSim::new(Device::A100);
        let cfg = task.naive_config();
        let a = sim.evaluate(task, &cfg, &mut Rng::new(5));
        let b = sim.evaluate(task, &cfg, &mut Rng::new(5));
        let c = sim.evaluate(task, &cfg, &mut Rng::new(6));
        assert_eq!(a.total_latency_s, b.total_latency_s);
        assert_ne!(a.total_latency_s, c.total_latency_s);
        let rel = (a.total_latency_s - c.total_latency_s).abs()
            / a.total_latency_s;
        assert!(rel < 0.2, "noise too large: {rel}");
    }

    #[test]
    fn counters_are_physical() {
        let suite = Suite::full(2);
        let sim = GpuSim::new(Device::H20);
        for task in suite.tasks.iter().step_by(11) {
            let mut rng = Rng::new(1);
            let m = sim.evaluate(task, &task.naive_config(), &mut rng);
            let c = &m.counters;
            assert!((0.0..=100.0).contains(&c.sm_pct));
            assert!((0.0..=100.0).contains(&c.dram_pct));
            assert!((0.0..=100.0).contains(&c.l2_pct));
            assert!((0.0..=1.0).contains(&c.occupancy));
            assert!(c.regs_per_thread > 0.0 && c.smem_per_block > 0.0);
            assert!(m.total_latency_s > 0.0);
            assert_eq!(m.per_shape_s.len(), task.shapes.len());
        }
    }

    #[test]
    fn counters_pinned_for_fixed_task_config_device() {
        // Pins the counter accumulation for a fixed (task, config,
        // device): sm/dram/l2 percentages are the total ideal work over
        // peak divided by total time — the per-shape `/ t * t`
        // time-weighting factors cancel algebraically and must never be
        // reintroduced (they only added two rounding steps per shape).
        // Expected values are recomputed here via the same roofline
        // terms, so any semantic drift in `evaluate` breaks the
        // bit-level equality below.
        let suite = Suite::full(1);
        let task = &suite.tasks[4];
        let sim = GpuSim::noiseless(Device::A100);
        let cfg = task.naive_config();
        let m = sim.evaluate(task, &cfg, &mut Rng::new(0));

        let p = &sim.profile;
        let eff = sim.efficiency(task, &cfg);
        let peak_flops = p.peak_tflops * 1.0e12;
        let dram_bw = p.dram_gbps * 1.0e9;
        let l2_bw = dram_bw * p.l2_bw_factor;
        let launch_s = p.launch_us * 1.0e-6;
        let (mut sm, mut dram, mut l2, mut total) = (0.0, 0.0, 0.0, 0.0f64);
        for shape in &task.shapes {
            let bytes_eff = shape.bytes * eff.traffic_factor;
            let spill = (shape.working_set / (p.l2_mb * 1.0e6)).min(2.0);
            let l2_bytes =
                bytes_eff * (1.1 + 0.5 * (1.0 - eff.l2) + 0.25 * spill);
            let t_comp = shape.flops / (peak_flops * eff.compute);
            let t_dram = bytes_eff / (dram_bw * eff.memory);
            let t_l2 = l2_bytes / (l2_bw * eff.l2);
            // noiseless: t = ideal * 1.0 == ideal bitwise
            total += t_comp.max(t_dram).max(t_l2) + launch_s;
            sm += 100.0 * (shape.flops / peak_flops);
            dram += 100.0 * (bytes_eff / dram_bw);
            l2 += 100.0 * (l2_bytes / l2_bw);
        }
        assert_eq!(m.total_latency_s.to_bits(), total.to_bits());
        assert_eq!(m.counters.sm_pct.to_bits(),
                   (sm / total).min(100.0).to_bits());
        assert_eq!(m.counters.dram_pct.to_bits(),
                   (dram / total).min(100.0).to_bits());
        assert_eq!(m.counters.l2_pct.to_bits(),
                   (l2 / total).min(100.0).to_bits());
        assert_eq!(m.counters.occupancy, eff.occ.occupancy);
        assert_eq!(m.counters.regs_per_thread, eff.occ.regs_per_thread);
        assert_eq!(m.counters.smem_per_block, eff.occ.smem_per_block);
        assert_eq!(m.counters.block_dim, eff.occ.threads_per_block);
    }

    #[test]
    fn evaluate_batch_is_bitwise_equal_to_serial_evaluates() {
        let suite = Suite::full(1);
        let task = &suite.tasks[4];
        let sim = GpuSim::new(Device::H20);
        let cfgs = [
            task.naive_config(),
            sim.oracle_config(task),
            KernelConfig { fusion: 2, vector: 2, ..task.naive_config() },
        ];
        let mut batch_rngs: Vec<Rng> = (0..cfgs.len())
            .map(|b| Rng::new(5).split("m", b as u64))
            .collect();
        let fused = sim.evaluate_batch(task, &cfgs, &mut batch_rngs);
        assert_eq!(fused.len(), cfgs.len());
        for (i, cfg) in cfgs.iter().enumerate() {
            let solo = sim.evaluate(
                task, cfg, &mut Rng::new(5).split("m", i as u64),
            );
            assert_eq!(fused[i].total_latency_s.to_bits(),
                       solo.total_latency_s.to_bits());
            assert_eq!(fused[i].per_shape_s, solo.per_shape_s);
            assert_eq!(fused[i].counters.sm_pct.to_bits(),
                       solo.counters.sm_pct.to_bits());
            assert_eq!(fused[i].counters.dram_pct.to_bits(),
                       solo.counters.dram_pct.to_bits());
            assert_eq!(fused[i].counters.l2_pct.to_bits(),
                       solo.counters.l2_pct.to_bits());
            assert_eq!(fused[i].counters.occupancy.to_bits(),
                       solo.counters.occupancy.to_bits());
        }
        // empty batch is a no-op
        assert!(sim.evaluate_batch(task, &[], &mut []).is_empty());
    }

    #[test]
    fn occupancy_drops_under_pressure() {
        let sim = GpuSim::noiseless(Device::A100);
        let light = KernelConfig::naive();
        let mut heavy = light;
        heavy.tile_m = 5;
        heavy.tile_n = 5;
        heavy.tile_k = 4;
        heavy.pipeline = 3;
        heavy.fusion = 3;
        assert!(
            sim.occupancy(&heavy).occupancy < sim.occupancy(&light).occupancy
        );
    }

    #[test]
    fn efficiency_is_lipschitz_like_in_config() {
        // small config steps produce bounded latency changes — the
        // structural property behind Assumption 2.
        let suite = Suite::full(1);
        let task = &suite.tasks[10];
        let sim = GpuSim::noiseless(Device::A100);
        let mut rng = Rng::new(0);
        let base = sim.oracle_config(task);
        let t0 = sim.evaluate(task, &base, &mut rng).total_latency_s;
        let mut step = base;
        step.tile_m = step.tile_m.saturating_sub(1);
        let t1 = sim.evaluate(task, &step, &mut rng).total_latency_s;
        let ratio = t1 / t0;
        assert!((0.8..2.0).contains(&ratio), "one tile step → {ratio}x");
    }
}
