//! Fixed-bucket log-linear histogram.
//!
//! The bucket layout is the classic HDR shape: values below
//! [`LINEAR_CUTOFF`] land in exact unit buckets; above it each power-of
//! -two range is split into [`SUBS`] linear sub-buckets, so relative
//! quantization error is bounded (~12.5% worst case, ~6% at the bucket
//! midpoint) while the whole table stays a fixed 512 `AtomicU64`s.
//!
//! Everything is an atomic add, which gives the two properties the
//! telemetry bus needs:
//!
//! * recording from many worker threads needs no lock, and
//! * [`Histogram::merge`] is a bucket-wise sum, so merging per-worker
//!   histograms is associative and commutative — the final snapshot is
//!   independent of worker completion order (asserted in
//!   `rust/tests/obs.rs`).
//!
//! Units are the caller's business; by convention metric names carry a
//! suffix (`_us` for microseconds, bare for dimensionless counts).

use std::sync::atomic::{AtomicU64, Ordering};

/// Values below this index into exact unit buckets.
const LINEAR_CUTOFF: u64 = 8;
/// Sub-buckets per power-of-two range above the cutoff.
const SUBS: usize = 8;
/// 8 exact buckets + (61 ranges × 8 subs) = 496 < 512.
const BUCKETS: usize = 512;

/// Bucket index for a value. Total order preserving: `v <= w` implies
/// `index(v) <= index(w)`.
fn index(v: u64) -> usize {
    if v < LINEAR_CUTOFF {
        return v as usize;
    }
    let h = 63 - v.leading_zeros() as usize; // floor(log2 v), >= 3
    let sub = ((v >> (h - 3)) & 0x7) as usize;
    (h - 2) * SUBS + sub
}

/// Inclusive upper bound of a bucket's value range — the `le` edge the
/// Prometheus text exporter emits for cumulative bucket series.
pub fn bucket_upper(idx: usize) -> u64 {
    if idx < LINEAR_CUTOFF as usize {
        return idx as u64;
    }
    let major = idx / SUBS;
    let sub = (idx % SUBS) as u64;
    let h = major + 2;
    let lo = (1u64 << h) | (sub << (h - 3));
    lo + ((1u64 << (h - 3)) - 1)
}

/// Midpoint of the bucket's value range — the representative returned
/// by percentile queries.
fn midpoint(idx: usize) -> u64 {
    if idx < LINEAR_CUTOFF as usize {
        return idx as u64;
    }
    let major = idx / SUBS;
    let sub = (idx % SUBS) as u64;
    let h = major + 2;
    let lo = (1u64 << h) | (sub << (h - 3));
    lo + (1u64 << (h - 3)) / 2
}

/// Lock-free fixed-size histogram. All mutation is `Relaxed` atomic
/// arithmetic; a snapshot taken while writers are active is a
/// consistent-enough advisory view (never a torn bucket, though counts
/// across fields may lag each other by in-flight records).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Bucket-wise add of `other` into `self`. Commutative and
    /// associative up to the atomic sums involved, so any merge order
    /// over a set of histograms yields the same final state.
    pub fn merge(&self, other: &Histogram) {
        for (a, b) in self.buckets.iter().zip(&other.buckets) {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                a.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum
            .fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min
            .fetch_min(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max
            .fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Immutable summary used for emission and assertions.
    pub fn snapshot(&self) -> HistSnapshot {
        let count = self.count.load(Ordering::Relaxed);
        let sum = self.sum.load(Ordering::Relaxed);
        let (min, max) = if count == 0 {
            (0, 0)
        } else {
            (
                self.min.load(Ordering::Relaxed),
                self.max.load(Ordering::Relaxed),
            )
        };
        let counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let pct = |q: f64| -> u64 {
            if count == 0 {
                return 0;
            }
            let target = ((q * count as f64).ceil() as u64).max(1);
            let mut seen = 0u64;
            for (i, n) in counts.iter().enumerate() {
                seen += n;
                if seen >= target {
                    return midpoint(i).clamp(min, max);
                }
            }
            max
        };
        HistSnapshot {
            count,
            sum,
            min,
            max,
            mean: if count == 0 {
                0.0
            } else {
                sum as f64 / count as f64
            },
            p50: pct(0.50),
            p90: pct(0.90),
            p95: pct(0.95),
            p99: pct(0.99),
            buckets: counts,
        }
    }
}

/// Point-in-time view of a [`Histogram`].
#[derive(Debug, Clone, PartialEq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub mean: f64,
    pub p50: u64,
    pub p90: u64,
    pub p95: u64,
    pub p99: u64,
    /// Raw bucket counts — compared directly in merge-order tests.
    pub buckets: Vec<u64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_is_monotone_and_in_range() {
        let mut last = 0usize;
        for v in [0u64, 1, 7, 8, 9, 15, 16, 100, 1000, 1 << 20, u64::MAX] {
            let i = index(v);
            assert!(i >= last, "index not monotone at {v}");
            assert!(i < BUCKETS);
            last = i;
        }
    }

    #[test]
    fn midpoint_lands_in_own_bucket() {
        for idx in 0..496 {
            assert_eq!(index(midpoint(idx)), idx, "idx {idx}");
        }
    }

    #[test]
    fn percentiles_bound_relative_error() {
        let h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 10_000);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 10_000);
        for (q, want) in [(s.p50, 5_000.0), (s.p95, 9_500.0), (s.p99, 9_900.0)]
        {
            let err = (q as f64 - want).abs() / want;
            assert!(err < 0.13, "q={q} want≈{want} err={err}");
        }
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Histogram::new().snapshot();
        assert_eq!((s.count, s.min, s.max, s.p50, s.p99), (0, 0, 0, 0, 0));
        assert_eq!(s.mean, 0.0);
        assert!(s.buckets.iter().all(|&n| n == 0));
    }

    #[test]
    fn single_sample_pins_every_percentile() {
        let h = Histogram::new();
        h.record(42);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        assert_eq!((s.min, s.max), (42, 42));
        // min/max clamping pins all percentiles to the lone sample
        assert_eq!((s.p50, s.p90, s.p95, s.p99), (42, 42, 42, 42));
        assert_eq!(s.mean, 42.0);
    }

    #[test]
    fn merge_with_saturated_top_bucket() {
        // both histograms hold u64::MAX — the top occupied bucket —
        // so the merged sum wraps mod 2^64 but counts, min/max and the
        // bucket table stay exact
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(u64::MAX);
        a.record(1);
        b.record(u64::MAX);
        a.merge(&b);
        let s = a.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, u64::MAX);
        let top = index(u64::MAX);
        assert_eq!(s.buckets[top], 2);
        // the top bucket's upper edge is exactly u64::MAX — no overflow
        assert_eq!(bucket_upper(top), u64::MAX);
        // p99 must land inside the saturated top bucket, never above max
        assert_eq!(index(s.p99), top);
        assert!(s.p99 <= s.max);
    }

    #[test]
    fn bucket_upper_is_the_inclusive_edge() {
        // exact unit buckets: upper == value
        for v in 0..LINEAR_CUTOFF {
            assert_eq!(bucket_upper(index(v)), v);
        }
        for idx in 0..496 {
            let upper = bucket_upper(idx);
            // the edge belongs to its own bucket…
            assert_eq!(index(upper), idx, "idx {idx}");
            // …and the next value crosses into the next bucket
            if upper < u64::MAX {
                assert_eq!(index(upper + 1), idx + 1, "idx {idx}");
            }
            assert!(upper >= midpoint(idx).saturating_sub(1));
        }
    }
}
