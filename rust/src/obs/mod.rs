//! Unified telemetry bus: spans, counters, and latency percentiles.
//!
//! Every hot layer (bandit loop, batch scheduler, trace store, sharded
//! server) reports into one [`Recorder`] handle. The recorder is
//! **advisory by construction**:
//!
//! * it only ever *observes* — it never touches an RNG stream, never
//!   orders work, and its output goes to `METRICS.json` (plus an
//!   optional `events.jsonl` span stream), never into `BENCH_*.json`
//!   or `trace.jsonl`. Byte-identity of the deterministic artifacts
//!   with telemetry on vs. off is a hard invariant, asserted in
//!   `rust/tests/obs.rs` and the CI `obs-smoke` gate;
//! * it is near-zero cost when disabled: handles resolved from a
//!   disabled (or absent) recorder are `None` inside and every op is a
//!   single branch. Hot loops resolve handles **once** (see
//!   [`PolicyHooks`]) so the steady-state cost of an enabled recorder
//!   is a relaxed atomic add — gated ≤2% end-to-end by `bench_policy`
//!   + `perf/baselines/obs/`.
//!
//! Wall-clock here is [`Instant`] (monotonic) only; nothing observable
//! in the deterministic artifacts depends on it.

pub mod decision;
pub mod hist;
pub mod regret;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

pub use decision::DecisionLedger;
pub use hist::{HistSnapshot, Histogram};
pub use regret::{CoveringRecord, RegretAccum};
pub use trace::TraceSink;

use crate::util::json::Json;

/// Schema version of `METRICS.json` (checked by
/// `scripts/check_metrics.py`).
pub const METRICS_SCHEMA_VERSION: usize = 1;

/// One entry in the optional span/event stream (`events.jsonl`).
struct Event {
    at_us: u64,
    kind: String,
    fields: Json,
}

/// The telemetry bus. Cheap to share (`Arc<Recorder>`); all mutation
/// is interior and lock-free on the hot path (the maps are locked only
/// when a handle is first resolved).
pub struct Recorder {
    enabled: bool,
    epoch: Instant,
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
    /// `Some` when the span/event stream was requested.
    events: Option<Mutex<Vec<Event>>>,
    /// `Some` when causal span tracing was requested (`--obs trace`).
    trace: Option<Arc<TraceSink>>,
    /// `Some` when the per-pull decision ledger was requested
    /// (`--obs events|trace`; never in the benched `--obs on` config).
    decisions: Option<DecisionLedger>,
    /// Cross-run regret curves (populated by serve workers / the repro
    /// runner, empty otherwise).
    regret: Mutex<RegretAccum>,
    /// Per-re-clustering covering diagnostics from the policy loop.
    covering: Mutex<Vec<CoveringRecord>>,
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Recorder")
            .field("enabled", &self.enabled)
            .field("events", &self.events.is_some())
            .finish()
    }
}

impl Default for Recorder {
    fn default() -> Recorder {
        Recorder::new()
    }
}

impl Recorder {
    /// An enabled recorder without the per-event stream.
    pub fn new() -> Recorder {
        Recorder {
            enabled: true,
            epoch: Instant::now(),
            counters: Mutex::new(BTreeMap::new()),
            hists: Mutex::new(BTreeMap::new()),
            events: None,
            trace: None,
            decisions: None,
            regret: Mutex::new(RegretAccum::default()),
            covering: Mutex::new(Vec::new()),
        }
    }

    /// Enabled recorder that additionally buffers a span/event stream
    /// for `events.jsonl` plus the per-pull decision ledger
    /// (`decisions.jsonl`).
    pub fn with_events() -> Recorder {
        Recorder {
            events: Some(Mutex::new(Vec::new())),
            decisions: Some(DecisionLedger::new()),
            ..Recorder::new()
        }
    }

    /// Everything [`Recorder::with_events`] buffers plus the causal
    /// span tree (`--obs trace` → `trace_events.json`).
    pub fn with_trace() -> Recorder {
        Recorder {
            trace: Some(Arc::new(TraceSink::new())),
            ..Recorder::with_events()
        }
    }

    /// A recorder whose every operation is a no-op branch. Exists so
    /// call sites can hold a handle unconditionally.
    pub fn disabled() -> Recorder {
        Recorder {
            enabled: false,
            ..Recorder::new()
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The causal span sink, when tracing was requested.
    pub fn trace(&self) -> Option<&Arc<TraceSink>> {
        self.trace.as_ref()
    }

    /// The per-pull decision ledger, when one was requested.
    pub fn decisions(&self) -> Option<&DecisionLedger> {
        self.decisions.as_ref()
    }

    /// The `decisions.jsonl` stream (empty when no ledger).
    pub fn decisions_jsonl(&self) -> String {
        self.decisions.as_ref().map_or(String::new(), |d| d.jsonl())
    }

    /// Fold one finished run's regret curve into the cross-run mean.
    pub fn observe_regret(&self, curve: &[f64], exact: bool) {
        if self.enabled {
            self.regret.lock().unwrap().observe(curve, exact);
        }
    }

    /// Record one re-clustering's covering diagnostics.
    pub fn observe_covering(&self, rec: CoveringRecord) {
        if self.enabled {
            self.covering.lock().unwrap().push(rec);
        }
    }

    /// Covering records observed so far (cloned; tests and exporters).
    pub fn covering_records(&self) -> Vec<CoveringRecord> {
        self.covering.lock().unwrap().clone()
    }

    /// The `regret` section of `METRICS.json`, when any run reported.
    pub fn regret_json(&self) -> Option<Json> {
        let r = self.regret.lock().unwrap();
        if r.is_empty() {
            None
        } else {
            Some(r.to_json())
        }
    }

    /// Resolve (creating on first use) a named counter handle.
    /// Increments through the handle are single relaxed atomic adds —
    /// resolve once outside hot loops.
    pub fn counter(&self, name: &str) -> Counter {
        if !self.enabled {
            return Counter(None);
        }
        let mut map = self.counters.lock().unwrap();
        let cell = map
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0)))
            .clone();
        Counter(Some(cell))
    }

    /// Resolve (creating on first use) a named histogram handle.
    pub fn hist(&self, name: &str) -> Hist {
        if !self.enabled {
            return Hist(None);
        }
        let mut map = self.hists.lock().unwrap();
        let cell = map
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone();
        Hist(Some(cell))
    }

    /// One-shot counter add (resolves the handle each call; fine off
    /// the hot path).
    pub fn add(&self, name: &str, n: u64) {
        self.counter(name).add(n);
    }

    /// Append to the span/event stream, if one was requested.
    pub fn event(&self, kind: &str, fields: Json) {
        if let Some(buf) = &self.events {
            let at_us = self.epoch.elapsed().as_micros() as u64;
            buf.lock().unwrap().push(Event {
                at_us,
                kind: kind.to_string(),
                fields,
            });
        }
    }

    /// Begin a scoped span; record it via [`Recorder::end_span`] (or
    /// use a pre-resolved [`Hist`] + [`Hist::start`] in hot loops).
    pub fn span(&self, name: &str) -> Span {
        if !self.enabled {
            return Span { inner: None };
        }
        Span {
            inner: Some((self.hist(name), name.to_string(), Instant::now())),
        }
    }

    /// Close a span: its elapsed time lands in the histogram of the
    /// span's name (microseconds) and, when the event stream is on, as
    /// one `span` event.
    pub fn end_span(&self, span: Span) {
        if let Some((hist, name, start)) = span.inner {
            let us = start.elapsed().as_micros() as u64;
            hist.record(us);
            self.event(
                "span",
                Json::obj(vec![
                    ("name", Json::str(name)),
                    ("us", Json::num(us as f64)),
                ]),
            );
        }
    }

    /// Fold another recorder's counters and histograms into this one.
    /// Bucket-wise sums make this order-independent across workers.
    pub fn merge_from(&self, other: &Recorder) {
        if !self.enabled || !other.enabled {
            return;
        }
        for (name, cell) in other.counters.lock().unwrap().iter() {
            self.counter(name).add(cell.load(Ordering::Relaxed));
        }
        for (name, h) in other.hists.lock().unwrap().iter() {
            if let Hist(Some(mine)) = self.hist(name) {
                mine.merge(h);
            }
        }
        self.regret
            .lock()
            .unwrap()
            .merge(&other.regret.lock().unwrap());
        self.covering
            .lock()
            .unwrap()
            .extend(other.covering.lock().unwrap().iter().cloned());
    }

    /// Current counter values, sorted by name.
    pub fn counter_values(&self) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Current histogram snapshots, sorted by name.
    pub fn hist_snapshots(&self) -> Vec<(String, HistSnapshot)> {
        self.hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot()))
            .collect()
    }

    /// The `METRICS.json` document. Advisory: wall-clock derived, never
    /// byte-compared, never fed back into the deterministic pipeline.
    pub fn metrics_json(&self) -> Json {
        let counters = Json::obj(
            self.counter_values()
                .iter()
                .map(|(k, v)| (k.as_str(), Json::num(*v as f64)))
                .collect::<Vec<_>>(),
        );
        let hists = Json::obj(
            self.hist_snapshots()
                .iter()
                .map(|(k, s)| (k.as_str(), snapshot_json(s)))
                .collect::<Vec<_>>(),
        );
        let mut doc = Json::obj(vec![
            ("schema_version", Json::num(METRICS_SCHEMA_VERSION as f64)),
            ("enabled", Json::Bool(self.enabled)),
            ("counters", counters),
            ("histograms", hists),
        ]);
        // optional sections: present only when something reported, so
        // existing consumers see an unchanged document otherwise
        if let Some(r) = self.regret_json() {
            doc.insert("regret", r);
        }
        let cov = self.covering.lock().unwrap();
        if !cov.is_empty() {
            doc.insert("covering", regret::covering_json(&cov));
        }
        doc
    }

    /// The optional `events.jsonl` stream: one compact JSON object per
    /// line, in emission order. Empty string when the stream is off.
    /// When the span sink is live its tree is appended as `span_tree`
    /// lines (the jsonl twin of the Chrome export, consumed by
    /// `kernelband metrics perfetto`).
    pub fn events_jsonl(&self) -> String {
        let Some(buf) = &self.events else {
            return String::new();
        };
        let mut out = String::new();
        for e in buf.lock().unwrap().iter() {
            let line = Json::obj(vec![
                ("at_us", Json::num(e.at_us as f64)),
                ("kind", Json::str(e.kind.clone())),
                ("fields", e.fields.clone()),
            ]);
            out.push_str(&line.dump());
            out.push('\n');
        }
        if let Some(sink) = &self.trace {
            for s in sink.snapshot() {
                let line = Json::obj(vec![
                    ("at_us", Json::num(s.start_us as f64)),
                    ("kind", Json::str("span_tree")),
                    ("fields", trace::span_fields(&s)),
                ]);
                out.push_str(&line.dump());
                out.push('\n');
            }
        }
        out
    }
}

/// JSON summary of one histogram (units are whatever the metric name's
/// suffix says, `_us` by convention for spans and latencies). The
/// `buckets` array lists only occupied buckets as `[upper_bound,
/// count]` pairs — the Prometheus exporter turns these into cumulative
/// `le` series without re-deriving the bucket layout.
fn snapshot_json(s: &HistSnapshot) -> Json {
    let buckets: Vec<Json> = s
        .buckets
        .iter()
        .enumerate()
        .filter(|(_, &n)| n > 0)
        .map(|(i, &n)| {
            Json::Arr(vec![
                Json::num(hist::bucket_upper(i) as f64),
                Json::num(n as f64),
            ])
        })
        .collect();
    Json::obj(vec![
        ("count", Json::num(s.count as f64)),
        ("sum", Json::num(s.sum as f64)),
        ("min", Json::num(s.min as f64)),
        ("max", Json::num(s.max as f64)),
        ("mean", Json::num(s.mean)),
        ("p50", Json::num(s.p50 as f64)),
        ("p90", Json::num(s.p90 as f64)),
        ("p95", Json::num(s.p95 as f64)),
        ("p99", Json::num(s.p99 as f64)),
        ("buckets", Json::Arr(buckets)),
    ])
}

/// Pre-resolved counter handle; `add` is one relaxed atomic op (or a
/// single branch when the recorder was disabled/absent).
#[derive(Clone, Default)]
pub struct Counter(Option<Arc<AtomicU64>>);

impl Counter {
    /// Handle that counts nothing (absent recorder).
    pub fn noop() -> Counter {
        Counter(None)
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if let Some(c) = &self.0 {
            c.fetch_add(n, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.as_ref().map_or(0, |c| c.load(Ordering::Relaxed))
    }
}

impl std::fmt::Debug for Counter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Counter(live={})", self.0.is_some())
    }
}

/// Pre-resolved histogram handle.
#[derive(Clone, Default)]
pub struct Hist(Option<Arc<Histogram>>);

impl Hist {
    pub fn noop() -> Hist {
        Hist(None)
    }

    #[inline]
    pub fn record(&self, v: u64) {
        if let Some(h) = &self.0 {
            h.record(v);
        }
    }

    /// Start a manual span against this histogram: returns `None` when
    /// the handle is inert, so disabled runs never read the clock.
    #[inline]
    pub fn start(&self) -> Option<Instant> {
        if self.0.is_some() {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a manual span started with [`Hist::start`], recording
    /// elapsed microseconds.
    #[inline]
    pub fn stop(&self, start: Option<Instant>) {
        if let (Some(h), Some(t0)) = (&self.0, start) {
            h.record(t0.elapsed().as_micros() as u64);
        }
    }

    pub fn snapshot(&self) -> Option<HistSnapshot> {
        self.0.as_ref().map(|h| h.snapshot())
    }
}

impl std::fmt::Debug for Hist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Hist(live={})", self.0.is_some())
    }
}

/// Scoped span token returned by [`Recorder::span`].
pub struct Span {
    inner: Option<(Hist, String, Instant)>,
}

/// Handle bundle for the bandit hot loop (`policy::optimize_sched`) and
/// the batch scheduler slots it drives. Resolved **once** per run so
/// the per-iteration cost is a handful of relaxed atomic ops; with no
/// recorder every field is inert.
///
/// Metric catalog (also documented in README "Observability"):
///
/// | name                              | kind | meaning |
/// |-----------------------------------|------|---------|
/// | `policy.iter_us`                  | hist | per-iteration span |
/// | `policy.arm_pulls`                | ctr  | UCB arm selections |
/// | `policy.reclusters`               | ctr  | re-clustering events |
/// | `policy.cluster_size`             | hist | pulled arm's member count |
/// | `sched.batch_width`               | hist | AIMD width trace |
/// | `sched.slots_admitted`            | ctr  | slots past the bound check |
/// | `sched.slots_bound_pruned`        | ctr  | slots pruned by Assumption-1 bound |
/// | `sched.slots_failed_verification` | ctr  | measured slots failing verify |
/// | `sched.slots_accepted`            | ctr  | measured slots accepted |
#[derive(Debug, Clone, Default)]
pub struct PolicyHooks {
    pub iter_us: Hist,
    pub arm_pulls: Counter,
    pub reclusters: Counter,
    pub cluster_size: Hist,
    pub batch_width: Hist,
    pub slots_admitted: Counter,
    pub slots_bound_pruned: Counter,
    pub slots_failed_verification: Counter,
    pub slots_accepted: Counter,
}

impl PolicyHooks {
    pub fn new(rec: Option<&Recorder>) -> PolicyHooks {
        let Some(r) = rec.filter(|r| r.enabled()) else {
            return PolicyHooks::default();
        };
        PolicyHooks {
            iter_us: r.hist("policy.iter_us"),
            arm_pulls: r.counter("policy.arm_pulls"),
            reclusters: r.counter("policy.reclusters"),
            cluster_size: r.hist("policy.cluster_size"),
            batch_width: r.hist("sched.batch_width"),
            slots_admitted: r.counter("sched.slots_admitted"),
            slots_bound_pruned: r.counter("sched.slots_bound_pruned"),
            slots_failed_verification: r
                .counter("sched.slots_failed_verification"),
            slots_accepted: r.counter("sched.slots_accepted"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_inert() {
        let r = Recorder::disabled();
        r.counter("x").incr();
        r.hist("y").record(7);
        let span = r.span("z");
        r.end_span(span);
        assert!(!r.enabled());
        assert!(r.counter_values().is_empty());
        assert!(r.hist_snapshots().is_empty());
        let m = r.metrics_json();
        assert_eq!(m.get("enabled"), Some(&Json::Bool(false)));
    }

    #[test]
    fn counters_and_hists_accumulate_through_handles() {
        let r = Recorder::new();
        let c = r.counter("a.b");
        c.add(3);
        c.incr();
        r.add("a.b", 1);
        let h = r.hist("lat_us");
        h.record(10);
        h.record(1000);
        assert_eq!(r.counter_values(), vec![("a.b".into(), 5)]);
        let snaps = r.hist_snapshots();
        assert_eq!(snaps.len(), 1);
        assert_eq!(snaps[0].1.count, 2);
        assert_eq!(snaps[0].1.sum, 1010);
    }

    #[test]
    fn events_stream_only_when_requested() {
        let quiet = Recorder::new();
        quiet.event("x", Json::Null);
        assert_eq!(quiet.events_jsonl(), "");
        let chatty = Recorder::with_events();
        chatty.event("lease", Json::obj(vec![("what", Json::str("grant"))]));
        let stream = chatty.events_jsonl();
        assert_eq!(stream.lines().count(), 1);
        assert!(stream.contains("\"kind\":\"lease\""));
    }

    #[test]
    fn merge_from_folds_counters_and_hists() {
        let a = Recorder::new();
        let b = Recorder::new();
        a.add("n", 2);
        b.add("n", 5);
        b.hist("h").record(42);
        a.merge_from(&b);
        assert_eq!(a.counter_values(), vec![("n".into(), 7)]);
        assert_eq!(a.hist_snapshots()[0].1.count, 1);
    }

    #[test]
    fn policy_hooks_default_is_noop() {
        let hooks = PolicyHooks::new(None);
        hooks.arm_pulls.incr();
        hooks.iter_us.record(9);
        assert!(hooks.iter_us.start().is_none());
        assert_eq!(hooks.arm_pulls.get(), 0);
        let off = Recorder::disabled();
        let hooks = PolicyHooks::new(Some(&off));
        hooks.slots_admitted.incr();
        assert_eq!(hooks.slots_admitted.get(), 0);
    }
}
