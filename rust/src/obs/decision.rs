//! Per-pull decision ledger: *why* the bandit did what it did.
//!
//! One jsonl row per arm pull (`kind:"pull"`), recording everything the
//! selection consumed at pick time:
//!
//! * the masked-UCB score of **every** `(cluster, strategy)` arm with
//!   its mask reason (`open` / `saturated` / `empty`) and whether the
//!   all-saturated fallback fired,
//! * the within-cluster softmax pick per batch slot — candidate pool,
//!   raw headrooms, normalized weights, picked kernel,
//! * each slot's Assumption-1 admission verdict — the profiling bound
//!   vs `prune_factor × best` threshold.
//!
//! Rows are plain [`Json`] built by the policy loop only when a ledger
//! is attached (`--obs events|trace`); the benched `--obs on`
//! configuration never constructs one, so the ≤2% overhead gate is
//! unaffected. Scores are recorded with Rust's shortest-roundtrip float
//! formatting, so `kernelband explain` can recompute them from the
//! recorded `(mu, n, t, c)` and demand **bit-exact** agreement — the
//! recomputation in [`recheck_pull`] calls the same
//! [`MaskedUcb::index`] the hot path's reduce is property-tested
//! against.

use std::sync::Mutex;

use crate::bandit::MaskedUcb;
use crate::util::json::Json;

/// Append-only buffer of decision rows (exported as `decisions.jsonl`).
#[derive(Debug, Default)]
pub struct DecisionLedger {
    rows: Mutex<Vec<Json>>,
}

impl DecisionLedger {
    pub fn new() -> DecisionLedger {
        DecisionLedger::default()
    }

    pub fn record(&self, row: Json) {
        self.rows.lock().unwrap().push(row);
    }

    pub fn len(&self) -> usize {
        self.rows.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All rows, one compact JSON object per line, in emission order.
    pub fn jsonl(&self) -> String {
        let rows = self.rows.lock().unwrap();
        let mut out = String::new();
        for r in rows.iter() {
            out.push_str(&r.dump());
            out.push('\n');
        }
        out
    }

    /// Cloned rows (tests and in-process readers).
    pub fn rows(&self) -> Vec<Json> {
        self.rows.lock().unwrap().clone()
    }
}

/// Recompute every recorded arm score of one `pull` row from its
/// `(mu, n, t, ucb_c)` and compare **bit-exactly** against the recorded
/// score. Returns the number of arms checked; any mismatch (or a
/// malformed row) is an error naming the offending arm.
pub fn recheck_pull(row: &Json) -> Result<usize, String> {
    if row.get("kind").and_then(Json::as_str) != Some("pull") {
        return Err("not a pull row".into());
    }
    let t = row
        .get("t")
        .and_then(Json::as_f64)
        .ok_or("pull row missing t")?;
    let c = row
        .get("ucb_c")
        .and_then(Json::as_f64)
        .ok_or("pull row missing ucb_c")?;
    let ucb = MaskedUcb { c };
    let arms = row
        .get("arms")
        .and_then(Json::as_arr)
        .ok_or("pull row missing arms")?;
    let mut checked = 0usize;
    for (i, arm) in arms.iter().enumerate() {
        let mu = arm
            .get("mu")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("arm {i}: missing mu"))?;
        let n = arm
            .get("n")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("arm {i}: missing n"))?;
        let recorded = arm
            .get("score")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("arm {i}: missing score"))?;
        let recomputed = ucb.index(mu, n, t);
        if recomputed.to_bits() != recorded.to_bits() {
            return Err(format!(
                "arm {i} (cluster {}, {}): recorded score {recorded} != \
                 recomputed {recomputed} from mu={mu} n={n} t={t} c={c}",
                arm.get("cluster").and_then(Json::as_f64).unwrap_or(-1.0),
                arm.get("strategy").and_then(Json::as_str).unwrap_or("?"),
            ));
        }
        checked += 1;
    }
    Ok(checked)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn pull_row(mu: f64, n: f64, t: f64, c: f64) -> Json {
        let score = MaskedUcb { c }.index(mu, n, t);
        Json::obj(vec![
            ("kind", Json::str("pull")),
            ("t", Json::num(t)),
            ("ucb_c", Json::num(c)),
            (
                "arms",
                Json::Arr(vec![Json::obj(vec![
                    ("cluster", Json::num(0.0)),
                    ("strategy", Json::str("tiling")),
                    ("mu", Json::num(mu)),
                    ("n", Json::num(n)),
                    ("score", Json::num(score)),
                    ("reason", Json::str("open")),
                ])]),
            ),
        ])
    }

    #[test]
    fn ledger_buffers_and_serializes() {
        let l = DecisionLedger::new();
        assert!(l.is_empty());
        l.record(Json::obj(vec![("kind", Json::str("pull"))]));
        assert_eq!(l.len(), 1);
        assert_eq!(l.jsonl().lines().count(), 1);
    }

    #[test]
    fn recheck_is_bit_exact_through_a_json_round_trip() {
        // the shortest-roundtrip float writer means dump→parse preserves
        // bits; recheck must pass after the full serialization cycle
        let row = pull_row(0.731, 3.0, 17.0, 2.0);
        let back = parse(&row.dump()).unwrap();
        assert_eq!(recheck_pull(&back), Ok(1));
    }

    #[test]
    fn recheck_flags_a_tampered_score() {
        let mut row = pull_row(0.5, 2.0, 9.0, 2.0);
        // nudge the recorded score by one ulp's worth of noise
        if let Some(Json::Arr(arms)) = row.get("arms").cloned().into() {
            let mut arm = arms[0].clone();
            let s = arm.get("score").unwrap().as_f64().unwrap();
            arm.insert("score", Json::num(s + 1e-12));
            row.insert("arms", Json::Arr(vec![arm]));
        }
        assert!(recheck_pull(&row).is_err());
    }

    #[test]
    fn recheck_rejects_non_pull_rows() {
        let row = Json::obj(vec![("kind", Json::str("covering"))]);
        assert!(recheck_pull(&row).is_err());
    }
}
