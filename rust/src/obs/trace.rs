//! Causal span tree: `(trace_id, span_id, parent_id)` for every event.
//!
//! PR 7's counters and histograms say *how much* happened; this sink
//! records *why* — each serve request, round, job, policy iteration,
//! gateway round-trip and measurement is a span whose `parent_id`
//! points at the decision that caused it, so the whole run forms one
//! causality tree. The sink is advisory like the rest of the bus: it
//! consumes no RNG, its output never lands in `BENCH_*.json` or
//! `trace.jsonl`, and it only exists at all under `--obs trace`.
//!
//! Two export shapes share one record type:
//!
//! * `trace_events.json` — Chrome-trace-event JSON (the Perfetto /
//!   `chrome://tracing` format): spans as `ph:"X"` complete events,
//!   instants as `ph:"i"`, one `tid` (track) per sequential execution
//!   lane. Load it at `ui.perfetto.dev` directly.
//! * `events.jsonl` `span_tree` lines — one compact object per span,
//!   interleaved with the PR 7 event stream so `kernelband metrics
//!   perfetto` can rebuild the Chrome JSON from a jsonl file alone.
//!
//! Timestamps are captured *inside* the sink lock, so emission order is
//! globally start-time-sorted — in particular the per-track
//! subsequences are monotone, which `scripts/check_trace_events.py`
//! asserts.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// Single-process runs carry one trace; the id exists so multi-process
/// aggregation has a namespace to disambiguate on.
pub const TRACE_ID: u64 = 1;

/// Track (Perfetto `tid`) of the serve request/round lane. Job lanes
/// are `TRACK_JOBS + seq` so concurrent jobs never interleave on one
/// track (monotone-ts-per-track is a validator invariant).
pub const TRACK_SERVE: u64 = 1;
pub const TRACK_JOBS: u64 = 16;

/// One node of the causality tree. `parent_id == 0` means root.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    pub span_id: u64,
    pub parent_id: u64,
    /// Sequential execution lane (Perfetto `tid`).
    pub track: u64,
    pub name: String,
    pub start_us: u64,
    /// `None` while the span is still open at snapshot time.
    pub dur_us: Option<u64>,
    /// `true` for point events (`ph:"i"` in the Chrome export).
    pub instant: bool,
    pub args: Json,
}

struct SinkState {
    spans: Vec<SpanRecord>,
    /// Open spans: `span_id -> index into spans`.
    open: BTreeMap<u64, usize>,
}

/// Lock-per-emission span sink. Emission is off every deterministic
/// path's hot loop (iteration granularity at the finest), so a mutex is
/// plenty; ids are allocated from one atomic so they are unique across
/// every thread that shares the sink.
pub struct TraceSink {
    epoch: Instant,
    next_id: AtomicU64,
    state: Mutex<SinkState>,
}

impl Default for TraceSink {
    fn default() -> TraceSink {
        TraceSink::new()
    }
}

impl TraceSink {
    pub fn new() -> TraceSink {
        TraceSink {
            epoch: Instant::now(),
            next_id: AtomicU64::new(1),
            state: Mutex::new(SinkState {
                spans: Vec::new(),
                open: BTreeMap::new(),
            }),
        }
    }

    /// Open a span under `parent` (0 = root) on `track`; returns the
    /// new span id for children to attach to.
    pub fn begin(&self, name: &str, parent: u64, track: u64, args: Json) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut st = self.state.lock().unwrap();
        // captured inside the lock: emission order == start order
        let start_us = self.epoch.elapsed().as_micros() as u64;
        let idx = st.spans.len();
        st.spans.push(SpanRecord {
            span_id: id,
            parent_id: parent,
            track,
            name: name.to_string(),
            start_us,
            dur_us: None,
            instant: false,
            args,
        });
        st.open.insert(id, idx);
        id
    }

    /// Close a span opened with [`TraceSink::begin`]. Unknown ids are
    /// ignored (double-close is harmless by construction).
    pub fn end(&self, id: u64) {
        let mut st = self.state.lock().unwrap();
        let now = self.epoch.elapsed().as_micros() as u64;
        if let Some(idx) = st.open.remove(&id) {
            let s = &mut st.spans[idx];
            s.dur_us = Some(now.saturating_sub(s.start_us));
        }
    }

    /// Record a point event under `parent`.
    pub fn instant(&self, name: &str, parent: u64, track: u64, args: Json) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut st = self.state.lock().unwrap();
        let start_us = self.epoch.elapsed().as_micros() as u64;
        st.spans.push(SpanRecord {
            span_id: id,
            parent_id: parent,
            track,
            name: name.to_string(),
            start_us,
            dur_us: Some(0),
            instant: true,
            args,
        });
    }

    /// Point-in-time copy of the tree, still-open spans clocked as of
    /// now (export while a server is live stays well-formed).
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let st = self.state.lock().unwrap();
        let now = self.epoch.elapsed().as_micros() as u64;
        st.spans
            .iter()
            .map(|s| {
                let mut s = s.clone();
                if s.dur_us.is_none() {
                    s.dur_us = Some(now.saturating_sub(s.start_us));
                }
                s
            })
            .collect()
    }

    /// The Chrome-trace-event document for this sink's current tree.
    pub fn chrome_trace_json(&self) -> Json {
        chrome_trace_from_spans(&self.snapshot())
    }
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let st = self.state.lock().unwrap();
        write!(f, "TraceSink(spans={}, open={})", st.spans.len(), st.open.len())
    }
}

/// The `events.jsonl` `span_tree` line for one span (the jsonl twin of
/// the Chrome export; [`span_from_fields`] round-trips it).
pub fn span_fields(s: &SpanRecord) -> Json {
    Json::obj(vec![
        ("span_id", Json::num(s.span_id as f64)),
        ("parent_id", Json::num(s.parent_id as f64)),
        ("track", Json::num(s.track as f64)),
        ("name", Json::str(s.name.clone())),
        ("start_us", Json::num(s.start_us as f64)),
        ("dur_us", Json::num(s.dur_us.unwrap_or(0) as f64)),
        ("instant", Json::Bool(s.instant)),
        ("args", s.args.clone()),
    ])
}

/// Parse one `span_tree` fields object back into a [`SpanRecord`].
pub fn span_from_fields(fields: &Json) -> Option<SpanRecord> {
    Some(SpanRecord {
        span_id: fields.get("span_id")?.as_f64()? as u64,
        parent_id: fields.get("parent_id")?.as_f64()? as u64,
        track: fields.get("track")?.as_f64()? as u64,
        name: fields.get("name")?.as_str()?.to_string(),
        start_us: fields.get("start_us")?.as_f64()? as u64,
        dur_us: Some(fields.get("dur_us")?.as_f64()? as u64),
        instant: matches!(fields.get("instant"), Some(Json::Bool(true))),
        args: fields.get("args").cloned().unwrap_or(Json::Null),
    })
}

/// Build the Chrome-trace-event JSON document
/// (`{"displayTimeUnit":"ms","traceEvents":[...]}`) from span records.
/// Spans become `ph:"X"` complete events, instants `ph:"i"`; every
/// event's `args` carries `(trace_id, span_id, parent_id)` so the
/// causality tree survives the format round-trip.
pub fn chrome_trace_from_spans(spans: &[SpanRecord]) -> Json {
    let events: Vec<Json> = spans
        .iter()
        .map(|s| {
            let mut args = Json::obj(vec![
                ("trace_id", Json::num(TRACE_ID as f64)),
                ("span_id", Json::num(s.span_id as f64)),
                ("parent_id", Json::num(s.parent_id as f64)),
            ]);
            if let Json::Obj(extra) = &s.args {
                for (k, v) in extra {
                    args.insert(k, v.clone());
                }
            }
            let mut ev = Json::obj(vec![
                ("name", Json::str(s.name.clone())),
                ("cat", Json::str("kernelband")),
                ("ts", Json::num(s.start_us as f64)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(s.track as f64)),
                ("args", args),
            ]);
            if s.instant {
                ev.insert("ph", Json::str("i"));
                ev.insert("s", Json::str("t"));
            } else {
                ev.insert("ph", Json::str("X"));
                ev.insert("dur", Json::num(s.dur_us.unwrap_or(0) as f64));
            }
            ev
        })
        .collect();
    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(events)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_form_a_tree_and_close() {
        let sink = TraceSink::new();
        let root = sink.begin("serve.request", 0, TRACK_SERVE, Json::Null);
        let round = sink.begin("serve.round", root, TRACK_SERVE, Json::Null);
        sink.instant("pull", round, TRACK_SERVE, Json::Null);
        sink.end(round);
        sink.end(root);
        let spans = sink.snapshot();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].parent_id, 0);
        assert_eq!(spans[1].parent_id, spans[0].span_id);
        assert_eq!(spans[2].parent_id, spans[1].span_id);
        assert!(spans.iter().all(|s| s.dur_us.is_some()));
        // ids unique
        let mut ids: Vec<u64> = spans.iter().map(|s| s.span_id).collect();
        ids.dedup();
        assert_eq!(ids.len(), 3);
    }

    #[test]
    fn open_spans_are_clocked_at_snapshot() {
        let sink = TraceSink::new();
        let id = sink.begin("x", 0, 1, Json::Null);
        let spans = sink.snapshot();
        assert_eq!(spans[0].span_id, id);
        assert!(spans[0].dur_us.is_some());
    }

    #[test]
    fn chrome_export_carries_causality_args() {
        let sink = TraceSink::new();
        let a = sink.begin("a", 0, 1, Json::obj(vec![("k", Json::str("v"))]));
        sink.instant("b", a, 1, Json::Null);
        sink.end(a);
        let doc = sink.chrome_trace_json();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[1].get("ph").unwrap().as_str(), Some("i"));
        let args = evs[0].get("args").unwrap();
        assert_eq!(args.get("parent_id").unwrap().as_f64(), Some(0.0));
        assert_eq!(args.get("k").unwrap().as_str(), Some("v"));
        assert_eq!(
            evs[1].get("args").unwrap().get("parent_id").unwrap().as_f64(),
            Some(a as f64)
        );
    }

    #[test]
    fn span_fields_round_trip() {
        let sink = TraceSink::new();
        let a = sink.begin("a", 0, 3, Json::Null);
        sink.end(a);
        let rec = &sink.snapshot()[0];
        let back = span_from_fields(&span_fields(rec)).unwrap();
        assert_eq!(&back, rec);
    }

    #[test]
    fn timestamps_are_monotone_in_emission_order() {
        let sink = TraceSink::new();
        for i in 0..32 {
            let id = sink.begin("s", 0, 1 + (i % 3), Json::Null);
            sink.end(id);
        }
        let spans = sink.snapshot();
        for w in spans.windows(2) {
            assert!(w[0].start_us <= w[1].start_us);
        }
    }
}
