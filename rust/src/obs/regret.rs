//! Online regret accounting and covering diagnostics (Theorem 1's
//! observables).
//!
//! KernelBand's regret bound is stated against the latent optimum and
//! scales with the covering number of the runtime clusters — neither of
//! which PR 7's counters could see. This module makes both measurable:
//!
//! * **Regret** — per-iteration empirical regret of the best kernel
//!   found so far vs an oracle latency. On grammar-generated tasks
//!   (`TaskSpec::lineage != 0`) the oracle is *exact*: the noiseless
//!   roofline model's provable optimum (`GpuSim::oracle_config`, the
//!   same recipe `gen/conformance.rs` proves admissible). Hand-built
//!   suite tasks have no latent ground truth, so the oracle falls back
//!   to the run's final best ("best-seen" semantics); the two modes are
//!   counted separately in `METRICS.json`. The exported series is
//!   *cumulative regret per pull* — the running mean of instantaneous
//!   regret — which is non-increasing deterministically per run (the
//!   best-so-far latency never regresses), hence non-increasing in
//!   expectation across any mix of runs.
//! * **Covering** — at every re-clustering: per-cluster radii (member →
//!   centroid φ-distance), the effective covering number (non-empty
//!   clusters), and the empirical Lipschitz ratio of runtime vs
//!   φ-distance to the cluster representative — a direct check on the
//!   smoothness assumption behind the bound. All O(n) per re-cluster,
//!   so the ≤2% telemetry-overhead gate is safe.
//!
//! Everything here is advisory: computed from already-measured
//! artifacts, consuming no RNG (the oracle evaluation runs a throwaway
//! `Rng::new(0)` on a *noiseless* sim — deterministic by construction
//! and invisible to every policy stream).

use crate::cluster::Clustering;
use crate::features::{phi_distance, Phi};
use crate::gpu_model::{Device, GpuSim};
use crate::policy::Trace;
use crate::rng::Rng;
use crate::util::json::Json;
use crate::workload::TaskSpec;

/// The latent-optimum latency for a grammar-generated task, or `None`
/// for hand-built tasks (lineage 0), whose optimum is not provable.
pub fn latent_oracle_latency_s(task: &TaskSpec, device: Device) -> Option<f64> {
    if task.lineage == 0 {
        return None;
    }
    let sim = GpuSim::noiseless(device);
    let cfg = sim.oracle_config(task);
    let m = sim.evaluate(task, &cfg, &mut Rng::new(0));
    Some(m.total_latency_s)
}

/// Cumulative-regret-per-pull curve for one finished trace. Returns the
/// series (one entry per iteration) and whether the oracle was exact
/// (`true`) or best-seen (`false`). Instantaneous regret at iteration
/// `t` is `(best_latency_so_far(t) − oracle) / oracle`, floored at 0;
/// the curve is its running mean, non-increasing by construction.
pub fn regret_curve(trace: &Trace, oracle_s: Option<f64>) -> (Vec<f64>, bool) {
    let exact = oracle_s.is_some();
    let best_at = |sp: f64| -> f64 {
        if sp > 0.0 {
            trace.naive_latency_s / sp
        } else {
            trace.naive_latency_s
        }
    };
    let final_best = trace
        .records
        .last()
        .map(|r| best_at(r.best_speedup_so_far))
        .unwrap_or(trace.naive_latency_s);
    let oracle = oracle_s.unwrap_or(final_best).max(f64::MIN_POSITIVE);
    let mut curve = Vec::with_capacity(trace.records.len());
    let mut sum = 0.0f64;
    for (i, r) in trace.records.iter().enumerate() {
        let inst = ((best_at(r.best_speedup_so_far) - oracle) / oracle).max(0.0);
        sum += inst;
        curve.push(sum / (i + 1) as f64);
    }
    (curve, exact)
}

/// Cross-run accumulator for regret curves: element-wise sums so the
/// exported series is the *mean* cumulative-regret-per-pull over every
/// observed run, independent of worker completion order.
#[derive(Debug, Default)]
pub struct RegretAccum {
    sum: Vec<f64>,
    count: Vec<u64>,
    pub exact_runs: u64,
    pub best_seen_runs: u64,
}

impl RegretAccum {
    pub fn observe(&mut self, curve: &[f64], exact: bool) {
        if curve.is_empty() {
            return;
        }
        if self.sum.len() < curve.len() {
            self.sum.resize(curve.len(), 0.0);
            self.count.resize(curve.len(), 0);
        }
        for (i, &v) in curve.iter().enumerate() {
            self.sum[i] += v;
            self.count[i] += 1;
        }
        if exact {
            self.exact_runs += 1;
        } else {
            self.best_seen_runs += 1;
        }
    }

    pub fn is_empty(&self) -> bool {
        self.sum.is_empty()
    }

    /// Fold another accumulator in (order-independent).
    pub fn merge(&mut self, other: &RegretAccum) {
        if other.sum.len() > self.sum.len() {
            self.sum.resize(other.sum.len(), 0.0);
            self.count.resize(other.sum.len(), 0);
        }
        for (i, &v) in other.sum.iter().enumerate() {
            self.sum[i] += v;
            self.count[i] += other.count[i];
        }
        self.exact_runs += other.exact_runs;
        self.best_seen_runs += other.best_seen_runs;
    }

    /// The `METRICS.json` `regret` section.
    pub fn to_json(&self) -> Json {
        let series: Vec<Json> = self
            .sum
            .iter()
            .zip(&self.count)
            .map(|(&s, &n)| Json::num(if n == 0 { 0.0 } else { s / n as f64 }))
            .collect();
        let final_v = series.last().and_then(Json::as_f64).unwrap_or(0.0);
        Json::obj(vec![
            ("runs_exact", Json::num(self.exact_runs as f64)),
            ("runs_best_seen", Json::num(self.best_seen_runs as f64)),
            ("pulls", Json::num(self.sum.len() as f64)),
            ("cumulative_regret_per_pull", Json::Arr(series)),
            ("final", Json::num(final_v)),
        ])
    }
}

/// One re-clustering's covering diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct CoveringRecord {
    /// Iteration at which the re-clustering happened.
    pub t: usize,
    /// Configured cluster count K.
    pub clusters: usize,
    /// Non-empty clusters — the effective covering number.
    pub covering_number: usize,
    /// Largest member→centroid φ-distance over all clusters.
    pub max_radius: f64,
    /// Mean member→centroid φ-distance over all points.
    pub mean_radius: f64,
    /// Max over members of |latency − latency(rep)| / φ-dist(·, rep) —
    /// the empirical Lipschitz constant of runtime in φ-space.
    pub lipschitz: f64,
}

/// Compute one covering record from a freshly converged clustering.
/// O(n) in frontier size (one pass; no pairwise distances).
pub fn covering_record(
    t: usize,
    clustering: &Clustering,
    points: &[Phi],
    latencies: &[f64],
) -> CoveringRecord {
    let k = clustering.centroids.len();
    let radii = clustering.radii(points);
    let max_radius = radii.iter().cloned().fold(0.0f64, f64::max);
    let mut nonempty = vec![false; k];
    let mut radius_sum = 0.0f64;
    let mut lipschitz = 0.0f64;
    for (i, p) in points.iter().enumerate() {
        let c = clustering.assign[i];
        nonempty[c] = true;
        radius_sum += phi_distance(p, &clustering.centroids[c]);
        let rep = clustering.representatives[c];
        if rep != usize::MAX && rep != i {
            let dr = phi_distance(p, &points[rep]);
            if dr > 0.0 {
                lipschitz = lipschitz
                    .max((latencies[i] - latencies[rep]).abs() / dr);
            }
        }
    }
    CoveringRecord {
        t,
        clusters: k,
        covering_number: nonempty.iter().filter(|&&b| b).count(),
        max_radius,
        mean_radius: if points.is_empty() {
            0.0
        } else {
            radius_sum / points.len() as f64
        },
        lipschitz,
    }
}

/// The `METRICS.json` `covering` section: one object per re-clustering,
/// in observation order.
pub fn covering_json(records: &[CoveringRecord]) -> Json {
    Json::Arr(
        records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("t", Json::num(r.t as f64)),
                    ("clusters", Json::num(r.clusters as f64)),
                    ("covering_number", Json::num(r.covering_number as f64)),
                    ("max_radius", Json::num(r.max_radius)),
                    ("mean_radius", Json::num(r.mean_radius)),
                    ("lipschitz", Json::num(r.lipschitz)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_means_curves_and_counts_modes() {
        let mut a = RegretAccum::default();
        a.observe(&[1.0, 0.5], true);
        a.observe(&[0.5, 0.25, 0.25], false);
        let j = a.to_json();
        assert_eq!(j.get("runs_exact").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("runs_best_seen").unwrap().as_f64(), Some(1.0));
        let s = j.get("cumulative_regret_per_pull").unwrap().as_arr().unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0].as_f64(), Some(0.75));
        assert_eq!(s[2].as_f64(), Some(0.25));
    }

    #[test]
    fn merge_is_order_independent() {
        let mut a = RegretAccum::default();
        let mut b = RegretAccum::default();
        let mut c1 = RegretAccum::default();
        let mut c2 = RegretAccum::default();
        a.observe(&[1.0], true);
        b.observe(&[0.5, 0.5], false);
        c1.merge(&a);
        c1.merge(&b);
        c2.merge(&b);
        c2.merge(&a);
        assert_eq!(c1.to_json().dump(), c2.to_json().dump());
    }

    #[test]
    fn covering_counts_nonempty_and_bounds_radius() {
        let p = |v: f64| {
            let mut x = Phi::default();
            x[0] = v;
            x
        };
        let clustering = Clustering {
            assign: vec![0, 0, 1],
            centroids: vec![p(0.0), p(10.0), p(99.0)], // cluster 2 empty
            representatives: vec![0, 2, usize::MAX],
        };
        let points = vec![p(0.0), p(2.0), p(10.0)];
        let lats = vec![1.0, 3.0, 5.0];
        let rec = covering_record(7, &clustering, &points, &lats);
        assert_eq!(rec.t, 7);
        assert_eq!(rec.clusters, 3);
        assert_eq!(rec.covering_number, 2);
        assert!((rec.max_radius - 2.0).abs() < 1e-12);
        // member 1 vs rep 0: |3-1|/2 = 1.0 is the steepest observed
        assert!((rec.lipschitz - 1.0).abs() < 1e-12);
    }
}
