//! Two-stage correctness verification (paper §4.1, Appendix H).
//!
//! *Call Accuracy* checks that the candidate runs at all (compile/launch
//! errors); *Execution Accuracy* checks numerical equivalence with
//! `torch.allclose(atol=1e-4, rtol=1e-4)`. In the simulated engine the
//! failure mode is carried by the surrogate LLM's [`GenOutcome`]; on the
//! PJRT engine the allclose check runs for real against the reference
//! artifact's output buffers.

use crate::llm::GenOutcome;

/// The paper's tolerances (Appendix H).
pub const ATOL: f32 = 1e-4;
pub const RTOL: f32 = 1e-4;

/// Result of the two-stage check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Verdict {
    /// Stage 1: no runtime/compile errors.
    pub call_ok: bool,
    /// Stage 2: numerically equivalent to the reference.
    pub exec_ok: bool,
}

impl Verdict {
    pub fn passed(&self) -> bool {
        self.call_ok && self.exec_ok
    }

    pub fn pass() -> Verdict {
        Verdict { call_ok: true, exec_ok: true }
    }
}

/// Map a simulated generation outcome onto the two stages.
pub fn verify_outcome(outcome: GenOutcome) -> Verdict {
    match outcome {
        GenOutcome::Ok => Verdict { call_ok: true, exec_ok: true },
        GenOutcome::CompileError => Verdict { call_ok: false, exec_ok: false },
        GenOutcome::WrongOutput => Verdict { call_ok: true, exec_ok: false },
    }
}

/// `|a - b| <= atol + rtol * |b|` elementwise — the torch.allclose
/// criterion used by the PJRT engine's execution-accuracy stage.
pub fn allclose(got: &[f32], want: &[f32], atol: f32, rtol: f32) -> bool {
    if got.len() != want.len() {
        return false;
    }
    got.iter().zip(want).all(|(&g, &w)| {
        if g.is_nan() || w.is_nan() {
            return false;
        }
        (g - w).abs() <= atol + rtol * w.abs()
    })
}

/// Two-stage verification of real output buffers.
pub fn verify_buffers(got: Option<&[f32]>, want: &[f32]) -> Verdict {
    match got {
        None => Verdict { call_ok: false, exec_ok: false },
        Some(g) => Verdict {
            call_ok: true,
            exec_ok: allclose(g, want, ATOL, RTOL),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_mapping() {
        assert!(verify_outcome(GenOutcome::Ok).passed());
        let compile = verify_outcome(GenOutcome::CompileError);
        assert!(!compile.call_ok && !compile.passed());
        let wrong = verify_outcome(GenOutcome::WrongOutput);
        assert!(wrong.call_ok && !wrong.exec_ok && !wrong.passed());
    }

    #[test]
    fn allclose_exact_and_tolerant() {
        let a = [1.0f32, 2.0, 3.0];
        assert!(allclose(&a, &a, ATOL, RTOL));
        let b = [1.00005f32, 2.0, 3.0];
        assert!(allclose(&b, &a, ATOL, RTOL));
        let c = [1.1f32, 2.0, 3.0];
        assert!(!allclose(&c, &a, ATOL, RTOL));
    }

    #[test]
    fn allclose_relative_scales_with_magnitude() {
        let want = [10_000.0f32];
        let got = [10_000.9f32]; // within rtol*|want| = 1.0
        assert!(allclose(&got, &want, ATOL, RTOL));
        let got2 = [10_002.0f32];
        assert!(!allclose(&got2, &want, ATOL, RTOL));
    }

    #[test]
    fn allclose_rejects_nan_and_shape_mismatch() {
        assert!(!allclose(&[f32::NAN], &[0.0], ATOL, RTOL));
        assert!(!allclose(&[0.0], &[f32::NAN], ATOL, RTOL));
        assert!(!allclose(&[0.0, 1.0], &[0.0], ATOL, RTOL));
    }

    #[test]
    fn buffer_verification_stages() {
        let want = [1.0f32, 2.0];
        assert!(verify_buffers(Some(&[1.0, 2.0]), &want).passed());
        let v = verify_buffers(Some(&[9.0, 2.0]), &want);
        assert!(v.call_ok && !v.exec_ok);
        assert!(!verify_buffers(None, &want).call_ok);
    }
}
