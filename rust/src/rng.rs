//! Splittable deterministic RNG.
//!
//! Every stochastic component in the reproduction — simulator noise,
//! surrogate-LLM sampling, subset selection, within-cluster softmax picks —
//! draws from an explicitly keyed [`Rng`] so that (a) every table and
//! figure is bit-reproducible, and (b) results are invariant to the order
//! in which tasks are executed (rayon parallelism does not perturb them).
//!
//! The generator is SplitMix64 (Steele et al., *Fast splittable
//! pseudorandom number generators*), which passes BigCrush for the 64-bit
//! stream and supports cheap key-derivation by hashing a label into the
//! state.

/// SplitMix64 stream with labeled splitting.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

const GOLDEN: u64 = 0x9e37_79b9_7f4a_7c15;

#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    /// New stream from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        Rng { state: mix(seed ^ GOLDEN) }
    }

    /// Derive an independent stream keyed by `(label, index)`.
    ///
    /// Deriving is position-independent: `rng.split("task", 7)` yields the
    /// same stream no matter how many numbers were drawn from `rng` first,
    /// because it hashes the *seed lineage*, not the current state.
    pub fn split(&self, label: &str, index: u64) -> Rng {
        let mut h = self.state;
        for &b in label.as_bytes() {
            h = mix(h ^ (b as u64).wrapping_mul(GOLDEN));
        }
        Rng { state: mix(h ^ index.wrapping_mul(GOLDEN)) }
    }

    /// Stable 64-bit fingerprint of this stream's seed lineage.
    ///
    /// Two `Rng`s produce identical draws iff their fingerprints match,
    /// so the fingerprint is usable as a cache key component: the
    /// persistent kernel store keys measurements by (task, config,
    /// device, noise lineage) and a replayed run reconstructs the exact
    /// same fingerprints, turning every simulated measurement into a
    /// lookup (see [`crate::store`]).
    #[inline]
    pub fn fingerprint(&self) -> u64 {
        self.state
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN);
        mix(self.state)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (n > 0) via Lemire rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Lognormal multiplicative noise with geometric σ = `sigma`
    /// (e.g. 0.03 ≈ ±3% jitter), mean-one in log space.
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Pick an index from unnormalized non-negative weights.
    ///
    /// All-zero weight vectors degrade to uniform. Used for the paper's
    /// within-cluster softmax sampling `P(k) ∝ exp(V_hw(k, s))`.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        assert!(!weights.is_empty());
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len() as u64) as usize;
        }
        let mut x = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Softmax draw over scores (temperature 1), numerically stable.
    pub fn softmax(&mut self, scores: &[f64]) -> usize {
        let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let w: Vec<f64> = scores.iter().map(|s| (s - m).exp()).collect();
        self.weighted(&w)
    }

    /// Allocation-free [`Rng::softmax`]: overwrites `scores` with the
    /// unnormalized weights and draws. Draw-for-draw identical to
    /// `softmax` on the same scores (same weights, same consumption),
    /// for the policy hot loop's reusable scratch buffer.
    pub fn softmax_in_place(&mut self, scores: &mut [f64]) -> usize {
        let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
        }
        self.weighted(scores)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `n` distinct indices from `0..len` (n <= len), sorted.
    pub fn sample_indices(&mut self, len: usize, n: usize) -> Vec<usize> {
        assert!(n <= len);
        let mut idx: Vec<usize> = (0..len).collect();
        self.shuffle(&mut idx);
        let mut out = idx[..n].to_vec();
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_is_position_independent() {
        let root = Rng::new(7);
        let mut consumed = root.clone();
        for _ in 0..10 {
            consumed.next_u64();
        }
        // split hashes lineage, not stream position — but we split from the
        // *original* value in both cases to document the contract.
        let mut s1 = root.split("task", 3);
        let mut s2 = root.split("task", 3);
        assert_eq!(s1.next_u64(), s2.next_u64());
        let mut s3 = root.split("task", 4);
        assert_ne!(s1.next_u64(), s3.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(4);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn weighted_all_zero_is_uniform() {
        let mut r = Rng::new(5);
        let w = [0.0, 0.0];
        let mut c0 = 0;
        for _ in 0..1000 {
            if r.weighted(&w) == 0 {
                c0 += 1;
            }
        }
        assert!(c0 > 350 && c0 < 650);
    }

    #[test]
    fn softmax_prefers_large_scores() {
        let mut r = Rng::new(6);
        let mut hits = 0;
        for _ in 0..1000 {
            if r.softmax(&[0.0, 5.0, 0.0]) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 950);
    }

    #[test]
    fn softmax_in_place_matches_softmax_draw_for_draw() {
        let scores = [0.3, -1.2, 4.0, 0.0, 2.5];
        for seed in 0..50 {
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            let mut buf = scores;
            let ia = a.softmax(&scores);
            let ib = b.softmax_in_place(&mut buf);
            assert_eq!(ia, ib);
            // identical stream positions afterwards
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn softmax_handles_neg_infinity() {
        let mut r = Rng::new(7);
        for _ in 0..100 {
            let i = r.softmax(&[f64::NEG_INFINITY, 1.0, f64::NEG_INFINITY]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(50, 10);
        assert_eq!(s.len(), 10);
        for w in s.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn lognormal_noise_centered() {
        let mut r = Rng::new(9);
        let mean: f64 = (0..20_000).map(|_| r.lognormal_noise(0.03)).sum::<f64>()
            / 20_000.0;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }
}
