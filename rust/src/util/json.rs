//! Minimal strict JSON parser and writer.
//!
//! Parsing covers the full JSON grammar (objects, arrays, strings with
//! escape sequences, numbers, booleans, null); numbers are parsed as
//! f64, which is exact for every integer the AOT manifest emits
//! (< 2^53). Errors carry byte offsets for debuggability.
//!
//! Writing ([`Json::dump`] / [`Json::pretty`]) is the output half used
//! by the experiment runner's `BENCH_*.json` result artifacts: object
//! keys serialize in sorted (BTreeMap) order and floats use Rust's
//! shortest-roundtrip formatting, so serialization is byte-deterministic
//! and `parse(dump(j)) == j` for every finite value. Non-finite numbers
//! (NaN geomeans of empty strata) serialize as `null`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// `obj.key` as &str or an error mentioning the key.
    pub fn str_field(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| JsonError::new(0, format!("missing string field {key:?}")))
    }

    pub fn f64_field(&self, key: &str) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(0.0)
    }

    // --- construction helpers (result-artifact building) ---------------

    /// A string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// A numeric value; non-finite inputs (NaN geomeans) become `null`.
    pub fn num(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null
        }
    }

    /// An object from `(key, value)` pairs (keys serialize sorted).
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            entries
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Insert a key into an object in place; debug-panics on non-objects.
    pub fn insert(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => debug_assert!(false, "Json::insert on a non-object"),
        }
    }

    // --- serialization -------------------------------------------------

    /// Compact serialization (no whitespace), byte-deterministic.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indentation, byte-deterministic.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..width * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        // f64 Display is shortest-roundtrip and never uses exponent
        // notation, so the output is valid JSON and parses back exactly.
        out.push_str(&format!("{n}"));
    } else {
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl JsonError {
    fn new(offset: usize, message: impl Into<String>) -> Self {
        JsonError { offset, message: message.into() }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse JSON-Lines text leniently: each non-empty line is parsed as an
/// independent document, and lines that fail to parse are counted
/// rather than fatal.
///
/// This is the replay half of the append-only trace log
/// ([`crate::store::log`]): a process killed mid-append leaves a
/// truncated final line, and a corruption-tolerant reader must recover
/// every complete record before it. Returns the parsed values in file
/// order plus the number of lines skipped as unparseable.
pub fn parse_lines_lossy(text: &str) -> (Vec<Json>, usize) {
    let mut values = Vec::new();
    let mut skipped = 0usize;
    for (_, parsed) in classify_lines(text) {
        match parsed {
            Ok(v) => values.push(v),
            Err(_) => skipped += 1,
        }
    }
    (values, skipped)
}

/// Parse JSON-Lines text line by line, keeping each line's text
/// alongside its parse outcome. This is the triage half of
/// `trace fsck`: a repair pass needs the raw bytes of a corrupt line
/// (to quarantine it verbatim), not just a skip count. Empty and
/// whitespace-only lines are omitted.
pub fn classify_lines(text: &str)
                      -> Vec<(&str, Result<Json, JsonError>)> {
    text.lines()
        .map(str::trim)
        .filter(|line| !line.is_empty())
        .map(|line| (line, parse(line)))
        .collect()
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(JsonError::new(p.pos, "trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(JsonError::new(
                self.pos.saturating_sub(1),
                format!("expected {:?}", b as char),
            ))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(JsonError::new(self.pos, format!("unexpected {:?}", c as char))),
            None => Err(JsonError::new(self.pos, "unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(JsonError::new(self.pos, format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    return Err(JsonError::new(
                        self.pos.saturating_sub(1),
                        "expected ',' or '}'",
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    return Err(JsonError::new(
                        self.pos.saturating_sub(1),
                        "expected ',' or ']'",
                    ))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(JsonError::new(self.pos, "unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| {
                                JsonError::new(self.pos, "bad \\u escape")
                            })?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| {
                                    JsonError::new(self.pos, "bad hex digit")
                                })?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(JsonError::new(self.pos, "bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(JsonError::new(self.pos, "control char in string"))
                }
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.bytes.len());
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| JsonError::new(start, "bad utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::new(start, format!("bad number {text:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].str_field("b").unwrap(),
            "c"
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A");
        let v = parse("\"caf\u{00e9}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn error_carries_offset() {
        let err = parse("[1, x]").unwrap_err();
        assert_eq!(err.offset, 4);
    }

    #[test]
    fn dump_serializes_scalars_compactly() {
        assert_eq!(Json::Null.dump(), "null");
        assert_eq!(Json::Bool(true).dump(), "true");
        assert_eq!(Json::num(1.0).dump(), "1");
        assert_eq!(Json::num(0.25).dump(), "0.25");
        assert_eq!(Json::str("hi").dump(), "\"hi\"");
        assert_eq!(
            Json::obj(vec![("b", Json::num(2.0)), ("a", Json::num(1.0))])
                .dump(),
            "{\"a\":1,\"b\":2}" // BTreeMap: sorted keys
        );
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::num(f64::NAN), Json::Null);
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::str("a\n\t\"\\ \u{1}é");
        let dumped = original.dump();
        assert_eq!(parse(&dumped).unwrap(), original);
    }

    #[test]
    fn writer_parser_roundtrip_compact_and_pretty() {
        let v = Json::obj(vec![
            ("experiment", Json::str("table1")),
            ("iterations", Json::num(20.0)),
            ("geomean", Json::num(1.2345678901234567)),
            ("failed_geomean", Json::num(f64::NAN)),
            ("cells", Json::Arr(vec![
                Json::obj(vec![
                    ("device", Json::str("H20")),
                    ("correct_pct", Json::num(87.5)),
                    ("curve", Json::Arr(vec![
                        Json::num(1.0),
                        Json::num(1.5),
                    ])),
                ]),
                Json::Arr(vec![]),
                Json::obj(vec![]),
            ])),
        ]);
        let reparsed = parse(&v.dump()).unwrap();
        assert_eq!(reparsed, v);
        let pretty = v.pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
        // identical structure serializes to identical bytes
        assert_eq!(v.dump(), reparsed.dump());
    }

    #[test]
    fn insert_extends_objects() {
        let mut v = Json::obj(vec![("a", Json::num(1.0))]);
        v.insert("b", Json::str("x"));
        assert_eq!(v.str_field("b").unwrap(), "x");
        assert_eq!(v.f64_field("a"), 1.0);
    }

    #[test]
    fn parse_lines_lossy_recovers_complete_records() {
        let text = "{\"v\":1,\"kind\":\"step\",\"t\":1}\n\
                    \n\
                    {\"v\":1,\"kind\":\"step\",\"t\":2}\n";
        let (vals, skipped) = parse_lines_lossy(text);
        assert_eq!(vals.len(), 2);
        assert_eq!(skipped, 0);
        assert_eq!(vals[1].f64_field("t"), 2.0);
    }

    #[test]
    fn parse_lines_lossy_skips_truncated_final_line() {
        // the crash-mid-append shape: last record cut off mid-object
        let text = "{\"v\":1,\"t\":1}\n{\"v\":1,\"t\":2}\n{\"v\":1,\"t\":";
        let (vals, skipped) = parse_lines_lossy(text);
        assert_eq!(vals.len(), 2);
        assert_eq!(skipped, 1);
    }

    #[test]
    fn parse_lines_lossy_skips_garbage_lines_independently() {
        let text = "not json at all\n{\"ok\":true}\n[1,2,\n{\"ok\":false}";
        let (vals, skipped) = parse_lines_lossy(text);
        assert_eq!(vals.len(), 2);
        assert_eq!(skipped, 2);
        assert_eq!(vals[0].get("ok"), Some(&Json::Bool(true)));
        assert_eq!(vals[1].get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn parse_lines_lossy_handles_empty_and_whitespace() {
        assert_eq!(parse_lines_lossy("").0.len(), 0);
        let (vals, skipped) = parse_lines_lossy("\n   \n\t\n");
        assert!(vals.is_empty());
        assert_eq!(skipped, 0);
    }

    #[test]
    fn roundtrips_manifest_shape() {
        let text = r#"{
  "version": 1,
  "artifacts": [
    {"name": "matmul_t64x64x64", "file": "matmul_t64x64x64.hlo.txt",
     "op": "matmul", "role": "variant",
     "params": {"bm": 64, "strategy": "tiling"},
     "inputs": [{"dims": [256, 256], "dtype": "f32"}],
     "outputs": [{"dims": [256, 256], "dtype": "f32"}],
     "flops": 33554432, "vmem_bytes": 49152, "mxu_util": 0.25}
  ]
}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.f64_field("version"), 1.0);
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].str_field("op").unwrap(), "matmul");
        assert_eq!(
            arts[0].get("params").unwrap().str_field("strategy").unwrap(),
            "tiling"
        );
        assert_eq!(arts[0].f64_field("flops"), 33554432.0);
    }
}
