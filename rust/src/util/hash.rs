//! FNV-1a hashing for content-addressed store keys.
//!
//! The persistent kernel store ([`crate::store`]) addresses cached
//! measurements and LLM proposals by a 64-bit FNV-1a digest over a
//! domain tag plus the ingredients that determine the result bit for bit
//! (task fingerprint, schedule hash, device fingerprint, RNG seed
//! lineage). FNV is not cryptographic — collisions are theoretically
//! possible but the keyed inputs are themselves 64-bit mixed values, and
//! a collision only ever swaps one deterministic simulation result for
//! another inside a diagnostic cache.

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Builder for multi-field keys: every field is folded into the digest
/// with a length-free little-endian encoding preceded by the byte count,
/// so `("ab", "c")` and `("a", "bc")` hash differently.
#[derive(Debug, Clone, Copy)]
pub struct KeyHasher(u64);

impl KeyHasher {
    /// Start a digest in a named domain ("measure", "proposal", …) so
    /// identical ingredients in different domains never collide.
    pub fn new(domain: &str) -> KeyHasher {
        KeyHasher(fnv1a(domain.as_bytes()))
    }

    fn fold(mut self, bytes: &[u8]) -> KeyHasher {
        self = self.fold_raw(&(bytes.len() as u64).to_le_bytes());
        self.fold_raw(bytes)
    }

    fn fold_raw(mut self, bytes: &[u8]) -> KeyHasher {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
        self
    }

    pub fn u64(self, v: u64) -> KeyHasher {
        self.fold(&v.to_le_bytes())
    }

    /// Bit-exact: NaN payloads and signed zeros are distinguished.
    pub fn f64(self, v: f64) -> KeyHasher {
        self.fold(&v.to_bits().to_le_bytes())
    }

    pub fn str(self, s: &str) -> KeyHasher {
        self.fold(s.as_bytes())
    }

    pub fn finish(self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_known_vector() {
        // FNV-1a("a") — standard test vector
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b""), FNV_OFFSET);
    }

    #[test]
    fn domains_separate_identical_fields() {
        let a = KeyHasher::new("measure").u64(7).finish();
        let b = KeyHasher::new("proposal").u64(7).finish();
        assert_ne!(a, b);
    }

    #[test]
    fn field_boundaries_matter() {
        let a = KeyHasher::new("t").str("ab").str("c").finish();
        let b = KeyHasher::new("t").str("a").str("bc").finish();
        assert_ne!(a, b);
    }

    #[test]
    fn f64_is_bit_exact() {
        let a = KeyHasher::new("t").f64(0.0).finish();
        let b = KeyHasher::new("t").f64(-0.0).finish();
        assert_ne!(a, b);
        let c = KeyHasher::new("t").f64(1.5).finish();
        let d = KeyHasher::new("t").f64(1.5).finish();
        assert_eq!(c, d);
    }

    #[test]
    fn order_matters() {
        let a = KeyHasher::new("t").u64(1).u64(2).finish();
        let b = KeyHasher::new("t").u64(2).u64(1).finish();
        assert_ne!(a, b);
    }
}
