//! Deterministic scoped-thread parallel map (rayon stand-in).
//!
//! Results come back in input order regardless of scheduling, and every
//! work item derives its randomness from a split RNG keyed by its index,
//! so experiment outputs are invariant to the degree of parallelism.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `items` using up to `threads` OS threads (0 = available
/// parallelism). Output order matches input order.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    }
    .min(items.len().max(1));

    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots_ptr = SlicePtr(slots.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots_ptr = SlicePtr(slots_ptr.0);
            scope.spawn(move || {
                // force whole-struct capture (edition-2021 closures would
                // otherwise capture the raw pointer field, which is !Send)
                let slots_ptr = slots_ptr;
                loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                // SAFETY: each index is claimed by exactly one thread via
                // the atomic counter, so writes never alias; the scope
                // guarantees the buffer outlives all threads.
                unsafe {
                    *slots_ptr.0.add(i) = Some(r);
                }
            }});
        }
    });

    slots.into_iter().map(|s| s.expect("slot filled")).collect()
}

/// Send/Sync wrapper for the disjoint-write output pointer.
struct SlicePtr<R>(*mut Option<R>);
unsafe impl<R: Send> Send for SlicePtr<R> {}
unsafe impl<R: Send> Sync for SlicePtr<R> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn matches_serial_execution() {
        let items: Vec<u64> = (0..50).collect();
        let serial = parallel_map(&items, 1, |i, &x| x.wrapping_mul(i as u64 + 7));
        let parallel = parallel_map(&items, 8, |i, &x| x.wrapping_mul(i as u64 + 7));
        assert_eq!(serial, parallel);
    }

    #[test]
    fn handles_empty_and_singleton() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[42u32], 4, |_, &x| x + 1), vec![43]);
    }

    #[test]
    fn index_argument_is_correct() {
        let items = vec!["a"; 64];
        let out = parallel_map(&items, 6, |i, _| i);
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }
}
