//! Deterministic scoped-thread parallel map (rayon stand-in).
//!
//! Results come back in input order regardless of scheduling, and every
//! work item derives its randomness from a split RNG keyed by its index,
//! so experiment outputs are invariant to the degree of parallelism.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `items` using up to `threads` OS threads (0 = available
/// parallelism). Output order matches input order.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    }
    .min(items.len().max(1));

    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots_ptr = SlicePtr(slots.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots_ptr = SlicePtr(slots_ptr.0);
            scope.spawn(move || {
                // force whole-struct capture (edition-2021 closures would
                // otherwise capture the raw pointer field, which is !Send)
                let slots_ptr = slots_ptr;
                loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                // SAFETY: each index is claimed by exactly one thread via
                // the atomic counter, so writes never alias; the scope
                // guarantees the buffer outlives all threads.
                unsafe {
                    *slots_ptr.0.add(i) = Some(r);
                }
            }});
        }
    });

    slots.into_iter().map(|s| s.expect("slot filled")).collect()
}

/// Send/Sync wrapper for the disjoint-write output pointer.
struct SlicePtr<R>(*mut Option<R>);
unsafe impl<R: Send> Send for SlicePtr<R> {}
unsafe impl<R: Send> Sync for SlicePtr<R> {}

/// Map `f` over `items` with one dedicated OS thread per item.
///
/// Unlike [`parallel_map`], which multiplexes items over a bounded
/// worker pool, every item here owns a thread for its whole lifetime —
/// the right shape for latency-bound jobs that block on shared
/// infrastructure (the optimization service's batched LLM gateway needs
/// *all* jobs submitting concurrently to fill its batching window; a
/// pooled worker that ran two jobs back-to-back would serialize them
/// and starve the batch). Output order matches input order.
pub fn spawn_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = items
            .iter()
            .enumerate()
            .map(|(i, t)| scope.spawn(move || f(i, t)))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn matches_serial_execution() {
        let items: Vec<u64> = (0..50).collect();
        let serial = parallel_map(&items, 1, |i, &x| x.wrapping_mul(i as u64 + 7));
        let parallel = parallel_map(&items, 8, |i, &x| x.wrapping_mul(i as u64 + 7));
        assert_eq!(serial, parallel);
    }

    #[test]
    fn handles_empty_and_singleton() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[42u32], 4, |_, &x| x + 1), vec![43]);
    }

    #[test]
    fn index_argument_is_correct() {
        let items = vec!["a"; 64];
        let out = parallel_map(&items, 6, |i, _| i);
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_across_1_2_8_threads() {
        // per-item work draws from a split RNG keyed by (item, index) —
        // the experiment runner's pattern — so outputs must be invariant
        // to the degree of parallelism, bit for bit.
        use crate::rng::Rng;
        let items: Vec<u64> = (0..64).collect();
        let run = |threads: usize| -> Vec<f64> {
            parallel_map(&items, threads, |i, &x| {
                let mut rng = Rng::new(x).split("par-test", i as u64);
                let mut acc = 0.0;
                for _ in 0..16 {
                    acc += rng.uniform();
                }
                acc
            })
        };
        let t1 = run(1);
        let t2 = run(2);
        let t8 = run(8);
        assert_eq!(t1, t2);
        assert_eq!(t1, t8);
    }

    #[test]
    fn spawn_map_preserves_order_and_indices() {
        let items: Vec<usize> = (0..12).collect();
        let out = spawn_map(&items, |i, &x| {
            assert_eq!(i, x);
            x * 3
        });
        assert_eq!(out, (0..12).map(|x| x * 3).collect::<Vec<_>>());
        let empty: Vec<u32> = vec![];
        assert!(spawn_map(&empty, |_, &x| x).is_empty());
    }

    #[test]
    fn spawn_map_runs_every_item_on_its_own_thread() {
        // all items rendezvous on one barrier: this can only complete if
        // every item really has a dedicated live thread.
        use std::sync::Barrier;
        let items: Vec<usize> = (0..8).collect();
        let barrier = Barrier::new(items.len());
        let out = spawn_map(&items, |i, &x| {
            barrier.wait();
            i + x
        });
        assert_eq!(out, (0..8).map(|x| x * 2).collect::<Vec<_>>());
    }
}
