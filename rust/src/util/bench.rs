//! Criterion-style timing harness for `cargo bench` (harness = false).
//!
//! Each bench target builds a [`BenchSuite`], registers closures, and
//! prints `name  time: [median ± spread]  throughput` lines plus the
//! experiment tables they regenerate. Measurement discipline follows
//! `triton.testing.do_bench`: warmup iterations, then timed samples with
//! median/percentile reporting.
//!
//! Benches that feed the CI perf-smoke job additionally collect
//! [`PerfEntry`] records and write a machine-readable `PERF_<suite>.json`
//! artifact via [`write_perf_artifact`] (destination `$KERNELBAND_PERF_DIR`,
//! default `perf/`), so the perf trajectory accumulates across runs.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Statistics of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub samples: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub p95: Duration,
}

/// Run `f` repeatedly: `warmup` unmeasured runs, then timed samples until
/// `min_samples` samples *and* `min_time` total measurement are reached.
pub fn measure<F: FnMut()>(mut f: F, warmup: usize, min_samples: usize,
                           min_time: Duration) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while samples.len() < min_samples || t0.elapsed() < min_time {
        let s = Instant::now();
        f();
        samples.push(s.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort();
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    BenchStats {
        samples: n,
        median: samples[n / 2],
        mean: total / n as u32,
        min: samples[0],
        p95: samples[(n * 95 / 100).min(n - 1)],
    }
}

fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// A named collection of benches with uniform reporting.
pub struct BenchSuite {
    name: String,
    warmup: usize,
    min_samples: usize,
    min_time: Duration,
}

impl BenchSuite {
    pub fn new(name: &str) -> Self {
        println!("==== bench suite: {name} ====");
        BenchSuite {
            name: name.to_string(),
            warmup: 1,
            min_samples: 5,
            min_time: Duration::from_millis(200),
        }
    }

    /// For heavyweight end-to-end benches: fewer samples.
    pub fn heavy(name: &str) -> Self {
        println!("==== bench suite: {name} ====");
        BenchSuite {
            name: name.to_string(),
            warmup: 0,
            min_samples: 3,
            min_time: Duration::from_millis(0),
        }
    }

    pub fn bench<F: FnMut()>(&self, name: &str, f: F) -> BenchStats {
        let stats = measure(f, self.warmup, self.min_samples, self.min_time);
        println!(
            "{}/{:<42} time: [{} .. median {} .. p95 {}]  ({} samples)",
            self.name,
            name,
            fmt_duration(stats.min),
            fmt_duration(stats.median),
            fmt_duration(stats.p95),
            stats.samples
        );
        stats
    }

    /// Bench with a throughput annotation (`items` processed per call).
    pub fn bench_throughput<F: FnMut()>(&self, name: &str, items: f64, f: F)
                                        -> BenchStats {
        let stats = self.bench(name, f);
        let per_s = items / stats.median.as_secs_f64().max(1e-12);
        println!("{}/{:<42} throughput: {per_s:.1} items/s", self.name, name);
        stats
    }
}

/// One recorded bench result destined for the perf artifact.
#[derive(Debug, Clone)]
pub struct PerfEntry {
    pub name: String,
    pub stats: BenchStats,
    /// Items processed per call (throughput annotation), if any.
    pub items: Option<f64>,
}

impl PerfEntry {
    pub fn new(name: &str, stats: BenchStats) -> PerfEntry {
        PerfEntry { name: name.to_string(), stats, items: None }
    }

    pub fn with_items(name: &str, stats: BenchStats, items: f64) -> PerfEntry {
        PerfEntry { name: name.to_string(), stats, items: Some(items) }
    }

    fn to_json(&self) -> Json {
        let median_s = self.stats.median.as_secs_f64();
        let mut fields = vec![
            ("name", Json::str(self.name.clone())),
            ("samples", Json::num(self.stats.samples as f64)),
            ("min_ns", Json::num(self.stats.min.as_nanos() as f64)),
            ("median_ns", Json::num(self.stats.median.as_nanos() as f64)),
            ("p95_ns", Json::num(self.stats.p95.as_nanos() as f64)),
        ];
        if let Some(items) = self.items {
            fields.push(("items_per_call", Json::num(items)));
            fields.push((
                "items_per_sec",
                Json::num(items / median_s.max(1e-12)),
            ));
        }
        Json::obj(fields)
    }
}

/// Assemble the `PERF_<suite>.json` root object. `extra` carries
/// bench-specific derived metrics (e.g. before/after speedup ratios).
pub fn perf_json(suite: &str, entries: &[PerfEntry],
                 extra: Vec<(&str, Json)>) -> Json {
    let mut fields = vec![
        ("schema_version", Json::num(1.0)),
        ("suite", Json::str(suite)),
        (
            "entries",
            Json::Arr(entries.iter().map(PerfEntry::to_json).collect()),
        ),
    ];
    fields.extend(extra);
    Json::obj(fields)
}

/// Write `PERF_<suite>.json` under `$KERNELBAND_PERF_DIR` (default
/// `perf/`); returns the path written. Timing artifacts are environment-
/// dependent by nature and deliberately live outside the deterministic
/// `BENCH_*.json` namespace.
pub fn write_perf_artifact(suite: &str, json: &Json)
                           -> std::io::Result<PathBuf> {
    let dir = std::env::var("KERNELBAND_PERF_DIR")
        .unwrap_or_else(|_| "perf".to_string());
    let dir = Path::new(&dir);
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("PERF_{suite}.json"));
    std::fs::write(&path, json.pretty() + "\n")?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perf_entry_json_has_throughput_fields() {
        let stats = measure(|| {}, 0, 3, Duration::from_millis(0));
        let e = PerfEntry::with_items("inner_loop", stats, 500.0);
        let j = e.to_json();
        assert_eq!(j.str_field("name").unwrap(), "inner_loop");
        assert!(j.get("items_per_sec").is_some());
        let root = perf_json("policy", &[e], vec![("speedup", Json::num(3.5))]);
        assert_eq!(root.str_field("suite").unwrap(), "policy");
        assert_eq!(root.f64_field("speedup"), 3.5);
        assert_eq!(root.get("entries").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn measure_counts_samples() {
        let mut calls = 0u64;
        let stats = measure(
            || calls += 1,
            2,
            7,
            Duration::from_millis(0),
        );
        assert!(stats.samples >= 7);
        assert!(calls as usize >= stats.samples + 2);
        assert!(stats.min <= stats.median && stats.median <= stats.p95);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(Duration::from_secs(2)).contains('s'));
        assert!(fmt_duration(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_duration(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_duration(Duration::from_nanos(50)).contains("ns"));
    }
}
