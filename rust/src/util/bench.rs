//! Criterion-style timing harness for `cargo bench` (harness = false).
//!
//! Each bench target builds a [`BenchSuite`], registers closures, and
//! prints `name  time: [median ± spread]  throughput` lines plus the
//! experiment tables they regenerate. Measurement discipline follows
//! `triton.testing.do_bench`: warmup iterations, then timed samples with
//! median/percentile reporting.

use std::time::{Duration, Instant};

/// Statistics of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub samples: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub p95: Duration,
}

/// Run `f` repeatedly: `warmup` unmeasured runs, then timed samples until
/// `min_samples` samples *and* `min_time` total measurement are reached.
pub fn measure<F: FnMut()>(mut f: F, warmup: usize, min_samples: usize,
                           min_time: Duration) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while samples.len() < min_samples || t0.elapsed() < min_time {
        let s = Instant::now();
        f();
        samples.push(s.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort();
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    BenchStats {
        samples: n,
        median: samples[n / 2],
        mean: total / n as u32,
        min: samples[0],
        p95: samples[(n * 95 / 100).min(n - 1)],
    }
}

fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// A named collection of benches with uniform reporting.
pub struct BenchSuite {
    name: String,
    warmup: usize,
    min_samples: usize,
    min_time: Duration,
}

impl BenchSuite {
    pub fn new(name: &str) -> Self {
        println!("==== bench suite: {name} ====");
        BenchSuite {
            name: name.to_string(),
            warmup: 1,
            min_samples: 5,
            min_time: Duration::from_millis(200),
        }
    }

    /// For heavyweight end-to-end benches: fewer samples.
    pub fn heavy(name: &str) -> Self {
        println!("==== bench suite: {name} ====");
        BenchSuite {
            name: name.to_string(),
            warmup: 0,
            min_samples: 3,
            min_time: Duration::from_millis(0),
        }
    }

    pub fn bench<F: FnMut()>(&self, name: &str, f: F) -> BenchStats {
        let stats = measure(f, self.warmup, self.min_samples, self.min_time);
        println!(
            "{}/{:<42} time: [{} .. median {} .. p95 {}]  ({} samples)",
            self.name,
            name,
            fmt_duration(stats.min),
            fmt_duration(stats.median),
            fmt_duration(stats.p95),
            stats.samples
        );
        stats
    }

    /// Bench with a throughput annotation (`items` processed per call).
    pub fn bench_throughput<F: FnMut()>(&self, name: &str, items: f64, f: F)
                                        -> BenchStats {
        let stats = self.bench(name, f);
        let per_s = items / stats.median.as_secs_f64().max(1e-12);
        println!("{}/{:<42} throughput: {per_s:.1} items/s", self.name, name);
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_samples() {
        let mut calls = 0u64;
        let stats = measure(
            || calls += 1,
            2,
            7,
            Duration::from_millis(0),
        );
        assert!(stats.samples >= 7);
        assert!(calls as usize >= stats.samples + 2);
        assert!(stats.min <= stats.median && stats.median <= stats.p95);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(Duration::from_secs(2)).contains('s'));
        assert!(fmt_duration(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_duration(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_duration(Duration::from_nanos(50)).contains("ns"));
    }
}
