//! In-crate replacements for the usual third-party utilities.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the pieces a production crate would
//! pull from crates.io are implemented here, scoped to exactly what this
//! system needs:
//!
//! * [`json`] — a strict, minimal JSON parser for `artifacts/manifest.json`
//! * [`par`] — deterministic scoped-thread parallel map (rayon stand-in)
//! * [`bench`] — a criterion-style timing harness for `cargo bench`

pub mod bench;
pub mod json;
pub mod par;
