//! In-crate replacements for the usual third-party utilities.
//!
//! The workspace's only external dependency is `anyhow`, so the pieces
//! a production crate would pull from crates.io are implemented here,
//! scoped to exactly what this system needs:
//!
//! * [`json`] — a strict, minimal JSON parser + deterministic writer
//!   (`artifacts/manifest.json` in, `BENCH_*.json` result artifacts out)
//! * [`par`] — deterministic scoped-thread parallel map (rayon stand-in)
//!   plus a one-thread-per-item fan-out for the service layer
//! * [`bench`] — a criterion-style timing harness for `cargo bench`
//! * [`hash`] — FNV-1a content-address hashing for the persistent store

pub mod bench;
pub mod hash;
pub mod json;
pub mod par;
