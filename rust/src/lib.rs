//! # KernelBand — hardware-aware multi-armed bandits for LLM kernel optimization
//!
//! Full-system reproduction of *"KernelBand: Steering LLM-based Kernel
//! Optimization via Hardware-Aware Multi-Armed Bandits"* as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: the contextual-bandit search
//!   policy (Algorithm 1: masked UCB over (cluster, strategy) arms,
//!   trace-driven K-means clustering of the kernel frontier, hardware-aware
//!   pruning via profiled saturation masks), the baselines it is evaluated
//!   against, and every substrate the paper depends on — a roofline GPU
//!   simulator standing in for RTX 4090 / H20 / A100, a surrogate code-LLM
//!   standing in for the four commercial backends, and a TritonBench-G-like
//!   workload suite.
//! * **L2/L1 (python/, build-time only)** — JAX graphs and Pallas kernels
//!   AOT-lowered to HLO-text artifacts: the clustering / UCB decision
//!   arithmetic, and the real kernel-variant search space (tiled matmul,
//!   fused epilogues, row-blocked softmax, fused layernorm, flash
//!   attention) that [`engine::PjrtEngine`] measures through PJRT.
//!
//! Python never runs on the request path: `make artifacts` lowers once,
//! and the Rust binary is self-contained afterwards.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`rng`] | splittable deterministic RNG — every stochastic component is keyed |
//! | [`strategy`] | the 6-strategy set `S` and its hardware-resource targets |
//! | [`gpu_model`] | roofline GPU simulator substrate (3 device profiles) |
//! | [`workload`] | TritonBench-G-like suite generator (183 kernels, 13 categories, L1–L5) |
//! | [`kernel`] | candidate-kernel state: config, provenance, measurements |
//! | [`llm`] | surrogate code-LLM substrate (4 model profiles) + cost accounting |
//! | [`profiler`] | hardware signatures h(k), saturation masks, NCU cost model |
//! | [`features`] | behavioral feature vector φ(k) (paper Eq. 4) |
//! | [`cluster`] | K-means over φ(k) (pure-Rust Lloyd; PJRT parity path) |
//! | [`bandit`] | masked UCB arm statistics + within-cluster softmax pick |
//! | [`policy`] | Algorithm 1 driver + all ablation variants |
//! | [`baselines`] | BoN, GEAK-style reflexion agent, torch compile modes |
//! | [`verify`] | two-stage correctness verification |
//! | [`metrics`] | Correct / Fast@1 / geomean (standard & fallback) / strata |
//! | [`engine`] | `EvalEngine` trait: simulated vs PJRT-real measurement |
//! | [`runtime`] | PJRT client wrapper: load + execute `artifacts/*.hlo.txt` |
//! | [`sched`] | batched-measurement scheduling: slot lineages, profiling-bound admission, shared recluster/profile memos |
//! | [`obs`] | advisory telemetry bus: scoped spans, atomic counters, log-linear latency histograms → `METRICS.json` (never the deterministic artifacts) |
//! | [`server`] | serving behind the `JobSpec`/`ServeBackend` API: multi-tenant job queue, in-process worker pool, sharded supervisor with leases / checkpoint crash-recovery / preemption, AIMD adaptive batch width |
//! | [`service`] | modeled optimization service: batched LLM gateway + shared recluster scheduler (Fig. 3; `serve --backend modeled`) |
//! | [`store`] | persistent trace store: content-addressed kernel cache, append-only trace log, per-iteration checkpoint journal, cross-session warm-start |
//! | [`eval`] | experiment harnesses regenerating every paper table/figure; [`eval::ExperimentRunner`] fans the grid out in parallel and emits `BENCH_*.json` artifacts |

pub mod bandit;
pub mod baselines;
pub mod cluster;
pub mod engine;
pub mod eval;
pub mod features;
pub mod gpu_model;
pub mod kernel;
pub mod llm;
pub mod metrics;
pub mod obs;
pub mod policy;
pub mod profiler;
pub mod rng;
pub mod runtime;
pub mod sched;
pub mod server;
pub mod service;
pub mod store;
pub mod strategy;
pub mod util;
pub mod verify;
pub mod workload;

/// Commonly-used items for examples and tests.
pub mod prelude {
    pub use crate::bandit::{ArmStats, MaskedUcb};
    pub use crate::baselines::{BestOfN, Geak};
    pub use crate::engine::{EvalEngine, SimEngine};
    pub use crate::gpu_model::{Device, DeviceProfile, GpuSim};
    pub use crate::kernel::{Candidate, KernelConfig};
    pub use crate::llm::{LlmProfile, SurrogateLlm};
    pub use crate::metrics::TaskOutcome;
    pub use crate::policy::{KernelBand, PolicyConfig};
    pub use crate::rng::Rng;
    pub use crate::strategy::Strategy;
    pub use crate::workload::{Category, Difficulty, Suite, TaskSpec};
}
