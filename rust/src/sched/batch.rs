//! Batch-slot RNG lineages and the profiling-bound admission test.
//!
//! ## Pinned RNG consumption order
//!
//! A batched iteration plans its slots in ascending slot order against
//! the iteration-entry frontier; every stochastic step of slot `b`
//! draws from a stream derived by [`slot_rng`]. Slot 0's streams are
//! *exactly* the pre-batch `(label, t)` lineages, so a batch-1 run is
//! bit-identical to the legacy loop — including every content-address
//! the persistent store derives from the measurement stream, which is
//! why warm stores recorded before batching still hit. Speculative
//! slots (`b ≥ 1`) fold the slot index into the high bits of the split
//! index; iteration counters are far below 2³², so speculative streams
//! can never collide with any legacy `(label, t)` stream.
//!
//! ## Profiling-bound pruning
//!
//! The paper's bounding function B(k, s) (Assumption 1) lower-bounds
//! the latency any child of kernel `k` under strategy `s` can reach:
//! the strategy relieves its target resource, so the child can at best
//! shrink the parent's latency by the factor the target's measured
//! utilization leaves on the table. Speculative slots whose bound
//! cannot beat `prune_factor ×` the current best are dropped *before*
//! the fused measurement — cheap signature arithmetic instead of a
//! full shape sweep. Slot 0 is always admitted (it is the legacy
//! candidate), so pruning can only ever skip work the pre-batch loop
//! never did.

use crate::profiler::HardwareSignature;
use crate::rng::Rng;
use crate::strategy::Strategy;

/// Stream for batch slot `slot` of iteration `t` under `label`.
/// Slot 0 ≡ `root.split(label, t)` — the legacy lineage.
pub fn slot_rng(root: &Rng, label: &str, t: usize, slot: usize) -> Rng {
    root.split(label, ((slot as u64) << 32) | t as u64)
}

/// Floor on the bound ratio: even a perfect transformation cannot
/// shrink latency below 5% of the parent (launch overhead, the other
/// roofline terms). Keeps the bound sane when a counter reads ~0%.
const BOUND_FLOOR: f64 = 0.05;

/// Assumption-1-style optimistic child latency for expanding `parent`
/// (latency `parent_latency_s`, signature `sig`) via `strategy`
/// (`None` = free-form: relief bounded by the dominant bottleneck).
///
/// The target resource currently runs at `h`% of peak; lifting it to
/// 100% shrinks the roofline term it gates by at most `h / 100`, so no
/// child can beat `parent_latency_s · h / 100`. RNG-free and
/// deterministic — admission never shifts any stochastic stream.
pub fn latency_bound(parent_latency_s: f64, sig: &HardwareSignature,
                     strategy: Option<Strategy>) -> f64 {
    let pct = match strategy {
        Some(s) => sig.get(s.target()),
        None => sig.get(sig.bottleneck()),
    };
    parent_latency_s * (pct / 100.0).clamp(BOUND_FLOOR, 1.0)
}

/// Admission test for a speculative slot: can a child of this parent
/// plausibly land inside the promising frontier?
pub fn admit(parent_latency_s: f64, sig: &HardwareSignature,
             strategy: Option<Strategy>, prune_factor: f64,
             best_latency_s: f64) -> bool {
    latency_bound(parent_latency_s, sig, strategy)
        <= prune_factor * best_latency_s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(sm: f64, dram: f64, l2: f64) -> HardwareSignature {
        HardwareSignature { sm_pct: sm, dram_pct: dram, l2_pct: l2 }
    }

    #[test]
    fn slot_zero_is_the_legacy_lineage() {
        let root = Rng::new(7);
        for t in [1usize, 5, 19, 40] {
            let mut legacy = root.split("pick", t as u64);
            let mut slot0 = slot_rng(&root, "pick", t, 0);
            assert_eq!(legacy.fingerprint(), slot0.fingerprint());
            assert_eq!(legacy.next_u64(), slot0.next_u64());
        }
    }

    #[test]
    fn speculative_slots_get_distinct_streams() {
        let root = Rng::new(7);
        let mut fps = std::collections::HashSet::new();
        for t in 1..=40usize {
            for b in 0..4usize {
                assert!(fps.insert(slot_rng(&root, "gen", t, b).fingerprint()));
            }
        }
        // and they never collide with legacy (label, t) streams of other
        // iterations within any realistic horizon
        for t in 1..=10_000u64 {
            assert!(!fps.contains(&root.split("gen", t).fingerprint())
                    || t <= 40);
        }
    }

    #[test]
    fn bound_scales_with_target_utilization() {
        // DRAM at 40%: a Vectorization child can reach at best 0.4×
        let s = sig(70.0, 40.0, 20.0);
        let b = latency_bound(1.0, &s, Some(Strategy::Vectorization));
        assert!((b - 0.40).abs() < 1e-12);
        // SM-gated strategy reads the SM counter
        let b2 = latency_bound(1.0, &s, Some(Strategy::Tiling));
        assert!((b2 - 0.70).abs() < 1e-12);
        // free-form: dominant bottleneck (SM at 70%)
        let b3 = latency_bound(1.0, &s, None);
        assert!((b3 - 0.70).abs() < 1e-12);
    }

    #[test]
    fn bound_is_floored_and_capped() {
        let s = sig(0.0, 150.0, 0.0);
        assert_eq!(latency_bound(2.0, &s, Some(Strategy::Tiling)),
                   2.0 * 0.05);
        assert_eq!(latency_bound(2.0, &s, Some(Strategy::Fusion)), 2.0);
    }

    #[test]
    fn admission_compares_against_pruned_frontier() {
        let s = sig(90.0, 10.0, 10.0);
        // parent 1.0s, SM at 90% → bound 0.9; best 0.5, factor 1.5 →
        // 0.9 <= 0.75 is false → pruned
        assert!(!admit(1.0, &s, Some(Strategy::Tiling), 1.5, 0.5));
        // DRAM at 10% → bound 0.1 <= 0.75 → admitted
        assert!(admit(1.0, &s, Some(Strategy::Vectorization), 1.5, 0.5));
    }
}
