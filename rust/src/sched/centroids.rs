//! Shared re-clustering memo: converged centroids reused between jobs
//! with matching fingerprints.
//!
//! Re-clustering is the last super-O(members) step in the bandit hot
//! loop. When a service run drives many jobs over the same kernels —
//! the production shape: thousands of users resubmitting the same hot
//! operators — every job recomputes an identical Lloyd run. This cache
//! memoizes [`Clustering`] results across jobs.
//!
//! ## Soundness / interleaving-invariance
//!
//! The memo key ([`seeded_key`] / [`cold_key`]) hashes **everything
//! that determines Lloyd's output bit for bit**: the full φ cloud (raw
//! f64 bits of every point), the iteration budget, and the
//! initialization (seed-centroid bits for the warm path, the k-means++
//! RNG lineage fingerprint for the cold path). Two requests can
//! therefore only share an entry when a from-scratch computation would
//! have produced the *exact same* `Clustering` — a pure memo. That is
//! what makes the cache safe to share across concurrently-scheduled
//! jobs: no job's results can depend on which job computed an entry
//! first, so scheduler interleaving never changes any job's
//! `BENCH_*.json` bytes (property-tested in `rust/tests/prop_sched.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::cluster::Clustering;
use crate::features::{Phi, PHI_DIM};
use crate::util::hash::KeyHasher;

fn fold_phis(mut h: KeyHasher, phis: &[Phi]) -> KeyHasher {
    h = h.u64(phis.len() as u64);
    for p in phis {
        for j in 0..PHI_DIM {
            h = h.f64(p[j]);
        }
    }
    h
}

/// Memo key for a *seeded* re-clustering (`cluster_seeded`): φ cloud +
/// Lloyd budget + the seed centroids' bits.
pub fn seeded_key(phis: &[Phi], seeds: &[Phi], iters: usize) -> u64 {
    let h = KeyHasher::new("recluster-seeded").u64(iters as u64);
    fold_phis(fold_phis(h, phis), seeds).finish()
}

/// Memo key for a *cold* re-clustering (k-means++): φ cloud + Lloyd
/// budget + K + the seeding RNG's lineage fingerprint (the stream fully
/// determines the k-means++ draws).
pub fn cold_key(phis: &[Phi], k: usize, iters: usize, rng_fp: u64) -> u64 {
    let h = KeyHasher::new("recluster-cold")
        .u64(iters as u64)
        .u64(k as u64)
        .u64(rng_fp);
    fold_phis(h, phis).finish()
}

/// Thread-safe `key → Clustering` memo with hit/miss accounting.
#[derive(Debug, Default)]
pub struct CentroidCache {
    map: Mutex<HashMap<u64, Clustering>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CentroidCache {
    pub fn new() -> CentroidCache {
        CentroidCache::default()
    }

    pub fn get(&self, key: u64) -> Option<Clustering> {
        let found = self.map.lock().unwrap().get(&key).cloned();
        match &found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    pub fn insert(&self, key: u64, c: &Clustering) {
        self.map.lock().unwrap().entry(key).or_insert_with(|| c.clone());
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterBackend, RustKmeans};
    use crate::rng::Rng;

    fn cloud(n: usize, seed: u64) -> Vec<Phi> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut p = [0.0; PHI_DIM];
                for v in p.iter_mut() {
                    *v = rng.uniform();
                }
                p
            })
            .collect()
    }

    #[test]
    fn keys_pin_every_determining_input() {
        let phis = cloud(12, 1);
        let seeds = cloud(3, 2);
        let k = seeded_key(&phis, &seeds, 8);
        assert_eq!(k, seeded_key(&phis, &seeds, 8));
        assert_ne!(k, seeded_key(&phis, &seeds, 9));
        assert_ne!(k, seeded_key(&phis, &cloud(3, 3), 8));
        let mut moved = phis.clone();
        moved[5][0] += 1e-12;
        assert_ne!(k, seeded_key(&moved, &seeds, 8));

        let c = cold_key(&phis, 3, 8, 0xdead);
        assert_ne!(c, cold_key(&phis, 2, 8, 0xdead));
        assert_ne!(c, cold_key(&phis, 3, 8, 0xbeef));
        // seeded and cold domains never collide
        assert_ne!(c, seeded_key(&phis, &seeds, 8));
    }

    #[test]
    fn memo_returns_bit_identical_clustering() {
        let phis = cloud(20, 4);
        let km = RustKmeans::default();
        let computed = km.cluster(&phis, 3, &mut Rng::new(9).split("cl", 0));
        let key = cold_key(&phis, 3, km.iters,
                           Rng::new(9).split("cl", 0).fingerprint());
        let cache = CentroidCache::new();
        assert!(cache.get(key).is_none());
        cache.insert(key, &computed);
        let back = cache.get(key).unwrap();
        assert_eq!(back.assign, computed.assign);
        assert_eq!(back.centroids, computed.centroids);
        assert_eq!(back.representatives, computed.representatives);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn first_insert_wins_for_identical_keys() {
        // pure-memo contract: identical keys carry identical values, so
        // or_insert keeping the first is observationally neutral
        let phis = cloud(10, 5);
        let km = RustKmeans::default();
        let a = km.cluster_seeded(&phis, &phis[..2]);
        let key = seeded_key(&phis, &phis[..2], km.iters);
        let cache = CentroidCache::new();
        cache.insert(key, &a);
        cache.insert(key, &a);
        assert_eq!(cache.len(), 1);
    }
}
