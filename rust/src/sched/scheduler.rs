//! Service-side shared re-clustering scheduler.
//!
//! `serve` fans jobs out, but before this module each job ran its
//! bandit loop fully independently — including re-clustering, the only
//! remaining super-O(members) step. The [`ReclusterScheduler`] gives
//! the whole service one worker that *interleaves* that step across
//! jobs:
//!
//! * concurrent recluster requests coalesce into **rounds** (window- or
//!   size-triggered, like the LLM gateway's batching);
//! * within a round, each distinct task fingerprint is re-clustered
//!   **once** — jobs refining the same kernel share the work;
//! * a fingerprint seen in any earlier round resumes **warm** (Lloyd
//!   from cached converged centroids: the modeled cheap early-exit
//!   path) instead of paying a cold k-means++ run.
//!
//! Like the rest of [`crate::service`], latencies here are *modeled*
//! (scaled by [`TIME_SCALE`]): the scheduler measures the pipeline's
//! shape — coalescing, dedup, warm reuse — not real Lloyd time. The
//! real-math counterpart is [`crate::sched::centroids::CentroidCache`],
//! whose pure-memo keying is what makes cross-job sharing safe; this
//! worker models the wall-clock the sharing saves. Shutdown is
//! drain-and-error: queued and newly-arriving requests complete with
//! [`SchedulerClosed`] instead of hanging their submitters.

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::service::{scaled_sleep, TIME_SCALE};

/// Scheduler knobs (modeled seconds).
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Cold re-clustering: k-means++ seeding + a full Lloyd run.
    pub cold_recluster_s: f64,
    /// Warm resume from cached centroids (early-exit after a step or
    /// two).
    pub warm_recluster_s: f64,
    /// Max requests coalesced into one round.
    pub max_round: usize,
    /// Round window (modeled seconds): a partial round is flushed
    /// after this long.
    pub window_s: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            cold_recluster_s: 18.0,
            warm_recluster_s: 2.5,
            max_round: 64,
            window_s: 2.0,
        }
    }
}

/// Error returned when the scheduler shuts down before a request is
/// served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulerClosed;

impl std::fmt::Display for SchedulerClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("recluster scheduler shut down before the request \
                     completed")
    }
}

/// What a served request learns about its round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReclusterGrant {
    /// This fingerprint's centroids were already cached (warm resume).
    pub warm: bool,
    /// Requests coalesced into the round that served this one.
    pub round_size: usize,
}

/// Scheduler runtime statistics.
#[derive(Debug, Default)]
pub struct SchedulerStats {
    pub requests: AtomicU64,
    pub rounds: AtomicU64,
    /// Requests whose fingerprint resumed from warm centroids.
    pub warm_hits: AtomicU64,
    /// Requests that shared a round-mate's identical re-clustering.
    pub dedup_shares: AtomicU64,
    pub max_round_seen: AtomicU64,
    /// Modeled microseconds saved vs every request paying a solo cold
    /// re-clustering (micro units so a plain atomic suffices).
    pub saved_model_us: AtomicU64,
}

struct Pending {
    fingerprint: u64,
    done: Arc<(Mutex<Option<Result<ReclusterGrant, SchedulerClosed>>>,
               Condvar)>,
}

struct Shared {
    queue: Mutex<VecDeque<Pending>>,
    ingress: Condvar,
    shutdown: AtomicBool,
    config: SchedulerConfig,
    stats: SchedulerStats,
    /// Fingerprints whose converged centroids are cached.
    warm: Mutex<HashSet<u64>>,
}

/// The shared scheduler (one worker OS thread).
pub struct ReclusterScheduler {
    shared: Arc<Shared>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ReclusterScheduler {
    pub fn spawn(config: SchedulerConfig) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            ingress: Condvar::new(),
            shutdown: AtomicBool::new(false),
            config,
            stats: SchedulerStats::default(),
            warm: Mutex::new(HashSet::new()),
        });
        let s = shared.clone();
        let worker = std::thread::spawn(move || Self::worker_loop(&s));
        ReclusterScheduler { shared, worker: Mutex::new(Some(worker)) }
    }

    fn drain_and_error(s: &Shared) {
        let drained: Vec<Pending> =
            s.queue.lock().unwrap().drain(..).collect();
        for p in drained {
            let (slot, cv) = &*p.done;
            *slot.lock().unwrap() = Some(Err(SchedulerClosed));
            cv.notify_one();
        }
        s.ingress.notify_all();
    }

    fn worker_loop(s: &Shared) {
        loop {
            // wait for the head of the next round
            let mut q = s.queue.lock().unwrap();
            while q.is_empty() {
                if s.shutdown.load(Ordering::Acquire) {
                    drop(q);
                    Self::drain_and_error(s);
                    return;
                }
                let (guard, _timeout) = s
                    .ingress
                    .wait_timeout(q, Duration::from_millis(5))
                    .unwrap();
                q = guard;
            }
            drop(q);
            // window: let the round fill (shutdown mid-window drains)
            let window =
                Duration::from_secs_f64(s.config.window_s * TIME_SCALE);
            let deadline = Instant::now() + window;
            loop {
                if s.shutdown.load(Ordering::Acquire) {
                    Self::drain_and_error(s);
                    return;
                }
                let filled =
                    s.queue.lock().unwrap().len() >= s.config.max_round;
                if filled || Instant::now() >= deadline {
                    break;
                }
                std::thread::sleep(Duration::from_micros(200));
            }
            // take the round
            let mut round = Vec::new();
            {
                let mut q = s.queue.lock().unwrap();
                while round.len() < s.config.max_round {
                    match q.pop_front() {
                        Some(p) => round.push(p),
                        None => break,
                    }
                }
            }
            s.ingress.notify_all();
            if round.is_empty() {
                continue;
            }
            // interleave: one pass over the round, paying each distinct
            // fingerprint once (warm when its centroids were already
            // cached *at round start* — round-mates of a first-time
            // fingerprint are dedup shares, not warm resumes)
            let mut grants: Vec<bool> = Vec::with_capacity(round.len());
            let mut seen_in_round: HashSet<u64> = HashSet::new();
            let mut cost_s = 0.0;
            let mut warm_hits = 0u64;
            let mut dedup = 0u64;
            {
                let mut warm = s.warm.lock().unwrap();
                for p in &round {
                    // classified against the round-start cache state;
                    // insertions happen after the pass
                    let was_warm = warm.contains(&p.fingerprint);
                    if was_warm {
                        warm_hits += 1;
                    }
                    if seen_in_round.insert(p.fingerprint) {
                        cost_s += if was_warm {
                            s.config.warm_recluster_s
                        } else {
                            s.config.cold_recluster_s
                        };
                    } else {
                        dedup += 1;
                    }
                    grants.push(was_warm);
                }
                warm.extend(seen_in_round.iter().copied());
            }
            scaled_sleep(cost_s);
            let n = round.len() as u64;
            let st = &s.stats;
            st.requests.fetch_add(n, Ordering::Relaxed);
            st.rounds.fetch_add(1, Ordering::Relaxed);
            st.warm_hits.fetch_add(warm_hits, Ordering::Relaxed);
            st.dedup_shares.fetch_add(dedup, Ordering::Relaxed);
            st.max_round_seen.fetch_max(n, Ordering::Relaxed);
            let solo_cost = n as f64 * s.config.cold_recluster_s;
            let saved_us = ((solo_cost - cost_s) * 1e6).max(0.0) as u64;
            st.saved_model_us.fetch_add(saved_us, Ordering::Relaxed);
            let round_size = round.len();
            for (p, warm) in round.into_iter().zip(grants) {
                let (slot, cv) = &*p.done;
                *slot.lock().unwrap() =
                    Some(Ok(ReclusterGrant { warm, round_size }));
                cv.notify_one();
            }
        }
    }

    /// Submit a recluster request for `fingerprint` and block until
    /// the round that serves it completes. Never blocks across
    /// shutdown.
    pub fn recluster(&self, fingerprint: u64)
                     -> Result<ReclusterGrant, SchedulerClosed> {
        let done = Arc::new((Mutex::new(None), Condvar::new()));
        {
            let mut q = self.shared.queue.lock().unwrap();
            // checked under the queue lock: serialized against the
            // worker's final drain (see `drain_and_error`)
            if self.shared.shutdown.load(Ordering::Acquire) {
                return Err(SchedulerClosed);
            }
            q.push_back(Pending { fingerprint, done: done.clone() });
        }
        self.shared.ingress.notify_all();
        let (slot, cv) = &*done;
        let mut guard = slot.lock().unwrap();
        while guard.is_none() {
            guard = cv.wait(guard).unwrap();
        }
        guard.take().unwrap()
    }

    /// Initiate shutdown and join the worker. Idempotent; called by
    /// `Drop`.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.ingress.notify_all();
        let handle = self.worker.lock().unwrap().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
        Self::drain_and_error(&self.shared);
    }

    pub fn stats(&self) -> &SchedulerStats {
        &self.shared.stats
    }

    pub fn requests(&self) -> u64 {
        self.shared.stats.requests.load(Ordering::Relaxed)
    }

    pub fn rounds(&self) -> u64 {
        self.shared.stats.rounds.load(Ordering::Relaxed)
    }

    pub fn warm_hits(&self) -> u64 {
        self.shared.stats.warm_hits.load(Ordering::Relaxed)
    }

    pub fn dedup_shares(&self) -> u64 {
        self.shared.stats.dedup_shares.load(Ordering::Relaxed)
    }

    pub fn max_round_seen(&self) -> u64 {
        self.shared.stats.max_round_seen.load(Ordering::Relaxed)
    }

    pub fn saved_model_s(&self) -> f64 {
        self.shared.stats.saved_model_us.load(Ordering::Relaxed) as f64
            * 1e-6
    }
}

impl Drop for ReclusterScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            cold_recluster_s: 10.0,
            warm_recluster_s: 1.0,
            max_round: 32,
            window_s: 5.0,
        }
    }

    #[test]
    fn round_dedups_matching_fingerprints() {
        let sched = Arc::new(ReclusterScheduler::spawn(cfg()));
        // 8 jobs, only 2 distinct task fingerprints, submitted together
        let grants: Vec<ReclusterGrant> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let s = sched.clone();
                    scope.spawn(move || s.recluster(100 + (i % 2)).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(grants.len(), 8);
        assert_eq!(sched.requests(), 8);
        // coalescing should need very few rounds; the first round pays
        // at most 2 cold reclusters for 8 requests
        assert!(sched.rounds() <= 4, "rounds = {}", sched.rounds());
        // with 2 distinct fingerprints only 2 requests ever pay cold:
        // every other request is a round-share or a warm resume
        assert!(sched.warm_hits() + sched.dedup_shares() >= 6,
                "warm = {} dedup = {}",
                sched.warm_hits(), sched.dedup_shares());
        assert!(sched.saved_model_s() > 0.0);
    }

    #[test]
    fn repeated_fingerprint_resumes_warm() {
        let sched = ReclusterScheduler::spawn(cfg());
        let first = sched.recluster(42).unwrap();
        assert!(!first.warm);
        let second = sched.recluster(42).unwrap();
        assert!(second.warm);
        let other = sched.recluster(43).unwrap();
        assert!(!other.warm);
        assert_eq!(sched.warm_hits(), 1);
    }

    #[test]
    fn shutdown_errors_pending_and_new_requests() {
        let slow = SchedulerConfig {
            // enormous window: nothing completes on its own
            window_s: 1e6,
            cold_recluster_s: 1e6,
            ..cfg()
        };
        let sched = Arc::new(ReclusterScheduler::spawn(slow));
        let s2 = sched.clone();
        let submitter = std::thread::spawn(move || s2.recluster(1));
        std::thread::sleep(Duration::from_millis(20));
        sched.shutdown();
        assert_eq!(submitter.join().unwrap(), Err(SchedulerClosed));
        assert_eq!(sched.recluster(2), Err(SchedulerClosed));
    }
}
