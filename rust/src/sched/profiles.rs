//! Shared NCU-signature cache — the profiler-cache ↔ store bridge.
//!
//! [`crate::profiler::Profiler`] memoizes representative signatures per
//! *run*; this cache makes those memos durable. The trace store loads
//! `profiles.jsonl` into a [`SharedProfiles`] at open and appends the
//! new entries at persist, so a warm session replays representative
//! profiling as pure lookups: zero recomputation, zero simulated NCU
//! cost (`Trace::profile_runs == 0` — asserted in
//! `rust/tests/prop_sched.rs`).
//!
//! ## Keying — why the *run* fingerprint is part of the address
//!
//! Within a run, `Profiler` returns the **first** signature profiled
//! for a code hash and serves every later request for that hash from
//! cache. Which measurement happens to be "first" is a deterministic
//! function of the whole run lineage (seed, method, task, device, LLM,
//! policy knobs, batch width) — but *not* of the code hash alone: two
//! different runs can first-profile the same schedule from different
//! measurements. A cache keyed only by `(device, code_hash)` would
//! therefore serve whichever run inserted first — making results
//! depend on scheduling order. Folding the run fingerprint into the
//! key ([`profile_key`]) restores the pure-memo property: an entry is
//! only ever read by a bit-identical replay of the run that wrote it,
//! which is exactly the warm-session scenario this cache exists for.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::profiler::HardwareSignature;
use crate::util::hash::KeyHasher;

/// Content address of one persisted representative signature.
pub fn profile_key(run_fp: u64, code_hash: u64) -> u64 {
    KeyHasher::new("profile").u64(run_fp).u64(code_hash).finish()
}

/// Thread-safe signature cache with append-only persistence
/// bookkeeping (mirrors [`crate::store::cache::ContentCache`]).
#[derive(Debug, Default)]
pub struct SharedProfiles {
    map: Mutex<HashMap<u64, HardwareSignature>>,
    dirty: Mutex<Vec<u64>>,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

impl SharedProfiles {
    pub fn new() -> SharedProfiles {
        SharedProfiles::default()
    }

    pub fn get(&self, key: u64) -> Option<HardwareSignature> {
        let found = self.map.lock().unwrap().get(&key).copied();
        match &found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    pub fn insert(&self, key: u64, sig: HardwareSignature) {
        let mut map = self.map.lock().unwrap();
        if let std::collections::hash_map::Entry::Vacant(e) = map.entry(key)
        {
            e.insert(sig);
            self.dirty.lock().unwrap().push(key);
        }
    }

    /// Insert at load time (not marked dirty).
    pub fn insert_loaded(&self, key: u64, sig: HardwareSignature) {
        self.map.lock().unwrap().insert(key, sig);
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Re-mark keys dirty after a failed append (the map retains the
    /// signatures; the next persist retries the same records).
    pub fn restore_dirty(&self, keys: impl IntoIterator<Item = u64>) {
        self.dirty.lock().unwrap().extend(keys);
    }

    /// Drain new entries sorted by key (deterministic append bytes
    /// regardless of worker scheduling).
    pub fn take_dirty(&self) -> Vec<(u64, HardwareSignature)> {
        let mut keys = std::mem::take(&mut *self.dirty.lock().unwrap());
        keys.sort_unstable();
        keys.dedup();
        let map = self.map.lock().unwrap();
        keys.into_iter()
            .filter_map(|k| map.get(&k).map(|s| (k, *s)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(x: f64) -> HardwareSignature {
        HardwareSignature { sm_pct: x, dram_pct: 2.0 * x, l2_pct: 3.0 * x }
    }

    #[test]
    fn keys_separate_runs_and_kernels() {
        let k = profile_key(1, 100);
        assert_eq!(k, profile_key(1, 100));
        assert_ne!(k, profile_key(2, 100));
        assert_ne!(k, profile_key(1, 101));
    }

    #[test]
    fn get_insert_counts_hits_and_misses() {
        let sp = SharedProfiles::new();
        assert!(sp.get(7).is_none());
        sp.insert(7, sig(10.0));
        assert_eq!(sp.get(7), Some(sig(10.0)));
        assert_eq!(sp.hits.load(Ordering::Relaxed), 1);
        assert_eq!(sp.misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn dirty_tracking_is_sorted_and_excludes_loaded() {
        let sp = SharedProfiles::new();
        sp.insert(9, sig(1.0));
        sp.insert(3, sig(2.0));
        sp.insert(9, sig(5.0)); // duplicate key: not re-marked dirty
        sp.insert_loaded(1, sig(3.0));
        let dirty = sp.take_dirty();
        assert_eq!(dirty.iter().map(|&(k, _)| k).collect::<Vec<_>>(),
                   vec![3, 9]);
        assert!(sp.take_dirty().is_empty());
        assert_eq!(sp.len(), 3);
        // duplicate insert kept the first value (pure-memo contract:
        // identical keys always carry identical values in practice)
        assert_eq!(sp.get(9), Some(sig(1.0)));
    }
}
