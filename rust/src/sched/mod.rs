//! Batched-measurement scheduling: shared state and helpers behind the
//! batch-per-iteration policy loop and the service-side scheduler.
//!
//! KernelBand's hot loop historically measured exactly one accepted
//! candidate per iteration. The paper's hardware-aware pruning only pays
//! off when many proposals are scored cheaply against profiling bounds
//! *before* the expensive measurement step — the batched-evaluation
//! shape this module provides:
//!
//! * [`batch`] — slot RNG-lineage derivation (slot 0 is bit-identical
//!   to the pre-batch stream layout, so `--batch 1` reproduces the
//!   legacy path byte for byte) and the Assumption-1-style latency
//!   bound that admits or prunes speculative slots before measurement;
//! * [`centroids`] — a sound cross-job re-clustering memo: keys hash
//!   everything that determines Lloyd's output bit for bit, so two jobs
//!   with matching fingerprints share converged centroids without any
//!   run's results depending on which job computed them first;
//! * [`profiles`] — the shared NCU-signature cache the trace store
//!   persists (`profiles.jsonl`), letting a warm session skip
//!   representative-profiling recomputation entirely;
//! * [`scheduler`] — the service-side [`scheduler::ReclusterScheduler`]:
//!   one worker interleaves the remaining super-O(members) step
//!   (re-clustering) across concurrent jobs, paying each distinct task
//!   fingerprint once per round and resuming warm for fingerprints seen
//!   before.
//!
//! ## Determinism contract
//!
//! Everything here is either RNG-free or a pure memo whose key pins the
//! value bit-exactly, so attaching a [`SchedContext`] (any batch size,
//! any shared caches, any thread count or job interleaving) never
//! changes what a given `(seed, method, task, device, llm)` run
//! computes — only how much work it repeats. `BENCH_*.json` byte
//! identity for any `--threads`/`--batch 1`/cold/warm combination is
//! asserted in `rust/tests/prop_sched.rs` and the CI smoke.

pub mod adaptive;
pub mod batch;
pub mod centroids;
pub mod profiles;
pub mod scheduler;

use std::sync::Arc;

use self::centroids::CentroidCache;
use self::profiles::SharedProfiles;

/// How the per-iteration candidate batch is sized.
///
/// `Fixed(n)` is the static width `--batch N` always had (0 and 1 both
/// mean the legacy single-candidate loop). `Adaptive { min, max }` is
/// `--batch auto`: an AIMD controller
/// ([`adaptive::AimdController`]) widens the speculation batch while
/// speculative slots keep turning into measured candidates and shrinks
/// it when most are wasted (pruned by the Assumption-1 bound or failed
/// verification). The controller's input is the previous iteration's
/// pinned slot-order outcome counts — per-job deterministic state,
/// never wall-clock — so the width sequence is a pure function of
/// (task, seed, bound/verdict outcomes) and artifacts stay
/// byte-identical for any `--threads N` and cold/warm store.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// Static per-iteration width (the pre-adaptive behavior).
    Fixed(usize),
    /// AIMD-controlled width in `[min, max]`, starting at `min`.
    Adaptive { min: usize, max: usize },
}

impl Default for BatchMode {
    fn default() -> Self {
        BatchMode::Fixed(1)
    }
}

impl BatchMode {
    /// Width of the first iteration (`Fixed(0)` normalizes to 1, like
    /// the legacy `--batch 0`).
    pub fn initial_width(self) -> usize {
        match self {
            BatchMode::Fixed(n) => n.max(1),
            BatchMode::Adaptive { min, .. } => min.max(1),
        }
    }

    /// Largest width this mode can ever plan.
    pub fn max_width(self) -> usize {
        match self {
            BatchMode::Fixed(n) => n.max(1),
            BatchMode::Adaptive { min, max } => max.max(min).max(1),
        }
    }

    /// Render for ledgers/artifacts ("3" / "auto(1..8)").
    pub fn label(self) -> String {
        match self {
            BatchMode::Fixed(n) => format!("{}", n.max(1)),
            BatchMode::Adaptive { min, max } => {
                format!("auto({}..{})", min.max(1), max.max(min).max(1))
            }
        }
    }
}

/// Per-run scheduling context handed to
/// [`crate::policy::KernelBand::optimize_sched`]. The default context
/// (`Fixed(1)`, no shared caches) reproduces the pre-batch behavior
/// bit for bit.
#[derive(Debug, Clone, Default)]
pub struct SchedContext {
    /// Per-iteration candidate batch sizing (see [`BatchMode`]).
    pub mode: BatchMode,
    /// Shared re-clustering memo (session-scoped, in-memory).
    pub centroids: Option<Arc<CentroidCache>>,
    /// Shared NCU-signature cache (persisted by the trace store).
    pub profiles: Option<Arc<SharedProfiles>>,
    /// Advisory telemetry bus. Strictly observational: the policy loop
    /// resolves counter/histogram handles from it but its presence
    /// never alters RNG streams, scheduling, or any deterministic
    /// artifact (asserted in `rust/tests/obs.rs`).
    pub obs: Option<Arc<crate::obs::Recorder>>,
    /// Causal-trace anchor for this job: where the policy loop's
    /// iteration spans and decision-ledger rows attach. Advisory like
    /// `obs`; `None` outside `--obs trace`/`events` executions.
    pub job: Option<JobObs>,
}

/// Per-job observation anchor: the span the policy's iteration spans
/// parent under, the Perfetto track (sequential lane) they render on,
/// and a human-readable label for decision-ledger rows.
#[derive(Debug, Clone)]
pub struct JobObs {
    /// Parent span id (0 = root) in the recorder's [`crate::obs::TraceSink`].
    pub span: u64,
    /// Perfetto track (`tid`) of this job's sequential lane.
    pub track: u64,
    /// Job label (e.g. `"r2/j5 task-name"`) stamped on ledger rows.
    pub label: Arc<str>,
}

impl SchedContext {
    pub fn with_batch(batch: usize) -> SchedContext {
        SchedContext {
            mode: BatchMode::Fixed(batch),
            ..SchedContext::default()
        }
    }

    pub fn with_mode(mode: BatchMode) -> SchedContext {
        SchedContext { mode, ..SchedContext::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_context_is_legacy_single_candidate() {
        let ctx = SchedContext::default();
        assert_eq!(ctx.mode, BatchMode::Fixed(1));
        assert_eq!(ctx.mode.initial_width(), 1);
        assert!(ctx.centroids.is_none());
        assert!(ctx.profiles.is_none());
        assert_eq!(SchedContext::with_batch(0).mode.initial_width(), 1);
        assert_eq!(SchedContext::with_batch(4).mode.initial_width(), 4);
    }

    #[test]
    fn batch_mode_widths_and_labels() {
        assert_eq!(BatchMode::Fixed(0).initial_width(), 1);
        assert_eq!(BatchMode::Fixed(0).max_width(), 1);
        assert_eq!(BatchMode::Fixed(3).initial_width(), 3);
        assert_eq!(BatchMode::Fixed(3).max_width(), 3);
        let auto = BatchMode::Adaptive { min: 1, max: 8 };
        assert_eq!(auto.initial_width(), 1);
        assert_eq!(auto.max_width(), 8);
        assert_eq!(auto.label(), "auto(1..8)");
        assert_eq!(BatchMode::Fixed(3).label(), "3");
        // degenerate bounds normalize instead of panicking
        let degen = BatchMode::Adaptive { min: 4, max: 2 };
        assert_eq!(degen.initial_width(), 4);
        assert_eq!(degen.max_width(), 4);
        let ctx = SchedContext::with_mode(auto);
        assert_eq!(ctx.mode, auto);
    }
}
