//! Batched-measurement scheduling: shared state and helpers behind the
//! batch-per-iteration policy loop and the service-side scheduler.
//!
//! KernelBand's hot loop historically measured exactly one accepted
//! candidate per iteration. The paper's hardware-aware pruning only pays
//! off when many proposals are scored cheaply against profiling bounds
//! *before* the expensive measurement step — the batched-evaluation
//! shape this module provides:
//!
//! * [`batch`] — slot RNG-lineage derivation (slot 0 is bit-identical
//!   to the pre-batch stream layout, so `--batch 1` reproduces the
//!   legacy path byte for byte) and the Assumption-1-style latency
//!   bound that admits or prunes speculative slots before measurement;
//! * [`centroids`] — a sound cross-job re-clustering memo: keys hash
//!   everything that determines Lloyd's output bit for bit, so two jobs
//!   with matching fingerprints share converged centroids without any
//!   run's results depending on which job computed them first;
//! * [`profiles`] — the shared NCU-signature cache the trace store
//!   persists (`profiles.jsonl`), letting a warm session skip
//!   representative-profiling recomputation entirely;
//! * [`scheduler`] — the service-side [`scheduler::ReclusterScheduler`]:
//!   one worker interleaves the remaining super-O(members) step
//!   (re-clustering) across concurrent jobs, paying each distinct task
//!   fingerprint once per round and resuming warm for fingerprints seen
//!   before.
//!
//! ## Determinism contract
//!
//! Everything here is either RNG-free or a pure memo whose key pins the
//! value bit-exactly, so attaching a [`SchedContext`] (any batch size,
//! any shared caches, any thread count or job interleaving) never
//! changes what a given `(seed, method, task, device, llm)` run
//! computes — only how much work it repeats. `BENCH_*.json` byte
//! identity for any `--threads`/`--batch 1`/cold/warm combination is
//! asserted in `rust/tests/prop_sched.rs` and the CI smoke.

pub mod batch;
pub mod centroids;
pub mod profiles;
pub mod scheduler;

use std::sync::Arc;

use self::centroids::CentroidCache;
use self::profiles::SharedProfiles;

/// Per-run scheduling context handed to
/// [`crate::policy::KernelBand::optimize_sched`]. The default context
/// (`batch = 1`, no shared caches) reproduces the pre-batch behavior
/// bit for bit.
#[derive(Debug, Clone, Default)]
pub struct SchedContext {
    /// Candidates proposed per iteration (0 and 1 both mean the legacy
    /// single-candidate loop).
    pub batch: usize,
    /// Shared re-clustering memo (session-scoped, in-memory).
    pub centroids: Option<Arc<CentroidCache>>,
    /// Shared NCU-signature cache (persisted by the trace store).
    pub profiles: Option<Arc<SharedProfiles>>,
}

impl SchedContext {
    pub fn with_batch(batch: usize) -> SchedContext {
        SchedContext { batch, ..SchedContext::default() }
    }

    /// Effective batch width (≥ 1).
    pub fn batch_width(&self) -> usize {
        self.batch.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_context_is_legacy_single_candidate() {
        let ctx = SchedContext::default();
        assert_eq!(ctx.batch_width(), 1);
        assert!(ctx.centroids.is_none());
        assert!(ctx.profiles.is_none());
        assert_eq!(SchedContext::with_batch(0).batch_width(), 1);
        assert_eq!(SchedContext::with_batch(4).batch_width(), 4);
    }
}
