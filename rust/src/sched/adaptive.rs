//! AIMD adaptive batch-width controller (`--batch auto`).
//!
//! The Assumption-1 admission bound ([`crate::sched::batch::admit`])
//! emits a live signal the static `--batch N` ignores: how much of the
//! planned speculation actually turned into measured candidates. This
//! controller turns that signal into a per-iteration width, TCP-style:
//!
//! * **additive increase** — every speculative slot paid off (became a
//!   measured candidate): the hardware headroom estimates say
//!   speculation is working, widen by 1 up to `max`;
//! * **multiplicative decrease** — most speculative slots were wasted
//!   (pruned by the bound *or* failed generation/verification — both
//!   burn a proposal with nothing measured): halve down to `min`;
//! * **hold** — partial waste: stay.
//!
//! Counting verification failures as waste matters: a
//! generation-failure-heavy regime must shrink the batch (each slot
//! still pays full proposal cost), not ratchet to `max` because the
//! failures never even reached the bound.
//!
//! At width 1 there are no speculative slots to observe, so the
//! controller probes upward — otherwise `Adaptive { min: 1, .. }`
//! could never leave the legacy single-candidate loop.
//!
//! ## Determinism contract
//!
//! The controller's entire state is `(min, max, width)` and its only
//! input is the previous iteration's `(speculative, wasted)` pair,
//! which the policy computes in pinned slot order from the verdicts
//! and the profiling bound — deterministic per (task, seed, warm
//! state), never wall-clock, thread count, or store temperature. The
//! width sequence is therefore a pure function of the run spec, which
//! is what keeps `--batch auto` artifacts byte-identical across
//! `--threads 1/4/8` and cold/warm store (locked in
//! `rust/tests/prop_sched.rs`). `Fixed(n)` collapses
//! `min == max == n`, making `observe` a no-op — bit-identical to the
//! pre-adaptive static batch.

use crate::sched::BatchMode;

/// Deterministic AIMD width controller. One instance per optimization
/// run; the policy reads [`AimdController::width`] at the top of every
/// iteration and feeds the iteration's outcomes back through
/// [`AimdController::observe`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AimdController {
    min: usize,
    max: usize,
    width: usize,
}

impl AimdController {
    /// A constant-width controller (`observe` never moves it).
    pub fn fixed(n: usize) -> AimdController {
        let n = n.max(1);
        AimdController { min: n, max: n, width: n }
    }

    /// An adaptive controller starting at `min` (degenerate bounds
    /// normalize: `min ≥ 1`, `max ≥ min`).
    pub fn adaptive(min: usize, max: usize) -> AimdController {
        let min = min.max(1);
        let max = max.max(min);
        AimdController { min, max, width: min }
    }

    pub fn from_mode(mode: BatchMode) -> AimdController {
        match mode {
            BatchMode::Fixed(n) => AimdController::fixed(n),
            BatchMode::Adaptive { min, max } => {
                AimdController::adaptive(min, max)
            }
        }
    }

    /// Width to plan for the next iteration (≥ 1).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Feed back one iteration's outcomes: `speculative` slots were
    /// planned beyond slot 0, of which `wasted` produced no measured
    /// candidate — pruned by the Assumption-1 bound or failed
    /// generation/verification. Both counts come from the pinned
    /// slot-order pipeline — deterministic state only.
    pub fn observe(&mut self, speculative: usize, wasted: usize) {
        if self.min == self.max {
            return; // Fixed(n): static by construction
        }
        debug_assert!(wasted <= speculative);
        if speculative == 0 {
            // width 1: no signal yet — probe upward
            self.width = (self.width + 1).min(self.max);
        } else if wasted * 2 > speculative {
            // mostly wasted: multiplicative decrease
            self.width = (self.width / 2).max(self.min);
        } else if wasted == 0 {
            // every speculative slot became a candidate: additive
            // increase
            self.width = (self.width + 1).min(self.max);
        }
        // partially wasted (0 < wasted ≤ ½): hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_mode_never_moves() {
        let mut c = AimdController::from_mode(BatchMode::Fixed(3));
        assert_eq!(c.width(), 3);
        c.observe(2, 2);
        assert_eq!(c.width(), 3);
        c.observe(2, 0);
        assert_eq!(c.width(), 3);
        // Fixed(0) normalizes to the legacy single-candidate loop
        assert_eq!(AimdController::fixed(0).width(), 1);
    }

    #[test]
    fn additive_increase_on_clean_payoff() {
        let mut c = AimdController::adaptive(1, 8);
        assert_eq!(c.width(), 1);
        c.observe(0, 0); // width-1 probe
        assert_eq!(c.width(), 2);
        c.observe(1, 0);
        assert_eq!(c.width(), 3);
        c.observe(2, 0);
        assert_eq!(c.width(), 4);
        // capped at max
        for _ in 0..10 {
            let s = c.width() - 1;
            c.observe(s, 0);
        }
        assert_eq!(c.width(), 8);
    }

    #[test]
    fn multiplicative_decrease_on_heavy_waste() {
        let mut c = AimdController::adaptive(1, 8);
        for _ in 0..10 {
            c.observe(c.width() - 1, 0);
        }
        assert_eq!(c.width(), 8);
        c.observe(7, 6); // 6 of 7 wasted
        assert_eq!(c.width(), 4);
        c.observe(3, 3);
        assert_eq!(c.width(), 2);
        c.observe(1, 1);
        assert_eq!(c.width(), 1); // floored at min
        // at width 1 there is no speculation to observe: probe upward
        c.observe(0, 0);
        assert_eq!(c.width(), 2);
    }

    #[test]
    fn partial_waste_holds() {
        let mut c = AimdController::adaptive(2, 8);
        assert_eq!(c.width(), 2);
        c.observe(1, 0);
        assert_eq!(c.width(), 3);
        // 1 of 2 wasted: exactly half → hold (not > ½)
        c.observe(2, 1);
        assert_eq!(c.width(), 3);
        // 1 of 3 wasted: hold
        c.observe(3, 1);
        assert_eq!(c.width(), 3);
        // 2 of 3 wasted: shrink
        c.observe(3, 2);
        assert_eq!(c.width(), 2);
    }

    #[test]
    fn width_sequence_is_a_pure_function_of_the_outcome_sequence() {
        let outcomes = [(0usize, 0usize), (1, 0), (2, 0), (3, 3), (1, 0),
                        (2, 1), (2, 0), (3, 0)];
        let run = || {
            let mut c = AimdController::adaptive(1, 6);
            let mut widths = Vec::new();
            for &(s, p) in &outcomes {
                widths.push(c.width());
                c.observe(s, p);
            }
            widths
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn degenerate_adaptive_bounds_are_fixed() {
        let mut c = AimdController::adaptive(3, 3);
        c.observe(2, 0);
        assert_eq!(c.width(), 3);
        // inverted bounds normalize to min
        let c2 = AimdController::adaptive(5, 2);
        assert_eq!(c2.width(), 5);
    }
}
