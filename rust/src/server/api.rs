//! The serve job API: typed job specs, serve requests and the backend
//! trait every serving mode implements.
//!
//! Callers build a [`ServeRequest`] — a list of [`JobSpec`]s plus queue
//! policy and an optional [`FaultPlan`] — and hand it to a
//! [`ServeBackend`]:
//!
//! * [`InProcess`](crate::server::InProcess) — the single-supervisor
//!   real path: queue → worker pool → real `optimize_sched` runs;
//! * [`Sharded`](crate::server::supervisor::Sharded) — the same real
//!   path behind a lease-holding supervisor with per-iteration
//!   checkpointing, crash recovery and preemption;
//! * [`Modeled`] — the TimeModel-based pipeline-shape simulation
//!   (previously `--modeled`), kept for fast smokes.
//!
//! The deterministic sections of every backend's [`ServeOutcome`] are a
//! pure function of the request: `InProcess` and `Sharded` produce
//! byte-identical deterministic artifacts for the same request, with or
//! without injected faults — that equivalence is what the recovery
//! property tests and the CI crash-recovery smoke pin down.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::gpu_model::Device;
use crate::llm::LlmProfile;
use crate::sched::BatchMode;
use crate::service::OptimizationService;
use crate::store::TraceStore;
use crate::util::json::Json;

/// One optimization job, fully specified. Two specs that hash to the
/// same [`crate::server::job_fingerprint`] perform bit-identical work
/// and may share results.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Owning tenant (0-based).
    pub tenant: usize,
    /// Larger runs earlier within a tenant; high-priority submissions
    /// are what preempt running shards in the sharded backend.
    pub priority: i64,
    /// Index into the serve hot set (reduced mod the set size).
    pub task_idx: usize,
    pub device: Device,
    pub llm: LlmProfile,
    /// Root seed of the job's bandit run.
    pub seed: u64,
    /// Per-iteration candidate batch sizing.
    pub batch: BatchMode,
    /// Bandit budget T.
    pub iterations: usize,
    /// Last scheduling round the job may still run in; popped after
    /// that it expires instead of executing. `None` = no deadline.
    pub deadline_rounds: Option<usize>,
}

impl JobSpec {
    pub fn new(tenant: usize, task_idx: usize) -> JobSpec {
        JobSpec {
            tenant,
            priority: 0,
            task_idx,
            device: Device::H20,
            llm: LlmProfile::DeepSeekV32,
            seed: 7,
            batch: BatchMode::Fixed(1),
            iterations: 12,
            deadline_rounds: None,
        }
    }

    pub fn priority(mut self, priority: i64) -> JobSpec {
        self.priority = priority;
        self
    }

    pub fn device(mut self, device: Device) -> JobSpec {
        self.device = device;
        self
    }

    pub fn llm(mut self, llm: LlmProfile) -> JobSpec {
        self.llm = llm;
        self
    }

    pub fn seed(mut self, seed: u64) -> JobSpec {
        self.seed = seed;
        self
    }

    pub fn batch(mut self, batch: BatchMode) -> JobSpec {
        self.batch = batch;
        self
    }

    pub fn iterations(mut self, iterations: usize) -> JobSpec {
        self.iterations = iterations;
        self
    }

    pub fn deadline_rounds(mut self, rounds: usize) -> JobSpec {
        self.deadline_rounds = Some(rounds);
        self
    }
}

impl Default for JobSpec {
    fn default() -> JobSpec {
        JobSpec::new(0, 0)
    }
}

/// Deterministic fault injection for the sharded backend
/// (`--fault kill-after=K,preempt=P,seed=S`). All draws come from a
/// dedicated seed, so faulted schedules replay bit-for-bit and never
/// perturb the jobs' own RNG streams.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Kill each fingerprint's worker once, after it has completed this
    /// many iterations (the lease is revoked and the job resumed from
    /// its checkpoints).
    pub kill_after: Option<usize>,
    /// Per-iteration-boundary probability of a preemption parking the
    /// running lease.
    pub preempt_prob: f64,
    /// Seed of the preemption draws.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan { kill_after: None, preempt_prob: 0.0, seed: 0 }
    }
}

impl FaultPlan {
    /// True when the plan injects nothing.
    pub fn is_none(&self) -> bool {
        self.kill_after.is_none() && self.preempt_prob <= 0.0
    }
}

/// Open-loop load generation (`--open-loop rate=R,duration=D`): jobs
/// arrive at a fixed rate regardless of completion speed, like traffic
/// from independent clients. Job `i` of the request arrives `i / rate`
/// seconds after the run starts; a round only begins executing once
/// every job it drained has "arrived". Pacing delays wall-clock
/// execution but never changes round composition, so every
/// deterministic artifact is byte-identical to the closed-loop run —
/// only the measured ledger gains queue-wait and end-to-end latency
/// percentiles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpenLoopPlan {
    /// Target arrival rate, jobs per second (> 0).
    pub rate: f64,
    /// Arrival-window length in seconds; with `rate` it sizes the
    /// default job count `max(1, round(rate * duration))`.
    pub duration_s: f64,
}

/// One serve run: the submitted jobs (in submission order — a job's
/// position is its sequence number) plus queue policy, worker sizing
/// and fault injection.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeRequest {
    pub jobs: Vec<JobSpec>,
    /// Hot-set size the jobs' `task_idx` indexes into.
    pub task_variety: usize,
    /// Admission: total jobs the queue accepts.
    pub queue_capacity: usize,
    /// Admission: jobs accepted per tenant.
    pub per_tenant_quota: usize,
    /// Jobs drained per scheduling round (0 = auto: 2 × tenants).
    pub round_max: usize,
    /// Worker threads per round (0 = available parallelism). Never
    /// affects deterministic bytes.
    pub workers: usize,
    pub fault: FaultPlan,
    /// Open-loop arrival pacing (`None` = classic closed-loop drain).
    /// Wall-clock only: never affects deterministic bytes.
    pub open_loop: Option<OpenLoopPlan>,
    /// Generated-workload hot set (`--variety grammar:<name>`): the
    /// hot set is drawn from this expanded grammar space instead of
    /// the Table-7 suite. `task_variety` still sizes the hot set.
    pub workload: Option<crate::workload::gen::GrammarSpec>,
}

impl Default for ServeRequest {
    fn default() -> ServeRequest {
        ServeRequest {
            jobs: Vec::new(),
            task_variety: 2,
            queue_capacity: usize::MAX,
            per_tenant_quota: usize::MAX,
            round_max: 0,
            workers: 0,
            fault: FaultPlan::default(),
            open_loop: None,
            workload: None,
        }
    }
}

impl ServeRequest {
    /// The classic serve grid: every tenant submits the same
    /// `jobs_per_tenant` hot-task jobs, interleaved tenant-by-tenant so
    /// admission decisions are tenant-fair. Job `j` of every tenant
    /// runs hot task `j % variety` (equal fingerprints across tenants
    /// are what dedup sharing feeds on).
    #[allow(clippy::too_many_arguments)]
    pub fn grid(tenants: usize, jobs_per_tenant: usize,
                iterations: usize, batch: BatchMode, variety: usize,
                device: Device, llm: LlmProfile, seed: u64)
                -> ServeRequest {
        let variety = variety.max(1);
        let mut jobs = Vec::with_capacity(tenants * jobs_per_tenant);
        for j in 0..jobs_per_tenant {
            for t in 0..tenants {
                jobs.push(
                    JobSpec::new(t, j % variety)
                        .device(device)
                        .llm(llm)
                        .seed(seed)
                        .batch(batch)
                        .iterations(iterations),
                );
            }
        }
        ServeRequest {
            jobs,
            task_variety: variety,
            ..ServeRequest::default()
        }
    }

    /// Number of tenants the job list spans.
    pub fn tenants(&self) -> usize {
        self.jobs.iter().map(|j| j.tenant + 1).max().unwrap_or(0)
    }

    /// Largest per-tenant job count (the grid's `jobs_per_tenant`).
    pub fn jobs_per_tenant(&self) -> usize {
        let tenants = self.tenants();
        (0..tenants)
            .map(|t| self.jobs.iter().filter(|j| j.tenant == t).count())
            .max()
            .unwrap_or(0)
    }

    pub(crate) fn effective_round_max(&self) -> usize {
        if self.round_max > 0 {
            self.round_max
        } else {
            (self.tenants() * 2).max(1)
        }
    }
}

/// What a backend hands back: the byte-compared deterministic artifact,
/// the measured ledger (when the backend separates one), the supervisor
/// ledger (sharded only) and the human-readable summary lines the CLI
/// prints verbatim.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub deterministic: Json,
    pub ledger: Option<Json>,
    pub supervisor: Option<Json>,
    pub lines: Vec<String>,
}

/// A serving mode. All three (`InProcess`, `Sharded`, `Modeled`) run
/// behind this one entry point; the CLI picks one with `--backend`.
pub trait ServeBackend {
    fn name(&self) -> &'static str;
    /// Run the request. `store` is the session store (`None` only for
    /// storeless modeled smokes; the real backends always receive one —
    /// in-memory when the CLI got no `--store`).
    fn run(&self, req: &ServeRequest,
           store: Option<&Arc<TraceStore>>) -> Result<ServeOutcome>;
}

/// The TimeModel-based service simulation (previously `--modeled`):
/// batched LLM gateway + modeled recluster scheduler, scaled sleeps.
/// Kept for fast pipeline-shape smokes; jobs all run under tenant 0 and
/// only `len`, `iterations` and a fixed batch width are honored.
#[derive(Debug, Clone, Copy, Default)]
pub struct Modeled;

impl ServeBackend for Modeled {
    fn name(&self) -> &'static str {
        "modeled"
    }

    fn run(&self, req: &ServeRequest,
           store: Option<&Arc<TraceStore>>) -> Result<ServeOutcome> {
        let jobs = req.jobs.len();
        let iterations =
            req.jobs.first().map_or(3, |j| j.iterations);
        let batch = match req.jobs.first().map_or(
            BatchMode::Fixed(1),
            |j| j.batch,
        ) {
            BatchMode::Fixed(n) => n.max(1),
            BatchMode::Adaptive { .. } => bail!(
                "--batch auto needs a real serve backend \
                 (inprocess or sharded)"
            ),
        };
        if !req.fault.is_none() {
            bail!("fault injection needs --backend sharded");
        }
        if req.open_loop.is_some() {
            bail!(
                "--open-loop needs a real serve backend \
                 (inprocess or sharded)"
            );
        }
        if req.workload.is_some() {
            bail!(
                "--variety grammar: needs a real serve backend \
                 (inprocess or sharded)"
            );
        }
        let mut service = OptimizationService::default();
        service.batch = batch;
        let report = service.run_with_store(
            jobs,
            iterations,
            store.map(|s| s.as_ref()),
        );
        let mut lines = vec![
            format!(
                "service: {} jobs x {} iterations  wall {:.1}s (modeled)  \
                 serial-equivalent {:.1}s  batching speedup {:.1}x",
                jobs,
                iterations,
                report.wall_model_s,
                report.serial_equivalent_s,
                report.batching_speedup()
            ),
            format!(
                "gateway: {} requests in {} batches (max batch {})",
                report.gateway_requests,
                report.gateway_batches,
                report.gateway_max_batch
            ),
            format!(
                "scheduler: {} recluster requests in {} rounds  \
                 warm_hits={} dedup_shares={} saved {:.1}s (modeled)",
                report.sched_requests,
                report.sched_rounds,
                report.sched_warm_hits,
                report.sched_dedup_shares,
                report.sched_saved_model_s
            ),
        ];
        if store.is_some() {
            lines.push(format!(
                "gateway_bypassed={}",
                report.gateway_bypassed
            ));
        }
        let mut json = Json::obj(vec![
            ("schema_version", Json::num(1.0)),
            ("experiment", Json::str("serve")),
            ("jobs", Json::num(jobs as f64)),
            ("iterations", Json::num(iterations as f64)),
            ("batch", Json::num(batch as f64)),
            ("wall_model_s", Json::num(report.wall_model_s)),
            (
                "serial_equivalent_s",
                Json::num(report.serial_equivalent_s),
            ),
            ("batching_speedup", Json::num(report.batching_speedup())),
            (
                "gateway_requests",
                Json::num(report.gateway_requests as f64),
            ),
            (
                "gateway_batches",
                Json::num(report.gateway_batches as f64),
            ),
            (
                "gateway_max_batch",
                Json::num(report.gateway_max_batch as f64),
            ),
            ("sched_requests", Json::num(report.sched_requests as f64)),
            ("sched_rounds", Json::num(report.sched_rounds as f64)),
            (
                "sched_warm_hits",
                Json::num(report.sched_warm_hits as f64),
            ),
            (
                "sched_dedup_shares",
                Json::num(report.sched_dedup_shares as f64),
            ),
            (
                "sched_saved_model_s",
                Json::num(report.sched_saved_model_s),
            ),
        ]);
        // only present with a store, so storeless artifacts keep their
        // pre-store byte layout
        if store.is_some() {
            json.insert(
                "gateway_bypassed",
                Json::num(report.gateway_bypassed as f64),
            );
        }
        Ok(ServeOutcome {
            deterministic: json,
            ledger: None,
            supervisor: None,
            lines,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_interleaves_tenants_in_submission_order() {
        let req = ServeRequest::grid(
            2,
            3,
            12,
            BatchMode::Fixed(1),
            2,
            Device::H20,
            LlmProfile::DeepSeekV32,
            7,
        );
        assert_eq!(req.jobs.len(), 6);
        let tenants: Vec<usize> =
            req.jobs.iter().map(|j| j.tenant).collect();
        assert_eq!(tenants, vec![0, 1, 0, 1, 0, 1]);
        let tasks: Vec<usize> =
            req.jobs.iter().map(|j| j.task_idx).collect();
        assert_eq!(tasks, vec![0, 0, 1, 1, 0, 0]);
        assert_eq!(req.tenants(), 2);
        assert_eq!(req.jobs_per_tenant(), 3);
        assert_eq!(req.effective_round_max(), 4);
    }

    #[test]
    fn builder_defaults_match_the_classic_config() {
        let j = JobSpec::new(1, 0);
        assert_eq!(j.tenant, 1);
        assert_eq!(j.priority, 0);
        assert_eq!(j.seed, 7);
        assert_eq!(j.iterations, 12);
        assert_eq!(j.batch, BatchMode::Fixed(1));
        assert_eq!(j.deadline_rounds, None);
        let j = j.priority(3).seed(9).iterations(5).deadline_rounds(1);
        assert_eq!(
            (j.priority, j.seed, j.iterations, j.deadline_rounds),
            (3, 9, 5, Some(1))
        );
    }

    #[test]
    fn modeled_backend_matches_the_legacy_artifact_layout() {
        let req = ServeRequest {
            jobs: (0..4)
                .map(|_| JobSpec::new(0, 0).iterations(2))
                .collect(),
            ..ServeRequest::default()
        };
        let out = Modeled.run(&req, None).expect("modeled run");
        assert!(out.ledger.is_none());
        assert!(out.supervisor.is_none());
        let d = out.deterministic.dump();
        assert!(d.contains("\"schema_version\":1"), "{d}");
        assert!(!d.contains("gateway_bypassed"));
        assert!(out
            .lines
            .iter()
            .any(|l| l.contains("recluster requests")));
        // adaptive batch is a real-path feature
        let mut bad = req.clone();
        bad.jobs[0].batch = BatchMode::Adaptive { min: 1, max: 4 };
        assert!(Modeled.run(&bad, None).is_err());
    }
}
