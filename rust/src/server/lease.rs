//! Lease table: which worker shard owns which job fingerprint.
//!
//! The sharded supervisor ([`crate::server::supervisor`]) never hands a
//! fingerprint to two shards at once. Every execution attempt is
//! bracketed by a lease: granted before the shard starts, heartbeated
//! while the shard reports progress, and closed in exactly one of three
//! ways —
//!
//! * **complete** — the run finished its budget; the lease is retired;
//! * **revoke** — the shard missed its heartbeat deadline (in the
//!   deterministic harness: the fault plan killed it); the supervisor
//!   reclaims the fingerprint and re-grants it later, resuming from the
//!   checkpoint journal;
//! * **park** — a higher-priority tick preempted the shard at an
//!   iteration boundary; the lease survives in `Parked` state and only
//!   its original fingerprint may resume it.
//!
//! Time here is logical: a stamp is `(round, tick)` from the
//! supervisor's scheduling loop, so the whole table — grants, expiries,
//! the event log — is a pure function of the job set and the fault
//! plan, never of wall-clock.

use std::collections::BTreeMap;

/// Lifecycle of one lease.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeaseState {
    /// A shard holds the fingerprint and is executing it.
    Active,
    /// Preempted at an iteration boundary; waiting to resume.
    Parked,
    /// Heartbeat deadline missed; fingerprint reclaimed.
    Revoked,
    /// Run finished; terminal.
    Completed,
}

/// Logical timestamp: `(round, tick)` of the supervisor loop.
pub type Stamp = (usize, usize);

/// One fingerprint's current lease.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lease {
    pub fingerprint: u64,
    /// Worker shard holding (or last holding) the lease.
    pub worker: usize,
    pub state: LeaseState,
    /// When the current grant happened.
    pub granted: Stamp,
    /// Last heartbeat (or state change).
    pub beat: Stamp,
}

/// Why a grant was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeaseError {
    /// The fingerprint has a live (`Active` or `Parked`) lease; granting
    /// it again would double-execute the job.
    AlreadyLeased,
}

/// One entry in the audit log.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LeaseEvent {
    pub stamp: Stamp,
    pub fingerprint: u64,
    pub worker: usize,
    pub what: &'static str,
}

/// The supervisor's lease ledger. `BTreeMap` keeps iteration order (and
/// therefore any serialized view) deterministic.
#[derive(Debug, Default)]
pub struct LeaseTable {
    leases: BTreeMap<u64, Lease>,
    events: Vec<LeaseEvent>,
    granted: u64,
    resumed: u64,
    revoked: u64,
    parked: u64,
    completed: u64,
}

impl LeaseTable {
    pub fn new() -> LeaseTable {
        LeaseTable::default()
    }

    fn log(&mut self, stamp: Stamp, fp: u64, worker: usize,
           what: &'static str) {
        self.events.push(LeaseEvent {
            stamp,
            fingerprint: fp,
            worker,
            what,
        });
    }

    /// Grant `fp` to `worker`. Refused while a live lease exists — the
    /// single-executor guarantee. A `Revoked` or `Completed` lease is
    /// not live; re-granting after revocation is the recovery path and
    /// is counted as a resume.
    pub fn grant(&mut self, fp: u64, worker: usize, stamp: Stamp)
                 -> Result<(), LeaseError> {
        if let Some(l) = self.leases.get(&fp) {
            if matches!(l.state, LeaseState::Active | LeaseState::Parked)
            {
                return Err(LeaseError::AlreadyLeased);
            }
            if l.state == LeaseState::Revoked {
                self.resumed += 1;
            }
        }
        self.leases.insert(fp, Lease {
            fingerprint: fp,
            worker,
            state: LeaseState::Active,
            granted: stamp,
            beat: stamp,
        });
        self.granted += 1;
        self.log(stamp, fp, worker, "grant");
        Ok(())
    }

    /// Record a heartbeat from the holder. Ignored unless `Active`.
    pub fn heartbeat(&mut self, fp: u64, stamp: Stamp) {
        if let Some(l) = self.leases.get_mut(&fp) {
            if l.state == LeaseState::Active {
                l.beat = stamp;
            }
        }
    }

    /// True when an `Active` lease last beat at or before
    /// `deadline` — the holder is presumed dead and should be revoked.
    pub fn expired(&self, fp: u64, deadline: Stamp) -> bool {
        self.leases.get(&fp).map_or(false, |l| {
            l.state == LeaseState::Active && l.beat <= deadline
        })
    }

    /// Reclaim an `Active` fingerprint whose holder vanished.
    pub fn revoke(&mut self, fp: u64, stamp: Stamp) {
        if let Some(l) = self.leases.get_mut(&fp) {
            if l.state == LeaseState::Active {
                l.state = LeaseState::Revoked;
                l.beat = stamp;
                self.revoked += 1;
                let w = l.worker;
                self.log(stamp, fp, w, "revoke");
            }
        }
    }

    /// Preempt an `Active` lease at an iteration boundary; it keeps its
    /// identity and may only be resumed (not re-granted).
    pub fn park(&mut self, fp: u64, stamp: Stamp) {
        if let Some(l) = self.leases.get_mut(&fp) {
            if l.state == LeaseState::Active {
                l.state = LeaseState::Parked;
                l.beat = stamp;
                self.parked += 1;
                let w = l.worker;
                self.log(stamp, fp, w, "park");
            }
        }
    }

    /// Resume a `Parked` lease on `worker`. Counted as a resume.
    pub fn resume(&mut self, fp: u64, worker: usize, stamp: Stamp)
                  -> Result<(), LeaseError> {
        match self.leases.get_mut(&fp) {
            Some(l) if l.state == LeaseState::Parked => {
                l.state = LeaseState::Active;
                l.worker = worker;
                l.granted = stamp;
                l.beat = stamp;
                self.resumed += 1;
                self.log(stamp, fp, worker, "resume");
                Ok(())
            }
            _ => Err(LeaseError::AlreadyLeased),
        }
    }

    /// Retire a finished lease.
    pub fn complete(&mut self, fp: u64, stamp: Stamp) {
        if let Some(l) = self.leases.get_mut(&fp) {
            if l.state == LeaseState::Active {
                l.state = LeaseState::Completed;
                l.beat = stamp;
                self.completed += 1;
                let w = l.worker;
                self.log(stamp, fp, w, "complete");
            }
        }
    }

    pub fn state(&self, fp: u64) -> Option<LeaseState> {
        self.leases.get(&fp).map(|l| l.state)
    }

    pub fn events(&self) -> &[LeaseEvent] {
        &self.events
    }

    /// `(granted, resumed, revoked, parked, completed)` counters.
    pub fn counters(&self) -> (u64, u64, u64, u64, u64) {
        (self.granted, self.resumed, self.revoked, self.parked,
         self.completed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn double_grant_is_refused_while_live() {
        let mut t = LeaseTable::new();
        t.grant(9, 0, (0, 0)).unwrap();
        assert_eq!(t.grant(9, 1, (0, 0)), Err(LeaseError::AlreadyLeased));
        t.park(9, (0, 1));
        // parked is still live — only resume may reactivate it
        assert_eq!(t.grant(9, 1, (0, 2)), Err(LeaseError::AlreadyLeased));
        t.resume(9, 1, (0, 2)).unwrap();
        assert_eq!(t.state(9), Some(LeaseState::Active));
        t.complete(9, (0, 3));
        assert_eq!(t.state(9), Some(LeaseState::Completed));
    }

    #[test]
    fn revoked_fingerprints_regrant_as_resumes() {
        let mut t = LeaseTable::new();
        t.grant(4, 0, (0, 0)).unwrap();
        assert!(t.expired(4, (0, 0)));
        t.heartbeat(4, (0, 1));
        assert!(!t.expired(4, (0, 0)));
        t.revoke(4, (0, 2));
        assert_eq!(t.state(4), Some(LeaseState::Revoked));
        // recovery: the fingerprint is grantable again
        t.grant(4, 2, (0, 3)).unwrap();
        let (granted, resumed, revoked, parked, completed) = t.counters();
        assert_eq!((granted, resumed, revoked, parked, completed),
                   (2, 1, 1, 0, 0));
    }

    #[test]
    fn event_log_is_ordered_and_complete() {
        let mut t = LeaseTable::new();
        t.grant(1, 0, (0, 0)).unwrap();
        t.park(1, (0, 1));
        t.resume(1, 1, (1, 0)).unwrap();
        t.complete(1, (1, 1));
        let whats: Vec<&str> =
            t.events().iter().map(|e| e.what).collect();
        assert_eq!(whats, vec!["grant", "park", "resume", "complete"]);
        assert!(t.events().windows(2).all(|w| w[0].stamp <= w[1].stamp));
    }

    #[test]
    fn lifecycle_guards_ignore_invalid_transitions() {
        let mut t = LeaseTable::new();
        t.revoke(7, (0, 0)); // unknown fp: no-op
        t.park(7, (0, 0));
        assert!(t.resume(7, 0, (0, 0)).is_err());
        t.grant(7, 0, (0, 1)).unwrap();
        t.complete(7, (0, 2));
        t.revoke(7, (0, 3)); // completed: no-op
        assert_eq!(t.state(7), Some(LeaseState::Completed));
        let (_, _, revoked, parked, _) = t.counters();
        assert_eq!((revoked, parked), (0, 0));
    }
}
