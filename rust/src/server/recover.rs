//! Crash recovery: reconcile the checkpoint journal on session start.
//!
//! A sharded serve session that dies mid-job leaves its per-iteration
//! checkpoints in the store's journal
//! (`checkpoints.jsonl`, see [`crate::store`]): completed jobs retire
//! their entries, so whatever survives a reopen is exactly the set of
//! interrupted runs. [`reconcile`] scans that set so the supervisor can
//! resume each one from its last iteration boundary instead of
//! restarting it — the journal prefix feeds
//! [`crate::policy::resume::RunCtl::resuming`], which replays the
//! recorded effects without a single new engine or LLM call.

use std::sync::Arc;

use crate::store::TraceStore;

/// One interrupted job found in the journal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PendingJob {
    pub fingerprint: u64,
    /// Iterations already banked; a resume starts at `checkpoints + 1`.
    pub checkpoints: usize,
}

/// What a session-start scan of the checkpoint journal found.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RecoverySummary {
    /// Interrupted jobs, in ascending fingerprint order.
    pub pending: Vec<PendingJob>,
}

impl RecoverySummary {
    pub fn is_clean(&self) -> bool {
        self.pending.is_empty()
    }

    /// Total banked iterations across all interrupted jobs.
    pub fn banked_iterations(&self) -> usize {
        self.pending.iter().map(|p| p.checkpoints).sum()
    }
}

/// Scan the store's live checkpoint journal for jobs a previous session
/// (or an earlier attempt in this one) left unfinished.
pub fn reconcile(store: &Arc<TraceStore>) -> RecoverySummary {
    let mut fps = store.ckpt_live();
    fps.sort_unstable();
    let pending = fps
        .into_iter()
        .map(|fp| PendingJob {
            fingerprint: fp,
            checkpoints: store.ckpt_prefix(fp).len(),
        })
        .collect();
    RecoverySummary { pending }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::resume::Checkpoint;

    fn ck(t: usize) -> Checkpoint {
        Checkpoint { t, strategy: None, slots: Vec::new() }
    }

    #[test]
    fn clean_store_reconciles_empty() {
        let store = Arc::new(TraceStore::in_memory());
        let s = reconcile(&store);
        assert!(s.is_clean());
        assert_eq!(s.banked_iterations(), 0);
    }

    #[test]
    fn interrupted_jobs_surface_with_their_banked_prefix() {
        let store = Arc::new(TraceStore::in_memory());
        store.ckpt_append(40, &ck(1));
        store.ckpt_append(40, &ck(2));
        store.ckpt_append(7, &ck(1));
        store.ckpt_append(99, &ck(1));
        store.ckpt_retire(99); // completed: must not surface
        let s = reconcile(&store);
        assert_eq!(s.pending, vec![
            PendingJob { fingerprint: 7, checkpoints: 1 },
            PendingJob { fingerprint: 40, checkpoints: 2 },
        ]);
        assert_eq!(s.banked_iterations(), 3);
    }

    #[test]
    fn recovery_survives_a_store_reopen() {
        let dir = std::env::temp_dir().join(format!(
            "kb-recover-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let store =
                Arc::new(TraceStore::open(&dir).expect("open store"));
            store.ckpt_append(11, &ck(1));
            store.persist().expect("persist");
        }
        let store =
            Arc::new(TraceStore::open(&dir).expect("reopen store"));
        let s = reconcile(&store);
        assert_eq!(s.pending.len(), 1);
        assert_eq!(s.pending[0].fingerprint, 11);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
