//! Multi-tenant priority job queue with admission control and
//! deterministic per-tenant fairness.
//!
//! The real serving loop separates *planning* from *execution*: every
//! job is submitted (and admitted or rejected) before any worker runs,
//! and rounds are popped from the queue on the planning thread only.
//! That makes admission and dispatch order pure functions of the
//! submitted job set — no wall-clock, no worker timing — which is what
//! keeps the serve ledger's deterministic sections byte-stable across
//! worker counts and store temperatures.
//!
//! ## Admission control
//!
//! Two bounds, both checked at submission: a global `capacity` (total
//! admitted jobs) and a `per_tenant_quota` (admitted jobs per tenant,
//! so one chatty tenant cannot starve the rest of the queue). Rejected
//! jobs are counted per tenant in the ledger, never silently dropped.
//!
//! ## Fairness + priority
//!
//! [`JobQueue::pop_round`] drains jobs in deficit-round-robin order:
//! each pop goes to the tenant with the fewest jobs dispatched so far
//! (ties to the lower tenant id), and within a tenant to the highest
//! `priority`, then lowest submission sequence. A round is just the
//! next `max` pops, so round composition is deterministic too.

/// One queued optimization job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Job {
    /// Global submission sequence number (deterministic tie-break).
    pub seq: usize,
    /// Owning tenant (0-based).
    pub tenant: usize,
    /// Larger runs earlier within a tenant.
    pub priority: i64,
    /// Index into the serve task hot set.
    pub task_idx: usize,
    /// Content fingerprint of the job's run spec — jobs with equal
    /// fingerprints perform identical work and can share results.
    pub fingerprint: u64,
}

/// Why a submission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejection {
    /// Global queue capacity reached.
    QueueFull,
    /// The tenant's admission quota reached.
    QuotaExceeded,
}

/// Deterministic multi-tenant queue (planning-thread only; execution
/// parallelism lives in [`crate::server::worker`]).
#[derive(Debug)]
pub struct JobQueue {
    capacity: usize,
    per_tenant_quota: usize,
    /// Pending jobs per tenant, in submission order.
    pending: Vec<Vec<Job>>,
    /// Jobs admitted per tenant (monotone; admission bookkeeping).
    admitted: Vec<usize>,
    /// Jobs dispatched per tenant (fairness deficit counter).
    dispatched: Vec<usize>,
    rejected: Vec<usize>,
    admitted_total: usize,
}

impl JobQueue {
    /// A capacity or quota of 0 is honored literally: every submission
    /// is rejected (drain/lock-out semantics), not clamped up.
    pub fn new(tenants: usize, capacity: usize, per_tenant_quota: usize)
               -> JobQueue {
        JobQueue {
            capacity,
            per_tenant_quota,
            pending: vec![Vec::new(); tenants],
            admitted: vec![0; tenants],
            dispatched: vec![0; tenants],
            rejected: vec![0; tenants],
            admitted_total: 0,
        }
    }

    /// Admit or reject a job. Decided entirely by the submission-time
    /// queue state, so identical submission sequences always admit the
    /// identical job set.
    pub fn submit(&mut self, job: Job) -> Result<(), Rejection> {
        let t = job.tenant;
        if self.admitted_total >= self.capacity {
            self.rejected[t] += 1;
            return Err(Rejection::QueueFull);
        }
        if self.admitted[t] >= self.per_tenant_quota {
            self.rejected[t] += 1;
            return Err(Rejection::QuotaExceeded);
        }
        self.admitted[t] += 1;
        self.admitted_total += 1;
        self.pending[t].push(job);
        Ok(())
    }

    pub fn is_empty(&self) -> bool {
        self.pending.iter().all(Vec::is_empty)
    }

    /// Pop the next round of up to `max` jobs in deficit-round-robin
    /// order (see module docs). Deterministic.
    pub fn pop_round(&mut self, max: usize) -> Vec<Job> {
        let mut round = Vec::new();
        while round.len() < max.max(1) {
            // tenant with pending work and the smallest dispatch count
            let Some(t) = (0..self.pending.len())
                .filter(|&t| !self.pending[t].is_empty())
                .min_by_key(|&t| (self.dispatched[t], t))
            else {
                break;
            };
            // best job of that tenant: highest priority, lowest seq
            let bi = self.pending[t]
                .iter()
                .enumerate()
                .min_by_key(|(_, j)| (-j.priority, j.seq))
                .map(|(i, _)| i)
                .expect("tenant has pending jobs");
            round.push(self.pending[t].remove(bi));
            self.dispatched[t] += 1;
        }
        round
    }

    pub fn admitted(&self) -> usize {
        self.admitted_total
    }

    pub fn rejected(&self) -> usize {
        self.rejected.iter().sum()
    }

    pub fn rejected_for(&self, tenant: usize) -> usize {
        self.rejected[tenant]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(seq: usize, tenant: usize, priority: i64) -> Job {
        Job { seq, tenant, priority, task_idx: seq, fingerprint: seq as u64 }
    }

    #[test]
    fn round_robin_interleaves_tenants() {
        let mut q = JobQueue::new(3, 64, 64);
        let mut seq = 0;
        for t in 0..3 {
            for _ in 0..3 {
                q.submit(job(seq, t, 0)).unwrap();
                seq += 1;
            }
        }
        let round = q.pop_round(6);
        let tenants: Vec<usize> = round.iter().map(|j| j.tenant).collect();
        // deficit round-robin: each tenant appears twice before any
        // appears a third time
        assert_eq!(tenants, vec![0, 1, 2, 0, 1, 2]);
        let rest = q.pop_round(16);
        assert_eq!(rest.len(), 3);
        assert!(q.is_empty());
    }

    #[test]
    fn priority_orders_within_a_tenant() {
        let mut q = JobQueue::new(1, 16, 16);
        q.submit(job(0, 0, 0)).unwrap();
        q.submit(job(1, 0, 5)).unwrap();
        q.submit(job(2, 0, 5)).unwrap();
        let round = q.pop_round(3);
        // highest priority first; equal priorities by submission order
        assert_eq!(round.iter().map(|j| j.seq).collect::<Vec<_>>(),
                   vec![1, 2, 0]);
    }

    #[test]
    fn admission_enforces_capacity_and_quota() {
        let mut q = JobQueue::new(2, 3, 2);
        assert!(q.submit(job(0, 0, 0)).is_ok());
        assert!(q.submit(job(1, 0, 0)).is_ok());
        // tenant 0 hits its quota before the queue fills
        assert_eq!(q.submit(job(2, 0, 0)), Err(Rejection::QuotaExceeded));
        assert!(q.submit(job(3, 1, 0)).is_ok());
        // global capacity now exhausted
        assert_eq!(q.submit(job(4, 1, 0)), Err(Rejection::QueueFull));
        assert_eq!(q.admitted(), 3);
        assert_eq!(q.rejected(), 2);
        assert_eq!(q.rejected_for(0), 1);
        assert_eq!(q.rejected_for(1), 1);
    }

    #[test]
    fn zero_capacity_or_quota_locks_tenants_out() {
        let mut q = JobQueue::new(2, 0, 4);
        assert_eq!(q.submit(job(0, 0, 0)), Err(Rejection::QueueFull));
        assert_eq!(q.admitted(), 0);
        let mut q2 = JobQueue::new(2, 8, 0);
        assert_eq!(q2.submit(job(0, 1, 0)), Err(Rejection::QuotaExceeded));
        assert_eq!(q2.rejected_for(1), 1);
        assert!(q2.is_empty());
    }

    #[test]
    fn pop_order_is_deterministic() {
        let build = || {
            let mut q = JobQueue::new(4, 64, 64);
            let mut seq = 0;
            for t in [2usize, 0, 3, 1, 2, 2, 0, 1] {
                q.submit(job(seq, t, (seq % 3) as i64)).unwrap();
                seq += 1;
            }
            let mut order = Vec::new();
            while !q.is_empty() {
                order.extend(q.pop_round(3).into_iter().map(|j| j.seq));
            }
            order
        };
        assert_eq!(build(), build());
    }
}
