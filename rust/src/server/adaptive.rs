//! Serving-facing surface of the adaptive batch-width controller.
//!
//! The AIMD controller itself lives in [`crate::sched::adaptive`] —
//! it hooks into `optimize_sched`'s batch planning, so it belongs to
//! the scheduling layer (the policy loop must not depend on the
//! serving subsystem that orchestrates it). This module re-exports it
//! as part of the server API because `--batch auto` is primarily a
//! serving feature: the multi-tenant loop is where adaptive
//! speculation width pays for itself across many concurrent runs.

pub use crate::sched::adaptive::AimdController;
