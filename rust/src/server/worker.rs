//! Worker pool: executes queued jobs as real `KernelBand` runs.
//!
//! A round (popped by [`crate::server::queue::JobQueue::pop_round`]) is
//! executed in three deterministic phases:
//!
//! 1. **dedup** — jobs are grouped by run fingerprint; each distinct
//!    fingerprint gets exactly one *representative* execution per
//!    round, later duplicates become zero-cost shares (the real-work
//!    analogue of the modeled scheduler's `dedup_shares`);
//! 2. **execute** — representatives fan out over
//!    [`crate::util::par::parallel_map`]; every execution is a full
//!    [`KernelBand::optimize_sched`] run of its own [`JobSpec`]
//!    (device, LLM, seed, batch mode, budget) through the session's
//!    shared [`crate::store::TraceStore`] caches (measurements,
//!    proposals), [`crate::sched::centroids::CentroidCache`] and
//!    [`crate::sched::profiles::SharedProfiles`], so a fingerprint
//!    seen in any earlier round resumes warm — pure lookups, zero LLM
//!    round-trips, zero re-profiling;
//! 3. **fan-in** — results are assembled in round order and fresh
//!    trace records are returned to the caller for appending in that
//!    canonical order, so the trace log bytes never depend on worker
//!    scheduling.
//!
//! Wall-clock here is *measured* (`Instant`), not modeled: no
//! [`crate::service::TIME_SCALE`] anywhere on this path. Measured
//! fields are kept out of the byte-compared artifact sections.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::engine::SimEngine;
use crate::llm::SurrogateLlm;
use crate::obs::regret as obs_regret;
use crate::obs::trace::TRACK_JOBS;
use crate::policy::{KernelBand, PolicyConfig};
use crate::rng::Rng;
use crate::sched::{JobObs, SchedContext};
use crate::util::json::Json;
use crate::server::api::JobSpec;
use crate::server::queue::Job;
use crate::server::tenant::tenant_label;
use crate::store::log::{records_for_trace_tenant, TraceRecord};
use crate::store::wrap::{CachedEngine, CachedLlm};
use crate::store::TraceStore;
use crate::util::par::parallel_map;
use crate::workload::TaskSpec;

/// Everything an execution needs, shared across the round's workers.
/// Per-job knobs (device, LLM, seed, batch, budget) live on each job's
/// [`JobSpec`], indexed by the job's submission `seq`.
pub struct ExecEnv<'a> {
    /// The serve hot set (jobs index into this via `task_idx`).
    pub tasks: &'a [TaskSpec],
    /// The request's job specs (jobs index into this via `seq`).
    pub specs: &'a [JobSpec],
    /// Session store shared by every tenant (caches + trace log).
    pub store: &'a Arc<TraceStore>,
    /// Worker threads per round (0 = available parallelism).
    pub workers: usize,
    /// Span id of the round currently executing (0 = no causal trace);
    /// `run_serve` stores it before each `exec_round` so job spans
    /// parent under their round. Advisory, like everything obs.
    pub round_span: AtomicU64,
}

/// Outcome of one job (executed or shared).
#[derive(Debug, Clone)]
pub struct JobResult {
    pub job: Job,
    /// Round the job completed in.
    pub round: usize,
    /// Served by sharing a round-mate's identical execution.
    pub shared: bool,
    pub task_name: String,
    pub correct: bool,
    pub best_speedup: f64,
    pub iterations: usize,
    pub cost_usd: f64,
    /// The adaptive controller's width decision trace (constant under
    /// `Fixed`). Deterministic; byte-compared in the artifact.
    pub width_trace: Vec<usize>,
    // --- measured / store-temperature-dependent ---------------------
    /// Representative NCU profilings recomputed (0 on warm replay).
    pub profile_runs: u64,
    /// LLM proposals actually simulated (0 on warm replay — the real
    /// path's "zero gateway round-trips").
    pub llm_round_trips: u64,
    /// Measurements actually simulated (0 on warm replay).
    pub measure_sims: u64,
    /// Measured execution wall-clock (0 for shares).
    pub wall_s: f64,
}

/// Execute one job for real. Returns the result plus the trace records
/// to append when the run performed new simulated work (`None` for a
/// pure replay, matching the experiment runner's guard against
/// duplicate log records).
fn execute(env: &ExecEnv<'_>, job: &Job, round: usize)
           -> (JobResult, Option<Vec<TraceRecord>>) {
    let t0 = Instant::now();
    let spec = &env.specs[job.seq];
    let task = &env.tasks[job.task_idx];
    let engine = CachedEngine::new(
        SimEngine::new(spec.device),
        env.store.clone(),
    );
    let llm = CachedLlm::new(
        SurrogateLlm::new(spec.llm),
        env.store.clone(),
    );
    // causal trace + decision-ledger anchor: each job gets its own
    // sequential track so concurrent jobs never interleave on one lane
    let rec = env.store.recorder();
    let track = TRACK_JOBS + job.seq as u64;
    let jspan = rec
        .as_ref()
        .and_then(|r| r.trace())
        .map(|s| {
            s.begin(
                "serve.job",
                env.round_span.load(Ordering::Relaxed),
                track,
                Json::obj(vec![
                    ("seq", Json::num(job.seq as f64)),
                    ("tenant", Json::num(job.tenant as f64)),
                    ("task", Json::str(task.name.clone())),
                ]),
            )
        });
    let job_obs = rec
        .as_ref()
        .filter(|r| r.trace().is_some() || r.decisions().is_some())
        .map(|_| JobObs {
            span: jspan.unwrap_or(0),
            track,
            label: Arc::from(
                format!("r{round}/j{} {}", job.seq, task.name).as_str(),
            ),
        });
    let ctx = SchedContext {
        mode: spec.batch,
        centroids: Some(env.store.session_centroids()),
        profiles: Some(env.store.profiles()),
        obs: rec.clone(),
        job: job_obs,
    };
    let mut cfg = PolicyConfig::default();
    cfg.iterations = spec.iterations;
    let trace = KernelBand::new(cfg).optimize_sched(
        task,
        &engine,
        &llm,
        &Rng::new(spec.seed),
        None,
        &ctx,
    );
    if let (Some(r), Some(id)) = (&rec, jspan) {
        if let Some(s) = r.trace() {
            s.end(id);
        }
    }
    // online regret vs the latent optimum: exact on grammar tasks
    // (provable oracle from the noiseless roofline model), best-seen on
    // the hand-built suite
    if let Some(r) = rec.as_ref().filter(|r| r.enabled()) {
        let oracle = obs_regret::latent_oracle_latency_s(task, spec.device);
        let (curve, exact) = obs_regret::regret_curve(&trace, oracle);
        r.observe_regret(&curve, exact);
    }
    let fresh = engine.local_sims() + llm.local_sims() > 0;
    let records = fresh.then(|| {
        records_for_trace_tenant(
            "serve",
            Some(&tenant_label(job.tenant)),
            spec.device.name(),
            spec.llm.spec().name,
            spec.seed,
            &trace,
        )
    });
    let result = JobResult {
        job: *job,
        round,
        shared: false,
        task_name: trace.task_name.clone(),
        correct: trace.correct(),
        best_speedup: trace.best_speedup(),
        iterations: trace.records.len(),
        cost_usd: trace.total_cost_usd(),
        width_trace: trace.width_trace(),
        profile_runs: trace.profile_runs,
        llm_round_trips: llm.local_sims(),
        measure_sims: engine.local_sims(),
        wall_s: t0.elapsed().as_secs_f64(),
    };
    (result, records)
}

/// Run one round: dedup by fingerprint, execute representatives in
/// parallel, fan results back in round order. The returned trace-record
/// batches are in representative round order — append them as returned
/// to keep the trace log bytes scheduling-invariant.
pub fn run_round(env: &ExecEnv<'_>, round: &[Job], round_no: usize)
                 -> (Vec<JobResult>, Vec<Vec<TraceRecord>>) {
    // phase 1: dedup — first occurrence of a fingerprint executes
    let mut rep_of: HashMap<u64, usize> = HashMap::new();
    let mut reps: Vec<Job> = Vec::new();
    // for each round position: (representative index, is_share)
    let mut plan: Vec<(usize, bool)> = Vec::with_capacity(round.len());
    for job in round {
        match rep_of.get(&job.fingerprint) {
            Some(&ri) => plan.push((ri, true)),
            None => {
                let ri = reps.len();
                rep_of.insert(job.fingerprint, ri);
                reps.push(*job);
                plan.push((ri, false));
            }
        }
    }

    // phase 2: execute representatives in parallel (results are pure
    // functions of the job spec, so scheduling never matters)
    let executed: Vec<(JobResult, Option<Vec<TraceRecord>>)> =
        parallel_map(&reps, env.workers, |_, job| {
            execute(env, job, round_no)
        });

    // phase 3: fan-in in round order
    let mut out = Vec::with_capacity(round.len());
    for (job, &(ri, is_share)) in round.iter().zip(&plan) {
        let rep = &executed[ri].0;
        if is_share {
            out.push(JobResult {
                job: *job,
                round: round_no,
                shared: true,
                task_name: rep.task_name.clone(),
                correct: rep.correct,
                best_speedup: rep.best_speedup,
                iterations: rep.iterations,
                cost_usd: rep.cost_usd,
                width_trace: rep.width_trace.clone(),
                // a share does no work and takes no measurable time
                profile_runs: 0,
                llm_round_trips: 0,
                measure_sims: 0,
                wall_s: 0.0,
            });
        } else {
            out.push(rep.clone());
        }
    }
    let records: Vec<Vec<TraceRecord>> = executed
        .into_iter()
        .filter_map(|(_, recs)| recs)
        .collect();
    (out, records)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env<'a>(tasks: &'a [TaskSpec], specs: &'a [JobSpec],
               store: &'a Arc<TraceStore>) -> ExecEnv<'a> {
        ExecEnv {
            tasks,
            specs,
            store,
            workers: 2,
            round_span: AtomicU64::new(0),
        }
    }

    fn hot_tasks() -> Vec<TaskSpec> {
        let suite = crate::workload::Suite::full(1);
        suite.tasks.into_iter().step_by(41).take(2).collect()
    }

    // one identical-spec entry per seq: equal-fingerprint jobs must
    // carry equal specs (run_serve derives the fingerprint from them)
    fn specs(n: usize) -> Vec<JobSpec> {
        (0..n).map(|_| JobSpec::new(0, 0).iterations(12)).collect()
    }

    fn job(seq: usize, tenant: usize, task_idx: usize, fp: u64) -> Job {
        Job { seq, tenant, priority: 0, task_idx, fingerprint: fp }
    }

    #[test]
    fn round_pays_each_fingerprint_once_and_shares_the_rest() {
        let tasks = hot_tasks();
        let specs = specs(4);
        let store = Arc::new(TraceStore::in_memory());
        let e = env(&tasks, &specs, &store);
        let round = vec![
            job(0, 0, 0, 100),
            job(1, 1, 0, 100),
            job(2, 2, 0, 100),
            job(3, 0, 1, 200),
        ];
        let (results, records) = run_round(&e, &round, 0);
        assert_eq!(results.len(), 4);
        let executed: Vec<&JobResult> =
            results.iter().filter(|r| !r.shared).collect();
        assert_eq!(executed.len(), 2); // fingerprints 100 and 200
        // shares mirror their representative's deterministic outcome
        assert_eq!(results[1].best_speedup, results[0].best_speedup);
        assert_eq!(results[1].width_trace, results[0].width_trace);
        assert!(results[1].shared);
        assert_eq!(results[1].llm_round_trips, 0);
        assert_eq!(results[1].measure_sims, 0);
        assert_eq!(results[1].wall_s, 0.0);
        // representatives did real measured work
        assert!(results[0].wall_s > 0.0);
        assert!(results[0].measure_sims > 0);
        assert!(results[0].llm_round_trips > 0);
        // one fresh trace-record batch per execution
        assert_eq!(records.len(), 2);
    }

    #[test]
    fn warm_round_is_pure_lookups() {
        let tasks = hot_tasks();
        let specs = specs(2);
        let store = Arc::new(TraceStore::in_memory());
        let e = env(&tasks, &specs, &store);
        let round = vec![job(0, 0, 0, 100)];
        let (cold, _) = run_round(&e, &round, 0);
        assert!(cold[0].measure_sims > 0);
        // same fingerprint, later round: the shared session caches make
        // it a replay — zero sims, zero LLM round-trips, zero profiling
        let (warm, recs) = run_round(&e, &vec![job(1, 1, 0, 100)], 1);
        assert_eq!(warm[0].measure_sims, 0);
        assert_eq!(warm[0].llm_round_trips, 0);
        assert_eq!(warm[0].profile_runs, 0);
        assert!(!warm[0].shared); // executed, just fully cached
        assert!(recs.is_empty()); // pure replay appends nothing
        // and the result bits match the cold pass
        assert_eq!(warm[0].best_speedup.to_bits(),
                   cold[0].best_speedup.to_bits());
        assert_eq!(warm[0].width_trace, cold[0].width_trace);
    }
}
