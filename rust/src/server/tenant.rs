//! Per-tenant accounting for the real serving loop.
//!
//! A [`TenantLedger`] aggregates what one tenant's jobs actually did —
//! work performed vs work elided by the shared session caches — plus
//! the measured wall-clock its executed jobs spent. The deterministic
//! fields (submission/admission counts, completions, shares) go into
//! the byte-compared artifact section; the measured and
//! store-temperature-dependent fields (`wall_s`, `profile_runs`,
//! `llm_round_trips`, `measure_sims`) live in the uploaded service
//! ledger only, because they legitimately differ between a cold and a
//! warm pass over the same store.

/// Canonical tenant label used for store namespacing ("t0", "t1", …).
pub fn tenant_label(tenant: usize) -> String {
    format!("t{tenant}")
}

/// One tenant's aggregate ledger.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TenantLedger {
    pub tenant: usize,
    // --- deterministic section -------------------------------------
    /// Jobs the tenant submitted.
    pub submitted: usize,
    /// Jobs admission control accepted.
    pub admitted: usize,
    /// Jobs rejected at admission (queue capacity or tenant quota).
    pub rejected: usize,
    /// Admitted jobs dropped unexecuted because their deadline round
    /// had passed by the time the queue popped them.
    pub expired: usize,
    /// Jobs that ran to completion (executed or shared).
    pub completed: usize,
    /// Completions served by sharing a round-mate's identical run.
    pub shared: usize,
    // --- measured / store-temperature-dependent section ------------
    /// Representative NCU profilings actually recomputed. 0 for a
    /// tenant whose jobs were all warm (shared-cache lookups).
    pub profile_runs: u64,
    /// LLM round-trips actually performed (proposal-cache misses).
    /// 0 for a warm tenant — the real-path analogue of the modeled
    /// gateway bypass.
    pub llm_round_trips: u64,
    /// Measurements actually simulated (kernel-cache misses).
    pub measure_sims: u64,
    /// Measured wall-clock seconds of the tenant's executed jobs.
    pub wall_s: f64,
}

impl TenantLedger {
    pub fn new(tenant: usize) -> TenantLedger {
        TenantLedger { tenant, ..TenantLedger::default() }
    }

    /// True when every completed job was a pure lookup: nothing
    /// simulated, nothing proposed, nothing re-profiled.
    pub fn is_warm(&self) -> bool {
        self.completed > 0
            && self.profile_runs == 0
            && self.llm_round_trips == 0
            && self.measure_sims == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_stable() {
        assert_eq!(tenant_label(0), "t0");
        assert_eq!(tenant_label(12), "t12");
    }

    #[test]
    fn warm_means_zero_new_work() {
        let mut l = TenantLedger::new(1);
        assert!(!l.is_warm()); // nothing completed yet
        l.completed = 3;
        assert!(l.is_warm());
        l.llm_round_trips = 1;
        assert!(!l.is_warm());
    }
}
