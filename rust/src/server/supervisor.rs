//! Sharded serving supervisor: leases, crash recovery, preemption.
//!
//! The [`Sharded`] backend runs the same queue → dedup → execute →
//! fan-in loop as [`crate::server::InProcess`], but every
//! representative execution happens under a lease
//! ([`crate::server::lease`]) and checkpoints each iteration into the
//! store's journal ([`crate::store`]):
//!
//! ```text
//!  supervisor ──grant lease──▶ worker shard ──ckpt per iter──▶ store
//!      │                            │
//!      │◀── heartbeat (completion) ─┘
//!      │
//!      ├─ missed heartbeat → revoke lease, RESUME job from its
//!      │  checkpoint prefix (next tick) — not restart
//!      └─ preemption (a high-priority arrival claims the shard) →
//!         park lease at the iteration boundary, resume it next tick
//! ```
//!
//! Recovery is a *resume*, never a restart: the checkpoint journal
//! records each iteration's external effects (strategy pick, proposals,
//! measurements), and [`crate::policy::KernelBand::optimize_ctl`]
//! replays them without a single new engine or LLM call, landing on the
//! exact iteration boundary the dead worker reached. Because the split
//! RNG derives independent streams per `(label, t, slot)`, the live
//! iterations that follow consume exactly the draws an uninterrupted
//! run would have — so a recovered run's deterministic artifact and
//! trace bytes are byte-identical to an uninterrupted one, and no
//! fingerprint's iteration is ever executed twice (the supervisor
//! ledger counts `double_executed` and CI pins it at zero).
//!
//! Fault injection ([`FaultPlan`]) is fully seeded: `kill-after=K`
//! kills each fingerprint's worker once after K completed iterations
//! (modeling a missed heartbeat deadline); `preempt=P` parks a running
//! lease at an iteration boundary with probability P per boundary
//! (modeling a high-priority submission claiming the shard). Neither
//! touches the jobs' own RNG streams, so faulted schedules replay
//! bit-for-bit.

use std::cell::Cell;
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::engine::SimEngine;
use crate::llm::SurrogateLlm;
use crate::policy::resume::{Checkpoint, RunCtl};
use crate::policy::{KernelBand, PolicyConfig};
use crate::rng::Rng;
use crate::sched::SchedContext;
use crate::server::api::{
    FaultPlan, ServeBackend, ServeOutcome, ServeRequest,
};
use crate::server::lease::{LeaseState, LeaseTable};
use crate::server::queue::Job;
use crate::server::recover::reconcile;
use crate::server::tenant::tenant_label;
use crate::server::worker::{ExecEnv, JobResult};
use crate::server::{run_serve, ServeReport};
use crate::store::log::{records_for_trace_tenant, TraceRecord};
use crate::store::wrap::{CachedEngine, CachedLlm};
use crate::store::TraceStore;
use crate::util::json::Json;
use crate::util::par::parallel_map;

/// The sharded serving backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct Sharded;

/// Why an attempt stopped early.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Interruption {
    /// The fault plan killed the worker (missed heartbeat).
    Killed,
    /// A high-priority arrival preempted the shard.
    Preempted,
}

/// One execution attempt's outcome.
struct AttemptOut {
    /// `Some` when the run completed its full budget.
    result: Option<(JobResult, Option<Vec<TraceRecord>>)>,
    interrupted: Option<Interruption>,
    /// Iterations this attempt executed live (replayed ones excluded);
    /// the double-execution ledger is built from these.
    live_ts: Vec<usize>,
}

/// Supervisor state carried across rounds.
struct SupState {
    lease: LeaseTable,
    /// Fingerprints whose worker the kill plan already claimed (each is
    /// killed exactly once, so recovery is guaranteed to converge).
    killed: Mutex<HashSet<u64>>,
    /// `(fingerprint, t)` boundaries already preempted once (a retry
    /// is never re-parked at the same boundary, so progress is
    /// guaranteed).
    parked: Mutex<HashSet<(u64, usize)>>,
    /// Every iteration each fingerprint executed live, across all
    /// attempts. A collision is a double execution.
    executed_iters: HashMap<u64, HashSet<usize>>,
    double_executed: u64,
    ticks: usize,
    recovered_jobs: usize,
    recovered_iterations: usize,
    /// Completion heartbeats received (one per finished attempt).
    heartbeats: u64,
}

/// Execute one leased attempt: resume from the store's checkpoint
/// prefix, checkpoint every live iteration back into the store, stop at
/// an iteration boundary if the fault plan fires.
fn attempt(env: &ExecEnv<'_>, job: &Job, round: usize,
           fault: &FaultPlan, killed: &Mutex<HashSet<u64>>,
           parked: &Mutex<HashSet<(u64, usize)>>) -> AttemptOut {
    let t0 = Instant::now();
    let spec = &env.specs[job.seq];
    let task = &env.tasks[job.task_idx];
    let fp = job.fingerprint;
    let engine =
        CachedEngine::new(SimEngine::new(spec.device), env.store.clone());
    let llm =
        CachedLlm::new(SurrogateLlm::new(spec.llm), env.store.clone());
    // same causal-trace anchor as the in-process worker: the attempt's
    // job span parents under the current round span and each job keeps
    // its own track lane
    let rec = env.store.recorder();
    let track = crate::obs::trace::TRACK_JOBS + job.seq as u64;
    let jspan = rec.as_ref().and_then(|r| r.trace()).map(|s| {
        s.begin(
            "serve.job",
            env.round_span.load(std::sync::atomic::Ordering::Relaxed),
            track,
            crate::util::json::Json::obj(vec![
                (
                    "seq",
                    crate::util::json::Json::num(job.seq as f64),
                ),
                (
                    "tenant",
                    crate::util::json::Json::num(job.tenant as f64),
                ),
                (
                    "task",
                    crate::util::json::Json::str(task.name.clone()),
                ),
            ]),
        )
    });
    let job_obs = rec
        .as_ref()
        .filter(|r| r.trace().is_some() || r.decisions().is_some())
        .map(|_| crate::sched::JobObs {
            span: jspan.unwrap_or(0),
            track,
            label: std::sync::Arc::from(
                format!("r{round}/j{} {}", job.seq, task.name).as_str(),
            ),
        });
    let ctx = SchedContext {
        mode: spec.batch,
        centroids: Some(env.store.session_centroids()),
        profiles: Some(env.store.profiles()),
        obs: rec.clone(),
        job: job_obs,
    };
    let mut cfg = PolicyConfig::default();
    cfg.iterations = spec.iterations;
    let prefix = env.store.ckpt_prefix(fp);
    let mut live_ts: Vec<usize> = Vec::new();
    let cause: Cell<Option<Interruption>> = Cell::new(None);
    let stop = |t: usize| -> bool {
        if let Some(k) = fault.kill_after {
            let mut dead = killed.lock().unwrap();
            if t > k && !dead.contains(&fp) {
                dead.insert(fp);
                cause.set(Some(Interruption::Killed));
                return true;
            }
        }
        if fault.preempt_prob > 0.0 {
            let mut draw = Rng::new(fault.seed)
                .split("preempt", fp)
                .split("t", t as u64);
            if draw.chance(fault.preempt_prob)
                && parked.lock().unwrap().insert((fp, t))
            {
                cause.set(Some(Interruption::Preempted));
                return true;
            }
        }
        false
    };
    let run = {
        let mut sink = |c: &Checkpoint| {
            env.store.ckpt_append(fp, c);
            live_ts.push(c.t);
        };
        let mut ctl = RunCtl {
            resume: &prefix,
            sink: Some(&mut sink),
            interrupt: Some(&stop),
        };
        KernelBand::new(cfg).optimize_ctl(
            task,
            &engine,
            &llm,
            &Rng::new(spec.seed),
            None,
            &ctx,
            &mut ctl,
        )
    };
    if let (Some(r), Some(id)) = (&rec, jspan) {
        if let Some(s) = r.trace() {
            s.end(id);
        }
    }
    if !run.completed {
        return AttemptOut {
            result: None,
            interrupted: Some(
                cause.get().unwrap_or(Interruption::Killed),
            ),
            live_ts,
        };
    }
    env.store.ckpt_retire(fp);
    let trace = run.trace;
    // online regret for the completed attempt (exact on grammar tasks)
    if let Some(r) = rec.as_ref().filter(|r| r.enabled()) {
        let oracle = crate::obs::regret::latent_oracle_latency_s(
            task,
            spec.device,
        );
        let (curve, exact) =
            crate::obs::regret::regret_curve(&trace, oracle);
        r.observe_regret(&curve, exact);
    }
    // same pure-replay guard as the in-process worker: a run served
    // entirely from cache appends no duplicate trace records
    let fresh = engine.local_sims() + llm.local_sims() > 0;
    let records = fresh.then(|| {
        records_for_trace_tenant(
            "serve",
            Some(&tenant_label(job.tenant)),
            spec.device.name(),
            spec.llm.spec().name,
            spec.seed,
            &trace,
        )
    });
    let result = JobResult {
        job: *job,
        round,
        shared: false,
        task_name: trace.task_name.clone(),
        correct: trace.correct(),
        best_speedup: trace.best_speedup(),
        iterations: trace.records.len(),
        cost_usd: trace.total_cost_usd(),
        width_trace: trace.width_trace(),
        profile_runs: trace.profile_runs,
        llm_round_trips: llm.local_sims(),
        measure_sims: engine.local_sims(),
        wall_s: t0.elapsed().as_secs_f64(),
    };
    AttemptOut {
        result: Some((result, records)),
        interrupted: None,
        live_ts,
    }
}

/// One supervised round: dedup like the in-process worker, then run
/// leased attempts in ticks until every representative completes.
/// Interrupted attempts come back in the next tick and *resume* from
/// their banked checkpoints.
fn run_round_sharded(state: &mut SupState, env: &ExecEnv<'_>,
                     round: &[Job], round_no: usize, fault: &FaultPlan)
                     -> (Vec<JobResult>, Vec<Vec<TraceRecord>>) {
    // phase 1: dedup — first occurrence of a fingerprint executes
    let mut rep_of: HashMap<u64, usize> = HashMap::new();
    let mut reps: Vec<Job> = Vec::new();
    let mut plan: Vec<(usize, bool)> = Vec::with_capacity(round.len());
    for job in round {
        match rep_of.get(&job.fingerprint) {
            Some(&ri) => plan.push((ri, true)),
            None => {
                let ri = reps.len();
                rep_of.insert(job.fingerprint, ri);
                reps.push(*job);
                plan.push((ri, false));
            }
        }
    }

    // phase 2: leased execution in ticks
    let shards = env.workers.max(1);
    let mut done: HashMap<u64, (JobResult, Option<Vec<TraceRecord>>)> =
        HashMap::new();
    let mut pending: Vec<Job> = reps.clone();
    let mut tick = 0usize;
    while !pending.is_empty() {
        let stamp = (round_no, tick);
        for (i, job) in pending.iter().enumerate() {
            let fp = job.fingerprint;
            match state.lease.state(fp) {
                Some(LeaseState::Parked) => {
                    state
                        .lease
                        .resume(fp, i % shards, stamp)
                        .expect("parked lease resumes");
                }
                _ => {
                    state
                        .lease
                        .grant(fp, i % shards, stamp)
                        .expect("no live lease: single-executor guard");
                }
            }
        }
        let outs: Vec<AttemptOut> =
            parallel_map(&pending, env.workers, |_, job| {
                attempt(env, job, round_no, fault, &state.killed,
                        &state.parked)
            });
        let mut next = Vec::new();
        for (job, out) in pending.iter().zip(outs) {
            let fp = job.fingerprint;
            let seen = state.executed_iters.entry(fp).or_default();
            for t in out.live_ts {
                if !seen.insert(t) {
                    state.double_executed += 1;
                }
            }
            match out.result {
                Some((res, recs)) => {
                    state.lease.heartbeat(fp, stamp);
                    state.heartbeats += 1;
                    state.lease.complete(fp, stamp);
                    done.insert(fp, (res, recs));
                }
                None => match out
                    .interrupted
                    .unwrap_or(Interruption::Killed)
                {
                    Interruption::Killed => {
                        // no heartbeat since the grant: the lease is
                        // past its deadline, reclaim it
                        debug_assert!(state.lease.expired(fp, stamp));
                        state.lease.revoke(fp, stamp);
                        next.push(*job);
                    }
                    Interruption::Preempted => {
                        state.lease.park(fp, stamp);
                        next.push(*job);
                    }
                },
            }
        }
        pending = next;
        tick += 1;
        state.ticks += 1;
    }

    // phase 3: fan-in in round order; trace-record batches in
    // representative order (identical to the in-process worker, so
    // trace bytes never depend on faults, ticks or shard scheduling)
    let records: Vec<Vec<TraceRecord>> = reps
        .iter()
        .filter_map(|r| {
            done.get_mut(&r.fingerprint)
                .and_then(|(_, recs)| recs.take())
        })
        .collect();
    let mut results = Vec::with_capacity(round.len());
    for (job, &(ri, is_share)) in round.iter().zip(&plan) {
        let rep = &done[&reps[ri].fingerprint].0;
        if is_share {
            results.push(JobResult {
                job: *job,
                round: round_no,
                shared: true,
                task_name: rep.task_name.clone(),
                correct: rep.correct,
                best_speedup: rep.best_speedup,
                iterations: rep.iterations,
                cost_usd: rep.cost_usd,
                width_trace: rep.width_trace.clone(),
                profile_runs: 0,
                llm_round_trips: 0,
                measure_sims: 0,
                wall_s: 0.0,
            });
        } else {
            results.push(rep.clone());
        }
    }
    (results, records)
}

fn supervisor_ledger(state: &SupState, req: &ServeRequest) -> Json {
    let (granted, resumed, revoked, parked, completed) =
        state.lease.counters();
    let events: Vec<Json> = state
        .lease
        .events()
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("round", Json::num(e.stamp.0 as f64)),
                ("tick", Json::num(e.stamp.1 as f64)),
                (
                    "fingerprint",
                    Json::str(format!("{:016x}", e.fingerprint)),
                ),
                ("worker", Json::num(e.worker as f64)),
                ("what", Json::str(e.what)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("backend", Json::str("sharded")),
        ("workers", Json::num(req.workers as f64)),
        (
            "kill_after",
            req.fault
                .kill_after
                .map_or(Json::Null, |k| Json::num(k as f64)),
        ),
        ("preempt_prob", Json::num(req.fault.preempt_prob)),
        ("fault_seed", Json::num(req.fault.seed as f64)),
        ("recovered_jobs", Json::num(state.recovered_jobs as f64)),
        (
            "recovered_iterations",
            Json::num(state.recovered_iterations as f64),
        ),
        ("leases", Json::num(granted as f64)),
        ("resumed", Json::num(resumed as f64)),
        ("revoked", Json::num(revoked as f64)),
        ("parked", Json::num(parked as f64)),
        ("completed", Json::num(completed as f64)),
        ("ticks", Json::num(state.ticks as f64)),
        (
            "double_executed",
            Json::num(state.double_executed as f64),
        ),
        ("events", Json::Arr(events)),
    ])
}

impl Sharded {
    /// Run the request and return the serve report plus the supervisor
    /// ledger (lease counters + event log).
    pub fn run_report(&self, req: &ServeRequest,
                      store: &Arc<TraceStore>) -> (ServeReport, Json) {
        // durability advisory: the store loaded over corrupt or torn
        // lines (skipped, not fatal). Recovery proceeds from the
        // surviving records; recommend a repair pass on stderr so the
        // deterministic stdout stream is untouched.
        let corrupt = store.loaded.corrupt_files();
        if !corrupt.is_empty() {
            let total: usize = corrupt.iter().map(|&(_, n)| n).sum();
            eprintln!(
                "[supervisor] store loaded with {total} corrupt \
                 line(s) skipped; run `kernelband trace fsck \
                 <STORE-DIR> --repair` to quarantine and compact"
            );
            if let Some(obs) = store.recorder() {
                obs.add("server.store_corrupt_lines", total as u64);
                for &(file, n) in &corrupt {
                    obs.event(
                        "store_corruption",
                        Json::obj(vec![
                            ("file", Json::str(file)),
                            ("skipped_lines", Json::num(n as f64)),
                        ]),
                    );
                }
            }
        }
        // crash recovery: anything a previous session left in the
        // checkpoint journal resumes instead of restarting
        let rec = reconcile(store);
        let mut state = SupState {
            lease: LeaseTable::new(),
            killed: Mutex::new(HashSet::new()),
            parked: Mutex::new(HashSet::new()),
            executed_iters: HashMap::new(),
            double_executed: 0,
            ticks: 0,
            recovered_jobs: rec.pending.len(),
            recovered_iterations: rec.banked_iterations(),
            heartbeats: 0,
        };
        let fault = req.fault;
        let mut report = run_serve(req, store, &mut |env, round, r| {
            run_round_sharded(&mut state, env, round, r, &fault)
        });
        let (granted, resumed, revoked, parked, completed) =
            state.lease.counters();
        report.supervisor = Some(crate::server::SupCounts {
            leases: granted,
            revoked,
            parked,
            resumed,
            completed,
            heartbeats: state.heartbeats,
            double_executed: state.double_executed,
            recovered_jobs: state.recovered_jobs as u64,
            recovered_iterations: state.recovered_iterations as u64,
        });
        // advisory telemetry: lease lifecycle counters plus (with
        // `--obs events`) the full lease event log. Never consulted by
        // anything deterministic.
        if let Some(obs) = store.recorder() {
            obs.add("server.lease.grant", granted);
            obs.add("server.lease.resume", resumed);
            obs.add("server.lease.revoke", revoked);
            obs.add("server.lease.park", parked);
            obs.add("server.lease.complete", completed);
            obs.add("server.lease.heartbeat", state.heartbeats);
            for e in state.lease.events() {
                obs.event(
                    "lease",
                    Json::obj(vec![
                        ("what", Json::str(e.what)),
                        ("round", Json::num(e.stamp.0 as f64)),
                        ("tick", Json::num(e.stamp.1 as f64)),
                        (
                            "fingerprint",
                            Json::str(format!(
                                "{:016x}",
                                e.fingerprint
                            )),
                        ),
                        ("worker", Json::num(e.worker as f64)),
                    ]),
                );
            }
        }
        let ledger = supervisor_ledger(&state, req);
        (report, ledger)
    }
}

impl ServeBackend for Sharded {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn run(&self, req: &ServeRequest,
           store: Option<&Arc<TraceStore>>) -> Result<ServeOutcome> {
        let owned;
        let store = match store {
            Some(s) => s,
            None => {
                owned = Arc::new(TraceStore::in_memory());
                &owned
            }
        };
        let (report, sup) = self.run_report(req, store);
        // the supervisor line now comes from summary_lines() (the
        // report carries SupCounts), same format as before
        let lines = report.summary_lines();
        Ok(ServeOutcome {
            deterministic: report.deterministic_json(),
            ledger: Some(report.ledger_json()),
            supervisor: Some(sup),
            lines,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_model::Device;
    use crate::llm::LlmProfile;
    use crate::sched::BatchMode;
    use crate::server::InProcess;

    fn small_req() -> ServeRequest {
        let mut req = ServeRequest::grid(
            2,
            2,
            6,
            BatchMode::Fixed(1),
            2,
            Device::H20,
            LlmProfile::DeepSeekV32,
            7,
        );
        req.workers = 2;
        req
    }

    #[test]
    fn unfaulted_sharded_matches_inprocess_bytes() {
        let req = small_req();
        let s1 = Arc::new(TraceStore::in_memory());
        let inproc = InProcess.run_report(&req, &s1);
        let s2 = Arc::new(TraceStore::in_memory());
        let (sharded, sup) = Sharded.run_report(&req, &s2);
        assert_eq!(
            inproc.deterministic_json().dump(),
            sharded.deterministic_json().dump()
        );
        assert_eq!(sup.f64_field("revoked"), 0.0);
        assert_eq!(sup.f64_field("parked"), 0.0);
        assert_eq!(sup.f64_field("double_executed"), 0.0);
        // every representative leased exactly once, all completed
        assert_eq!(sup.f64_field("leases"), sup.f64_field("completed"));
        // clean runs retire every checkpoint
        assert!(s2.ckpt_live().is_empty());
    }

    #[test]
    fn killed_workers_resume_to_identical_bytes() {
        let mut faulted = small_req();
        faulted.fault.kill_after = Some(2);
        let s1 = Arc::new(TraceStore::in_memory());
        let clean = InProcess.run_report(&small_req(), &s1);
        let s2 = Arc::new(TraceStore::in_memory());
        let (recovered, sup) = Sharded.run_report(&faulted, &s2);
        assert_eq!(
            clean.deterministic_json().dump(),
            recovered.deterministic_json().dump()
        );
        assert!(sup.f64_field("revoked") > 0.0);
        assert!(sup.f64_field("resumed") > 0.0);
        assert_eq!(sup.f64_field("double_executed"), 0.0);
        assert!(s2.ckpt_live().is_empty());
    }

    #[test]
    fn preemption_parks_and_resumes_without_drift() {
        let mut faulted = small_req();
        faulted.fault.preempt_prob = 0.6;
        faulted.fault.seed = 11;
        let s1 = Arc::new(TraceStore::in_memory());
        let clean = InProcess.run_report(&small_req(), &s1);
        let s2 = Arc::new(TraceStore::in_memory());
        let (preempted, sup) = Sharded.run_report(&faulted, &s2);
        assert_eq!(
            clean.deterministic_json().dump(),
            preempted.deterministic_json().dump()
        );
        assert!(sup.f64_field("parked") > 0.0, "ledger: {}", sup.dump());
        assert_eq!(sup.f64_field("parked"), sup.f64_field("resumed"));
        assert_eq!(sup.f64_field("double_executed"), 0.0);
    }
}
