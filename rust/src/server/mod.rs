//! Real-workload serving subsystem: a multi-tenant job queue driving
//! actual `KernelBand` optimization runs, behind a typed job API.
//!
//! Callers describe work with [`JobSpec`]s bundled into a
//! [`ServeRequest`] and pick a [`ServeBackend`]:
//!
//! ```text
//!  JobSpec builder ──▶ ServeRequest ──▶ ServeBackend::run
//!                                           │
//!              ┌────────────────────────────┼──────────────────┐
//!              ▼                            ▼                  ▼
//!         InProcess                     Sharded             Modeled
//!      queue → workers          supervisor → leases →    TimeModel
//!      (this module)            worker shards, ckpt      simulation
//!                               recovery + preemption    (smokes)
//! ```
//!
//! * [`api`] — [`JobSpec`], [`ServeRequest`], [`FaultPlan`], the
//!   [`ServeBackend`] trait and the [`Modeled`] backend;
//! * [`queue`] — priority queue with admission control (global
//!   capacity + per-tenant quota) and deterministic deficit-round-robin
//!   fairness;
//! * [`worker`] — executes each round's distinct fingerprints as real
//!   [`crate::policy::KernelBand::optimize_sched`] runs over suite
//!   tasks, sharing the session [`crate::store::TraceStore`],
//!   [`crate::sched::centroids::CentroidCache`] and
//!   [`crate::sched::profiles::SharedProfiles`] across tenants — a
//!   fingerprint pays real work once per round (round-mates share) and
//!   resumes warm in later rounds and later sessions (pure lookups);
//! * [`supervisor`] / [`lease`] / [`recover`] — the [`Sharded`]
//!   backend: leased worker shards, per-iteration checkpointing into
//!   the store journal, crash recovery that *resumes* (never restarts)
//!   a killed worker's job, and seeded preemption that parks a lease
//!   at an iteration boundary;
//! * [`tenant`] — per-tenant ledgers and the store namespacing labels;
//! * [`adaptive`] — serving-facing re-export of the AIMD batch-width
//!   controller behind `--batch auto` (it lives in
//!   [`crate::sched::adaptive`], where it hooks into the policy's
//!   batch planning).
//!
//! ## Determinism contract
//!
//! Admission, round composition, dedup, per-job traces, adaptive width
//! sequences, costs and speedups are pure functions of the
//! [`ServeRequest`] — independent of real-backend choice (`InProcess`
//! vs `Sharded`), worker count, worker timing, injected faults and
//! store temperature — and live in the artifact's byte-compared
//! sections ([`ServeReport::deterministic_json`]). Measured wall-clock
//! and cache-temperature counters (profile runs, LLM round-trips,
//! simulated measurements) are real observations that legitimately
//! vary; they live only in the uploaded service ledger
//! ([`ServeReport::ledger_json`]). No `TIME_SCALE` anywhere on this
//! path.

pub mod adaptive;
pub mod api;
pub mod lease;
pub mod queue;
pub mod recover;
pub mod supervisor;
pub mod tenant;
pub mod worker;

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::gpu_model::Device;
use crate::llm::LlmProfile;
use crate::sched::BatchMode;
use crate::store::log::TraceRecord;
use crate::store::TraceStore;
use crate::util::hash::KeyHasher;
use crate::util::json::Json;
use crate::workload::{Suite, TaskSpec};

use self::queue::{Job, JobQueue};
use self::tenant::{tenant_label, TenantLedger};
use self::worker::{run_round, ExecEnv, JobResult};

pub use self::api::{
    FaultPlan, JobSpec, Modeled, OpenLoopPlan, ServeBackend,
    ServeOutcome, ServeRequest,
};
pub use self::supervisor::Sharded;

/// Exact (nearest-rank) percentiles over a measured sample. Used for
/// the open-loop latency report; wall-clock derived, so it lives only
/// in the measured ledger, never in byte-compared artifacts.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

impl Percentiles {
    pub fn from_samples(xs: &[f64]) -> Percentiles {
        if xs.is_empty() {
            return Percentiles::default();
        }
        let mut s: Vec<f64> = xs.to_vec();
        s.sort_by(|a, b| {
            a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
        });
        let rank = |q: f64| -> f64 {
            let k = (q * s.len() as f64).ceil() as usize;
            s[k.clamp(1, s.len()) - 1]
        };
        Percentiles {
            p50: rank(0.50),
            p95: rank(0.95),
            p99: rank(0.99),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            max: *s.last().unwrap(),
        }
    }

    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("p50_s", Json::num(self.p50)),
            ("p95_s", Json::num(self.p95)),
            ("p99_s", Json::num(self.p99)),
            ("mean_s", Json::num(self.mean)),
            ("max_s", Json::num(self.max)),
        ])
    }
}

/// Lease/recovery counters from the sharded supervisor, surfaced into
/// the measured ledger and summary lines. Fault- and timing-dependent,
/// so never part of the byte-compared deterministic artifact.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SupCounts {
    pub leases: u64,
    pub revoked: u64,
    pub parked: u64,
    pub resumed: u64,
    pub completed: u64,
    pub heartbeats: u64,
    pub double_executed: u64,
    pub recovered_jobs: u64,
    pub recovered_iterations: u64,
}

/// Per-job queue-wait and end-to-end latency samples from an open-loop
/// run (`--open-loop rate=R,duration=D`). Pure wall-clock observations:
/// the paced schedule executes the exact same rounds as the closed-loop
/// drain, so this struct only ever feeds the measured ledger.
#[derive(Debug, Clone, Default)]
pub struct OpenLoopStats {
    /// Target arrival rate (jobs per second).
    pub rate: f64,
    /// Arrival-window length the request was sized for.
    pub duration_s: f64,
    /// Seconds each completed job waited between its modeled arrival
    /// and its round starting to execute.
    pub queue_wait_s: Vec<f64>,
    /// Seconds between each completed job's modeled arrival and its
    /// round finishing (end-to-end latency; shares complete with their
    /// round-mate).
    pub latency_s: Vec<f64>,
}

impl OpenLoopStats {
    pub fn arrivals(&self) -> usize {
        self.latency_s.len()
    }

    pub fn queue_wait(&self) -> Percentiles {
        Percentiles::from_samples(&self.queue_wait_s)
    }

    pub fn latency(&self) -> Percentiles {
        Percentiles::from_samples(&self.latency_s)
    }
}

/// Header values of the deterministic artifact, derived from the
/// request's job list (a [`ServeRequest::grid`] round-trips exactly).
#[derive(Debug, Clone)]
pub struct ServeHeader {
    pub batch: BatchMode,
    pub tenants: usize,
    pub jobs_per_tenant: usize,
    pub iterations: usize,
    pub task_variety: usize,
    pub seed: u64,
    pub device: Device,
    pub llm: LlmProfile,
}

/// Outcome of a real serve run. See the module docs for which fields
/// are deterministic and which are measured.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub header: ServeHeader,
    pub jobs: Vec<JobResult>,
    pub tenants: Vec<TenantLedger>,
    /// Scheduling rounds the queue drained into.
    pub rounds: usize,
    /// Jobs that performed a real execution (distinct fingerprints,
    /// summed over rounds).
    pub executions: usize,
    /// Jobs served by sharing a round-mate's identical execution.
    pub dedup_shares: usize,
    pub admitted: usize,
    pub rejected: usize,
    /// Admitted jobs dropped at pop time because their deadline round
    /// had already passed.
    pub expired: usize,
    // --- measured / store-temperature-dependent ---------------------
    /// Measured end-to-end wall-clock of the run (seconds).
    pub wall_s: f64,
    /// Session re-clustering memo hits/misses (work elided vs paid).
    pub centroid_hits: u64,
    pub centroid_misses: u64,
    /// Store counters observed this run (0 sims on a warm store pass).
    pub store_measure_sims: u64,
    pub store_measure_hits: u64,
    pub store_llm_sims: u64,
    pub store_llm_hits: u64,
    /// Supervisor lease/recovery counters (sharded backend only).
    pub supervisor: Option<SupCounts>,
    /// Arrival-paced latency samples (`--open-loop` runs only).
    pub open_loop: Option<OpenLoopStats>,
}

impl ServeReport {
    /// Total measured wall-clock across executed jobs (excludes queue
    /// orchestration; shares are free).
    pub fn job_wall_s(&self) -> f64 {
        self.jobs.iter().map(|j| j.wall_s).sum()
    }

    /// The byte-compared artifact section: every field here is a pure
    /// function of the [`ServeRequest`] — re-running the same request
    /// against any store temperature with any worker count, any real
    /// backend and any fault plan must reproduce these bytes exactly
    /// (CI `cmp`s them).
    pub fn deterministic_json(&self) -> Json {
        let jobs = self
            .jobs
            .iter()
            .map(|j| {
                Json::obj(vec![
                    ("seq", Json::num(j.job.seq as f64)),
                    ("tenant", Json::str(tenant_label(j.job.tenant))),
                    ("priority", Json::num(j.job.priority as f64)),
                    ("task", Json::str(j.task_name.clone())),
                    (
                        "fingerprint",
                        Json::str(format!("{:016x}", j.job.fingerprint)),
                    ),
                    ("round", Json::num(j.round as f64)),
                    ("shared", Json::Bool(j.shared)),
                    ("correct", Json::Bool(j.correct)),
                    ("best_speedup", Json::num(j.best_speedup)),
                    ("cost_usd", Json::num(j.cost_usd)),
                    ("iterations", Json::num(j.iterations as f64)),
                    (
                        "widths",
                        Json::Arr(
                            j.width_trace
                                .iter()
                                .map(|&w| Json::num(w as f64))
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let tenants = self
            .tenants
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("tenant", Json::str(tenant_label(t.tenant))),
                    ("submitted", Json::num(t.submitted as f64)),
                    ("admitted", Json::num(t.admitted as f64)),
                    ("rejected", Json::num(t.rejected as f64)),
                    ("expired", Json::num(t.expired as f64)),
                    ("completed", Json::num(t.completed as f64)),
                    ("shared", Json::num(t.shared as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema_version", Json::num(2.0)),
            ("experiment", Json::str("serve")),
            ("mode", Json::str("real")),
            ("batch", Json::str(self.header.batch.label())),
            ("tenants", Json::num(self.header.tenants as f64)),
            (
                "jobs_per_tenant",
                Json::num(self.header.jobs_per_tenant as f64),
            ),
            ("iterations", Json::num(self.header.iterations as f64)),
            (
                "task_variety",
                Json::num(self.header.task_variety as f64),
            ),
            ("seed", Json::num(self.header.seed as f64)),
            ("device", Json::str(self.header.device.name())),
            ("llm", Json::str(self.header.llm.spec().name)),
            ("rounds", Json::num(self.rounds as f64)),
            ("executions", Json::num(self.executions as f64)),
            ("dedup_shares", Json::num(self.dedup_shares as f64)),
            ("admitted", Json::num(self.admitted as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("expired", Json::num(self.expired as f64)),
            ("jobs", Json::Arr(jobs)),
            ("tenant_ledger", Json::Arr(tenants)),
        ])
    }

    /// The full service ledger (uploaded as a CI artifact, never
    /// byte-compared): the deterministic section plus measured
    /// wall-clock and cache-temperature observations.
    pub fn ledger_json(&self) -> Json {
        let mut root = self.deterministic_json();
        root.insert("wall_s", Json::num(self.wall_s));
        root.insert("job_wall_s", Json::num(self.job_wall_s()));
        root.insert("centroid_hits", Json::num(self.centroid_hits as f64));
        root.insert(
            "centroid_misses",
            Json::num(self.centroid_misses as f64),
        );
        root.insert(
            "measure_sims",
            Json::num(self.store_measure_sims as f64),
        );
        root.insert(
            "measure_hits",
            Json::num(self.store_measure_hits as f64),
        );
        root.insert("llm_sims", Json::num(self.store_llm_sims as f64));
        root.insert("llm_hits", Json::num(self.store_llm_hits as f64));
        let walls: Vec<Json> = self
            .jobs
            .iter()
            .map(|j| Json::num(j.wall_s))
            .collect();
        root.insert("job_walls_s", Json::Arr(walls));
        let tenant_measured: Vec<Json> = self
            .tenants
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("tenant", Json::str(tenant_label(t.tenant))),
                    ("profile_runs", Json::num(t.profile_runs as f64)),
                    (
                        "llm_round_trips",
                        Json::num(t.llm_round_trips as f64),
                    ),
                    ("measure_sims", Json::num(t.measure_sims as f64)),
                    ("wall_s", Json::num(t.wall_s)),
                    ("warm", Json::Bool(t.is_warm())),
                ])
            })
            .collect();
        root.insert("tenant_measured", Json::Arr(tenant_measured));
        if let Some(s) = &self.supervisor {
            root.insert(
                "supervisor_counts",
                Json::obj(vec![
                    ("leases", Json::num(s.leases as f64)),
                    ("revoked", Json::num(s.revoked as f64)),
                    ("parked", Json::num(s.parked as f64)),
                    ("resumed", Json::num(s.resumed as f64)),
                    ("completed", Json::num(s.completed as f64)),
                    ("heartbeats", Json::num(s.heartbeats as f64)),
                    (
                        "double_executed",
                        Json::num(s.double_executed as f64),
                    ),
                    (
                        "recovered_jobs",
                        Json::num(s.recovered_jobs as f64),
                    ),
                    (
                        "recovered_iterations",
                        Json::num(s.recovered_iterations as f64),
                    ),
                ]),
            );
        }
        if let Some(o) = &self.open_loop {
            root.insert(
                "open_loop",
                Json::obj(vec![
                    ("rate_jobs_per_s", Json::num(o.rate)),
                    ("duration_s", Json::num(o.duration_s)),
                    ("arrivals", Json::num(o.arrivals() as f64)),
                    ("queue_wait", o.queue_wait().json()),
                    ("latency", o.latency().json()),
                ]),
            );
        }
        root
    }

    /// The human-readable summary the CLI prints. Backends may append
    /// their own lines (the sharded supervisor adds a lease summary).
    pub fn summary_lines(&self) -> Vec<String> {
        let h = &self.header;
        let mut lines = vec![
            format!(
                "serve[real]: {} tenants x {} jobs x {} iters  batch {}  device {}  llm {}",
                h.tenants,
                h.jobs_per_tenant,
                h.iterations,
                h.batch.label(),
                h.device.name(),
                h.llm.spec().name,
            ),
            format!(
                "queue: admitted={} rejected={} expired={}  rounds={} executions={} dedup_shares={}",
                self.admitted,
                self.rejected,
                self.expired,
                self.rounds,
                self.executions,
                self.dedup_shares,
            ),
            format!(
                "wall: {:.4}s measured end-to-end  {:.4}s summed over executed jobs  centroid memo {} hits / {} misses",
                self.wall_s,
                self.job_wall_s(),
                self.centroid_hits,
                self.centroid_misses,
            ),
        ];
        for t in &self.tenants {
            lines.push(format!(
                "tenant t{}: submitted={} admitted={} rejected={} expired={} completed={} shared={} profile_runs={} llm_round_trips={} measure_sims={} wall={:.4}s{}",
                t.tenant,
                t.submitted,
                t.admitted,
                t.rejected,
                t.expired,
                t.completed,
                t.shared,
                t.profile_runs,
                t.llm_round_trips,
                t.measure_sims,
                t.wall_s,
                if t.is_warm() { " [warm]" } else { "" },
            ));
        }
        if let Some(s) = &self.supervisor {
            // keep this exact field layout: the CI recovery smoke greps
            // `supervisor: .*resumed=` and `double_executed=0` from it
            lines.push(format!(
                "supervisor: leases={} revoked={} parked={} resumed={} \
                 double_executed={} recovered={} heartbeats={}",
                s.leases,
                s.revoked,
                s.parked,
                s.resumed,
                s.double_executed,
                s.recovered_jobs,
                s.heartbeats,
            ));
        }
        if let Some(o) = &self.open_loop {
            let qw = o.queue_wait();
            let lat = o.latency();
            lines.push(format!(
                "open-loop: rate={:.2} jobs/s duration={:.2}s \
                 arrivals={}",
                o.rate,
                o.duration_s,
                o.arrivals(),
            ));
            lines.push(format!(
                "queue-wait: p50={:.4}s p95={:.4}s p99={:.4}s \
                 max={:.4}s",
                qw.p50, qw.p95, qw.p99, qw.max,
            ));
            lines.push(format!(
                "latency: p50={:.4}s p95={:.4}s p99={:.4}s max={:.4}s",
                lat.p50, lat.p95, lat.p99, lat.max,
            ));
        }
        lines
    }
}

/// Deterministic content fingerprint of a job's run spec: two jobs with
/// equal fingerprints perform bit-identical work.
pub fn job_fingerprint(task: &TaskSpec, device: Device, llm: LlmProfile,
                       iterations: usize, batch: BatchMode, seed: u64)
                       -> u64 {
    let mut h = KeyHasher::new("serve-job")
        .u64(task.id as u64)
        .str(&task.name)
        .str(device.name())
        .str(llm.spec().name)
        .u64(iterations as u64)
        .u64(seed);
    // normalized exactly like the controller (and the policy run_key):
    // configs that execute bit-identically must share a fingerprint,
    // or dedup/warm sharing silently stops working for them
    h = match batch {
        BatchMode::Fixed(n) => h.u64(n.max(1) as u64),
        BatchMode::Adaptive { min, max } => h
            .u64(u64::MAX)
            .u64(min.max(1) as u64)
            .u64(max.max(min).max(1) as u64),
    };
    h.finish()
}

/// Pick the serve hot set from the full suite: `variety` tasks spread
/// evenly across the 183-task space (deterministic).
pub fn hot_set(suite: &Suite, variety: usize) -> Vec<TaskSpec> {
    let variety = variety.clamp(1, suite.len().max(1));
    let stride = (suite.len() / variety).max(1);
    suite
        .tasks
        .iter()
        .step_by(stride)
        .take(variety)
        .cloned()
        .collect()
}

/// The shared serving skeleton both real backends run on: submit every
/// job (all admission decided before any work), drain rounds through
/// `exec_round`, append trace batches in canonical order, fan the
/// ledgers in. Per-tenant trace/profile counters are recorded into the
/// store's tenant namespace ([`TraceStore::tenant_add`]) for
/// `kernelband trace stats`.
pub(crate) fn run_serve(
    req: &ServeRequest,
    store: &Arc<TraceStore>,
    exec_round: &mut dyn FnMut(&ExecEnv<'_>, &[Job], usize)
        -> (Vec<JobResult>, Vec<Vec<TraceRecord>>),
) -> ServeReport {
    // grammar workloads serve their expanded space as the hot set;
    // the spec was validated at CLI parse time, so expansion only
    // fails for hand-built requests naming an unknown grammar
    let suite = match &req.workload {
        Some(spec) => Suite::from_grammar(spec)
            .expect("grammar workload validated at parse time"),
        None => Suite::full(crate::eval::EXPERIMENT_SEED),
    };
    let hot = hot_set(&suite, req.task_variety);
    let tenants_n = req.tenants();
    let first = req.jobs.first();
    let header = ServeHeader {
        batch: first.map_or(BatchMode::Fixed(1), |j| j.batch),
        tenants: tenants_n,
        jobs_per_tenant: req.jobs_per_tenant(),
        iterations: first.map_or(12, |j| j.iterations),
        task_variety: req.task_variety,
        seed: first.map_or(7, |j| j.seed),
        device: first.map_or(Device::H20, |j| j.device),
        llm: first.map_or(LlmProfile::DeepSeekV32, |j| j.llm),
    };

    // --- submission phase: all admission decided before any work, in
    // the request's submission order, so rejections are deterministic
    let mut queue = JobQueue::new(
        tenants_n,
        req.queue_capacity,
        req.per_tenant_quota,
    );
    let mut submitted = vec![0usize; tenants_n];
    for (seq, spec) in req.jobs.iter().enumerate() {
        let task_idx = spec.task_idx % hot.len();
        let fingerprint = job_fingerprint(
            &hot[task_idx],
            spec.device,
            spec.llm,
            spec.iterations,
            spec.batch,
            spec.seed,
        );
        submitted[spec.tenant] += 1;
        let _ = queue.submit(Job {
            seq,
            tenant: spec.tenant,
            priority: spec.priority,
            task_idx,
            fingerprint,
        });
    }
    let admitted_per_tenant: Vec<usize> = (0..tenants_n)
        .map(|t| submitted[t] - queue.rejected_for(t))
        .collect();

    // --- execution phase: drain rounds; snapshot store counters
    // around it so the report shows this run's observations even when
    // the session store is shared with other work
    let sims0 = store.stats.measure_sims.load(Ordering::Relaxed);
    let mhits0 = store.stats.measure_hits.load(Ordering::Relaxed);
    let llm0 = store.stats.llm_sims.load(Ordering::Relaxed);
    let lhits0 = store.stats.llm_hits.load(Ordering::Relaxed);
    let cent = store.session_centroids();
    let chits0 = cent.hits();
    let cmiss0 = cent.misses();
    let env = ExecEnv {
        tasks: &hot,
        specs: &req.jobs,
        store,
        workers: req.workers,
        round_span: std::sync::atomic::AtomicU64::new(0),
    };
    // causal trace root (`--obs trace`): request → round → job spans;
    // advisory, so the deterministic report bytes never see it
    let sink = store.recorder().and_then(|r| r.trace().cloned());
    let req_span = sink.as_ref().map(|s| {
        s.begin(
            "serve.request",
            0,
            crate::obs::trace::TRACK_SERVE,
            crate::util::json::Json::obj(vec![
                (
                    "tenants",
                    crate::util::json::Json::num(tenants_n as f64),
                ),
                (
                    "jobs",
                    crate::util::json::Json::num(req.jobs.len() as f64),
                ),
            ]),
        )
    });
    // advisory queue telemetry: noop handles when no recorder is
    // attached, so the closed-loop hot path pays a single branch
    let (qwait_h, lat_h) = match store.recorder() {
        Some(r) => (
            r.hist("server.queue_wait_us"),
            r.hist("server.job_latency_us"),
        ),
        None => (crate::obs::Hist::noop(), crate::obs::Hist::noop()),
    };
    // open-loop arrival model: job i of the request arrives i/rate
    // seconds into the run (closed-loop runs arrive all at once)
    let arrival_s = |seq: usize| -> f64 {
        match req.open_loop {
            Some(p) if p.rate > 0.0 => seq as f64 / p.rate,
            _ => 0.0,
        }
    };
    let mut queue_waits: Vec<f64> = Vec::new();
    let mut latencies: Vec<f64> = Vec::new();
    let t0 = Instant::now();
    let mut jobs: Vec<JobResult> = Vec::new();
    let mut rounds = 0usize;
    let mut expired_per_tenant = vec![0usize; tenants_n];
    let round_max = req.effective_round_max();
    while !queue.is_empty() {
        let round = queue.pop_round(round_max);
        // deadlines are enforced at pop time: an admitted job whose
        // deadline round has passed expires instead of executing
        let mut live = Vec::with_capacity(round.len());
        for job in round {
            let deadline = req.jobs[job.seq].deadline_rounds;
            if deadline.map_or(false, |d| d < rounds) {
                expired_per_tenant[job.tenant] += 1;
            } else {
                live.push(job);
            }
        }
        if !live.is_empty() {
            // open-loop pacing delays execution until every job in the
            // round has arrived; it never changes which jobs the round
            // holds, so deterministic bytes are untouched
            if req.open_loop.is_some() {
                let latest = live
                    .iter()
                    .map(|j| arrival_s(j.seq))
                    .fold(0.0, f64::max);
                let now = t0.elapsed().as_secs_f64();
                if latest > now {
                    std::thread::sleep(
                        std::time::Duration::from_secs_f64(
                            latest - now,
                        ),
                    );
                }
            }
            let round_tspan = sink.as_ref().map(|s| {
                s.begin(
                    "serve.round",
                    req_span.unwrap_or(0),
                    crate::obs::trace::TRACK_SERVE,
                    crate::util::json::Json::obj(vec![
                        (
                            "round",
                            crate::util::json::Json::num(rounds as f64),
                        ),
                        (
                            "jobs",
                            crate::util::json::Json::num(
                                live.len() as f64,
                            ),
                        ),
                    ]),
                )
            });
            env.round_span.store(
                round_tspan.unwrap_or(0),
                Ordering::Relaxed,
            );
            let exec_start = t0.elapsed().as_secs_f64();
            let (mut results, record_batches) =
                exec_round(&env, &live, rounds);
            let exec_end = t0.elapsed().as_secs_f64();
            if let (Some(s), Some(id)) = (&sink, round_tspan) {
                s.end(id);
            }
            for job in &live {
                let a = arrival_s(job.seq);
                let wait = (exec_start - a).max(0.0);
                let lat = (exec_end - a).max(0.0);
                qwait_h.record((wait * 1e6) as u64);
                lat_h.record((lat * 1e6) as u64);
                if req.open_loop.is_some() {
                    queue_waits.push(wait);
                    latencies.push(lat);
                }
            }
            // canonical-order append: trace bytes never depend on
            // worker scheduling
            for records in record_batches {
                store.append_trace(records);
            }
            jobs.append(&mut results);
        }
        rounds += 1;
    }
    if let (Some(s), Some(id)) = (&sink, req_span) {
        s.end(id);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    // --- ledger fan-in
    let mut tenants: Vec<TenantLedger> = (0..tenants_n)
        .map(|t| {
            let mut l = TenantLedger::new(t);
            l.submitted = submitted[t];
            l.admitted = admitted_per_tenant[t];
            l.rejected = queue.rejected_for(t);
            l.expired = expired_per_tenant[t];
            l
        })
        .collect();
    for j in &jobs {
        let l = &mut tenants[j.job.tenant];
        l.completed += 1;
        if j.shared {
            l.shared += 1;
        }
        l.profile_runs += j.profile_runs;
        l.llm_round_trips += j.llm_round_trips;
        l.measure_sims += j.measure_sims;
        l.wall_s += j.wall_s;
    }
    // per-tenant store namespace: jobs + bandit steps + profile
    // recomputations this run contributed under each tenant label
    for l in &tenants {
        let steps: usize = jobs
            .iter()
            .filter(|j| j.job.tenant == l.tenant && !j.shared)
            .map(|j| j.iterations)
            .sum();
        // a job is "warm" when it completed without any fresh work —
        // no profile recomputation, LLM round-trip or simulated
        // measurement (dedup shares count: their round-mate paid)
        let warm = jobs
            .iter()
            .filter(|j| {
                j.job.tenant == l.tenant
                    && j.profile_runs == 0
                    && j.llm_round_trips == 0
                    && j.measure_sims == 0
            })
            .count();
        store.tenant_add(
            &tenant_label(l.tenant),
            l.completed as u64,
            steps as u64,
            l.profile_runs,
            warm as u64,
        );
    }
    let executions = jobs.iter().filter(|j| !j.shared).count();
    let dedup_shares = jobs.len() - executions;
    let expired = expired_per_tenant.iter().sum();
    ServeReport {
        header,
        executions,
        dedup_shares,
        admitted: queue.admitted(),
        rejected: queue.rejected(),
        expired,
        jobs,
        tenants,
        rounds,
        wall_s,
        centroid_hits: cent.hits() - chits0,
        centroid_misses: cent.misses() - cmiss0,
        store_measure_sims: store
            .stats
            .measure_sims
            .load(Ordering::Relaxed)
            - sims0,
        store_measure_hits: store
            .stats
            .measure_hits
            .load(Ordering::Relaxed)
            - mhits0,
        store_llm_sims: store.stats.llm_sims.load(Ordering::Relaxed)
            - llm0,
        store_llm_hits: store.stats.llm_hits.load(Ordering::Relaxed)
            - lhits0,
        supervisor: None,
        open_loop: req.open_loop.map(|p| OpenLoopStats {
            rate: p.rate,
            duration_s: p.duration_s,
            queue_wait_s: queue_waits,
            latency_s: latencies,
        }),
    }
}

/// The single-supervisor real backend: queue → worker pool → real
/// `optimize_sched` runs; no leases, no checkpointing, no faults.
#[derive(Debug, Clone, Copy, Default)]
pub struct InProcess;

impl InProcess {
    /// Run the request and return the raw typed report (tests and
    /// embedders want the struct; [`ServeBackend::run`] wraps it into
    /// a [`ServeOutcome`]).
    pub fn run_report(&self, req: &ServeRequest,
                      store: &Arc<TraceStore>) -> ServeReport {
        run_serve(req, store, &mut |env, round, r| {
            run_round(env, round, r)
        })
    }
}

impl ServeBackend for InProcess {
    fn name(&self) -> &'static str {
        "inprocess"
    }

    fn run(&self, req: &ServeRequest,
           store: Option<&Arc<TraceStore>>) -> Result<ServeOutcome> {
        if !req.fault.is_none() {
            anyhow::bail!(
                "fault injection needs --backend sharded \
                 (the in-process backend has no leases to revoke)"
            );
        }
        let owned;
        let store = match store {
            Some(s) => s,
            None => {
                // storeless runs still share one in-memory session
                // store across tenants (cross-tenant dedup needs it)
                owned = Arc::new(TraceStore::in_memory());
                &owned
            }
        };
        let report = self.run_report(req, store);
        Ok(ServeOutcome {
            deterministic: report.deterministic_json(),
            ledger: Some(report.ledger_json()),
            supervisor: None,
            lines: report.summary_lines(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_req() -> ServeRequest {
        let mut req = ServeRequest::grid(
            3,
            3,
            10,
            BatchMode::Fixed(1),
            2,
            Device::H20,
            LlmProfile::DeepSeekV32,
            7,
        );
        req.workers = 2;
        req
    }

    #[test]
    fn deterministic_sections_are_byte_stable_across_workers_and_temp() {
        let run = |workers: usize, store: &Arc<TraceStore>| {
            let mut req = small_req();
            req.workers = workers;
            InProcess.run_report(&req, store)
        };
        let s1 = Arc::new(TraceStore::in_memory());
        let a = run(1, &s1);
        let s2 = Arc::new(TraceStore::in_memory());
        let b = run(4, &s2);
        assert_eq!(
            a.deterministic_json().dump(),
            b.deterministic_json().dump()
        );
        // warm pass over the same store: measured counters collapse,
        // deterministic bytes do not move
        let c = run(4, &s2);
        assert_eq!(
            a.deterministic_json().dump(),
            c.deterministic_json().dump()
        );
        assert_eq!(c.store_measure_sims, 0);
        assert_eq!(c.store_llm_sims, 0);
        assert!(b.store_measure_sims > 0);
    }

    #[test]
    fn overlapping_fingerprints_are_paid_once_per_round() {
        let store = Arc::new(TraceStore::in_memory());
        let report = InProcess.run_report(&small_req(), &store);
        assert_eq!(report.admitted, 9);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.expired, 0);
        assert_eq!(report.jobs.len(), 9);
        // within every round, executed jobs carry distinct fingerprints
        for round in 0..report.rounds {
            let mut seen = std::collections::HashSet::new();
            for j in report.jobs.iter().filter(|j| j.round == round) {
                if !j.shared {
                    assert!(
                        seen.insert(j.job.fingerprint),
                        "round {round} paid a fingerprint twice"
                    );
                }
            }
        }
        // 3 tenants × identical job lists: most completions are shares
        assert!(report.dedup_shares >= 4, "shares = {}", report.dedup_shares);
        assert!(report.executions + report.dedup_shares == 9);
        // measured wall-clock is present and positive
        assert!(report.wall_s > 0.0);
        assert!(report.job_wall_s() > 0.0);
        for j in report.jobs.iter().filter(|j| !j.shared) {
            assert!(j.wall_s > 0.0);
        }
    }

    #[test]
    fn admission_control_rejects_deterministically() {
        let mut req = small_req();
        req.queue_capacity = 5;
        req.per_tenant_quota = 2;
        let store = Arc::new(TraceStore::in_memory());
        let report = InProcess.run_report(&req, &store);
        // submission interleaves tenants: t0 j0, t1 j0, t2 j0, t0 j1,
        // t1 j1 — then the capacity of 5 is exhausted
        assert_eq!(report.admitted, 5);
        assert_eq!(report.rejected, 4);
        assert_eq!(report.jobs.len(), 5);
        let t2 = &report.tenants[2];
        assert_eq!(t2.submitted, 3);
        assert_eq!(t2.admitted, 1);
        assert_eq!(t2.rejected, 2);
        // and the rejection pattern replays bit-for-bit
        let store2 = Arc::new(TraceStore::in_memory());
        let again = InProcess.run_report(&req, &store2);
        assert_eq!(
            report.deterministic_json().dump(),
            again.deterministic_json().dump()
        );
    }

    #[test]
    fn deadlines_expire_at_pop_time() {
        let mut req = small_req();
        // 9 jobs, round_max 6: seqs 6..9 land in round 1. A deadline
        // of round 0 on tenant 2's last job expires it there.
        req.jobs[8].deadline_rounds = Some(0);
        let store = Arc::new(TraceStore::in_memory());
        let report = InProcess.run_report(&req, &store);
        assert_eq!(report.expired, 1);
        assert_eq!(report.jobs.len(), 8);
        assert_eq!(report.tenants[2].expired, 1);
        assert_eq!(report.tenants[2].completed, 2);
        // expired jobs replay deterministically too
        let store2 = Arc::new(TraceStore::in_memory());
        let again = InProcess.run_report(&req, &store2);
        assert_eq!(
            report.deterministic_json().dump(),
            again.deterministic_json().dump()
        );
        // a deadline the schedule meets changes nothing
        let mut relaxed = small_req();
        relaxed.jobs[8].deadline_rounds = Some(5);
        let store3 = Arc::new(TraceStore::in_memory());
        let met = InProcess.run_report(&relaxed, &store3);
        assert_eq!(met.expired, 0);
        assert_eq!(met.jobs.len(), 9);
    }

    #[test]
    fn hot_set_is_deterministic_and_bounded() {
        let suite = Suite::full(crate::eval::EXPERIMENT_SEED);
        let a = hot_set(&suite, 4);
        let b = hot_set(&suite, 4);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
        }
        // oversized variety clamps to the suite
        assert_eq!(hot_set(&suite, 10_000).len(), suite.len());
        assert_eq!(hot_set(&suite, 0).len(), 1);
    }

    #[test]
    fn job_fingerprints_separate_every_spec_axis() {
        let suite = Suite::full(crate::eval::EXPERIMENT_SEED);
        let t = &suite.tasks[0];
        let base = job_fingerprint(t, Device::H20,
                                   LlmProfile::DeepSeekV32, 10,
                                   BatchMode::Fixed(1), 7);
        assert_eq!(base, job_fingerprint(t, Device::H20,
                                         LlmProfile::DeepSeekV32, 10,
                                         BatchMode::Fixed(1), 7));
        assert_ne!(base, job_fingerprint(&suite.tasks[1], Device::H20,
                                         LlmProfile::DeepSeekV32, 10,
                                         BatchMode::Fixed(1), 7));
        assert_ne!(base, job_fingerprint(t, Device::A100,
                                         LlmProfile::DeepSeekV32, 10,
                                         BatchMode::Fixed(1), 7));
        assert_ne!(base, job_fingerprint(t, Device::H20,
                                         LlmProfile::DeepSeekV32, 11,
                                         BatchMode::Fixed(1), 7));
        assert_ne!(base, job_fingerprint(t, Device::H20,
                                         LlmProfile::DeepSeekV32, 10,
                                         BatchMode::Adaptive { min: 1, max: 8 },
                                         7));
        assert_ne!(base, job_fingerprint(t, Device::H20,
                                         LlmProfile::DeepSeekV32, 10,
                                         BatchMode::Fixed(1), 8));
    }
}
