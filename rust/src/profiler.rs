//! Hardware signatures h(k) and the NCU-style profiling cost model.
//!
//! Paper §3.2/Appendix A: the hardware signature is three Nsight-Compute
//! throughput metrics — SM, DRAM and L2 `pct_of_peak_sustained_elapsed`.
//! Profiling is expensive (≈10 s per kernel), which is why KernelBand
//! profiles only the *centroid* of each cluster during re-clustering and
//! caches results by code hash (§3.3, §3.6). This module reproduces both
//! the signature and the cost accounting so the Fig. 3 time-breakdown and
//! the representative-profiling ablation are measurable.

use std::collections::HashMap;


use crate::kernel::Counters;
use crate::strategy::{Resource, Strategy};

/// Seconds per NCU profiling run (paper §3.3: "≈10 s").
pub const PROFILE_COST_S: f64 = 10.0;

/// Default saturation threshold θ_sat (paper §3.6: 75%).
pub const THETA_SAT: f64 = 75.0;

/// The 3-metric NCU signature (percent of peak).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HardwareSignature {
    pub sm_pct: f64,
    pub dram_pct: f64,
    pub l2_pct: f64,
}

impl HardwareSignature {
    pub fn from_counters(c: &Counters) -> Self {
        HardwareSignature { sm_pct: c.sm_pct, dram_pct: c.dram_pct, l2_pct: c.l2_pct }
    }

    /// `h(k)[resource]`.
    pub fn get(&self, r: Resource) -> f64 {
        match r {
            Resource::Sm => self.sm_pct,
            Resource::Dram => self.dram_pct,
            Resource::L2 => self.l2_pct,
        }
    }

    /// The dominant bottleneck.
    pub fn bottleneck(&self) -> Resource {
        let mut best = Resource::Sm;
        let mut val = self.sm_pct;
        if self.dram_pct > val {
            best = Resource::Dram;
            val = self.dram_pct;
        }
        if self.l2_pct > val {
            best = Resource::L2;
        }
        best
    }

    /// Paper Eq. 5: strategy `s` is valid iff its target resource is not
    /// saturated.
    pub fn strategy_valid(&self, s: Strategy, theta_sat: f64) -> bool {
        self.get(s.target()) < theta_sat
    }

    /// Paper §3.4: remaining headroom score for the within-cluster
    /// softmax pick, `V_hw(k, s) = θ_sat − h(k)[Target(s)]`.
    pub fn headroom(&self, s: Strategy, theta_sat: f64) -> f64 {
        theta_sat - self.get(s.target())
    }
}

/// Code-hash-keyed profile cache with cost accounting.
///
/// This models the *cost* of NCU profiling (representatives only,
/// cached by code hash). The per-candidate signatures the hot loop
/// reads every iteration are memoized separately at candidate birth in
/// [`crate::policy::frontier::Frontier`] — `from_counters` is free in
/// this simulation, so the frontier memo carries no cost accounting,
/// while `Profiler` keeps charging the 10 s per *new* representative
/// profile that the Fig. 3 breakdown needs.
#[derive(Debug, Default, Clone)]
pub struct Profiler {
    cache: HashMap<u64, HardwareSignature>,
    /// Cumulative simulated NCU time spent (cache misses × 10 s).
    pub total_cost_s: f64,
    /// Cache statistics.
    pub misses: u64,
    pub hits: u64,
}

impl Profiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Profile a kernel: returns the NCU signature derived from its
    /// execution counters, charging [`PROFILE_COST_S`] on a cache miss.
    pub fn profile(&mut self, code_hash: u64, counters: &Counters)
                   -> HardwareSignature {
        if let Some(sig) = self.cache.get(&code_hash) {
            self.hits += 1;
            return *sig;
        }
        let sig = HardwareSignature::from_counters(counters);
        self.cache.insert(code_hash, sig);
        self.misses += 1;
        self.total_cost_s += PROFILE_COST_S;
        sig
    }

    /// Cost-free lookup of an already-profiled signature (the hook for
    /// persisting representative profiles in the trace store — see
    /// ROADMAP "Profiler cache ↔ store integration").
    pub fn cached(&self, code_hash: u64) -> Option<HardwareSignature> {
        self.cache.get(&code_hash).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counters(sm: f64, dram: f64, l2: f64) -> Counters {
        Counters { sm_pct: sm, dram_pct: dram, l2_pct: l2, ..Default::default() }
    }

    #[test]
    fn bottleneck_detection() {
        assert_eq!(
            HardwareSignature { sm_pct: 80.0, dram_pct: 40.0, l2_pct: 30.0 }
                .bottleneck(),
            Resource::Sm
        );
        assert_eq!(
            HardwareSignature { sm_pct: 20.0, dram_pct: 90.0, l2_pct: 30.0 }
                .bottleneck(),
            Resource::Dram
        );
        assert_eq!(
            HardwareSignature { sm_pct: 20.0, dram_pct: 30.0, l2_pct: 95.0 }
                .bottleneck(),
            Resource::L2
        );
    }

    #[test]
    fn saturated_resource_masks_strategy() {
        let sig = HardwareSignature { sm_pct: 80.0, dram_pct: 40.0, l2_pct: 30.0 };
        // Tiling targets SM which is saturated at θ=75
        assert!(!sig.strategy_valid(Strategy::Tiling, THETA_SAT));
        // Vectorization targets DRAM which has headroom
        assert!(sig.strategy_valid(Strategy::Vectorization, THETA_SAT));
        assert!(sig.strategy_valid(Strategy::AccessLayout, THETA_SAT));
    }

    #[test]
    fn headroom_matches_definition() {
        let sig = HardwareSignature { sm_pct: 50.0, dram_pct: 60.0, l2_pct: 10.0 };
        assert!((sig.headroom(Strategy::Fusion, 75.0) - 15.0).abs() < 1e-12);
        assert!((sig.headroom(Strategy::Tiling, 75.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn cache_avoids_recharging() {
        let mut p = Profiler::new();
        let c = counters(10.0, 20.0, 30.0);
        let s1 = p.profile(42, &c);
        let s2 = p.profile(42, &c);
        assert_eq!(s1, s2);
        assert_eq!(p.misses, 1);
        assert_eq!(p.hits, 1);
        assert!((p.total_cost_s - PROFILE_COST_S).abs() < 1e-12);
        p.profile(43, &c);
        assert!((p.total_cost_s - 2.0 * PROFILE_COST_S).abs() < 1e-12);
    }

    #[test]
    fn cached_lookup() {
        let mut p = Profiler::new();
        assert!(p.cached(7).is_none());
        p.profile(7, &counters(1.0, 2.0, 3.0));
        assert!(p.cached(7).is_some());
    }
}
